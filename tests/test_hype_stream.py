"""Streaming engine test harness (DESIGN.md §4h).

Three layers, per the issue:

1. **Oracle equivalence** — ``stream_oracle`` below is a pure-numpy
   streaming partitioner with the engine's exact semantics (same f32
   expression order, same first-max tie break, same hash, same CSR-order
   first-2048 truncation, batch-stale fringes, live sketch). At
   ``micro_batch=1`` the device engine must match it bit for bit
   (golden-hash-enforced), and stay hash-identical across repeated runs
   and across ``REPRO_PALLAS_INTERPRET`` modes.
2. **Property-based incremental consistency** — random op logs replayed
   through ``apply_updates`` must keep the exact-decrement sketch
   invariant (digest vs from-scratch recount), produce a valid bounded-
   slack assignment, and stay within a fixed km1 factor of a from-
   scratch ``hype_superstep`` run on the final graph; delete-then-
   reinsert restores the score cache exactly.
3. **Quality / resilience / memory** — the one-pass km1 ratio vs offline
   ``hype`` under ``STREAM_KM1_BOUND``, mid-stream snapshot+fatal-fault
   resume restoring bit-identically, fault retries, and the streaming
   byte planner.
"""
import dataclasses
import hashlib

import numpy as np
import pytest

from repro.core import membudget, metrics, refine, scoring
from repro.engines.superstep import SuperstepParams, hype_superstep_partition
from repro.core.hype_stream import (STREAM_KM1_BOUND, StreamParams,
                                    apply_updates, hype_stream_partition,
                                    recompute_sketch)
from repro.core.partition_api import balance_slack, partition
from repro.core.resilience import UnrecoverableFault
from repro.data.synthetic import community_hypergraph, powerlaw_hypergraph
from tests._hyp_compat import given, settings, st

TILE_CAP = scoring.L_BUCKETS[-1]


def _digest(a: np.ndarray) -> str:
    return hashlib.sha256(
        np.ascontiguousarray(a, dtype=np.int32).tobytes()).hexdigest()[:16]


@pytest.fixture(scope="module")
def hg():
    return powerlaw_hypergraph(400, 300, seed=5, max_edge=14,
                               max_degree=12)


# ------------------------------------------------------- the numpy oracle

def stream_oracle(hg, k: int, p: StreamParams) -> np.ndarray:
    """Reference streaming partitioner, exact engine semantics.

    Sequential within each micro-batch against the LIVE sketch/sizes;
    fringe-intersection counts against the fringe state at batch START
    (the device computes them in one fused kernel call before the
    commit loop); per-partition fringes are s-slot rings appended in
    batch order after each batch. All float math is float32 in the
    device program's exact expression order, ties break to the lowest
    partition id (np.argmax == jnp.argmax first occurrence).
    """
    n = hg.n
    order = (np.arange(n, dtype=np.int64) if p.order == "natural"
             else np.random.default_rng(p.seed).permutation(n))
    bits = p.sketch_bits
    sketch = np.zeros((k, 1 << bits), np.int32)
    sizes = np.zeros(k, np.int32)
    fringe = np.full((k, p.s), -1, np.int32)
    fpos = np.zeros(k, np.int64)
    a = np.full(n, -1, np.int32)
    cap = -(-n // k)
    inv_target = np.float32(k / max(n, 1))
    alpha = np.float32(p.balance_alpha)
    fw = np.float32(p.fringe_weight)
    adj = hg.vertex_adjacency()
    cursor = 0
    while cursor < n:
        batch = order[cursor:cursor + p.micro_batch]
        fr0 = fringe.copy()                    # batch-stale fringe state
        parts = np.empty(batch.size, np.int32)
        for i, v in enumerate(batch):
            v = int(v)
            es = hg.vertex_edges(v)[:TILE_CAP].astype(np.int64)
            nbrs = adj[1][adj[0][v]:adj[0][v + 1]][:TILE_CAP]
            b = scoring.stream_bucket(es, bits)
            conn = (sketch[:, b] > 0).sum(axis=1).astype(np.float32)
            fcnt = np.array([np.isin(nbrs, fr0[q]).sum()
                             for q in range(k)], dtype=np.float32)
            score = conn + fw * fcnt \
                - alpha * sizes.astype(np.float32) * inv_target
            score = np.where(sizes >= cap, -np.float32(np.inf), score)
            q = int(np.argmax(score))
            a[v] = q
            parts[i] = q
            sizes[q] += 1
            np.add.at(sketch[q], b, 1)
        for q in np.unique(parts):             # ring push, batch order
            vp = batch[parts == q].astype(np.int32)
            pos = int(fpos[q])
            if vp.size >= p.s:
                start = (pos + vp.size - p.s) % p.s
                fringe[q, (start + np.arange(p.s)) % p.s] = vp[-p.s:]
            else:
                fringe[q, (pos + np.arange(vp.size)) % p.s] = vp
            fpos[q] = pos + vp.size
        cursor += batch.size
    return a


# -------------------------------------------------- oracle equivalence

@pytest.mark.parametrize("k", [3, 7])
def test_micro_batch_1_bit_identical_to_oracle(hg, k):
    """The acceptance gate: golden-hash equality device vs numpy."""
    p = StreamParams(micro_batch=1, s=8, seed=2)
    dev = hype_stream_partition(hg, k, p)
    ora = stream_oracle(hg, k, p)
    assert _digest(dev) == _digest(ora), \
        f"k={k}: device diverged from the oracle on " \
        f"{int((dev != ora).sum())}/{hg.n} vertices"


@pytest.mark.parametrize("mb", [4, 32])
def test_micro_batches_match_oracle(hg, mb):
    """Larger batches only coarsen fringe staleness — the oracle models
    exactly that, so equality must hold at any micro_batch."""
    p = StreamParams(micro_batch=mb, s=8, seed=2)
    assert _digest(hype_stream_partition(hg, 5, p)) == \
        _digest(stream_oracle(hg, 5, p))


def test_golden_hash_deterministic_across_runs(hg):
    p = StreamParams(micro_batch=16, seed=4)
    h1 = _digest(hype_stream_partition(hg, 4, p))
    h2 = _digest(hype_stream_partition(hg, 4, p))
    assert h1 == h2


def test_golden_hash_across_interpret_modes(hg, monkeypatch):
    """The env override steers the kernel mode per call; the stream's
    hash must not depend on it. CPU backends only lower in interpret
    mode, so the compiled leg runs on accelerators only."""
    import jax

    p = StreamParams(micro_batch=8, seed=4)
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    h_default = _digest(hype_stream_partition(hg, 4, p))
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert _digest(hype_stream_partition(hg, 4, p)) == h_default
    if jax.default_backend() == "tpu":      # compiled mode exists there
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
        assert _digest(hype_stream_partition(hg, 4, p)) == h_default
    assert h_default == _digest(stream_oracle(hg, 4, p))


def test_natural_order_and_seeds_change_the_stream(hg):
    base = hype_stream_partition(hg, 4, StreamParams(seed=0))
    nat = hype_stream_partition(hg, 4, StreamParams(order="natural"))
    other = hype_stream_partition(hg, 4, StreamParams(seed=1))
    assert _digest(nat) != _digest(base)
    assert _digest(other) != _digest(base)


# ------------------------------------------------------- engine contract

@pytest.mark.parametrize("k", [2, 6])
def test_stream_contract(hg, k):
    a, stats = hype_stream_partition(hg, k, StreamParams(),
                                     return_stats=True)
    assert a.shape == (hg.n,) and a.dtype == np.int32
    assert a.min() >= 0 and a.max() < k
    sizes = np.bincount(a, minlength=k)
    assert sizes.max() <= -(-hg.n // k)           # hard capacity cap
    assert sizes.max() - sizes.min() <= balance_slack("hype_stream",
                                                      hg.n, k)
    assert stats.vertices == hg.n
    assert stats.device_calls == stats.micro_batches
    assert stats.vertices_per_s > 0


def test_registry_dispatch_forwards_stream_knobs(hg):
    a = partition(hg, 3, "hype_stream", seed=1, micro_batch=32,
                  sketch_bits=12, s=8)
    assert (a >= 0).all() and (a < 3).all()


def test_stream_sketch_matches_recount(hg):
    """After a full pass the device-maintained sketch equals the
    from-scratch recount — no drift across donated buffers."""
    _, state = hype_stream_partition(hg, 5, StreamParams(micro_batch=16),
                                     return_state=True)
    sk, sz = recompute_sketch(state.hg, state.assignment, 5,
                              state.params.sketch_bits)
    assert (sk == state.sketch).all() and (sz == state.sizes).all()


def test_param_validation(hg):
    with pytest.raises(ValueError, match="micro_batch"):
        hype_stream_partition(hg, 2, StreamParams(micro_batch=0))
    with pytest.raises(ValueError, match="sketch_bits"):
        hype_stream_partition(hg, 2, StreamParams(sketch_bits=30))
    with pytest.raises(ValueError, match="order"):
        hype_stream_partition(hg, 2, StreamParams(order="sorted"))
    with pytest.raises(ValueError, match="snapshot_dir"):
        hype_stream_partition(hg, 2, StreamParams(snapshot_every=3))
    with pytest.raises(ValueError, match="k"):
        hype_stream_partition(hg, 0)


def test_k1_and_empty_graph(hg):
    assert (hype_stream_partition(hg, 1) == 0).all()
    empty = powerlaw_hypergraph(0, 0, seed=0)
    assert hype_stream_partition(empty, 3).size == 0


# --------------------------------------------------------- quality bound

def test_one_pass_quality_within_documented_bound():
    """km1(hype_stream) / km1(offline hype) <= STREAM_KM1_BOUND on the
    quick generators — the regression gate for the scoring function."""
    graphs = [
        powerlaw_hypergraph(800, 600, seed=7, max_edge=20, max_degree=14),
        community_hypergraph(800, 550, 6, seed=7),
    ]
    for g in graphs:
        for k in (4, 16):
            base = metrics.k_minus_1(g, partition(g, k, "hype", seed=0))
            got = metrics.k_minus_1(
                g, partition(g, k, "hype_stream", seed=0))
            assert got <= STREAM_KM1_BOUND * max(base, 1), \
                f"n={g.n} k={k}: {got} vs offline {base}"


# ----------------------------------------- incremental mode: unit pieces

def _stream_state(hg, k=4, **kw):
    _, state = hype_stream_partition(
        hg, k, StreamParams(micro_batch=16, **kw), return_state=True)
    return state


def _assert_sketch_invariant(state):
    sk, sz = recompute_sketch(state.hg, state.assignment, state.k,
                              state.params.sketch_bits)
    got = state.sketch_digest()
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(sk).tobytes())
    h.update(np.ascontiguousarray(sz).tobytes())
    assert got == h.hexdigest()[:16], "sketch drifted from the recount"


def test_apply_updates_each_op_kind(hg):
    state = _stream_state(hg)
    apply_updates(state, [
        ("remove_vertex", 7),
        ("remove_edge", 3),
        ("add_edge", [1, 2, 10]),
        ("add_vertex", [0, 4]),
    ])
    _assert_sketch_invariant(state)
    assert state.assignment[7] == -1              # deleted slot stays
    assert state.hg.edge_pins(3).size == 0        # emptied, not renumbered
    assert state.hg.n == hg.n + 1                 # appended id = old n
    assert state.assignment[hg.n] >= 0            # new vertex re-admitted
    assert state.stats.inserts == 2 and state.stats.deletes == 2
    assert (state.fringe != 7).all()              # scrubbed from fringes


def test_apply_updates_unknown_op(hg):
    with pytest.raises(ValueError, match="unknown stream op"):
        apply_updates(_stream_state(hg), [("rename_vertex", 1)])


def test_full_assignment_fills_deterministically(hg):
    state = _stream_state(hg)
    apply_updates(state, [("remove_vertex", 3), ("remove_vertex", 11)])
    f1, f2 = state.full_assignment(), state.full_assignment()
    assert (f1 == f2).all()
    assert f1.min() >= 0 and f1.max() < state.k
    assert (f1[state.assignment >= 0]
            == state.assignment[state.assignment >= 0]).all()


def test_refine_candidates_restriction(hg):
    """The bounded-radius re-expansion contract: only candidate vertices
    may move, and an empty candidate set is a no-op."""
    a = partition(hg, 4, "random", seed=3)
    unchanged, _ = refine.refine_kway(hg, a, 4, passes=2,
                                      candidates=np.empty(0, np.int64))
    assert (unchanged == a).all()
    cand = np.arange(50, dtype=np.int64)
    refined, rs = refine.refine_kway(hg, a, 4, passes=2, candidates=cand,
                                     use_device=False)
    moved = np.flatnonzero(refined != a)
    assert np.isin(moved, cand).all()
    full, _ = refine.refine_kway(hg, a, 4, passes=2, use_device=False)
    assert np.flatnonzero(full != a).size >= moved.size


# --------------------------------- property-based incremental consistency

def _random_ops(hg, state, rng, n_ops):
    """A valid random op log against the live state (ids checked against
    the state as each op is generated, exactly as a caller would)."""
    ops = []
    sim_n, sim_m = state.hg.n, state.hg.m
    alive_v = set(np.flatnonzero(state.assignment >= 0).tolist())
    alive_e = set(np.flatnonzero(np.diff(state.hg.e2v_indptr) > 0).tolist())
    for _ in range(n_ops):
        kind = rng.integers(0, 4)
        if kind == 0 and len(alive_v) > state.k * 2:
            v = int(rng.choice(sorted(alive_v)))
            ops.append(("remove_vertex", v))
            alive_v.discard(v)
        elif kind == 1 and len(alive_e) > 2:
            e = int(rng.choice(sorted(alive_e)))
            ops.append(("remove_edge", e))
            alive_e.discard(e)
        elif kind == 2 and len(alive_v) >= 2:
            pins = rng.choice(sorted(alive_v),
                              size=int(rng.integers(2, 6)),
                              replace=False)
            ops.append(("add_edge", [int(x) for x in pins]))
            alive_e.add(sim_m)
            sim_m += 1
        elif len(alive_e) >= 1:
            es = rng.choice(sorted(alive_e),
                            size=min(int(rng.integers(1, 4)),
                                     len(alive_e)),
                            replace=False)
            ops.append(("add_vertex", [int(x) for x in es]))
            alive_v.add(sim_n)
            sim_n += 1
    return ops


def _check_random_log_consistency(seed):
    """Any valid op log leaves: the exact sketch invariant, a valid
    bounded-slack assignment over the live vertices, and km1 within a
    fixed factor of a from-scratch hype_superstep run on the final
    graph (the issue's acceptance property)."""
    hg = powerlaw_hypergraph(120, 90, seed=11, max_edge=10, max_degree=8)
    k = 3
    _, state = hype_stream_partition(hg, k, StreamParams(micro_batch=8),
                                     return_state=True)
    rng = np.random.default_rng(seed)
    apply_updates(state, _random_ops(hg, state, rng, 15))
    _assert_sketch_invariant(state)
    live = state.assignment >= 0
    assert state.assignment[live].max() < k
    sizes = np.bincount(state.assignment[live], minlength=k)
    assert sizes.max() - sizes.min() <= k, sizes
    # quality vs from-scratch on the final graph: the incremental path
    # must not collapse. 2x the one-pass bound + a small-graph absolute
    # slack keeps this a collapse detector, not a tie requirement.
    full = state.full_assignment()
    km_inc = metrics.k_minus_1(state.hg, full)
    scratch = hype_superstep_partition(state.hg, k,
                                       SuperstepParams(seed=0))
    km_scr = metrics.k_minus_1(state.hg, scratch)
    assert km_inc <= 2 * STREAM_KM1_BOUND * max(km_scr, 1) + 30, \
        (km_inc, km_scr)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_apply_updates_random_log_consistency(seed):
    """Fixed-seed instances of the property — always run, even without
    hypothesis (the container's shim skips @given tests)."""
    _check_random_log_consistency(seed)


@given(seed=st.integers(min_value=0, max_value=10**6))
@settings(max_examples=10, deadline=None)
def test_apply_updates_random_log_consistency_hypothesis(seed):
    _check_random_log_consistency(seed)


def _check_delete_reinsert(v):
    """Deleting a vertex and re-adding it with the same memberships must
    restore the score cache exactly: zero residue against the recount,
    and — when the re-admission lands in the original partition — the
    (sketch, sizes) digest equals the pre-delete digest bit for bit
    (buckets depend only on edge ids, which are stable)."""
    hg = powerlaw_hypergraph(400, 300, seed=5, max_edge=14, max_degree=12)
    _, state = hype_stream_partition(hg, 4, StreamParams(micro_batch=8),
                                     return_state=True)
    edges = state.hg.vertex_edges(int(v)).tolist()
    part_before = int(state.assignment[v])
    digest_before = state.sketch_digest()
    apply_updates(state, [("remove_vertex", int(v))])
    _assert_sketch_invariant(state)
    apply_updates(state, [("add_vertex", edges)])
    _assert_sketch_invariant(state)
    new_id = state.hg.n - 1
    if int(state.assignment[new_id]) == part_before \
            and state.stats.refine_moves == 0 \
            and state.stats.rebalance_moves == 0:
        assert state.sketch_digest() == digest_before


@pytest.mark.parametrize("v", [0, 17, 250])
def test_delete_then_reinsert_restores_score_cache(v):
    _check_delete_reinsert(v)


@given(v=st.integers(min_value=0, max_value=399))
@settings(max_examples=10, deadline=None)
def test_delete_then_reinsert_restores_score_cache_hypothesis(v):
    _check_delete_reinsert(v)


# --------------------------------------------- resilience: faults, resume

def test_fault_retry_replays_batch_bit_identically(hg):
    p0 = StreamParams(micro_batch=16, seed=3)
    ref = hype_stream_partition(hg, 4, p0)
    a, st2 = hype_stream_partition(
        hg, 4, dataclasses.replace(p0, fault_plan="dispatch@2"),
        return_state=True)
    assert (a == ref).all()
    assert st2.stats.faults_injected == 1 and st2.stats.retries == 1


def test_fatal_fault_raises(hg):
    with pytest.raises(UnrecoverableFault):
        hype_stream_partition(hg, 4, StreamParams(
            micro_batch=16, fault_plan="dispatch@2:fatal"))


def test_env_fault_plan_reaches_stream(hg, monkeypatch):
    """The CI streaming job runs under REPRO_FAULT_PLAN=dispatch@2; the
    injected fault must be retried without changing the result."""
    ref = hype_stream_partition(hg, 4, StreamParams(seed=3))
    monkeypatch.setenv("REPRO_FAULT_PLAN", "dispatch@2")
    a, state = hype_stream_partition(hg, 4, StreamParams(seed=3),
                                     return_state=True)
    assert (a == ref).all()
    assert state.stats.faults_injected == 1


def test_snapshot_resume_is_bit_identical(hg, tmp_path):
    """Kill the stream mid-pass with a fatal fault; resuming from the
    last snapshot must finish bit-identically to the uninterrupted
    run — the issue's mid-stream restore acceptance."""
    d = str(tmp_path)
    p0 = StreamParams(micro_batch=16, seed=3)
    ref = hype_stream_partition(hg, 4, p0)
    p_crash = dataclasses.replace(p0, snapshot_every=3, snapshot_dir=d,
                                  fault_plan="dispatch@8:fatal")
    with pytest.raises(UnrecoverableFault):
        hype_stream_partition(hg, 4, p_crash)
    p_resume = dataclasses.replace(p0, snapshot_every=3, snapshot_dir=d,
                                   resume=d)
    a, state = hype_stream_partition(hg, 4, p_resume, return_state=True)
    assert (a == ref).all()
    assert state.stats.resumed_at == 6          # last multiple-of-3 batch
    assert state.stats.restore_s >= 0
    _assert_sketch_invariant(state)


def test_cross_config_snapshot_cold_starts(hg, tmp_path):
    """A snapshot from different stream knobs must not be adopted — the
    replay would diverge from its prefix."""
    d = str(tmp_path)
    hype_stream_partition(hg, 4, StreamParams(
        micro_batch=8, seed=1, snapshot_every=2, snapshot_dir=d))
    a, state = hype_stream_partition(hg, 4, StreamParams(
        micro_batch=16, seed=3, resume=d), return_state=True)
    assert state.stats.resumed_at == -1         # cold start
    assert (a == hype_stream_partition(
        hg, 4, StreamParams(micro_batch=16, seed=3))).all()


# ------------------------------------------------- streaming byte planner

def test_stream_memory_planner_ladder():
    spec = membudget.StreamSpec(n=1000, k=8, micro_batch=64,
                                sketch_bits=16, s=16, tile_l=2048)
    full = membudget.estimate_stream_bytes(spec)
    mb, tl, planned, fits = membudget.plan_stream_memory(spec, None)
    assert (mb, tl, fits) == (64, 2048, True)   # rung 0 untouched
    mb, tl, planned, fits = membudget.plan_stream_memory(spec, full // 2)
    assert fits and planned <= full // 2
    assert mb < 64 and tl == 2048               # halve micro-batch first
    mb, tl, planned, fits = membudget.plan_stream_memory(spec, 1)
    assert not fits and (mb, tl) == (1, scoring.L_BUCKETS[0])


def test_stream_engine_honors_budget(hg):
    spec = membudget.StreamSpec(n=hg.n, k=4, micro_batch=64,
                                sketch_bits=16, s=16, tile_l=2048)
    budget = membudget.estimate_stream_bytes(spec) // 2
    a, stats = hype_stream_partition(
        hg, 4, StreamParams(micro_batch=64, mem_budget=budget),
        return_stats=True)
    assert (a >= 0).all()
    assert stats.plan_micro_batch < 64
    assert 0 < stats.planned_bytes <= budget


def test_stream_budget_env_var(hg, monkeypatch):
    monkeypatch.setenv("REPRO_DEVICE_MEM_BUDGET", "1MB")
    a, stats = hype_stream_partition(hg, 4, StreamParams(micro_batch=64),
                                     return_stats=True)
    assert (a >= 0).all()
    assert stats.plan_micro_batch < 64


# ------------------------------------------------- hypergraph delta APIs

def test_delta_apis_preserve_ids(hg):
    g1 = hg.with_edges([[0, 1, 2]])
    assert g1.m == hg.m + 1 and g1.n == hg.n
    assert sorted(g1.edge_pins(hg.m).tolist()) == [0, 1, 2]
    g1.validate()
    g2 = g1.with_vertices([[0, int(hg.m)]])
    assert g2.n == hg.n + 1
    assert hg.m in g2.vertex_edges(hg.n).tolist()
    g2.validate()
    g3 = g2.without_edges([0])
    assert g3.m == g2.m and g3.edge_pins(0).size == 0
    assert (g3.edge_pins(1) == g2.edge_pins(1)).all()
    g3.validate()
    g4 = g3.without_vertices([5])
    assert g4.n == g3.n and g4.vertex_edges(5).size == 0
    g4.validate()
    assert g4.fingerprint() != hg.fingerprint()
