"""Property tests for the MoE dispatch/combine invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from _hyp_compat import given, settings, st

from repro.models.moe import MoEConfig, init_moe_layer, moe_ffn
from repro.models.transformer import TransformerConfig


def _cfg(E, K, cf=8.0):
    return TransformerConfig(
        name="t", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        d_head=16, d_ff=16, vocab=64, moe=MoEConfig(E, K, cf),
        remat=False, dtype=jnp.float32)


@given(st.integers(2, 8), st.integers(1, 2), st.integers(0, 20))
@settings(max_examples=12, deadline=None)
def test_moe_finite_and_shape(E, K, seed):
    K = min(K, E)
    cfg = _cfg(E, K)
    lp = init_moe_layer(jax.random.PRNGKey(seed), 32, 16, cfg.moe,
                        jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 16, 32))
    out, aux = moe_ffn(cfg, lp, x)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 0.99  # Switch aux loss lower bound is ~1 (balanced)


def test_moe_huge_capacity_no_drops_matches_dense_mixture():
    """With capacity >> tokens, MoE output = weighted sum of expert MLPs."""
    cfg = _cfg(4, 2, cf=64.0)
    lp = init_moe_layer(jax.random.PRNGKey(0), 32, 16, cfg.moe, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
    out, _ = moe_ffn(cfg, lp, x)

    # dense oracle: route every token through its top-k experts directly
    logits = x.astype(jnp.float32) @ lp["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_i = jax.lax.top_k(probs, 2)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    expect = jnp.zeros_like(x)
    for e in range(4):
        h = jax.nn.silu(x @ lp["moe_gate"][e]) * (x @ lp["moe_up"][e])
        y = h @ lp["moe_down"][e]
        w = jnp.where(top_i == e, top_p, 0.0).sum(-1)
        expect = expect + y * w[..., None]
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-4, rtol=1e-3)


def test_moe_zero_capacity_drops_everything():
    cfg = dataclasses.replace(_cfg(4, 2), moe_cf_override=1e-9)
    lp = init_moe_layer(jax.random.PRNGKey(0), 32, 16, cfg.moe, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
    out, _ = moe_ffn(cfg, lp, x)
    # capacity 1 slot per expert: most tokens dropped, output tiny but
    # finite; the residual connection in the block keeps training sane
    assert np.isfinite(np.asarray(out)).all()


def test_moe_shard_c_constraint_is_noop_without_mesh():
    base = _cfg(4, 2)
    sc = dataclasses.replace(base, moe_shard_c=True)
    lp = init_moe_layer(jax.random.PRNGKey(0), 32, 16, base.moe,
                        jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    a, _ = moe_ffn(base, lp, x)
    b, _ = moe_ffn(sc, lp, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))
