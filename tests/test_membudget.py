"""Device-memory budgeting (DESIGN.md §4g): budget parsing and
resolution, the pure byte-model planner and its rung ladder, the paged
adjacency image, and the engine-level OOM recovery contract — a budget
tight enough to force re-tiling rungs must complete on the SAME engine
with results bit-identical to the unconstrained run, and real allocator
failures must converge on the injected-fault recovery path."""
import dataclasses
import hashlib
import signal

import numpy as np
import pytest

from repro.core import membudget as mb
from repro.core import metrics, partition_api, resilience
from repro.engines import runtime, superstep
from repro.engines.superstep import (SuperstepParams,
                                     hype_superstep_partition)
from repro.core.hypergraph import Hypergraph
from repro.data.synthetic import powerlaw_hypergraph


def _digest(a: np.ndarray) -> str:
    return hashlib.sha256(
        np.ascontiguousarray(a, dtype=np.int32).tobytes()).hexdigest()[:16]


@pytest.fixture(autouse=True)
def _hang_guard():
    """Same 180 s wall-clock guard as test_resilience: a wedged retry
    loop must fail the test, not hang the suite."""
    def _alarm(signum, frame):
        raise TimeoutError("test exceeded the 180 s membudget guard")

    old = signal.signal(signal.SIGALRM, _alarm)
    signal.alarm(180)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture(scope="module")
def hg():
    return powerlaw_hypergraph(600, 400, seed=11, max_edge=30,
                               max_degree=20)


@pytest.fixture(scope="module")
def base_d2(hg):
    """Unconstrained depth-2 baseline (assignment, stats)."""
    return hype_superstep_partition(
        hg, 5, SuperstepParams(seed=0, t=8), return_stats=True)


@pytest.fixture(scope="module")
def base_d1(hg):
    """Unconstrained depth-1 (lock-step) baseline assignment."""
    return hype_superstep_partition(
        hg, 5, SuperstepParams(seed=0, t=8, pipeline_depth=1))


# -------------------------------------------------- parsing / taxonomy

def test_parse_budget():
    assert mb.parse_budget(None) is None
    assert mb.parse_budget(0) is None
    assert mb.parse_budget("") is None
    assert mb.parse_budget(" none ") is None
    assert mb.parse_budget("unlimited") is None
    assert mb.parse_budget(12345) == 12345
    assert mb.parse_budget("512") == 512
    assert mb.parse_budget("2KB") == 2_000
    assert mb.parse_budget("2KiB") == 2048
    assert mb.parse_budget("512MB") == 512 * 10 ** 6
    assert mb.parse_budget("1.5GiB") == int(1.5 * (1 << 30))
    assert mb.parse_budget("2g") == 2 * 10 ** 9
    with pytest.raises(ValueError, match="unparseable"):
        mb.parse_budget("lots")
    with pytest.raises(ValueError, match="unparseable"):
        mb.parse_budget("12 parsecs")


def test_is_oom_error():
    class XlaRuntimeError(RuntimeError):
        pass

    class OutOfMemoryError(RuntimeError):
        pass

    assert mb.is_oom_error(MemoryError())
    assert mb.is_oom_error(
        XlaRuntimeError("RESOURCE_EXHAUSTED: Out of memory allocating "
                        "1073741824 bytes"))
    assert mb.is_oom_error(RuntimeError("device out of memory"))
    assert mb.is_oom_error(OutOfMemoryError("alloc failed"))
    assert not mb.is_oom_error(ValueError("bad tile width"))
    assert not mb.is_oom_error(RuntimeError("INVALID_ARGUMENT: shape"))


def test_resolve_budget_priority(monkeypatch):
    monkeypatch.setenv(mb.ENV_BUDGET, "1MB")
    assert mb.resolve_budget("2KiB") == 2048          # knob wins
    assert mb.resolve_budget(None) == 10 ** 6         # env next
    # knob 0/"none" is an EXPLICIT unconstrained, beating the env var
    assert mb.resolve_budget(0) is None
    assert mb.resolve_budget("none") is None
    monkeypatch.delenv(mb.ENV_BUDGET)
    # no knob, no env: backend probe (None on stat-less CPU backends)
    probed = mb.resolve_budget(None)
    assert probed is None or probed > 0


# --------------------------------------------------------- pure planner

def _spec(**kw):
    base = dict(n=600, adj_pins=20_000, k=5, rows=8, pool_cap=64, t=8,
                tile_l=512, pipeline_depth=2)
    base.update(kw)
    return mb.MemSpec(**base)


def test_estimate_bytes_monotone():
    """Planned bytes are monotone non-decreasing in every size input."""
    base = _spec()
    b0 = mb.estimate_plan_bytes(base)
    assert b0 > 0
    for field, bump in [("n", 600), ("adj_pins", 50_000), ("k", 11),
                        ("rows", 24), ("pool_cap", 128), ("t", 24),
                        ("tile_l", 2048), ("pipeline_depth", 3)]:
        bigger = _spec(**{field: bump})
        assert mb.estimate_plan_bytes(bigger) >= b0, field
    # and in the override knobs the ladder actually varies
    assert mb.estimate_plan_bytes(base, tile_l=128) <= b0
    assert mb.estimate_plan_bytes(base, g_chunk=2) <= b0
    assert mb.estimate_plan_bytes(base, pipeline_depth=1) <= b0
    assert mb.estimate_plan_bytes(
        base, spill_cache=True) <= mb.estimate_plan_bytes(base)


def test_rung_ladder_deterministic_and_cumulative():
    spec = _spec()
    a = mb.rung_ladder(spec)
    b = mb.rung_ladder(spec)
    assert a == b                                    # deterministic
    assert [p.rung for p in a] == list(range(len(a)))
    assert a[0].tile_l == spec.tile_l and a[0].g_chunk == 1
    assert not a[0].spill_cache and not a[0].paged
    # the documented shedding order: chunk, tile_l, depth, spill, paged
    assert a[1].g_chunk == 2
    assert a[2].tile_l < spec.tile_l                 # one bucket down
    assert a[3].pipeline_depth == 1
    assert a[4].spill_cache and a[4].g_chunk == 1    # full-stack program
    assert a[5].paged and not a[5].spill_cache and a[5].page_bytes > 0
    # the width/depth rungs each shed bytes monotonically; the spill
    # rung trades the score cache (n*4) against re-widening the gather
    # (its program has no chunked variant), so it only promises to stay
    # below rung 0 — and the paged rung pays a resident-page floor
    planned = [p.planned_bytes for p in a]
    assert planned[:4] == sorted(planned[:4], reverse=True)
    assert len(set(planned[:4])) == 4              # strictly shedding
    assert planned[4] < planned[0]


def test_rung_ladder_feature_gating():
    plans = mb.rung_ladder(_spec(), mb.SHARDED_FEATURES)
    assert all(not p.spill_cache and not p.paged and p.g_chunk == 1
               for p in plans)
    assert any(p.tile_l < 512 for p in plans)
    assert any(p.pipeline_depth == 1 for p in plans)
    # tile_l already at the smallest bucket: the drop rung is skipped
    small = mb.rung_ladder(_spec(tile_l=32))
    assert all(p.tile_l == 32 for p in small)


def test_plan_memory_picks_first_fitting_rung():
    spec = _spec()
    plans = mb.rung_ladder(spec)
    # unconstrained -> rung 0, today's tile choices
    p0 = mb.plan_memory(spec, None)
    assert p0.rung == 0 and p0.fits and p0.tile_l == spec.tile_l
    assert mb.plan_memory(spec, plans[0].planned_bytes * 10).rung == 0
    # a budget exactly at rung 2's bytes excludes rungs 0-1
    chosen = mb.plan_memory(spec, plans[2].planned_bytes)
    assert chosen.rung == 2 and chosen.fits
    assert chosen.planned_bytes <= plans[2].planned_bytes


def test_plan_memory_best_effort_when_nothing_fits():
    spec = _spec()
    plan = mb.plan_memory(spec, 1)
    assert not plan.fits
    assert plan.rung == mb.rung_ladder(spec)[-1].rung


def test_plan_memory_rung_start_and_exhaustion():
    spec = _spec()
    assert mb.plan_memory(spec, None, rung_start=2).rung == 2
    with pytest.raises(mb.MemoryLadderExhausted):
        mb.plan_memory(spec, None, rung_start=99)


def test_dtype_narrowing_helpers():
    assert mb.device_ptr_nbytes(2 ** 31 - 1) == 4
    assert mb.device_ptr_nbytes(2 ** 31) == 8
    assert mb.narrow_len_dtype(2 ** 15 - 1) is np.int16
    assert mb.narrow_len_dtype(2 ** 15) is np.int32


# ------------------------------------------------------- paged adjacency

def _synthetic_csr(n=200_000, deg=4, seed=0):
    rng = np.random.default_rng(seed)
    indptr = (np.arange(n + 1, dtype=np.int64) * deg)
    indices = rng.integers(0, n, size=n * deg).astype(np.int32)
    return indptr, indices


def test_paged_gather_matches_dense_reference():
    indptr, indices = _synthetic_csr()
    stats = runtime.BatchedStats()
    pa = mb.PagedAdjacency((indptr, indices), page_bytes=1, stats=stats)
    assert pa.n_chunks > 4                    # floor forces real paging
    rng = np.random.default_rng(1)
    ids = rng.integers(0, pa.n, size=64).astype(np.int32)
    ids[::7] = -1                             # pad rows stay all -1
    tile_l = 16
    got = np.asarray(pa.gather(ids, tile_l))
    want = np.full((ids.size, tile_l), -1, np.int32)
    for i, v in enumerate(ids):
        if v < 0:
            continue
        row = indices[indptr[v]:indptr[v + 1]][:tile_l]
        want[i, :row.size] = row
    np.testing.assert_array_equal(got, want)
    assert stats.page_uploads > 0 and stats.page_bytes > 0


def test_paged_lru_hits_and_evictions():
    indptr, indices = _synthetic_csr()
    stats = runtime.BatchedStats()
    pa = mb.PagedAdjacency((indptr, indices), page_bytes=1, stats=stats)
    # touch every chunk: more chunks than fit under the byte budget
    ids = (np.arange(pa.n_chunks) * pa.chunk_rows).astype(np.int32)
    pa.gather(ids, 8)
    assert stats.page_uploads == pa.n_chunks
    assert stats.page_evictions > 0
    assert pa.resident_bytes <= pa.page_bytes
    # re-gathering the most recent chunk is a hit, not an upload
    up = stats.page_uploads
    pa.gather(ids[-1:], 8)
    assert stats.page_uploads == up and stats.page_hits >= 1


# -------------------------------------------------- engine-level contract

def test_unconstrained_budget_is_rung0_golden(hg, base_d1):
    """mem_budget='none' is an explicit unconstrained run: rung 0,
    today's tile choices, bit-identical output."""
    a, st = hype_superstep_partition(
        hg, 5, SuperstepParams(seed=0, t=8, pipeline_depth=1,
                               mem_budget="none"), return_stats=True)
    assert _digest(a) == _digest(base_d1)
    assert st.plan_rung == 0 and st.mem_retries == 0
    assert st.peak_bytes_planned > 0
    assert st.peak_bytes_observed > 0


def test_tight_budget_forces_rung_without_degradation(hg, base_d2):
    """The ISSUE acceptance bar: a budget below rung 0's planned bytes
    forces >= 1 re-tiling rung, the engine completes WITHOUT engine
    degradation, and the result matches the unconstrained run
    bit-identically (so km1 matches exactly too)."""
    base_a, base_st = base_d2
    budget = int(base_st.peak_bytes_planned) - 1
    a, st = hype_superstep_partition(
        hg, 5, SuperstepParams(seed=0, t=8, mem_budget=budget),
        return_stats=True)
    assert st.plan_rung >= 1                  # planned below rung 0
    assert st.mem_retries == 0                # planning, not crashing
    assert st.fallbacks == 0                  # same engine throughout
    assert _digest(a) == _digest(base_a)
    assert metrics.k_minus_1(hg, a) == metrics.k_minus_1(hg, base_a)


@pytest.mark.parametrize("rung", [1, 2, 3, 4, 5])
def test_forced_rungs_bit_exact(hg, base_d2, base_d1, rung):
    """Every rung of the ladder reproduces its reference exactly:
    rungs 1-2 keep the depth-2 schedule (phase chunking and the tile_l
    drop are bit-exact on this graph), rungs 3-5 clamp the pipeline to
    depth 1 and land on the lock-step baseline."""
    a, st = superstep.run_pipeline(
        hg, 5, SuperstepParams(seed=0, t=8, rows=8), mem_rung=rung)
    want = base_d2[0] if rung <= 2 else base_d1
    assert _digest(a) == _digest(want), rung
    assert st.stats.plan_rung == rung
    if rung == 5:
        assert st.stats.page_uploads > 0


def test_paged_rung_runs_csr_exceeding_budget(hg, base_d1):
    """A budget smaller than the CSR image itself: only the paged rung
    can host the graph, and it still reproduces the lock-step result."""
    a, st = hype_superstep_partition(
        hg, 5, SuperstepParams(seed=0, t=8, mem_budget="24KB"),
        return_stats=True)
    assert st.plan_rung == 5
    assert st.page_uploads > 0
    assert _digest(a) == _digest(base_d1)


def test_injected_and_real_oom_converge(hg, base_d2, monkeypatch):
    """The satellite contract: a real RESOURCE_EXHAUSTED at the upload
    site and the injected non-fatal 'oom' fault take the SAME recovery
    path — one same-engine retry at rung 1 — and converge on identical
    assignments (which also equal the fault-free run's)."""
    inj, sti = hype_superstep_partition(
        hg, 5, SuperstepParams(seed=0, t=8, fault_plan="oom"),
        return_stats=True)

    calls = {"n": 0}
    real = Hypergraph.device_adjacency

    def failing_once(self, *a, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError(
                "RESOURCE_EXHAUSTED: Out of memory allocating "
                "9999999999 bytes")
        return real(self, *a, **kw)

    monkeypatch.setattr(Hypergraph, "device_adjacency", failing_once)
    rea, str_ = hype_superstep_partition(
        hg, 5, SuperstepParams(seed=0, t=8), return_stats=True)

    assert sti.mem_retries == 1 == str_.mem_retries
    assert sti.plan_rung == str_.plan_rung == 1
    assert _digest(inj) == _digest(rea) == _digest(base_d2[0])


def test_oom_at_dispatch_warm_starts_next_rung(hg):
    """'oom@N' pins the allocation failure to dispatch ordinal N: the
    retry warm-starts from the partial assignment and still delivers a
    complete, balanced partition on the same engine."""
    a, st = hype_superstep_partition(
        hg, 5, SuperstepParams(seed=0, t=8, fault_plan="oom@2"),
        return_stats=True)
    assert st.mem_retries == 1 and st.plan_rung >= 1
    assert st.fallbacks == 0
    assert (a >= 0).all() and (a < 5).all()
    sizes = np.bincount(a, minlength=5)
    assert sizes.max() - sizes.min() <= 1


def test_oom_ladder_exhaustion_escalates(hg):
    """One injected OOM per rung: after the last rung the engine raises
    UnrecoverableFault (for the engine-degradation ladder), never an
    infinite retry loop."""
    n_rungs = len(mb.rung_ladder(mb.MemSpec(
        n=hg.n, adj_pins=1, k=5, rows=8, pool_cap=64, t=8,
        tile_l=512, pipeline_depth=2)))
    plan = resilience.FaultPlan(
        [resilience.FaultSpec("oom", 0) for _ in range(n_rungs)])
    with pytest.raises(resilience.UnrecoverableFault,
                       match="memory rungs exhausted"):
        hype_superstep_partition(
            hg, 5, SuperstepParams(seed=0, t=8, fault_plan=plan))
    assert not plan.specs                      # every rung consumed one


def test_mem_budget_knob_via_partition(hg, base_d1):
    """The registered knob path: mem_budget forwarded through
    partition() reaches the engine's planner."""
    a = partition_api.partition(hg, 5, "hype_superstep", seed=0, t=8,
                                mem_budget="24KB")
    assert _digest(a) == _digest(base_d1)
