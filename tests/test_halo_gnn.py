"""halo_gnn: the §Perf C variant lowers and trains on a small mesh."""
import subprocess
import sys

import pytest

pytest.importorskip("repro.dist", reason="repro.dist not built yet")

SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import sys; sys.path.insert(0, 'src')
import jax, jax.numpy as jnp, numpy as np
from repro.dist.halo_gnn import halo_gatedgcn_specs, make_halo_gatedgcn_step

k = 8
mesh = jax.make_mesh((4, 2), ('data', 'model'))
specs, dims = halo_gatedgcn_specs(1024, 4096, 12, k, beta=0.5, d_hidden=16)
step, p_abs, o_abs = make_halo_gatedgcn_step(mesh, k, 12, 16, 2, 5)

rng = np.random.default_rng(0)
def concretize(s):
    if s.dtype == jnp.int32:
        hi = dims['n_local']
        return jnp.asarray(rng.integers(0, hi, s.shape).astype(np.int32))
    if s.dtype == jnp.bool_:
        return jnp.ones(s.shape, bool)
    return jnp.asarray(rng.normal(size=s.shape).astype(np.float32) * 0.1)
params = jax.tree.map(concretize, p_abs)
opt = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), o_abs,
                   is_leaf=lambda x: hasattr(x, 'shape'))
batch = {kk: concretize(v) for kk, v in specs.items()}
batch['labels'] = batch['labels'] % 5
batch['edge_src'] = batch['edge_src'] % (dims['n_local'] + k * dims['b_max'])
with mesh:
    p2, o2, m = jax.jit(step)(params, opt, batch)
loss = float(m['loss'])
assert np.isfinite(loss), loss
# loss decreases over a few steps
for _ in range(5):
    p2, o2, m = jax.jit(step)(p2, o2, batch)
assert float(m['loss']) < loss
print('halo gnn OK', loss, float(m['loss']))
"""


def test_halo_gnn_trains_multidevice():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=560, cwd=".")
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    assert "halo gnn OK" in r.stdout
