"""Integration: every GNN arch trains (loss decreases) on learnable data,
and the sampled-minibatch path composes with the real neighbor sampler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data.graphs import NeighborSampler, build_graph_batch, random_graph
from repro.models.gnn import gnn_loss, init_gnn_params
from repro.train.optimizer import AdamWConfig, adamw_update, init_adamw

GNN = ["gatedgcn", "meshgraphnet", "schnet", "graphsage-reddit"]


@pytest.mark.parametrize("arch_id", GNN)
def test_gnn_loss_decreases(arch_id):
    arch = get_arch(arch_id)
    cfg = arch.build_cfg(reduced=True)
    n, e = 200, 800
    src, dst = random_graph(n, e / n, seed=3)
    batch_np = build_graph_batch(n, src, dst, cfg.d_in, cfg.n_classes,
                                 seed=3, pad_nodes=256, pad_edges=1024)
    batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
    params = init_gnn_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                          weight_decay=0.0)
    opt = init_adamw(params, opt_cfg)

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(lambda p_: gnn_loss(p_, batch, cfg))(p)
        p, o, _ = adamw_update(g, o, p, opt_cfg)
        return p, o, loss

    losses = []
    for _ in range(60):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    # graphsage's per-layer L2 normalization caps the step-wise progress
    thresh = 0.9 if arch_id == "graphsage-reddit" else 0.7
    assert losses[-1] < thresh * losses[0], (arch_id, losses[0], losses[-1])


def test_sampled_minibatch_trains_graphsage():
    """End-to-end: real fanout sampler -> padded batch -> train step."""
    arch = get_arch("graphsage-reddit")
    cfg = arch.build_cfg(reduced=True)
    n = 1000
    src, dst = random_graph(n, 8.0, seed=5)
    rng = np.random.default_rng(0)
    n_classes = cfg.n_classes
    proto = rng.normal(size=(n_classes, cfg.d_in)).astype(np.float32)
    labels = rng.integers(0, n_classes, n).astype(np.int32)
    feats = (proto[labels] + rng.normal(size=(n, cfg.d_in)) * 0.3
             ).astype(np.float32)
    sampler = NeighborSampler(n, src, dst)

    params = init_gnn_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100,
                          weight_decay=0.0)
    opt = init_adamw(params, opt_cfg)

    @jax.jit
    def step(p, o, batch):
        loss, g = jax.value_and_grad(lambda p_: gnn_loss(p_, batch, cfg))(p)
        p, o, _ = adamw_update(g, o, p, opt_cfg)
        return p, o, loss

    losses = []
    for i in range(50):
        seeds = rng.choice(n, 64, replace=False)
        b = sampler.sample_padded(seeds, (5, 3), rng, max_nodes=1536,
                                  max_edges=2048, features=feats,
                                  labels=labels)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, loss = step(params, opt, b)
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < 0.85 * np.mean(losses[:5])
