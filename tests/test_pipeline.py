"""Pipelined superstep scheduler (DESIGN.md §4d): depth-1 golden parity
with the pre-pipeline engine, the depth>1 contract (completeness /
balance / determinism / quality band), pipeline counter consistency,
``take_delta`` overflow semantics, and the interpret-mode env override."""
import dataclasses
import hashlib
import os

import numpy as np
import pytest

from repro.core import metrics, resilience
from repro.engines.sharded import ShardedParams, hype_sharded_partition
from repro.engines.superstep import (SuperstepParams, SuperstepState,
                                     hype_superstep_partition)
from repro.core.hypergraph import Hypergraph
from repro.data.synthetic import powerlaw_hypergraph, reddit_like


def _digest(a: np.ndarray) -> str:
    return hashlib.sha256(
        np.ascontiguousarray(a, dtype=np.int32).tobytes()).hexdigest()[:16]


@pytest.fixture(scope="module")
def hg():
    return powerlaw_hypergraph(600, 400, seed=11, max_edge=30,
                               max_degree=20)


# --------------------------------------------- depth-1 golden parity

# sha256 prefixes of the assignments the lock-step (pre-pipeline)
# engine produced for these exact configurations, captured at the commit
# that introduced the pipeline. pipeline_depth=1 must reproduce them bit
# for bit: the device-side admission move, the flat bucket store and the
# vectorized harvest are all exact refactors of the lock-step schedule.
_GOLD_PL600 = {(5, 8): "9e8abe668aa53a74",
               (16, 8): "bbcd2f732e03af91",
               (16, 16): "e67c679d4029b7d0"}
_GOLD_TINY = {2: "a102badbeab32296", 3: "b4293f255e72d527"}
_GOLD_PL300 = "f821db1120c8d632"
_GOLD_REDDIT = "13f232f653c9c752"


@pytest.mark.parametrize("k,t", sorted(_GOLD_PL600))
def test_depth1_bit_identical_powerlaw(hg, k, t):
    a = hype_superstep_partition(
        hg, k, SuperstepParams(seed=0, t=t, pipeline_depth=1))
    assert _digest(a) == _GOLD_PL600[(k, t)]


def test_depth1_bit_identical_restart_heavy():
    """Dense short-edge graph at k=24 / pool_cap=16 hits the restart and
    pool-release paths; the golden pins them too."""
    hg = powerlaw_hypergraph(300, 500, seed=21, max_edge=10,
                             max_degree=30)
    a = hype_superstep_partition(
        hg, 24, SuperstepParams(seed=1, pool_cap=16, pipeline_depth=1))
    assert _digest(a) == _GOLD_PL300


def test_depth1_bit_identical_edge_cases():
    hg = Hypergraph.from_edge_lists(6, [[0, 1], [1, 2, 3], []])
    for k, want in _GOLD_TINY.items():
        a = hype_superstep_partition(
            hg, k, SuperstepParams(seed=0, pipeline_depth=1))
        assert _digest(a) == want


def test_depth1_bit_identical_reddit_quick():
    a = hype_superstep_partition(
        reddit_like(scale=0.005, seed=0), 32,
        SuperstepParams(seed=0, t=16, pipeline_depth=1))
    assert _digest(a) == _GOLD_REDDIT


# --------------------------------------------------- depth>1 contract

@pytest.mark.parametrize("depth", [2, 3])
def test_pipelined_complete_balanced_deterministic(hg, depth):
    p = SuperstepParams(seed=0, t=8, pipeline_depth=depth)
    a1 = hype_superstep_partition(hg, 16, p)
    a2 = hype_superstep_partition(hg, 16, p)
    np.testing.assert_array_equal(a1, a2)
    assert a1.dtype == np.int32
    assert a1.min() >= 0 and a1.max() < 16
    sizes = metrics.partition_sizes(a1, 16)
    assert sizes.max() - sizes.min() <= 1


def test_pipelined_quality_band(hg):
    """Speculative packing may reorder admissions, but the cut must stay
    in the lock-step engine's regime (same band the engine ladder holds
    between rungs)."""
    for k, t in ((16, 8), (8, 16)):
        km = {}
        for depth in (1, 2):
            a = hype_superstep_partition(
                hg, k, SuperstepParams(seed=0, t=t, pipeline_depth=depth))
            km[depth] = metrics.k_minus_1(hg, a)
        assert km[2] <= 1.15 * km[1] + 20, km


def test_pipelined_edge_cases():
    hg = Hypergraph.from_edge_lists(6, [[0, 1], [1, 2, 3], []])
    for k in (1, 2, 3, 8):
        a = hype_superstep_partition(
            hg, k, SuperstepParams(seed=0, pipeline_depth=2))
        assert (a >= 0).all() and (a < k).all()
        sizes = np.bincount(a, minlength=min(k, 6))
        assert sizes.max() - sizes.min() <= 1


def test_pipelined_sharded_contract(hg):
    import jax
    if len(jax.devices()) < 2:
        pytest.skip("needs a simulated multi-device mesh")
    for depth in (1, 2):
        p = ShardedParams(seed=0, devices=2, pipeline_depth=depth)
        a1 = hype_sharded_partition(hg, 16, p)
        a2 = hype_sharded_partition(hg, 16, p)
        np.testing.assert_array_equal(a1, a2)
        sizes = metrics.partition_sizes(a1, 16)
        assert sizes.max() - sizes.min() <= 1


def test_pipeline_depth_validated(hg):
    with pytest.raises(ValueError, match="pipeline_depth"):
        hype_superstep_partition(
            hg, 4, SuperstepParams(seed=0, pipeline_depth=0))


# ------------------------------------------------- counter consistency

def test_pipeline_counters(hg):
    """Counters must be mutually consistent: depth 1 never sees a stale
    slot; at any depth the stall count is bounded by the supersteps and
    the host/device split covers real time."""
    _, s1 = hype_superstep_partition(
        hg, 16, SuperstepParams(seed=0, pipeline_depth=1),
        return_stats=True)
    assert s1.stale_redraws == 0
    assert s1.supersteps > 0
    assert s1.pipeline_stalls <= s1.supersteps
    assert s1.host_s > 0.0 and s1.device_s >= 0.0
    _, s2 = hype_superstep_partition(
        hg, 16, SuperstepParams(seed=0, pipeline_depth=2),
        return_stats=True)
    assert s2.supersteps > 0
    assert s2.pipeline_stalls <= s2.supersteps
    # a stale slot only exists while >1 superstep is in flight, and a
    # superstep exposes at most the per-phase pool buffer (pool_cap
    # plus the pipeline's (depth-1)*t slack) to staleness
    assert s2.stale_redraws <= s2.supersteps * 16 * (64 + 8)


def test_pipeline_device_resident_claims(hg):
    """The pipelined engine keeps the superstep engine's transfer story:
    one kernel call per superstep, id-sized steady-state H2D traffic."""
    from repro.core import scoring
    _, stt = hype_superstep_partition(
        hg, 8, SuperstepParams(seed=0, pipeline_depth=2),
        return_stats=True)
    assert stt.kernel_calls == stt.supersteps
    assert stt.host_rows == 0
    per_step = stt.host_to_device_bytes / stt.supersteps
    assert per_step < 8 * 64 * scoring.L_BUCKETS[-1]


# ------------------------------------------------ take_delta overflow

def test_take_delta_cap_overflow():
    """The leftover path must preserve FIFO order and dtypes (int64 ids,
    int32 phases) across an overflowing drain."""
    hg = powerlaw_hypergraph(120, 90, seed=3, max_edge=12, max_degree=8)
    # empty plan: these unit tests drive host-side state directly, so
    # an env-injected fault (chaos/low-memory CI) must not fire here
    st = SuperstepState(hg, 4, SuperstepParams(
        seed=0, fault_plan=resilience.FaultPlan()))
    st.assign_now(np.array([5, 7, 9]), 1)
    st.assign_now(np.array([11, 13]), 2)
    ids, vals = st.take_delta(3)
    assert ids.dtype == np.int64 and vals.dtype == np.int32
    np.testing.assert_array_equal(ids, [5, 7, 9])
    np.testing.assert_array_equal(vals, [1, 1, 1])
    # the leftover tail must keep its dtypes and order, and new queued
    # deltas must drain after it
    st.assign_now(np.array([17]), 3)
    ids, vals = st.take_delta(3)
    assert ids.dtype == np.int64 and vals.dtype == np.int32
    np.testing.assert_array_equal(ids, [11, 13, 17])
    np.testing.assert_array_equal(vals, [2, 2, 3])
    ids, vals = st.take_delta(3)
    assert ids.size == 0 and vals.size == 0
    assert ids.dtype == np.int64 and vals.dtype == np.int32


def test_take_delta_exact_cap_boundary():
    hg = powerlaw_hypergraph(120, 90, seed=3, max_edge=12, max_degree=8)
    # empty plan: these unit tests drive host-side state directly, so
    # an env-injected fault (chaos/low-memory CI) must not fire here
    st = SuperstepState(hg, 4, SuperstepParams(
        seed=0, fault_plan=resilience.FaultPlan()))
    st.assign_now(np.array([1, 2, 3]), 0)
    ids, vals = st.take_delta(3)        # exactly cap: no leftover
    np.testing.assert_array_equal(ids, [1, 2, 3])
    assert not st.delta_ids and not st.delta_vals


# -------------------------------------------- interpret-mode override

def test_pallas_interpret_env_override(monkeypatch):
    from repro.kernels._compat import pallas_interpret
    import jax
    default = jax.default_backend() != "tpu"
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert pallas_interpret() is default
    for val, want in (("1", True), ("true", True), ("on", True),
                      ("0", False), ("false", False), ("off", False)):
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", val)
        assert pallas_interpret() is want, val
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "")   # empty = default
    assert pallas_interpret() is default


def test_pallas_interpret_reaches_kernels(monkeypatch, hg):
    """The env override must actually steer the engines' kernel calls:
    forcing interpret mode on CPU is a no-op that still completes."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    a = hype_superstep_partition(hg, 4, SuperstepParams(seed=0))
    assert (a >= 0).all()
