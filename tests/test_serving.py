"""Serving-path integration tests: prefill/decode vs full-forward oracle,
rolling window cache, and multi-step decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import (TransformerConfig, forward,
                                      init_params, prefill, serve_step)


def _greedy_decode(params, cfg, prompts, n_new):
    cache, logits = prefill(params, prompts, cfg)
    cache = dict(cache)
    Skv = cfg.window if cfg.window else prompts.shape[1] + n_new
    if cache["k"].shape[2] < Skv:
        pad = Skv - cache["k"].shape[2]
        cache["k"] = jnp.pad(cache["k"],
                             ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache["v"] = jnp.pad(cache["v"],
                             ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    toks = [jnp.argmax(logits, -1)[:, None].astype(jnp.int32)]
    for _ in range(n_new - 1):
        logits, cache = serve_step(params, cache, toks[-1], cfg)
        toks.append(jnp.argmax(logits, -1)[:, None].astype(jnp.int32))
    return jnp.concatenate(toks, axis=1)


def _oracle_decode(params, cfg, prompts, n_new):
    toks = prompts
    out = []
    for _ in range(n_new):
        x, _ = forward(params, toks, cfg)
        nxt = jnp.argmax(x[:, -1] @ params["lm_head"], -1)[:, None]
        nxt = nxt.astype(jnp.int32)
        out.append(nxt)
        toks = jnp.concatenate([toks, nxt], axis=1)
    return jnp.concatenate(out, axis=1)


@pytest.mark.parametrize("window", [None, 24])
def test_decode_matches_oracle(window):
    cfg = TransformerConfig(
        name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=128, vocab=211, window=window, remat=False,
        dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 211)
    n_new = 6
    got = _greedy_decode(params, cfg, prompts, n_new)
    want = _oracle_decode(params, cfg, prompts, n_new)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_rolling_cache_wraps():
    """Decode far past the window: the rolling buffer must keep working."""
    cfg = TransformerConfig(
        name="t", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        d_head=16, d_ff=64, vocab=97, window=8, remat=False,
        dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, 97)
    got = _greedy_decode(params, cfg, prompts, 20)   # wraps 8-slot buffer
    want = _oracle_decode(params, cfg, prompts, 20)
    # past the window the oracle still attends within window thanks to the
    # causal+window mask; sequences must agree exactly
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_decode_matches_regular():
    """serve_step_paged must produce identical logits to serve_step."""
    from repro.models.transformer import serve_step_paged
    cfg = TransformerConfig(
        name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=128, vocab=131, remat=False, dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 131)
    cache, _ = prefill(params, prompts, cfg)
    cache = dict(cache)
    pad = 4
    cache["k"] = jnp.pad(cache["k"], ((0, 0), (0, 0), (0, pad), (0, 0),
                                      (0, 0)))
    cache["v"] = jnp.pad(cache["v"], ((0, 0), (0, 0), (0, pad), (0, 0),
                                      (0, 0)))
    tok = jnp.ones((2, 1), jnp.int32)
    logits_reg, new_cache = serve_step(params, cache, tok, cfg)
    logits_paged, k_new, v_new, pos = serve_step_paged(params, cache, tok,
                                                       cfg)
    np.testing.assert_allclose(np.asarray(logits_reg),
                               np.asarray(logits_paged), atol=1e-4,
                               rtol=1e-4)
    # returned K/V equal what regular decode wrote into the cache slot
    slot = int(cache["pos"])
    np.testing.assert_allclose(
        np.asarray(new_cache["k"][:, :, slot]),
        np.asarray(k_new[:, :, 0]), atol=1e-5, rtol=1e-5)
    assert int(pos) == slot + 1


def test_blockwise_attention_matches_einsum():
    import dataclasses
    base = TransformerConfig(
        name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=128, vocab=101, window=48, remat=False,
        dtype=jnp.float32)
    blk = dataclasses.replace(base, attention_impl="blockwise",
                              attention_block=16)
    params = init_params(jax.random.PRNGKey(0), base)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, 101)
    a, _ = forward(params, toks, base)
    b, _ = forward(params, toks, blk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                               rtol=1e-4)


def test_seq_shard_flag_is_mesh_noop_on_cpu():
    """seq_shard only adds constraints; without a mesh it is identical."""
    base = TransformerConfig(
        name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_head=16, d_ff=128, vocab=101, remat=False, dtype=jnp.float32)
    import dataclasses
    ss = dataclasses.replace(base, seq_shard=True)
    params = init_params(jax.random.PRNGKey(0), base)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 101)
    a, _ = forward(params, toks, base)
    b, _ = forward(params, toks, ss)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))
