"""Registry drift guard: every entry in ``partition_api.METHODS`` must
produce a valid full assignment within its *documented* balance slack on
a small synthetic hypergraph, and the description surface
(``describe_methods``) must cover the registry exactly. A method added
to ``partition()`` without registry metadata — or whose balance claim
drifts from its implementation — fails here, not in production."""
import dataclasses
import inspect

import numpy as np
import pytest

from repro.core import metrics
from repro.core.partition_api import (METHOD_INFO, METHODS, balance_slack,
                                      describe_methods, method_knobs,
                                      partition)
from repro.data.synthetic import powerlaw_hypergraph


@pytest.fixture(scope="module")
def hg():
    return powerlaw_hypergraph(500, 350, seed=9, max_edge=24,
                               max_degree=16)


@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("k", [3, 8])
def test_registry_method_contract(hg, method, k):
    a = partition(hg, k, method, seed=0)
    assert a.shape == (hg.n,)
    assert a.dtype == np.int32
    assert a.min() >= 0 and a.max() < k          # full assignment
    sizes = metrics.partition_sizes(a, k)
    assert sizes.max() - sizes.min() <= balance_slack(method, hg.n, k), \
        f"{method} exceeded its documented balance slack"


def test_describe_methods_covers_registry():
    desc = describe_methods()
    assert tuple(desc) == METHODS                # same names, same order
    for name, line in desc.items():
        assert isinstance(line, str) and len(line) > 10, name
        assert "\n" not in line                  # one-liners


def test_registry_metadata_complete():
    for name, info in METHOD_INFO.items():
        assert callable(info["balance_slack"]), name
        assert info["balance_slack"](1000, 8) >= 1, name


def test_registered_knobs_match_engine_signatures():
    """Two-way drift guard between the registry and the engines.

    Registry -> engine: every knob ``method_knobs`` documents must exist
    on the params dataclass / callable it is forwarded to (a renamed
    field drifts here). Engine -> registry: every params field except
    ``seed`` (owned by ``partition()``) and the method's own
    ``knob_exclude`` pins must surface as a documented knob — an engine
    can no longer grow a field the registry silently hides. The classes
    are imported from their engine modules directly, so the test also
    pins the ``params`` specs in ``METHOD_INFO`` to the real classes."""
    from repro.core.hype import HypeParams
    from repro.core.hype_stream import StreamParams
    from repro.core.minmax import minmax_partition
    from repro.core.multilevel import hype_multilevel_partition
    from repro.core.shp import shp_partition
    from repro.engines.batched import BatchedParams
    from repro.engines.device import DeviceParams
    from repro.engines.sharded import ShardedParams
    from repro.engines.superstep import SuperstepParams

    param_cls = {
        "hype": HypeParams,
        "hype_weighted": HypeParams,
        "hype_batched": BatchedParams,
        "hype_superstep": SuperstepParams,
        "hype_sharded": ShardedParams,
        "hype_device": DeviceParams,
        "hype_stream": StreamParams,
    }
    for method, cls in param_cls.items():
        fields = {f.name for f in dataclasses.fields(cls)}
        knobs = method_knobs(method)
        assert isinstance(knobs, tuple), method
        assert len(set(knobs)) == len(knobs), method       # no dupes
        assert set(knobs) <= fields, (method, set(knobs) - fields)
        hidden = {"seed"} | set(METHOD_INFO[method].get("knob_exclude",
                                                        ()))
        assert set(knobs) == fields - hidden, \
            (method, set(knobs) ^ (fields - hidden))
        # the registered spec must resolve to this very class
        spec = METHOD_INFO[method].get("params")
        assert spec is not None, method
        import importlib
        assert getattr(importlib.import_module(spec[0]), spec[1]) is cls
    sig_fields = {
        "hype_multilevel": set(
            inspect.signature(hype_multilevel_partition).parameters),
        "minmax_nb": set(inspect.signature(minmax_partition).parameters),
        "shp": set(inspect.signature(shp_partition).parameters),
    }
    for method, fields in sig_fields.items():
        missing = set(method_knobs(method)) - fields
        assert not missing, (method, missing)
    # the pipelined scheduler's knob is registered on both engines that
    # share it — the drift test stays exhaustive as knobs are added
    assert "pipeline_depth" in method_knobs("hype_superstep")
    assert "pipeline_depth" in method_knobs("hype_sharded")
    assert "devices" in method_knobs("hype_sharded")
    # the refinement post-pass knob is registered on every engine of
    # the HYPE batched family plus the k-way multilevel composition
    for method in ("hype_batched", "hype_superstep", "hype_sharded",
                   "hype_multilevel"):
        assert "refine_passes" in method_knobs(method), method
    # the resilience knobs (DESIGN.md §4f) are registered on every
    # engine of the batched family — snapshotting, resume and fault
    # injection are part of the public surface, not internals
    for method in ("hype_batched", "hype_superstep", "hype_sharded"):
        for knob in ("snapshot_every", "snapshot_dir", "resume",
                     "fault_plan", "max_retries", "keep_last"):
            assert knob in method_knobs(method), (method, knob)
    # the device-memory budget knob (DESIGN.md §4g) is registered on the
    # device-resident engines only — host engines have no device image
    for method in ("hype_superstep", "hype_sharded", "hype_stream",
                   "hype_device"):
        assert "mem_budget" in method_knobs(method), method
    assert "mem_budget" not in method_knobs("hype_batched")
    # the streaming engine's own knobs (DESIGN.md §4h): micro-batching,
    # sketch width and the incremental-update dirty radius are public
    for knob in ("micro_batch", "sketch_bits", "update_radius"):
        assert knob in method_knobs("hype_stream"), knob
    # ... and it shares the full resilience surface with the family
    for knob in ("snapshot_every", "snapshot_dir", "resume",
                 "fault_plan", "max_retries", "keep_last"):
        assert knob in method_knobs("hype_stream"), knob
    # the §4i device-loop engine's own knobs: chunked while_loop cadence,
    # the optional fp16 score cache, and the ring-capacity overrides
    for knob in ("chunk_supersteps", "cache_dtype", "store_cap",
                 "act_cap", "snapshot_every", "resume", "fault_plan"):
        assert knob in method_knobs("hype_device"), knob


def test_registered_presets_are_valid_knobs():
    """Every preset bundle must spell the shared fast/balanced/quality
    vocabulary and set only knobs the method actually registers;
    ``fast`` is always empty (bit-identical to the engine defaults)."""
    from repro.core.partition_api import method_presets

    with_presets = [m for m in METHODS if method_presets(m)]
    assert set(with_presets) == {"hype_batched", "hype_superstep",
                                 "hype_sharded", "hype_device"}
    for method in with_presets:
        presets = method_presets(method)
        assert tuple(presets) == ("fast", "balanced", "quality"), method
        assert presets["fast"] == {}, method
        knobs = set(method_knobs(method))
        for name, bundle in presets.items():
            unknown = set(bundle) - knobs
            assert not unknown, (method, name, unknown)


def test_partition_knobs_match_signatures():
    """Method-independent knobs in ``PARTITION_KNOBS`` must exist as
    keyword parameters of ``partition`` AND ``partition_resilient``
    with defaults equal to the registered value — the hard-coded
    threshold can never silently drift from the documented knob."""
    from repro.core.partition_api import PARTITION_KNOBS, partition_resilient

    assert "auto_validate_max_n" in PARTITION_KNOBS
    for fn in (partition, partition_resilient):
        sig = inspect.signature(fn)
        for name, default in PARTITION_KNOBS.items():
            assert name in sig.parameters, (fn.__name__, name)
            par = sig.parameters[name]
            assert par.kind is inspect.Parameter.KEYWORD_ONLY, name
            assert par.default == default, (fn.__name__, name)


def test_auto_validate_threshold_knob(hg):
    """auto_validate_max_n gates the "auto" sweep: a corrupt graph slips
    past a tiny threshold (validation skipped) but is caught by the
    default, and validate=True overrides the threshold entirely."""
    bad = dataclasses.replace(hg, v2e_indptr=hg.v2e_indptr.copy())
    bad.v2e_indptr[-1] += 1                      # CSR corruption
    # threshold below n: auto skips validation, random engine completes
    a = partition(bad, 4, "random", seed=0, auto_validate_max_n=10)
    assert a.shape == (hg.n,)
    # default threshold: auto validates and rejects the corruption
    with pytest.raises(ValueError):
        partition(bad, 4, "random", seed=0)
    # explicit validate=True ignores the threshold
    with pytest.raises(ValueError):
        partition(bad, 4, "random", seed=0, validate=True,
                  auto_validate_max_n=10)


def test_registered_knobs_are_forwarded(hg):
    """A registered knob must actually reach the engine: pipeline_depth=1
    vs default must both run to completion through partition()."""
    a = partition(hg, 4, "hype_superstep", seed=0, pipeline_depth=1)
    assert (a >= 0).all() and (a < 4).all()


def test_unknown_method_raises(hg):
    with pytest.raises(ValueError, match="unknown method"):
        partition(hg, 4, "definitely_not_registered")
