"""Registry drift guard: every entry in ``partition_api.METHODS`` must
produce a valid full assignment within its *documented* balance slack on
a small synthetic hypergraph, and the description surface
(``describe_methods``) must cover the registry exactly. A method added
to ``partition()`` without registry metadata — or whose balance claim
drifts from its implementation — fails here, not in production."""
import numpy as np
import pytest

from repro.core import metrics
from repro.core.partition_api import (METHOD_INFO, METHODS, balance_slack,
                                      describe_methods, partition)
from repro.data.synthetic import powerlaw_hypergraph


@pytest.fixture(scope="module")
def hg():
    return powerlaw_hypergraph(500, 350, seed=9, max_edge=24,
                               max_degree=16)


@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("k", [3, 8])
def test_registry_method_contract(hg, method, k):
    a = partition(hg, k, method, seed=0)
    assert a.shape == (hg.n,)
    assert a.dtype == np.int32
    assert a.min() >= 0 and a.max() < k          # full assignment
    sizes = metrics.partition_sizes(a, k)
    assert sizes.max() - sizes.min() <= balance_slack(method, hg.n, k), \
        f"{method} exceeded its documented balance slack"


def test_describe_methods_covers_registry():
    desc = describe_methods()
    assert tuple(desc) == METHODS                # same names, same order
    for name, line in desc.items():
        assert isinstance(line, str) and len(line) > 10, name
        assert "\n" not in line                  # one-liners


def test_registry_metadata_complete():
    for name, info in METHOD_INFO.items():
        assert callable(info["balance_slack"]), name
        assert info["balance_slack"](1000, 8) >= 1, name


def test_unknown_method_raises(hg):
    with pytest.raises(ValueError, match="unknown method"):
        partition(hg, 4, "definitely_not_registered")
