"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and absence of NaNs (deliverable (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.launch.cells import build_cell
from repro.models import transformer as tf_mod

LM = [a for a in ARCH_IDS if get_arch(a).family == "lm"]
GNN = [a for a in ARCH_IDS if get_arch(a).family == "gnn"]
REC = [a for a in ARCH_IDS if get_arch(a).family == "recsys"]


def _concretize(tree, seed=0):
    """Turn ShapeDtypeStructs into small concrete arrays."""
    rng = np.random.default_rng(seed)

    def f(s):
        if s.dtype == jnp.int32:
            return jnp.asarray(
                rng.integers(0, 2, size=s.shape).astype(np.int32))
        if s.dtype == jnp.bool_:
            return jnp.ones(s.shape, bool)
        return jnp.asarray(rng.normal(size=s.shape).astype(np.float32) * 0.1,
                           dtype=s.dtype)
    return jax.tree.map(f, tree)


def _init_state(plan, arch_id):
    arch = get_arch(arch_id)
    if arch.family == "lm":
        cfg = arch.build_cfg(reduced=True)
        params = tf_mod.init_params(jax.random.PRNGKey(0), cfg)
    else:
        params = _concretize(plan.args[0], seed=1)
    return params


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_smoke(arch_id):
    arch = get_arch(arch_id)
    shape = {"lm": "train_4k", "gnn": "full_graph_sm",
             "recsys": "train_batch"}[arch.family]
    plan = build_cell(arch_id, shape, mesh=None, reduced=True)
    params = _init_state(plan, arch_id)
    opt = _concretize(plan.args[1])
    opt = type(plan.args[1])(step=jnp.zeros((), jnp.int32),
                             m=jax.tree.map(jnp.zeros_like, opt.m),
                             v=jax.tree.map(jnp.zeros_like, opt.v))
    batch = _concretize(plan.args[2])
    new_p, new_opt, metrics = jax.jit(plan.fn)(params, opt, batch)
    assert jax.tree.structure(new_p) == jax.tree.structure(params)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch_id}: loss is not finite"
    assert int(new_opt.step) == 1
    # params actually changed
    d = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, new_p))
    assert max(d) > 0


@pytest.mark.parametrize("arch_id", LM)
def test_lm_decode_smoke(arch_id):
    plan = build_cell(arch_id, "decode_32k", mesh=None, reduced=True)
    arch = get_arch(arch_id)
    cfg = arch.build_cfg(reduced=True)
    params = tf_mod.init_params(jax.random.PRNGKey(0), cfg)
    ck = jnp.zeros(plan.args[1].shape, plan.args[1].dtype)
    cv = jnp.zeros(plan.args[2].shape, plan.args[2].dtype)
    pos = jnp.zeros((), jnp.int32)
    toks = jnp.ones(plan.args[4].shape, jnp.int32)
    logits, nk, nv, npos = jax.jit(plan.fn)(params, ck, cv, pos, toks)
    assert logits.shape == (toks.shape[0], cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    assert int(npos) == 1


@pytest.mark.parametrize("arch_id", LM)
def test_lm_prefill_smoke(arch_id):
    plan = build_cell(arch_id, "prefill_32k", mesh=None, reduced=True)
    arch = get_arch(arch_id)
    cfg = arch.build_cfg(reduced=True)
    params = tf_mod.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.ones(plan.args[1].shape, jnp.int32)
    cache, logits = jax.jit(plan.fn)(params, toks)
    assert logits.shape == (toks.shape[0], cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    Skv = min(toks.shape[1], cfg.window) if cfg.window else toks.shape[1]
    assert cache["k"].shape == (cfg.n_layers, toks.shape[0], Skv,
                                cfg.n_kv_heads, cfg.d_head)


@pytest.mark.parametrize("arch_id,shape", [(a, s) for a in GNN for s in
                                           ("molecule", "minibatch_lg")])
def test_gnn_other_shapes_smoke(arch_id, shape):
    plan = build_cell(arch_id, shape, mesh=None, reduced=True)
    params = _concretize(plan.args[0], seed=2)
    batch = _concretize(plan.args[2])
    # valid edge indices
    n = batch["nodes"].shape[0]
    batch["edge_src"] = batch["edge_src"] % n
    batch["edge_dst"] = batch["edge_dst"] % n
    from repro.models.gnn import gnn_forward
    arch = get_arch(arch_id)
    cfg = arch.build_cfg(reduced=True, shape=shape)
    out = gnn_forward(params, batch, cfg)
    assert out.shape[0] == n
    assert not bool(jnp.any(jnp.isnan(out)))


@pytest.mark.parametrize("shape", ["serve_p99", "retrieval_cand"])
def test_recsys_serving_smoke(shape):
    plan = build_cell("two-tower-retrieval", shape, mesh=None, reduced=True)
    params = _concretize(plan.args[0], seed=3)
    batch = _concretize(plan.args[1])
    out = jax.jit(plan.fn)(params, batch)
    if shape == "serve_p99":
        assert out.shape == (batch["user_ids"].shape[0],)
    else:
        vals, idx = out
        assert vals.shape == (128,) and idx.shape == (128,)
    flat = jax.tree.leaves(out)
    assert all(not bool(jnp.any(jnp.isnan(x.astype(jnp.float32))))
               for x in flat)


def test_all_cells_enumeration():
    from repro.launch.cells import all_cells
    cells = all_cells()
    assert len(cells) == 40, f"expected 40 cells, got {len(cells)}"
    skips = [c for c in cells if c[2]]
    # long_500k skipped for the 4 pure full-attention LM archs
    assert len(skips) == 4
    for aid, shape, reason in skips:
        assert shape == "long_500k" and aid != "mixtral-8x22b"
