"""Optional-hypothesis shim.

Some environments (including the reference container) don't ship
``hypothesis``; without this shim every module importing it ERRORs at
collection and, under ``pytest -x``, takes the whole suite down. When
hypothesis is available this module re-exports it untouched; otherwise
``@given(...)`` turns the test into a skip and ``st.*`` return inert
placeholders (they are only evaluated at decoration time).
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - depends on environment
    import pytest

    HAS_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    class _Inert:
        """Callable placeholder that absorbs any use (st.composite
        decorators, strategy constructors, .map/.filter chains)."""

        def __call__(self, *_a, **_k):
            return self

        def __getattr__(self, _name):
            return self

    st = _Inert()
