"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hype_score.ops import hype_scores
from repro.kernels.hype_score.ref import hype_scores_ref
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.neighbor_agg.ops import neighbor_agg
from repro.kernels.neighbor_agg.ref import neighbor_agg_ref


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------ flash attn

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,Hq,Hkv,D,window", [
    (2, 128, 4, 4, 64, None),          # MHA
    (1, 256, 8, 2, 64, None),          # GQA 4:1
    (2, 256, 4, 4, 32, 64),            # sliding window
    (1, 128, 2, 1, 128, None),         # MQA, d=128
])
def test_flash_attention_matches_ref(B, S, Hq, Hkv, D, window, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, S, Hq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_noncausal():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


@given(st.integers(1, 3), st.sampled_from([64, 128, 192]),
       st.sampled_from([1, 2, 4]), st.integers(0, 100))
@settings(max_examples=8, deadline=None)
def test_flash_attention_property(B, S, Hkv, seed):
    """GQA invariances across random shapes (property-based)."""
    Hq, D = Hkv * 2, 32
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=3e-5)


# ------------------------------------------------------------- hype score

@pytest.mark.parametrize("B,L,s", [(16, 32, 10), (64, 8, 4), (7, 128, 16),
                                   (1, 1, 1)])
def test_hype_scores_matches_ref(B, L, s):
    rng = np.random.default_rng(0)
    nbrs = rng.integers(-1, 500, size=(B, L)).astype(np.int32)
    fringe = rng.choice(500, size=s, replace=False).astype(np.int32)
    out = hype_scores(jnp.asarray(nbrs), jnp.asarray(fringe))
    ref = hype_scores_ref(jnp.asarray(nbrs), jnp.asarray(fringe))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@given(st.integers(1, 40), st.integers(1, 24), st.integers(1, 12),
       st.integers(0, 99))
@settings(max_examples=15, deadline=None)
def test_hype_scores_property(B, L, s, seed):
    rng = np.random.default_rng(seed)
    nbrs = rng.integers(-1, 64, size=(B, L)).astype(np.int32)
    fringe = rng.integers(0, 64, size=(s,)).astype(np.int32)
    out = np.asarray(hype_scores(jnp.asarray(nbrs), jnp.asarray(fringe)))
    ref = np.asarray(hype_scores_ref(jnp.asarray(nbrs), jnp.asarray(fringe)))
    np.testing.assert_array_equal(out, ref)
    # invariant: 0 <= score <= #valid
    assert (out >= 0).all()
    assert (out <= (nbrs >= 0).sum(1)).all()


# ---------------------------------------------------------- embedding bag

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("V,D,B,bag,combine", [
    (128, 64, 8, 4, "mean"), (1000, 128, 16, 8, "sum"), (32, 256, 4, 1,
                                                         "mean")])
def test_embedding_bag_matches_ref(V, D, B, bag, combine, dtype):
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(V, D)), dtype)
    ids = rng.integers(-1, V, size=(B, bag)).astype(np.int32)
    out = embedding_bag(table, jnp.asarray(ids), combine=combine)
    ref = embedding_bag_ref(table, jnp.asarray(ids), combine=combine)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_embedding_bag_all_padded_row():
    table = jnp.ones((16, 32), jnp.float32)
    ids = jnp.full((2, 4), -1, jnp.int32)
    out = embedding_bag(table, ids)
    np.testing.assert_allclose(np.asarray(out), 0.0)


# ----------------------------------------------------------- neighbor agg

@pytest.mark.parametrize("N,D,F,B,K", [(64, 32, 16, 8, 4),
                                       (200, 128, 64, 16, 10),
                                       (30, 16, 8, 4, 15)])
def test_neighbor_agg_matches_ref(N, D, F, B, K):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(D, F)) * 0.1, jnp.float32)
    nbrs = rng.integers(-1, N, size=(B, K)).astype(np.int32)
    out = neighbor_agg(x, jnp.asarray(nbrs), w)
    ref = neighbor_agg_ref(x, jnp.asarray(nbrs), w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                               rtol=1e-4)


@given(st.integers(2, 50), st.integers(1, 8), st.integers(1, 12),
       st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_neighbor_agg_property(N, B, K, seed):
    D, F = 16, 8
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(D, F)) * 0.1, jnp.float32)
    nbrs = rng.integers(-1, N, size=(B, K)).astype(np.int32)
    out = np.asarray(neighbor_agg(x, jnp.asarray(nbrs), w))
    ref = np.asarray(neighbor_agg_ref(x, jnp.asarray(nbrs), w))
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)
