"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hype_score.kernel import SELECT_PAD
from repro.kernels.hype_score.ops import hype_score_select, hype_scores
from repro.kernels.hype_score.ref import (hype_score_select_ref,
                                          hype_scores_ref)
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.neighbor_agg.ops import neighbor_agg
from repro.kernels.neighbor_agg.ref import neighbor_agg_ref


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------ flash attn

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,Hq,Hkv,D,window", [
    (2, 128, 4, 4, 64, None),          # MHA
    (1, 256, 8, 2, 64, None),          # GQA 4:1
    (2, 256, 4, 4, 32, 64),            # sliding window
    (1, 128, 2, 1, 128, None),         # MQA, d=128
])
def test_flash_attention_matches_ref(B, S, Hq, Hkv, D, window, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, S, Hq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_noncausal():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


@given(st.integers(1, 3), st.sampled_from([64, 128, 192]),
       st.sampled_from([1, 2, 4]), st.integers(0, 100))
@settings(max_examples=8, deadline=None)
def test_flash_attention_property(B, S, Hkv, seed):
    """GQA invariances across random shapes (property-based)."""
    Hq, D = Hkv * 2, 32
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=3e-5)


# ------------------------------------------------------------- hype score

@pytest.mark.parametrize("B,L,s", [(16, 32, 10), (64, 8, 4), (7, 128, 16),
                                   (1, 1, 1)])
def test_hype_scores_matches_ref(B, L, s):
    rng = np.random.default_rng(0)
    nbrs = rng.integers(-1, 500, size=(B, L)).astype(np.int32)
    fringe = rng.choice(500, size=s, replace=False).astype(np.int32)
    out = hype_scores(jnp.asarray(nbrs), jnp.asarray(fringe))
    ref = hype_scores_ref(jnp.asarray(nbrs), jnp.asarray(fringe))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@given(st.integers(1, 40), st.integers(1, 24), st.integers(1, 12),
       st.integers(0, 99))
@settings(max_examples=15, deadline=None)
def test_hype_scores_property(B, L, s, seed):
    rng = np.random.default_rng(seed)
    nbrs = rng.integers(-1, 64, size=(B, L)).astype(np.int32)
    fringe = rng.integers(0, 64, size=(s,)).astype(np.int32)
    out = np.asarray(hype_scores(jnp.asarray(nbrs), jnp.asarray(fringe)))
    ref = np.asarray(hype_scores_ref(jnp.asarray(nbrs), jnp.asarray(fringe)))
    np.testing.assert_array_equal(out, ref)
    # invariant: 0 <= score <= #valid
    assert (out >= 0).all()
    assert (out <= (nbrs >= 0).sum(1)).all()


# ------------------------------------------------------ fused score+select

def _select_case(G, R, L, s, P, select_k, seed, fringe_fill="full"):
    """Run kernel + oracle on one randomized case and compare exactly."""
    rng = np.random.default_rng(seed)
    nbrs = rng.integers(-1, 3 * L, size=(G, R, L)).astype(np.int32)
    fringe = rng.integers(0, 3 * L, size=(G, s)).astype(np.int32)
    if fringe_fill == "empty":
        fringe[:] = -1
    elif fringe_fill == "partial":
        fringe[:, s // 2:] = -1
    bias = np.where(rng.random((G, R)) < 0.25, np.inf,
                    np.where(rng.random((G, R)) < 0.2, 1e12,
                             0.0)).astype(np.float32)
    prev = np.where(rng.random((G, P)) < 0.5,
                    (rng.random((G, P)) * 30).astype(np.float32),
                    np.float32(np.inf))
    out = hype_score_select(jnp.asarray(nbrs), jnp.asarray(fringe),
                            jnp.asarray(bias), jnp.asarray(prev),
                            select_k=select_k)
    ref = hype_score_select_ref(nbrs, fringe, bias, prev, select_k)
    for got, want, name in zip(out, ref, ("scores", "sel_idx", "sel_val")):
        np.testing.assert_array_equal(np.asarray(got), want, err_msg=name)


@pytest.mark.parametrize("L", [32, 128, 512, 2048])   # every L bucket
def test_score_select_matches_ref_all_widths(L):
    from repro.core.scoring import L_BUCKETS
    assert L in L_BUCKETS
    _select_case(G=3, R=4, L=L, s=8, P=6, select_k=5, seed=L)


@pytest.mark.parametrize("fill", ["empty", "partial", "full"])
def test_score_select_fringe_fill_levels(fill):
    _select_case(G=4, R=8, L=64, s=10, P=8, select_k=6, seed=7,
                 fringe_fill=fill)


def test_score_select_all_pad_rows():
    """All -1 rows + all-inf pool must select nothing real, in order."""
    G, R, L, P, k = 2, 4, 32, 4, 5
    nbrs = np.full((G, R, L), -1, np.int32)
    fringe = np.full((G, 3), -1, np.int32)
    bias = np.full((G, R), np.inf, np.float32)
    prev = np.full((G, P), np.inf, np.float32)
    scores, idx, val = hype_score_select(
        jnp.asarray(nbrs), jnp.asarray(fringe), jnp.asarray(bias),
        jnp.asarray(prev), select_k=k)
    ref = hype_score_select_ref(nbrs, fringe, bias, prev, k)
    np.testing.assert_array_equal(np.asarray(idx), ref[1])
    assert (np.asarray(val) >= SELECT_PAD).all()     # "nothing there"


def test_score_select_orders_admissions():
    """Selections come back best-first and point at the true minima."""
    G, R, L, P, k = 1, 4, 8, 3, 4
    nbrs = np.full((G, R, L), -1, np.int32)
    nbrs[0, 0, :3] = [5, 6, 7]       # score 3
    nbrs[0, 1, :1] = [9]             # score 1
    nbrs[0, 2, :2] = [5, 9]          # score 2
    nbrs[0, 3, :5] = [1, 2, 3, 4, 5]  # score 5
    fringe = np.full((G, 2), -1, np.int32)
    bias = np.zeros((G, R), np.float32)
    prev = np.asarray([[2.0, np.inf, 0.0]], np.float32)
    _, idx, val = hype_score_select(
        jnp.asarray(nbrs), jnp.asarray(fringe), jnp.asarray(bias),
        jnp.asarray(prev), select_k=k)
    # pool slot 2 (score 0), row 1 (1), then the score-2 tie: row 2 wins
    # over pool slot 0 by lowest-index-first
    np.testing.assert_array_equal(np.asarray(idx)[0], [R + 2, 1, 2, R + 0])
    np.testing.assert_array_equal(np.asarray(val)[0], [0.0, 1.0, 2.0, 2.0])


@given(st.integers(1, 6), st.integers(1, 8), st.integers(1, 16),
       st.integers(1, 6), st.integers(0, 99))
@settings(max_examples=12, deadline=None)
def test_score_select_property(G, R, L, P, seed):
    rng = np.random.default_rng(seed)
    select_k = int(rng.integers(1, R + P + 1))
    _select_case(G=G, R=R, L=L, s=int(rng.integers(1, 6)), P=P,
                 select_k=select_k, seed=seed + 1000)


# ---------------------------------------------------------- embedding bag

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("V,D,B,bag,combine", [
    (128, 64, 8, 4, "mean"), (1000, 128, 16, 8, "sum"), (32, 256, 4, 1,
                                                         "mean")])
def test_embedding_bag_matches_ref(V, D, B, bag, combine, dtype):
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(V, D)), dtype)
    ids = rng.integers(-1, V, size=(B, bag)).astype(np.int32)
    out = embedding_bag(table, jnp.asarray(ids), combine=combine)
    ref = embedding_bag_ref(table, jnp.asarray(ids), combine=combine)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_embedding_bag_all_padded_row():
    table = jnp.ones((16, 32), jnp.float32)
    ids = jnp.full((2, 4), -1, jnp.int32)
    out = embedding_bag(table, ids)
    np.testing.assert_allclose(np.asarray(out), 0.0)


# ----------------------------------------------------------- neighbor agg

@pytest.mark.parametrize("N,D,F,B,K", [(64, 32, 16, 8, 4),
                                       (200, 128, 64, 16, 10),
                                       (30, 16, 8, 4, 15)])
def test_neighbor_agg_matches_ref(N, D, F, B, K):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(D, F)) * 0.1, jnp.float32)
    nbrs = rng.integers(-1, N, size=(B, K)).astype(np.int32)
    out = neighbor_agg(x, jnp.asarray(nbrs), w)
    ref = neighbor_agg_ref(x, jnp.asarray(nbrs), w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                               rtol=1e-4)


@given(st.integers(2, 50), st.integers(1, 8), st.integers(1, 12),
       st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_neighbor_agg_property(N, B, K, seed):
    D, F = 16, 8
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(D, F)) * 0.1, jnp.float32)
    nbrs = rng.integers(-1, N, size=(B, K)).astype(np.int32)
    out = np.asarray(neighbor_agg(x, jnp.asarray(nbrs), w))
    ref = np.asarray(neighbor_agg_ref(x, jnp.asarray(nbrs), w))
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)
