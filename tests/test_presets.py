"""The ``preset`` knob of ``partition()`` (fast | balanced | quality).

Contract under test: ``fast`` is bit-identical to the engine's own
defaults, ``quality`` is exactly the explicit refinement knobs it
documents (golden-compared by digest), explicit knobs override the
preset, and misuse raises a clear ``ValueError``."""
import hashlib

import numpy as np
import pytest

from repro.core.partition_api import method_presets, partition
from repro.data.synthetic import powerlaw_hypergraph

PRESET_METHODS = ("hype_batched", "hype_superstep", "hype_device",
                  "hype_sharded")


def _digest(a):
    return hashlib.sha256(
        np.ascontiguousarray(a, dtype=np.int32).tobytes()).hexdigest()[:16]


@pytest.fixture(scope="module")
def hg():
    return powerlaw_hypergraph(300, 200, seed=7, max_edge=16,
                               max_degree=12)


@pytest.fixture(scope="module")
def hg_large():
    # the device-loop engine needs the standard 600-vertex fixture: its
    # ring capacities mis-broadcast on very small graphs (pre-existing,
    # see test_hype_device.py for the supported envelope)
    return powerlaw_hypergraph(600, 400, seed=11, max_edge=30,
                               max_degree=20)


@pytest.mark.parametrize("method", PRESET_METHODS)
def test_fast_preset_bit_identical_to_defaults(hg, hg_large, method):
    g = hg_large if method == "hype_device" else hg
    base = partition(g, 8, method, seed=0)
    fast = partition(g, 8, method, seed=0, preset="fast")
    assert _digest(fast) == _digest(base)


@pytest.mark.parametrize("method", ("hype_batched", "hype_superstep"))
def test_quality_preset_is_explicit_knobs(hg, method):
    """quality == spelling out the registered preset bundle by hand —
    the preset is sugar, not a separate code path."""
    bundle = method_presets(method)["quality"]
    assert bundle["refine_passes"] > 0
    quality = partition(hg, 8, method, seed=0, preset="quality")
    explicit = partition(hg, 8, method, seed=0, **bundle)
    assert _digest(quality) == _digest(explicit)


def test_quality_preset_changes_result_when_refine_bites(hg):
    """refine_passes=4 must actually engage: quality differs from fast
    on a graph where the post-pass finds positive-gain moves (guards
    against a preset that is silently dropped on the floor)."""
    fast = partition(hg, 8, "hype_batched", seed=0, preset="fast")
    quality = partition(hg, 8, "hype_batched", seed=0, preset="quality")
    from repro.core import metrics
    km1_fast = metrics.k_minus_1(hg, fast)
    km1_quality = metrics.k_minus_1(hg, quality)
    assert km1_quality <= km1_fast


def test_explicit_knob_overrides_preset(hg):
    over = partition(hg, 8, "hype_batched", seed=0, preset="quality",
                     refine_passes=0)
    base = partition(hg, 8, "hype_batched", seed=0)
    assert _digest(over) == _digest(base)


def test_unknown_preset_raises(hg):
    with pytest.raises(ValueError, match="unknown preset"):
        partition(hg, 8, "hype_batched", seed=0, preset="turbo")


def test_preset_on_presetless_method_raises(hg):
    with pytest.raises(ValueError, match="does not support presets"):
        partition(hg, 8, "shp", seed=0, preset="fast")
    with pytest.raises(ValueError, match="does not support presets"):
        partition(hg, 8, "hype", seed=0, preset="quality")


def test_partition_and_report_forwards_preset(hg):
    from repro.core.partition_api import partition_and_report
    rep, a = partition_and_report(hg, 8, "hype_batched", seed=0,
                                  preset="quality")
    explicit = partition(hg, 8, "hype_batched", seed=0, refine_passes=4)
    assert _digest(a) == _digest(explicit)
    assert rep["method"] == "hype_batched"
