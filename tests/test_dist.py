"""Distribution layer tests.

Multi-device tests run in a subprocess so the XLA device-count flag does
not contaminate this process's jax runtime.
"""
import subprocess
import sys

import numpy as np
import pytest

pytest.importorskip("repro.dist", reason="repro.dist not built yet")

from repro.core.hype import HypeParams, hype_partition
from repro.dist.partitioned_gnn import (build_partitioned_graph,
                                        graph_to_hypergraph)
from repro.data.graphs import random_graph

SUBPROC_HALO = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import sys; sys.path.insert(0, 'src')
import jax, jax.numpy as jnp, numpy as np
from repro.dist.partitioned_gnn import (build_partitioned_graph,
    partition_graph_hype, halo_aggregate, reference_aggregate,
    scatter_to_parts, gather_from_parts)
from repro.data.graphs import random_graph

k = 8
mesh = jax.make_mesh((k,), ('devices',))
n = 300
src, dst = random_graph(n, 5.0, seed=2)
asg = partition_graph_hype(n, src, dst, k, seed=0)
rng = np.random.default_rng(0)
x = rng.normal(size=(n, 8)).astype(np.float32)
W = rng.normal(size=(8, 8)).astype(np.float32) * 0.1
msg_fn = lambda h: h @ W
ref = np.asarray(reference_aggregate(n, jnp.asarray(src), jnp.asarray(dst),
                                     jnp.asarray(x), msg_fn))
for mode in ('alltoall', 'allgather'):
    pg = build_partitioned_graph(n, src, dst, asg, k, mode=mode)
    xp = jnp.asarray(scatter_to_parts(pg, x))
    pga = {kk: jnp.asarray(getattr(pg, kk)) for kk in
           ('send_idx', 'edge_src_local', 'edge_dst_local', 'edge_mask')}
    if mode == 'allgather':
        pga['send_idx'] = pga['send_idx'].reshape(k, 1, -1)
    out_parts = halo_aggregate(pga, xp, msg_fn, mesh, mode=mode)
    out = gather_from_parts(pg, np.asarray(out_parts), n)
    assert np.allclose(out, ref, atol=1e-4), f'{mode} mismatch'
    print(f'{mode} OK')

# distributed embedding lookup matches dense oracle
from repro.dist.partitioned_embedding import (RowPlacement, assemble_bags,
    distributed_lookup, route_queries)
vocab, d, bag = 512, 16, 8
table = rng.normal(size=(vocab, d)).astype(np.float32)
asg = (np.arange(vocab) % k).astype(np.int32)
pl = RowPlacement.from_assignment(asg, k)
tables = jnp.asarray(pl.shard_table(table))
ids_all, reqs, backs = [], [], []
for shard in range(k):
    ids = rng.integers(-1, vocab, (2, bag)).astype(np.int64)
    req, back, _ = route_queries(pl, ids, shard, q_max=2 * bag)
    ids_all.append(ids); reqs.append(req); backs.append(back)
resp = distributed_lookup(tables, jnp.asarray(np.stack(reqs)), mesh)
for shard in range(k):
    out = np.asarray(assemble_bags(resp[shard], jnp.asarray(backs[shard]),
                                   (2, bag)))
    ids = ids_all[shard]
    valid = ids >= 0
    vecs = table[np.where(valid, ids, 0)] * valid[..., None]
    expect = vecs.sum(1) / np.maximum(valid.sum(1), 1)[:, None]
    assert np.allclose(out, expect, atol=1e-5), f'shard {shard} mismatch'
print('embedding OK')
"""


def test_halo_and_embedding_multidevice():
    r = subprocess.run([sys.executable, "-c", SUBPROC_HALO],
                       capture_output=True, text=True, timeout=600,
                       cwd=".")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "alltoall OK" in r.stdout
    assert "allgather OK" in r.stdout
    assert "embedding OK" in r.stdout


def test_partitioned_graph_covers_all_edges():
    n = 200
    src, dst = random_graph(n, 4.0, seed=1)
    hg = graph_to_hypergraph(n, src, dst)
    asg = hype_partition(hg, 4, HypeParams(seed=0))
    for mode in ("alltoall", "allgather"):
        pg = build_partitioned_graph(n, src, dst, asg, 4, mode=mode)
        assert int(pg.edge_mask.sum()) == src.size
        # every local dst slot is a valid local node
        assert (pg.edge_dst_local[pg.edge_mask] < pg.n_local).all()
        # perm covers every node exactly once
        ids = pg.perm[pg.perm >= 0]
        assert sorted(ids.tolist()) == list(range(n))


def test_sharding_rules_divisibility_fallback():
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import spec_for
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # 24 heads on a 16-way axis must fall back to replication on a
    # 16-wide mesh; on a 1-wide mesh everything divides
    spec = spec_for(mesh, (2, 8, 24, 64), ("batch", None, "heads", None),
                    {"batch": ("data",), "heads": ("model",)})
    assert spec == P("data", None, "model", None)
