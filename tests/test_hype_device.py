"""Fully device-resident loop engine (DESIGN.md §4i): golden-hash parity
with the pipelined superstep engine at depth 1, loop-counter consistency,
the warm-pool cache-hit counter, snapshot + bit-identical resume at chunk
granularity, the OOM rung-ladder fallback, the fp16 score-cache knob, the
interpret-mode override, and parameter validation."""
import dataclasses
import hashlib

import numpy as np
import pytest

from repro.core import metrics, resilience
from repro.engines.device import DeviceParams, hype_device_partition
from repro.engines.superstep import (SuperstepParams,
                                     hype_superstep_partition)
from repro.data.synthetic import powerlaw_hypergraph, reddit_like


def _digest(a: np.ndarray) -> str:
    return hashlib.sha256(
        np.ascontiguousarray(a, dtype=np.int32).tobytes()).hexdigest()[:16]


@pytest.fixture(scope="module")
def hg():
    return powerlaw_hypergraph(600, 400, seed=11, max_edge=30,
                               max_degree=20)


@pytest.fixture(scope="module")
def dev_16_8(hg):
    """One shared (k=16, t=8) device run: parity + counter tests below
    all read it, so the while_loop program compiles once per module.
    The empty plan pins the DEVICE path: the counter/host-fraction
    assertions measure the loop itself, so an env-injected fault
    (chaos/low-memory CI) must not push this run onto the fallback."""
    return hype_device_partition(
        hg, 16, DeviceParams(seed=0, t=8,
                             fault_plan=resilience.FaultPlan()),
        return_stats=True)


# --------------------------------------------------- golden-hash parity

# The exact digests test_pipeline.py pins for hype_superstep at
# pipeline_depth=1: the device loop runs the same lock-step cadence as
# one on-device program and must land on them bit for bit.
_GOLD_PL600 = {(5, 8): "9e8abe668aa53a74",
               (16, 8): "bbcd2f732e03af91",
               (16, 16): "e67c679d4029b7d0"}
_GOLD_REDDIT = "13f232f653c9c752"


def test_device_bit_identical_16_8(dev_16_8):
    a, _ = dev_16_8
    assert _digest(a) == _GOLD_PL600[(16, 8)]


@pytest.mark.parametrize("k,t", [(5, 8), (16, 16)])
def test_device_bit_identical_powerlaw(hg, k, t):
    a = hype_device_partition(hg, k, DeviceParams(seed=0, t=t))
    assert _digest(a) == _GOLD_PL600[(k, t)]


def test_device_bit_identical_reddit_quick():
    a = hype_device_partition(reddit_like(scale=0.005, seed=0), 32,
                              DeviceParams(seed=0, t=16))
    assert _digest(a) == _GOLD_REDDIT


# ------------------------------------------------- counter consistency

def test_device_loop_counters(dev_16_8):
    """The loop counters must tell a consistent story: at least one
    chunk ran, every superstep is a device round (plus any pack-only
    rounds), the refill triggers came from the kernel, and both the
    one-time image and the resident carry are accounted."""
    _, st = dev_16_8
    assert st.supersteps > 0
    assert st.loop_chunks >= 1
    assert st.loop_rounds >= st.supersteps
    assert st.loop_pack_only >= 0
    assert st.loop_rounds >= st.loop_pack_only
    assert st.refill_signals > 0
    assert st.loop_store_peak > 0
    assert 0 < st.loop_state_bytes < st.device_image_bytes
    assert st.kernel_calls == st.supersteps


def test_device_host_fraction(dev_16_8):
    """The tentpole claim: the host does (almost) nothing per chunk —
    its share of the loop must stay under 10% of total loop time."""
    _, st = dev_16_8
    assert st.device_s > 0.0
    assert st.host_s <= 0.1 * (st.host_s + st.device_s)


def test_device_fallback_counter_is_zero(dev_16_8):
    """A supported graph must run on the device path, not fall back."""
    _, st = dev_16_8
    assert st.fallbacks == 0
    assert st.plan_rung == 0


# ------------------------------------------------ warm-pool cache hits

def test_warm_pool_cache_hits_host(hg):
    """Satellite regression: pool slots re-served from the score cache
    must count as hits (the counter was dead before §4i). A small t
    with a deep pool holds candidates across supersteps, so later
    supersteps serve them from cache."""
    _, st = hype_superstep_partition(
        hg, 16, SuperstepParams(seed=0, t=4, pool_cap=64,
                                pipeline_depth=1,
                                fault_plan=resilience.FaultPlan()),
        return_stats=True)
    assert st.cache_hits > 0


def test_warm_pool_cache_hits_device(hg):
    """The device loop counts the same event on device (S_CACHE_HITS)
    and must agree with the host engine bit for bit — same schedule,
    same held pool, same hits."""
    p_host = SuperstepParams(seed=0, t=4, pool_cap=64, pipeline_depth=1,
                             fault_plan=resilience.FaultPlan())
    a_host, st_host = hype_superstep_partition(hg, 16, p_host,
                                               return_stats=True)
    a_dev, st_dev = hype_device_partition(
        hg, 16, DeviceParams(seed=0, t=4, pool_cap=64,
                             fault_plan=resilience.FaultPlan()),
        return_stats=True)
    np.testing.assert_array_equal(a_dev, a_host)
    assert st_dev.cache_hits > 0
    assert st_dev.cache_hits == st_host.cache_hits


# ------------------------------------- snapshot + bit-identical resume

def test_device_snapshot_resume_bit_identical(hg, tmp_path):
    """Kill a snapshotting device run with an injected fatal fault,
    resume from the chunk-boundary snapshot: the final assignment must
    equal the uninterrupted run's bit for bit."""
    d = str(tmp_path / "killed")
    clean = hype_device_partition(
        hg, 16, DeviceParams(seed=0, t=8))
    with pytest.raises(resilience.UnrecoverableFault):
        hype_device_partition(hg, 16, DeviceParams(
            seed=0, t=8, snapshot_every=4, snapshot_dir=d,
            fault_plan="dispatch@5:fatal"))
    a, st = hype_device_partition(hg, 16, DeviceParams(
        seed=0, t=8, snapshot_every=4, snapshot_dir=d, resume=d),
        return_stats=True)
    np.testing.assert_array_equal(a, clean)
    assert _digest(a) == _GOLD_PL600[(16, 8)]
    assert st.resumed_at >= 4


# ------------------------------------------------ OOM rung-ladder path

def test_device_oom_falls_down_rung_ladder(hg):
    """An injected device OOM mid-loop must fall down the §4g host rung
    ladder (the device program has no reduced-memory variant), finish
    complete and balanced, and report the retry + rung + fallback."""
    a, st = hype_device_partition(
        hg, 16, DeviceParams(seed=0, t=8, fault_plan="oom@2"),
        return_stats=True)
    assert (a >= 0).all() and (a < 16).all()
    sizes = metrics.partition_sizes(a, 16)
    assert sizes.max() - sizes.min() <= 1
    assert st.mem_retries >= 1
    assert st.plan_rung >= 1
    assert st.fallbacks >= 1


# ------------------------------------------------- fp16 score cache

def test_device_fp16_cache(hg, dev_16_8):
    """cache_dtype="float16" halves the resident cache bytes. Scores on
    this graph are small exact integers (< 2048 external neighbors), so
    fp16 storage rounds nothing and the result stays bit-identical; the
    quality band is asserted too so the test degrades gracefully if the
    graph ever grows past the exact-integer range."""
    a32, st32 = dev_16_8
    a16, st16 = hype_device_partition(
        hg, 16, DeviceParams(seed=0, t=8, cache_dtype="float16",
                             fault_plan=resilience.FaultPlan()),
        return_stats=True)
    assert st16.loop_state_bytes < st32.loop_state_bytes
    assert st16.device_image_bytes < st32.device_image_bytes
    np.testing.assert_array_equal(a16, a32)
    km32 = metrics.k_minus_1(hg, a32)
    km16 = metrics.k_minus_1(hg, a16)
    assert km16 <= 1.02 * km32 + 2


# -------------------------------------------- interpret-mode override

def test_device_interpret_mode(monkeypatch, hg, dev_16_8):
    """Forcing interpret mode must still complete and stay on the same
    schedule (on CPU it is the default, so this also guards the env
    plumbing through the device-loop program)."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    a = hype_device_partition(hg, 16, DeviceParams(seed=0, t=8))
    np.testing.assert_array_equal(a, dev_16_8[0])


# ------------------------------------------- compile-cache env knob

def test_compile_cache_env_knob(monkeypatch, tmp_path):
    """REPRO_COMPILE_CACHE wires the persistent XLA compile cache:
    unset/falsy leaves it off, a path turns it on (idempotently)."""
    from repro.kernels import _compat
    cc = str(tmp_path / "cc")
    try:
        _compat.enable_compile_cache.cache_clear()
        monkeypatch.delenv("REPRO_COMPILE_CACHE", raising=False)
        assert _compat.enable_compile_cache() is None
        _compat.enable_compile_cache.cache_clear()
        monkeypatch.setenv("REPRO_COMPILE_CACHE", "off")
        assert _compat.enable_compile_cache() is None
        _compat.enable_compile_cache.cache_clear()
        monkeypatch.setenv("REPRO_COMPILE_CACHE", cc)
        assert _compat.enable_compile_cache() == cc
        # cached: a second call must not re-read the (changed) env
        monkeypatch.setenv("REPRO_COMPILE_CACHE", "0")
        assert _compat.enable_compile_cache() == cc
    finally:
        _compat.enable_compile_cache.cache_clear()
        import jax
        try:     # leave the process-global config as we found it
            jax.config.update("jax_compilation_cache_dir", None)
        except Exception:
            pass


# -------------------------------------------------- parameter contract

def test_device_param_validation(hg):
    with pytest.raises(ValueError, match="chunk_supersteps"):
        hype_device_partition(hg, 4, DeviceParams(chunk_supersteps=0))
    with pytest.raises(ValueError, match="cache_dtype"):
        hype_device_partition(hg, 4, DeviceParams(cache_dtype="bf16"))
    with pytest.raises(ValueError, match="snapshot_dir"):
        hype_device_partition(hg, 4, DeviceParams(snapshot_every=2))


def test_device_k1_shortcut(hg):
    a = hype_device_partition(hg, 1, DeviceParams(seed=0))
    assert (a == 0).all() and a.dtype == np.int32


def test_device_unsupported_falls_back(hg):
    """A graph/config the int32 encoding gates reject must transparently
    fall back to hype_superstep and still satisfy the contract."""
    from repro.core import device_loop
    # bud * 2^CLS_CLAMP reaches 2^31: the stage-A cumsum could overflow
    assert not device_loop.supported(n=10, m=100, kG=4, bud=1 << 13)
    # rows=2048 -> bud=8192 trips the same gate through the public API
    a = hype_device_partition(hg, 3, DeviceParams(seed=0, t=8,
                                                  rows=2048))
    assert (a >= 0).all() and (a < 3).all()
