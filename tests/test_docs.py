"""The documentation surface is part of tier-1: links must resolve, the
README quickstart must execute, and DESIGN.md's engine accounting must
match the method registry (the drift this PR's issue was filed about)."""
import pathlib
import re
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent


def test_doc_links_resolve():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"), "links"],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_readme_quickstart_executes():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"),
         "quickstart"],
        capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_design_engine_table_matches_registry():
    """DESIGN.md §1 lists every HYPE engine the registry exposes, and its
    prose counts them consistently (no 'three engines' next to a
    five-row table again)."""
    text = (REPO / "DESIGN.md").read_text()
    sec1 = text.split("## 2.")[0]
    from repro.core.partition_api import METHODS
    for m in METHODS:
        if m.startswith("hype") and m not in ("hype_weighted",):
            assert f"`{m}`" in sec1, f"engine {m} missing from DESIGN §1"
    assert "three engines" not in text
    # eight ladder rows: five growth rungs (hype_jax is the side-rung),
    # the multilevel composition of the refinement subsystem (§4e), the
    # streaming/online engine (§4h) and the device-resident loop (§4i)
    table_rows = re.findall(r"^\| `hype", sec1, re.MULTILINE)
    assert len(table_rows) == 8


def test_readme_documents_the_commands():
    text = (REPO / "README.md").read_text()
    assert "python -m pytest" in text                  # tier-1
    assert "benchmarks.bench_engine_scaling" in text   # bench repro
    assert "BENCH_engines.json" in text
    assert "xla_force_host_platform_device_count" in text
