"""Batched-candidate engine: validity, cross-engine agreement, kernel use,
shared-scoring equivalences, the fringe-release regression, and the
device-resident superstep engine (validity, stats, exact cache)."""
import numpy as np
import pytest

from repro.core import metrics
from repro.core.hype import HypeParams, hype_partition
from repro.core.hype_batched import (BatchedParams, SuperstepParams,
                                     _SuperstepState,
                                     hype_batched_partition,
                                     hype_superstep_partition)
from repro.core.hype_jax import PaddedHypergraph, hype_jax_partition
from repro.core.hypergraph import Hypergraph
from repro.core.partition_api import METHODS, partition
from repro.core import scoring
from repro.data.synthetic import powerlaw_hypergraph


@pytest.fixture(scope="module")
def hg():
    return powerlaw_hypergraph(600, 400, seed=11, max_edge=30,
                               max_degree=20)


# ------------------------------------------------------------- validity

@pytest.mark.parametrize("k", [2, 5, 16])
def test_batched_complete_and_balanced(hg, k):
    a = hype_batched_partition(hg, k, BatchedParams(seed=0))
    assert a.shape == (hg.n,)
    assert a.dtype == np.int32
    assert a.min() >= 0 and a.max() < k
    sizes = metrics.partition_sizes(a, k)
    assert sizes.max() - sizes.min() <= 1


def test_batched_deterministic(hg):
    a1 = hype_batched_partition(hg, 6, BatchedParams(seed=3))
    a2 = hype_batched_partition(hg, 6, BatchedParams(seed=3))
    np.testing.assert_array_equal(a1, a2)


def test_batched_registered_in_api(hg):
    assert "hype_batched" in METHODS
    a = partition(hg, 4, "hype_batched", seed=0)
    assert a.min() >= 0 and a.max() < 4


# ------------------------------------------- cross-engine agreement

def test_t1_agrees_with_numpy_engine():
    """t=1 recovers sequential admission: same-seed runs must be complete,
    balanced, and within quality tolerance of the paper engine."""
    for seed in (0, 1):
        hg = powerlaw_hypergraph(400, 260, seed=seed, max_edge=20,
                                 max_degree=14)
        k = 5
        a_b = hype_batched_partition(hg, k, BatchedParams(seed=seed, t=1))
        a_n = hype_partition(hg, k, HypeParams(seed=seed))
        for a in (a_b, a_n):
            assert (a >= 0).all() and (a < k).all()
            sizes = metrics.partition_sizes(a, k)
            assert sizes.max() - sizes.min() <= 1
        km_b = metrics.k_minus_1(hg, a_b)
        km_n = metrics.k_minus_1(hg, a_n)
        assert km_b <= 1.35 * km_n + 20


def test_t1_agrees_with_jax_engine():
    hg = powerlaw_hypergraph(250, 160, seed=2, max_edge=16, max_degree=10)
    k = 4
    a_b = hype_batched_partition(hg, k, BatchedParams(seed=0, t=1))
    a_j = hype_jax_partition(hg, k, seed=0)
    km_b = metrics.k_minus_1(hg, a_b)
    km_j = metrics.k_minus_1(hg, a_j)
    sizes = metrics.partition_sizes(a_b, k)
    assert sizes.max() - sizes.min() <= 1
    assert km_b <= 1.35 * km_j + 20


def test_t_is_speed_knob_not_quality_cliff(hg):
    """Raising t cuts steps; quality stays in the same regime."""
    k = 8
    _, st1 = hype_batched_partition(hg, k, BatchedParams(seed=0, t=1),
                                    return_stats=True)
    a8, st8 = hype_batched_partition(hg, k, BatchedParams(seed=0, t=8),
                                     return_stats=True)
    assert st8.steps < st1.steps
    km8 = metrics.k_minus_1(hg, a8)
    km_r = metrics.k_minus_1(
        hg, partition(hg, k, "random", seed=0))
    assert km8 < km_r


# ------------------------------------------------------------ edge cases

def test_k1_single_partition(hg):
    a = hype_batched_partition(hg, 1, BatchedParams(seed=0))
    assert (a == 0).all()


def test_singletons_and_empty_edges():
    # 6 vertices; vertex 4,5 are singletons (no pins); edge 2 is empty
    hg = Hypergraph.from_edge_lists(6, [[0, 1], [1, 2, 3], []])
    for k in (1, 2, 3):
        a = hype_batched_partition(hg, k, BatchedParams(seed=0))
        assert (a >= 0).all() and (a < k).all()
        sizes = metrics.partition_sizes(a, k)
        assert sizes.max() - sizes.min() <= 1


def test_kernel_on_hot_path(hg):
    """The Pallas hype_scores kernel must score the bulk candidates."""
    from repro.core import resilience

    # an explicitly empty plan keeps this run fault-free even under the
    # chaos CI env (an injected NaN tile legitimately quarantines rows
    # to the host path, which is exactly what host_rows == 0 rules out)
    _, st = hype_batched_partition(
        hg, 6, BatchedParams(seed=0, kernel_min=1,
                             fault_plan=resilience.FaultPlan()),
        return_stats=True)
    assert st.kernel_calls > 0
    assert st.kernel_rows > 0
    assert st.host_rows == 0      # kernel_min=1 routes everything there


# ------------------------------------------- shared scoring equivalence

def test_tile_paths_agree():
    """Adjacency fast path == per-batch dedup path, row for row."""
    hg = powerlaw_hypergraph(300, 200, seed=4, max_edge=18, max_degree=12)
    rng = np.random.default_rng(0)
    assignment = np.where(rng.random(hg.n) < 0.3,
                          rng.integers(0, 4, hg.n), -1).astype(np.int32)
    cands = rng.choice(np.flatnonzero(assignment < 0), 40, replace=False)
    adj = hg.vertex_adjacency()
    t1, tr1 = scoring.neighbor_tile(hg, cands, assignment, pad_b=64)
    t2, tr2 = scoring.neighbor_tile_adj(adj, cands, assignment, pad_b=64)
    np.testing.assert_array_equal(tr1, tr2)
    # same sets per row (construction order may differ)
    for i in range(len(cands)):
        np.testing.assert_array_equal(np.sort(t1[i][t1[i] >= 0]),
                                      np.sort(t2[i][t2[i] >= 0]))


def test_batched_dext_matches_scalar():
    """Vectorized d_ext == the numpy engine's per-vertex d_ext."""
    from repro.core.hype import _HypeState
    hg = powerlaw_hypergraph(300, 200, seed=5, max_edge=18, max_degree=12)
    st = _HypeState(hg, 4, HypeParams(seed=0))
    rng = np.random.default_rng(1)
    st.assignment[rng.random(hg.n) < 0.25] = 1
    fr = rng.choice(np.flatnonzero(st.assignment < 0), 8, replace=False)
    st.in_fringe[fr] = True
    vs = rng.integers(0, hg.n, 50)
    batch = scoring.batched_dext_numpy(hg, vs, st.in_fringe, st.assignment)
    scalar = np.asarray([st.d_ext(int(v)) for v in vs])
    np.testing.assert_allclose(batch, scalar)
    # adjacency path agrees too
    adj = hg.vertex_adjacency()
    np.testing.assert_allclose(
        scoring.batched_dext_adj(adj, vs, st.in_fringe, st.assignment),
        scalar)


def test_padded_hypergraph_vectorized_matches_loop():
    """from_hypergraph: numpy scatter == the per-row loop, bit for bit."""
    for seed in range(4):
        hg = powerlaw_hypergraph(120, 90, seed=seed, max_edge=14,
                                 max_degree=9)
        ph = PaddedHypergraph.from_hypergraph(hg)
        max_deg = max(1, int(hg.vertex_degrees.max()))
        max_size = max(1, int(hg.edge_sizes.max()))
        v2e = np.full((hg.n, max_deg), -1, dtype=np.int32)
        e2v = np.full((hg.m, max_size), -1, dtype=np.int32)
        for v in range(hg.n):
            es = hg.vertex_edges(v)
            v2e[v, :es.size] = es
        for e in range(hg.m):
            ps = hg.edge_pins(e)
            e2v[e, :ps.size] = ps
        np.testing.assert_array_equal(np.asarray(ph.v2e), v2e)
        np.testing.assert_array_equal(np.asarray(ph.e2v), e2v)
    # degenerate: vertices/edges with no pins at all
    hg0 = Hypergraph.from_edge_lists(3, [[], [0]])
    ph0 = PaddedHypergraph.from_hypergraph(hg0)
    assert ph0.v2e.shape == (3, 1) and ph0.e2v.shape == (2, 1)


def test_vertex_adjacency_matches_neighbors():
    hg = powerlaw_hypergraph(150, 100, seed=6, max_edge=12, max_degree=8)
    indptr, indices = hg.vertex_adjacency()
    for v in (0, 7, int(np.argmax(hg.vertex_degrees)), hg.n - 1):
        row = indices[indptr[v]:indptr[v + 1]]
        np.testing.assert_array_equal(np.sort(row), hg.neighbors(v))


# ------------------------------------------------------ superstep engine

@pytest.mark.parametrize("k", [2, 5, 16])
def test_superstep_complete_and_balanced(hg, k):
    a = hype_superstep_partition(hg, k, SuperstepParams(seed=0))
    assert a.shape == (hg.n,)
    assert a.dtype == np.int32
    assert a.min() >= 0 and a.max() < k
    sizes = metrics.partition_sizes(a, k)
    assert sizes.max() - sizes.min() <= 1


def test_superstep_deterministic(hg):
    a1 = hype_superstep_partition(hg, 6, SuperstepParams(seed=3))
    a2 = hype_superstep_partition(hg, 6, SuperstepParams(seed=3))
    np.testing.assert_array_equal(a1, a2)


def test_superstep_registered_in_api(hg):
    assert "hype_superstep" in METHODS
    a = partition(hg, 4, "hype_superstep", seed=0)
    assert a.min() >= 0 and a.max() < 4


def test_superstep_quality_regime(hg):
    """Concurrent k-way growth stays in the sequential engines' quality
    regime (same tolerance as the batched engine's agreement tests)."""
    k = 8
    a_s = hype_superstep_partition(hg, k, SuperstepParams(seed=0))
    a_n = hype_partition(hg, k, HypeParams(seed=0))
    km_s = metrics.k_minus_1(hg, a_s)
    km_n = metrics.k_minus_1(hg, a_n)
    assert km_s <= 1.35 * km_n + 20


def test_superstep_edge_cases():
    hg = Hypergraph.from_edge_lists(6, [[0, 1], [1, 2, 3], []])
    for k in (1, 2, 3, 8):
        a = hype_superstep_partition(hg, k, SuperstepParams(seed=0))
        assert (a >= 0).all() and (a < k).all()
        sizes = np.bincount(a, minlength=min(k, 6))
        assert sizes.max() - sizes.min() <= 1


def test_superstep_stats_counters(hg):
    """The superstep/transfer counters must measure the device traffic."""
    _, stt = hype_superstep_partition(hg, 8, SuperstepParams(seed=0),
                                      return_stats=True)
    assert stt.supersteps > 0
    assert stt.kernel_calls == stt.supersteps
    assert stt.kernel_rows > 0
    assert stt.device_image_bytes > 0
    assert stt.host_to_device_bytes > 0
    assert stt.cache_invalidations > 0
    assert stt.host_rows == 0            # no host-scoring fallback path
    # per-superstep traffic is ids + small bias buffers, not (B, L) tiles
    per_step = (stt.host_to_device_bytes / stt.supersteps)
    assert per_step < 8 * 64 * scoring.L_BUCKETS[-1]


def test_superstep_cache_exact_after_admissions():
    """Property check for decrement-based invalidation: after ANY
    admission sequence — device-selected winners (clipped decrements +
    host-queued tails) and host injections alike — every cached score
    equals a fresh ``batched_dext_adj`` recompute: the stale-score
    drift the old per-phase wipe was hiding cannot exist."""
    for seed in (0, 1, 2):
        hg = powerlaw_hypergraph(300, 200, seed=10 + seed, max_edge=18,
                                 max_degree=12)
        k, R, t = 4, 8, 2
        rng = np.random.default_rng(seed)
        st = _SuperstepState(hg, k, SuperstepParams(seed=seed))
        fringe = np.full((k, 1), -1, np.int32)
        empty_pool = np.full((k, 4), -1, np.int32)
        acc = np.zeros(k, dtype=np.int64)
        targets = np.full(k, hg.n, dtype=np.int64)
        for step in range(10):
            # score a random batch of never-scored vertices; the device
            # admits up to a random per-phase cap of them (cap 0 phases
            # exercise the selection-without-admission path) ...
            cand = np.flatnonzero(~st.cache_scored & (st.assignment < 0))
            fresh = np.full((k, R), -1, np.int32)
            if cand.size:
                pick = rng.choice(cand, size=min(k * R, cand.size),
                                  replace=False)
                fresh.reshape(-1)[:pick.size] = pick
            bias = np.where(fresh >= 0, 0, np.inf).astype(np.float32)
            cap = rng.integers(0, t + 1, size=k)
            tgt = (acc + cap).astype(np.int32)
            handle = st.dispatch(fresh, bias, empty_pool, fringe,
                                 fresh[fresh >= 0].astype(np.int64),
                                 tgt, 32, t)
            st.harvest(handle, acc, targets)
            # ... then admit a random batch by host injection too
            un = np.flatnonzero(st.assignment < 0)
            if un.size == 0:
                break
            vs = rng.choice(un, size=min(int(rng.integers(1, 8)),
                                         un.size), replace=False)
            g = int(rng.integers(0, k))
            st.assign_now(vs, g)
            acc[g] += vs.size
        while st.delta_ids or st.pending_dirty:    # flush tails + deltas
            handle = st.dispatch(np.full((k, 1), -1, np.int32),
                                 np.full((k, 1), np.inf, np.float32),
                                 np.full((k, 1), -1, np.int32), fringe,
                                 np.empty(0, dtype=np.int64),
                                 acc.astype(np.int32), 32, 1)
            st.harvest(handle, acc, targets)
        cache = np.asarray(st.dev_cache, dtype=np.float64)
        # rows wider than the run's tile width are truncated hubs parked
        # at ~1e12 — the exactness contract covers everything else
        scored = np.flatnonzero(st.cache_scored & (st.deg <= st.tile_l))
        assert scored.size > 50
        ref = scoring.batched_dext_adj(st.adj, scored,
                                       np.zeros(hg.n, dtype=bool),
                                       st.assignment)
        assert (ref > 0).any()           # the recompute is not trivial
        np.testing.assert_allclose(cache[scored], ref)
        # device/host assignment + totals parity after the flush
        np.testing.assert_array_equal(np.asarray(st.dev_assign),
                                      st.assignment)
        np.testing.assert_array_equal(
            np.asarray(st.dev_acc),
            np.bincount(st.assignment[st.assignment >= 0],
                        minlength=k))


def test_superstep_cross_phase_cache_reuse():
    """Scores survive phase completion: when a finished phase releases
    its pool and another phase redraws those vertices, they are cache
    hits — impossible under the old per-phase wipe."""
    for seed in range(3):
        hg = powerlaw_hypergraph(300, 500, seed=21 + seed, max_edge=10,
                                 max_degree=30)
        _, stt = hype_superstep_partition(
            hg, 24, SuperstepParams(seed=seed, pool_cap=16),
            return_stats=True)
        assert stt.cache_hits > 0


# --------------------------------------------- fringe-release regression

def test_seq_grow_releases_fringe():
    """After each phase the jittable engine must leave in_fringe all-False
    (the old `.at[].set(x & (idx < 0))` eviction raced on vertex 0)."""
    import jax
    import jax.numpy as jnp
    from repro.core import hype_jax as hj

    hg = powerlaw_hypergraph(200, 140, seed=7, max_edge=14, max_degree=10)
    ph = PaddedHypergraph.from_hypergraph(hg)
    n, s, r = ph.n, 10, 2
    state = hj._SeqState(
        assignment=jnp.full((n,), -1, jnp.int32),
        in_fringe=jnp.zeros((n,), bool),
        fringe=jnp.full((s,), -1, jnp.int32),
        cache=jnp.full((n,), -1.0, jnp.float32),
        edge_active=jnp.zeros((ph.m,), bool),
        core_size=jnp.int32(0),
        rand_key=jax.random.PRNGKey(0),
    )
    grow = jax.jit(hj._seq_grow, static_argnames=("part", "s", "r"))
    for part in range(3):
        state = grow(ph, state, part=part, target=jnp.int32(n // 4),
                     s=s, r=r)
        state = hj._release_fringe(state, n, s)
        assert not bool(np.asarray(state.in_fringe).any()), \
            f"in_fringe leaked after phase {part}"
        assert (np.asarray(state.fringe) == -1).all()
