"""Batched-candidate engine suite: validity, cross-engine agreement,
edge cases, and Pallas-kernel hot-path coverage (repro.engines.batched)."""
import numpy as np
import pytest

from repro.core import metrics
from repro.core.hype import HypeParams, hype_partition
from repro.core.hype_jax import hype_jax_partition
from repro.core.hypergraph import Hypergraph
from repro.core.partition_api import METHODS, partition
from repro.data.synthetic import powerlaw_hypergraph
from repro.engines.batched import BatchedParams, hype_batched_partition


@pytest.fixture(scope="module")
def hg():
    return powerlaw_hypergraph(600, 400, seed=11, max_edge=30,
                               max_degree=20)

# ------------------------------------------------------------- validity

@pytest.mark.parametrize("k", [2, 5, 16])
def test_batched_complete_and_balanced(hg, k):
    a = hype_batched_partition(hg, k, BatchedParams(seed=0))
    assert a.shape == (hg.n,)
    assert a.dtype == np.int32
    assert a.min() >= 0 and a.max() < k
    sizes = metrics.partition_sizes(a, k)
    assert sizes.max() - sizes.min() <= 1


def test_batched_deterministic(hg):
    a1 = hype_batched_partition(hg, 6, BatchedParams(seed=3))
    a2 = hype_batched_partition(hg, 6, BatchedParams(seed=3))
    np.testing.assert_array_equal(a1, a2)


def test_batched_registered_in_api(hg):
    assert "hype_batched" in METHODS
    a = partition(hg, 4, "hype_batched", seed=0)
    assert a.min() >= 0 and a.max() < 4


# ------------------------------------------- cross-engine agreement

def test_t1_agrees_with_numpy_engine():
    """t=1 recovers sequential admission: same-seed runs must be complete,
    balanced, and within quality tolerance of the paper engine."""
    for seed in (0, 1):
        hg = powerlaw_hypergraph(400, 260, seed=seed, max_edge=20,
                                 max_degree=14)
        k = 5
        a_b = hype_batched_partition(hg, k, BatchedParams(seed=seed, t=1))
        a_n = hype_partition(hg, k, HypeParams(seed=seed))
        for a in (a_b, a_n):
            assert (a >= 0).all() and (a < k).all()
            sizes = metrics.partition_sizes(a, k)
            assert sizes.max() - sizes.min() <= 1
        km_b = metrics.k_minus_1(hg, a_b)
        km_n = metrics.k_minus_1(hg, a_n)
        assert km_b <= 1.35 * km_n + 20


def test_t1_agrees_with_jax_engine():
    hg = powerlaw_hypergraph(250, 160, seed=2, max_edge=16, max_degree=10)
    k = 4
    a_b = hype_batched_partition(hg, k, BatchedParams(seed=0, t=1))
    a_j = hype_jax_partition(hg, k, seed=0)
    km_b = metrics.k_minus_1(hg, a_b)
    km_j = metrics.k_minus_1(hg, a_j)
    sizes = metrics.partition_sizes(a_b, k)
    assert sizes.max() - sizes.min() <= 1
    assert km_b <= 1.35 * km_j + 20


def test_t_is_speed_knob_not_quality_cliff(hg):
    """Raising t cuts steps; quality stays in the same regime."""
    k = 8
    _, st1 = hype_batched_partition(hg, k, BatchedParams(seed=0, t=1),
                                    return_stats=True)
    a8, st8 = hype_batched_partition(hg, k, BatchedParams(seed=0, t=8),
                                     return_stats=True)
    assert st8.steps < st1.steps
    km8 = metrics.k_minus_1(hg, a8)
    km_r = metrics.k_minus_1(
        hg, partition(hg, k, "random", seed=0))
    assert km8 < km_r


# ------------------------------------------------------------ edge cases

def test_k1_single_partition(hg):
    a = hype_batched_partition(hg, 1, BatchedParams(seed=0))
    assert (a == 0).all()


def test_singletons_and_empty_edges():
    # 6 vertices; vertex 4,5 are singletons (no pins); edge 2 is empty
    hg = Hypergraph.from_edge_lists(6, [[0, 1], [1, 2, 3], []])
    for k in (1, 2, 3):
        a = hype_batched_partition(hg, k, BatchedParams(seed=0))
        assert (a >= 0).all() and (a < k).all()
        sizes = metrics.partition_sizes(a, k)
        assert sizes.max() - sizes.min() <= 1


def test_kernel_on_hot_path(hg):
    """The Pallas hype_scores kernel must score the bulk candidates."""
    from repro.core import resilience

    # an explicitly empty plan keeps this run fault-free even under the
    # chaos CI env (an injected NaN tile legitimately quarantines rows
    # to the host path, which is exactly what host_rows == 0 rules out)
    _, st = hype_batched_partition(
        hg, 6, BatchedParams(seed=0, kernel_min=1,
                             fault_plan=resilience.FaultPlan()),
        return_stats=True)
    assert st.kernel_calls > 0
    assert st.kernel_rows > 0
    assert st.host_rows == 0      # kernel_min=1 routes everything there


