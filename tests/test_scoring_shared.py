"""Shared scoring-layer equivalences and the jittable-engine fringe
regression: tile construction paths, vectorized d_ext vs the scalar
reference, PaddedHypergraph construction, and CSR adjacency."""
import numpy as np

from repro.core import scoring
from repro.core.hype import HypeParams
from repro.core.hype_jax import PaddedHypergraph
from repro.core.hypergraph import Hypergraph
from repro.data.synthetic import powerlaw_hypergraph

# ------------------------------------------- shared scoring equivalence

def test_tile_paths_agree():
    """Adjacency fast path == per-batch dedup path, row for row."""
    hg = powerlaw_hypergraph(300, 200, seed=4, max_edge=18, max_degree=12)
    rng = np.random.default_rng(0)
    assignment = np.where(rng.random(hg.n) < 0.3,
                          rng.integers(0, 4, hg.n), -1).astype(np.int32)
    cands = rng.choice(np.flatnonzero(assignment < 0), 40, replace=False)
    adj = hg.vertex_adjacency()
    t1, tr1 = scoring.neighbor_tile(hg, cands, assignment, pad_b=64)
    t2, tr2 = scoring.neighbor_tile_adj(adj, cands, assignment, pad_b=64)
    np.testing.assert_array_equal(tr1, tr2)
    # same sets per row (construction order may differ)
    for i in range(len(cands)):
        np.testing.assert_array_equal(np.sort(t1[i][t1[i] >= 0]),
                                      np.sort(t2[i][t2[i] >= 0]))


def test_batched_dext_matches_scalar():
    """Vectorized d_ext == the numpy engine's per-vertex d_ext."""
    from repro.core.hype import _HypeState
    hg = powerlaw_hypergraph(300, 200, seed=5, max_edge=18, max_degree=12)
    st = _HypeState(hg, 4, HypeParams(seed=0))
    rng = np.random.default_rng(1)
    st.assignment[rng.random(hg.n) < 0.25] = 1
    fr = rng.choice(np.flatnonzero(st.assignment < 0), 8, replace=False)
    st.in_fringe[fr] = True
    vs = rng.integers(0, hg.n, 50)
    batch = scoring.batched_dext_numpy(hg, vs, st.in_fringe, st.assignment)
    scalar = np.asarray([st.d_ext(int(v)) for v in vs])
    np.testing.assert_allclose(batch, scalar)
    # adjacency path agrees too
    adj = hg.vertex_adjacency()
    np.testing.assert_allclose(
        scoring.batched_dext_adj(adj, vs, st.in_fringe, st.assignment),
        scalar)


def test_padded_hypergraph_vectorized_matches_loop():
    """from_hypergraph: numpy scatter == the per-row loop, bit for bit."""
    for seed in range(4):
        hg = powerlaw_hypergraph(120, 90, seed=seed, max_edge=14,
                                 max_degree=9)
        ph = PaddedHypergraph.from_hypergraph(hg)
        max_deg = max(1, int(hg.vertex_degrees.max()))
        max_size = max(1, int(hg.edge_sizes.max()))
        v2e = np.full((hg.n, max_deg), -1, dtype=np.int32)
        e2v = np.full((hg.m, max_size), -1, dtype=np.int32)
        for v in range(hg.n):
            es = hg.vertex_edges(v)
            v2e[v, :es.size] = es
        for e in range(hg.m):
            ps = hg.edge_pins(e)
            e2v[e, :ps.size] = ps
        np.testing.assert_array_equal(np.asarray(ph.v2e), v2e)
        np.testing.assert_array_equal(np.asarray(ph.e2v), e2v)
    # degenerate: vertices/edges with no pins at all
    hg0 = Hypergraph.from_edge_lists(3, [[], [0]])
    ph0 = PaddedHypergraph.from_hypergraph(hg0)
    assert ph0.v2e.shape == (3, 1) and ph0.e2v.shape == (2, 1)


def test_vertex_adjacency_matches_neighbors():
    hg = powerlaw_hypergraph(150, 100, seed=6, max_edge=12, max_degree=8)
    indptr, indices = hg.vertex_adjacency()
    for v in (0, 7, int(np.argmax(hg.vertex_degrees)), hg.n - 1):
        row = indices[indptr[v]:indptr[v + 1]]
        np.testing.assert_array_equal(np.sort(row), hg.neighbors(v))




# --------------------------------------------- fringe-release regression

def test_seq_grow_releases_fringe():
    """After each phase the jittable engine must leave in_fringe all-False
    (the old `.at[].set(x & (idx < 0))` eviction raced on vertex 0)."""
    import jax
    import jax.numpy as jnp
    from repro.core import hype_jax as hj

    hg = powerlaw_hypergraph(200, 140, seed=7, max_edge=14, max_degree=10)
    ph = PaddedHypergraph.from_hypergraph(hg)
    n, s, r = ph.n, 10, 2
    state = hj._SeqState(
        assignment=jnp.full((n,), -1, jnp.int32),
        in_fringe=jnp.zeros((n,), bool),
        fringe=jnp.full((s,), -1, jnp.int32),
        cache=jnp.full((n,), -1.0, jnp.float32),
        edge_active=jnp.zeros((ph.m,), bool),
        core_size=jnp.int32(0),
        rand_key=jax.random.PRNGKey(0),
    )
    grow = jax.jit(hj._seq_grow, static_argnames=("part", "s", "r"))
    for part in range(3):
        state = grow(ph, state, part=part, target=jnp.int32(n // 4),
                     s=s, r=r)
        state = hj._release_fringe(state, n, s)
        assert not bool(np.asarray(state.in_fringe).any()), \
            f"in_fringe leaked after phase {part}"
        assert (np.asarray(state.fringe) == -1).all()
