"""Optimizer, checkpoint, fault tolerance, compression, data pipeline."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.train.optimizer import (AdamWConfig, adamw_update, init_adamw,
                                   lr_at)
from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.grad_compression import compress_tree, init_error_feedback
from repro.data.pipeline import Prefetcher, TokenStream
from repro.data.graphs import NeighborSampler, random_graph


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                      weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = init_adamw(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, cfg)
    assert float(loss(params)) < 1e-2


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(lr_at(cfg, jnp.int32(s))) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(0.1, abs=1e-6)


def test_grad_clip_applied():
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(3)}
    opt = init_adamw(params, cfg)
    g = {"w": jnp.full(3, 100.0)}
    _, _, stats = adamw_update(g, opt, params, cfg)
    assert float(stats["grad_norm"]) > 100


def test_checkpoint_roundtrip_bf16():
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": {"c": jnp.float32(3.5), "d": np.arange(4)}}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 7, tree)
        assert latest_step(d) == 7
        out = restore_checkpoint(d, 7, tree)
        np.testing.assert_array_equal(np.asarray(out["a"], np.float32),
                                      np.asarray(tree["a"], np.float32))
        assert float(out["b"]["c"]) == 3.5


def test_checkpoint_gc_and_latest():
    tree = {"x": jnp.ones(2)}
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            save_checkpoint(d, s, tree, keep_last=2)
        dirs = [x for x in os.listdir(d) if x.startswith("step_")]
        assert len(dirs) == 2
        assert latest_step(d) == 5


def test_checkpoint_shape_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"x": jnp.ones((2, 2))})
        with pytest.raises(AssertionError):
            restore_checkpoint(d, 1, {"x": jnp.ones((3, 3))})


def test_run_training_rewind_truncates_history():
    """A checkpoint-restore rewind must also rewind the metrics log:
    the replayed steps re-append their metrics, so without truncation
    the history double-counts every step between checkpoint and fault
    (``steps_done != len(metrics_history)``)."""
    from repro.train.fault_tolerance import FTConfig, run_training

    def train_step(params, opt, batch):
        params = params + batch
        return params, opt, {"loss": float(params.sum()), "step_in": 1.0}

    def batch_at(step):
        return jnp.full((2,), float(step + 1))

    fails = {"armed": True}

    def fail_injector(step):
        # one injected node failure at step 7, after the step-5 ckpt
        if step == 7 and fails["armed"]:
            fails["armed"] = False
            raise RuntimeError("injected node failure")

    with tempfile.TemporaryDirectory() as d:
        ft = FTConfig(ckpt_dir=d, ckpt_every=5, max_retries_per_step=2)
        state = (jnp.zeros(2), jnp.zeros(1))
        res = run_training(train_step, state, iter(()), 10, ft,
                           batch_at=batch_at, fail_injector=fail_injector)
    assert res.failures_recovered == 1
    assert res.steps_done == 10
    # exactly one metrics entry per completed step — the rewound steps
    # (5, 6) appear once, not twice
    assert len(res.metrics_history) == 10
    losses = [m["loss"] for m in res.metrics_history]
    # deterministic replay: the history equals a failure-free run's
    expect = np.cumsum(2 * np.arange(1.0, 11.0))
    np.testing.assert_allclose(losses, expect)


def test_compression_error_feedback_reduces_bias():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
    err = init_error_feedback(g)
    total_deq = np.zeros(64, np.float32)
    total_g = np.zeros(64, np.float32)
    for _ in range(50):
        deq, err = compress_tree(g, err)
        total_deq += np.asarray(deq["w"])
        total_g += np.asarray(g["w"])
    # error feedback keeps the cumulative quantized sum unbiased
    rel = np.abs(total_deq - total_g).max() / np.abs(total_g).max()
    assert rel < 0.01


def test_token_stream_deterministic_and_sharded():
    a = TokenStream(100, 4, 16, shard=0, n_shards=2, seed=1).batch_at(3)
    b = TokenStream(100, 4, 16, shard=0, n_shards=2, seed=1).batch_at(3)
    c = TokenStream(100, 4, 16, shard=1, n_shards=2, seed=1).batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].max() < 100


def test_prefetcher_straggler_reserve():
    import itertools
    import time

    def slow_gen():
        yield {"x": 1}
        time.sleep(1.0)
        yield {"x": 2}

    pf = Prefetcher(slow_gen(), depth=1, timeout_s=0.1)
    first = next(pf)
    assert first["x"] == 1
    second = next(pf)           # times out -> re-serves last batch
    assert second["x"] == 1
    assert pf.skipped >= 1
    pf.close()


def test_neighbor_sampler_shapes_and_validity():
    n = 500
    src, dst = random_graph(n, 6.0, seed=0)
    sampler = NeighborSampler(n, src, dst)
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(n, 8)).astype(np.float32)
    labels = rng.integers(0, 5, n).astype(np.int32)
    seeds = rng.choice(n, 32, replace=False)
    batch = sampler.sample_padded(seeds, (5, 3), rng, max_nodes=1024,
                                  max_edges=2048, features=feats,
                                  labels=labels)
    assert batch["nodes"].shape == (1024, 8)
    assert batch["edge_src"].shape == (2048,)
    e = batch["edge_mask"].sum()
    assert 0 < e <= 32 * 5 * (1 + 3)
    # all real edges reference in-range nodes
    assert batch["edge_src"][batch["edge_mask"]].max() < 1024
    # seeds-first relabeling: first len(seeds) slots are the seeds
    np.testing.assert_array_equal(batch["nodes"][:32], feats[seeds])


@given(st.integers(10, 200), st.integers(1, 8), st.integers(0, 20))
@settings(max_examples=10, deadline=None)
def test_sampler_property(n, fanout, seed):
    src, dst = random_graph(n, 3.0, seed=seed)
    if src.size == 0:
        return
    sampler = NeighborSampler(n, src, dst)
    rng = np.random.default_rng(seed)
    seeds = rng.choice(n, min(8, n), replace=False)
    sub = sampler.sample(seeds, [fanout], rng)
    # every sampled edge's dst is a seed, src is a real in-neighbor
    assert (sub["edge_dst"] < len(seeds)).all()
