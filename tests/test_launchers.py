"""CLI launcher smoke tests (reduced configs, tiny step counts)."""
import os
import subprocess
import sys

import pytest

ENV = {**os.environ, "PYTHONPATH": "src"}


def _run(mod, *args, timeout=560):
    return subprocess.run([sys.executable, "-m", mod, *args],
                          capture_output=True, text=True, timeout=timeout,
                          env=ENV, cwd=".")


@pytest.mark.slow
def test_train_cli_lm_reduced(tmp_path):
    r = _run("repro.launch.train", "--arch", "stablelm-3b", "--reduced",
             "--steps", "8", "--ckpt_dir", str(tmp_path))
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    assert "loss" in r.stdout


@pytest.mark.slow
def test_train_cli_recsys_reduced(tmp_path):
    r = _run("repro.launch.train", "--arch", "two-tower-retrieval",
             "--reduced", "--steps", "8", "--ckpt_dir", str(tmp_path))
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]


@pytest.mark.slow
def test_serve_cli_lm_reduced():
    r = _run("repro.launch.serve", "--arch", "mixtral-8x22b", "--reduced",
             "--batch", "2", "--prompt_len", "8", "--tokens", "4")
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    assert "tok/s" in r.stdout
