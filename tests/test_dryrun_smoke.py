"""Dry-run harness smoke test: one real cell at 512 placeholder devices
(subprocess — the XLA flag must precede jax init)."""
import json
import os
import subprocess
import sys
import tempfile

import pytest

# a sharded (mesh != None) dry-run needs the repro.dist sharding rules
pytest.importorskip("repro.dist", reason="repro.dist not built yet")


@pytest.mark.slow
def test_dryrun_one_cell_512_devices():
    with tempfile.TemporaryDirectory() as d:
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "two-tower-retrieval", "--shape", "serve_p99",
             "--mesh", "single", "--out", d, "--force"],
            capture_output=True, text=True, timeout=560,
            env={**os.environ, "PYTHONPATH": "src"}, cwd=".")
        assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
        path = os.path.join(
            d, "two-tower-retrieval__serve_p99__single.json")
        with open(path) as f:
            rec = json.load(f)
        assert rec["roofline"]["bound"] in ("compute", "memory",
                                            "collective")
        assert rec["cost_per_device"]["flops"] > 0
