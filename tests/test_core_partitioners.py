"""Behavior tests for HYPE and all baseline partitioners."""
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.core.hypergraph import Hypergraph
from repro.core.hype import HypeParams, hype_partition, hyperedge_balanced_hype
from repro.core.partition_api import METHODS, partition
from repro.core import metrics
from repro.data.synthetic import powerlaw_hypergraph, community_hypergraph


@pytest.fixture(scope="module")
def hg():
    return powerlaw_hypergraph(800, 500, seed=7, max_edge=40, max_degree=24)


@pytest.mark.parametrize("method", METHODS)
def test_valid_complete_assignment(hg, method):
    k = 8
    a = partition(hg, k, method, seed=0)
    assert a.shape == (hg.n,)
    assert a.min() >= 0 and a.max() < k
    assert a.dtype == np.int32


@pytest.mark.parametrize("method", ["hype", "minmax_nb", "random"])
def test_determinism(hg, method):
    a1 = partition(hg, 4, method, seed=11)
    a2 = partition(hg, 4, method, seed=11)
    np.testing.assert_array_equal(a1, a2)


def test_hype_perfect_vertex_balance(hg):
    """Paper §III-B1 step 4: perfectly balanced vertex counts."""
    for k in (2, 7, 16):
        a = hype_partition(hg, k, HypeParams(seed=0))
        sizes = metrics.partition_sizes(a, k)
        assert sizes.max() - sizes.min() <= 1


def test_hype_beats_random(hg):
    k = 16
    a_h = partition(hg, k, "hype", seed=0)
    a_r = partition(hg, k, "random", seed=0)
    assert metrics.k_minus_1(hg, a_h) < 0.75 * metrics.k_minus_1(hg, a_r)


def test_hype_weighted_balance(hg):
    a = hype_partition(hg, 4, HypeParams(seed=0, balance="weighted"))
    w = 1.0 + hg.vertex_degrees
    loads = np.zeros(4)
    np.add.at(loads, a, w)
    assert loads.max() <= 1.35 * loads.mean()


def test_hyperedge_balanced_flip(hg):
    a = hyperedge_balanced_hype(hg, 4, HypeParams(seed=0))
    assert a.shape == (hg.m,)
    sizes = metrics.partition_sizes(a, 4)
    assert sizes.max() - sizes.min() <= 1


def test_hype_k1_single_partition(hg):
    a = hype_partition(hg, 1, HypeParams(seed=0))
    assert (a == 0).all()
    assert metrics.k_minus_1(hg, a) == 0


def test_partition_and_report_contract(hg):
    """Pins the documented return shape: ``(report dict, assignment)``."""
    from repro.core.partition_api import partition_and_report
    out = partition_and_report(hg, 4, "hype_batched", seed=0)
    assert isinstance(out, tuple) and len(out) == 2
    rep, assignment = out
    assert isinstance(rep, dict)
    for key in ("k_minus_1", "method", "k", "runtime_s"):
        assert key in rep
    assert rep["method"] == "hype_batched" and rep["k"] == 4
    assert isinstance(assignment, np.ndarray)
    assert assignment.shape == (hg.n,) and assignment.dtype == np.int32
    np.testing.assert_array_equal(
        assignment, partition(hg, 4, "hype_batched", seed=0))


def test_minmax_nb_slack_respected(hg):
    from repro.core.minmax import minmax_partition
    a = minmax_partition(hg, 8, mode="nb", slack=50, seed=0)
    sizes = metrics.partition_sizes(a, 8)
    assert sizes.max() - sizes.min() <= 51


def test_minmax_nb_slack_zero_hard_cap():
    """slack=0 is the tightest nb constraint: the hard cap ceil(n/k)
    must hold for every partition, fallback branch included."""
    from repro.core.minmax import minmax_partition
    hg = powerlaw_hypergraph(203, 140, seed=2, max_edge=12, max_degree=8)
    for k in (4, 7):
        a = minmax_partition(hg, k, mode="nb", slack=0, seed=0)
        sizes = metrics.partition_sizes(a, k)
        assert sizes.max() <= -(-hg.n // k), sizes
        assert sizes.sum() == hg.n


def test_minmax_eligibility_fallback_keeps_cap():
    """Regression for the fallback bug: when the slack filter empties,
    the least-loaded fallback must still respect the nb-mode hard cap
    instead of silently over-filling a capped partition."""
    from repro.core.minmax import _eligible_partitions
    eloads = np.zeros(3, dtype=np.int64)
    # fallback fires (every partition at/over cap): degrade to the bare
    # least-loaded survival rule so the stream never stalls
    vsizes = np.array([5, 5, 6], dtype=np.int64)
    eligible = _eligible_partitions("nb", vsizes, eloads, slack=0,
                                    cap=5)
    np.testing.assert_array_equal(eligible, [True, True, False])
    # fallback with under-cap partitions available (forced via an
    # always-empty slack filter): only under-cap partitions may be
    # eligible — the old `vsizes == vsizes.min()` fallback ignored cap
    # entirely
    vsizes = np.array([2, 3, 4], dtype=np.int64)
    eligible = _eligible_partitions("nb", vsizes, eloads, slack=-1,
                                    cap=3)
    assert eligible.any()
    assert not (eligible & ~(vsizes < 3)).any()     # cap respected
    np.testing.assert_array_equal(eligible, [True, False, False])
    # eb mode keeps its own fallback (no vertex-cap concept there)
    eligible = _eligible_partitions(
        "eb", np.array([1, 1, 1], dtype=np.int64),
        np.array([9, 9, 9], dtype=np.int64), slack=-1, cap=1)
    assert eligible.any()


def test_structure_aware_beats_stream_on_community_graph():
    """The paper's core claim, on a strongly clustered hypergraph."""
    hg = powerlaw_hypergraph(4000, 2500, seed=5, max_edge=60, max_degree=30)
    k = 16
    km = {m: metrics.k_minus_1(hg, partition(hg, k, m, seed=0))
          for m in ("hype", "minmax_nb", "random")}
    assert km["hype"] < km["random"]
    assert km["minmax_nb"] < km["random"]
    assert km["hype"] < 1.25 * km["minmax_nb"]  # competitive or better


@given(st.integers(2, 6), st.integers(0, 5))
@settings(max_examples=12, deadline=None)
def test_property_hype_partitions_everything(k, seed):
    hg = powerlaw_hypergraph(120, 80, seed=seed, max_edge=15, max_degree=10)
    a = hype_partition(hg, k, HypeParams(seed=seed))
    assert (a >= 0).all() and (a < k).all()
    sizes = metrics.partition_sizes(a, k)
    assert sizes.sum() == hg.n
    assert sizes.max() - sizes.min() <= 1


def test_hype_stats_cache_effect(hg):
    _, st_c = hype_partition(hg, 8, HypeParams(seed=0, use_cache=True),
                             return_stats=True)
    _, st_n = hype_partition(hg, 8, HypeParams(seed=0, use_cache=False),
                             return_stats=True)
    assert st_n.score_computations > st_c.score_computations
