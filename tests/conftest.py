"""Test-session environment setup.

Force a 4-device CPU platform *before* anything imports jax, so the
mesh-sharded engine (``hype_sharded``, DESIGN.md §4c) is exercised on a
real multi-device mesh in every CI run. Harmless for the single-device
engines: jit still places un-sharded computations on device 0.
"""
import os

_FLAG = "--xla_force_host_platform_device_count=4"
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (_flags + " " + _FLAG).strip()
