"""Correctness of the §Perf optimization variants (pad_vocab, bf16 MoE
accumulation, capacity override) — optimizations must not change math."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.moe import MoEConfig
from repro.models.transformer import (TransformerConfig, init_params,
                                      lm_loss)


def _loss(cfg, seed=0):
    params = init_params(jax.random.PRNGKey(seed), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    return params, float(lm_loss(params, batch, cfg))


def test_pad_vocab_same_loss_scale():
    base = TransformerConfig(
        name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=128, vocab=101, remat=False, dtype=jnp.float32)
    padded = dataclasses.replace(base, pad_vocab=True)
    assert padded.vocab_padded == 256
    p, l0 = _loss(base)
    p2, l1 = _loss(padded)
    assert p2["embed"].shape[0] == 256
    assert p2["lm_head"].shape[1] == 256
    # same vocab entropy regime: losses agree to ~1% (different random
    # head init, identical masking semantics)
    assert abs(l0 - l1) / l0 < 0.05


def test_pad_vocab_padded_logits_never_predicted():
    cfg = TransformerConfig(
        name="t", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        d_head=16, d_ff=64, vocab=97, pad_vocab=True, remat=False,
        dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    from repro.models.transformer import forward
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 97)
    x, _ = forward(params, toks, cfg)
    logits = x[:, -1] @ params["lm_head"]
    pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
    logits = jnp.where(pad_mask, -1e30, logits)
    assert int(jnp.argmax(logits, -1).max()) < 97


def test_moe_bf16_accum_close_to_fp32():
    moe = MoEConfig(n_experts=4, top_k=2)
    base = TransformerConfig(
        name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_head=16, d_ff=64, vocab=128, moe=moe, remat=False,
        dtype=jnp.float32)
    b16 = dataclasses.replace(base, moe_accum_bf16=True)
    params = init_params(jax.random.PRNGKey(0), base)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 128)
    batch = {"tokens": toks, "labels": toks}
    l0 = float(lm_loss(params, batch, base))
    l1 = float(lm_loss(params, batch, b16))
    assert abs(l0 - l1) / l0 < 0.02, (l0, l1)


def test_moe_cf_override_reduces_capacity_drops_more():
    moe = MoEConfig(n_experts=4, top_k=2, capacity_factor=2.0)
    base = TransformerConfig(
        name="t", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        d_head=16, d_ff=32, vocab=64, moe=moe, remat=False,
        dtype=jnp.float32)
    tight = dataclasses.replace(base, moe_cf_override=0.5)
    params = init_params(jax.random.PRNGKey(0), base)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0, 64)
    batch = {"tokens": toks, "labels": toks}
    # both finite; tight capacity must still produce a valid loss
    l0 = float(lm_loss(params, batch, base))
    l1 = float(lm_loss(params, batch, tight))
    assert np.isfinite(l0) and np.isfinite(l1)
