"""Mesh-sharded superstep engine: validity, determinism, parity with the
single-device superstep engine, lowest-phase-wins conflict resolution,
collective counters, and exactness of the replicated score cache
(device-side decrements + host-queued tails)."""
import numpy as np
import pytest

from repro.core import metrics, scoring
from repro.engines import sharded
from repro.engines.sharded import (ShardedParams, ShardedState,
                                   hype_sharded_partition)
from repro.engines.superstep import (SuperstepParams,
                                     hype_superstep_partition)
from repro.core.hypergraph import Hypergraph
from repro.core.partition_api import METHODS, partition
from repro.data.synthetic import powerlaw_hypergraph


def _devices() -> int:
    import jax
    return len(jax.devices())


needs_multi = pytest.mark.skipif(
    "_devices() < 2",
    reason="needs >= 2 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count, set by tests/conftest.py)")


@pytest.fixture(scope="module")
def hg():
    return powerlaw_hypergraph(600, 400, seed=11, max_edge=30,
                               max_degree=20)


# ------------------------------------------------------------- validity

@needs_multi
@pytest.mark.parametrize("k", [2, 5, 16])
def test_sharded_complete_and_balanced(hg, k):
    a = hype_sharded_partition(hg, k, ShardedParams(seed=0, devices=2))
    assert a.shape == (hg.n,)
    assert a.dtype == np.int32
    assert a.min() >= 0 and a.max() < k
    sizes = metrics.partition_sizes(a, k)
    assert sizes.max() - sizes.min() <= 1


@needs_multi
def test_sharded_deterministic(hg):
    a1 = hype_sharded_partition(hg, 6, ShardedParams(seed=3, devices=2))
    a2 = hype_sharded_partition(hg, 6, ShardedParams(seed=3, devices=2))
    np.testing.assert_array_equal(a1, a2)


def test_sharded_registered_in_api(hg):
    assert "hype_sharded" in METHODS
    a = partition(hg, 4, "hype_sharded", seed=0)
    assert a.min() >= 0 and a.max() < 4


def test_sharded_single_device_degenerates(hg):
    """devices=1 must still satisfy the full contract (no mesh needed)."""
    a = hype_sharded_partition(hg, 5, ShardedParams(seed=0, devices=1))
    sizes = metrics.partition_sizes(a, 5)
    assert sizes.max() - sizes.min() <= 1


def test_sharded_edge_cases():
    hg = Hypergraph.from_edge_lists(6, [[0, 1], [1, 2, 3], []])
    for k in (1, 2, 3, 8):
        a = hype_sharded_partition(hg, k, ShardedParams(seed=0))
        assert (a >= 0).all() and (a < k).all()
        sizes = np.bincount(a, minlength=min(k, 6))
        assert sizes.max() - sizes.min() <= 1


# ------------------------------------------------------------- parity

@needs_multi
def test_sharded_quality_parity_small(hg):
    """2- and 4-device runs stay in the single-device quality regime."""
    k = 16
    a_s = hype_superstep_partition(hg, k, SuperstepParams(seed=0))
    km_s = metrics.k_minus_1(hg, a_s)
    for d in (2, min(4, _devices())):
        a = hype_sharded_partition(hg, k, ShardedParams(seed=0,
                                                        devices=d))
        sizes = metrics.partition_sizes(a, k)
        assert sizes.max() - sizes.min() <= 1
        km = metrics.k_minus_1(hg, a)
        assert km <= 1.15 * km_s + 20


@needs_multi
def test_sharded_km1_within_5pct_at_scale():
    """Acceptance bound at benchmark scale: the quick reddit generator at
    k=32, sharded over 2 and 4 devices, must land within 5% of the
    single-device superstep engine's k-1 (same seed, same t)."""
    from repro.data.synthetic import reddit_like
    hg = reddit_like(scale=0.01, seed=0)
    k, t = 32, 16
    a_ref = hype_superstep_partition(hg, k, SuperstepParams(seed=0, t=t))
    km_ref = metrics.k_minus_1(hg, a_ref)
    for d in (2, min(4, _devices())):
        a = hype_sharded_partition(
            hg, k, ShardedParams(seed=0, t=t, devices=d))
        km = metrics.k_minus_1(hg, a)
        assert km <= 1.05 * km_ref, (d, km, km_ref)


# ------------------------------------------------- conflict resolution

@needs_multi
def test_conflict_lowest_phase_wins_program():
    """Two phases (on different devices) proposing the same vertex in one
    superstep: the lowest phase id must win, the loser gets nothing, and
    the conflict is counted — deterministically."""
    import jax.numpy as jnp
    hg = powerlaw_hypergraph(120, 90, seed=3, max_edge=12, max_degree=8)
    adj = hg.vertex_adjacency()
    dev = hg.device_adjacency()
    n = hg.n
    v = int(np.argmax(np.diff(adj[0])[: n // 2]))    # any real vertex
    D, kL, R, t = 2, 1, 4, 2
    kG = D * kL
    fresh = np.full((kG, R), -1, np.int32)
    fresh[0, 0] = v
    fresh[1, 0] = v                                  # phase 1, device 1
    bias = np.where(fresh >= 0, 0, np.inf).astype(np.float32)
    pool = np.full((kG, 4), -1, np.int32)
    fringe = np.full((kG, 1), -1, np.int32)
    targets = np.full(kG, t, np.int32)       # cap = t admissions each
    assign = jnp.full((n,), -1, jnp.int32)
    cache = jnp.full((n,), -1.0, jnp.float32)
    acc = jnp.zeros((kG,), jnp.int32)
    empty_i = np.full(4, -1, np.int32)
    poison = jnp.zeros((1,), jnp.int32)
    (a2, c2, acc2, poison2, winners, ncf,
     n_stale) = sharded.sharded_superstep_device(
        dev[0], dev[1], assign, cache, acc, poison, empty_i,
        np.zeros(4, np.int32), empty_i, np.zeros(4, np.float32),
        fresh, bias, pool, fringe, targets, np.zeros(1, np.int32),
        num_devices=D, group_l=kL, tile_l=32, select_k=t,
        interpret=True)
    assert int(np.asarray(poison2)[0]) == 0          # finite scores
    winners = np.asarray(winners)
    assert winners[0, 0] == v                        # lowest phase won
    assert v not in winners[1]                       # loser redraws later
    assert int(ncf) == 1
    assert int(n_stale) == 0                         # nothing in flight
    assert int(np.asarray(a2)[v]) == 0
    assert int(np.asarray(acc2)[0]) >= 1             # winner counted


@needs_multi
def test_sharded_conflicts_happen_and_are_counted(hg):
    """Device groups draw pools independently, so overlapping proposals
    must occur on a clustered graph — and be resolved, not double-
    assigned (completeness + balance above already guarantee that)."""
    _, st = hype_sharded_partition(hg, 8, ShardedParams(seed=0,
                                                        devices=2),
                                   return_stats=True)
    assert st.admission_conflicts > 0


# ------------------------------------------------- collective counters

@needs_multi
def test_sharded_collective_counters(hg):
    _, st = hype_sharded_partition(hg, 8, ShardedParams(seed=0,
                                                        devices=2),
                                   return_stats=True)
    assert st.supersteps > 0
    assert st.collectives == st.supersteps
    assert st.collective_bytes > 0
    assert st.collective_bytes % st.collectives == 0
    assert st.host_rows == 0             # every score is device-side
    assert st.device_image_bytes > 0     # counted once per replica
    # the gathered payload is ids + scores, not (n,)-sized state
    per_step = st.collective_bytes / st.collectives
    assert per_step < 4 * hg.n


# --------------------------------------------------- cache exactness

@needs_multi
def test_sharded_cache_exact_after_admissions():
    """The replicated cache stays *exact* under mixed admission paths:
    device-selected winners (clipped decrement + host-queued tails) and
    host injections. After any sequence, every cached score equals a
    fresh ``batched_dext_adj`` recompute."""
    for seed in (0, 1):
        hg = powerlaw_hypergraph(300, 200, seed=10 + seed, max_edge=18,
                                 max_degree=12)
        k, D, R, t = 4, 2, 8, 2
        rng = np.random.default_rng(seed)
        p = ShardedParams(seed=seed, t=t, rows=R, devices=D)
        st = ShardedState(hg, k, p, D)
        fringe = np.full((k, 1), -1, np.int32)
        empty_pool = np.full((k, 4), -1, np.int32)
        acc = np.zeros(k, dtype=np.int64)
        targets = np.full(k, hg.n, dtype=np.int64)
        # make sure the tail path runs: the widest vertex, if wider than
        # the run's tile, must be admitted at least once
        wide_v = int(np.argmax(st.deg))
        for step in range(10):
            cand = np.flatnonzero(~st.cache_scored & (st.assignment < 0))
            fresh = np.full((k, R), -1, np.int32)
            if cand.size:
                pick = rng.choice(cand, size=min(k * R - 1, cand.size),
                                  replace=False)
                if st.assignment[wide_v] < 0 \
                        and wide_v not in pick:
                    pick = np.concatenate([[wide_v], pick])
                fresh.reshape(-1)[:pick.size] = pick
            # zero bias everywhere: wide rows stay admissible, so the
            # clipped-decrement + tail machinery actually executes
            bias = np.where(fresh >= 0, 0, np.inf).astype(np.float32)
            cap = rng.integers(0, t + 1, size=k)
            tgt = (acc + cap).astype(np.int32)
            handle = st.dispatch(fresh, bias, empty_pool, fringe,
                                 fresh[fresh >= 0].astype(np.int64),
                                 tgt, 32, t)
            st.harvest(handle, acc, targets)   # mirror, like the runner
            # host-injection path too
            un = np.flatnonzero(st.assignment < 0)
            if un.size and step % 3 == 0:
                vs = rng.choice(un, size=min(3, un.size), replace=False)
                g = int(rng.integers(0, k))
                st.assign_now(vs, g)
                acc[g] += vs.size
        while st.delta_ids or st.pending_dirty:    # flush tails + deltas
            handle = st.dispatch(np.full((k, 1), -1, np.int32),
                                 np.full((k, 1), np.inf, np.float32),
                                 np.full((k, 1), -1, np.int32), fringe,
                                 np.empty(0, dtype=np.int64),
                                 acc.astype(np.int32), 32, 1)
            st.harvest(handle, acc, targets)
        cache = np.asarray(st.dev_cache, dtype=np.float64)
        scored = np.flatnonzero(st.cache_scored & (st.deg <= st.tile_l))
        assert scored.size > 50
        ref = scoring.batched_dext_adj(st.adj, scored,
                                       np.zeros(hg.n, dtype=bool),
                                       st.assignment)
        assert (ref > 0).any()
        np.testing.assert_allclose(cache[scored], ref)
        # device/host assignment + totals parity after the flush
        np.testing.assert_array_equal(np.asarray(st.dev_assign),
                                      st.assignment)
        np.testing.assert_array_equal(
            np.asarray(st.dev_acc),
            np.bincount(st.assignment[st.assignment >= 0],
                        minlength=k))


# ------------------------------------------------- kernel shard offsets

def test_score_select_shard_matches_full():
    """The per-shard phase-group offset wrapper must reproduce the full
    fused call on the corresponding slice."""
    import jax.numpy as jnp
    from repro.kernels.hype_score.ops import (hype_score_select,
                                              hype_score_select_shard)
    rng = np.random.default_rng(0)
    G, R, L, P, s, t = 4, 3, 32, 5, 4, 2
    nbrs = rng.integers(-1, 50, size=(G, R, L)).astype(np.int32)
    fringe = rng.integers(-1, 50, size=(G, s)).astype(np.int32)
    bias = np.zeros((G, R), np.float32)
    prev = np.where(rng.random((G, P)) < 0.5,
                    rng.random((G, P)) * 10, np.inf).astype(np.float32)
    full = hype_score_select(jnp.asarray(nbrs), jnp.asarray(fringe),
                             jnp.asarray(bias), jnp.asarray(prev),
                             select_k=t, interpret=True)
    for off, gl in ((0, 2), (2, 2), (1, 3)):
        shard = hype_score_select_shard(
            jnp.asarray(nbrs[off:off + gl]), jnp.asarray(fringe),
            jnp.asarray(bias), jnp.asarray(prev), select_k=t,
            shard_offset=off, interpret=True)
        for a, b in zip(shard, (full[0][off:off + gl],
                                full[1][off:off + gl],
                                full[2][off:off + gl])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
