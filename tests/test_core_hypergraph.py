"""Unit + property tests for the hypergraph structure and metrics."""
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.core.hypergraph import Hypergraph
from repro.core import metrics


def tiny():
    # fig-4-like: three edges, one big
    return Hypergraph.from_edge_lists(6, [[0, 1, 2, 3], [3, 4], [4, 5], [0, 5]])


def test_csr_roundtrip():
    hg = tiny()
    hg.validate()
    assert hg.n == 6 and hg.m == 4
    assert hg.n_pins == 10
    assert list(hg.edge_pins(1)) == [3, 4]
    assert set(hg.vertex_edges(3)) == {0, 1}
    assert set(hg.neighbors(3)) == {0, 1, 2, 4}


def test_duplicate_pins_removed():
    hg = Hypergraph.from_pins(3, 1, np.array([0, 0, 1, 2]), np.array([0, 0, 0, 0]))
    assert hg.n_pins == 3
    assert hg.edge_sizes[0] == 3


def test_flip_involution():
    hg = tiny()
    f2 = hg.flip().flip()
    assert f2.n == hg.n and f2.m == hg.m
    np.testing.assert_array_equal(np.sort(f2.edge_pins(0)), np.sort(hg.edge_pins(0)))


def test_k_minus_1_hand_checked():
    hg = tiny()
    # all in one partition
    assert metrics.k_minus_1(hg, np.zeros(6, np.int32)) == 0
    # split {0,1,2} | {3,4,5}: e0 spans 2 -> 1; e1 spans 1... pins(e1)={3,4} both p1 -> 0
    a = np.array([0, 0, 0, 1, 1, 1], np.int32)
    # e0={0,1,2,3} spans {0,1} -> 1; e1={3,4} -> 0; e2={4,5} -> 0; e3={0,5} spans -> 1
    assert metrics.k_minus_1(hg, a) == 2
    assert metrics.hyperedge_cut(hg, a) == 2
    assert metrics.sum_external_degree(hg, a) == 4


def test_imbalance():
    a = np.array([0, 0, 0, 1], np.int32)
    assert metrics.vertex_imbalance(a, 2) == pytest.approx((3 - 1) / 3)
    assert metrics.vertex_imbalance(np.array([0, 1], np.int32), 2) == 0.0


def _corrupt(hg, **overrides):
    """Rebuild ``hg`` with raw (possibly invalid) arrays swapped in."""
    fields = dict(n=hg.n, m=hg.m, v2e_indptr=hg.v2e_indptr,
                  v2e_indices=hg.v2e_indices, e2v_indptr=hg.e2v_indptr,
                  e2v_indices=hg.e2v_indices)
    fields.update(overrides)
    return Hypergraph(**fields)


@pytest.mark.parametrize("corruption,match", [
    # each case violates exactly one validate() invariant
    (lambda hg: _corrupt(hg, v2e_indptr=hg.v2e_indptr[:-1]),
     "v2e_indptr shape"),
    (lambda hg: _corrupt(hg, e2v_indptr=hg.e2v_indptr[:-1]),
     "e2v_indptr shape"),
    (lambda hg: _corrupt(hg, v2e_indices=hg.v2e_indices[:-1],
                         e2v_indices=hg.e2v_indices[:-1]),
     "v2e_indptr\\[-1\\]"),
    (lambda hg: _corrupt(
        hg, e2v_indptr=np.concatenate([hg.e2v_indptr[:-1],
                                       [hg.n_pins + 1]])),
     "e2v_indptr\\[-1\\]"),
    (lambda hg: _corrupt(
        hg, v2e_indices=np.concatenate([hg.v2e_indices,
                                        hg.v2e_indices[:1]]),
        v2e_indptr=hg.v2e_indptr + (np.arange(hg.n + 1) >= 1)),
     "pin-count mismatch"),
    (lambda hg: _corrupt(
        hg, e2v_indices=np.where(np.arange(hg.n_pins) == 0, -1,
                                 hg.e2v_indices)),
     "negative vertex id"),
    (lambda hg: _corrupt(
        hg, e2v_indices=np.where(np.arange(hg.n_pins) == 0, hg.n,
                                 hg.e2v_indices)),
     "vertex id .* out of range"),
    (lambda hg: _corrupt(
        hg, v2e_indices=np.where(np.arange(hg.n_pins) == 0, -2,
                                 hg.v2e_indices)),
     "negative edge id"),
    (lambda hg: _corrupt(
        hg, v2e_indices=np.where(np.arange(hg.n_pins) == 0, hg.m + 3,
                                 hg.v2e_indices)),
     "edge id .* out of range"),
])
def test_validate_raises_on_corruption(corruption, match):
    """validate() must RAISE (not assert — `python -O` strips asserts,
    silently no-opping validation) on every corrupted invariant."""
    hg = tiny()
    hg.validate()                       # sane baseline passes
    with pytest.raises(ValueError, match=match):
        corruption(hg).validate()


# ------------------------------------------------------- metrics / spans

def test_metrics_explicit_k_equivalence():
    """Threading k and sharing one spans computation must not change any
    metric — including when high partitions are unoccupied (the old
    keying hashed on assignment.max() + 2)."""
    hg = tiny()
    a = np.array([0, 0, 0, 1, 1, 1], np.int32)
    for k in (2, 3, 7):                 # k=3,7: partitions 2.. unoccupied
        spans = metrics.spans_per_edge(hg, a, k)
        np.testing.assert_array_equal(spans, metrics.spans_per_edge(hg, a))
        assert metrics.k_minus_1(hg, a, k) == metrics.k_minus_1(hg, a) == 2
        assert metrics.hyperedge_cut(hg, a, k) == 2
        assert metrics.sum_external_degree(hg, a, k) == 4
        assert metrics.k_minus_1(hg, a, k, spans=spans) == 2
        assert metrics.hyperedge_cut(hg, a, k, spans=spans) == 2
        assert metrics.sum_external_degree(hg, a, k, spans=spans) == 4
        rep = metrics.all_metrics(hg, a, k)
        assert rep["k_minus_1"] == 2 and rep["hyperedge_cut"] == 2
        assert rep["soed"] == 4


def test_metrics_reject_out_of_range_k():
    hg = tiny()
    a = np.array([0, 0, 0, 1, 1, 1], np.int32)
    with pytest.raises(ValueError, match=">= k"):
        metrics.k_minus_1(hg, a, 1)


def test_metrics_reject_incomplete_assignment():
    hg = tiny()
    a = np.array([0, 0, 0, 1, 1, -1], np.int32)
    with pytest.raises(ValueError, match="complete"):
        metrics.k_minus_1(hg, a, 2)


@st.composite
def hypergraphs(draw):
    n = draw(st.integers(min_value=2, max_value=40))
    m = draw(st.integers(min_value=1, max_value=30))
    n_pins = draw(st.integers(min_value=1, max_value=120))
    vs = draw(st.lists(st.integers(0, n - 1), min_size=n_pins, max_size=n_pins))
    es = draw(st.lists(st.integers(0, m - 1), min_size=n_pins, max_size=n_pins))
    return Hypergraph.from_pins(n, m, np.array(vs), np.array(es))


@given(hypergraphs(), st.integers(min_value=1, max_value=8), st.integers(0, 3))
@settings(max_examples=50, deadline=None)
def test_property_metric_bounds(hg, k, seed):
    """(k-1) bounds: 0 <= k-1 <= sum(min(|e|, k) - 1); flip preserves pins."""
    rng = np.random.default_rng(seed)
    a = rng.integers(0, k, size=hg.n).astype(np.int32)
    km1 = metrics.k_minus_1(hg, a)
    sizes = hg.edge_sizes
    ub = int(np.sum(np.maximum(np.minimum(sizes, k) - 1, 0)))
    assert 0 <= km1 <= ub
    assert metrics.hyperedge_cut(hg, a) <= km1 or km1 == 0
    assert hg.flip().n_pins == hg.n_pins
    hg.flip().validate()


# ------------------------------------------------ index-dtype boundaries

def test_csr_index_dtype_boundary():
    """The int32->int64 flip happens exactly at max(n, m) == 2**31 —
    tested on the extracted decision function, no giant allocations."""
    from repro.core.hypergraph import csr_index_dtype
    lim = 2**31
    assert csr_index_dtype(10, 10) is np.int32
    assert csr_index_dtype(lim - 1, 1) is np.int32
    assert csr_index_dtype(1, lim - 1) is np.int32
    assert csr_index_dtype(lim, 1) is np.int64
    assert csr_index_dtype(1, lim) is np.int64
    assert csr_index_dtype(lim + 7, lim + 7) is np.int64


def test_from_pins_uses_decision_dtype():
    hg = tiny()
    from repro.core.hypergraph import csr_index_dtype
    want = csr_index_dtype(hg.n, hg.m)
    assert hg.v2e_indices.dtype == want
    assert hg.e2v_indices.dtype == want
    # indptr stays int64 regardless: pin counts overflow before ids do
    assert hg.v2e_indptr.dtype == np.int64
    assert hg.e2v_indptr.dtype == np.int64


def test_device_ptr_dtype_boundary():
    """Device indptr narrows on the flat *indices* length (pin count),
    flipping at 2**31 like the host decision."""
    import jax.numpy as jnp
    from repro.core.hypergraph import device_ptr_dtype
    lim = 2**31
    assert device_ptr_dtype(0) is jnp.int32
    assert device_ptr_dtype(lim - 1) is jnp.int32
    assert device_ptr_dtype(lim) is jnp.int64
    assert device_ptr_dtype(lim + 1) is jnp.int64


def test_device_adjacency_ptr_dtype_propagation():
    """device_adjacency must upload its indptr with the dtype the
    decision function picks for the actual indices length."""
    import jax.numpy as jnp
    from repro.core.hypergraph import device_ptr_dtype
    hg = tiny()
    dev = hg.device_adjacency()
    assert dev is not None
    indptr_dev, indices_dev = dev
    host = hg.vertex_adjacency(80_000_000)
    assert indptr_dev.dtype == device_ptr_dtype(host[1].size)
    assert indptr_dev.dtype == jnp.int32          # tiny graph fits
    np.testing.assert_array_equal(np.asarray(indptr_dev), host[0])
