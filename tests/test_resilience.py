"""Resilience subsystem (DESIGN.md §4f): fault-plan parsing, the
checkpoint store, superstep/phase-granular snapshot + bit-identical
resume on every engine of the batched family, fault-injection recovery
equality, exception-safe teardown, entry validation, and the
graceful-degradation engine ladder."""
import dataclasses
import hashlib
import os
import signal

import numpy as np
import pytest

from repro.core import partition_api, resilience
from repro.core.hype import HypeParams, hype_partition
from repro.engines.batched import BatchedParams, hype_batched_partition
from repro.engines.sharded import ShardedParams, hype_sharded_partition
from repro.engines.superstep import (SuperstepParams, SuperstepState,
                                     hype_superstep_partition)
from repro.core.hypergraph import Hypergraph
from repro.core import metrics
from repro.data.synthetic import powerlaw_hypergraph

# Golden depth-1 digest shared with test_pipeline.py: the abort test
# reruns the engine after a simulated crash and must land exactly here.
_GOLD_PL600_16_8 = "bbcd2f732e03af91"


def _digest(a: np.ndarray) -> str:
    return hashlib.sha256(
        np.ascontiguousarray(a, dtype=np.int32).tobytes()).hexdigest()[:16]


def _devices() -> int:
    import jax
    return len(jax.devices())


needs_multi = pytest.mark.skipif(
    "_devices() < 2",
    reason="needs >= 2 devices (XLA_FLAGS set by tests/conftest.py)")


@pytest.fixture(autouse=True)
def _hang_guard():
    """Per-test wall-clock guard: a wedged replay/teardown path must
    fail the test, not hang the suite (no pytest-timeout in the image,
    so SIGALRM does the job; main-thread CPython only, which is where
    pytest runs these)."""
    def _alarm(signum, frame):
        raise TimeoutError("test exceeded the 180 s resilience guard")

    old = signal.signal(signal.SIGALRM, _alarm)
    signal.alarm(180)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture(scope="module")
def hg():
    return powerlaw_hypergraph(600, 400, seed=11, max_edge=30,
                               max_degree=20)


# ----------------------------------------------------- fault-plan layer

def test_fault_plan_parse():
    plan = resilience.FaultPlan.parse("dispatch@2;nan@4,collective@3")
    assert [(s.kind, s.superstep, s.fatal) for s in plan.specs] == [
        ("dispatch", 2, False), ("nan", 4, False), ("collective", 3, False)]
    plan = resilience.FaultPlan.parse("dispatch@9:fatal; oom")
    assert plan.specs[0].fatal and plan.specs[0].superstep == 9
    assert plan.specs[1].kind == "oom"
    with pytest.raises(ValueError, match="unknown fault kind"):
        resilience.FaultPlan.parse("frobnicate@1")
    with pytest.raises(ValueError, match="bad fault superstep"):
        resilience.FaultPlan.parse("nan@soon")


def test_fault_plan_fire_is_one_shot():
    plan = resilience.FaultPlan.parse("dispatch@2;oom")
    assert plan.fire(("nan",), 2) is None           # wrong kind
    assert plan.fire(("dispatch",), 1) is None      # wrong superstep
    sp = plan.fire(("dispatch",), 2)
    assert sp is not None and sp.kind == "dispatch"
    assert plan.fire(("dispatch",), 2) is None      # consumed
    assert plan.fire(("oom",), 99).kind == "oom"    # oom: any superstep
    assert plan.fired and not plan.specs


def test_fault_plan_env_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_FAULT_PLAN", raising=False)
    assert resilience.resolve_fault_plan(None) is None
    monkeypatch.setenv("REPRO_FAULT_PLAN", "nan@1;dispatch@2")
    plan = resilience.resolve_fault_plan(None)
    assert [s.kind for s in plan.specs] == ["nan", "dispatch"]
    # each resolution is a FRESH plan: engine runs do not share firing
    # state through the env var
    assert resilience.resolve_fault_plan(None) is not plan
    shared = resilience.FaultPlan.parse("oom")
    assert resilience.resolve_fault_plan(shared) is shared


# ----------------------------------------------------- checkpoint store

def _mk_ckpt(step, fp="f" * 16):
    return resilience.PartitionCheckpoint(
        engine="hype_superstep", superstep=step, fingerprint=fp,
        config={"k": 4}, payload={"assignment": np.arange(6, dtype=np.int32)})


def test_snapshot_roundtrip_and_latest(tmp_path):
    d = str(tmp_path)
    assert resilience.latest_snapshot(d) is None
    assert resilience.load_latest(d) is None
    p2 = resilience.save_snapshot(d, _mk_ckpt(2))
    resilience.save_snapshot(d, _mk_ckpt(5))
    ck = resilience.load_latest(d)
    assert ck.superstep == 5 and ck.engine == "hype_superstep"
    np.testing.assert_array_equal(resilience.warm_assignment(ck),
                                  np.arange(6))
    assert resilience.load_snapshot(p2).superstep == 2
    with open(os.path.join(d, "LATEST")) as f:
        assert f.read().strip() == "snap_00000005.ckpt"


def test_snapshot_gc_keeps_last(tmp_path):
    d = str(tmp_path)
    for step in range(1, 7):
        resilience.save_snapshot(d, _mk_ckpt(step), keep_last=3)
    snaps = sorted(f for f in os.listdir(d) if f.endswith(".ckpt"))
    assert snaps == ["snap_00000004.ckpt", "snap_00000005.ckpt",
                     "snap_00000006.ckpt"]
    assert resilience.load_latest(d).superstep == 6


def test_checkpoint_fingerprint_guard(hg):
    ck = _mk_ckpt(3, fp="0" * 16)
    with pytest.raises(ValueError, match="fingerprint"):
        resilience.check_checkpoint(ck, hg, 4)
    ck2 = _mk_ckpt(3, fp=hg.fingerprint())
    resilience.check_checkpoint(ck2, hg, 4)          # matching: fine
    with pytest.raises(ValueError, match="k="):
        resilience.check_checkpoint(ck2, hg, 8)


def test_resume_against_wrong_graph_raises(hg, tmp_path):
    other = powerlaw_hypergraph(100, 80, seed=1, max_edge=6, max_degree=5)
    d = str(tmp_path)
    hype_superstep_partition(other, 4, SuperstepParams(
        seed=0, snapshot_every=1, snapshot_dir=d))
    with pytest.raises(ValueError, match="fingerprint"):
        hype_superstep_partition(hg, 4, SuperstepParams(seed=0, resume=d))


def test_snapshot_requires_dir():
    hg = Hypergraph.from_edge_lists(6, [[0, 1], [1, 2, 3]])
    for params in (SuperstepParams(snapshot_every=2),
                   ShardedParams(snapshot_every=2),
                   BatchedParams(snapshot_every=2)):
        with pytest.raises(ValueError, match="snapshot_dir"):
            if isinstance(params, ShardedParams):
                hype_sharded_partition(hg, 2, params)
            elif isinstance(params, SuperstepParams):
                hype_superstep_partition(hg, 2, params)
            else:
                hype_batched_partition(hg, 2, params)


# -------------------------------------- bit-identical snapshot + resume

def _kill_and_resume(run, d):
    """Kill a snapshotting run with a fatal fault, then resume it."""
    with pytest.raises(resilience.UnrecoverableFault):
        run(fault_plan="dispatch@5:fatal", snapshot_dir=d, resume=None)
    assert any(f.endswith(".ckpt") for f in os.listdir(d))
    return run(fault_plan=None, snapshot_dir=d, resume=d)


def test_resume_bit_identical_superstep_pd1(hg, tmp_path):
    def run(fault_plan, snapshot_dir, resume):
        return hype_superstep_partition(hg, 16, SuperstepParams(
            seed=0, pool_cap=8, pipeline_depth=1, snapshot_every=2,
            snapshot_dir=snapshot_dir, resume=resume,
            fault_plan=fault_plan), return_stats=True)

    base, _ = run(None, str(tmp_path / "base"), None)
    a, st = _kill_and_resume(run, str(tmp_path / "killed"))
    assert _digest(a) == _digest(base)
    assert st.resumed_at >= 2 and st.restore_s >= 0.0
    assert st.snapshots > 0 and st.snapshot_s >= 0.0


def test_resume_bit_identical_superstep_pd2(hg, tmp_path):
    """Depth-2 pipeline: the snapshot drain is part of the schedule, so
    interrupted + resumed must equal the uninterrupted same-cadence
    run (NOT the cadence-free one)."""
    def run(fault_plan, snapshot_dir, resume):
        return hype_superstep_partition(hg, 16, SuperstepParams(
            seed=0, pool_cap=8, pipeline_depth=2, snapshot_every=3,
            snapshot_dir=snapshot_dir, resume=resume,
            fault_plan=fault_plan), return_stats=True)

    base, _ = run(None, str(tmp_path / "base"), None)
    a, st = _kill_and_resume(run, str(tmp_path / "killed"))
    assert _digest(a) == _digest(base)
    assert st.resumed_at >= 3


@needs_multi
def test_resume_bit_identical_sharded(hg, tmp_path):
    def run(fault_plan, snapshot_dir, resume):
        return hype_sharded_partition(hg, 16, ShardedParams(
            seed=0, pool_cap=8, devices=4, snapshot_every=2,
            snapshot_dir=snapshot_dir, resume=resume,
            fault_plan=fault_plan), return_stats=True)

    base, _ = run(None, str(tmp_path / "base"), None)
    a, st = _kill_and_resume(run, str(tmp_path / "killed"))
    assert _digest(a) == _digest(base)
    assert st.resumed_at >= 2


def test_resume_bit_identical_batched(hg, tmp_path):
    """Batched snapshots are phase-granular; kill mid-run at a kernel
    ordinal and resume from the last completed phase."""
    def run(fault_plan, snapshot_dir, resume):
        return hype_batched_partition(hg, 16, BatchedParams(
            seed=0, snapshot_every=3, snapshot_dir=snapshot_dir,
            resume=resume, fault_plan=fault_plan), return_stats=True)

    base, _ = run(None, str(tmp_path / "base"), None)
    with pytest.raises(resilience.UnrecoverableFault):
        run("dispatch@9:fatal", str(tmp_path / "killed"), None)
    a, st = run(None, str(tmp_path / "killed"), str(tmp_path / "killed"))
    assert _digest(a) == _digest(base)
    assert st.resumed_at >= 3

    # snapshot cadence does not perturb the batched schedule at all
    plain = hype_batched_partition(hg, 16, BatchedParams(seed=0))
    assert _digest(base) == _digest(plain)


# ------------------------------------------- fault recovery == fault-free

def test_superstep_transient_faults_are_exact(hg):
    # empty plan (NOT None): the baseline must stay fault-free even
    # when the chaos CI env sets REPRO_FAULT_PLAN
    base, s0 = hype_superstep_partition(
        hg, 16, SuperstepParams(seed=0, pool_cap=8,
                                fault_plan=resilience.FaultPlan()),
        return_stats=True)
    for plan in ("dispatch@2", "nan@3", "dispatch@1;nan@4"):
        a, st = hype_superstep_partition(hg, 16, SuperstepParams(
            seed=0, pool_cap=8, fault_plan=plan), return_stats=True)
        assert _digest(a) == _digest(base), plan
        n = len(plan.split(";"))
        assert st.faults_injected == n, plan
        assert st.retries == n, plan
        # recovery never inflates the work counters
        assert st.kernel_calls == s0.kernel_calls
        assert st.supersteps == s0.supersteps
    assert s0.faults_injected == 0 and s0.retries == 0


def test_superstep_pd2_nan_window_replay(hg):
    """At depth 2 a poisoned superstep drags its in-flight successor
    into the replay window; the recovered run is still bit-exact."""
    base = hype_superstep_partition(
        hg, 16, SuperstepParams(seed=0, pool_cap=8, pipeline_depth=2))
    a, st = hype_superstep_partition(hg, 16, SuperstepParams(
        seed=0, pool_cap=8, pipeline_depth=2, fault_plan="nan@3"),
        return_stats=True)
    assert _digest(a) == _digest(base)
    assert st.faults_injected == 1 and st.retries >= 1


def test_batched_nan_quarantine_is_exact(hg):
    """A NaN-poisoned kernel tile is quarantined and re-scored on the
    host with the kernel's exact clipped-tile arithmetic: the final
    assignment cannot drift."""
    base, s0 = hype_batched_partition(
        hg, 16, BatchedParams(seed=0, fault_plan=resilience.FaultPlan()),
        return_stats=True)
    a, st = hype_batched_partition(hg, 16, BatchedParams(
        seed=0, fault_plan="nan@2"), return_stats=True)
    assert _digest(a) == _digest(base)
    assert st.faults_injected == 1
    assert st.host_rows > s0.host_rows          # quarantined rows
    assert st.kernel_calls == s0.kernel_calls


def test_batched_transient_dispatch_retry(hg):
    base, _ = hype_batched_partition(
        hg, 16, BatchedParams(seed=0), return_stats=True)
    a, st = hype_batched_partition(hg, 16, BatchedParams(
        seed=0, fault_plan="dispatch@2"), return_stats=True)
    assert _digest(a) == _digest(base)
    assert st.faults_injected == 1 and st.retries == 1


@needs_multi
def test_sharded_collective_fault_is_exact(hg):
    base = hype_sharded_partition(
        hg, 16, ShardedParams(seed=0, pool_cap=8, devices=4))
    a, st = hype_sharded_partition(hg, 16, ShardedParams(
        seed=0, pool_cap=8, devices=4,
        fault_plan="collective@2;nan@3"), return_stats=True)
    assert _digest(a) == _digest(base)
    assert st.faults_injected == 2 and st.retries == 2


def test_retry_budget_exhaustion_is_unrecoverable(hg):
    # same transient fault injected at every early superstep with a
    # zero retry budget: the engine must escalate, not loop
    a_plan = resilience.FaultPlan(
        [resilience.FaultSpec("dispatch", 2, fatal=True)])
    with pytest.raises(resilience.UnrecoverableFault):
        hype_superstep_partition(hg, 16, SuperstepParams(
            seed=0, pool_cap=8, fault_plan=a_plan))
    assert a_plan.fired and not a_plan.specs


def test_oom_at_upload_recovers_on_same_engine(hg):
    # Non-fatal OOM at upload is no longer unrecoverable: the memory
    # rung ladder (DESIGN.md §4g) retries the SAME engine at a smaller
    # plan and the result matches the fault-free run bit-identically.
    base = hype_superstep_partition(hg, 16, SuperstepParams(seed=0))
    a, st = hype_superstep_partition(hg, 16, SuperstepParams(
        seed=0, fault_plan="oom"), return_stats=True)
    assert _digest(a) == _digest(base)
    assert st.mem_retries == 1 and st.plan_rung >= 1


def test_fatal_oom_at_upload_is_unrecoverable(hg):
    # Only oom:fatal abandons the engine for the degradation ladder.
    with pytest.raises(resilience.UnrecoverableFault, match="OOM"):
        hype_superstep_partition(hg, 16, SuperstepParams(
            seed=0, fault_plan="oom:fatal"))


# ------------------------------------------------- chaos (env-driven)

def test_chaos_env_plan_km1_equal(hg, monkeypatch):
    """The chaos CI contract: with REPRO_FAULT_PLAN injecting a
    dispatch fault and a NaN tile, every engine must finish with an
    assignment *equal* to the fault-free one (replay-exact recovery,
    not merely graceful)."""
    monkeypatch.delenv("REPRO_FAULT_PLAN", raising=False)
    base = hype_superstep_partition(
        hg, 16, SuperstepParams(seed=0, pool_cap=8))
    monkeypatch.setenv("REPRO_FAULT_PLAN", "dispatch@2;nan@4")
    a, st = hype_superstep_partition(
        hg, 16, SuperstepParams(seed=0, pool_cap=8), return_stats=True)
    assert _digest(a) == _digest(base)
    assert st.faults_injected == 2
    assert metrics.k_minus_1(hg, a) == metrics.k_minus_1(hg, base)


# ------------------------------------------------- exception-safe abort

def test_abort_mid_pipeline_engine_reusable(hg, monkeypatch):
    """A KeyboardInterrupt mid-run (user ^C between harvests) must tear
    down the in-flight donated-buffer chains; the process stays healthy
    and a fresh run still reproduces the golden digest."""
    calls = {"n": 0}
    real = SuperstepState.harvest

    def exploding(self, handle, acc, targets, exclude=()):
        calls["n"] += 1
        if calls["n"] == 3:
            raise KeyboardInterrupt
        return real(self, handle, acc, targets, exclude)

    monkeypatch.setattr(SuperstepState, "harvest", exploding)
    with pytest.raises(KeyboardInterrupt):
        hype_superstep_partition(
            hg, 16, SuperstepParams(seed=0, t=8, pipeline_depth=2))
    monkeypatch.setattr(SuperstepState, "harvest", real)
    a = hype_superstep_partition(
        hg, 16, SuperstepParams(seed=0, t=8, pipeline_depth=1))
    assert _digest(a) == _GOLD_PL600_16_8


def test_abort_via_injected_exception_leaves_no_debris(hg, monkeypatch):
    """Same teardown path driven by an arbitrary error inside harvest:
    the raised exception propagates unchanged (not masked by a
    teardown failure) and a rerun is exact."""
    real = SuperstepState.harvest

    class Boom(RuntimeError):
        pass

    def exploding(self, handle, acc, targets, exclude=()):
        raise Boom("host-side failure mid-harvest")

    monkeypatch.setattr(SuperstepState, "harvest", exploding)
    with pytest.raises(Boom):
        hype_superstep_partition(
            hg, 16, SuperstepParams(seed=0, t=8, pipeline_depth=2))
    monkeypatch.setattr(SuperstepState, "harvest", real)
    a = hype_superstep_partition(
        hg, 16, SuperstepParams(seed=0, t=8, pipeline_depth=1))
    assert _digest(a) == _GOLD_PL600_16_8


# ------------------------------------------------------ interpret knob

def test_superstep_interpret_not_cached(hg, monkeypatch):
    """Engine state must re-read pallas_interpret() per call — a cached
    value would pin the whole run to the mode active at __init__."""
    # empty plan: state is constructed directly, so an env-injected
    # fault (chaos/low-memory CI) must not fire at __init__
    st = SuperstepState(hg, 4, SuperstepParams(
        seed=0, fault_plan=resilience.FaultPlan()))
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert st.interpret is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert st.interpret is False
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET")


# ------------------------------------------------------ entry validation

def _corrupt(hg):
    bad = Hypergraph(n=hg.n, m=hg.m,
                     v2e_indptr=hg.v2e_indptr.copy(),
                     v2e_indices=hg.v2e_indices.copy(),
                     e2v_indptr=hg.e2v_indptr.copy(),
                     e2v_indices=hg.e2v_indices.copy())
    bad.e2v_indices[0] = hg.n + 7          # out-of-range vertex id
    return bad


def test_partition_validates_by_default(hg):
    with pytest.raises(ValueError):
        partition_api.partition(_corrupt(hg), 4, "random", seed=0)


def test_partition_validate_opt_out(hg):
    # validate=False skips the sweep entirely: the corrupt graph reaches
    # the (structure-insensitive) random engine and completes
    a = partition_api.partition(_corrupt(hg), 4, "random", seed=0,
                                validate=False)
    assert a.shape == (hg.n,)
    with pytest.raises(ValueError, match="validate"):
        partition_api.partition(hg, 4, "random", validate="sometimes")


# ------------------------------------------------- degradation ladder

def test_ladder_oom_degrades_one_rung(hg, tmp_path):
    a, rep = partition_api.partition_resilient(
        hg, 16, "hype_sharded", seed=0, pool_cap=8,
        snapshot_dir=str(tmp_path), snapshot_every=2,
        fault_plan="oom:fatal")
    assert rep["method"] == "hype_superstep"
    assert rep["requested_method"] == "hype_sharded"
    assert rep["fallbacks"] == 1 == rep["stats"].fallbacks
    assert rep["degraded_from"][0]["method"] == "hype_sharded"
    assert "OOM" in rep["degraded_from"][0]["error"]
    assert (a >= 0).all()


def test_ladder_resumes_fallback_from_snapshot(hg, tmp_path):
    a, rep = partition_api.partition_resilient(
        hg, 16, "hype_sharded", seed=0, pool_cap=8,
        snapshot_dir=str(tmp_path), snapshot_every=2,
        fault_plan="dispatch@5:fatal")
    assert rep["method"] == "hype_superstep"
    # the sharded rung published snapshots before dying; the fallback
    # rung warm-started from the last one instead of from scratch
    assert rep["stats"].resumed_at >= 2
    assert rep["fallbacks"] == 1
    sizes = np.bincount(a, minlength=16)
    assert sizes.max() - sizes.min() <= 1


def test_ladder_reaches_numpy_rung(hg, tmp_path):
    plan = resilience.FaultPlan.parse("oom:fatal;oom:fatal;"
                                      "dispatch@3:fatal")
    a, rep = partition_api.partition_resilient(
        hg, 16, "hype_sharded", seed=0, pool_cap=8, kernel_min=1,
        snapshot_dir=str(tmp_path), snapshot_every=2, fault_plan=plan)
    assert rep["method"] == "hype"
    assert [r["method"] for r in rep["degraded_from"]] == [
        "hype_sharded", "hype_superstep", "hype_batched"]
    assert rep["fallbacks"] == 3
    sizes = np.bincount(a, minlength=16)
    assert sizes.max() - sizes.min() <= 1
    assert metrics.k_minus_1(hg, a) >= 0


def test_ladder_exhausted_reraises(hg):
    # the numpy rung has no injection sites, so drive the ladder bottom
    # rung directly: a fatal fault on hype_batched with no further rung
    # must surface, not vanish
    plan = resilience.FaultPlan.parse("dispatch@3:fatal")
    a, rep = partition_api.partition_resilient(
        hg, 16, "hype_batched", seed=0, kernel_min=1, fault_plan=plan)
    assert rep["method"] == "hype" and rep["fallbacks"] == 1


def test_hype_warm_start_contract(hg):
    base = hype_partition(hg, 16, HypeParams(seed=0))
    # warm-starting from a prefix of a valid assignment keeps validity
    warm = base.copy()
    warm[hg.n // 2:] = -1
    a = hype_partition(hg, 16, HypeParams(seed=0), warm_start=warm)
    sizes = np.bincount(a, minlength=16)
    assert (a >= 0).all() and sizes.max() - sizes.min() <= 1
    with pytest.raises(ValueError, match="shape"):
        hype_partition(hg, 4, HypeParams(), warm_start=np.zeros(3, np.int32))
    with pytest.raises(ValueError, match=">= k"):
        hype_partition(hg, 4, HypeParams(),
                       warm_start=np.full(hg.n, 9, np.int32))
