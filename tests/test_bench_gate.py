"""The compare_baseline CI gate: speedup-regression logic plus the
refined-row km1 quality gate added with the refinement subsystem."""
import importlib.util
import pathlib

import pytest


@pytest.fixture(scope="module")
def gate():
    path = pathlib.Path(__file__).resolve().parents[1] / "benchmarks" \
        / "compare_baseline.py"
    spec = importlib.util.spec_from_file_location("compare_baseline",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _row(speedup, km1, refined=False):
    row = {"speedup_vs_hype": speedup, "km1_ratio_vs_hype": km1}
    if refined:
        row["refined"] = True
    return row


def test_gate_passes_within_bounds(gate, capsys):
    base = {"a": _row(5.0, 1.01), "r": _row(4.0, 0.97, refined=True)}
    cur = {"a": _row(4.5, 1.02), "r": _row(4.2, 0.98, refined=True)}
    assert gate.compare(base, cur) == 0


def test_gate_fails_on_speedup_regression(gate, capsys):
    base = {"a": _row(8.0, 1.0)}
    cur = {"a": _row(5.0, 1.0)}          # lost 37% > MAX_REGRESSION
    assert gate.compare(base, cur) == 1
    assert "REGRESSION" in capsys.readouterr().out


def test_gate_fails_on_refined_km1_regression(gate, capsys):
    """A refined row regressing km1 by more than 2% fails — the quality
    the refinement pass bought is enforced, not just measured."""
    base = {"r": _row(4.0, 0.95, refined=True)}
    cur = {"r": _row(4.0, 0.98, refined=True)}   # +3.2% > tol
    assert gate.compare(base, cur) == 1
    assert "refined-row" in capsys.readouterr().out


def test_gate_refined_tolerance_is_not_the_110_bound(gate):
    """Unrefined rows keep the loose 1.10 bound; the 2% tolerance only
    applies to refined rows."""
    base = {"a": _row(4.0, 0.95)}
    cur = {"a": _row(4.0, 0.98)}         # same +3.2%, unrefined: OK
    assert gate.compare(base, cur) == 0


def test_gate_refined_new_row_never_fails(gate):
    base = {"a": _row(4.0, 1.0)}
    cur = {"a": _row(4.0, 1.0), "r": _row(3.0, 0.9, refined=True)}
    assert gate.compare(base, cur) == 0
