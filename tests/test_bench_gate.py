"""The compare_baseline CI gate: speedup-regression logic, the
refined-row km1 quality gate added with the refinement subsystem, and
the absolute streaming gate (one-pass km1 bound + sketch invariant)
added with the streaming engine. Also a bench collection guard: every
``benchmarks/bench_*.py`` must import, expose a callable ``run`` and be
wired into ``benchmarks/run.py`` — a dead stub can't silently rot."""
import importlib
import importlib.util
import json
import pathlib

import pytest

BENCH_DIR = pathlib.Path(__file__).resolve().parents[1] / "benchmarks"


@pytest.fixture(scope="module")
def gate():
    path = pathlib.Path(__file__).resolve().parents[1] / "benchmarks" \
        / "compare_baseline.py"
    spec = importlib.util.spec_from_file_location("compare_baseline",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _row(speedup, km1, refined=False):
    row = {"speedup_vs_hype": speedup, "km1_ratio_vs_hype": km1}
    if refined:
        row["refined"] = True
    return row


def test_gate_passes_within_bounds(gate, capsys):
    base = {"a": _row(5.0, 1.01), "r": _row(4.0, 0.97, refined=True)}
    cur = {"a": _row(4.5, 1.02), "r": _row(4.2, 0.98, refined=True)}
    assert gate.compare(base, cur) == 0


def test_gate_fails_on_speedup_regression(gate, capsys):
    base = {"a": _row(8.0, 1.0)}
    cur = {"a": _row(5.0, 1.0)}          # lost 37% > MAX_REGRESSION
    assert gate.compare(base, cur) == 1
    assert "REGRESSION" in capsys.readouterr().out


def test_gate_fails_on_refined_km1_regression(gate, capsys):
    """A refined row regressing km1 by more than 2% fails — the quality
    the refinement pass bought is enforced, not just measured."""
    base = {"r": _row(4.0, 0.95, refined=True)}
    cur = {"r": _row(4.0, 0.98, refined=True)}   # +3.2% > tol
    assert gate.compare(base, cur) == 1
    assert "refined-row" in capsys.readouterr().out


def test_gate_refined_tolerance_is_not_the_110_bound(gate):
    """Unrefined rows keep the loose 1.10 bound; the 2% tolerance only
    applies to refined rows."""
    base = {"a": _row(4.0, 0.95)}
    cur = {"a": _row(4.0, 0.98)}         # same +3.2%, unrefined: OK
    assert gate.compare(base, cur) == 0


def test_gate_refined_new_row_never_fails(gate):
    base = {"a": _row(4.0, 1.0)}
    cur = {"a": _row(4.0, 1.0), "r": _row(3.0, 0.9, refined=True)}
    assert gate.compare(base, cur) == 0


# -- the streaming gate (DESIGN.md §4h) ---------------------------------

def test_streaming_gate_passes_under_bound(gate, capsys):
    rows = {"github_k8": {"km1_ratio_vs_hype": 1.4,
                          "vertices_per_s": 5000},
            "updates": {"updates_per_s": 40.0,
                        "sketch_invariant_exact": True}}
    assert gate.check_streaming(rows) == 0
    assert "[ok]" in capsys.readouterr().out


def test_streaming_gate_fails_over_bound(gate, capsys):
    rows = {"github_k8": {"km1_ratio_vs_hype":
                          gate.STREAM_KM1_BOUND + 0.1}}
    assert gate.check_streaming(rows) == 1
    assert "one-pass bound" in capsys.readouterr().out


def test_streaming_gate_fails_on_broken_sketch_invariant(gate, capsys):
    rows = {"updates": {"updates_per_s": 40.0,
                        "sketch_invariant_exact": False}}
    assert gate.check_streaming(rows) == 1
    assert "sketch invariant" in capsys.readouterr().out


def test_streaming_gate_empty_is_ok(gate):
    assert gate.check_streaming({}) == 0


def test_stream_bound_matches_engine_constant(gate):
    from repro.core.hype_stream import STREAM_KM1_BOUND
    assert gate.STREAM_KM1_BOUND == STREAM_KM1_BOUND


def _bench_json(tmp_path, name, speedups=None, streaming=None):
    meta = {}
    if speedups is not None:
        meta["speedups"] = speedups
    if streaming is not None:
        meta["streaming"] = streaming
    path = tmp_path / name
    path.write_text(json.dumps({"meta": meta, "rows": []}))
    return str(path)


def test_main_combines_compare_and_streaming_rcs(gate, tmp_path):
    """main() must fail when EITHER the baseline comparison or the
    streaming gate fails — a streaming-quality break can't hide behind
    a clean speedup table, and vice versa."""
    ok_speed = {"a": _row(4.0, 1.0)}
    bad_stream = {"g_k8": {"km1_ratio_vs_hype": 9.9}}
    ok_stream = {"g_k8": {"km1_ratio_vs_hype": 1.2}}
    base = _bench_json(tmp_path, "base.json", speedups=ok_speed)
    # clean compare + bad streaming -> fail
    cur = _bench_json(tmp_path, "cur1.json", speedups=ok_speed,
                      streaming=bad_stream)
    assert gate.main(["prog", base, cur]) == 1
    # clean compare + clean streaming -> pass
    cur = _bench_json(tmp_path, "cur2.json", speedups=ok_speed,
                      streaming=ok_stream)
    assert gate.main(["prog", base, cur]) == 0
    # regressed compare + clean streaming -> fail
    cur = _bench_json(tmp_path, "cur3.json",
                      speedups={"a": _row(1.0, 1.0)},
                      streaming=ok_stream)
    assert gate.main(["prog", base, cur]) == 1
    # no baseline speedups: only the streaming gate decides
    empty = _bench_json(tmp_path, "empty.json")
    cur = _bench_json(tmp_path, "cur4.json", streaming=bad_stream)
    assert gate.main(["prog", empty, cur]) == 1
    cur = _bench_json(tmp_path, "cur5.json", streaming=ok_stream)
    assert gate.main(["prog", empty, cur]) == 0


# -- bench collection guard ---------------------------------------------

def _bench_modules():
    return sorted(p.stem for p in BENCH_DIR.glob("bench_*.py"))


@pytest.mark.parametrize("name", _bench_modules())
def test_bench_module_imports_and_has_run(name):
    """Every bench_*.py must import cleanly and expose a callable
    ``run`` — a module that stops importing (or loses its entry point)
    is a dead stub and fails collection here, not at release time."""
    mod = importlib.import_module(f"benchmarks.{name}")
    assert callable(getattr(mod, "run", None)), \
        f"benchmarks/{name}.py has no callable run()"


def test_bench_runner_references_every_module():
    """benchmarks/run.py is the umbrella entry point: a bench module
    that exists but is never referenced there silently rots."""
    src = (BENCH_DIR / "run.py").read_text()
    missing = [n for n in _bench_modules() if n not in src]
    assert not missing, \
        f"benchmarks/run.py does not reference: {missing}"
