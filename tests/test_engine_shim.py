"""Back-compat shims for the engine split.

``repro.core.hype_batched`` (the old monolith) and the moved
``repro.core.scoring`` device-program names must keep resolving — with
a ``DeprecationWarning`` — to the same objects the new
``repro.engines`` modules export, so pinned imports survive the
refactor verbatim."""
import hashlib
import warnings

import numpy as np
import pytest

from repro.data.synthetic import powerlaw_hypergraph

# every name the monolith ever exported (public API + the private
# helpers the test-suite and downstream notebooks reached into)
_OLD_PUBLIC = (
    "BatchedParams", "BatchedStats", "SuperstepParams", "ShardedParams",
    "DeviceParams", "hype_batched_partition", "hype_superstep_partition",
    "hype_sharded_partition", "hype_device_partition",
)
_OLD_PRIVATE = (
    "_BatchedState", "_SuperstepState", "_ShardedState", "_CallArgs",
    "_Superstep", "_PH_SHIFT", "_CLS_SHIFT", "_SEQ_START", "_RESET0",
    "_RESET1", "_grow_partition", "_harvest_next", "_teardown_pipeline",
    "_maybe_refine", "_run_pipeline", "_run_pipeline_budgeted",
    "_device_probe_faults", "_device_probe_nan", "_device_export",
    "_device_attempt", "_run_device_loop",
)
_OLD_SCORING = (
    "pipeline_superstep_device", "chunked_superstep_device",
    "spill_superstep_device", "paged_superstep_device",
    "sharded_superstep_device", "_pipeline_program", "_chunked_program",
    "_spill_program", "_paged_program", "_sharded_mesh",
    "_sharded_program",
)


def _digest(a):
    return hashlib.sha256(
        np.ascontiguousarray(a, dtype=np.int32).tobytes()).hexdigest()[:16]


@pytest.mark.parametrize("name", _OLD_PUBLIC + _OLD_PRIVATE)
def test_hype_batched_shim_resolves_every_old_name(name):
    import repro.core.hype_batched as hb
    with pytest.warns(DeprecationWarning, match="repro.engines"):
        obj = getattr(hb, name)
    assert obj is not None


@pytest.mark.parametrize("name", _OLD_SCORING)
def test_scoring_shim_resolves_moved_programs(name):
    from repro.core import scoring
    with pytest.warns(DeprecationWarning, match="moved to repro.engines"):
        obj = getattr(scoring, name)
    assert callable(obj)


def test_shim_returns_the_engine_objects():
    """The shim must alias, not duplicate: isinstance checks and
    monkeypatching through the old path keep working."""
    import repro.core.hype_batched as hb
    from repro.engines import batched, runtime, sharded, superstep
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        assert hb.BatchedParams is batched.BatchedParams
        assert hb.BatchedStats is runtime.BatchedStats
        assert hb._BatchedState is batched.BatchedState
        assert hb._SuperstepState is superstep.SuperstepState
        assert hb._ShardedState is sharded.ShardedState
        assert hb._maybe_refine is runtime.maybe_refine
        assert hb.hype_superstep_partition is \
            superstep.hype_superstep_partition


def test_unknown_name_still_raises_attribute_error():
    import repro.core.hype_batched as hb
    from repro.core import scoring
    with pytest.raises(AttributeError):
        hb.definitely_not_a_thing
    with pytest.raises(AttributeError):
        scoring.definitely_not_a_thing


def test_old_partition_entry_points_still_run():
    """A pinned `from repro.core.hype_batched import ...` call site must
    produce bit-identical assignments through the shim."""
    import repro.core.hype_batched as hb
    from repro.engines.superstep import (SuperstepParams,
                                         hype_superstep_partition)
    hg = powerlaw_hypergraph(200, 140, seed=5, max_edge=12, max_degree=10)
    new = hype_superstep_partition(
        hg, 8, SuperstepParams(seed=0, t=8, pipeline_depth=1))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = hb.hype_superstep_partition(
            hg, 8, hb.SuperstepParams(seed=0, t=8, pipeline_depth=1))
    assert _digest(old) == _digest(new)


def test_compat_run_pipeline_matches_new_driver():
    import repro.core.hype_batched as hb
    from repro.engines import superstep
    hg = powerlaw_hypergraph(200, 140, seed=5, max_edge=12, max_degree=10)
    a_new, st_new = superstep.run_pipeline(
        hg, 5, superstep.SuperstepParams(seed=0, t=8, rows=8))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        a_old, st_old = hb._run_pipeline(
            hg, 5, superstep.SuperstepParams(seed=0, t=8, rows=8))
    assert _digest(a_old) == _digest(a_new)
    assert st_old.stats.supersteps == st_new.stats.supersteps
