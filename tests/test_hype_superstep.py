"""Device-resident superstep engine suite: validity, determinism,
quality regime, stats counters and the exact-decrement score cache
(repro.engines.superstep; the pipeline driver itself is covered by
test_pipeline.py)."""
import numpy as np
import pytest

from repro.core import metrics, scoring
from repro.core.hype import HypeParams, hype_partition
from repro.core.hypergraph import Hypergraph
from repro.core.partition_api import METHODS, partition
from repro.data.synthetic import powerlaw_hypergraph
from repro.engines.superstep import (SuperstepParams, SuperstepState,
                                     hype_superstep_partition)


@pytest.fixture(scope="module")
def hg():
    return powerlaw_hypergraph(600, 400, seed=11, max_edge=30,
                               max_degree=20)

# ------------------------------------------------------ superstep engine

@pytest.mark.parametrize("k", [2, 5, 16])
def test_superstep_complete_and_balanced(hg, k):
    a = hype_superstep_partition(hg, k, SuperstepParams(seed=0))
    assert a.shape == (hg.n,)
    assert a.dtype == np.int32
    assert a.min() >= 0 and a.max() < k
    sizes = metrics.partition_sizes(a, k)
    assert sizes.max() - sizes.min() <= 1


def test_superstep_deterministic(hg):
    a1 = hype_superstep_partition(hg, 6, SuperstepParams(seed=3))
    a2 = hype_superstep_partition(hg, 6, SuperstepParams(seed=3))
    np.testing.assert_array_equal(a1, a2)


def test_superstep_registered_in_api(hg):
    assert "hype_superstep" in METHODS
    a = partition(hg, 4, "hype_superstep", seed=0)
    assert a.min() >= 0 and a.max() < 4


def test_superstep_quality_regime(hg):
    """Concurrent k-way growth stays in the sequential engines' quality
    regime (same tolerance as the batched engine's agreement tests)."""
    k = 8
    a_s = hype_superstep_partition(hg, k, SuperstepParams(seed=0))
    a_n = hype_partition(hg, k, HypeParams(seed=0))
    km_s = metrics.k_minus_1(hg, a_s)
    km_n = metrics.k_minus_1(hg, a_n)
    assert km_s <= 1.35 * km_n + 20


def test_superstep_edge_cases():
    hg = Hypergraph.from_edge_lists(6, [[0, 1], [1, 2, 3], []])
    for k in (1, 2, 3, 8):
        a = hype_superstep_partition(hg, k, SuperstepParams(seed=0))
        assert (a >= 0).all() and (a < k).all()
        sizes = np.bincount(a, minlength=min(k, 6))
        assert sizes.max() - sizes.min() <= 1


def test_superstep_stats_counters(hg):
    """The superstep/transfer counters must measure the device traffic."""
    _, stt = hype_superstep_partition(hg, 8, SuperstepParams(seed=0),
                                      return_stats=True)
    assert stt.supersteps > 0
    assert stt.kernel_calls == stt.supersteps
    assert stt.kernel_rows > 0
    assert stt.device_image_bytes > 0
    assert stt.host_to_device_bytes > 0
    assert stt.cache_invalidations > 0
    assert stt.host_rows == 0            # no host-scoring fallback path
    # per-superstep traffic is ids + small bias buffers, not (B, L) tiles
    per_step = (stt.host_to_device_bytes / stt.supersteps)
    assert per_step < 8 * 64 * scoring.L_BUCKETS[-1]


def test_superstep_cache_exact_after_admissions():
    """Property check for decrement-based invalidation: after ANY
    admission sequence — device-selected winners (clipped decrements +
    host-queued tails) and host injections alike — every cached score
    equals a fresh ``batched_dext_adj`` recompute: the stale-score
    drift the old per-phase wipe was hiding cannot exist."""
    for seed in (0, 1, 2):
        hg = powerlaw_hypergraph(300, 200, seed=10 + seed, max_edge=18,
                                 max_degree=12)
        k, R, t = 4, 8, 2
        rng = np.random.default_rng(seed)
        st = SuperstepState(hg, k, SuperstepParams(seed=seed))
        fringe = np.full((k, 1), -1, np.int32)
        empty_pool = np.full((k, 4), -1, np.int32)
        acc = np.zeros(k, dtype=np.int64)
        targets = np.full(k, hg.n, dtype=np.int64)
        for step in range(10):
            # score a random batch of never-scored vertices; the device
            # admits up to a random per-phase cap of them (cap 0 phases
            # exercise the selection-without-admission path) ...
            cand = np.flatnonzero(~st.cache_scored & (st.assignment < 0))
            fresh = np.full((k, R), -1, np.int32)
            if cand.size:
                pick = rng.choice(cand, size=min(k * R, cand.size),
                                  replace=False)
                fresh.reshape(-1)[:pick.size] = pick
            bias = np.where(fresh >= 0, 0, np.inf).astype(np.float32)
            cap = rng.integers(0, t + 1, size=k)
            tgt = (acc + cap).astype(np.int32)
            handle = st.dispatch(fresh, bias, empty_pool, fringe,
                                 fresh[fresh >= 0].astype(np.int64),
                                 tgt, 32, t)
            st.harvest(handle, acc, targets)
            # ... then admit a random batch by host injection too
            un = np.flatnonzero(st.assignment < 0)
            if un.size == 0:
                break
            vs = rng.choice(un, size=min(int(rng.integers(1, 8)),
                                         un.size), replace=False)
            g = int(rng.integers(0, k))
            st.assign_now(vs, g)
            acc[g] += vs.size
        while st.delta_ids or st.pending_dirty:    # flush tails + deltas
            handle = st.dispatch(np.full((k, 1), -1, np.int32),
                                 np.full((k, 1), np.inf, np.float32),
                                 np.full((k, 1), -1, np.int32), fringe,
                                 np.empty(0, dtype=np.int64),
                                 acc.astype(np.int32), 32, 1)
            st.harvest(handle, acc, targets)
        cache = np.asarray(st.dev_cache, dtype=np.float64)
        # rows wider than the run's tile width are truncated hubs parked
        # at ~1e12 — the exactness contract covers everything else
        scored = np.flatnonzero(st.cache_scored & (st.deg <= st.tile_l))
        assert scored.size > 50
        ref = scoring.batched_dext_adj(st.adj, scored,
                                       np.zeros(hg.n, dtype=bool),
                                       st.assignment)
        assert (ref > 0).any()           # the recompute is not trivial
        np.testing.assert_allclose(cache[scored], ref)
        # device/host assignment + totals parity after the flush
        np.testing.assert_array_equal(np.asarray(st.dev_assign),
                                      st.assignment)
        np.testing.assert_array_equal(
            np.asarray(st.dev_acc),
            np.bincount(st.assignment[st.assignment >= 0],
                        minlength=k))


def test_superstep_cross_phase_cache_reuse():
    """Scores survive phase completion: when a finished phase releases
    its pool and another phase redraws those vertices, they are cache
    hits — impossible under the old per-phase wipe."""
    for seed in range(3):
        hg = powerlaw_hypergraph(300, 500, seed=21 + seed, max_edge=10,
                                 max_degree=30)
        _, stt = hype_superstep_partition(
            hg, 24, SuperstepParams(seed=seed, pool_cap=16),
            return_stats=True)
        assert stt.cache_hits > 0


