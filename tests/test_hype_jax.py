"""JAX HYPE engines: validity, cross-engine quality, parallel growth."""
import numpy as np
import pytest

from repro.core.hype import HypeParams, hype_partition
from repro.core.hype_jax import (PaddedHypergraph, hype_jax_partition,
                                 hype_parallel_partition)
from repro.core import metrics
from repro.core.minmax import random_partition
from repro.data.synthetic import powerlaw_hypergraph


@pytest.fixture(scope="module")
def hg():
    return powerlaw_hypergraph(300, 200, seed=3, max_edge=20, max_degree=12)


def test_padded_views(hg):
    ph = PaddedHypergraph.from_hypergraph(hg)
    assert ph.n == hg.n and ph.m == hg.m
    assert ph.v2e.shape[0] == hg.n
    # row contents match CSR
    v = int(np.argmax(hg.vertex_degrees))
    row = np.asarray(ph.v2e[v])
    np.testing.assert_array_equal(np.sort(row[row >= 0]),
                                  np.sort(hg.vertex_edges(v)))


@pytest.mark.parametrize("k", [2, 5, 8])
def test_jax_sequential_valid_balanced(hg, k):
    a = hype_jax_partition(hg, k, seed=0)
    assert a.shape == (hg.n,)
    assert a.min() >= 0 and a.max() < k
    sizes = metrics.partition_sizes(a, k)
    assert sizes.max() - sizes.min() <= 1


@pytest.mark.parametrize("k", [4, 8])
def test_parallel_valid(hg, k):
    a = hype_parallel_partition(hg, k, seed=0)
    assert a.min() >= 0 and a.max() < k
    sizes = metrics.partition_sizes(a, k)
    # parallel growth is balanced up to collision slack
    assert sizes.max() <= 1.5 * (hg.n / k) + 2


def test_jax_matches_numpy_quality(hg):
    """Engines share the algorithm, not the RNG; quality must be close."""
    k = 6
    km_np = metrics.k_minus_1(hg, hype_partition(hg, k, HypeParams(seed=0)))
    km_jx = metrics.k_minus_1(hg, hype_jax_partition(hg, k, seed=0))
    km_rd = metrics.k_minus_1(hg, random_partition(hg, k, seed=0))
    assert km_jx < km_rd
    assert km_jx <= 1.5 * km_np + 10


def test_parallel_quality_beats_random(hg):
    k = 8
    km_p = metrics.k_minus_1(hg, hype_parallel_partition(hg, k, seed=0))
    km_r = metrics.k_minus_1(hg, random_partition(hg, k, seed=0))
    assert km_p < km_r


def test_jax_deterministic(hg):
    a1 = hype_jax_partition(hg, 4, seed=9)
    a2 = hype_jax_partition(hg, 4, seed=9)
    np.testing.assert_array_equal(a1, a2)
