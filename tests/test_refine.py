"""Device-resident k-way refinement (DESIGN.md §4e): kway_gains kernel
parity vs its numpy oracle across all L buckets / pad / fill levels,
exact-gain verification against brute-force (k-1) deltas, the
refine_kway contract (monotone quality, preserved balance, determinism,
additive stats.gain), the refine_passes=0 bit-identity golden, engine
integration, and the rebuilt multilevel / hype_multilevel partitioners."""
import hashlib

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.core import metrics
from repro.engines.batched import BatchedParams, hype_batched_partition
from repro.engines.superstep import (SuperstepParams,
                                     hype_superstep_partition)
from repro.core.hypergraph import Hypergraph
from repro.core.refine import (RefineStats, _cut_boundary, _host_gains,
                               admit_moves, exact_gain_matrix,
                               rebalance_kway, refine_kway)
from repro.data.synthetic import powerlaw_hypergraph
from repro.kernels.kway_refine.ops import kway_gains
from repro.kernels.kway_refine.ref import kway_gains_ref


def _digest(a: np.ndarray) -> str:
    return hashlib.sha256(
        np.ascontiguousarray(a, dtype=np.int32).tobytes()).hexdigest()[:16]


@pytest.fixture(scope="module")
def hg():
    return powerlaw_hypergraph(600, 400, seed=11, max_edge=30,
                               max_degree=20)


# ----------------------------------------------------- kernel vs oracle

def _gain_case(B, L, k, seed, fill="full"):
    rng = np.random.default_rng(seed)
    parts = rng.integers(-1, k, size=(B, L)).astype(np.int32)
    own = rng.integers(0, k, size=(B,)).astype(np.int32)
    if fill == "empty":
        parts[:] = -1
    elif fill == "partial":
        parts[:, L // 2:] = -1
    if B > 1:       # a pad row, exactly as the ops wrapper builds them
        parts[-1] = -1
        own[-1] = -1
    out = np.asarray(kway_gains(jnp.asarray(parts), jnp.asarray(own),
                                k=k))
    ref = kway_gains_ref(parts, own, k)
    np.testing.assert_array_equal(out, ref)
    # own column and pad rows are zero by construction
    real = own >= 0
    assert (out[real, own[real]] == 0).all()
    if B > 1:
        assert (out[-1] == 0).all()


@pytest.mark.parametrize("L", [32, 128, 512, 2048])     # every L bucket
def test_kway_gains_matches_ref_all_widths(L):
    from repro.core.scoring import L_BUCKETS
    assert L in L_BUCKETS
    _gain_case(B=24, L=L, k=8, seed=L)


@pytest.mark.parametrize("fill", ["empty", "partial", "full"])
def test_kway_gains_fill_levels(fill):
    _gain_case(B=16, L=64, k=5, seed=3, fill=fill)


@pytest.mark.parametrize("B,L,k", [(1, 1, 2), (7, 33, 3), (300, 16, 32)])
def test_kway_gains_odd_shapes(B, L, k):
    _gain_case(B=B, L=L, k=k, seed=B * L + k)


@given(st.integers(1, 40), st.integers(1, 64), st.integers(2, 16),
       st.integers(0, 99))
@settings(max_examples=15, deadline=None)
def test_kway_gains_property(B, L, k, seed):
    rng = np.random.default_rng(seed)
    parts = rng.integers(-1, k, size=(B, L)).astype(np.int32)
    own = rng.integers(0, k, size=(B,)).astype(np.int32)
    out = np.asarray(kway_gains(jnp.asarray(parts), jnp.asarray(own),
                                k=k))
    np.testing.assert_array_equal(out, kway_gains_ref(parts, own, k))
    # gains are bounded by the row's valid width
    width = (parts >= 0).sum(axis=1)
    assert (np.abs(out) <= width[:, None]).all()


# ------------------------------------------------- exact gains / boundary

@given(st.integers(2, 6), st.integers(0, 20))
@settings(max_examples=20, deadline=None)
def test_exact_gain_matches_brute_force(k, seed):
    """exact_gain_matrix must equal the true (k-1) delta of every
    single-vertex move, measured by recomputing the metric."""
    hg = powerlaw_hypergraph(40, 30, seed=seed, max_edge=8, max_degree=6)
    rng = np.random.default_rng(seed)
    a = rng.integers(0, k, size=hg.n).astype(np.int32)
    cand = rng.choice(hg.n, size=min(10, hg.n), replace=False)
    gains = exact_gain_matrix(hg, cand.astype(np.int64), a, k)
    km0 = metrics.k_minus_1(hg, a, k)
    for i, v in enumerate(cand):
        for q in range(k):
            if q == a[v]:
                assert gains[i, q] == 0
                continue
            b = a.copy()
            b[v] = q
            assert km0 - metrics.k_minus_1(hg, b, k) == gains[i, q], \
                (v, int(a[v]), q)


def test_exact_gain_matches_brute_force_seeded():
    """Deterministic twin of the property test above (hypothesis is
    optional in CI; this exactness check must always run)."""
    for k, seed in ((2, 0), (3, 7), (6, 13)):
        hg = powerlaw_hypergraph(40, 30, seed=seed, max_edge=8,
                                 max_degree=6)
        rng = np.random.default_rng(seed)
        a = rng.integers(0, k, size=hg.n).astype(np.int32)
        cand = rng.choice(hg.n, size=10, replace=False)
        gains = exact_gain_matrix(hg, cand.astype(np.int64), a, k)
        km0 = metrics.k_minus_1(hg, a, k)
        for i, v in enumerate(cand):
            for q in range(k):
                b = a.copy()
                b[v] = q
                assert km0 - metrics.k_minus_1(hg, b, k) == gains[i, q]


def test_cut_boundary(hg):
    a = np.zeros(hg.n, dtype=np.int32)
    assert _cut_boundary(hg, a).size == 0       # uncut: no boundary
    a[: hg.n // 2] = 1
    boundary = _cut_boundary(hg, a)
    spans = metrics.spans_per_edge(hg, a, 2)
    pins = np.unique(np.concatenate(
        [hg.edge_pins(int(e)) for e in np.flatnonzero(spans > 1)]))
    np.testing.assert_array_equal(boundary, pins)


def test_host_gains_match_kernel_semantics(hg):
    """The host screening twin equals the oracle fed untruncated tiles."""
    rng = np.random.default_rng(0)
    k = 4
    a = rng.integers(0, k, size=hg.n).astype(np.int32)
    adj = hg.vertex_adjacency()
    cand = rng.choice(hg.n, size=32, replace=False).astype(np.int64)
    g = _host_gains(adj, cand, a, k)
    deg = np.diff(adj[0])
    L = int(deg[cand].max())
    tile = np.full((cand.size, L), -1, np.int32)
    for i, v in enumerate(cand):
        nb = adj[1][adj[0][v]:adj[0][v + 1]]
        tile[i, :nb.size] = a[nb]
    np.testing.assert_array_equal(
        g, kway_gains_ref(tile, a[cand].astype(np.int32), k))


# --------------------------------------------------- admission machinery

def test_admit_moves_balance_and_conflicts():
    # two triangle-ish edges sharing vertex 2; k=2 with tight caps
    hg = Hypergraph.from_edge_lists(6, [[0, 1, 2], [2, 3, 4], [4, 5]])
    sizes = np.array([3, 3], dtype=np.int64)
    lo, hi = np.array([3, 3]), np.array([3, 3])
    stats = RefineStats()
    # v0: 0->1 (gain 5) and v3: 1->0 (gain 4): balance-blocked singly,
    # admitted as a swap; v1: 0->1 (gain 3) conflicts with v0 via edge 0
    vs = np.array([0, 3, 1])
    src = np.array([0, 1, 0])
    dst = np.array([1, 0, 1])
    gain = np.array([5, 4, 3])
    adm_v, adm_dst = admit_moves(vs, src, dst, gain, hg, sizes, lo, hi,
                                 stats)
    assert sorted(adm_v.tolist()) == [0, 3]
    assert stats.swaps == 1 and stats.moves == 2
    assert stats.gain == 9
    assert stats.rejected_conflict == 1
    np.testing.assert_array_equal(sizes, [3, 3])    # swap is neutral


def test_admit_moves_single_move_respects_window():
    hg = Hypergraph.from_edge_lists(4, [[0, 1], [2, 3]])
    sizes = np.array([3, 1], dtype=np.int64)
    lo, hi = np.array([1, 1]), np.array([3, 3])
    stats = RefineStats()
    adm_v, adm_dst = admit_moves(
        np.array([0]), np.array([0]), np.array([1]), np.array([2]),
        hg, sizes, lo, hi, stats)
    assert adm_v.tolist() == [0] and adm_dst.tolist() == [1]
    np.testing.assert_array_equal(sizes, [2, 2])


# ------------------------------------------------- refine_kway contract

@pytest.mark.parametrize("k", [4, 16])
@pytest.mark.parametrize("use_device", [True, False])
def test_refine_monotone_balanced_deterministic(hg, k, use_device):
    a0 = hype_superstep_partition(hg, k, SuperstepParams(seed=0))
    km0 = metrics.k_minus_1(hg, a0, k)
    a1, st1 = refine_kway(hg, a0, k, 4, use_device=use_device)
    a2, _ = refine_kway(hg, a0, k, 4, use_device=use_device)
    np.testing.assert_array_equal(a1, a2)           # deterministic
    km1 = metrics.k_minus_1(hg, a1, k)
    assert km1 <= km0                               # monotone
    assert km0 - km1 == st1.gain                    # exactly additive
    sizes = metrics.partition_sizes(a1, k)
    assert sizes.max() - sizes.min() <= 1           # contract preserved
    assert st1.moves > 0 and (a1 != a0).sum() == st1.moves


def test_refine_delta_buffer_holds_a_full_pass():
    """Regression: a pass can admit up to cand_cap moves — far more
    than one screening tile — and the next pass's device delta buffer
    must hold all of them (it used to be sized by tile_rows only,
    crashing the second pass with a broadcast error)."""
    edges = [[2 * i, 2 * i + 1] for i in range(50)]
    hg = Hypergraph.from_edge_lists(200, edges)
    a = np.zeros(200, dtype=np.int32)
    a[1:100:2] = 1          # each pair split across the two partitions
    a[175:200] = 1          # filler: sizes 125 / 75, slack for singles
    km0 = metrics.k_minus_1(hg, a, 2)
    a1, st = refine_kway(hg, a, 2, 2, tile_rows=8, cand_cap=64)
    assert st.moves > 8                     # one pass overflowed a tile
    assert st.passes_run >= 2               # second pass ran (no crash)
    assert metrics.k_minus_1(hg, a1, 2) < km0


def test_refine_zero_passes_is_identity(hg):
    a0 = hype_superstep_partition(hg, 8, SuperstepParams(seed=0))
    a1, st = refine_kway(hg, a0, 8, 0)
    assert a1 is a0                                 # strict no-op
    assert st.passes_run == 0 and st.moves == 0


def test_refine_requires_complete_assignment(hg):
    a = np.full(hg.n, -1, dtype=np.int32)
    with pytest.raises(ValueError, match="complete"):
        refine_kway(hg, a, 4, 1)


def test_refine_k1_and_uncut_noop(hg):
    a = np.zeros(hg.n, dtype=np.int32)
    a1, st = refine_kway(hg, a, 1, 3)
    assert st.moves == 0
    a2, st2 = refine_kway(hg, a, 4, 3)      # all in part 0: no boundary
    # a move could only help balance, and refinement never forces one
    assert metrics.k_minus_1(hg, a2, 4) == 0


def test_rebalance_kway(hg):
    rng = np.random.default_rng(1)
    k = 5
    a = rng.integers(0, 2, size=hg.n).astype(np.int32)  # parts 2..4 empty
    b = rebalance_kway(hg, a, k)
    sizes = metrics.partition_sizes(b, k)
    assert sizes.max() - sizes.min() <= 1
    assert sizes.sum() == hg.n
    np.testing.assert_array_equal(b, rebalance_kway(hg, a, k))


# ----------------------------------------------------- engine integration

# refine_passes=0 must keep today's outputs bit-identical: the same
# lock-step golden digest test_pipeline.py pins (powerlaw 600/400 seed
# 11, k=16, t=8, pipeline_depth=1).
_GOLD_PL600_K16_T8 = "bbcd2f732e03af91"


def test_refine_passes_zero_bit_identical_golden(hg):
    a = hype_superstep_partition(
        hg, 16, SuperstepParams(seed=0, t=8, pipeline_depth=1,
                                refine_passes=0))
    assert _digest(a) == _GOLD_PL600_K16_T8


@pytest.mark.parametrize("method", ["hype_batched", "hype_superstep"])
def test_engine_refine_knob(hg, method):
    from repro.core.partition_api import partition
    k = 16
    a0 = partition(hg, k, method, seed=0)
    a1 = partition(hg, k, method, seed=0, refine_passes=3)
    assert metrics.k_minus_1(hg, a1, k) <= metrics.k_minus_1(hg, a0, k)
    sizes = metrics.partition_sizes(a1, k)
    assert sizes.max() - sizes.min() <= 1


def test_engine_refine_stats_surfaced(hg):
    _, st = hype_superstep_partition(
        hg, 16, SuperstepParams(seed=0, refine_passes=3),
        return_stats=True)
    assert st.refine is not None
    assert st.refine.passes_run >= 1
    assert st.refine.gain >= st.refine.moves > 0    # every move gains >=1
    _, st0 = hype_batched_partition(
        hg, 8, BatchedParams(seed=0), return_stats=True)
    assert st0.refine is None                       # off by default


def test_sharded_refine_knob(hg):
    import jax
    from repro.engines.sharded import (ShardedParams,
                                       hype_sharded_partition)
    if len(jax.devices()) < 2:
        pytest.skip("needs a simulated multi-device mesh")
    a0 = hype_sharded_partition(hg, 16, ShardedParams(seed=0, devices=2))
    a1 = hype_sharded_partition(
        hg, 16, ShardedParams(seed=0, devices=2, refine_passes=3))
    assert metrics.k_minus_1(hg, a1, 16) <= metrics.k_minus_1(hg, a0, 16)
    sizes = metrics.partition_sizes(a1, 16)
    assert sizes.max() - sizes.min() <= 1


# ------------------------------------------------------- hype_multilevel

@pytest.mark.parametrize("k", [3, 8])
def test_hype_multilevel_contract(hg, k):
    from repro.core.multilevel import hype_multilevel_partition
    a = hype_multilevel_partition(hg, k, seed=0)
    assert a.dtype == np.int32 and a.shape == (hg.n,)
    assert a.min() >= 0 and a.max() < k
    sizes = metrics.partition_sizes(a, k)
    assert sizes.max() - sizes.min() <= 1
    np.testing.assert_array_equal(a, hype_multilevel_partition(
        hg, k, seed=0))


def test_hype_multilevel_coarsens_large_graph():
    """Force the coarsening + weighted-uncoarsening path (coarsest well
    below n) and check the contract survives the projections."""
    from repro.core.multilevel import hype_multilevel_partition
    hg = powerlaw_hypergraph(1500, 1000, seed=4, max_edge=20,
                             max_degree=12)
    a = hype_multilevel_partition(hg, 8, seed=0, coarsest=200)
    sizes = metrics.partition_sizes(a, 8)
    assert sizes.max() - sizes.min() <= 1
    assert metrics.k_minus_1(hg, a, 8) > 0          # sane output


def test_hype_multilevel_quality_beats_random(hg):
    from repro.core.partition_api import partition
    km_ml = metrics.k_minus_1(hg, partition(hg, 8, "hype_multilevel",
                                            seed=0), 8)
    km_r = metrics.k_minus_1(hg, partition(hg, 8, "random", seed=0), 8)
    assert km_ml < km_r
