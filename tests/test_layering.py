"""The import-layering lint (tools/check_layering.py) must hold on the
real tree AND actually detect violations — each rule is probed with a
synthetic offending module so a silently broken lint fails here."""
import pathlib
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "tools"))
from check_layering import check_tree, violations_for_source  # noqa: E402

SRC = pathlib.Path(__file__).resolve().parent.parent / "src"


def test_real_tree_is_clean():
    assert check_tree(SRC) == []


def test_core_may_not_import_engines():
    bad = "from repro.engines.batched import BatchedParams\n"
    v = violations_for_source("repro.core.partition_api", bad)
    assert len(v) == 1 and "layering rule 1" in v[0][1]
    v = violations_for_source("repro.core.partition_api",
                              "import repro.engines\n")
    assert len(v) == 1


def test_core_lazy_import_is_sanctioned():
    ok = ("def run():\n"
          "    from repro.engines.batched import BatchedParams\n"
          "    return BatchedParams\n")
    assert violations_for_source("repro.core.partition_api", ok) == []


def test_engine_sibling_public_import_ok_private_rejected():
    mod = "repro.engines.superstep"
    assert violations_for_source(
        mod, "from .batched import BatchedParams\n") == []
    v = violations_for_source(
        mod, "from .batched import _grow_partition\n")
    assert len(v) == 1 and "non-public" in v[0][1]
    v = violations_for_source(mod, "from .batched import *\n")
    assert len(v) == 1


def test_engine_may_not_bind_sibling_module_object():
    mod = "repro.engines.device"
    v = violations_for_source(mod, "import repro.engines.superstep\n")
    assert len(v) == 1 and "binds sibling" in v[0][1]
    v = violations_for_source(mod, "from repro.engines import superstep\n")
    assert len(v) == 1
    # ... but the shared layer is importable as a module
    assert violations_for_source(
        mod, "from repro.engines import runtime\n") == []
    assert violations_for_source(mod, "from .runtime import run_pipeline\n") == []


def test_shared_layer_below_every_engine():
    v = violations_for_source("repro.engines.runtime",
                              "from .batched import BatchedParams\n")
    assert len(v) == 1 and "shared engine layer" in v[0][1]
    assert violations_for_source("repro.engines.pipeline",
                                 "from .runtime import EngineRuntime\n") == []


def test_core_and_kernel_imports_unrestricted():
    mod = "repro.engines.sharded"
    ok = ("from repro.core.scoring import gather_csr_rows\n"
          "from repro.kernels.hype_score.ops import hype_score_select\n"
          "import numpy as np\n")
    assert violations_for_source(mod, ok) == []


@pytest.mark.parametrize("snippet", [
    "from repro.engines.superstep import SuperstepParams\n",
    "import repro.engines.superstep\n",
])
def test_cli_entry_detects_violation(tmp_path, snippet):
    """End-to-end: a violating file under a scratch src tree is caught
    by the same tree walker the CI entry point runs."""
    pkg = tmp_path / "src" / "repro" / "core"
    pkg.mkdir(parents=True)
    (pkg / "bad.py").write_text(snippet)
    msgs = check_tree(tmp_path / "src")
    assert len(msgs) == 1 and "bad.py" in msgs[0]
