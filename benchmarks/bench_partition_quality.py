"""Paper Figures 7/8/9 (a+b+c): (k-1) quality, runtime, and imbalance
vs number of partitions, for HYPE and the baselines, per dataset."""
from __future__ import annotations

import time

from repro.core import metrics
from repro.core.partition_api import partition

from .common import QUICK, dataset, emit


def run(datasets=("github", "stackoverflow", "reddit"), ks=(2, 8, 32, 128),
        methods=("hype", "minmax_nb", "minmax_eb", "random")):
    results = {}
    for ds in datasets:
        hg = dataset(ds)
        # hMETIS-analog only at small scale (the paper: group (I) cannot
        # partition large hypergraphs — reproduced by omission here)
        meths = methods + (("multilevel", "shp") if ds == "github" and
                           not QUICK else ())
        for k in ks:
            for m in meths:
                if m in ("multilevel", "shp") and k > 32:
                    continue
                t0 = time.perf_counter()
                a = partition(hg, k, m, seed=0)
                dt = time.perf_counter() - t0
                km1 = metrics.k_minus_1(hg, a)
                imb = metrics.vertex_imbalance(a, k)
                results[(ds, k, m)] = (km1, dt, imb)
                emit(f"partition_quality/{ds}/k{k}/{m}", dt * 1e6,
                     f"km1={km1};imb={imb:.3f}")
    # paper headline: HYPE vs MinMax improvement at large k
    for ds in datasets:
        for k in ks:
            if (ds, k, "hype") in results and (ds, k, "minmax_nb") in results:
                h = results[(ds, k, "hype")][0]
                m = results[(ds, k, "minmax_nb")][0]
                emit(f"partition_quality/{ds}/k{k}/hype_vs_minmax_nb", 0.0,
                     f"improvement={100 * (1 - h / max(m, 1)):.1f}%")
    return results


if __name__ == "__main__":
    run()
