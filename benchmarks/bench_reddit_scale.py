"""Paper Figure 10 analog: the largest hypergraph (reddit-like), k=128 —
HYPE quality AND runtime vs the streaming baselines, now including the
repo's own ``hype_stream`` one-pass engine (DESIGN.md §4h) with its
sustained vertices/sec. Also the k-independence of HYPE's runtime
(paper §IV-A)."""
from __future__ import annotations

import time

from repro.core import metrics
from repro.core.hype_stream import StreamParams, hype_stream_partition
from repro.core.partition_api import partition

from .common import dataset, emit


def run():
    hg = dataset("reddit")
    emit("reddit/stats", 0.0,
         f"n={hg.n};m={hg.m};pins={hg.n_pins}")
    res = {}
    for m in ("hype", "minmax_nb", "minmax_eb"):
        t0 = time.perf_counter()
        a = partition(hg, 128, m, seed=0)
        dt = time.perf_counter() - t0
        km1 = metrics.k_minus_1(hg, a)
        res[m] = (km1, dt)
        emit(f"reddit/k128/{m}", dt * 1e6, f"km1={km1}")
    h, mm = res["hype"][0], res["minmax_eb"][0]
    emit("reddit/k128/hype_vs_minmax_eb", 0.0,
         f"improvement={100 * (1 - h / max(mm, 1)):.1f}%")

    # the streaming-scale row: one-pass hype_stream against the same
    # k=128 field — km1 ratio vs offline hype plus sustained ingest
    t0 = time.perf_counter()
    a_s, st = hype_stream_partition(hg, 128, StreamParams(seed=0),
                                    return_stats=True)
    dt = time.perf_counter() - t0
    km1_s = metrics.k_minus_1(hg, a_s)
    emit("reddit/k128/hype_stream", dt * 1e6,
         f"km1={km1_s};ratio_vs_hype={km1_s / max(res['hype'][0], 1):.2f};"
         f"vertices_per_s={st.vertices_per_s:.0f}")

    # runtime vs k: HYPE flat, MinMax grows (paper Fig 9b)
    for k in (2, 32, 128):
        for m in ("hype", "minmax_nb"):
            t0 = time.perf_counter()
            partition(hg, k, m, seed=0)
            dt = time.perf_counter() - t0
            emit(f"reddit/runtime_vs_k/{m}/k{k}", dt * 1e6, "")


if __name__ == "__main__":
    run()
