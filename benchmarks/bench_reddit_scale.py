"""Paper Figure 10 analog: the largest hypergraph (reddit-like), k=128 —
HYPE quality AND runtime vs streaming MinMax. Also the k-independence of
HYPE's runtime (paper §IV-A)."""
from __future__ import annotations

import time

from repro.core import metrics
from repro.core.partition_api import partition

from .common import dataset, emit


def run():
    hg = dataset("reddit")
    emit("reddit/stats", 0.0,
         f"n={hg.n};m={hg.m};pins={hg.n_pins}")
    res = {}
    for m in ("hype", "minmax_nb", "minmax_eb"):
        t0 = time.perf_counter()
        a = partition(hg, 128, m, seed=0)
        dt = time.perf_counter() - t0
        km1 = metrics.k_minus_1(hg, a)
        res[m] = (km1, dt)
        emit(f"reddit/k128/{m}", dt * 1e6, f"km1={km1}")
    h, mm = res["hype"][0], res["minmax_eb"][0]
    emit("reddit/k128/hype_vs_minmax_eb", 0.0,
         f"improvement={100 * (1 - h / max(mm, 1)):.1f}%")

    # runtime vs k: HYPE flat, MinMax grows (paper Fig 9b)
    for k in (2, 32, 128):
        for m in ("hype", "minmax_nb"):
            t0 = time.perf_counter()
            partition(hg, k, m, seed=0)
            dt = time.perf_counter() - t0
            emit(f"reddit/runtime_vs_k/{m}/k{k}", dt * 1e6, "")


if __name__ == "__main__":
    run()
