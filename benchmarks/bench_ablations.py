"""Paper Figures 3 / 5 / 6: fringe size s, candidate count r, and the
score cache — quality stays, runtime drops (StackOverflow hypergraph)."""
from __future__ import annotations

import time

from repro.core import metrics
from repro.core.hype import HypeParams, hype_partition

from .common import dataset, emit


def run(k: int = 32):
    hg = dataset("stackoverflow")

    # Fig 3: fringe size sweep
    for s in (2, 10, 50, 200):
        t0 = time.perf_counter()
        a = hype_partition(hg, k, HypeParams(seed=0, s=s))
        dt = time.perf_counter() - t0
        emit(f"ablation/fringe_s{s}", dt * 1e6,
             f"km1={metrics.k_minus_1(hg, a)}")

    # Fig 5: candidate count sweep (r=2 should be best or near-best)
    for r in (1, 2, 4, 8):
        t0 = time.perf_counter()
        a = hype_partition(hg, k, HypeParams(seed=0, r=r))
        dt = time.perf_counter() - t0
        emit(f"ablation/candidates_r{r}", dt * 1e6,
             f"km1={metrics.k_minus_1(hg, a)}")

    # Fig 6: lazy score cache on/off
    for cache in (True, False):
        t0 = time.perf_counter()
        a, st = hype_partition(hg, k, HypeParams(seed=0, use_cache=cache),
                               return_stats=True)
        dt = time.perf_counter() - t0
        emit(f"ablation/cache_{'on' if cache else 'off'}", dt * 1e6,
             f"km1={metrics.k_minus_1(hg, a)};"
             f"score_computations={st.score_computations}")

    # Eq.1-literal vs universe external-neighbors score (paper ambiguity;
    # DESIGN.md §3)
    for mode in ("universe", "eq1"):
        t0 = time.perf_counter()
        a = hype_partition(hg, k, HypeParams(seed=0, dext_mode=mode))
        dt = time.perf_counter() - t0
        emit(f"ablation/dext_{mode}", dt * 1e6,
             f"km1={metrics.k_minus_1(hg, a)}")


if __name__ == "__main__":
    run()
