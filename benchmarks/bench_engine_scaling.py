"""Engine scaling sweep -> BENCH_engines.json (the repo's perf trajectory).

Sweeps partitioning engines x (dataset/n, k, t) on the synthetic
github / stackoverflow / reddit generators and records runtime + quality
for every row, machine-readably, so future PRs can diff performance.

    PYTHONPATH=src python -m benchmarks.bench_engine_scaling

Timing protocol: per dataset the batched engine's one-time costs
(adjacency build, Pallas interpret-mode traces) are warmed once and
reported separately in ``meta``; every row's ``runtime_s`` is then the
best of ``REPEATS`` steady-state runs. The jittable ``hype_jax`` engine
moves one vertex per while_loop iteration, so it only runs on a small
synthetic row (it exists for on-device validation, not throughput).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import metrics
from repro.core.hype import HypeParams, hype_partition
from repro.engines.batched import BatchedParams, hype_batched_partition
from repro.engines.device import DeviceParams, hype_device_partition
from repro.engines.sharded import ShardedParams, hype_sharded_partition
from repro.engines.superstep import (SuperstepParams,
                                     hype_superstep_partition)
from repro.core.hype_stream import (StreamParams, apply_updates,
                                    hype_stream_partition)
from repro.data.synthetic import powerlaw_hypergraph

from .common import QUICK, dataset, emit

OUT_PATH = "BENCH_engines.json"
REPEATS = 2
KS = (8, 32)
TS = (1, 8, 16)          # batched-engine admissions-per-step knob
SUPERSTEP_TS = (8, 16)   # superstep engine: admissions per phase per step
SHARDED_K = 32           # device-count scaling axis runs at the large k
SHARDED_T = 16
SHARDED_DEVICES = (1, 2, 4)   # clamped to the simulated mesh size
PIPELINE_K = 32          # pipeline-depth axis: k/t of the acceptance row
PIPELINE_T = 16          # (depth-1 vs default depth-2, host/device split)
REFINE_K = 32            # refinement axis: the k/t acceptance row gets a
REFINE_T = 16            # refined sibling (engine suffix `_r{passes}`)
REFINE_PASSES = 4        # kway_refine post-passes for the refined rows
JAX_N = 300              # hype_jax validation row size
STREAM_MB = 64           # streaming-engine micro-batch for the rows
STREAM_OPS = 120         # op-log length for the update-throughput row


def _run(fn, *args, **kw):
    best, out = None, None
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        dt = time.perf_counter() - t0
        best = dt if best is None or dt < best else best
    return out, best


def _row(name, hg, k, engine, runtime, assignment, extra=None):
    rec = {
        "dataset": name, "n": hg.n, "m": hg.m, "pins": hg.n_pins,
        "k": k, "engine": engine, "runtime_s": round(runtime, 4),
        "k_minus_1": metrics.k_minus_1(hg, assignment),
        "imbalance": round(metrics.vertex_imbalance(assignment, k), 4),
    }
    if extra:
        rec.update(extra)
    emit(f"engine/{name}/k{k}/{engine}", runtime * 1e6,
         f"km1={rec['k_minus_1']}")
    return rec


def run():
    rows = []
    meta = {"quick": QUICK, "repeats": REPEATS,
            "adjacency_build_s": {}, "speedups": {},
            "superstep_stats": {}, "sharded_stats": {}, "pipeline": {},
            "refine": {}, "streaming": {}, "device_loop": {}}

    # warm the Pallas interpret traces once (process-wide)
    import jax
    n_dev = len(jax.devices())
    warm = powerlaw_hypergraph(200, 150, seed=1)
    hype_batched_partition(warm, 4, BatchedParams(seed=0))
    hype_superstep_partition(warm, 4, SuperstepParams(seed=0))
    for d in SHARDED_DEVICES:
        if d <= n_dev:
            hype_sharded_partition(warm, 4,
                                   ShardedParams(seed=0, devices=d))

    for name in ("github", "stackoverflow", "reddit"):
        hg = dataset(name)
        t0 = time.perf_counter()
        hg.vertex_adjacency()
        meta["adjacency_build_s"][name] = round(
            time.perf_counter() - t0, 4)
        for k in KS:
            a, dt = _run(hype_partition, hg, k, HypeParams(seed=0))
            base = _row(name, hg, k, "hype", dt, a)
            rows.append(base)
            # streaming axis (DESIGN.md §4h): the one-pass engine vs
            # the offline base — km1 ratio must stay under the
            # documented STREAM_KM1_BOUND (compare_baseline gates it),
            # vertices/sec is the sustained-ingest headline
            (a_s, st_s), dt_s = _run(
                hype_stream_partition, hg, k,
                StreamParams(seed=0, micro_batch=STREAM_MB),
                return_stats=True)
            rec_s = _row(name, hg, k, "hype_stream", dt_s, a_s,
                         {"micro_batch": STREAM_MB,
                          "speedup_vs_hype": round(
                              base["runtime_s"] / max(dt_s, 1e-9), 2),
                          "km1_ratio_vs_hype": round(
                              rec_ratio(a_s, base, hg), 4)})
            rows.append(rec_s)
            meta["streaming"][f"{name}_k{k}"] = {
                "micro_batch": STREAM_MB,
                "micro_batches": st_s.micro_batches,
                "vertices_per_s": round(st_s.vertices_per_s),
                "host_to_device_bytes": st_s.host_to_device_bytes,
                "km1_ratio_vs_hype": rec_s["km1_ratio_vs_hype"],
            }
            batched_t8_s = None
            superstep_ref = None
            for t in TS:
                a, dt = _run(hype_batched_partition, hg, k,
                             BatchedParams(seed=0, t=t))
                if t == 8:
                    batched_t8_s = dt
                rec = _row(name, hg, k, f"hype_batched_t{t}", dt, a,
                           {"t": t,
                            "speedup_vs_hype": round(
                                base["runtime_s"] / max(dt, 1e-9), 2),
                            "km1_ratio_vs_hype": round(
                                rec_ratio(a, base, hg), 4)})
                rows.append(rec)
            for t in SUPERSTEP_TS:
                (a, stt), dt = _run(hype_superstep_partition, hg, k,
                                    SuperstepParams(seed=0, t=t),
                                    return_stats=True)
                rec = _row(name, hg, k, f"hype_superstep_t{t}", dt, a,
                           {"t": t,
                            "speedup_vs_hype": round(
                                base["runtime_s"] / max(dt, 1e-9), 2),
                            "speedup_vs_batched_t8": round(
                                batched_t8_s / max(dt, 1e-9), 2),
                            "km1_ratio_vs_hype": round(
                                rec_ratio(a, base, hg), 4)})
                rows.append(rec)
                # host->device traffic counters (from the last timed
                # run): the measurable part of the "device-resident
                # superstep" claim
                meta["superstep_stats"][f"{name}_k{k}_t{t}"] = {
                    "supersteps": stt.supersteps,
                    "kernel_rows": stt.kernel_rows,
                    "cache_hits": stt.cache_hits,
                    "cache_invalidations": stt.cache_invalidations,
                    "device_image_bytes": stt.device_image_bytes,
                    "host_to_device_bytes": stt.host_to_device_bytes,
                    "h2d_bytes_per_superstep": round(
                        stt.host_to_device_bytes
                        / max(stt.supersteps, 1)),
                    "host_s": round(stt.host_s, 4),
                    "device_s": round(stt.device_s, 4),
                    "pipeline_stalls": stt.pipeline_stalls,
                    "stale_redraws": stt.stale_redraws,
                }
                if k == SHARDED_K and t == SHARDED_T:
                    superstep_ref = (dt, metrics.k_minus_1(hg, a))
                # refinement axis: the acceptance row's refined sibling
                # (kway_refine post-passes; the km1_ratio_vs_hype of
                # these rows is the quality win compare_baseline gates)
                if k == REFINE_K and t == REFINE_T:
                    (ar, str_), dtr = _run(
                        hype_superstep_partition, hg, k,
                        SuperstepParams(seed=0, t=t,
                                        refine_passes=REFINE_PASSES),
                        return_stats=True)
                    rows.append(_row(
                        name, hg, k,
                        f"hype_superstep_t{t}_r{REFINE_PASSES}", dtr,
                        ar, {"t": t, "refine_passes": REFINE_PASSES,
                             "refined": True,
                             "speedup_vs_hype": round(
                                 base["runtime_s"] / max(dtr, 1e-9), 2),
                             "km1_ratio_vs_hype": round(
                                 rec_ratio(ar, base, hg), 4)}))
                    rs = str_.refine
                    meta["refine"][f"{name}_k{k}_t{t}"] = {
                        "refine_passes": REFINE_PASSES,
                        "passes_run": rs.passes_run,
                        "boundary_rows": rs.boundary_rows,
                        "kernel_calls": rs.kernel_calls,
                        "proposals": rs.proposals,
                        "moves": rs.moves,
                        "swaps": rs.swaps,
                        "gain": rs.gain,
                        "km1_before": rec["k_minus_1"],
                        "km1_after": metrics.k_minus_1(hg, ar),
                        "rejected_conflict": rs.rejected_conflict,
                        "rejected_balance": rs.rejected_balance,
                        "refine_s_overhead": round(
                            max(dtr - dt, 0.0), 4),
                    }
                # pipeline-depth axis: depth-1 (lock-step) vs the
                # default double-buffered engine on the acceptance row,
                # with the host/device wall-clock split of each
                if k == PIPELINE_K and t == PIPELINE_T:
                    (a1, st1), dt1 = _run(
                        hype_superstep_partition, hg, k,
                        SuperstepParams(seed=0, t=t, pipeline_depth=1),
                        return_stats=True)
                    km1_d1 = metrics.k_minus_1(hg, a1)
                    rows.append(_row(
                        name, hg, k, f"hype_superstep_t{t}_pd1", dt1,
                        a1, {"t": t, "pipeline_depth": 1,
                             "speedup_vs_hype": round(
                                 base["runtime_s"] / max(dt1, 1e-9), 2),
                             "km1_ratio_vs_hype": round(
                                 rec_ratio(a1, base, hg), 4)}))
                    # device-loop axis (DESIGN.md §4i): the megakernel
                    # engine vs the lock-step schedule it reproduces —
                    # bit-identical assignment, host time off the loop
                    (ad, std), dtd = _run(
                        hype_device_partition, hg, k,
                        DeviceParams(seed=0, t=t), return_stats=True)
                    rows.append(_row(
                        name, hg, k, f"hype_device_t{t}", dtd, ad,
                        {"t": t,
                         "speedup_vs_hype": round(
                             base["runtime_s"] / max(dtd, 1e-9), 2),
                         "speedup_vs_superstep_pd1": round(
                             dt1 / max(dtd, 1e-9), 2),
                         "km1_ratio_vs_hype": round(
                             rec_ratio(ad, base, hg), 4),
                         "km1_ratio_vs_superstep_pd1": round(
                             metrics.k_minus_1(hg, ad)
                             / max(km1_d1, 1), 4)}))
                    loop_total = std.host_s + std.device_s
                    meta["device_loop"][f"{name}_k{k}_t{t}"] = {
                        "runtime_s": round(dtd, 4),
                        "pd1_s": round(dt1, 4),
                        "speedup_vs_pd1": round(
                            dt1 / max(dtd, 1e-9), 3),
                        "host_s": round(std.host_s, 4),
                        "device_s": round(std.device_s, 4),
                        # the tentpole gate: host share of loop time
                        # must stay under 10% (compare_baseline fails
                        # above it)
                        "host_frac": round(
                            std.host_s / max(loop_total, 1e-9), 4),
                        "bit_identical_to_pd1": bool((ad == a1).all()),
                        "supersteps": std.supersteps,
                        "loop_chunks": std.loop_chunks,
                        "loop_rounds": std.loop_rounds,
                        "loop_pack_only": std.loop_pack_only,
                        "refill_signals": std.refill_signals,
                        "cache_hits": std.cache_hits,
                        "loop_store_peak": std.loop_store_peak,
                        "loop_state_bytes": std.loop_state_bytes,
                        "device_image_bytes": std.device_image_bytes,
                    }
                    meta["pipeline"][f"{name}_k{k}_t{t}"] = {
                        "depth1_s": round(dt1, 4),
                        "depth2_s": round(dt, 4),
                        "speedup_depth2_vs_depth1": round(
                            dt1 / max(dt, 1e-9), 3),
                        "km1_ratio_depth2_vs_depth1": round(
                            rec["k_minus_1"] / max(km1_d1, 1), 4),
                        "depth1_host_s": round(st1.host_s, 4),
                        "depth1_device_s": round(st1.device_s, 4),
                        "depth2_host_s": round(stt.host_s, 4),
                        "depth2_device_s": round(stt.device_s, 4),
                        "device_loop_s": round(dtd, 4),
                        "device_loop_host_s": round(std.host_s, 4),
                        "device_loop_host_frac": round(
                            std.host_s / max(loop_total, 1e-9), 4),
                        "depth2_stale_redraws": stt.stale_redraws,
                        "depth2_pipeline_stalls": stt.pipeline_stalls,
                        "supersteps_depth1": st1.supersteps,
                        "supersteps_depth2": stt.supersteps,
                    }
            # device-count scaling axis: the mesh-sharded engine at the
            # large k (CPU-simulated mesh; the row records architecture
            # metrics — collective traffic, conflicts — alongside time)
            if k == SHARDED_K and superstep_ref is not None:
                for d in SHARDED_DEVICES:
                    if d > n_dev:
                        continue
                    (a, stt), dt = _run(
                        hype_sharded_partition, hg, k,
                        ShardedParams(seed=0, t=SHARDED_T, devices=d),
                        return_stats=True)
                    km = metrics.k_minus_1(hg, a)
                    rec = _row(name, hg, k, f"hype_sharded_d{d}", dt, a,
                               {"t": SHARDED_T, "devices": d,
                                "speedup_vs_hype": round(
                                    base["runtime_s"] / max(dt, 1e-9),
                                    2),
                                "km1_ratio_vs_hype": round(
                                    rec_ratio(a, base, hg), 4),
                                "km1_ratio_vs_superstep": round(
                                    km / max(superstep_ref[1], 1), 4)})
                    rows.append(rec)
                    meta["sharded_stats"][f"{name}_k{k}_d{d}"] = {
                        "supersteps": stt.supersteps,
                        "host_s": round(stt.host_s, 4),
                        "device_s": round(stt.device_s, 4),
                        "stale_redraws": stt.stale_redraws,
                        "collectives": stt.collectives,
                        "collective_bytes": stt.collective_bytes,
                        "collective_bytes_per_superstep": round(
                            stt.collective_bytes
                            / max(stt.collectives, 1)),
                        "admission_conflicts": stt.admission_conflicts,
                        "cache_invalidations": stt.cache_invalidations,
                        "device_image_bytes": stt.device_image_bytes,
                        "host_to_device_bytes": stt.host_to_device_bytes,
                        "runtime_vs_superstep_t16": round(
                            dt / max(superstep_ref[0], 1e-9), 3),
                    }

    # resilience axis (DESIGN.md §4f): what fault tolerance costs on
    # the acceptance-row superstep config — snapshot publish overhead,
    # kill + resume restore cost, and chaos (injected-fault) recovery
    # overhead, each pinned against the fault-free run's quality.
    import tempfile

    from repro.core import resilience

    hg_r = dataset("github")
    res_meta = {}
    (a_plain, _), dt_plain = _run(
        hype_superstep_partition, hg_r, PIPELINE_K,
        SuperstepParams(seed=0, t=PIPELINE_T), return_stats=True)
    km1_plain = metrics.k_minus_1(hg_r, a_plain)
    with tempfile.TemporaryDirectory() as snapdir:
        (a_snap, st_snap), dt_snap = _run(
            hype_superstep_partition, hg_r, PIPELINE_K,
            SuperstepParams(seed=0, t=PIPELINE_T, snapshot_every=4,
                            snapshot_dir=snapdir), return_stats=True)
        res_meta["snapshot"] = {
            "snapshot_every": 4,
            "snapshots": st_snap.snapshots,
            "snapshot_s": round(st_snap.snapshot_s, 4),
            "overhead_s": round(max(dt_snap - dt_plain, 0.0), 4),
            "overhead_frac": round(
                max(dt_snap - dt_plain, 0.0) / max(dt_plain, 1e-9), 3),
            "km1_vs_plain": round(
                metrics.k_minus_1(hg_r, a_snap) / max(km1_plain, 1), 4),
        }
        km1_snap = metrics.k_minus_1(hg_r, a_snap)
    with tempfile.TemporaryDirectory() as snapdir:
        kill_step = max(2, st_snap.supersteps // 2)
        try:
            hype_superstep_partition(hg_r, PIPELINE_K, SuperstepParams(
                seed=0, t=PIPELINE_T, snapshot_every=4,
                snapshot_dir=snapdir,
                fault_plan=f"dispatch@{kill_step}:fatal"))
            killed = False
        except resilience.UnrecoverableFault:
            killed = True
        if killed:
            t0 = time.perf_counter()
            a_res, st_res = hype_superstep_partition(
                hg_r, PIPELINE_K, SuperstepParams(
                    seed=0, t=PIPELINE_T, snapshot_every=4,
                    snapshot_dir=snapdir, resume=snapdir),
                return_stats=True)
            res_meta["kill_resume"] = {
                "killed_at_superstep": kill_step,
                "resumed_at": st_res.resumed_at,
                "restore_s": round(st_res.restore_s, 4),
                "resume_wall_s": round(time.perf_counter() - t0, 4),
                # bit-exact resume => equal quality to the same-cadence
                # uninterrupted run (the gated invariant)
                "km1_equal_to_uninterrupted":
                    metrics.k_minus_1(hg_r, a_res) == km1_snap,
            }
    (a_chaos, st_chaos), dt_chaos = _run(
        hype_superstep_partition, hg_r, PIPELINE_K,
        SuperstepParams(seed=0, t=PIPELINE_T,
                        fault_plan="dispatch@2;nan@4"),
        return_stats=True)
    res_meta["chaos"] = {
        "fault_plan": "dispatch@2;nan@4",
        "faults_injected": st_chaos.faults_injected,
        "retries": st_chaos.retries,
        "recovery_overhead_s": round(max(dt_chaos - dt_plain, 0.0), 4),
        "km1_equal_to_fault_free":
            metrics.k_minus_1(hg_r, a_chaos) == km1_plain,
    }
    meta["resilience"] = res_meta

    # memory axis (DESIGN.md §4g): the budget planner + rung ladder on
    # the acceptance-row superstep config, at pipeline_depth=1 so every
    # rung is bit-comparable. Three rows: unconstrained (rung 0), a
    # budget one byte under rung 0's plan (forces >= 1 re-tiling rung),
    # and a budget below the CSR image (forces the paged adjacency).
    # The gated invariants: rung runs keep km1 EQUAL to unconstrained
    # and paging overhead stays bounded vs the resident-image runtime.
    mem_meta = {}
    hg_m = dataset("github")
    (a_m0, st_m0), dt_m0 = _run(
        hype_superstep_partition, hg_m, PIPELINE_K,
        SuperstepParams(seed=0, t=PIPELINE_T, pipeline_depth=1),
        return_stats=True)
    km1_m0 = metrics.k_minus_1(hg_m, a_m0)
    mem_meta["unconstrained"] = {
        "plan_rung": st_m0.plan_rung,
        "peak_bytes_planned": st_m0.peak_bytes_planned,
        "peak_bytes_observed": st_m0.peak_bytes_observed,
        "runtime_s": round(dt_m0, 4),
    }
    tight = int(st_m0.peak_bytes_planned) - 1
    (a_mr, st_mr), dt_mr = _run(
        hype_superstep_partition, hg_m, PIPELINE_K,
        SuperstepParams(seed=0, t=PIPELINE_T, pipeline_depth=1,
                        mem_budget=tight), return_stats=True)
    mem_meta["forced_rung"] = {
        "mem_budget": tight,
        "plan_rung": st_mr.plan_rung,
        "mem_retries": st_mr.mem_retries,
        "peak_bytes_planned": st_mr.peak_bytes_planned,
        "peak_bytes_observed": st_mr.peak_bytes_observed,
        "runtime_s": round(dt_mr, 4),
        "overhead_vs_unconstrained": round(dt_mr / max(dt_m0, 1e-9), 3),
        "km1_equal_to_unconstrained":
            metrics.k_minus_1(hg_m, a_mr) == km1_m0,
    }
    (a_mp, st_mp), dt_mp = _run(
        hype_superstep_partition, hg_m, PIPELINE_K,
        SuperstepParams(seed=0, t=PIPELINE_T, pipeline_depth=1,
                        mem_budget="6.4MB"), return_stats=True)
    mem_meta["paged"] = {
        "mem_budget": "6.4MB",
        "plan_rung": st_mp.plan_rung,
        "peak_bytes_planned": st_mp.peak_bytes_planned,
        "peak_bytes_observed": st_mp.peak_bytes_observed,
        "page_uploads": st_mp.page_uploads,
        "page_hits": st_mp.page_hits,
        "page_evictions": st_mp.page_evictions,
        "page_bytes": st_mp.page_bytes,
        "runtime_s": round(dt_mp, 4),
        # the ISSUE-7 acceptance bound: <= 1.5x resident at quick scale
        "paging_overhead_vs_resident": round(
            dt_mp / max(dt_m0, 1e-9), 3),
        "km1_equal_to_unconstrained":
            metrics.k_minus_1(hg_m, a_mp) == km1_m0,
    }
    meta["memory"] = mem_meta

    # streaming update-throughput axis (DESIGN.md §4h): replay a mixed
    # insert/delete op log through apply_updates on a live stream state
    # — updates/sec sustained is the incremental-maintenance headline,
    # and the exact-decrement invariant is re-checked after the replay
    from repro.core.hype_stream import recompute_sketch

    hg_s = dataset("github")
    _, state = hype_stream_partition(
        hg_s, PIPELINE_K, StreamParams(seed=0, micro_batch=STREAM_MB),
        return_state=True)
    rng = np.random.default_rng(7)
    ops = []
    for i in range(STREAM_OPS):
        kind = i % 4
        if kind == 0:
            ops.append(("remove_vertex", int(rng.integers(0, hg_s.n))))
        elif kind == 1:
            ops.append(("remove_edge", int(rng.integers(0, hg_s.m))))
        elif kind == 2:
            pins = rng.integers(0, hg_s.n, size=4)
            ops.append(("add_edge", sorted({int(x) for x in pins})))
        else:
            es = rng.integers(0, hg_s.m, size=3)
            ops.append(("add_vertex", sorted({int(x) for x in es})))
    t0 = time.perf_counter()
    apply_updates(state, ops)
    dt_u = time.perf_counter() - t0
    sk, sz = recompute_sketch(state.hg, state.assignment, PIPELINE_K,
                              state.params.sketch_bits)
    meta["streaming"]["updates"] = {
        "dataset": "github", "k": PIPELINE_K, "ops": len(ops),
        "updates_per_s": round(len(ops) / max(dt_u, 1e-9)),
        "readmitted": state.stats.readmitted,
        "refine_moves": state.stats.refine_moves,
        "rebalance_moves": state.stats.rebalance_moves,
        "sketch_invariant_exact": bool(
            (sk == state.sketch).all() and (sz == state.sizes).all()),
    }
    emit(f"engine/github/k{PIPELINE_K}/hype_stream_updates",
         dt_u * 1e6 / max(len(ops), 1),
         f"updates_per_s={meta['streaming']['updates']['updates_per_s']}")

    # small-n row including the jittable engines (validation scale)
    from repro.core.hype_jax import (hype_jax_partition,
                                     hype_parallel_partition)
    hg = powerlaw_hypergraph(JAX_N, 200, seed=3, max_edge=20,
                             max_degree=12)
    for engine, fn in (("hype", lambda: hype_partition(
            hg, 8, HypeParams(seed=0))),
            ("hype_batched_t8", lambda: hype_batched_partition(
                hg, 8, BatchedParams(seed=0))),
            ("hype_jax", lambda: hype_jax_partition(hg, 8, seed=0)),
            ("hype_parallel", lambda: hype_parallel_partition(
                hg, 8, seed=0))):
        a, dt = _run(fn)
        rows.append(_row("powerlaw_small", hg, 8, engine, dt, a))

    # headline acceptance numbers: reddit @ k=32
    for r in rows:
        if r["dataset"] == "reddit" and r["k"] == 32 \
                and (r["engine"].startswith("hype_batched")
                     or r["engine"].startswith("hype_superstep")
                     or r["engine"].startswith("hype_sharded")
                     or r["engine"].startswith("hype_device")):
            head = {
                "speedup_vs_hype": r["speedup_vs_hype"],
                "km1_ratio_vs_hype": r["km1_ratio_vs_hype"],
            }
            if "speedup_vs_batched_t8" in r:
                head["speedup_vs_batched_t8"] = r["speedup_vs_batched_t8"]
            if "km1_ratio_vs_superstep" in r:
                head["km1_ratio_vs_superstep"] = r["km1_ratio_vs_superstep"]
            if "speedup_vs_superstep_pd1" in r:
                head["speedup_vs_superstep_pd1"] = \
                    r["speedup_vs_superstep_pd1"]
                head["km1_ratio_vs_superstep_pd1"] = \
                    r["km1_ratio_vs_superstep_pd1"]
            if r.get("refined"):
                head["refined"] = True      # compare_baseline km1 gate
            meta["speedups"][f"reddit_k32_{r['engine']}"] = head

    payload = {"meta": meta, "rows": rows}
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {os.path.abspath(OUT_PATH)} ({len(rows)} rows)",
          flush=True)
    return payload


def rec_ratio(assignment, base, hg):
    km = metrics.k_minus_1(hg, assignment)
    return km / max(base["k_minus_1"], 1)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
