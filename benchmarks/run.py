"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # quick mode
    BENCH_FULL=1 PYTHONPATH=src python -m benchmarks.run

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
Mapping to the paper:
  bench_partition_quality  -> Fig 7, 8, 9 (quality/runtime/balance vs k)
  bench_ablations          -> Fig 3 (s), Fig 5 (r), Fig 6 (cache)
  bench_reddit_scale       -> Fig 10 + runtime-vs-k claims
  bench_beyond_paper       -> §VI future work + HYPE-driven placement
  bench_kernels            -> Pallas kernel oracles
  bench_engine_scaling     -> engines x (n, k, t) -> BENCH_engines.json
  roofline_table           -> EXPERIMENTS.md §Roofline source
"""
from __future__ import annotations

import time


def main() -> None:
    t0 = time.time()
    from . import (bench_ablations, bench_beyond_paper,
                   bench_engine_scaling, bench_kernels,
                   bench_partition_quality, bench_reddit_scale,
                   roofline_table)
    print("name,us_per_call,derived")
    bench_partition_quality.run()
    bench_ablations.run()
    bench_reddit_scale.run()
    bench_beyond_paper.run()
    bench_kernels.run()
    bench_engine_scaling.run()
    roofline_table.run()
    print(f"\n# total benchmark wall time: {time.time() - t0:.1f}s",
          flush=True)


if __name__ == "__main__":
    main()
