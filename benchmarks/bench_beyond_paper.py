"""Beyond-paper benchmarks:

1. parallel k-way growth (paper §VI future work) — quality + collisions
2. HYPE-driven placement vs hash/random: halo-exchange volume for
   distributed GNN aggregation and remote-lookup fraction for distributed
   embedding tables (the collective-term reduction used in §Perf).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import metrics
from repro.core.hype import HypeParams, hype_partition
from repro.core.hype_jax import hype_parallel_partition
from repro.core.minmax import random_partition
from repro.data.synthetic import powerlaw_hypergraph
from repro.placement.partitioned_gnn import (build_partitioned_graph,
                                        graph_to_hypergraph)

from .common import emit


def run_parallel_growth(n=3000, m=2000, k=16):
    hg = powerlaw_hypergraph(n, m, seed=4, max_edge=60, max_degree=24)
    t0 = time.perf_counter()
    a_seq = hype_partition(hg, k, HypeParams(seed=0))
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    a_par = hype_parallel_partition(hg, k, seed=0)
    t_par = time.perf_counter() - t0
    emit("beyond/parallel_growth/seq", t_seq * 1e6,
         f"km1={metrics.k_minus_1(hg, a_seq)}")
    emit("beyond/parallel_growth/par", t_par * 1e6,
         f"km1={metrics.k_minus_1(hg, a_par)};"
         f"imb={metrics.vertex_imbalance(a_par, k):.3f}")


def run_placement_traffic(n=4000, avg_deg=8, k=8):
    """Collective-volume proxy: all-to-all payload k*s_max*d bytes."""
    rng = np.random.default_rng(0)
    # community-structured graph (ring locality) — the regime the paper's
    # technique targets
    src = rng.integers(0, n, n * avg_deg)
    offs = rng.integers(1, 40, n * avg_deg)
    dst = (src + offs) % n
    hg = graph_to_hypergraph(n, src, dst)
    d_feat = 128
    for name, asg in (
        ("hype", hype_partition(hg, k, HypeParams(seed=0))),
        ("random", random_partition(hg, k, seed=0)),
    ):
        pg = build_partitioned_graph(n, src, dst, asg, k)
        bytes_a2a = k * pg.s_max * d_feat * 4
        emit(f"beyond/placement/{name}", 0.0,
             f"s_max={pg.s_max};exchanged={pg.stats['exchanged_rows']};"
             f"a2a_bytes_per_dev={bytes_a2a};"
             f"remote_edge_frac={pg.stats['remote_edge_frac']:.3f}")


def run_embedding_placement(vocab=8192, n_queries=4000, bag=16, k=8):
    """Shards-touched / remote fraction under affinity routing (each
    query served by the shard owning most of its rows): HYPE vs hash."""
    from repro.placement.partitioned_embedding import (RowPlacement,
                                                  partition_rows_hype)
    rng = np.random.default_rng(0)
    # co-access pattern with popularity skew and correlated rows
    centers = rng.integers(0, vocab, n_queries)
    queries = [np.unique((centers[i] + rng.geometric(0.05, bag)) % vocab)
               for i in range(n_queries)]
    asg_h = partition_rows_hype(vocab, queries, k, seed=0)
    asg_r = (np.arange(vocab) * 2654435761 % vocab % k).astype(np.int32)
    for name, asg in (("hype", asg_h), ("hash", asg_r)):
        pl = RowPlacement.from_assignment(asg, k)
        touched, remote = [], []
        for i in range(n_queries):
            counts = np.bincount(pl.owner[queries[i]], minlength=k)
            touched.append(int((counts > 0).sum()))
            remote.append(1.0 - counts.max() / max(counts.sum(), 1))
        emit(f"beyond/embedding_placement/{name}", 0.0,
             f"shards_touched={np.mean(touched):.2f};"
             f"remote_frac={np.mean(remote):.3f}")


def run():
    run_parallel_growth()
    run_placement_traffic()
    run_embedding_placement()


if __name__ == "__main__":
    run()
