"""Shared benchmark utilities: timing, CSV output, dataset cache."""
from __future__ import annotations

import functools
import os
import time

import numpy as np

from repro.kernels._compat import enable_compile_cache

QUICK = os.environ.get("BENCH_FULL", "0") != "1"

# Opt into JAX's persistent compilation cache (REPRO_COMPILE_CACHE=dir)
# before any benchmark traces a program: repeat runs then skip the XLA
# compile entirely, which keeps quick-mode timings about the engines
# rather than about tracing. No-op when the knob is unset.
COMPILE_CACHE_DIR = enable_compile_cache()

# dataset scales: quick mode keeps the full suite ~ minutes on CPU;
# BENCH_FULL=1 runs the paper-scale graphs (github full scale).
GITHUB_SCALE = 1.0 if not QUICK else 0.25
STACKOVERFLOW_SCALE = 1.0 if not QUICK else 0.06
REDDIT_SCALE = 0.02 if not QUICK else 0.01

_ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    _ROWS.append(row)
    print(row, flush=True)


def timed(fn, *args, repeats: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt


@functools.lru_cache(maxsize=8)
def dataset(name: str, seed: int = 0):
    from repro.data.synthetic import (github_like, reddit_like,
                                      stackoverflow_like)
    if name == "github":
        return github_like(scale=GITHUB_SCALE, seed=seed)
    if name == "stackoverflow":
        return stackoverflow_like(scale=STACKOVERFLOW_SCALE, seed=seed)
    if name == "reddit":
        return reddit_like(scale=REDDIT_SCALE, seed=seed)
    raise ValueError(name)


def all_rows():
    return list(_ROWS)
