"""Guard the engine perf trajectory: fail CI on >25% speedup regression.

    python benchmarks/compare_baseline.py BASELINE.json CURRENT.json

Compares every ``speedup_vs_hype`` entry in the two files' ``meta``
blocks (``meta["speedups"]``, written by ``bench_engine_scaling``). A
row present in both that lost more than ``MAX_REGRESSION`` of its
baseline speedup fails the check; rows that only exist on one side are
reported but never fail (engines come and go between PRs). Quality is
guarded twice:

* a row whose ``km1_ratio_vs_hype`` newly exceeds the 1.10 acceptance
  bound fails;
* a **refined** row (``"refined": true`` — the ``refine_passes`` post-
  pass rows) whose ``km1_ratio_vs_hype`` regressed by more than
  ``KM1_REFINED_TOL`` (2%) over its baseline fails, so the quality the
  refinement subsystem bought stays *enforced*, not just measured.

The streaming engine has its own gate (``check_streaming``): every
``meta["streaming"]`` row of the *current* run with a
``km1_ratio_vs_hype`` must stay under ``STREAM_KM1_BOUND`` (the
documented one-pass bound of DESIGN.md §4h — a single pass is allowed
to trail offline quality, but boundedly), and the update-throughput row
must report an exact sketch invariant. Absolute, not baseline-relative:
the bound holds from the first run that has streaming rows.

The device-resident loop (DESIGN.md §4i) adds ``check_device_loop``:
every ``meta["device_loop"]`` row of the current run must be
bit-identical to the lock-step pd1 schedule it reproduces
(``bit_identical_to_pd1``) and keep the host's share of loop time under
``HOST_FRAC_BOUND`` — the tentpole claim, enforced per run. Its
``reddit_k32_hype_device_*`` speedup/km1 rows ride the regular
baseline-relative gates above through ``meta["speedups"]``.

Pure stdlib — runnable before dependencies are installed.
"""
from __future__ import annotations

import json
import sys

MAX_REGRESSION = 0.25      # fraction of baseline speedup a row may lose
KM1_BOUND = 1.10           # quality acceptance bound (ISSUE 2)
KM1_REFINED_TOL = 0.02     # max relative km1 regression on refined rows
STREAM_KM1_BOUND = 2.0     # one-pass bound; = core.hype_stream's constant
HOST_FRAC_BOUND = 0.10     # §4i: host share of device-loop wall time
KM1_DEVICE_TOL = 0.02      # device row vs pd1 quality tolerance (ISSUE 9)


def load_speedups(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    return payload.get("meta", {}).get("speedups", {})


def load_streaming(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    return payload.get("meta", {}).get("streaming", {})


def load_device_loop(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    return payload.get("meta", {}).get("device_loop", {})


def check_device_loop(dev: dict, speedups: dict | None = None) -> int:
    """Absolute gates on the current run's §4i device-loop rows."""
    failures = []
    for key in sorted(speedups or {}):
        if "hype_device" not in key:
            continue
        ratio = float(speedups[key].get("km1_ratio_vs_superstep_pd1",
                                        1.0))
        if ratio > 1.0 + KM1_DEVICE_TOL:
            failures.append(
                f"{key}: km1_ratio_vs_superstep_pd1 {ratio} > "
                f"{1.0 + KM1_DEVICE_TOL} (device quality drifted from "
                "the schedule it claims to reproduce)")
    for key in sorted(dev):
        row = dev[key]
        status = "ok"
        if not row.get("bit_identical_to_pd1", True):
            status = "PARITY"
            failures.append(
                f"device_loop {key}: assignment diverged from the "
                "lock-step pd1 schedule (bit_identical_to_pd1 false)")
        frac = float(row.get("host_frac", 0.0))
        if frac > HOST_FRAC_BOUND:
            status = "HOST_FRAC"
            failures.append(
                f"device_loop {key}: host_frac {frac} > "
                f"{HOST_FRAC_BOUND} — the host crept back onto the loop")
        print(f"    device_loop {key}: host_frac {frac}  "
              f"speedup_vs_pd1 {row.get('speedup_vs_pd1', '-')}x  "
              f"[{status}]")
    if failures:
        print("\nFAIL: device-loop gate:")
        for f in failures:
            print(f"  {f}")
        return 1
    return 0


def check_streaming(streaming: dict) -> int:
    """Absolute quality gate on the current run's streaming rows."""
    failures = []
    for key in sorted(streaming):
        row = streaming[key]
        if "km1_ratio_vs_hype" in row:
            ratio = float(row["km1_ratio_vs_hype"])
            status = "ok"
            if ratio > STREAM_KM1_BOUND:
                status = "QUALITY"
                failures.append(
                    f"streaming {key}: km1_ratio_vs_hype {ratio} > "
                    f"one-pass bound {STREAM_KM1_BOUND}")
            print(f"    streaming {key}: km1 {ratio}  "
                  f"v/s {row.get('vertices_per_s', '-')}  [{status}]")
        if "sketch_invariant_exact" in row \
                and not row["sketch_invariant_exact"]:
            failures.append(
                f"streaming {key}: sketch invariant broke during the "
                "update-throughput replay")
    if failures:
        print("\nFAIL: streaming gate:")
        for f in failures:
            print(f"  {f}")
        return 1
    return 0


def compare(base: dict, cur: dict) -> int:
    failures = []
    if not set(base) & set(cur):
        # every baseline row vanished: a rename or a broken meta writer
        # would otherwise make the gate silently vacuous
        print("FAIL: no speedup row of the baseline exists in the "
              "current run — the regression gate compared nothing "
              f"(baseline keys: {sorted(base)}; current: {sorted(cur)})")
        return 1
    for key in sorted(set(base) | set(cur)):
        if key not in cur:
            print(f"  - {key}: only in baseline (row removed)")
            continue
        if key not in base:
            print(f"  + {key}: new row "
                  f"(speedup {cur[key]['speedup_vs_hype']}x)")
            continue
        b = float(base[key]["speedup_vs_hype"])
        c = float(cur[key]["speedup_vs_hype"])
        ratio = c / b if b > 0 else 1.0
        status = "ok"
        if ratio < 1.0 - MAX_REGRESSION:
            status = "REGRESSION"
            failures.append(
                f"{key}: speedup {b}x -> {c}x "
                f"({(1.0 - ratio) * 100:.0f}% lost, limit "
                f"{MAX_REGRESSION * 100:.0f}%)")
        km_b = float(base[key].get("km1_ratio_vs_hype", 0.0))
        km_c = float(cur[key].get("km1_ratio_vs_hype", 0.0))
        if km_c > KM1_BOUND >= km_b:
            status = "QUALITY"
            failures.append(
                f"{key}: km1_ratio_vs_hype {km_b} -> {km_c} "
                f"(crossed the {KM1_BOUND} bound)")
        refined = bool(base[key].get("refined")
                       or cur[key].get("refined"))
        if refined and km_b > 0 \
                and km_c > km_b * (1.0 + KM1_REFINED_TOL):
            status = "QUALITY"
            failures.append(
                f"{key}: refined-row km1_ratio_vs_hype {km_b} -> {km_c} "
                f"(> {KM1_REFINED_TOL * 100:.0f}% quality regression)")
        print(f"    {key}: {b}x -> {c}x  km1 {km_b} -> {km_c}  [{status}]")
    if failures:
        print("\nFAIL: perf trajectory regressed:")
        for f in failures:
            print(f"  {f}")
        return 1
    print("\nOK: no speedup regression beyond "
          f"{MAX_REGRESSION * 100:.0f}% and no quality-bound crossing")
    return 0


def main(argv) -> int:
    if len(argv) != 3:
        print(__doc__)
        return 2
    base = load_speedups(argv[1])
    cur = load_speedups(argv[2])
    stream_rc = check_streaming(load_streaming(argv[2]))
    dev_rc = check_device_loop(load_device_loop(argv[2]), cur)
    if not base:
        print("baseline has no meta.speedups — nothing to compare; "
              + ("OK" if stream_rc == 0 and dev_rc == 0
                 else "absolute gates FAILED"))
        return stream_rc or dev_rc
    return compare(base, cur) or stream_rc or dev_rc


if __name__ == "__main__":
    sys.exit(main(sys.argv))
