"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode, so
wall-times are NOT TPU-indicative; we report (a) correctness deltas vs
the jnp oracle and (b) the oracle's XLA-CPU time as the reference number.
The derived column carries the analytic FLOPs of the call so the roofline
table can place each kernel.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hype_score.ops import hype_scores
from repro.kernels.hype_score.ref import hype_scores_ref
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.neighbor_agg.ops import neighbor_agg
from repro.kernels.neighbor_agg.ref import neighbor_agg_ref

from .common import emit, timed


def run():
    rng = np.random.default_rng(0)

    # flash attention
    B, S, H, D = 1, 512, 4, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    ref, t_ref = timed(lambda: jax.block_until_ready(
        attention_ref(q, k, v)), repeats=3)
    out = flash_attention(q, k, v)
    err = float(jnp.abs(out - ref).max())
    flops = 4 * B * H * S * S * D
    emit("kernel/flash_attention/ref_xla", t_ref * 1e6,
         f"maxerr={err:.2e};flops={flops}")

    # hype_score
    nbrs = jnp.asarray(rng.integers(-1, 10_000, size=(4096, 64)), jnp.int32)
    fringe = jnp.asarray(rng.choice(10_000, 10, replace=False), jnp.int32)
    ref2, t2 = timed(lambda: jax.block_until_ready(
        hype_scores_ref(nbrs, fringe)), repeats=5)
    out2 = hype_scores(nbrs, fringe)
    emit("kernel/hype_score/ref_xla", t2 * 1e6,
         f"exact={bool((out2 == ref2).all())};cmp={4096 * 64 * 10}")

    # embedding bag
    table = jnp.asarray(rng.normal(size=(65536, 128)), jnp.float32)
    ids = jnp.asarray(rng.integers(-1, 65536, size=(1024, 8)), jnp.int32)
    ref3, t3 = timed(lambda: jax.block_until_ready(
        embedding_bag_ref(table, ids)), repeats=5)
    out3 = embedding_bag(table, ids)
    emit("kernel/embedding_bag/ref_xla", t3 * 1e6,
         f"maxerr={float(jnp.abs(out3 - ref3).max()):.2e};"
         f"rows={1024 * 8}")

    # neighbor agg
    x = jnp.asarray(rng.normal(size=(4096, 128)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(128, 128)) * 0.1, jnp.float32)
    nb = jnp.asarray(rng.integers(-1, 4096, size=(512, 15)), jnp.int32)
    ref4, t4 = timed(lambda: jax.block_until_ready(
        neighbor_agg_ref(x, nb, w)), repeats=5)
    out4 = neighbor_agg(x, nb, w)
    emit("kernel/neighbor_agg/ref_xla", t4 * 1e6,
         f"maxerr={float(jnp.abs(out4 - ref4).max()):.2e};"
         f"flops={512 * 128 * 128 * 2}")


if __name__ == "__main__":
    run()
