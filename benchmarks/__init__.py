"""Benchmark package init: simulate a multi-device CPU mesh.

Must run before anything imports jax (``python -m benchmarks.run``
imports this first), so the engine-scaling sweep can exercise the
mesh-sharded engine's device-count axis on CPU. No-op when the flag is
already set or when jax was imported earlier in the process — the
sharded engine then clamps to however many devices exist.
"""
import os

_FLAG = "--xla_force_host_platform_device_count=4"
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (_flags + " " + _FLAG).strip()
