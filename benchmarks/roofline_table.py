"""Render the §Roofline table from artifacts/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os

ART = "artifacts/dryrun"


def load_records(mesh="single"):
    recs = []
    for p in sorted(glob.glob(os.path.join(ART, f"*__{mesh}.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def render(mesh="single") -> str:
    """Re-derives the roofline from the stored per-device costs so that
    MODEL_FLOPS refinements apply without recompiling."""
    from repro.launch.roofline import model_flops, roofline_report
    rows = [
        "| arch | shape | compute_s | memory_s | collective_s | bound | "
        "useful_ratio | roofline_frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    n_dev = 512 if mesh == "multi" else 256
    for r in load_records(mesh):
        if r.get("skipped"):
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"SKIP | — | — |")
            continue
        c = r["cost_per_device"]
        mf = model_flops(r["arch"], r["shape"], r.get("meta", {}))
        rf = roofline_report(
            flops_per_device=c["flops"], bytes_per_device=c["bytes"],
            collective_wire_bytes=c["wire"], n_devices=n_dev,
            model_flops_global=mf)
        ur = rf.get("useful_flops_ratio")
        fr = rf.get("roofline_fraction")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4f} | "
            f"{rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
            f"{rf['bound']} | "
            + (f"{ur:.3f}" if ur is not None else "—") + " | "
            + (f"{fr:.4f}" if fr is not None else "—") + " |")
    return "\n".join(rows)


def run():
    for mesh in ("single", "multi"):
        recs = load_records(mesh)
        if recs:
            print(f"\n## Roofline ({mesh}-pod mesh)\n")
            print(render(mesh))


if __name__ == "__main__":
    run()
