# The paper's primary contribution: HYPE hypergraph partitioning.
#   hypergraph.py    — dual-CSR hypergraph structure + flip trick
#   hype.py          — faithful Alg. 1-3 engine (s/r/caching opts)
#   hype_jax.py      — jittable JAX engine + parallel k-way growth
#   hype_batched.py  — batched / superstep / mesh-sharded engines
#   scoring.py       — shared batched d_ext scoring + device programs
#   minmax.py        — streaming MinMax EB/NB baseline (NIPS'15)
#   shp.py           — Social-Hash-style swap baseline (VLDB'17)
#   multilevel.py    — mini-hMETIS (coarsen/bisect/FM) baseline
#   metrics.py       — (k-1), cut, SOED, imbalance, replication
#   partition_api.py — unified partition(hg, k, method) entry point
