"""Partitioning quality metrics (paper §IV).

All metrics are fully vectorized over the pin arrays, so they run in
O(n_pins log n_pins) and scale to hundreds of millions of pins.
"""
from __future__ import annotations

import numpy as np

from .hypergraph import Hypergraph


def _edge_partition_pairs(hg: Hypergraph, assignment: np.ndarray):
    """Unique (edge, partition) pairs over all pins."""
    edge_of_pin = np.repeat(np.arange(hg.m, dtype=np.int64), hg.edge_sizes)
    part_of_pin = assignment[hg.e2v_indices].astype(np.int64)
    if np.any(part_of_pin < 0):
        raise ValueError("metrics require a complete assignment")
    key = edge_of_pin * np.int64(assignment.max() + 2) + part_of_pin
    uniq_key = np.unique(key)
    uniq_edges = uniq_key // np.int64(assignment.max() + 2)
    return uniq_edges


def spans_per_edge(hg: Hypergraph, assignment: np.ndarray) -> np.ndarray:
    """For each hyperedge, the number of distinct partitions it spans."""
    uniq_edges = _edge_partition_pairs(hg, assignment)
    spans = np.zeros(hg.m, dtype=np.int64)
    np.add.at(spans, uniq_edges, 1)
    return spans


def k_minus_1(hg: Hypergraph, assignment: np.ndarray) -> int:
    """The (k-1) metric: sum over hyperedges of (#partitions spanned - 1).

    This is the paper's primary quality objective (§II). Empty hyperedges
    (size 0) contribute 0.
    """
    spans = spans_per_edge(hg, assignment)
    nonempty = hg.edge_sizes > 0
    return int(np.sum(spans[nonempty] - 1))


def hyperedge_cut(hg: Hypergraph, assignment: np.ndarray) -> int:
    """Number of hyperedges spanning more than one partition."""
    return int(np.sum(spans_per_edge(hg, assignment) > 1))


def sum_external_degree(hg: Hypergraph, assignment: np.ndarray) -> int:
    """SOED: sum of spans over cut hyperedges."""
    spans = spans_per_edge(hg, assignment)
    return int(np.sum(spans[spans > 1]))


def partition_sizes(assignment: np.ndarray, k: int) -> np.ndarray:
    sizes = np.zeros(k, dtype=np.int64)
    np.add.at(sizes, assignment.astype(np.int64), 1)
    return sizes


def vertex_imbalance(assignment: np.ndarray, k: int) -> float:
    """(maxsize - minsize) / maxsize, the paper's fairness metric (§IV)."""
    sizes = partition_sizes(assignment, k)
    mx = sizes.max()
    return float((mx - sizes.min()) / mx) if mx > 0 else 0.0


def replication_factor(hg: Hypergraph, assignment: np.ndarray) -> float:
    """Average #partitions spanned per hyperedge.

    Directly proportional to the halo/communication volume of a
    vertex-partitioned distributed computation over the hypergraph.
    """
    spans = spans_per_edge(hg, assignment)
    nonempty = hg.edge_sizes > 0
    return float(spans[nonempty].mean()) if nonempty.any() else 0.0


def all_metrics(hg: Hypergraph, assignment: np.ndarray, k: int) -> dict:
    spans = spans_per_edge(hg, assignment)
    nonempty = hg.edge_sizes > 0
    return {
        "k_minus_1": int(np.sum(spans[nonempty] - 1)),
        "hyperedge_cut": int(np.sum(spans > 1)),
        "soed": int(np.sum(spans[spans > 1])),
        "vertex_imbalance": vertex_imbalance(assignment, k),
        "replication_factor": float(spans[nonempty].mean()) if nonempty.any() else 0.0,
    }
