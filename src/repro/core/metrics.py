"""Partitioning quality metrics (paper §IV).

All metrics are fully vectorized over the pin arrays, so they run in
O(n_pins log n_pins) and scale to hundreds of millions of pins.

Every spans-derived metric takes an optional explicit ``k`` (the keying
for the (edge, partition) dedup; defaulting to ``assignment.max() + 1``
is only correct when the top partition happens to be occupied) and an
optional precomputed ``spans`` array — ``spans_per_edge`` is a full
pin-array sort/unique, so a report that needs several metrics should
compute it once and share it (``all_metrics`` does).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .hypergraph import Hypergraph


def _edge_partition_pairs(hg: Hypergraph, assignment: np.ndarray,
                          k: Optional[int] = None):
    """Unique (edge, partition) pairs over all pins (edge ids only).

    Keys on the explicit partition count ``k`` so the same assignment
    always hashes identically, no matter which partitions happen to be
    occupied (the old keying used ``assignment.max() + 2``).
    """
    part_of_pin = assignment[hg.e2v_indices].astype(np.int64)
    if np.any(part_of_pin < 0):
        raise ValueError("metrics require a complete assignment")
    if k is None:
        k = int(assignment.max()) + 1 if assignment.size else 1
    elif part_of_pin.size and part_of_pin.max() >= k:
        raise ValueError(
            f"assignment uses partition {int(part_of_pin.max())} "
            f">= k = {k}")
    edge_of_pin = np.repeat(np.arange(hg.m, dtype=np.int64),
                            hg.edge_sizes)
    key = edge_of_pin * np.int64(k) + part_of_pin
    uniq_key = np.unique(key)
    return uniq_key // np.int64(k)


def spans_per_edge(hg: Hypergraph, assignment: np.ndarray,
                   k: Optional[int] = None) -> np.ndarray:
    """For each hyperedge, the number of distinct partitions it spans."""
    uniq_edges = _edge_partition_pairs(hg, assignment, k)
    spans = np.zeros(hg.m, dtype=np.int64)
    np.add.at(spans, uniq_edges, 1)
    return spans


def _spans(hg, assignment, k, spans):
    return spans if spans is not None else spans_per_edge(hg, assignment,
                                                          k)


def k_minus_1(hg: Hypergraph, assignment: np.ndarray,
              k: Optional[int] = None, *,
              spans: Optional[np.ndarray] = None) -> int:
    """The (k-1) metric: sum over hyperedges of (#partitions spanned - 1).

    This is the paper's primary quality objective (§II). Empty hyperedges
    (size 0) contribute 0. Pass ``spans`` (a ``spans_per_edge`` result)
    to share one spans computation across several metrics.
    """
    spans = _spans(hg, assignment, k, spans)
    nonempty = hg.edge_sizes > 0
    return int(np.sum(spans[nonempty] - 1))


def hyperedge_cut(hg: Hypergraph, assignment: np.ndarray,
                  k: Optional[int] = None, *,
                  spans: Optional[np.ndarray] = None) -> int:
    """Number of hyperedges spanning more than one partition."""
    return int(np.sum(_spans(hg, assignment, k, spans) > 1))


def sum_external_degree(hg: Hypergraph, assignment: np.ndarray,
                        k: Optional[int] = None, *,
                        spans: Optional[np.ndarray] = None) -> int:
    """SOED: sum of spans over cut hyperedges."""
    spans = _spans(hg, assignment, k, spans)
    return int(np.sum(spans[spans > 1]))


def partition_sizes(assignment: np.ndarray, k: int) -> np.ndarray:
    sizes = np.zeros(k, dtype=np.int64)
    np.add.at(sizes, assignment.astype(np.int64), 1)
    return sizes


def vertex_imbalance(assignment: np.ndarray, k: int) -> float:
    """(maxsize - minsize) / maxsize, the paper's fairness metric (§IV)."""
    sizes = partition_sizes(assignment, k)
    mx = sizes.max()
    return float((mx - sizes.min()) / mx) if mx > 0 else 0.0


def replication_factor(hg: Hypergraph, assignment: np.ndarray,
                       k: Optional[int] = None, *,
                       spans: Optional[np.ndarray] = None) -> float:
    """Average #partitions spanned per hyperedge.

    Directly proportional to the halo/communication volume of a
    vertex-partitioned distributed computation over the hypergraph.
    """
    spans = _spans(hg, assignment, k, spans)
    nonempty = hg.edge_sizes > 0
    return float(spans[nonempty].mean()) if nonempty.any() else 0.0


def all_metrics(hg: Hypergraph, assignment: np.ndarray, k: int) -> dict:
    spans = spans_per_edge(hg, assignment, k)   # computed once, shared
    return {
        "k_minus_1": k_minus_1(hg, assignment, k, spans=spans),
        "hyperedge_cut": hyperedge_cut(hg, assignment, k, spans=spans),
        "soed": sum_external_degree(hg, assignment, k, spans=spans),
        "vertex_imbalance": vertex_imbalance(assignment, k),
        "replication_factor": replication_factor(hg, assignment, k,
                                                 spans=spans),
    }
