"""HYPE: hypergraph partitioning via neighborhood expansion (paper §III).

Faithful implementation of Algorithms 1-3 with the three optimizations of
§III-B2:

  (a) fringe candidates are drawn from the *smallest* hyperedges incident
      to the core first (min-heap over active hyperedges keyed by size),
  (b) the number of fringe candidates per step is limited to ``r`` (=2),
  (c) external-neighbors scores are lazily cached (never recomputed).

Balancing modes (§III-C):
  * ``vertex``   — exactly |V|/k vertices per partition (default).
  * ``weighted`` — weight w(v) = 1 + deg(v); each partition receives
                   ~(Σw)/k total weight.
  * hyperedge balancing is achieved by partitioning ``hg.flip()``.

The engine is a host-side numpy implementation (the paper's own engine is
sequential C++); ``hype_jax.py`` holds the jittable JAX adaptation and the
beyond-paper parallel k-way growth.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import numpy as np

from .hypergraph import Hypergraph
from .scoring import batched_dext_numpy


@dataclasses.dataclass
class HypeParams:
    s: int = 10                 # max fringe size (paper Fig. 3)
    r: int = 2                  # fringe candidates per step (paper Fig. 5)
    use_cache: bool = True      # lazy score caching (paper Fig. 6)
    balance: str = "vertex"     # "vertex" | "weighted"
    dext_mode: str = "universe"  # "universe" (paper intent) | "eq1" (literal)
    dext_cap: Optional[int] = None  # optional cap on pins scanned per score
    seed: int = 0


@dataclasses.dataclass
class HypeStats:
    score_computations: int = 0
    cache_hits: int = 0
    edges_scanned: int = 0
    random_restarts: int = 0


class _HypeState:
    """Mutable partitioning state shared across the k growth phases."""

    def __init__(self, hg: Hypergraph, k: int, params: HypeParams):
        self.hg = hg
        self.k = k
        self.p = params
        n, m = hg.n, hg.m
        self.assignment = np.full(n, -1, dtype=np.int32)
        self.in_fringe = np.zeros(n, dtype=bool)
        # Working copy of e2v pins: assigned pins are compacted to the
        # front of each edge slice so they are never rescanned.
        self.pins = hg.e2v_indices.copy()
        self.cursor = hg.e2v_indptr[:-1].copy()       # first live pin per edge
        self.edge_end = hg.e2v_indptr[1:]
        self.edge_sizes = hg.edge_sizes
        self.edge_dead = self.cursor >= self.edge_end  # empty edges are dead
        # Per-partition activation epoch: edge active iff epoch[e] == phase.
        self.edge_epoch = np.full(m, -1, dtype=np.int32)
        # Lazy external-neighbors score cache (cleared per phase, Alg 1 l.6).
        self.cache = np.full(n, -1.0)
        self.rng = np.random.default_rng(params.seed)
        # Random-seed stream: shuffled vertex order with a skip pointer.
        self.rand_order = self.rng.permutation(n)
        self.rand_ptr = 0
        self.stats = HypeStats()

    # ------------------------------------------------------------------ #
    def random_unassigned(self) -> int:
        n = self.hg.n
        while self.rand_ptr < n:
            v = int(self.rand_order[self.rand_ptr])
            self.rand_ptr += 1
            if self.assignment[v] < 0 and not self.in_fringe[v]:
                return v
        # All remaining vertices sit in the fringe; fall back to a scan.
        rem = np.flatnonzero((self.assignment < 0) & ~self.in_fringe)
        if rem.size == 0:
            return -1
        return int(rem[0])

    # ------------------------------------------------------------------ #
    def d_ext(self, v: int) -> float:
        """External-neighbors score d_ext(v, F).

        Eq. 1 in the paper reads |N(v) \\ F|, but the surrounding text
        defines "external" as neighbors *in the remaining vertex universe*
        ("a low number of neighbors in the remaining vertex universe").
        Taking Eq. 1 literally would count core neighbors as external and
        penalize exactly the high-locality vertices, so — like the paper's
        released C++ implementation — we count neighbors that are neither
        in the fringe nor already assigned to any core:

            d_ext(v, F) = |N(v) ∩ V'|    with V' = V \\ F \\ C_0 ... \\ C_i

        ``dext_mode='eq1'`` restores the literal reading for ablations.
        """
        self.stats.score_computations += 1
        hg = self.hg
        lo, hi = hg.v2e_indptr[v], hg.v2e_indptr[v + 1]
        es = hg.v2e_indices[lo:hi]
        if es.size == 0:
            return 0.0
        cap = self.p.dext_cap
        parts = []
        scanned = 0
        for e in es:
            a, b = hg.e2v_indptr[e], hg.e2v_indptr[e + 1]
            parts.append(hg.e2v_indices[a:b])
            scanned += b - a
            if cap is not None and scanned >= cap:
                break
        allp = np.concatenate(parts) if len(parts) > 1 else parts[0]
        uniq = np.unique(allp)
        if self.p.dext_mode == "eq1":
            ext = int((~self.in_fringe[uniq]).sum())
            self_external = not self.in_fringe[v]
        else:
            external = (~self.in_fringe[uniq]) & (self.assignment[uniq] < 0)
            ext = int(external.sum())
            self_external = (not self.in_fringe[v]) and self.assignment[v] < 0
        if self_external:
            ext -= 1  # v itself was counted
        score = float(max(ext, 0))
        if cap is not None and scanned >= cap:
            score += 1e12  # capped vertices compare as "huge neighborhood"
        return score

    def score(self, v: int) -> float:
        """Cached score read (Alg 3 line 2 always reads the cache)."""
        c = self.cache[v]
        if c >= 0.0:
            self.stats.cache_hits += 1
            return float(c)
        sc = self.d_ext(v)
        self.cache[v] = sc
        return sc

    def refresh(self, v: int) -> float:
        """Fringe-update scoring (Alg 2 l.14-16).

        With caching (paper default) the score is computed at most once per
        phase (lazy policy); the ablation ``use_cache=False`` recomputes a
        fresh score on every fringe update instead.
        """
        if self.p.use_cache and self.cache[v] >= 0.0:
            self.stats.cache_hits += 1
            return float(self.cache[v])
        sc = self.d_ext(v)
        self.cache[v] = sc
        return sc

    def refresh_many(self, vs: list) -> None:
        """Batch fringe-update scoring: one vectorized d_ext pass.

        Produces exactly the same scores/stats as per-vertex ``refresh``
        in the default "universe" mode; the eq1 / capped ablation modes
        keep the scalar path (they exist for fidelity, not speed).
        """
        if self.p.dext_mode != "universe" or self.p.dext_cap is not None:
            for v in vs:
                self.refresh(v)
            return
        if self.p.use_cache:
            miss = [v for v in vs if self.cache[v] < 0.0]
            self.stats.cache_hits += len(vs) - len(miss)
        else:
            miss = list(vs)
        if not miss:
            return
        scores = batched_dext_numpy(self.hg, np.asarray(miss, np.int64),
                                    self.in_fringe, self.assignment)
        self.cache[miss] = scores
        self.stats.score_computations += len(miss)


def _grow_partition(st: _HypeState, part: int, target: float,
                    weights: Optional[np.ndarray],
                    warm: bool = False) -> None:
    """Grow core set C_part until it reaches ``target`` size/weight.

    ``warm`` continues a phase that already holds members (a warm start
    from a partition snapshot — the degradation ladder's last rung):
    existing members are activated instead of drawing a seed, and
    growth resumes from their accumulated size/weight.
    """
    hg, p = st.hg, st.p
    heap: list = []            # (edge_size, edge_id) of active hyperedges
    fringe: list = []          # vertex ids, |fringe| <= s
    st.cache[:] = -1.0         # Alg 1 line 6: clear cache per phase

    def activate(v: int) -> None:
        lo, hi = hg.v2e_indptr[v], hg.v2e_indptr[v + 1]
        for e in hg.v2e_indices[lo:hi]:
            e = int(e)
            if st.edge_epoch[e] != part and not st.edge_dead[e]:
                st.edge_epoch[e] = part
                heapq.heappush(heap, (int(st.edge_sizes[e]), e))

    def add_to_core(v: int) -> float:
        st.assignment[v] = part
        st.in_fringe[v] = False
        activate(v)
        return 1.0 if weights is None else float(weights[v])

    acc = 0.0
    if warm:
        members = np.flatnonzero(st.assignment == part)
        if members.size:
            acc = (float(members.size) if weights is None
                   else float(weights[members].sum()))
            if acc >= target:
                return
            for v in members:
                activate(int(v))
    if acc == 0.0:
        # --- Alg 1 line 3: random seed vertex ---
        seed = st.random_unassigned()
        if seed < 0:
            return
        acc = add_to_core(seed)

    while acc < target:
        # ---------------- upd8_fringe (Alg 2) ----------------
        cand: list = []
        requeue: list = []
        while heap and len(cand) < p.r:
            size_e, e = heapq.heappop(heap)
            if st.edge_epoch[e] != part or st.edge_dead[e]:
                continue
            cur, end = int(st.cursor[e]), int(st.edge_end[e])
            pins = st.pins
            while cur < end and len(cand) < p.r:
                st.stats.edges_scanned += 1
                v = int(pins[cur])
                if st.assignment[v] >= 0:
                    # compact assigned pin to the front, never rescan
                    pins[cur] = pins[int(st.cursor[e])]
                    pins[int(st.cursor[e])] = v
                    st.cursor[e] += 1
                    cur += 1
                    continue
                if st.in_fringe[v] or v in cand:
                    cur += 1
                    continue
                cand.append(v)
                cur += 1
            if st.cursor[e] >= end:
                st.edge_dead[e] = True
            elif len(cand) >= p.r:
                requeue.append((size_e, e))   # still has live pins
            else:
                requeue.append((size_e, e))
        for item in requeue:
            heapq.heappush(heap, item)

        # update cache / compute scores for new candidates (Alg 2 l.14-16)
        # and set fringe to top-s by score (Alg 2 l.18-20)
        pool = fringe + cand
        if pool:
            st.refresh_many(pool)
            scored = sorted(pool, key=st.score)
            fringe = scored[:p.s]
            for v in scored[p.s:]:
                st.in_fringe[v] = False      # evicted back to the universe
            for v in fringe:
                st.in_fringe[v] = True
        if not fringe:                        # Alg 2 l.21-22: random restart
            v = st.random_unassigned()
            if v < 0:
                return
            st.stats.random_restarts += 1
            fringe = [v]
            st.in_fringe[v] = True

        # ---------------- upd8_core (Alg 3) ----------------
        best_i = min(range(len(fringe)), key=lambda i: st.score(fringe[i]))
        v = fringe.pop(best_i)
        acc += add_to_core(v)

    # release fringe (§III-B1 step 4)
    for v in fringe:
        st.in_fringe[v] = False


def hype_partition(hg: Hypergraph, k: int,
                   params: Optional[HypeParams] = None,
                   return_stats: bool = False,
                   warm_start: Optional[np.ndarray] = None):
    """Partition ``hg`` into ``k`` parts with HYPE (Alg. 1).

    Returns an int32 assignment array of shape (n,); every vertex is
    assigned to exactly one partition in [0, k).

    ``warm_start`` adopts a (possibly partial, -1 = unassigned)
    assignment before growing — the degradation ladder's last rung
    (core/resilience.py) resumes here from the last snapshot when every
    device engine failed; values must lie in [-1, k).
    """
    if params is None:
        params = HypeParams()
    if k < 1:
        raise ValueError("k must be >= 1")
    st = _HypeState(hg, k, params)
    n = hg.n
    warm = False
    if warm_start is not None:
        wa = np.asarray(warm_start)
        if wa.shape != (n,):
            raise ValueError(
                f"warm_start must have shape ({n},), got {wa.shape}")
        if wa.max(initial=-1) >= k:
            raise ValueError("warm_start names a partition >= k")
        got = wa >= 0
        st.assignment[got] = wa[got].astype(np.int32)
        warm = True

    if params.balance == "vertex":
        weights = None
        base, rem = divmod(n, k)
        targets = [base + (1 if i < rem else 0) for i in range(k)]
    elif params.balance == "weighted":
        weights = 1.0 + hg.vertex_degrees.astype(np.float64)
        total = float(weights.sum())
        targets = [total / k] * k
    else:
        raise ValueError(f"unknown balance mode {params.balance!r}")

    for i in range(k):
        if i == k - 1:
            # Last partition absorbs every remaining vertex so the
            # assignment is always complete (weighted mode may round).
            rem_v = np.flatnonzero(st.assignment < 0)
            st.assignment[rem_v] = i
            st.in_fringe[:] = False
            break
        _grow_partition(st, i, targets[i], weights, warm=warm)

    assert (st.assignment >= 0).all()
    if return_stats:
        return st.assignment, st.stats
    return st.assignment


def hyperedge_balanced_hype(hg: Hypergraph, k: int,
                            params: Optional[HypeParams] = None) -> np.ndarray:
    """Perfect hyperedge balancing via the flip trick (paper §III-C).

    Partitions the flipped hypergraph (hyperedges become vertices), then
    returns the assignment of *hyperedges* to partitions.
    """
    return hype_partition(hg.flip(), k, params)
