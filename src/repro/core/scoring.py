"""Shared candidate-scoring machinery for the HYPE engines.

The engine family (numpy ``hype.py``, jittable ``hype_jax.py``, the
``repro.engines`` fast engines) needs the same primitive: the external-neighbors
score d_ext(v, F) = |N(v) ∩ V'| for a *batch* of candidate vertices, where
V' is the remaining vertex universe (neither assigned nor in the fringe).
This module holds the two batched implementations they share:

  * numpy side — CSR slice gathering (``gather_csr_rows``), the padded
    (B, L) neighbor *tile* the Pallas ``hype_scores`` kernel consumes
    (``neighbor_tile``), and a direct vectorized count
    (``batched_dext_numpy``) for engines that score on host.
  * JAX side — ``batched_dext_jax``: gather + sort + first-occurrence
    segment counting over padded incidence arrays. O(W log W) per
    candidate with W = max_deg * max_size, independent of n — this
    replaces the old O(n) dense-membership-mask-per-candidate scoring.

Tile contract (matches kernels/hype_score): rows are pre-deduplicated
neighbor lists, -1 padded; *assigned* neighbors and the candidate itself
are dropped on the host, so

    kernel_score = #valid - #(valid ∩ fringe) = |N(v) ∩ V'|

exactly the engines' "universe" d_ext. Tile shapes are bucketed (B padded
to a fixed batch, L to ``L_BUCKETS``) so the jitted kernel retraces only a
handful of times per process.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

# Width buckets for the (B, L) kernel tile: each distinct L traces the
# jitted Pallas call once (~0.15 s in interpret mode), so keep the set
# small. Rows wider than the last bucket are truncated and penalized.
L_BUCKETS = (32, 128, 512, 2048)
# Score added to candidates whose neighbor scan was truncated: they
# compare as "huge neighborhood" (same convention as HypeParams.dext_cap).
TRUNC_PENALTY = 1e12


def gather_csr_rows(indptr: np.ndarray, indices: np.ndarray,
                    ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Concatenate CSR slices ``indices[indptr[i]:indptr[i+1]]`` for ``ids``.

    Returns ``(values, owner)`` where ``owner[j]`` is the position in
    ``ids`` that produced ``values[j]``. Fully vectorized (no per-row
    Python loop).
    """
    ids = np.asarray(ids, dtype=np.int64)
    starts = indptr[ids].astype(np.int64)
    lens = (indptr[ids + 1] - indptr[ids]).astype(np.int64)
    total = int(lens.sum())
    if total == 0:
        return (np.empty(0, dtype=indices.dtype),
                np.empty(0, dtype=np.int64))
    out_start = np.cumsum(lens) - lens
    pos = (np.arange(total, dtype=np.int64)
           - np.repeat(out_start, lens) + np.repeat(starts, lens))
    owner = np.repeat(np.arange(ids.size, dtype=np.int64), lens)
    return indices[pos], owner


def _bucket_width(width: int) -> int:
    for b in L_BUCKETS:
        if width <= b:
            return b
    return L_BUCKETS[-1]


def _pin_budget(erow: np.ndarray, elen: np.ndarray, rows: int,
                cap: int) -> Tuple[np.ndarray, np.ndarray]:
    """Per-row pin budget over row-major (owner, length) edge pairs.

    Keeps whole edges until a row's cumulative pin count reaches ``cap``
    (hub protection). Returns ``(keep, truncated)``: a mask over the edge
    pairs and the per-row truncation flags — the single source of truth
    for the budget semantics shared by the kernel-tile and host paths.
    """
    excl = np.cumsum(elen) - elen
    row_first = np.searchsorted(erow, np.arange(rows, dtype=np.int64))
    # rows with no edges point past the end; they contribute nothing
    row_base = np.zeros(rows, dtype=np.int64)
    has = row_first < erow.size
    row_base[has] = excl[row_first[has]]
    keep = (excl - row_base[erow]) < cap
    truncated = np.zeros(rows, dtype=bool)
    np.logical_or.at(truncated, erow[~keep], True)
    return keep, truncated


def neighbor_tile_adj(adj, cands: np.ndarray, assignment: np.ndarray, *,
                      pad_b: int | None = None
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """(B, L) tile from a precomputed adjacency CSR — gather only, no sort.

    ``adj`` is ``Hypergraph.vertex_adjacency()`` output: rows are already
    unique neighbor lists with self excluded, so building the tile is one
    CSR gather + an assigned-filter + a compacting scatter. Rows with more
    than ``L_BUCKETS[-1]`` surviving neighbors are truncated and flagged.
    """
    indptr, indices = adj
    cands = np.asarray(cands, dtype=np.int64)
    B = cands.size
    rows_out = pad_b or max(B, 1)
    if B == 0:
        return (np.full((rows_out, L_BUCKETS[0]), -1, np.int32),
                np.zeros(0, dtype=bool))
    nbrs, prow = gather_csr_rows(indptr, indices, cands)
    truncated = np.zeros(B, dtype=bool)
    if nbrs.size:
        nbrs = nbrs.astype(np.int64)
        keep = assignment[nbrs] < 0
        nbrs, prow = nbrs[keep], prow[keep]
    if nbrs.size:
        counts = np.bincount(prow, minlength=B)
        row_start = np.cumsum(counts) - counts
        offs = np.arange(nbrs.size, dtype=np.int64) - row_start[prow]
        max_w = L_BUCKETS[-1]
        truncated |= counts > max_w
        keep2 = offs < max_w
        prow, nbrs, offs = prow[keep2], nbrs[keep2], offs[keep2]
        L = _bucket_width(int(counts.clip(max=max_w).max()))
        tile = np.full((rows_out, L), -1, np.int32)
        tile[prow, offs] = nbrs
    else:
        tile = np.full((rows_out, L_BUCKETS[0]), -1, np.int32)
    return tile, truncated


def batched_dext_adj(adj, vs: np.ndarray, in_fringe: np.ndarray,
                     assignment: np.ndarray) -> np.ndarray:
    """d_ext over a precomputed adjacency CSR.

    Applies the same hub convention as ``neighbor_tile_adj``: vertices
    with more than ``L_BUCKETS[-1]`` unassigned neighbors (the tile width
    cut) get ``TRUNC_PENALTY`` added, so a candidate scores as a "huge
    neighborhood" hub regardless of which path scored it.
    """
    vs = np.asarray(vs, dtype=np.int64)
    if vs.size == 0:
        return np.zeros(0, dtype=np.float64)
    indptr, indices = adj
    nbrs, prow = gather_csr_rows(indptr, indices, vs)
    if not nbrs.size:
        return np.zeros(vs.size, dtype=np.float64)
    nbrs = nbrs.astype(np.int64)
    unassigned = assignment[nbrs] < 0
    ext = (~in_fringe[nbrs]) & unassigned
    scores = np.bincount(prow[ext], minlength=vs.size).astype(np.float64)
    wide = np.bincount(prow[unassigned],
                       minlength=vs.size) > L_BUCKETS[-1]
    scores[wide] += TRUNC_PENALTY
    return scores


def neighbor_tile(hg, cands: np.ndarray, assignment: np.ndarray, *,
                  cap_pins: int = 8192, pad_b: int | None = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Build the dense (B, L) neighbor tile for a candidate batch.

    For each candidate v, the row holds the *unique unassigned* neighbors
    of v (v itself excluded), -1 padded. Per-candidate work is capped at
    ``cap_pins`` scanned pins / ``L_BUCKETS[-1]`` unique neighbors; capped
    rows are flagged in the returned ``truncated`` mask and must receive a
    large score penalty (hubs compare as "huge neighborhood", which is
    what the paper's score wants anyway).

    Returns ``(tile, truncated)``: tile is int32 (pad_b or B, L) with L in
    ``L_BUCKETS``; truncated is bool (B,).
    """
    cands = np.asarray(cands, dtype=np.int64)
    B = cands.size
    rows_out = pad_b or max(B, 1)
    n = hg.n
    if B == 0:
        return (np.full((rows_out, L_BUCKETS[0]), -1, np.int32),
                np.zeros(0, dtype=bool))

    edges, erow = gather_csr_rows(hg.v2e_indptr, hg.v2e_indices, cands)
    edges = edges.astype(np.int64)
    truncated = np.zeros(B, dtype=bool)
    if edges.size:
        elen = (hg.e2v_indptr[edges + 1] - hg.e2v_indptr[edges]).astype(
            np.int64)
        keep, truncated = _pin_budget(erow, elen, B, cap_pins)
        edges, erow = edges[keep], erow[keep]

    pins, pidx = gather_csr_rows(hg.e2v_indptr, hg.e2v_indices, edges)
    prow = erow[pidx] if pins.size else pidx
    if pins.size:
        pins = pins.astype(np.int64)
        ok = (assignment[pins] < 0) & (pins != cands[prow])
        pins, prow = pins[ok], prow[ok]

    if pins.size:
        key = np.unique(prow * np.int64(n) + pins)
        prow2 = key // n
        pins2 = key % n
        counts = np.bincount(prow2, minlength=B)
        row_start = np.zeros(B, dtype=np.int64)
        row_start[1:] = np.cumsum(counts)[:-1]
        offs = np.arange(key.size, dtype=np.int64) - row_start[prow2]
        max_w = L_BUCKETS[-1]
        wide = counts > max_w
        truncated |= wide
        keep2 = offs < max_w
        prow2, pins2, offs = prow2[keep2], pins2[keep2], offs[keep2]
        L = _bucket_width(int(counts.clip(max=max_w).max()))
        tile = np.full((rows_out, L), -1, np.int32)
        tile[prow2, offs] = pins2
    else:
        tile = np.full((rows_out, L_BUCKETS[0]), -1, np.int32)
    return tile, truncated


def batched_dext_numpy(hg, vs: np.ndarray, in_fringe: np.ndarray,
                       assignment: np.ndarray, *,
                       cap_pins: int | None = None,
                       max_width: int | None = None) -> np.ndarray:
    """Vectorized d_ext(v, F) = |N(v) ∩ V'| for a batch of vertices.

    One pass over the concatenated pin lists of all candidates: gather,
    dedup (vertex, neighbor) pairs, count external ones. Bit-identical to
    ``hype.py``'s per-vertex d_ext in the default "universe" mode when
    ``cap_pins`` and ``max_width`` are None. ``cap_pins`` truncates the
    per-candidate pin scan; ``max_width`` applies the kernel tile's
    width cut (> max_width unique unassigned neighbors). Either
    truncation adds ``TRUNC_PENALTY`` (same convention as the tile path
    and HypeParams.dext_cap).
    """
    vs = np.asarray(vs, dtype=np.int64)
    if vs.size == 0:
        return np.zeros(0, dtype=np.float64)
    n = hg.n
    edges, erow = gather_csr_rows(hg.v2e_indptr, hg.v2e_indices, vs)
    edges = edges.astype(np.int64)
    truncated = np.zeros(vs.size, dtype=bool)
    if cap_pins is not None and edges.size:
        elen = (hg.e2v_indptr[edges + 1] - hg.e2v_indptr[edges]).astype(
            np.int64)
        keep, truncated = _pin_budget(erow, elen, vs.size, cap_pins)
        edges, erow = edges[keep], erow[keep]
    pins, pidx = gather_csr_rows(hg.e2v_indptr, hg.e2v_indices, edges)
    scores = np.zeros(vs.size, dtype=np.float64)
    if pins.size:
        prow = erow[pidx]
        key = np.unique(prow * np.int64(n) + pins.astype(np.int64))
        prow2 = key // n
        pins2 = key % n
        unassigned = assignment[pins2] < 0
        ext = (~in_fringe[pins2]) & unassigned
        scores = np.bincount(prow2[ext], minlength=vs.size).astype(
            np.float64)
        # v itself is a pin of each incident edge: counted once iff it is
        # still "external" and has at least one edge.
        deg = hg.v2e_indptr[vs + 1] - hg.v2e_indptr[vs]
        self_ext = (~in_fringe[vs]) & (assignment[vs] < 0) & (deg > 0)
        scores = np.maximum(scores - self_ext, 0.0)
        if max_width is not None:
            nonself = pins2 != vs[prow2]
            wide = np.bincount(prow2[unassigned & nonself],
                               minlength=vs.size) > max_width
            scores[wide] += TRUNC_PENALTY
    scores[truncated] += TRUNC_PENALTY
    return scores


# ------------------------------------------------------------- superstep
# Shared traced helpers of the device-resident superstep programs (now
# in ``repro.engines.superstep``/``.sharded``): one jitted program
# performs the whole per-superstep device work of the superstep engine —
# apply the host's injection delta (seeds / restarts), decrement-
# invalidate the cached scores of the delta's neighbors, gather the
# fresh candidate tiles from the device CSR, run the fused score+select
# kernel, write the fresh scores back into the device cache, and apply
# the per-phase admissions *on device*: stale proposals (candidates
# assigned by an interleaved superstep of the pipeline) are masked out,
# and the per-phase remaining-target cap is enforced against a device-
# resident admission counter. Winner-neighbor decrements ride the NEXT
# dispatch's host-preaggregated dirty pairs (the lock-step schedule).
# Only ids cross the host boundary, and the (n,)-sized assignment/cache
# (plus the (k,) counter) are *donated* — each superstep updates the
# image in place instead of copying it.

import functools as _functools


def _apply_host_injections(assign, cache, acc, delta_ids, delta_vals,
                           dirty_ids, dirty_counts):
    """Traced prefix shared by BOTH superstep programs.

    Applies the host's injection delta (seeds / restarts) to the
    assignment, counts the injections into the per-phase admission
    totals, and applies the pre-aggregated (unique id, count) dirty
    decrements to the score cache. Keeping this in one function is what
    keeps the single-device and sharded programs semantically identical
    — edit here, not in the program bodies.
    """
    import jax.numpy as jnp

    n = assign.shape[0]
    inj = delta_ids >= 0
    assign = assign.at[jnp.where(inj, delta_ids, n)].set(
        delta_vals, mode="drop")
    acc = acc.at[jnp.where(inj, delta_vals, acc.shape[0])].add(
        1, mode="drop")
    cache = cache.at[jnp.where(dirty_ids >= 0, dirty_ids, n)].add(
        -dirty_counts, mode="drop")
    return assign, cache, acc


def _gather_fresh_tiles(indptr, indices, assign, flat, tile_l):
    """Traced helper shared by both superstep programs.

    Gathers the flat fresh-candidate ids' CSR rows at static width
    ``tile_l``; assigned neighbors are masked to -1 *in place* (no
    compaction — the kernel counts valid entries, not positions).
    """
    import jax
    import jax.numpy as jnp

    fsafe = jnp.where(flat >= 0, flat, 0)
    fstart = indptr[fsafe]
    fdeg = indptr[fsafe + 1] - fstart
    col = jax.lax.broadcasted_iota(jnp.int32, (flat.shape[0], tile_l), 1)
    fvalid = (col < fdeg[:, None]) & (flat >= 0)[:, None]
    nbr = indices[jnp.where(fvalid, fstart[:, None] + col, 0)]
    unassigned = assign[jnp.where(fvalid, nbr, 0)] < 0
    return jnp.where(fvalid & unassigned, nbr, -1).astype(jnp.int32)


def _stale_masked_prev(pool, assign, cache):
    """Traced helper shared by both superstep programs.

    Held pool scores ride along from the device cache; slots that went
    stale (assigned by an interleaved superstep of the pipeline) are
    masked to +inf so selection skips them and takes the phase's
    next-best candidate. Returns ``(prev, n_stale)``.
    """
    import jax.numpy as jnp

    psafe = jnp.where(pool >= 0, pool, 0)
    pool_ok = (pool >= 0) & (assign[psafe] < 0)
    prev = jnp.where(pool_ok, cache[psafe], jnp.inf).astype(jnp.float32)
    n_stale = ((pool >= 0) & ~pool_ok).sum().astype(jnp.int32)
    return prev, n_stale


def _poison_guard(flat, scores_flat, poison, reset):
    """Traced NaN/inf quarantine shared by both superstep programs.

    A superstep whose fresh scores contain a non-finite value (a
    poisoned tile — injected by a ``FaultPlan`` or a real device fault)
    must not be admitted: the program reverts ALL its mutations and
    raises the sticky ``poison`` flag so any in-flight superstep
    dispatched after it self-aborts too, preserving device-effect order
    for the host's in-order replay (DESIGN.md §4f). ``reset`` is the
    host's replay marker: a replay ignores the sticky flag (the host
    replays the whole aborted window in order) but still re-checks its
    own fresh scores. Pad rows (``flat < 0``) legitimately carry +inf
    bias and are excluded. Returns the replicated ``poisoned`` bool.
    """
    import jax.numpy as jnp

    bad = ((flat >= 0) & ~jnp.isfinite(scores_flat)).any()
    return bad | ((poison[0] > 0) & (reset[0] == 0))


# The superstep/sharded device programs (pipeline_superstep_device and
# the memory-rung/sharded variants) moved to the per-engine modules in
# ``repro.engines`` next to the states that drive them; the module
# ``__getattr__`` below keeps the old ``scoring.*`` names resolving
# (with a DeprecationWarning). The traced helpers above stay here: they
# are the shared scoring vocabulary (engines, device_loop, membudget).
_MOVED_PROGRAMS = {
    "_pipeline_program": "superstep",
    "pipeline_superstep_device": "superstep",
    "_chunked_program": "superstep",
    "chunked_superstep_device": "superstep",
    "_spill_program": "superstep",
    "spill_superstep_device": "superstep",
    "_paged_program": "superstep",
    "paged_superstep_device": "superstep",
    "_sharded_mesh": "sharded",
    "_sharded_program": "sharded",
    "sharded_superstep_device": "sharded",
}


def __getattr__(name):
    mod = _MOVED_PROGRAMS.get(name)
    if mod is None:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    import importlib
    import warnings
    warnings.warn(
        f"repro.core.scoring.{name} moved to repro.engines.{mod}",
        DeprecationWarning, stacklevel=2)
    return getattr(importlib.import_module(f"repro.engines.{mod}"), name)



# ------------------------------------------------------------ k-way refine
# Device half of the refinement subsystem (DESIGN.md §4e): one jitted
# call applies the host's admitted-move delta to the device-resident
# assignment (the same delta-scatter convention as the superstep
# programs' `_apply_host_injections`), gathers the candidate tile's
# neighbor *partitions* from the device CSR, and runs the Pallas
# `kway_gains` kernel — so screening every boundary vertex costs one
# gather + k broadcast-compares on device, and only candidate ids go
# down / (B, k) gain rows come back. The assignment is DONATED and
# threaded through the driver's screening calls exactly like the
# superstep image.


def _gather_part_tiles(indptr, indices, assign, cand, tile_l):
    """Neighbor-partition tile for ``cand`` at static width ``tile_l``.

    The refinement sibling of ``_gather_fresh_tiles``: same CSR gather,
    but rows hold the neighbors' partition ids (every neighbor, assigned
    or not) instead of unassigned vertex ids. Pads are -1.
    """
    import jax
    import jax.numpy as jnp

    csafe = jnp.where(cand >= 0, cand, 0)
    start = indptr[csafe]
    deg = indptr[csafe + 1] - start
    col = jax.lax.broadcasted_iota(jnp.int32, (cand.shape[0], tile_l), 1)
    valid = (col < deg[:, None]) & (cand >= 0)[:, None]
    nbr = indices[jnp.where(valid, start[:, None] + col, 0)]
    return jnp.where(valid, assign[nbr], -1).astype(jnp.int32)


@_functools.lru_cache(maxsize=None)
def _refine_program():
    import jax
    import jax.numpy as jnp
    from repro.kernels.kway_refine.ops import kway_gains

    @_functools.partial(
        jax.jit, static_argnames=("tile_l", "k", "interpret"),
        donate_argnums=(2,))
    def step(indptr, indices, assign, delta_ids, delta_vals, cand, *,
             tile_l, k, interpret):
        n = assign.shape[0]
        # 1. apply the host's admitted-move delta (pads route to the
        #    out-of-bounds index n, the repo-wide masked-scatter rule)
        inj = delta_ids >= 0
        assign = assign.at[jnp.where(inj, delta_ids, n)].set(
            delta_vals, mode="drop")
        # 2. gather the candidates' neighbor-partition tiles
        parts = _gather_part_tiles(indptr, indices, assign, cand, tile_l)
        own = jnp.where(cand >= 0, assign[
            jnp.where(cand >= 0, cand, 0)], -1).astype(jnp.int32)
        # 3. Pallas move-gain kernel: (B, k) connectivity gains
        gains = kway_gains(parts, own, k=k, interpret=interpret)
        return assign, gains

    return step


def refine_gains_device(indptr, indices, assign, delta_ids, delta_vals,
                        cand, *, tile_l: int, k: int, interpret: bool):
    """Run one refinement screening call; see ``_refine_program``.

    ``assign`` is DONATED — keep the returned array, never reuse the
    input. ``delta_ids``/``delta_vals`` carry the host's admitted moves
    since the previous call (-1 padded); ``cand`` is the (-1 padded)
    candidate id tile. Returns ``(assign', gains)`` with ``gains``
    (B, k) float32 — ``gains[b, q]`` is the connectivity gain of moving
    ``cand[b]`` to partition ``q`` (0 for ``q == own`` and pad rows).
    """
    return _refine_program()(
        indptr, indices, assign, delta_ids, delta_vals, cand,
        tile_l=tile_l, k=k, interpret=interpret)


# ------------------------------------------------- streaming sketch program
# Device program of the single-pass streaming engine (core/hype_stream.py,
# DESIGN.md §4h). One jitted call per micro-batch: the fused
# ``hype_score_select`` kernel computes the batch's fringe-intersection
# counts against all k partition fringes at once, then a ``fori_loop``
# commits the batch *sequentially* — each vertex scores its k targets
# against the live partition sketch (per-partition hashed edge-presence
# counts) with a FREIGHT-style balance penalty, and its admission updates
# the sketch and sizes in the loop carry. Sketch and sizes are DONATED
# and stay device-resident across micro-batches; only the (mb, L) tiles
# go down and the (mb,) chosen partitions come back. At micro_batch=1
# the schedule is exactly the sequential streaming algorithm, which is
# what the numpy oracle in tests/test_hype_stream.py replicates
# bit-for-bit (same f32 expression, same first-max tie break).

# Fibonacci multiplicative hashing: bucket = top ``sketch_bits`` bits of
# (id * 2654435761) in uint32 arithmetic — identical on host and device.
STREAM_HASH_MULT = 2654435761


def stream_bucket(edge_ids: np.ndarray, sketch_bits: int) -> np.ndarray:
    """Host twin of the device bucket hash (exactly the same uint32 math).

    Negative (pad) ids hash like any other bits — callers mask validity
    separately, the hash itself never branches.
    """
    ids = np.asarray(edge_ids).astype(np.uint32)
    h = ids * np.uint32(STREAM_HASH_MULT)
    return (h >> np.uint32(32 - sketch_bits)).astype(np.int32)


@_functools.lru_cache(maxsize=None)
def _stream_program(sketch_bits: int, interpret: bool):
    import jax
    import jax.numpy as jnp
    from repro.kernels.hype_score.ops import hype_score_select

    n_buckets = 1 << sketch_bits
    shift = jnp.uint32(32 - sketch_bits)
    mult = jnp.uint32(STREAM_HASH_MULT)

    @_functools.partial(jax.jit, donate_argnums=(3, 4))
    def step(edge_tile, nbr_tile, fringe, sketch, sizes, valid_row,
             alpha, fringe_w, inv_target, cap):
        mb = edge_tile.shape[0]
        k = sketch.shape[0]
        e_valid = edge_tile >= 0
        buckets = ((edge_tile.astype(jnp.uint32) * mult)
                   >> shift).astype(jnp.int32)
        # Fringe-intersection counts via the fused Pallas kernel: the
        # kernel scores #valid - #(valid ∩ fringe_p) per phase, so the
        # intersection count is valid_cnt - score — exact integers in
        # float32. The pool is a single +inf slot (selection unused).
        nbrs = jnp.broadcast_to(nbr_tile[None],
                                (k,) + nbr_tile.shape)
        bias = jnp.zeros((k, mb), jnp.float32)
        prev = jnp.full((k, 1), jnp.inf, jnp.float32)
        kscore, _, _ = hype_score_select(nbrs, fringe, bias, prev,
                                         select_k=1,
                                         interpret=interpret)
        valid_cnt = (nbr_tile >= 0).sum(axis=1).astype(jnp.float32)
        fcnt = valid_cnt[:, None] - kscore.T          # (mb, k) f32

        def body(i, carry):
            parts, sketch, sizes = carry
            ev = e_valid[i]
            brow = buckets[i]
            pres = sketch[:, brow] > 0                # (k, Le)
            conn = jnp.sum(pres & ev[None, :],
                           axis=1).astype(jnp.float32)
            score = conn + fringe_w * fcnt[i] \
                - alpha * sizes.astype(jnp.float32) * inv_target
            score = jnp.where(sizes >= cap, -jnp.inf, score)
            p = jnp.argmax(score).astype(jnp.int32)   # first-max tie break
            upd = valid_row[i]
            sizes = sizes.at[p].add(jnp.where(upd, 1, 0))
            bm = jnp.where(ev & upd, brow, n_buckets)
            sketch = sketch.at[p, bm].add(1, mode="drop")
            parts = parts.at[i].set(jnp.where(upd, p, -1))
            return parts, sketch, sizes

        parts0 = jnp.full((mb,), -1, jnp.int32)
        parts, sketch, sizes = jax.lax.fori_loop(
            0, mb, body, (parts0, sketch, sizes))
        return parts, sketch, sizes

    return step


def stream_step_device(edge_tile, nbr_tile, fringe, sketch, sizes,
                       valid_row, *, alpha: float, fringe_w: float,
                       inv_target: float, cap: int, sketch_bits: int,
                       interpret: bool):
    """Run one streaming micro-batch; see ``_stream_program``.

    ``edge_tile`` (mb, Le) int32 incident-edge ids / ``nbr_tile``
    (mb, Ln) int32 neighbor ids, both -1 padded; ``fringe`` (k, s)
    int32 per-partition fringes (-1 = empty slot); ``valid_row`` (mb,)
    bool marks real (non-pad) batch rows. ``sketch`` (k, 2**sketch_bits)
    int32 and ``sizes`` (k,) int32 are DONATED device arrays — keep the
    returned pair, never reuse the inputs. Returns
    ``(parts (mb,) int32, sketch', sizes')``.
    """
    import jax.numpy as jnp

    return _stream_program(int(sketch_bits), bool(interpret))(
        edge_tile, nbr_tile, fringe, sketch, sizes, valid_row,
        jnp.float32(alpha), jnp.float32(fringe_w),
        jnp.float32(inv_target), jnp.int32(cap))


# --------------------------------------------------------------------- JAX
# (imported lazily by callers that run on device; keeping the import at
# module level is fine — the repo is a JAX codebase — but the numpy helpers
# above stay usable without touching the device runtime.)

def batched_dext_jax(v2e, e2v, vs, ext_mask):
    """d_ext for a batch of vertices on padded incidence arrays (jittable).

    ``v2e``: (n, max_deg) int32, -1 padded; ``e2v``: (m, max_size) int32,
    -1 padded; ``vs``: (B,) int32 vertex ids (entries < 0 allowed, score
    undefined for them — mask at the call site); ``ext_mask``: (n,) bool,
    True where a vertex counts as "external" (unassigned, not in fringe).

    Gather all pins of all incident edges into a (B, max_deg * max_size)
    tile, sort each row, and count first occurrences that are external —
    a segment-style unique-count with no O(n) scatter per candidate.
    """
    import jax.numpy as jnp

    n = v2e.shape[0]
    safe_vs = jnp.where(vs >= 0, vs, 0)
    es = v2e[safe_vs]                                   # (B, D)
    ev = es >= 0
    pins = e2v[jnp.where(ev, es, 0)]                    # (B, D, S)
    pins = jnp.where(ev[:, :, None] & (pins >= 0), pins, n)
    flat = pins.reshape(pins.shape[0], -1)
    flat = jnp.where(flat == safe_vs[:, None], n, flat)   # exclude self
    srt = jnp.sort(flat, axis=1)
    first = jnp.concatenate(
        [jnp.ones((srt.shape[0], 1), bool), srt[:, 1:] != srt[:, :-1]],
        axis=1)
    ext_pad = jnp.concatenate([ext_mask, jnp.zeros((1,), bool)])
    counted = first & ext_pad[srt]
    return counted.sum(axis=1).astype(jnp.float32)


# ISSUE.md names `scoring.device_loop_program` as the fully
# device-resident loop's entry point; the program outgrew this module
# and lives in core/device_loop.py — re-exported here so the documented
# import path keeps working. Bottom-of-file on purpose: device_loop's
# program builder imports back into scoring lazily.
from .device_loop import (  # noqa: E402,F401
    DeviceLoopConfig, device_loop_program)
