"""Shared candidate-scoring machinery for the HYPE engines.

The three engines (numpy ``hype.py``, jittable ``hype_jax.py``, batched
``hype_batched.py``) all need the same primitive: the external-neighbors
score d_ext(v, F) = |N(v) ∩ V'| for a *batch* of candidate vertices, where
V' is the remaining vertex universe (neither assigned nor in the fringe).
This module holds the two batched implementations they share:

  * numpy side — CSR slice gathering (``gather_csr_rows``), the padded
    (B, L) neighbor *tile* the Pallas ``hype_scores`` kernel consumes
    (``neighbor_tile``), and a direct vectorized count
    (``batched_dext_numpy``) for engines that score on host.
  * JAX side — ``batched_dext_jax``: gather + sort + first-occurrence
    segment counting over padded incidence arrays. O(W log W) per
    candidate with W = max_deg * max_size, independent of n — this
    replaces the old O(n) dense-membership-mask-per-candidate scoring.

Tile contract (matches kernels/hype_score): rows are pre-deduplicated
neighbor lists, -1 padded; *assigned* neighbors and the candidate itself
are dropped on the host, so

    kernel_score = #valid - #(valid ∩ fringe) = |N(v) ∩ V'|

exactly the engines' "universe" d_ext. Tile shapes are bucketed (B padded
to a fixed batch, L to ``L_BUCKETS``) so the jitted kernel retraces only a
handful of times per process.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

# Width buckets for the (B, L) kernel tile: each distinct L traces the
# jitted Pallas call once (~0.15 s in interpret mode), so keep the set
# small. Rows wider than the last bucket are truncated and penalized.
L_BUCKETS = (32, 128, 512, 2048)
# Score added to candidates whose neighbor scan was truncated: they
# compare as "huge neighborhood" (same convention as HypeParams.dext_cap).
TRUNC_PENALTY = 1e12


def gather_csr_rows(indptr: np.ndarray, indices: np.ndarray,
                    ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Concatenate CSR slices ``indices[indptr[i]:indptr[i+1]]`` for ``ids``.

    Returns ``(values, owner)`` where ``owner[j]`` is the position in
    ``ids`` that produced ``values[j]``. Fully vectorized (no per-row
    Python loop).
    """
    ids = np.asarray(ids, dtype=np.int64)
    starts = indptr[ids].astype(np.int64)
    lens = (indptr[ids + 1] - indptr[ids]).astype(np.int64)
    total = int(lens.sum())
    if total == 0:
        return (np.empty(0, dtype=indices.dtype),
                np.empty(0, dtype=np.int64))
    out_start = np.cumsum(lens) - lens
    pos = (np.arange(total, dtype=np.int64)
           - np.repeat(out_start, lens) + np.repeat(starts, lens))
    owner = np.repeat(np.arange(ids.size, dtype=np.int64), lens)
    return indices[pos], owner


def _bucket_width(width: int) -> int:
    for b in L_BUCKETS:
        if width <= b:
            return b
    return L_BUCKETS[-1]


def _pin_budget(erow: np.ndarray, elen: np.ndarray, rows: int,
                cap: int) -> Tuple[np.ndarray, np.ndarray]:
    """Per-row pin budget over row-major (owner, length) edge pairs.

    Keeps whole edges until a row's cumulative pin count reaches ``cap``
    (hub protection). Returns ``(keep, truncated)``: a mask over the edge
    pairs and the per-row truncation flags — the single source of truth
    for the budget semantics shared by the kernel-tile and host paths.
    """
    excl = np.cumsum(elen) - elen
    row_first = np.searchsorted(erow, np.arange(rows, dtype=np.int64))
    # rows with no edges point past the end; they contribute nothing
    row_base = np.zeros(rows, dtype=np.int64)
    has = row_first < erow.size
    row_base[has] = excl[row_first[has]]
    keep = (excl - row_base[erow]) < cap
    truncated = np.zeros(rows, dtype=bool)
    np.logical_or.at(truncated, erow[~keep], True)
    return keep, truncated


def neighbor_tile_adj(adj, cands: np.ndarray, assignment: np.ndarray, *,
                      pad_b: int | None = None
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """(B, L) tile from a precomputed adjacency CSR — gather only, no sort.

    ``adj`` is ``Hypergraph.vertex_adjacency()`` output: rows are already
    unique neighbor lists with self excluded, so building the tile is one
    CSR gather + an assigned-filter + a compacting scatter. Rows with more
    than ``L_BUCKETS[-1]`` surviving neighbors are truncated and flagged.
    """
    indptr, indices = adj
    cands = np.asarray(cands, dtype=np.int64)
    B = cands.size
    rows_out = pad_b or max(B, 1)
    if B == 0:
        return (np.full((rows_out, L_BUCKETS[0]), -1, np.int32),
                np.zeros(0, dtype=bool))
    nbrs, prow = gather_csr_rows(indptr, indices, cands)
    truncated = np.zeros(B, dtype=bool)
    if nbrs.size:
        nbrs = nbrs.astype(np.int64)
        keep = assignment[nbrs] < 0
        nbrs, prow = nbrs[keep], prow[keep]
    if nbrs.size:
        counts = np.bincount(prow, minlength=B)
        row_start = np.cumsum(counts) - counts
        offs = np.arange(nbrs.size, dtype=np.int64) - row_start[prow]
        max_w = L_BUCKETS[-1]
        truncated |= counts > max_w
        keep2 = offs < max_w
        prow, nbrs, offs = prow[keep2], nbrs[keep2], offs[keep2]
        L = _bucket_width(int(counts.clip(max=max_w).max()))
        tile = np.full((rows_out, L), -1, np.int32)
        tile[prow, offs] = nbrs
    else:
        tile = np.full((rows_out, L_BUCKETS[0]), -1, np.int32)
    return tile, truncated


def batched_dext_adj(adj, vs: np.ndarray, in_fringe: np.ndarray,
                     assignment: np.ndarray) -> np.ndarray:
    """d_ext over a precomputed adjacency CSR.

    Applies the same hub convention as ``neighbor_tile_adj``: vertices
    with more than ``L_BUCKETS[-1]`` unassigned neighbors (the tile width
    cut) get ``TRUNC_PENALTY`` added, so a candidate scores as a "huge
    neighborhood" hub regardless of which path scored it.
    """
    vs = np.asarray(vs, dtype=np.int64)
    if vs.size == 0:
        return np.zeros(0, dtype=np.float64)
    indptr, indices = adj
    nbrs, prow = gather_csr_rows(indptr, indices, vs)
    if not nbrs.size:
        return np.zeros(vs.size, dtype=np.float64)
    nbrs = nbrs.astype(np.int64)
    unassigned = assignment[nbrs] < 0
    ext = (~in_fringe[nbrs]) & unassigned
    scores = np.bincount(prow[ext], minlength=vs.size).astype(np.float64)
    wide = np.bincount(prow[unassigned],
                       minlength=vs.size) > L_BUCKETS[-1]
    scores[wide] += TRUNC_PENALTY
    return scores


def neighbor_tile(hg, cands: np.ndarray, assignment: np.ndarray, *,
                  cap_pins: int = 8192, pad_b: int | None = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Build the dense (B, L) neighbor tile for a candidate batch.

    For each candidate v, the row holds the *unique unassigned* neighbors
    of v (v itself excluded), -1 padded. Per-candidate work is capped at
    ``cap_pins`` scanned pins / ``L_BUCKETS[-1]`` unique neighbors; capped
    rows are flagged in the returned ``truncated`` mask and must receive a
    large score penalty (hubs compare as "huge neighborhood", which is
    what the paper's score wants anyway).

    Returns ``(tile, truncated)``: tile is int32 (pad_b or B, L) with L in
    ``L_BUCKETS``; truncated is bool (B,).
    """
    cands = np.asarray(cands, dtype=np.int64)
    B = cands.size
    rows_out = pad_b or max(B, 1)
    n = hg.n
    if B == 0:
        return (np.full((rows_out, L_BUCKETS[0]), -1, np.int32),
                np.zeros(0, dtype=bool))

    edges, erow = gather_csr_rows(hg.v2e_indptr, hg.v2e_indices, cands)
    edges = edges.astype(np.int64)
    truncated = np.zeros(B, dtype=bool)
    if edges.size:
        elen = (hg.e2v_indptr[edges + 1] - hg.e2v_indptr[edges]).astype(
            np.int64)
        keep, truncated = _pin_budget(erow, elen, B, cap_pins)
        edges, erow = edges[keep], erow[keep]

    pins, pidx = gather_csr_rows(hg.e2v_indptr, hg.e2v_indices, edges)
    prow = erow[pidx] if pins.size else pidx
    if pins.size:
        pins = pins.astype(np.int64)
        ok = (assignment[pins] < 0) & (pins != cands[prow])
        pins, prow = pins[ok], prow[ok]

    if pins.size:
        key = np.unique(prow * np.int64(n) + pins)
        prow2 = key // n
        pins2 = key % n
        counts = np.bincount(prow2, minlength=B)
        row_start = np.zeros(B, dtype=np.int64)
        row_start[1:] = np.cumsum(counts)[:-1]
        offs = np.arange(key.size, dtype=np.int64) - row_start[prow2]
        max_w = L_BUCKETS[-1]
        wide = counts > max_w
        truncated |= wide
        keep2 = offs < max_w
        prow2, pins2, offs = prow2[keep2], pins2[keep2], offs[keep2]
        L = _bucket_width(int(counts.clip(max=max_w).max()))
        tile = np.full((rows_out, L), -1, np.int32)
        tile[prow2, offs] = pins2
    else:
        tile = np.full((rows_out, L_BUCKETS[0]), -1, np.int32)
    return tile, truncated


def batched_dext_numpy(hg, vs: np.ndarray, in_fringe: np.ndarray,
                       assignment: np.ndarray, *,
                       cap_pins: int | None = None,
                       max_width: int | None = None) -> np.ndarray:
    """Vectorized d_ext(v, F) = |N(v) ∩ V'| for a batch of vertices.

    One pass over the concatenated pin lists of all candidates: gather,
    dedup (vertex, neighbor) pairs, count external ones. Bit-identical to
    ``hype.py``'s per-vertex d_ext in the default "universe" mode when
    ``cap_pins`` and ``max_width`` are None. ``cap_pins`` truncates the
    per-candidate pin scan; ``max_width`` applies the kernel tile's
    width cut (> max_width unique unassigned neighbors). Either
    truncation adds ``TRUNC_PENALTY`` (same convention as the tile path
    and HypeParams.dext_cap).
    """
    vs = np.asarray(vs, dtype=np.int64)
    if vs.size == 0:
        return np.zeros(0, dtype=np.float64)
    n = hg.n
    edges, erow = gather_csr_rows(hg.v2e_indptr, hg.v2e_indices, vs)
    edges = edges.astype(np.int64)
    truncated = np.zeros(vs.size, dtype=bool)
    if cap_pins is not None and edges.size:
        elen = (hg.e2v_indptr[edges + 1] - hg.e2v_indptr[edges]).astype(
            np.int64)
        keep, truncated = _pin_budget(erow, elen, vs.size, cap_pins)
        edges, erow = edges[keep], erow[keep]
    pins, pidx = gather_csr_rows(hg.e2v_indptr, hg.e2v_indices, edges)
    scores = np.zeros(vs.size, dtype=np.float64)
    if pins.size:
        prow = erow[pidx]
        key = np.unique(prow * np.int64(n) + pins.astype(np.int64))
        prow2 = key // n
        pins2 = key % n
        unassigned = assignment[pins2] < 0
        ext = (~in_fringe[pins2]) & unassigned
        scores = np.bincount(prow2[ext], minlength=vs.size).astype(
            np.float64)
        # v itself is a pin of each incident edge: counted once iff it is
        # still "external" and has at least one edge.
        deg = hg.v2e_indptr[vs + 1] - hg.v2e_indptr[vs]
        self_ext = (~in_fringe[vs]) & (assignment[vs] < 0) & (deg > 0)
        scores = np.maximum(scores - self_ext, 0.0)
        if max_width is not None:
            nonself = pins2 != vs[prow2]
            wide = np.bincount(prow2[unassigned & nonself],
                               minlength=vs.size) > max_width
            scores[wide] += TRUNC_PENALTY
    scores[truncated] += TRUNC_PENALTY
    return scores


# ------------------------------------------------------------- superstep
# Device-resident superstep program: one jitted call performs the whole
# per-superstep device work of the superstep engine (hype_batched.py) —
# apply the host's injection delta (seeds / restarts), decrement-
# invalidate the cached scores of the delta's neighbors, gather the
# fresh candidate tiles from the device CSR, run the fused score+select
# kernel, write the fresh scores back into the device cache, and apply
# the per-phase admissions *on device*: stale proposals (candidates
# assigned by an interleaved superstep of the pipeline) are masked out,
# and the per-phase remaining-target cap is enforced against a device-
# resident admission counter. Winner-neighbor decrements ride the NEXT
# dispatch's host-preaggregated dirty pairs (the lock-step schedule).
# Only ids cross the host boundary, and the (n,)-sized assignment/cache
# (plus the (k,) counter) are *donated* — each superstep updates the
# image in place instead of copying it.

import functools as _functools


def _apply_host_injections(assign, cache, acc, delta_ids, delta_vals,
                           dirty_ids, dirty_counts):
    """Traced prefix shared by BOTH superstep programs.

    Applies the host's injection delta (seeds / restarts) to the
    assignment, counts the injections into the per-phase admission
    totals, and applies the pre-aggregated (unique id, count) dirty
    decrements to the score cache. Keeping this in one function is what
    keeps the single-device and sharded programs semantically identical
    — edit here, not in the program bodies.
    """
    import jax.numpy as jnp

    n = assign.shape[0]
    inj = delta_ids >= 0
    assign = assign.at[jnp.where(inj, delta_ids, n)].set(
        delta_vals, mode="drop")
    acc = acc.at[jnp.where(inj, delta_vals, acc.shape[0])].add(
        1, mode="drop")
    cache = cache.at[jnp.where(dirty_ids >= 0, dirty_ids, n)].add(
        -dirty_counts, mode="drop")
    return assign, cache, acc


def _gather_fresh_tiles(indptr, indices, assign, flat, tile_l):
    """Traced helper shared by both superstep programs.

    Gathers the flat fresh-candidate ids' CSR rows at static width
    ``tile_l``; assigned neighbors are masked to -1 *in place* (no
    compaction — the kernel counts valid entries, not positions).
    """
    import jax
    import jax.numpy as jnp

    fsafe = jnp.where(flat >= 0, flat, 0)
    fstart = indptr[fsafe]
    fdeg = indptr[fsafe + 1] - fstart
    col = jax.lax.broadcasted_iota(jnp.int32, (flat.shape[0], tile_l), 1)
    fvalid = (col < fdeg[:, None]) & (flat >= 0)[:, None]
    nbr = indices[jnp.where(fvalid, fstart[:, None] + col, 0)]
    unassigned = assign[jnp.where(fvalid, nbr, 0)] < 0
    return jnp.where(fvalid & unassigned, nbr, -1).astype(jnp.int32)


def _stale_masked_prev(pool, assign, cache):
    """Traced helper shared by both superstep programs.

    Held pool scores ride along from the device cache; slots that went
    stale (assigned by an interleaved superstep of the pipeline) are
    masked to +inf so selection skips them and takes the phase's
    next-best candidate. Returns ``(prev, n_stale)``.
    """
    import jax.numpy as jnp

    psafe = jnp.where(pool >= 0, pool, 0)
    pool_ok = (pool >= 0) & (assign[psafe] < 0)
    prev = jnp.where(pool_ok, cache[psafe], jnp.inf).astype(jnp.float32)
    n_stale = ((pool >= 0) & ~pool_ok).sum().astype(jnp.int32)
    return prev, n_stale


def _poison_guard(flat, scores_flat, poison, reset):
    """Traced NaN/inf quarantine shared by both superstep programs.

    A superstep whose fresh scores contain a non-finite value (a
    poisoned tile — injected by a ``FaultPlan`` or a real device fault)
    must not be admitted: the program reverts ALL its mutations and
    raises the sticky ``poison`` flag so any in-flight superstep
    dispatched after it self-aborts too, preserving device-effect order
    for the host's in-order replay (DESIGN.md §4f). ``reset`` is the
    host's replay marker: a replay ignores the sticky flag (the host
    replays the whole aborted window in order) but still re-checks its
    own fresh scores. Pad rows (``flat < 0``) legitimately carry +inf
    bias and are excluded. Returns the replicated ``poisoned`` bool.
    """
    import jax.numpy as jnp

    bad = ((flat >= 0) & ~jnp.isfinite(scores_flat)).any()
    return bad | ((poison[0] > 0) & (reset[0] == 0))


@_functools.lru_cache(maxsize=None)
def _pipeline_program():
    import jax
    import jax.numpy as jnp
    from repro.kernels.hype_score.kernel import SELECT_PAD
    from repro.kernels.hype_score.ops import hype_score_select

    # poison is NOT donated: at pipeline depth > 1 each in-flight handle
    # keeps a reference to its own poison output, which the next
    # dispatch would otherwise consume before harvest can read it —
    # and it is 4 bytes, so donation buys nothing.
    @_functools.partial(
        jax.jit, static_argnames=("tile_l", "select_k", "interpret"),
        donate_argnums=(2, 3, 4))
    def step(indptr, indices, assign, cache, acc, poison, delta_ids,
             delta_vals, dirty_ids, dirty_counts, fresh, bias, pool,
             fringe, targets, reset, *, tile_l, select_k, interpret):
        n = assign.shape[0]
        G, R = fresh.shape
        assign0, cache0, acc0 = assign, cache, acc
        # 1.-2. host injections (seeds / restarts — decrement-exact: the
        #    dirty pairs carry their pre-aggregated neighbor multiset
        #    plus earlier winners' queued decrements); the host only
        #    injects vertices that cannot sit in any in-flight slot, so
        #    the scatter is race-free at any pipeline depth.
        assign, cache, acc = _apply_host_injections(
            assign, cache, acc, delta_ids, delta_vals, dirty_ids,
            dirty_counts)
        # 3. gather fresh candidate tiles from the device CSR
        flat = fresh.reshape(-1)
        tile = _gather_fresh_tiles(indptr, indices, assign, flat, tile_l)
        # 4. held pool scores, stale slots masked (the redraw rule)
        prev, n_stale = _stale_masked_prev(pool, assign, cache)
        # 5. fused score + per-phase top-select
        scores, sel_idx, sel_val = hype_score_select(
            tile.reshape(G, R, tile_l), fringe, bias, prev,
            select_k=select_k, interpret=interpret)
        # 6. fresh scores enter the cache (pad rows dropped)
        cache = cache.at[jnp.where(flat >= 0, flat, n)].set(
            scores.reshape(-1), mode="drop")
        # 7. map selected slots to vertex ids; admissible = a real score
        #    on a still-unassigned id. The per-phase cap is the phase's
        #    remaining target, computed against the *device* totals —
        #    the host view may lag the pipeline, the device never does.
        slots = jnp.concatenate([fresh, pool], axis=1)
        cand = jnp.take_along_axis(slots, sel_idx, axis=1)
        ok = (sel_val < jnp.float32(SELECT_PAD)) & (cand >= 0)
        ok &= assign[jnp.where(cand >= 0, cand, 0)] < 0
        cap = jnp.maximum(targets - acc, 0)
        rank = jnp.cumsum(ok.astype(jnp.int32), axis=1)
        adm = ok & (rank <= cap[:, None])
        winners = jnp.where(adm, cand, -1)
        # 8. apply the winners on device (the host mirrors them at
        #    harvest time, possibly supersteps later). Their score-cache
        #    decrements stay HOST-side: the harvest pre-aggregates the
        #    winners' neighbor multiset into the next dispatch's dirty
        #    pairs — shipping (unique id, count) pairs is far cheaper
        #    than a (G*t, tile_l) gather+scatter here, and at depth 1 it
        #    reproduces the lock-step decrement schedule exactly.
        phase_row = jax.lax.broadcasted_iota(jnp.int32, adm.shape, 0)
        assign = assign.at[jnp.where(adm, cand, n)].set(
            phase_row, mode="drop")
        acc = acc + adm.sum(axis=1, dtype=acc.dtype)
        # 9. NaN/inf quarantine: a poisoned superstep reverts every
        #    mutation and admits nothing; the host replays it from the
        #    handle's buffers (reset=1). A no-op select when clean, so
        #    fault-free runs stay bit-identical.
        poisoned = _poison_guard(flat, scores.reshape(-1), poison, reset)
        assign = jnp.where(poisoned, assign0, assign)
        cache = jnp.where(poisoned, cache0, cache)
        acc = jnp.where(poisoned, acc0, acc)
        winners = jnp.where(poisoned, -1, winners)
        n_stale = jnp.where(poisoned, 0, n_stale)
        poison = poisoned.astype(jnp.int32)[None]
        return assign, cache, acc, poison, winners, n_stale

    return step


def pipeline_superstep_device(indptr, indices, assign, cache, acc,
                              poison, delta_ids, delta_vals, dirty_ids,
                              dirty_counts, fresh, bias, pool, fringe,
                              targets, reset, *, tile_l: int,
                              select_k: int, interpret: bool):
    """Run one device superstep; see ``_pipeline_program`` for the plan.

    All array arguments are device-resident jax arrays except the small
    per-superstep id buffers (delta, dirty, fresh, bias, pool, fringe,
    targets, reset), which are the only host->device traffic.
    ``assign``, ``cache``, ``acc`` and ``poison`` are DONATED — callers
    must keep the returned arrays and never touch the inputs again.
    ``poison`` is the sticky (1,) int32 quarantine flag threaded
    through the run (see ``_poison_guard``); ``reset`` is the (1,)
    int32 replay marker. ``tile_l`` is a static gather width (bucketed
    by the caller so the program retraces only a handful of times);
    ``select_k`` is the per-phase admission count.
    Returns ``(assign', cache', acc', poison', winners, n_stale)``
    where ``winners`` is (G, select_k) int32 admitted ids (-1 = none),
    ``n_stale`` counts pool slots skipped because an interleaved
    superstep of the pipeline had already assigned them, and
    ``poison'[0] > 0`` means the superstep aborted (nothing applied)
    and must be replayed by the host.
    """
    return _pipeline_program()(
        indptr, indices, assign, cache, acc, poison, delta_ids,
        delta_vals, dirty_ids, dirty_counts, fresh, bias, pool, fringe,
        targets, reset, tile_l=tile_l, select_k=select_k,
        interpret=interpret)


# ------------------------------------------------- memory-rung variants
# Program variants for the memory-budget rung ladder (core/membudget.py,
# DESIGN.md §4g). Each shares the traced helpers above with
# ``_pipeline_program`` — the default program is deliberately left
# untouched (its depth-1 outputs are golden-hashed), and every variant
# is bit-exact to it on the single-device engine:
#
#   * ``_chunked_program``   — scores the G phases in ``g_chunk``
#     sequential slices (``lax.map``), dividing the peak (G·R, tile_l)
#     gather-tile footprint by ``g_chunk``. Phases are independent
#     until admission (selection runs against the pre-winner assignment
#     snapshot), so chunked scoring computes the same scores in the
#     same order.
#   * ``_spill_program``     — no device score cache: the host keeps a
#     float32 mirror, applies the dirty decrements itself (IEEE-
#     identical float32 adds of integer counts) and ships the held-pool
#     scores in; fresh scores return with the winners. Depth-1 only.
#   * ``_paged_program``     — takes the *pre-gathered raw* neighbor
#     tile (built chunk-by-chunk by ``membudget.PagedAdjacency``) and
#     applies the assignment masking in-program, reproducing
#     ``_gather_fresh_tiles``'s output exactly without a resident CSR.


@_functools.lru_cache(maxsize=None)
def _chunked_program():
    import jax
    import jax.numpy as jnp
    from repro.kernels.hype_score.kernel import SELECT_PAD
    from repro.kernels.hype_score.ops import hype_score_select

    @_functools.partial(
        jax.jit,
        static_argnames=("tile_l", "select_k", "interpret", "g_chunk"),
        donate_argnums=(2, 3, 4))
    def step(indptr, indices, assign, cache, acc, poison, delta_ids,
             delta_vals, dirty_ids, dirty_counts, fresh, bias, pool,
             fringe, targets, reset, *, tile_l, select_k, interpret,
             g_chunk):
        n = assign.shape[0]
        G, R = fresh.shape
        assign0, cache0, acc0 = assign, cache, acc
        assign, cache, acc = _apply_host_injections(
            assign, cache, acc, delta_ids, delta_vals, dirty_ids,
            dirty_counts)
        prev, n_stale = _stale_masked_prev(pool, assign, cache)
        # phase-chunked gather + score: pad G to a g_chunk multiple
        # (pad phases carry -1 candidates / +inf bias, so they select
        # nothing), then lax.map the gather + fused kernel over the
        # chunks — sequential execution divides the peak tile bytes by
        # g_chunk while computing the exact scores of the full call.
        Gc = -(-G // g_chunk)
        pad = g_chunk * Gc - G

        def padg(a, fill):
            if pad == 0:
                return a
            return jnp.concatenate(
                [a, jnp.full((pad,) + a.shape[1:], fill, a.dtype)])

        fresh_p = padg(fresh, -1).reshape(g_chunk, Gc, R)
        bias_p = padg(bias, jnp.inf).reshape(g_chunk, Gc, R)
        prev_p = padg(prev, jnp.inf).reshape(g_chunk, Gc, prev.shape[1])
        fringe_p = padg(fringe, -1).reshape(
            g_chunk, Gc, fringe.shape[1])

        def score_chunk(args):
            fr_c, bi_c, pr_c, fg_c = args
            flat_c = fr_c.reshape(-1)
            tile_c = _gather_fresh_tiles(indptr, indices, assign,
                                         flat_c, tile_l)
            return hype_score_select(
                tile_c.reshape(Gc, R, tile_l), fg_c, bi_c, pr_c,
                select_k=select_k, interpret=interpret)

        scores_c, sel_idx_c, sel_val_c = jax.lax.map(
            score_chunk, (fresh_p, bias_p, prev_p, fringe_p))
        scores = scores_c.reshape(g_chunk * Gc, R)[:G]
        sel_idx = sel_idx_c.reshape(g_chunk * Gc, select_k)[:G]
        sel_val = sel_val_c.reshape(g_chunk * Gc, select_k)[:G]
        # steps 6-9 of _pipeline_program, verbatim
        flat = fresh.reshape(-1)
        cache = cache.at[jnp.where(flat >= 0, flat, n)].set(
            scores.reshape(-1), mode="drop")
        slots = jnp.concatenate([fresh, pool], axis=1)
        cand = jnp.take_along_axis(slots, sel_idx, axis=1)
        ok = (sel_val < jnp.float32(SELECT_PAD)) & (cand >= 0)
        ok &= assign[jnp.where(cand >= 0, cand, 0)] < 0
        cap = jnp.maximum(targets - acc, 0)
        rank = jnp.cumsum(ok.astype(jnp.int32), axis=1)
        adm = ok & (rank <= cap[:, None])
        winners = jnp.where(adm, cand, -1)
        phase_row = jax.lax.broadcasted_iota(jnp.int32, adm.shape, 0)
        assign = assign.at[jnp.where(adm, cand, n)].set(
            phase_row, mode="drop")
        acc = acc + adm.sum(axis=1, dtype=acc.dtype)
        poisoned = _poison_guard(flat, scores.reshape(-1), poison, reset)
        assign = jnp.where(poisoned, assign0, assign)
        cache = jnp.where(poisoned, cache0, cache)
        acc = jnp.where(poisoned, acc0, acc)
        winners = jnp.where(poisoned, -1, winners)
        n_stale = jnp.where(poisoned, 0, n_stale)
        poison = poisoned.astype(jnp.int32)[None]
        return assign, cache, acc, poison, winners, n_stale

    return step


def chunked_superstep_device(indptr, indices, assign, cache, acc,
                             poison, delta_ids, delta_vals, dirty_ids,
                             dirty_counts, fresh, bias, pool, fringe,
                             targets, reset, *, tile_l: int,
                             select_k: int, interpret: bool,
                             g_chunk: int):
    """``pipeline_superstep_device`` with phase-chunked scoring.

    Identical contract and bit-identical outputs; ``g_chunk`` slices
    the gather + fused-kernel stage so only 1/g_chunk of the phases'
    tiles is materialized at a time (memory rung 1+, DESIGN.md §4g).
    """
    return _chunked_program()(
        indptr, indices, assign, cache, acc, poison, delta_ids,
        delta_vals, dirty_ids, dirty_counts, fresh, bias, pool, fringe,
        targets, reset, tile_l=tile_l, select_k=select_k,
        interpret=interpret, g_chunk=g_chunk)


@_functools.lru_cache(maxsize=None)
def _spill_program():
    import jax
    import jax.numpy as jnp
    from repro.kernels.hype_score.kernel import SELECT_PAD
    from repro.kernels.hype_score.ops import hype_score_select

    @_functools.partial(
        jax.jit, static_argnames=("tile_l", "select_k", "interpret"),
        donate_argnums=(2, 3))
    def step(indptr, indices, assign, acc, poison, delta_ids,
             delta_vals, fresh, bias, pool, prev_host, fringe, targets,
             reset, *, tile_l, select_k, interpret):
        n = assign.shape[0]
        G, R = fresh.shape
        assign0, acc0 = assign, acc
        # injections only — the dirty decrements were applied to the
        # HOST cache mirror at pack time (identical float32 arithmetic)
        inj = delta_ids >= 0
        assign = assign.at[jnp.where(inj, delta_ids, n)].set(
            delta_vals, mode="drop")
        acc = acc.at[jnp.where(inj, delta_vals, acc.shape[0])].add(
            1, mode="drop")
        flat = fresh.reshape(-1)
        tile = _gather_fresh_tiles(indptr, indices, assign, flat, tile_l)
        # held pool scores arrive from the host mirror; staleness is
        # still masked on device against the post-injection assignment
        psafe = jnp.where(pool >= 0, pool, 0)
        pool_ok = (pool >= 0) & (assign[psafe] < 0)
        prev = jnp.where(pool_ok, prev_host, jnp.inf).astype(jnp.float32)
        n_stale = ((pool >= 0) & ~pool_ok).sum().astype(jnp.int32)
        scores, sel_idx, sel_val = hype_score_select(
            tile.reshape(G, R, tile_l), fringe, bias, prev,
            select_k=select_k, interpret=interpret)
        slots = jnp.concatenate([fresh, pool], axis=1)
        cand = jnp.take_along_axis(slots, sel_idx, axis=1)
        ok = (sel_val < jnp.float32(SELECT_PAD)) & (cand >= 0)
        ok &= assign[jnp.where(cand >= 0, cand, 0)] < 0
        cap = jnp.maximum(targets - acc, 0)
        rank = jnp.cumsum(ok.astype(jnp.int32), axis=1)
        adm = ok & (rank <= cap[:, None])
        winners = jnp.where(adm, cand, -1)
        phase_row = jax.lax.broadcasted_iota(jnp.int32, adm.shape, 0)
        assign = assign.at[jnp.where(adm, cand, n)].set(
            phase_row, mode="drop")
        acc = acc + adm.sum(axis=1, dtype=acc.dtype)
        poisoned = _poison_guard(flat, scores.reshape(-1), poison, reset)
        assign = jnp.where(poisoned, assign0, assign)
        acc = jnp.where(poisoned, acc0, acc)
        winners = jnp.where(poisoned, -1, winners)
        n_stale = jnp.where(poisoned, 0, n_stale)
        poison = poisoned.astype(jnp.int32)[None]
        # fresh scores return to the host, which owns the cache now;
        # the host only writes them after the poison check
        return assign, acc, poison, winners, n_stale, scores

    return step


def spill_superstep_device(indptr, indices, assign, acc, poison,
                           delta_ids, delta_vals, fresh, bias, pool,
                           prev_host, fringe, targets, reset, *,
                           tile_l: int, select_k: int, interpret: bool):
    """``pipeline_superstep_device`` with the score cache spilled to host.

    The (n,) float32 cache lives on host (memory rung 4, depth-1 only):
    the caller applies dirty decrements to its mirror, ships the held
    pool's ``prev_host`` scores in, and writes the returned ``scores``
    back at harvest. All arithmetic the device skipped is IEEE-exact
    float32 on host, so results match the resident-cache program bit
    for bit at depth 1. ``assign``/``acc`` are DONATED.
    Returns ``(assign', acc', poison', winners, n_stale, scores)``.
    """
    return _spill_program()(
        indptr, indices, assign, acc, poison, delta_ids, delta_vals,
        fresh, bias, pool, prev_host, fringe, targets, reset,
        tile_l=tile_l, select_k=select_k, interpret=interpret)


@_functools.lru_cache(maxsize=None)
def _paged_program():
    import jax
    import jax.numpy as jnp
    from repro.kernels.hype_score.kernel import SELECT_PAD
    from repro.kernels.hype_score.ops import hype_score_select

    @_functools.partial(
        jax.jit, static_argnames=("select_k", "interpret"),
        donate_argnums=(0, 1, 2))
    def step(assign, cache, acc, poison, delta_ids, delta_vals,
             dirty_ids, dirty_counts, tile_raw, fresh, bias, pool,
             fringe, targets, reset, *, select_k, interpret):
        n = assign.shape[0]
        G, R = fresh.shape
        tile_l = tile_raw.shape[1]
        assign0, cache0, acc0 = assign, cache, acc
        assign, cache, acc = _apply_host_injections(
            assign, cache, acc, delta_ids, delta_vals, dirty_ids,
            dirty_counts)
        flat = fresh.reshape(-1)
        # the raw tile was gathered from the paged CSR before this call;
        # masking assigned neighbors here — against the post-injection
        # assignment — reproduces _gather_fresh_tiles's output exactly
        valid = tile_raw >= 0
        unassigned = assign[jnp.where(valid, tile_raw, 0)] < 0
        tile = jnp.where(valid & unassigned, tile_raw,
                         -1).astype(jnp.int32)
        prev, n_stale = _stale_masked_prev(pool, assign, cache)
        scores, sel_idx, sel_val = hype_score_select(
            tile.reshape(G, R, tile_l), fringe, bias, prev,
            select_k=select_k, interpret=interpret)
        cache = cache.at[jnp.where(flat >= 0, flat, n)].set(
            scores.reshape(-1), mode="drop")
        slots = jnp.concatenate([fresh, pool], axis=1)
        cand = jnp.take_along_axis(slots, sel_idx, axis=1)
        ok = (sel_val < jnp.float32(SELECT_PAD)) & (cand >= 0)
        ok &= assign[jnp.where(cand >= 0, cand, 0)] < 0
        cap = jnp.maximum(targets - acc, 0)
        rank = jnp.cumsum(ok.astype(jnp.int32), axis=1)
        adm = ok & (rank <= cap[:, None])
        winners = jnp.where(adm, cand, -1)
        phase_row = jax.lax.broadcasted_iota(jnp.int32, adm.shape, 0)
        assign = assign.at[jnp.where(adm, cand, n)].set(
            phase_row, mode="drop")
        acc = acc + adm.sum(axis=1, dtype=acc.dtype)
        poisoned = _poison_guard(flat, scores.reshape(-1), poison, reset)
        assign = jnp.where(poisoned, assign0, assign)
        cache = jnp.where(poisoned, cache0, cache)
        acc = jnp.where(poisoned, acc0, acc)
        winners = jnp.where(poisoned, -1, winners)
        n_stale = jnp.where(poisoned, 0, n_stale)
        poison = poisoned.astype(jnp.int32)[None]
        return assign, cache, acc, poison, winners, n_stale

    return step


def paged_superstep_device(assign, cache, acc, poison, delta_ids,
                           delta_vals, dirty_ids, dirty_counts,
                           tile_raw, fresh, bias, pool, fringe, targets,
                           reset, *, select_k: int, interpret: bool):
    """``pipeline_superstep_device`` without a resident CSR image.

    ``tile_raw`` is the (G·R, tile_l) *unmasked* neighbor-id tile
    assembled by ``membudget.PagedAdjacency.gather`` (memory rung 5);
    the program applies the assignment masking itself, so the scores —
    and therefore the whole run — are bit-identical to the
    resident-image engine. The single-device program's only other CSR
    use (winner decrements) already lives host-side, which is what
    makes this rung possible at all. ``assign``/``cache``/``acc`` are
    DONATED. Returns ``(assign', cache', acc', poison', winners,
    n_stale)``.
    """
    return _paged_program()(
        assign, cache, acc, poison, delta_ids, delta_vals, dirty_ids,
        dirty_counts, tile_raw, fresh, bias, pool, fringe, targets,
        reset, select_k=select_k, interpret=interpret)


# ---------------------------------------------------------- sharded superstep
# Mesh-sharded superstep program: the per-superstep device work of the
# sharded engine, run under shard_map over a 1-D device mesh. The CSR
# image, assignment and score cache are *replicated* on every device;
# the k phase groups are sharded — each device gathers, scores and
# selects only its own contiguous group of phases, then ONE all_gather
# per superstep exchanges (fresh scores | admissions) so every replica
# applies the same cache writes, conflict resolution and exact-decrement
# invalidations. Replicas therefore stay bit-identical without ever
# shipping the (n,)-sized state between devices.


@_functools.lru_cache(maxsize=None)
def _sharded_mesh(num_devices: int):
    """1-D device mesh over the first ``num_devices`` local devices."""
    import jax
    import numpy as _np
    from jax.sharding import Mesh

    return Mesh(_np.asarray(jax.devices()[:num_devices]), ("shard",))


@_functools.lru_cache(maxsize=None)
def _sharded_program(num_devices: int, group_l: int, tile_l: int,
                     select_k: int, interpret: bool):
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.kernels.hype_score.kernel import SELECT_PAD
    from repro.kernels.hype_score.ops import hype_score_select_shard

    kL = group_l

    def step(indptr, indices, assign, cache, acc, poison, delta_ids,
             delta_vals, dirty_ids, dirty_counts, fresh, bias, pool,
             fringe, targets, reset):
        n = assign.shape[0]
        G, R = fresh.shape
        t = select_k
        assign0, cache0, acc0 = assign, cache, acc
        # 1. host injections + dirty decrements — replicated inputs,
        #    applied identically on every replica (shared helper keeps
        #    this program bit-aligned with the single-device one)
        assign, cache, acc = _apply_host_injections(
            assign, cache, acc, delta_ids, delta_vals, dirty_ids,
            dirty_counts)
        # 2. this device's phase-group shard; the admission cap is each
        #    phase's remaining target per the *device* totals (the host
        #    view may lag the pipeline, the replicas never do)
        off = jax.lax.axis_index("shard") * kL
        fresh_l = jax.lax.dynamic_slice_in_dim(fresh, off, kL, 0)
        pool_l = jax.lax.dynamic_slice_in_dim(pool, off, kL, 0)
        cap = jnp.maximum(targets - acc, 0)
        cap_l = jax.lax.dynamic_slice_in_dim(cap, off, kL, 0)
        # 3. gather ONLY the shard's fresh-candidate tiles from the
        #    replicated CSR
        flat = fresh_l.reshape(-1)
        tile = _gather_fresh_tiles(indptr, indices, assign, flat, tile_l)
        # 4. held pool scores from the replicated cache, stale slots
        #    masked — computed on the *global* pool so the count is
        #    replicated
        prev, n_stale = _stale_masked_prev(pool, assign, cache)
        # 5. fused score + top-select on the local phase group
        scores_l, sel_idx, sel_val = hype_score_select_shard(
            tile.reshape(kL, R, tile_l), fringe, bias, prev,
            select_k=t, shard_offset=off, interpret=interpret)
        # 6. map selected slots to vertex ids and apply the per-phase
        #    admission cap (remaining target): slots are score-ascending,
        #    so the cap keeps the best ``cap`` admissible ones.
        slots = jnp.concatenate([fresh_l, pool_l], axis=1)
        cand = jnp.take_along_axis(slots, sel_idx, axis=1)
        ok = (sel_val < jnp.float32(SELECT_PAD)) & (cand >= 0)
        ok &= assign[jnp.where(cand >= 0, cand, 0)] < 0
        rank = jnp.cumsum(ok.astype(jnp.int32), axis=1)
        adm = ok & (rank <= cap_l[:, None])
        adm_ids = jnp.where(adm, cand, -1)              # (kL, t)
        # 7. the superstep's single collective: all devices exchange
        #    [fresh scores | proposed admissions] in one all_gather
        payload = jnp.concatenate(
            [jax.lax.bitcast_convert_type(scores_l, jnp.int32), adm_ids],
            axis=1)                                     # (kL, R + t)
        gathered = jax.lax.all_gather(payload, "shard", axis=0,
                                      tiled=True)       # (G, R + t)
        g_scores = jax.lax.bitcast_convert_type(gathered[:, :R],
                                                jnp.float32)
        g_adm = gathered[:, R:]                         # (G, t)
        # 8. fresh scores enter every replica's cache (fresh ids are a
        #    replicated input, so the write is identical everywhere)
        flat_g = fresh.reshape(-1)
        cache = cache.at[jnp.where(flat_g >= 0, flat_g, n)].set(
            g_scores.reshape(-1), mode="drop")
        # 9. deterministic conflict resolution: when several phases
        #    propose the same vertex in one superstep, the LOWEST phase
        #    id wins; losers keep the vertex out and redraw from their
        #    pools next superstep. Sort (id, phase) pairs and keep each
        #    id's first occurrence.
        ids_f = g_adm.reshape(-1)                       # (G * t,)
        phase_f = (jax.lax.iota(jnp.int32, G * t) // t)
        ids_key = jnp.where(ids_f >= 0, ids_f, n)
        order = jnp.lexsort((phase_f, ids_key))
        sorted_ids = ids_f[order]
        first = jnp.concatenate(
            [jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]])
        win_sorted = first & (sorted_ids >= 0)
        winner = jnp.zeros((G * t,), bool).at[order].set(win_sorted)
        n_conflicts = ((ids_f >= 0) & ~winner).sum().astype(jnp.int32)
        # 10. apply the winners to every replica's assignment + totals
        assign = assign.at[jnp.where(winner, ids_f, n)].set(
            phase_f, mode="drop")
        acc = acc.at[phase_f].add(winner.astype(acc.dtype))
        # 11. exact-decrement invalidation for the winners: every
        #     neighbor of a newly assigned vertex has one fewer
        #     unassigned neighbor. Gather width is the run's tile_l;
        #     the (rare) winners with more neighbors than that get their
        #     tail decrements queued by the host into the next
        #     superstep's dirty buffer, keeping the cache exact.
        wsafe = jnp.where(winner, ids_f, 0)
        wstart = indptr[wsafe]
        wdeg = jnp.minimum(indptr[wsafe + 1] - wstart, tile_l)
        wcol = jax.lax.broadcasted_iota(jnp.int32, (G * t, tile_l), 1)
        wvalid = (wcol < wdeg[:, None]) & winner[:, None]
        wnbr = indices[jnp.where(wvalid, wstart[:, None] + wcol, 0)]
        cache = cache.at[jnp.where(wvalid, wnbr, n)].add(
            -1.0, mode="drop")
        winners = jnp.where(winner, ids_f, -1).reshape(G, t)
        # 12. NaN/inf quarantine on the *gathered* scores — replicated
        #     input to the guard, so every replica takes the same revert
        #     branch and the replicas stay bit-identical. No-op when
        #     clean (fault-free runs unchanged).
        poisoned = _poison_guard(flat_g, g_scores.reshape(-1), poison,
                                 reset)
        assign = jnp.where(poisoned, assign0, assign)
        cache = jnp.where(poisoned, cache0, cache)
        acc = jnp.where(poisoned, acc0, acc)
        winners = jnp.where(poisoned, -1, winners)
        n_conflicts = jnp.where(poisoned, 0, n_conflicts)
        n_stale = jnp.where(poisoned, 0, n_stale)
        poison = poisoned.astype(jnp.int32)[None]
        return assign, cache, acc, poison, winners, n_conflicts, n_stale

    mesh = _sharded_mesh(num_devices)
    rep = P()     # every array is replicated; devices differ via axis_index
    # poison undonated for the same reason as _pipeline_program: older
    # in-flight handles must still be able to read their poison output.
    return jax.jit(shard_map(
        step, mesh=mesh,
        in_specs=(rep,) * 16, out_specs=(rep,) * 7,
        check_rep=False), donate_argnums=(2, 3, 4))


def sharded_superstep_device(indptr, indices, assign, cache, acc,
                             poison, delta_ids, delta_vals, dirty_ids,
                             dirty_counts, fresh, bias, pool, fringe,
                             targets, reset, *, num_devices: int,
                             group_l: int, tile_l: int, select_k: int,
                             interpret: bool):
    """Run one mesh-sharded superstep; see ``_sharded_program``.

    ``fresh``/``bias``/``pool``/``fringe``/``targets`` stack all
    ``G = num_devices * group_l`` phases; each device processes the
    contiguous group ``[axis_index * group_l, ...)`` and ONE all_gather
    per call exchanges (fresh scores | proposed admissions), after which
    every replica applies identical cache writes, lowest-phase-wins
    conflict resolution and exact decrements. ``assign``/``cache``/
    ``acc``/``poison`` are DONATED — keep the returned arrays, never
    reuse the inputs. ``poison``/``reset`` are the (1,) int32 NaN
    quarantine flag and replay marker (see ``_poison_guard``); a
    poisoned superstep reverts every mutation on every replica and must
    be replayed by the host. Admission caps are each phase's remaining
    target computed against the device-resident ``acc`` totals, so they
    stay exact at any pipeline depth. Returns ``(assign', cache',
    acc', poison', winners (G, select_k) int32 ids (-1 = none),
    n_conflicts, n_stale)``.
    """
    return _sharded_program(num_devices, group_l, tile_l, select_k,
                            interpret)(
        indptr, indices, assign, cache, acc, poison, delta_ids,
        delta_vals, dirty_ids, dirty_counts, fresh, bias, pool, fringe,
        targets, reset)


# ------------------------------------------------------------ k-way refine
# Device half of the refinement subsystem (DESIGN.md §4e): one jitted
# call applies the host's admitted-move delta to the device-resident
# assignment (the same delta-scatter convention as the superstep
# programs' `_apply_host_injections`), gathers the candidate tile's
# neighbor *partitions* from the device CSR, and runs the Pallas
# `kway_gains` kernel — so screening every boundary vertex costs one
# gather + k broadcast-compares on device, and only candidate ids go
# down / (B, k) gain rows come back. The assignment is DONATED and
# threaded through the driver's screening calls exactly like the
# superstep image.


def _gather_part_tiles(indptr, indices, assign, cand, tile_l):
    """Neighbor-partition tile for ``cand`` at static width ``tile_l``.

    The refinement sibling of ``_gather_fresh_tiles``: same CSR gather,
    but rows hold the neighbors' partition ids (every neighbor, assigned
    or not) instead of unassigned vertex ids. Pads are -1.
    """
    import jax
    import jax.numpy as jnp

    csafe = jnp.where(cand >= 0, cand, 0)
    start = indptr[csafe]
    deg = indptr[csafe + 1] - start
    col = jax.lax.broadcasted_iota(jnp.int32, (cand.shape[0], tile_l), 1)
    valid = (col < deg[:, None]) & (cand >= 0)[:, None]
    nbr = indices[jnp.where(valid, start[:, None] + col, 0)]
    return jnp.where(valid, assign[nbr], -1).astype(jnp.int32)


@_functools.lru_cache(maxsize=None)
def _refine_program():
    import jax
    import jax.numpy as jnp
    from repro.kernels.kway_refine.ops import kway_gains

    @_functools.partial(
        jax.jit, static_argnames=("tile_l", "k", "interpret"),
        donate_argnums=(2,))
    def step(indptr, indices, assign, delta_ids, delta_vals, cand, *,
             tile_l, k, interpret):
        n = assign.shape[0]
        # 1. apply the host's admitted-move delta (pads route to the
        #    out-of-bounds index n, the repo-wide masked-scatter rule)
        inj = delta_ids >= 0
        assign = assign.at[jnp.where(inj, delta_ids, n)].set(
            delta_vals, mode="drop")
        # 2. gather the candidates' neighbor-partition tiles
        parts = _gather_part_tiles(indptr, indices, assign, cand, tile_l)
        own = jnp.where(cand >= 0, assign[
            jnp.where(cand >= 0, cand, 0)], -1).astype(jnp.int32)
        # 3. Pallas move-gain kernel: (B, k) connectivity gains
        gains = kway_gains(parts, own, k=k, interpret=interpret)
        return assign, gains

    return step


def refine_gains_device(indptr, indices, assign, delta_ids, delta_vals,
                        cand, *, tile_l: int, k: int, interpret: bool):
    """Run one refinement screening call; see ``_refine_program``.

    ``assign`` is DONATED — keep the returned array, never reuse the
    input. ``delta_ids``/``delta_vals`` carry the host's admitted moves
    since the previous call (-1 padded); ``cand`` is the (-1 padded)
    candidate id tile. Returns ``(assign', gains)`` with ``gains``
    (B, k) float32 — ``gains[b, q]`` is the connectivity gain of moving
    ``cand[b]`` to partition ``q`` (0 for ``q == own`` and pad rows).
    """
    return _refine_program()(
        indptr, indices, assign, delta_ids, delta_vals, cand,
        tile_l=tile_l, k=k, interpret=interpret)


# ------------------------------------------------- streaming sketch program
# Device program of the single-pass streaming engine (core/hype_stream.py,
# DESIGN.md §4h). One jitted call per micro-batch: the fused
# ``hype_score_select`` kernel computes the batch's fringe-intersection
# counts against all k partition fringes at once, then a ``fori_loop``
# commits the batch *sequentially* — each vertex scores its k targets
# against the live partition sketch (per-partition hashed edge-presence
# counts) with a FREIGHT-style balance penalty, and its admission updates
# the sketch and sizes in the loop carry. Sketch and sizes are DONATED
# and stay device-resident across micro-batches; only the (mb, L) tiles
# go down and the (mb,) chosen partitions come back. At micro_batch=1
# the schedule is exactly the sequential streaming algorithm, which is
# what the numpy oracle in tests/test_hype_stream.py replicates
# bit-for-bit (same f32 expression, same first-max tie break).

# Fibonacci multiplicative hashing: bucket = top ``sketch_bits`` bits of
# (id * 2654435761) in uint32 arithmetic — identical on host and device.
STREAM_HASH_MULT = 2654435761


def stream_bucket(edge_ids: np.ndarray, sketch_bits: int) -> np.ndarray:
    """Host twin of the device bucket hash (exactly the same uint32 math).

    Negative (pad) ids hash like any other bits — callers mask validity
    separately, the hash itself never branches.
    """
    ids = np.asarray(edge_ids).astype(np.uint32)
    h = ids * np.uint32(STREAM_HASH_MULT)
    return (h >> np.uint32(32 - sketch_bits)).astype(np.int32)


@_functools.lru_cache(maxsize=None)
def _stream_program(sketch_bits: int, interpret: bool):
    import jax
    import jax.numpy as jnp
    from repro.kernels.hype_score.ops import hype_score_select

    n_buckets = 1 << sketch_bits
    shift = jnp.uint32(32 - sketch_bits)
    mult = jnp.uint32(STREAM_HASH_MULT)

    @_functools.partial(jax.jit, donate_argnums=(3, 4))
    def step(edge_tile, nbr_tile, fringe, sketch, sizes, valid_row,
             alpha, fringe_w, inv_target, cap):
        mb = edge_tile.shape[0]
        k = sketch.shape[0]
        e_valid = edge_tile >= 0
        buckets = ((edge_tile.astype(jnp.uint32) * mult)
                   >> shift).astype(jnp.int32)
        # Fringe-intersection counts via the fused Pallas kernel: the
        # kernel scores #valid - #(valid ∩ fringe_p) per phase, so the
        # intersection count is valid_cnt - score — exact integers in
        # float32. The pool is a single +inf slot (selection unused).
        nbrs = jnp.broadcast_to(nbr_tile[None],
                                (k,) + nbr_tile.shape)
        bias = jnp.zeros((k, mb), jnp.float32)
        prev = jnp.full((k, 1), jnp.inf, jnp.float32)
        kscore, _, _ = hype_score_select(nbrs, fringe, bias, prev,
                                         select_k=1,
                                         interpret=interpret)
        valid_cnt = (nbr_tile >= 0).sum(axis=1).astype(jnp.float32)
        fcnt = valid_cnt[:, None] - kscore.T          # (mb, k) f32

        def body(i, carry):
            parts, sketch, sizes = carry
            ev = e_valid[i]
            brow = buckets[i]
            pres = sketch[:, brow] > 0                # (k, Le)
            conn = jnp.sum(pres & ev[None, :],
                           axis=1).astype(jnp.float32)
            score = conn + fringe_w * fcnt[i] \
                - alpha * sizes.astype(jnp.float32) * inv_target
            score = jnp.where(sizes >= cap, -jnp.inf, score)
            p = jnp.argmax(score).astype(jnp.int32)   # first-max tie break
            upd = valid_row[i]
            sizes = sizes.at[p].add(jnp.where(upd, 1, 0))
            bm = jnp.where(ev & upd, brow, n_buckets)
            sketch = sketch.at[p, bm].add(1, mode="drop")
            parts = parts.at[i].set(jnp.where(upd, p, -1))
            return parts, sketch, sizes

        parts0 = jnp.full((mb,), -1, jnp.int32)
        parts, sketch, sizes = jax.lax.fori_loop(
            0, mb, body, (parts0, sketch, sizes))
        return parts, sketch, sizes

    return step


def stream_step_device(edge_tile, nbr_tile, fringe, sketch, sizes,
                       valid_row, *, alpha: float, fringe_w: float,
                       inv_target: float, cap: int, sketch_bits: int,
                       interpret: bool):
    """Run one streaming micro-batch; see ``_stream_program``.

    ``edge_tile`` (mb, Le) int32 incident-edge ids / ``nbr_tile``
    (mb, Ln) int32 neighbor ids, both -1 padded; ``fringe`` (k, s)
    int32 per-partition fringes (-1 = empty slot); ``valid_row`` (mb,)
    bool marks real (non-pad) batch rows. ``sketch`` (k, 2**sketch_bits)
    int32 and ``sizes`` (k,) int32 are DONATED device arrays — keep the
    returned pair, never reuse the inputs. Returns
    ``(parts (mb,) int32, sketch', sizes')``.
    """
    import jax.numpy as jnp

    return _stream_program(int(sketch_bits), bool(interpret))(
        edge_tile, nbr_tile, fringe, sketch, sizes, valid_row,
        jnp.float32(alpha), jnp.float32(fringe_w),
        jnp.float32(inv_target), jnp.int32(cap))


# --------------------------------------------------------------------- JAX
# (imported lazily by callers that run on device; keeping the import at
# module level is fine — the repo is a JAX codebase — but the numpy helpers
# above stay usable without touching the device runtime.)

def batched_dext_jax(v2e, e2v, vs, ext_mask):
    """d_ext for a batch of vertices on padded incidence arrays (jittable).

    ``v2e``: (n, max_deg) int32, -1 padded; ``e2v``: (m, max_size) int32,
    -1 padded; ``vs``: (B,) int32 vertex ids (entries < 0 allowed, score
    undefined for them — mask at the call site); ``ext_mask``: (n,) bool,
    True where a vertex counts as "external" (unassigned, not in fringe).

    Gather all pins of all incident edges into a (B, max_deg * max_size)
    tile, sort each row, and count first occurrences that are external —
    a segment-style unique-count with no O(n) scatter per candidate.
    """
    import jax.numpy as jnp

    n = v2e.shape[0]
    safe_vs = jnp.where(vs >= 0, vs, 0)
    es = v2e[safe_vs]                                   # (B, D)
    ev = es >= 0
    pins = e2v[jnp.where(ev, es, 0)]                    # (B, D, S)
    pins = jnp.where(ev[:, :, None] & (pins >= 0), pins, n)
    flat = pins.reshape(pins.shape[0], -1)
    flat = jnp.where(flat == safe_vs[:, None], n, flat)   # exclude self
    srt = jnp.sort(flat, axis=1)
    first = jnp.concatenate(
        [jnp.ones((srt.shape[0], 1), bool), srt[:, 1:] != srt[:, :-1]],
        axis=1)
    ext_pad = jnp.concatenate([ext_mask, jnp.zeros((1,), bool)])
    counted = first & ext_pad[srt]
    return counted.sum(axis=1).astype(jnp.float32)


# ISSUE.md names `scoring.device_loop_program` as the fully
# device-resident loop's entry point; the program outgrew this module
# and lives in core/device_loop.py — re-exported here so the documented
# import path keeps working. Bottom-of-file on purpose: device_loop's
# program builder imports back into scoring lazily.
from .device_loop import (  # noqa: E402,F401
    DeviceLoopConfig, device_loop_program)
