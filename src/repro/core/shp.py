"""Social-Hash-style iterative swap partitioner (Kabiljo et al., VLDB'17).

The paper's group (II) baseline. SHP starts from a balanced random
assignment and iteratively improves it: every vertex computes the partition
that maximizes its hyperedge overlap ("probabilistic fanout gain" in SHP);
moves are then applied in *balanced swaps* so partition sizes never change.

This is a single-host vectorized rendition of the distributed original:
each iteration is O(n_pins * k / 8) via the same bit-matrix trick as
``minmax.py``. It converges to a local optimum of the overlap objective,
which correlates with the (k-1) metric.
"""
from __future__ import annotations

import numpy as np

from .hypergraph import Hypergraph
from .minmax import random_partition


def _edge_partition_bits(hg: Hypergraph, assignment: np.ndarray, k: int):
    kbytes = (k + 7) // 8
    part_of_pin = assignment[hg.e2v_indices].astype(np.int64)
    edge_of_pin = np.repeat(np.arange(hg.m, dtype=np.int64), hg.edge_sizes)
    bits = np.zeros((hg.m, kbytes), dtype=np.uint8)
    byte_idx = part_of_pin // 8
    bit_val = (1 << (part_of_pin % 8)).astype(np.uint8)
    np.bitwise_or.at(bits, (edge_of_pin, byte_idx), bit_val)
    return bits


def shp_partition(hg: Hypergraph, k: int, *, iters: int = 16,
                  seed: int = 0, init: np.ndarray | None = None,
                  swap_frac: float = 1.0) -> np.ndarray:
    n = hg.n
    rng = np.random.default_rng(seed)
    assignment = (init.copy() if init is not None
                  else random_partition(hg, k, seed))

    edge_of_pin = np.repeat(np.arange(hg.m, dtype=np.int64), hg.edge_sizes)
    for _ in range(iters):
        bits = _edge_partition_bits(hg, assignment, k)
        # per-vertex overlap with each partition
        unpacked = np.unpackbits(bits, axis=1, count=k, bitorder="little")
        # overlap[v, p] = sum over incident edges of bit p
        deg = hg.vertex_degrees
        overlap = np.zeros((n, k), dtype=np.int32)
        np.add.at(overlap, np.repeat(np.arange(n, dtype=np.int64), deg),
                  unpacked[hg.v2e_indices])
        # Exclude the vertex's own contribution to its current partition:
        # count, per pin, how many pins of that edge sit in the pin's own
        # partition; if the pin is the only one, the edge's bit exists only
        # because of v itself.
        part_of_pin = assignment[hg.e2v_indices].astype(np.int64)
        pin_key = edge_of_pin * np.int64(k) + part_of_pin
        uk, inv, cnts = np.unique(pin_key, return_inverse=True,
                                  return_counts=True)
        solo_pin = (cnts[inv] == 1).astype(np.int32)
        solo = np.zeros(n, dtype=np.int32)
        np.add.at(solo, hg.e2v_indices, solo_pin)
        overlap[np.arange(n), assignment] -= solo
        cur = overlap[np.arange(n), assignment]
        desire = np.argmax(overlap, axis=1).astype(np.int32)
        gain = overlap[np.arange(n), desire] - cur
        movers = np.flatnonzero((desire != assignment) & (gain > 0))
        if movers.size == 0:
            break
        if swap_frac < 1.0:
            movers = rng.choice(movers, size=max(1, int(movers.size * swap_frac)),
                                replace=False)
        # Balanced swapping: for each ordered pair (a, b) match the
        # highest-gain movers a->b with movers b->a and swap both sides.
        src = assignment[movers]
        dst = desire[movers]
        g = gain[movers]
        moved = 0
        # group movers by (src, dst)
        pair_key = src.astype(np.int64) * k + dst
        order = np.lexsort((-g, pair_key))
        movers, src, dst, pair_key = movers[order], src[order], dst[order], pair_key[order]
        starts = np.searchsorted(pair_key, np.arange(k * k, dtype=np.int64))
        ends = np.searchsorted(pair_key, np.arange(1, k * k + 1, dtype=np.int64))
        for a in range(k):
            for b in range(a + 1, k):
                i0, i1 = starts[a * k + b], ends[a * k + b]
                j0, j1 = starts[b * k + a], ends[b * k + a]
                t = min(i1 - i0, j1 - j0)
                if t > 0:
                    sel = np.concatenate([movers[i0:i0 + t], movers[j0:j0 + t]])
                    assignment[sel] = np.concatenate(
                        [np.full(t, b, np.int32), np.full(t, a, np.int32)])
                    moved += 2 * t
        if moved == 0:
            break
    return assignment
