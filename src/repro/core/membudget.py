"""Device-memory budgeting for the superstep engines (DESIGN.md §4g).

Running out of device memory should be a *handled, bounded-cost* event,
not a crash or a silent fall-off-the-device cliff. This module holds the
three pieces that make it one:

  * **Budget planner** — ``plan_memory`` estimates the bytes of every
    device-resident tensor of a superstep run (CSR image, assignment,
    score cache, gather tiles, pipeline double buffers) *before* upload
    with ``estimate_plan_bytes``, a pure function of the graph/knob
    sizes, and walks a deterministic **rung ladder** of progressively
    smaller configurations until one fits the budget:

        rung 0  the engine's default plan (today's tile choices)
        rung 1  phase-chunked scoring (``g_chunk=2`` — "halve tile_b")
        rung 2  drop ``tile_l`` one ``L_BUCKETS`` bucket (skipped when
                already at the smallest bucket)
        rung 3  ``pipeline_depth=1`` (lock-step, golden-exact)
        rung 4  spill the score cache to host (depth-1 only)
        rung 5  paged adjacency (the CSR image itself no longer fits)

    Every rung except the ``tile_l`` drop is *bit-exact* on the
    single-device engine: phase chunks score the same tiles in the same
    order, depth 1 is golden-hashed, the host float32 cache mirror
    performs IEEE-identical arithmetic, and the paged gather feeds the
    program the same raw rows ``scoring._gather_fresh_tiles`` would
    have produced. The ``tile_l`` drop only changes results for rows
    wider than the smaller bucket (they pick up the hub penalty).

  * **OOM taxonomy** — ``is_oom_error`` classifies *real* allocator
    failures (jaxlib ``XlaRuntimeError`` RESOURCE_EXHAUSTED,
    ``MemoryError``) so the upload/dispatch/harvest sites can convert
    them — and the injected non-fatal ``oom`` fault of
    ``resilience.FaultPlan`` — into one ``DeviceOOM`` recovery path:
    retry the *same* engine at the next rung, warm-started from the
    host assignment mirror, before ``partition_resilient`` is ever
    allowed to change engines. A fatal ``oom:fatal`` spec still raises
    ``UnrecoverableFault`` for the engine-degradation ladder.

  * **Paged adjacency** — ``PagedAdjacency`` keeps the vertex-adjacency
    CSR on host and pages fixed-row-range chunks onto the device under
    an LRU byte budget; per-superstep candidate tiles are gathered
    chunk-by-chunk on device (async dispatch overlaps the uploads with
    scoring), so graphs whose CSR image exceeds the budget still run
    on-device. Per-chunk row offsets are narrowed to int32 and row
    lengths to int16 when the ids allow (``narrow_len_dtype``).

The budget itself comes from the ``mem_budget=`` engine knob, the
``REPRO_DEVICE_MEM_BUDGET`` env var (``"512MB"``, ``"2GiB"``, plain
bytes), or — when neither is set — a probe of the backend's
``memory_stats()['bytes_limit']``; CPU backends without stats run
unconstrained (rung 0, today's behavior, bit for bit).
"""
from __future__ import annotations

import collections
import dataclasses
import os
import re
from typing import Optional, Sequence, Tuple

import numpy as np

from . import scoring

ENV_BUDGET = "REPRO_DEVICE_MEM_BUDGET"

# Rung feature sets: the single-device engine supports every reduction;
# the sharded engine's program variants only exist for the width/depth
# knobs (its CSR is replicated, so paging would need a different
# collective layout — see DESIGN.md §4g).
SUPERSTEP_FEATURES = ("chunk", "tile_l", "depth", "spill", "paged")
SHARDED_FEATURES = ("tile_l", "depth")


class DeviceOOM(RuntimeError):
    """A device allocation failed (real or injected, non-fatal).

    Carries enough context for the re-tiling retry loop: ``rung`` is the
    memory-plan rung the failing attempt ran at (None when the failure
    predates planning) and ``partial`` is the host assignment mirror at
    failure time, used to warm-start the next rung.
    """

    def __init__(self, msg: str, rung: Optional[int] = None,
                 partial: Optional[np.ndarray] = None):
        super().__init__(msg)
        self.rung = rung
        self.partial = partial


class MemoryLadderExhausted(RuntimeError):
    """Every memory rung was tried and the device still OOMs.

    The re-tiling loop converts this into ``UnrecoverableFault`` so the
    engine-degradation ladder (partition_api) takes over.
    """


def is_oom_error(exc: BaseException) -> bool:
    """True when ``exc`` is a real allocator failure.

    Covers ``MemoryError``, jaxlib's ``XlaRuntimeError`` with a
    RESOURCE_EXHAUSTED status, and any runtime error whose message names
    an out-of-memory condition (different jaxlib versions route the
    status through different exception classes, so the match is on the
    message, not the type hierarchy).
    """
    if isinstance(exc, MemoryError):
        return True
    text = f"{type(exc).__name__}: {exc}"
    return ("RESOURCE_EXHAUSTED" in text
            or "out of memory" in text.lower()
            or "OutOfMemory" in text)


# ----------------------------------------------------------- budget source

_UNIT = {
    "": 1, "b": 1,
    "k": 10 ** 3, "kb": 10 ** 3, "kib": 1 << 10,
    "m": 10 ** 6, "mb": 10 ** 6, "mib": 1 << 20,
    "g": 10 ** 9, "gb": 10 ** 9, "gib": 1 << 30,
    "t": 10 ** 12, "tb": 10 ** 12, "tib": 1 << 40,
}


def parse_budget(text) -> Optional[int]:
    """Parse a byte budget: int, ``"512MB"``, ``"1.5GiB"``, ``"2g"``.

    Decimal units (KB/MB/GB) are powers of 10, binary units (KiB/MiB/
    GiB) powers of 2. ``None``, ``""``, ``"none"`` and ``0`` mean
    *unconstrained* and return None.
    """
    if text is None:
        return None
    if isinstance(text, (int, np.integer)):
        return int(text) or None
    s = str(text).strip().lower()
    if s in ("", "none", "unlimited"):
        return None
    m = re.fullmatch(r"([0-9]*\.?[0-9]+)\s*([a-z]*)", s)
    if not m or m.group(2) not in _UNIT:
        raise ValueError(
            f"unparseable memory budget {text!r}; use bytes or a "
            f"KB/MB/GB/KiB/MiB/GiB suffix")
    return int(float(m.group(1)) * _UNIT[m.group(2)]) or None


def probe_device_budget() -> Optional[int]:
    """The backend's allocator limit, or None when it has none to report.

    CPU backends (and TPU runtimes without ``memory_stats``) return
    None, which the planner treats as unconstrained — exactly today's
    behavior.
    """
    try:
        import jax
        stats = jax.devices()[0].memory_stats()
    except Exception:
        return None
    if not stats:
        return None
    limit = stats.get("bytes_limit")
    return int(limit) if limit else None


def observed_peak_bytes() -> Optional[int]:
    """``peak_bytes_in_use`` of device 0, or None when untracked."""
    try:
        import jax
        stats = jax.devices()[0].memory_stats()
    except Exception:
        return None
    if not stats:
        return None
    peak = stats.get("peak_bytes_in_use")
    return int(peak) if peak else None


def resolve_budget(mem_budget=None) -> Optional[int]:
    """Resolve the device byte budget: knob > env > backend probe.

    ``mem_budget`` (the engine knob) wins when set; otherwise the
    ``REPRO_DEVICE_MEM_BUDGET`` env var; otherwise the backend's own
    reported limit. None means unconstrained.
    """
    if mem_budget is not None:
        return parse_budget(mem_budget)
    env = os.environ.get(ENV_BUDGET, "").strip()
    if env:
        return parse_budget(env)
    return probe_device_budget()


# ---------------------------------------------------------------- planner

@dataclasses.dataclass(frozen=True)
class MemSpec:
    """The size inputs of the byte model — everything known pre-upload."""
    n: int              # vertices
    adj_pins: int       # vertex-adjacency indices (expanded neighbor pairs)
    k: int              # stacked phases G of one superstep
    rows: int           # fresh candidate rows per phase (R)
    pool_cap: int       # held pool slots per phase (P)
    t: int              # admissions per phase per superstep
    tile_l: int         # default gather width (L bucket)
    pipeline_depth: int


@dataclasses.dataclass(frozen=True)
class MemPlan:
    """One rung of the ladder, with its planned peak byte count."""
    rung: int
    tile_l: int
    g_chunk: int            # phases scored in g_chunk sequential slices
    pipeline_depth: int
    spill_cache: bool       # score cache lives on host (float32 mirror)
    paged: bool             # CSR paged on demand instead of resident
    page_bytes: int         # resident-page budget when paged
    planned_bytes: int
    fits: bool              # planned_bytes <= budget (best-effort if not)


def device_ptr_nbytes(adj_pins: int) -> int:
    """Bytes per indptr entry of the device CSR image.

    Mirrors ``Hypergraph.device_adjacency``: int32 while the indices
    array is addressable with 31 bits, int64 beyond.
    """
    return 4 if adj_pins < 2 ** 31 else 8


def narrow_len_dtype(max_len: int):
    """Narrowest unsigned-safe int dtype for per-chunk row lengths."""
    return np.int16 if max_len < 2 ** 15 else np.int32


def estimate_plan_bytes(spec: MemSpec, *, tile_l: Optional[int] = None,
                        g_chunk: int = 1,
                        pipeline_depth: Optional[int] = None,
                        spill_cache: bool = False, paged: bool = False,
                        page_bytes: int = 0) -> int:
    """Planned peak device bytes of one superstep-engine configuration.

    A pure function, monotone non-decreasing in every size input
    (``n``, ``adj_pins``, ``k``, ``rows``, ``pool_cap``, ``t``,
    ``tile_l``, ``pipeline_depth``) — the property the planner tests
    pin. The model counts:

      * the CSR image (indptr + indices), or the resident-page budget
        plus the assembled full-width gather tile when ``paged``;
      * the mutable image: assignment + score cache (host-resident when
        ``spill_cache``) + per-phase totals + poison flag;
      * per-superstep transients — the (G/g_chunk · rows, tile_l)
        gather tile, the kernel's score/select outputs and the small
        host-built id buffers — multiplied by ``pipeline_depth``
        (each in-flight superstep keeps its own transients live).
    """
    tile_l = spec.tile_l if tile_l is None else tile_l
    depth = (spec.pipeline_depth if pipeline_depth is None
             else pipeline_depth)
    n, k = spec.n, spec.k
    g, r, p, t = spec.k, spec.rows, spec.pool_cap, spec.t

    if paged:
        csr = page_bytes + (n + 1) * 8 // 64    # host indptr slices only
    else:
        csr = (n + 1) * device_ptr_nbytes(spec.adj_pins) \
            + spec.adj_pins * 4
    image = n * 4                               # assignment
    if not spill_cache:
        image += n * 4                          # score cache
    image += k * 4 + 4                          # acc + poison

    chunk_rows = -(-g // g_chunk) * r
    gather = chunk_rows * tile_l * 4            # the dominant transient
    if paged:
        gather = g * r * tile_l * 4             # full assembled tile
    kernel = g * r * 4 + g * (r + p) * 8        # scores + select scratch
    hostbuf = g * (2 * r + p + t + 2) * 4       # fresh/bias/pool/targets
    transient = gather + kernel + hostbuf
    return csr + image + max(1, depth) * transient


def rung_ladder(spec: MemSpec,
                features: Sequence[str] = SUPERSTEP_FEATURES,
                budget: Optional[int] = None) -> Tuple[MemPlan, ...]:
    """The deterministic rung ladder for ``spec``.

    Rungs are cumulative — each keeps the previous rung's reductions
    and sheds one more thing. Feature-gated rungs are skipped when the
    engine does not support them (``SHARDED_FEATURES``) or when they
    would be a no-op (``tile_l`` already at the smallest bucket).
    ``budget`` is only used to size the paged rung's resident-page
    allowance; the fit decision lives in ``plan_memory``.
    """
    cfgs = [dict(tile_l=spec.tile_l, g_chunk=1,
                 pipeline_depth=spec.pipeline_depth, spill_cache=False,
                 paged=False, page_bytes=0)]

    def push(**kw):
        cfg = dict(cfgs[-1])
        cfg.update(kw)
        cfgs.append(cfg)

    if "chunk" in features and spec.k > 1:
        push(g_chunk=2)                          # "halve tile_b"
    if "tile_l" in features:
        buckets = [b for b in scoring.L_BUCKETS if b < spec.tile_l]
        if buckets:
            push(tile_l=buckets[-1])             # one bucket down
    if "depth" in features and spec.pipeline_depth > 1:
        push(pipeline_depth=1)
    if "spill" in features:
        # the spill program scores the full phase stack (no chunked
        # variant exists for it), so its config says so honestly
        push(pipeline_depth=1, spill_cache=True, g_chunk=1)
    if "paged" in features:
        base = cfgs[-1]
        fixed = estimate_plan_bytes(
            spec, tile_l=base["tile_l"], g_chunk=1,
            pipeline_depth=1, spill_cache=False, paged=True,
            page_bytes=0)
        page_bytes = _MIN_PAGE_BYTES * 2
        if budget is not None and budget > fixed:
            page_bytes = max(page_bytes, budget - fixed)
        push(pipeline_depth=1, spill_cache=False, paged=True,
             g_chunk=1, page_bytes=int(page_bytes))

    plans = []
    for rung, cfg in enumerate(cfgs):
        bytes_ = estimate_plan_bytes(spec, **cfg)
        plans.append(MemPlan(rung=rung, planned_bytes=bytes_,
                             fits=(budget is None or bytes_ <= budget),
                             **cfg))
    return tuple(plans)


def plan_memory(spec: MemSpec, budget: Optional[int],
                features: Sequence[str] = SUPERSTEP_FEATURES,
                rung_start: int = 0) -> MemPlan:
    """Pick the largest plan (lowest rung) that fits ``budget``.

    With ``budget=None`` (unconstrained) rung ``rung_start`` is chosen
    directly — rung 0 reproduces today's tile choices bit-identically.
    When no rung from ``rung_start`` on fits, the *last* rung is
    returned with ``fits=False`` (best effort: the ladder's smallest
    configuration is still the best available answer; a real allocator
    failure will surface as ``DeviceOOM`` and walk further rungs).
    ``rung_start`` past the end of the ladder raises
    ``MemoryLadderExhausted`` — every retry rung has been consumed.
    """
    plans = rung_ladder(spec, features, budget)
    if rung_start >= len(plans):
        raise MemoryLadderExhausted(
            f"all {len(plans)} memory rungs exhausted "
            f"(budget={budget}, spec={spec})")
    for plan in plans[rung_start:]:
        if plan.fits:
            return plan
    return plans[-1]


# ------------------------------------------------------- streaming planner

@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """Size inputs of the streaming engine's byte model (DESIGN.md §4h)."""
    n: int              # vertices
    k: int              # partitions
    micro_batch: int    # vertices per device call
    sketch_bits: int    # sketch table is (k, 2**sketch_bits) int32
    s: int              # fringe slots per partition
    tile_l: int         # neighbor-tile gather width (L bucket)


def estimate_stream_bytes(spec: StreamSpec, *,
                          micro_batch: Optional[int] = None,
                          tile_l: Optional[int] = None) -> int:
    """Planned peak device bytes of one streaming micro-batch step.

    Pure and monotone non-decreasing in every size input, like
    ``estimate_plan_bytes``. Counts the resident sketch + sizes image,
    the per-batch edge/neighbor tiles, and the kernel's (k, mb, L)
    broadcast of the neighbor tile (the dominant transient of the fused
    fringe scoring), plus the small fringe/score buffers.
    """
    mb = spec.micro_batch if micro_batch is None else micro_batch
    tl = spec.tile_l if tile_l is None else tile_l
    k, s = spec.k, spec.s
    image = k * (1 << spec.sketch_bits) * 4 + k * 4     # sketch + sizes
    tiles = 2 * mb * tl * 4                             # edge + nbr tile
    kernel = k * mb * tl * 4 + k * mb * 4 + k * s * 4   # broadcast+scores
    out = mb * 4                                        # chosen parts
    return image + tiles + kernel + out


def plan_stream_memory(spec: StreamSpec,
                       budget: Optional[int]) -> Tuple[int, int, int, bool]:
    """Pick the streaming rung: halve ``micro_batch``, then drop ``tile_l``.

    Returns ``(micro_batch, tile_l, planned_bytes, fits)``. Rung 0 is
    the caller's own plan (returned untouched when the budget is None
    or already met — the unconstrained path stays bit-identical).
    Subsequent rungs halve the micro-batch down to 1, then walk
    ``tile_l`` down the ``L_BUCKETS`` ladder; like ``plan_memory``, an
    exhausted ladder returns the smallest configuration best-effort
    with ``fits=False``.
    """
    mb, tl = spec.micro_batch, spec.tile_l
    planned = estimate_stream_bytes(spec)
    if budget is None or planned <= budget:
        return mb, tl, planned, True
    while mb > 1:
        mb = max(1, mb // 2)
        planned = estimate_stream_bytes(spec, micro_batch=mb)
        if planned <= budget:
            return mb, tl, planned, True
    while True:
        lower = [b for b in scoring.L_BUCKETS if b < tl]
        if not lower:
            break
        tl = lower[-1]
        planned = estimate_stream_bytes(spec, micro_batch=mb, tile_l=tl)
        if planned <= budget:
            return mb, tl, planned, True
    return mb, tl, planned, False


# ----------------------------------------------------------- paged image

_MIN_PAGE_BYTES = 1 << 18       # floor so at least two chunks stay resident

import functools as _functools


@_functools.lru_cache(maxsize=None)
def _page_gather_program():
    """Jitted per-chunk tile gather, shared across chunks via padding.

    One trace per (B, tile_l, chunk_rows, chunk_pins) shape — chunks
    are padded to a common shape so the whole paged run traces once.
    ``lo`` is a traced scalar (the chunk's first vertex id), so chunk
    identity never retraces.
    """
    import jax
    import jax.numpy as jnp

    @_functools.partial(jax.jit, donate_argnums=(0,))
    def gather(out, rstart, rlen, idx, ids, lo):
        rows = rlen.shape[0]
        local = ids - lo
        in_chunk = (local >= 0) & (local < rows) & (ids >= 0)
        lsafe = jnp.where(in_chunk, local, 0)
        start = rstart[lsafe]
        deg = rlen[lsafe].astype(jnp.int32)
        col = jax.lax.broadcasted_iota(
            jnp.int32, (ids.shape[0], out.shape[1]), 1)
        valid = (col < deg[:, None]) & in_chunk[:, None]
        nbr = idx[jnp.where(valid, start[:, None] + col, 0)]
        return jnp.where(valid, nbr, out)

    return gather


class PagedAdjacency:
    """LRU-paged device copy of the vertex-adjacency CSR.

    The CSR is split into fixed-row-count chunks (vertex-id ranges);
    each chunk's device image is ``(row_start int32, row_len int16 when
    degrees allow, indices int32)``, padded to a common shape so the
    gather program traces once. ``gather`` assembles a raw (B, tile_l)
    neighbor-id tile for a candidate batch on device, uploading absent
    chunks and evicting least-recently-used ones to stay under
    ``page_bytes``. Uploads are async (jax dispatch), so the next
    chunk's transfer overlaps the previous chunk's gather — and the
    pipeline driver overlaps the whole assembly with the in-flight
    superstep's scoring.

    Counters (page_uploads / page_hits / page_evictions / page_bytes)
    are accumulated onto ``stats`` when given (a ``BatchedStats``).
    """

    def __init__(self, adj, page_bytes: int, stats=None):
        indptr, indices = adj
        self.indptr = indptr
        self.indices = indices
        self.n = int(indptr.shape[0]) - 1
        self.page_bytes = max(int(page_bytes), 2 * _MIN_PAGE_BYTES)
        self.stats = stats
        deg = np.diff(indptr)
        self.max_deg = int(deg.max()) if deg.size else 0
        self.len_dtype = narrow_len_dtype(self.max_deg)
        # fixed row count per chunk, sized so an *average* chunk costs
        # about 1/16 of the page budget: fine-grained chunks make the
        # resident hit ratio track page_bytes/csr_bytes smoothly (the
        # zigzag sweep in gather() keeps ~capacity/total chunks hot),
        # while the floor keeps per-chunk dispatch overhead bounded
        mean_deg = indices.size / max(self.n, 1)
        target = max(self.page_bytes // 16, _MIN_PAGE_BYTES)
        per_row = 4 * mean_deg + 4 + self.len_dtype().itemsize
        self.chunk_rows = int(max(1, min(self.n, target // max(per_row, 1))))
        self.n_chunks = -(-self.n // self.chunk_rows)
        # common padded shape: one trace for every chunk of the run
        bounds = np.minimum(
            np.arange(self.n_chunks + 1, dtype=np.int64) * self.chunk_rows,
            self.n)
        self.chunk_pins = int(
            (indptr[bounds[1:]] - indptr[bounds[:-1]]).max()
        ) if self.n_chunks else 0
        self._resident: "collections.OrderedDict[int, tuple]" = \
            collections.OrderedDict()
        self._resident_bytes = 0
        self._sweep = 0

    def chunk_of(self, ids: np.ndarray) -> np.ndarray:
        return ids // self.chunk_rows

    def _upload(self, c: int):
        import jax.numpy as jnp

        lo = c * self.chunk_rows
        hi = min(lo + self.chunk_rows, self.n)
        base = int(self.indptr[lo])
        rstart = np.zeros(self.chunk_rows, dtype=np.int32)
        rlen = np.zeros(self.chunk_rows, dtype=self.len_dtype)
        rstart[:hi - lo] = (self.indptr[lo:hi] - base).astype(np.int32)
        rlen[:hi - lo] = (self.indptr[lo + 1:hi + 1]
                          - self.indptr[lo:hi]).astype(self.len_dtype)
        idx = np.zeros(self.chunk_pins, dtype=np.int32)
        pins = int(self.indptr[hi]) - base
        idx[:pins] = self.indices[base:base + pins]
        entry = (jnp.asarray(rstart), jnp.asarray(rlen),
                 jnp.asarray(idx), np.int32(lo),
                 rstart.nbytes + rlen.nbytes + idx.nbytes)
        self._resident[c] = entry
        self._resident_bytes += entry[4]
        if self.stats is not None:
            self.stats.page_uploads += 1
            self.stats.page_bytes += entry[4]
        while (self._resident_bytes > self.page_bytes
               and len(self._resident) > 1):
            _, old = self._resident.popitem(last=False)
            self._resident_bytes -= old[4]
            if self.stats is not None:
                self.stats.page_evictions += 1
        return entry

    def gather(self, flat_ids: np.ndarray, tile_l: int):
        """Raw (B, tile_l) neighbor-id device tile for ``flat_ids``.

        Rows of pad ids (< 0) stay all -1; real rows hold the first
        ``tile_l`` CSR neighbors, -1 padded — exactly the pre-masking
        rows ``scoring._gather_fresh_tiles`` reads from a resident CSR,
        so the paged program's in-program assignment masking reproduces
        the resident path bit for bit.
        """
        import jax.numpy as jnp

        flat_ids = np.asarray(flat_ids, dtype=np.int32)
        out = jnp.full((flat_ids.shape[0], tile_l), -1, jnp.int32)
        real = flat_ids[flat_ids >= 0]
        if real.size == 0:
            return out
        ids_dev = jnp.asarray(flat_ids)
        gather = _page_gather_program()
        # Alternate the chunk visit direction per call: each chunk
        # writes a disjoint row set of `out`, so order is free — and a
        # zigzag turns the repeated full-range sweep (LRU's worst case:
        # zero hits whenever capacity < total) into one where every
        # sweep re-enters where the last one ended, keeping
        # ~capacity/total of the chunks permanently hot.
        chunks = np.unique(self.chunk_of(real.astype(np.int64)))
        if self._sweep & 1:
            chunks = chunks[::-1]
        self._sweep += 1
        for c in chunks:
            c = int(c)
            entry = self._resident.get(c)
            if entry is None:
                entry = self._upload(c)
            else:
                self._resident.move_to_end(c)
                if self.stats is not None:
                    self.stats.page_hits += 1
            rstart, rlen, idx, lo, _ = entry
            out = gather(out, rstart, rlen, idx, ids_dev, lo)
        return out

    @property
    def resident_bytes(self) -> int:
        return self._resident_bytes
