"""Hypergraph data structure (CSR in both directions).

A hypergraph G = (V, E) with |V| = n vertices and |E| = m hyperedges is
stored as two CSR structures:

  * ``v2e``: for each vertex, the list of incident hyperedge ids.
  * ``e2v``: for each hyperedge, the list of member vertex ids (its "pins").

A *pin* is one (vertex, hyperedge) incidence. ``n_pins`` equals the paper's
"#Edges" column in Table II.

All arrays are plain numpy so the structure can scale to hundreds of
millions of pins on a single host; JAX-facing code converts the (small,
padded) views it needs.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

# Ids at or above 2**31 no longer fit int32; the decision is extracted so
# the boundary can be tested without allocating 2-billion-row graphs.
_INT32_LIMIT = 2**31


def csr_index_dtype(n: int, m: int):
    """Numpy dtype for CSR *indices* arrays of an (n, m) hypergraph.

    int32 while every vertex AND hyperedge id fits, int64 otherwise.
    Indptr arrays stay int64 regardless (pin counts overflow first).
    """
    return np.int32 if max(int(n), int(m)) < _INT32_LIMIT else np.int64


def device_ptr_dtype(n_indices: int):
    """JAX dtype for the device CSR ``indptr`` image.

    Offsets index into the flat indices array, so the flip happens at
    ``n_indices`` (pin count), not vertex count. Imports jax lazily —
    host-only code paths must not pay for it.
    """
    import jax.numpy as jnp
    return jnp.int32 if int(n_indices) < _INT32_LIMIT else jnp.int64


@dataclasses.dataclass(frozen=True)
class Hypergraph:
    n: int                     # number of vertices
    m: int                     # number of hyperedges
    v2e_indptr: np.ndarray     # (n+1,) int64
    v2e_indices: np.ndarray    # (n_pins,) int32/int64 hyperedge ids
    e2v_indptr: np.ndarray     # (m+1,) int64
    e2v_indices: np.ndarray    # (n_pins,) int32/int64 vertex ids

    # ------------------------------------------------------------------ #
    # Constructors
    # ------------------------------------------------------------------ #
    @classmethod
    def from_pins(cls, n: int, m: int, vertex_ids: np.ndarray,
                  edge_ids: np.ndarray) -> "Hypergraph":
        """Build from parallel pin arrays (vertex_ids[i] ∈ edge edge_ids[i]).

        Parameters
        ----------
        n, m : int
            Vertex and hyperedge counts; ids outside ``[0, n)`` /
            ``[0, m)`` raise ``ValueError``. Vertices or edges with no
            pins are legal (they become empty CSR rows).
        vertex_ids, edge_ids : array-like of int
            Parallel arrays, one entry per pin. Duplicate
            (vertex, edge) pins are deduplicated — a vertex appears at
            most once per hyperedge.

        Returns
        -------
        Hypergraph
            Immutable, with both CSR directions built; index dtype is
            int32 when ids fit, int64 otherwise. This is the
            construction path every loader and generator funnels
            through (``from_edge_lists`` included).
        """
        vertex_ids = np.asarray(vertex_ids, dtype=np.int64)
        edge_ids = np.asarray(edge_ids, dtype=np.int64)
        if vertex_ids.shape != edge_ids.shape:
            raise ValueError("pin arrays must be parallel")
        if vertex_ids.size and (vertex_ids.min() < 0 or vertex_ids.max() >= n):
            raise ValueError("vertex id out of range")
        if edge_ids.size and (edge_ids.min() < 0 or edge_ids.max() >= m):
            raise ValueError("edge id out of range")

        # de-duplicate pins (a vertex may appear at most once per hyperedge)
        key = edge_ids * np.int64(n) + vertex_ids
        _, uniq = np.unique(key, return_index=True)
        vertex_ids, edge_ids = vertex_ids[uniq], edge_ids[uniq]

        idx_dtype = csr_index_dtype(n, m)

        # e2v CSR: sort pins by edge id
        order = np.argsort(edge_ids, kind="stable")
        e2v_indices = vertex_ids[order].astype(idx_dtype)
        e2v_indptr = np.zeros(m + 1, dtype=np.int64)
        np.add.at(e2v_indptr, edge_ids + 1, 1)
        np.cumsum(e2v_indptr, out=e2v_indptr)

        # v2e CSR: sort pins by vertex id
        order = np.argsort(vertex_ids, kind="stable")
        v2e_indices = edge_ids[order].astype(idx_dtype)
        v2e_indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(v2e_indptr, vertex_ids + 1, 1)
        np.cumsum(v2e_indptr, out=v2e_indptr)

        return cls(n=n, m=m, v2e_indptr=v2e_indptr, v2e_indices=v2e_indices,
                   e2v_indptr=e2v_indptr, e2v_indices=e2v_indices)

    @classmethod
    def from_edge_lists(cls, n: int, edges: Sequence[Iterable[int]]) -> "Hypergraph":
        """Build from a list of hyperedges, each an iterable of vertex ids.

        Convenience wrapper over ``from_pins`` for tests and small
        graphs (it materializes python lists — use ``from_pins``
        directly for anything large). ``len(edges)`` becomes ``m``;
        empty iterables are legal and become empty hyperedges.
        """
        edge_ids, vertex_ids = [], []
        for e, pins in enumerate(edges):
            for v in pins:
                edge_ids.append(e)
                vertex_ids.append(v)
        return cls.from_pins(n, len(edges),
                             np.asarray(vertex_ids, dtype=np.int64),
                             np.asarray(edge_ids, dtype=np.int64))

    # ------------------------------------------------------------------ #
    # Properties / views
    # ------------------------------------------------------------------ #
    @property
    def n_pins(self) -> int:
        return int(self.e2v_indices.shape[0])

    @property
    def edge_sizes(self) -> np.ndarray:
        return np.diff(self.e2v_indptr)

    @property
    def vertex_degrees(self) -> np.ndarray:
        return np.diff(self.v2e_indptr)

    def edge_pins(self, e: int) -> np.ndarray:
        return self.e2v_indices[self.e2v_indptr[e]:self.e2v_indptr[e + 1]]

    def vertex_edges(self, v: int) -> np.ndarray:
        return self.v2e_indices[self.v2e_indptr[v]:self.v2e_indptr[v + 1]]

    def neighbors(self, v: int) -> np.ndarray:
        """Unique neighbor set N(v). O(sum of incident edge sizes)."""
        es = self.vertex_edges(v)
        if es.size == 0:
            return np.empty(0, dtype=self.e2v_indices.dtype)
        parts = [self.edge_pins(int(e)) for e in es]
        nb = np.unique(np.concatenate(parts))
        return nb[nb != v]

    def vertex_adjacency(self, max_expanded: int = 80_000_000):
        """CSR of unique neighbor lists N(v) for ALL vertices, memoized.

        Built in one vectorized pass: every pin (v, e) contributes all
        pins of e, and the (v, u) pairs are deduplicated globally — total
        intermediate work is sum over edges of |e|^2. Returns
        ``(indptr, indices)`` (self-loops excluded), or None when the
        expansion would exceed ``max_expanded`` pairs (pathological hub
        edges; callers fall back to per-batch deduplication).
        """
        cache = self.__dict__.get("_adj_cache")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_adj_cache", cache)
        if max_expanded in cache:
            return cache[max_expanded]
        expanded = int((self.edge_sizes.astype(np.int64) ** 2).sum())
        if expanded > max_expanded:
            adj = None
        else:
            from .scoring import gather_csr_rows   # numpy-only, no cycle
            sizes = self.edge_sizes.astype(np.int64)
            edge_of_pin = np.repeat(np.arange(self.m, dtype=np.int64),
                                    sizes)
            # expand: for pin j of edge e, all pins of e
            nbr, owner_pin = gather_csr_rows(self.e2v_indptr,
                                             self.e2v_indices, edge_of_pin)
            nbr = nbr.astype(np.int64)
            owner = self.e2v_indices[owner_pin].astype(np.int64)
            keys = np.unique(owner * np.int64(self.n) + nbr)
            ov, nb = keys // self.n, keys % self.n
            keep = ov != nb
            ov, nb = ov[keep], nb[keep]
            indptr = np.zeros(self.n + 1, dtype=np.int64)
            indptr[1:] = np.cumsum(np.bincount(ov, minlength=self.n))
            adj = (indptr, nb.astype(np.int32))
        cache[max_expanded] = adj               # frozen-dataclass memo
        return adj

    def device_adjacency(self, max_expanded: int = 80_000_000, *,
                         mesh=None):
        """``vertex_adjacency`` uploaded to the device(s) once, memoized.

        Returns ``(indptr_dev, indices_dev)`` jax arrays (int32 where ids
        fit, otherwise int64) or None when the host-side expansion guard
        trips. The superstep engine gathers its candidate tiles from this
        image so refills never ship a freshly built (B, L) tile across
        the host boundary — only candidate *ids* move.

        With ``mesh`` (a ``jax.sharding.Mesh``), the CSR image is placed
        *replicated* across every mesh device — the layout the sharded
        superstep engine wants: each device gathers its own phase group's
        tiles from a full local copy, so sharding the phases never
        shards (or ships) the graph. Memoized per (max_expanded, mesh).
        """
        cache = self.__dict__.get("_device_adj_cache")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_device_adj_cache", cache)
        key = (max_expanded, mesh)
        if key in cache:
            return cache[key]
        adj = self.vertex_adjacency(max_expanded)
        if adj is None:
            dev = None
        else:
            import jax
            import jax.numpy as jnp
            indptr, indices = adj
            ptr_t = device_ptr_dtype(indices.size)
            dev = (jnp.asarray(indptr, ptr_t), jnp.asarray(indices))
            if mesh is not None:
                from jax.sharding import NamedSharding, PartitionSpec
                rep = NamedSharding(mesh, PartitionSpec())
                dev = tuple(jax.device_put(a, rep) for a in dev)
        cache[key] = dev
        return dev

    # ------------------------------------------------------------------ #
    # Delta / append APIs (streaming engine, core/hype_stream.py)
    # ------------------------------------------------------------------ #
    def _pin_arrays(self):
        """Parallel ``(vertex_ids, edge_ids)`` int64 pin arrays."""
        edge_ids = np.repeat(np.arange(self.m, dtype=np.int64),
                             self.edge_sizes)
        return self.e2v_indices.astype(np.int64), edge_ids

    def with_edges(self, new_edges: Sequence[Iterable[int]],
                   n: int | None = None) -> "Hypergraph":
        """Append hyperedges; returns a new graph with ``m + len(new_edges)``.

        ``new_edges`` is a sequence of pin iterables over *existing*
        vertex ids (or ids below ``n`` when growing the vertex count).
        Edge ids of the incumbent graph are preserved — appended edges
        take ids ``m, m+1, ...`` — so per-edge bookkeeping (the stream
        engine's sketch buckets) stays valid across the append.
        """
        vids, eids = self._pin_arrays()
        add_v, add_e = [], []
        for i, pins in enumerate(new_edges):
            for v in pins:
                add_v.append(int(v))
                add_e.append(self.m + i)
        vids = np.concatenate([vids, np.asarray(add_v, dtype=np.int64)])
        eids = np.concatenate([eids, np.asarray(add_e, dtype=np.int64)])
        return Hypergraph.from_pins(n if n is not None else self.n,
                                    self.m + len(new_edges), vids, eids)

    def with_vertices(self, memberships: Sequence[Iterable[int]]
                      ) -> "Hypergraph":
        """Append vertices; returns a new graph with ``n + len(memberships)``.

        Each entry lists the *existing* hyperedge ids the new vertex
        joins (possibly empty — isolated vertices are legal). Incumbent
        vertex and edge ids are preserved; appended vertices take ids
        ``n, n+1, ...``.
        """
        vids, eids = self._pin_arrays()
        add_v, add_e = [], []
        for i, edges in enumerate(memberships):
            for e in edges:
                add_v.append(self.n + i)
                add_e.append(int(e))
        vids = np.concatenate([vids, np.asarray(add_v, dtype=np.int64)])
        eids = np.concatenate([eids, np.asarray(add_e, dtype=np.int64)])
        return Hypergraph.from_pins(self.n + len(memberships), self.m,
                                    vids, eids)

    def without_edges(self, edge_ids: Iterable[int]) -> "Hypergraph":
        """Drop all pins of the given hyperedges; ids stay stable.

        The edge *slots* are kept (they become empty hyperedges), so no
        surviving edge is renumbered — deletions never invalidate ids
        held by incremental state.
        """
        drop = np.zeros(self.m, dtype=bool)
        drop[np.asarray(list(edge_ids), dtype=np.int64)] = True
        vids, eids = self._pin_arrays()
        keep = ~drop[eids]
        return Hypergraph.from_pins(self.n, self.m, vids[keep],
                                    eids[keep])

    def without_vertices(self, vertex_ids: Iterable[int]) -> "Hypergraph":
        """Drop all pins of the given vertices; ids stay stable.

        The vertex *slots* are kept (they become isolated vertices), so
        no surviving vertex is renumbered.
        """
        drop = np.zeros(self.n, dtype=bool)
        drop[np.asarray(list(vertex_ids), dtype=np.int64)] = True
        vids, eids = self._pin_arrays()
        keep = ~drop[vids]
        return Hypergraph.from_pins(self.n, self.m, vids[keep],
                                    eids[keep])

    # ------------------------------------------------------------------ #
    # Transformations
    # ------------------------------------------------------------------ #
    def flip(self) -> "Hypergraph":
        """Swap roles of vertices and hyperedges (paper §III-C).

        Flipping twice is the identity (up to pin ordering). Used for
        perfect hyperedge balancing: balance vertices in the flipped graph.
        """
        return Hypergraph(n=self.m, m=self.n,
                          v2e_indptr=self.e2v_indptr, v2e_indices=self.e2v_indices,
                          e2v_indptr=self.v2e_indptr, e2v_indices=self.v2e_indices)

    def validate(self) -> None:
        """Check the CSR invariants; raise ``ValueError`` on corruption.

        Raises (never asserts — ``python -O`` strips ``assert``, which
        would turn validation into a silent no-op) with a message naming
        the violated invariant. Returns None on a well-formed structure.
        """
        if self.v2e_indptr.shape != (self.n + 1,):
            raise ValueError(
                f"v2e_indptr shape {self.v2e_indptr.shape} != (n+1,) "
                f"= ({self.n + 1},)")
        if self.e2v_indptr.shape != (self.m + 1,):
            raise ValueError(
                f"e2v_indptr shape {self.e2v_indptr.shape} != (m+1,) "
                f"= ({self.m + 1},)")
        if self.v2e_indptr[-1] != self.v2e_indices.shape[0]:
            raise ValueError(
                f"v2e_indptr[-1] = {int(self.v2e_indptr[-1])} does not "
                f"match v2e_indices size {self.v2e_indices.shape[0]}")
        if self.e2v_indptr[-1] != self.e2v_indices.shape[0]:
            raise ValueError(
                f"e2v_indptr[-1] = {int(self.e2v_indptr[-1])} does not "
                f"match e2v_indices size {self.e2v_indices.shape[0]}")
        if self.v2e_indices.shape != self.e2v_indices.shape:
            raise ValueError(
                f"pin-count mismatch: {self.v2e_indices.shape[0]} v2e "
                f"pins vs {self.e2v_indices.shape[0]} e2v pins")
        if self.e2v_indices.size:
            if self.e2v_indices.min() < 0:
                raise ValueError("negative vertex id in e2v_indices")
            if self.e2v_indices.max() >= self.n:
                raise ValueError(
                    f"vertex id {int(self.e2v_indices.max())} out of "
                    f"range [0, {self.n})")
        if self.v2e_indices.size:
            if self.v2e_indices.min() < 0:
                raise ValueError("negative edge id in v2e_indices")
            if self.v2e_indices.max() >= self.m:
                raise ValueError(
                    f"edge id {int(self.v2e_indices.max())} out of "
                    f"range [0, {self.m})")

    def fingerprint(self) -> str:
        """Stable 16-hex-digit digest of the CSR structure, memoized.

        Identifies the graph a ``PartitionCheckpoint`` belongs to
        (core/resilience.py): restore refuses a snapshot whose
        fingerprint does not match the hypergraph it is applied to.
        Covers (n, m) and all four CSR arrays, so any structural edit
        changes it.
        """
        cached = self.__dict__.get("_fingerprint")
        if cached is not None:
            return cached
        import hashlib
        h = hashlib.sha256()
        h.update(np.asarray([self.n, self.m], dtype=np.int64).tobytes())
        for a in (self.v2e_indptr, self.v2e_indices,
                  self.e2v_indptr, self.e2v_indices):
            h.update(np.ascontiguousarray(a).tobytes())
        fp = h.hexdigest()[:16]
        object.__setattr__(self, "_fingerprint", fp)
        return fp

    def stats(self) -> dict:
        es, vd = self.edge_sizes, self.vertex_degrees
        return {
            "n_vertices": self.n,
            "n_hyperedges": self.m,
            "n_pins": self.n_pins,
            "max_edge_size": int(es.max()) if self.m else 0,
            "mean_edge_size": float(es.mean()) if self.m else 0.0,
            "max_vertex_degree": int(vd.max()) if self.n else 0,
            "mean_vertex_degree": float(vd.mean()) if self.n else 0.0,
        }

    # Sorted-by-size edge order (ascending); HYPE sorts hyperedges once.
    def edges_by_size(self) -> np.ndarray:
        return np.argsort(self.edge_sizes, kind="stable")
