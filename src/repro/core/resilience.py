"""Resilience subsystem for the partitioning engines (DESIGN.md §4f).

Three pieces, shared by every engine of the HYPE batched family:

  * **Checkpoints** — ``PartitionCheckpoint`` captures the complete
    engine state at a superstep (device engines) or phase (classic
    batched engine) boundary: assignment, score cache, pool store,
    per-phase counters and RNG state. Snapshots are published with an
    atomic ``.tmp`` + ``os.replace`` rename plus a ``LATEST`` pointer
    file, and garbage-collected down to ``keep_last``. Restoring a
    same-engine/same-config snapshot continues the run *bit-identically*
    to an uninterrupted run with the same snapshot cadence; a
    cross-engine restore (the degradation ladder) warm-starts from the
    snapshotted assignment instead.

  * **Fault injection** — ``FaultPlan`` deterministically injects
    faults at chosen supersteps: ``dispatch`` (an exception raised at
    the device-dispatch site), ``nan`` (a NaN-poisoned score tile),
    ``collective`` (a failed all_gather — fires only at the sharded
    engine's dispatch site) and ``oom`` (simulated allocation failure
    during the device image upload). Plans come from the ``fault_plan``
    engine param or the ``REPRO_FAULT_PLAN`` env var
    (``"dispatch@2;nan@4;collective@3"``); each spec fires at most once
    per engine run.

  * **Failure taxonomy** — ``FaultInjected`` marks an injected fault at
    its injection site; ``UnrecoverableFault`` is what engines raise
    when recovery inside the run is impossible (fatal injected fault,
    retry budget exhausted, device call failed after buffer donation).
    ``partition_api.partition_resilient`` catches it and walks the
    degradation ladder, resuming from the last snapshot.

The checkpoint store intentionally mirrors ``train/checkpoint.py``'s
publishing discipline (tmp + rename + LATEST + keep_last) without
importing it — core must stay importable without the train stack.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
from typing import Optional, Sequence, Tuple

import numpy as np

FAULT_KINDS = ("dispatch", "nan", "collective", "oom")

_SNAP_FMT = "snap_%08d.ckpt"
_LATEST = "LATEST"


class FaultInjected(RuntimeError):
    """An injected fault fired at its site (see ``FaultPlan``)."""

    def __init__(self, kind: str, superstep: int, fatal: bool = False):
        super().__init__(
            f"injected {kind} fault at superstep {superstep}"
            + (" (fatal)" if fatal else ""))
        self.kind = kind
        self.superstep = superstep
        self.fatal = fatal


class UnrecoverableFault(RuntimeError):
    """The engine cannot recover inside this run.

    Raised on a fatal injected fault, an exhausted retry budget, an
    exhausted memory-rung ladder (``membudget.MemoryLadderExhausted``
    after every re-tiling rung still OOMs), or a device failure after
    buffer donation (the donated inputs are consumed, so the call
    cannot be re-issued). ``partition_resilient`` catches it and falls
    back down the engine ladder from the last snapshot. Non-fatal
    memory faults do NOT raise this — they raise
    ``membudget.DeviceOOM`` and are retried on the same engine at a
    smaller memory plan first (DESIGN.md §4g).
    """


@dataclasses.dataclass
class FaultSpec:
    kind: str            # one of FAULT_KINDS
    superstep: int = 0   # 1-based dispatch ordinal; 0 for "oom" = any site
    fatal: bool = False  # fatal -> UnrecoverableFault instead of retry


class FaultPlan:
    """A deterministic, one-shot-per-spec fault schedule.

    ``fire(kinds, superstep)`` consumes and returns the first pending
    spec whose kind is in ``kinds`` and whose superstep matches. A bare
    ``"oom"`` spec (superstep 0) matches ANY site that asks for the
    kind — it fires at the first, the device-image upload — while
    ``"oom@N"`` pins the fault to dispatch ordinal ``N`` so allocation
    failures mid-run can be simulated too. A non-fatal ``oom`` is
    recovered by the memory-rung retry loop (``core/membudget.py``,
    DESIGN.md §4g) on the SAME engine; only ``oom:fatal`` abandons the
    engine for the degradation ladder. A plan object is stateful: pass
    the *same* instance through a degradation ladder so a consumed
    fault does not re-fire after a fallback.
    """

    def __init__(self, specs: Sequence[FaultSpec] = ()):
        self.specs = list(specs)
        self.fired: list = []

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"FaultPlan({self.specs!r})"

    @classmethod
    def parse(cls, text: str) -> "FaultPlan":
        """Parse ``"kind@superstep[:fatal]"`` specs, ``;``/``,`` joined.

        Examples: ``"dispatch@2"``, ``"nan@4;collective@3"``,
        ``"dispatch@9:fatal"``, ``"oom"`` (fires at image upload).
        """
        specs = []
        for raw in text.replace(",", ";").split(";"):
            part = raw.strip()
            if not part:
                continue
            fatal = False
            if part.endswith(":fatal"):
                fatal = True
                part = part[: -len(":fatal")]
            if "@" in part:
                kind, _, step = part.partition("@")
                try:
                    superstep = int(step)
                except ValueError:
                    raise ValueError(
                        f"bad fault superstep in {raw!r}") from None
            else:
                kind, superstep = part, 0
            kind = kind.strip().lower()
            if kind not in FAULT_KINDS:
                raise ValueError(
                    f"unknown fault kind {kind!r} in {raw!r}; "
                    f"choose from {FAULT_KINDS}")
            specs.append(FaultSpec(kind, superstep, fatal))
        return cls(specs)

    @classmethod
    def from_env(cls) -> Optional["FaultPlan"]:
        """Fresh plan from ``REPRO_FAULT_PLAN``, or None when unset.

        Parsed *per engine run* (every ``resolve_fault_plan(None)``
        call), so each run in a chaos suite sees the full plan.
        """
        text = os.environ.get("REPRO_FAULT_PLAN", "").strip()
        return cls.parse(text) if text else None

    def fire(self, kinds: Tuple[str, ...],
             superstep: int) -> Optional[FaultSpec]:
        for sp in self.specs:
            if sp.kind in kinds and (sp.superstep == superstep
                                     or (sp.kind == "oom"
                                         and sp.superstep == 0)):
                self.specs.remove(sp)
                self.fired.append(sp)
                return sp
        return None


def resolve_fault_plan(param) -> Optional[FaultPlan]:
    """Resolve an engine's ``fault_plan`` param to a live plan.

    None -> a fresh plan parsed from ``REPRO_FAULT_PLAN`` (or None);
    str -> parsed; a ``FaultPlan`` instance -> used as-is (shared firing
    state, which is what the degradation ladder wants).
    """
    if param is None:
        return FaultPlan.from_env()
    if isinstance(param, str):
        return FaultPlan.parse(param)
    return param


# --------------------------------------------------------------- checkpoints

@dataclasses.dataclass
class PartitionCheckpoint:
    """One published snapshot of a partition run.

    ``engine`` + ``config`` decide restore semantics: an exact match
    restores the full payload and continues bit-identically; anything
    else (the ladder's cross-engine resume) warm-starts from
    ``payload["assignment"]`` only. ``fingerprint`` pins the hypergraph
    the snapshot belongs to — restoring against a different graph is a
    hard error, not a silent corruption.
    """
    engine: str
    superstep: int          # superstep (device engines) / phase (batched)
    fingerprint: str
    config: dict
    payload: dict


def save_snapshot(dirpath: str, ckpt: PartitionCheckpoint,
                  keep_last: int = 3) -> str:
    """Atomically publish ``ckpt`` under ``dirpath``; returns its path.

    Write to ``.tmp``, fsync, ``os.replace`` (atomic on POSIX), then
    update the ``LATEST`` pointer the same way and GC old snapshots down
    to ``keep_last`` (by modification time — the ladder may interleave
    engines whose step counters are not comparable).
    """
    os.makedirs(dirpath, exist_ok=True)
    name = _SNAP_FMT % ckpt.superstep
    final = os.path.join(dirpath, name)
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        pickle.dump(ckpt, f, protocol=4)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    ltmp = os.path.join(dirpath, _LATEST + ".tmp")
    with open(ltmp, "w") as f:
        f.write(name)
    os.replace(ltmp, os.path.join(dirpath, _LATEST))
    _gc(dirpath, keep_last, keep=name)
    return final


def _gc(dirpath: str, keep_last: int, keep: str) -> None:
    snaps = [f for f in os.listdir(dirpath)
             if f.startswith("snap_") and f.endswith(".ckpt")]
    if len(snaps) <= keep_last:
        return
    snaps.sort(key=lambda f: os.path.getmtime(os.path.join(dirpath, f)))
    for f in snaps[:-keep_last]:
        if f != keep:
            try:
                os.remove(os.path.join(dirpath, f))
            except OSError:  # pragma: no cover - concurrent GC race
                pass


def latest_snapshot(dirpath: str) -> Optional[str]:
    """Path of the newest published snapshot in ``dirpath``, or None.

    Prefers the ``LATEST`` pointer (it is what the last atomic publish
    named); falls back to the newest snapshot file by mtime when the
    pointer is missing or dangling.
    """
    if not os.path.isdir(dirpath):
        return None
    ptr = os.path.join(dirpath, _LATEST)
    if os.path.exists(ptr):
        with open(ptr) as f:
            name = f.read().strip()
        path = os.path.join(dirpath, name)
        if os.path.exists(path):
            return path
    snaps = [f for f in os.listdir(dirpath)
             if f.startswith("snap_") and f.endswith(".ckpt")]
    if not snaps:
        return None
    snaps.sort(key=lambda f: os.path.getmtime(os.path.join(dirpath, f)))
    return os.path.join(dirpath, snaps[-1])


def load_snapshot(path: str) -> PartitionCheckpoint:
    with open(path, "rb") as f:
        ckpt = pickle.load(f)
    if not isinstance(ckpt, PartitionCheckpoint):
        raise ValueError(f"{path} is not a PartitionCheckpoint")
    return ckpt


def load_latest(path_or_dir: str) -> Optional[PartitionCheckpoint]:
    """Load a snapshot from a file path OR the newest one in a directory."""
    if os.path.isdir(path_or_dir):
        path = latest_snapshot(path_or_dir)
        return load_snapshot(path) if path else None
    if os.path.exists(path_or_dir):
        return load_snapshot(path_or_dir)
    return None


def check_checkpoint(ckpt: PartitionCheckpoint, hg, k: int) -> None:
    """Refuse a snapshot that does not belong to this (graph, k) run."""
    fp = hg.fingerprint()
    if ckpt.fingerprint != fp:
        raise ValueError(
            f"checkpoint fingerprint {ckpt.fingerprint} does not match "
            f"hypergraph {fp}: refusing to restore against a different "
            f"graph")
    ck = int(ckpt.config.get("k", k))
    if ck != k:
        raise ValueError(
            f"checkpoint was taken at k={ck}, cannot resume a k={k} run")


def warm_assignment(ckpt: PartitionCheckpoint) -> np.ndarray:
    """The snapshot's (possibly partial) assignment for warm starts."""
    return np.asarray(ckpt.payload["assignment"], dtype=np.int32)
