"""Streaming MinMax hypergraph partitioning (Alistarh et al., NIPS'15).

The paper's group (III) baseline. Vertices arrive in a random stream; each
vertex is greedily assigned to the partition with the largest overlap of
incident hyperedges, subject to a balance constraint:

  * ``minmax_eb`` — hyperedge-balanced (the original MinMax): the load of a
    partition is the number of distinct hyperedges incident to it; a vertex
    may only go to partitions whose load is within ``slack`` of the minimum.
  * ``minmax_nb`` — vertex-balanced variant introduced by the HYPE paper
    (footnote 2: slack of up to 100 vertices).

Per-partition hyperedge incidence is stored as a bit matrix (m x k bits) so
the overlap score for a vertex costs O(deg(v) * k/8) bytes of traffic.
"""
from __future__ import annotations

import numpy as np

from .hypergraph import Hypergraph


def _eligible_partitions(mode: str, vsizes: np.ndarray,
                         eloads: np.ndarray, slack: int,
                         cap: int) -> np.ndarray:
    """Eligibility mask for one streamed vertex (slack filter + fallback).

    ``nb`` mode: within ``slack`` of the least vertex-loaded partition
    AND under the hard vertex capacity ``cap``. ``eb`` mode: within
    ``slack`` of the least edge-loaded partition. When the slack filter
    empties, fall back to the least-loaded partitions — in ``nb`` mode
    the fallback must STILL respect ``cap`` (the old fallback dropped
    it, silently over-filling a capped partition); only when every
    partition is at capacity (impossible while vertices remain, kept as
    a never-stall guarantee) does the bare least-loaded rule apply.
    """
    if mode == "nb":
        eligible = vsizes <= vsizes.min() + slack
        eligible &= vsizes < cap
    else:
        eligible = eloads <= eloads.min() + slack
    if not eligible.any():
        if mode == "nb":
            under = vsizes < cap
            if under.any():
                return under & (vsizes == vsizes[under].min())
        return vsizes == vsizes.min()
    return eligible


def minmax_partition(hg: Hypergraph, k: int, *, mode: str = "nb",
                     slack: int = 100, seed: int = 0) -> np.ndarray:
    if mode not in ("nb", "eb"):
        raise ValueError("mode must be 'nb' or 'eb'")
    n, m = hg.n, hg.m
    rng = np.random.default_rng(seed)
    order = rng.permutation(n)

    kbytes = (k + 7) // 8
    # bit j of edge_bits[e, j//8] set <=> edge e touches partition j
    edge_bits = np.zeros((m, kbytes), dtype=np.uint8)
    bit_of = np.zeros((k, kbytes), dtype=np.uint8)
    for p in range(k):
        bit_of[p, p // 8] = np.uint8(1 << (p % 8))

    assignment = np.full(n, -1, dtype=np.int32)
    vsizes = np.zeros(k, dtype=np.int64)     # vertices per partition
    eloads = np.zeros(k, dtype=np.int64)     # distinct edges per partition

    indptr, indices = hg.v2e_indptr, hg.v2e_indices
    cap = -(-n // k) + slack                 # hard vertex capacity (nb mode)

    for v in order:
        v = int(v)
        es = indices[indptr[v]:indptr[v + 1]]
        if es.size:
            masks = edge_bits[es]                       # (deg, kbytes)
            bits = np.unpackbits(masks, axis=1, count=k, bitorder="little")
            overlap = bits.sum(axis=0).astype(np.int64)  # (k,)
        else:
            overlap = np.zeros(k, dtype=np.int64)

        eligible = _eligible_partitions(mode, vsizes, eloads, slack, cap)

        score = np.where(eligible, overlap, -1)
        best = int(np.argmax(score - 1e-9 * vsizes))  # tie-break: least loaded
        assignment[v] = best
        vsizes[best] += 1
        if es.size:
            newly = bits[:, best] == 0
            eloads[best] += int(newly.sum())
            edge_bits[es] |= bit_of[best]

    return assignment


def random_partition(hg: Hypergraph, k: int, seed: int = 0) -> np.ndarray:
    """Balanced random assignment (lower-bound-quality baseline)."""
    rng = np.random.default_rng(seed)
    base = np.arange(hg.n, dtype=np.int64) % k
    return rng.permutation(base).astype(np.int32)


def hashing_partition(hg: Hypergraph, k: int) -> np.ndarray:
    """Deterministic hash assignment (what production systems default to)."""
    v = np.arange(hg.n, dtype=np.uint64)
    h = (v * np.uint64(0x9E3779B97F4A7C15)) >> np.uint64(40)
    return (h % np.uint64(k)).astype(np.int32)
