"""Multilevel hypergraph partitioners (group (I) stand-in for hMETIS).

Two entry points share the coarsening machinery:

* ``multilevel_partition`` — recursive multilevel bisection:
  1. *Coarsen*: heavy-connectivity pair matching over small hyperedges
     (ring pairs inside each edge accumulate connectivity weight; greedy
     matching on the heaviest pairs), iterated until the graph is small.
  2. *Initial bisection*: weighted greedy fill from a random order.
  3. *Uncoarsen + refinement*: project the bipartition back one level
     at a time and refine. The refinement is the shared vectorized
     gain machinery of ``core/refine.py`` (exact-gain, edge-disjoint,
     balance-windowed admission) — the FM-style positive-gain pass it
     replaces walked every vertex in a Python loop per pass.
  4. Recurse on the two halves for k-way.

* ``hype_multilevel_partition`` — direct k-way multilevel (method
  ``hype_multilevel``): coarsen once, partition the coarsest graph with
  the device-resident ``hype_superstep`` engine, then uncoarsen with
  the same k-way refinement machinery at every level (weighted windows
  on the coarse levels, an exact rebalance + unit-cap refinement at the
  finest). This is the composition the refinement subsystem exists for
  (DESIGN.md §4e): neighborhood expansion seeds the solution, FM-style
  uncoarsening refinement closes the quality gap.

hMETIS itself is closed-source; the bisection rendition reproduces its
algorithmic family (multilevel recursive bisection, paper §IV "group
(I)") at the small/medium scales where the paper reports it is
competitive — and like the original it is expected to struggle (here:
be prohibitively slow) on massive hypergraphs, which the benchmarks
demonstrate.
"""
from __future__ import annotations

import numpy as np

from .hypergraph import Hypergraph
from .refine import refine_kway, rebalance_kway

_MAX_MATCH_EDGE = 64      # only edges this small contribute matching pairs
_COARSEST = 160           # stop coarsening below this many vertices
_EPS = 0.05               # bisection balance tolerance


def _pair_weights(hg: Hypergraph):
    """Connectivity weight per vertex pair from ring pairs in small edges."""
    sizes = hg.edge_sizes
    keep = (sizes >= 2) & (sizes <= _MAX_MATCH_EDGE)
    us, vs, ws = [], [], []
    eids = np.flatnonzero(keep)
    for e in eids:
        pins = hg.edge_pins(int(e)).astype(np.int64)
        nxt = np.roll(pins, -1)
        us.append(pins)
        vs.append(nxt)
        ws.append(np.full(pins.size, 1.0 / (pins.size - 1)))
    if not us:
        return (np.empty(0, np.int64),) * 2 + (np.empty(0, np.float64),)
    u = np.concatenate(us); v = np.concatenate(vs); w = np.concatenate(ws)
    lo, hi = np.minimum(u, v), np.maximum(u, v)
    key = lo * np.int64(hg.n) + hi
    uk, inv = np.unique(key, return_inverse=True)
    wsum = np.zeros(uk.size)
    np.add.at(wsum, inv, w)
    return uk // hg.n, uk % hg.n, wsum


def _coarsen_once(hg: Hypergraph, vweights: np.ndarray):
    u, v, w = _pair_weights(hg)
    order = np.argsort(-w, kind="stable")
    matched = np.full(hg.n, -1, dtype=np.int64)
    for i in order:
        a, b = int(u[i]), int(v[i])
        if matched[a] < 0 and matched[b] < 0 and a != b:
            matched[a], matched[b] = b, a
    # build coarse ids
    cid = np.full(hg.n, -1, dtype=np.int64)
    nxt = 0
    for x in range(hg.n):
        if cid[x] >= 0:
            continue
        cid[x] = nxt
        if matched[x] >= 0:
            cid[matched[x]] = nxt
        nxt += 1
    if nxt >= hg.n:   # no contraction happened
        return None
    # rebuild pins under the contraction map
    edge_of_pin = np.repeat(np.arange(hg.m, dtype=np.int64), hg.edge_sizes)
    cpins = cid[hg.e2v_indices]
    chg = Hypergraph.from_pins(nxt, hg.m, cpins, edge_of_pin)
    cw = np.zeros(nxt)
    np.add.at(cw, cid, vweights)
    return chg, cw, cid


def _fm_refine(hg: Hypergraph, side: np.ndarray, vweights: np.ndarray,
               target_a: float, passes: int = 3) -> np.ndarray:
    """2-way refinement of boolean ``side`` (True = side B).

    The shared k-way gain machinery (``core/refine.py``) at k = 2:
    exact cut gains for every boundary vertex in one vectorized pass,
    admitted greedily under edge-disjointness and the ``±_EPS`` weight
    window — the same positive-gain moves the old per-vertex FM loop
    hunted for, without the O(n) Python pass per refinement round.
    """
    total = float(vweights.sum())
    lo = np.array([target_a - _EPS * total,
                   (total - target_a) - _EPS * total])
    hi = np.array([target_a + _EPS * total,
                   (total - target_a) + _EPS * total])
    refined, _ = refine_kway(hg, side.astype(np.int32), 2, passes,
                             weights=vweights, lo=lo, hi=hi,
                             use_device=False)
    return refined.astype(bool)


def _bisect(hg: Hypergraph, vweights: np.ndarray, frac_a: float,
            rng: np.random.Generator) -> np.ndarray:
    """Multilevel 2-way split. Returns bool array (True = side B)."""
    levels = []
    cur, curw = hg, vweights
    while cur.n > _COARSEST:
        res = _coarsen_once(cur, curw)
        if res is None:
            break
        chg, cw, cid = res
        levels.append((cur, curw, cid))
        cur, curw = chg, cw
    # initial partition at coarsest: greedy weighted fill
    total = float(curw.sum())
    target_a = frac_a * total
    order = rng.permutation(cur.n)
    side = np.zeros(cur.n, dtype=bool)
    acc = 0.0
    for v in order:
        if acc + curw[v] <= target_a:
            acc += curw[v]
        else:
            side[v] = True
    side = _fm_refine(cur, side, curw, target_a)
    # uncoarsen
    while levels:
        fine, finew, cid = levels.pop()
        side = side[cid]
        side = _fm_refine(fine, side, finew, frac_a * float(finew.sum()))
    return side


def _sub_hypergraph(hg: Hypergraph, mask: np.ndarray):
    new_id = np.cumsum(mask) - 1
    edge_of_pin = np.repeat(np.arange(hg.m, dtype=np.int64), hg.edge_sizes)
    keep = mask[hg.e2v_indices]
    vp = new_id[hg.e2v_indices[keep]]
    ep = edge_of_pin[keep]
    # re-number edges compactly, drop edges with < 2 remaining pins
    ue, inv = np.unique(ep, return_inverse=True)
    cnt = np.bincount(inv)
    keep_e = cnt[inv] >= 2
    ue2, inv2 = np.unique(inv[keep_e], return_inverse=True)
    sub = Hypergraph.from_pins(int(mask.sum()), int(ue2.size),
                               vp[keep_e], inv2)
    return sub, np.flatnonzero(mask)


def multilevel_partition(hg: Hypergraph, k: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    assignment = np.zeros(hg.n, dtype=np.int32)
    vweights = np.ones(hg.n)

    def rec(sub: Hypergraph, ids: np.ndarray, w: np.ndarray, kk: int, base: int):
        if kk == 1 or sub.n == 0:
            assignment[ids] = base
            return
        k1 = kk // 2
        side = _bisect(sub, w, k1 / kk, rng)
        maskA = ~side
        subA, la = _sub_hypergraph(sub, maskA)
        subB, lb = _sub_hypergraph(sub, side)
        rec(subA, ids[la], w[maskA], k1, base)
        rec(subB, ids[lb], w[side], kk - k1, base + k1)

    rec(hg, np.arange(hg.n, dtype=np.int64), vweights, k, 0)
    return assignment


def hype_multilevel_partition(hg: Hypergraph, k: int, *, seed: int = 0,
                              refine_passes: int = 3,
                              coarsest: int = 3000) -> np.ndarray:
    """Direct k-way multilevel partitioning (method ``hype_multilevel``).

    Coarsen by heavy-connectivity matching until the graph drops below
    ``max(coarsest, 8k)`` vertices, produce the initial k-way assignment
    with the device-resident ``hype_superstep`` engine (all k phases
    grown concurrently on the coarsest graph), then uncoarsen: project
    the assignment through each contraction map and run the shared
    k-way refinement (``core/refine.py``) — weighted balance windows on
    the coarse levels, then an exact rebalance plus unit-cap refinement
    at the finest level, so the final assignment keeps the HYPE family's
    ``max - min <= 1`` vertex-balance contract. Seeded-deterministic.
    """
    if k < 1:
        raise ValueError("k must be >= 1")
    out_small = np.zeros(hg.n, dtype=np.int32)
    if k == 1 or hg.n == 0:
        return out_small
    from repro.engines.superstep import (SuperstepParams,
                                         hype_superstep_partition)

    levels = []
    cur, curw = hg, np.ones(hg.n)
    while cur.n > max(coarsest, 8 * k):
        res = _coarsen_once(cur, curw)
        if res is None:
            break
        chg, cw, cid = res
        levels.append((cur, curw, cid))
        cur, curw = chg, cw

    a = hype_superstep_partition(cur, k, SuperstepParams(seed=seed))

    def _window(w):
        tgt = float(w.sum()) / k
        return (np.full(k, (1.0 - 2 * _EPS) * tgt),
                np.full(k, (1.0 + 2 * _EPS) * tgt))

    if levels:      # coarse-vertex counts balance, weights may not:
        lo, hi = _window(curw)      # refine under the weighted window
        a, _ = refine_kway(cur, a, k, refine_passes, weights=curw,
                           lo=lo, hi=hi, use_device=False)
    while levels:
        fine, finew, cid = levels.pop()
        a = a[cid]
        if levels:      # intermediate level: still weighted
            lo, hi = _window(finew)
            a, _ = refine_kway(fine, a, k, refine_passes, weights=finew,
                               lo=lo, hi=hi, use_device=False)
    # finest level: unit weights — restore the exact balance contract,
    # then refine under the tight [floor, ceil] caps (device screening)
    a = rebalance_kway(hg, np.asarray(a, dtype=np.int32), k)
    a, _ = refine_kway(hg, a, k, refine_passes)
    return a.astype(np.int32)
