"""Mini multilevel hypergraph partitioner (group (I) stand-in for hMETIS).

Recursive multilevel bisection:
  1. *Coarsen*: heavy-connectivity pair matching over small hyperedges
     (ring pairs inside each edge accumulate connectivity weight; greedy
     matching on the heaviest pairs), iterated until the graph is small.
  2. *Initial bisection*: weighted greedy fill from a random order.
  3. *Uncoarsen + FM refinement*: project the bipartition back one level at
     a time and run Fiduccia-Mattheyses-style positive-gain passes.
  4. Recurse on the two halves for k-way.

hMETIS itself is closed-source; this rendition reproduces its algorithmic
family (multilevel recursive bisection, paper §IV "group (I)") at the small
/medium scales where the paper reports it is competitive — and like the
original it is expected to fail (here: be prohibitively slow) on massive
hypergraphs, which the benchmarks demonstrate.
"""
from __future__ import annotations

import numpy as np

from .hypergraph import Hypergraph

_MAX_MATCH_EDGE = 64      # only edges this small contribute matching pairs
_COARSEST = 160           # stop coarsening below this many vertices
_EPS = 0.05               # bisection balance tolerance


def _pair_weights(hg: Hypergraph):
    """Connectivity weight per vertex pair from ring pairs in small edges."""
    sizes = hg.edge_sizes
    keep = (sizes >= 2) & (sizes <= _MAX_MATCH_EDGE)
    us, vs, ws = [], [], []
    eids = np.flatnonzero(keep)
    for e in eids:
        pins = hg.edge_pins(int(e)).astype(np.int64)
        nxt = np.roll(pins, -1)
        us.append(pins)
        vs.append(nxt)
        ws.append(np.full(pins.size, 1.0 / (pins.size - 1)))
    if not us:
        return (np.empty(0, np.int64),) * 2 + (np.empty(0, np.float64),)
    u = np.concatenate(us); v = np.concatenate(vs); w = np.concatenate(ws)
    lo, hi = np.minimum(u, v), np.maximum(u, v)
    key = lo * np.int64(hg.n) + hi
    uk, inv = np.unique(key, return_inverse=True)
    wsum = np.zeros(uk.size)
    np.add.at(wsum, inv, w)
    return uk // hg.n, uk % hg.n, wsum


def _coarsen_once(hg: Hypergraph, vweights: np.ndarray):
    u, v, w = _pair_weights(hg)
    order = np.argsort(-w, kind="stable")
    matched = np.full(hg.n, -1, dtype=np.int64)
    for i in order:
        a, b = int(u[i]), int(v[i])
        if matched[a] < 0 and matched[b] < 0 and a != b:
            matched[a], matched[b] = b, a
    # build coarse ids
    cid = np.full(hg.n, -1, dtype=np.int64)
    nxt = 0
    for x in range(hg.n):
        if cid[x] >= 0:
            continue
        cid[x] = nxt
        if matched[x] >= 0:
            cid[matched[x]] = nxt
        nxt += 1
    if nxt >= hg.n:   # no contraction happened
        return None
    # rebuild pins under the contraction map
    edge_of_pin = np.repeat(np.arange(hg.m, dtype=np.int64), hg.edge_sizes)
    cpins = cid[hg.e2v_indices]
    chg = Hypergraph.from_pins(nxt, hg.m, cpins, edge_of_pin)
    cw = np.zeros(nxt)
    np.add.at(cw, cid, vweights)
    return chg, cw, cid


def _fm_refine(hg: Hypergraph, side: np.ndarray, vweights: np.ndarray,
               target_a: float, passes: int = 3) -> np.ndarray:
    """2-way FM-style refinement of boolean ``side`` (True = side B)."""
    side = side.copy()
    edge_of_pin = np.repeat(np.arange(hg.m, dtype=np.int64), hg.edge_sizes)
    for _ in range(passes):
        cntB = np.zeros(hg.m, dtype=np.int64)
        np.add.at(cntB, edge_of_pin, side[hg.e2v_indices].astype(np.int64))
        cntA = hg.edge_sizes - cntB
        # gain of moving v out of its side
        gA = np.zeros(hg.n, dtype=np.int64)   # gain if v in A moves to B
        gB = np.zeros(hg.n, dtype=np.int64)
        np.add.at(gA, hg.e2v_indices,
                  (cntB[edge_of_pin] > 0).astype(np.int64)
                  - (cntA[edge_of_pin] > 1).astype(np.int64))
        np.add.at(gB, hg.e2v_indices,
                  (cntA[edge_of_pin] > 0).astype(np.int64)
                  - (cntB[edge_of_pin] > 1).astype(np.int64))
        gain = np.where(side, gB, gA)
        order = np.argsort(-gain, kind="stable")
        wA = float(vweights[~side].sum())
        total = float(vweights.sum())
        lo, hi = target_a - _EPS * total, target_a + _EPS * total
        moved_any = False
        locked = np.zeros(hg.n, dtype=bool)
        for v in order:
            v = int(v)
            if gain[v] <= 0:
                break
            if locked[v]:
                continue
            wv = float(vweights[v])
            if side[v]:     # B -> A
                if wA + wv > hi:
                    continue
                wA += wv
            else:           # A -> B
                if wA - wv < lo:
                    continue
                wA -= wv
            # verify gain is still correct w.r.t. current counts
            es = hg.vertex_edges(v)
            if side[v]:
                g = int((cntA[es] > 0).sum() - (cntB[es] > 1).sum())
            else:
                g = int((cntB[es] > 0).sum() - (cntA[es] > 1).sum())
            if g <= 0:
                if side[v]:
                    wA -= wv
                else:
                    wA += wv
                continue
            if side[v]:
                cntB[es] -= 1
                cntA[es] += 1
            else:
                cntA[es] -= 1
                cntB[es] += 1
            side[v] = ~side[v]
            locked[v] = True
            moved_any = True
        if not moved_any:
            break
    return side


def _bisect(hg: Hypergraph, vweights: np.ndarray, frac_a: float,
            rng: np.random.Generator) -> np.ndarray:
    """Multilevel 2-way split. Returns bool array (True = side B)."""
    levels = []
    cur, curw = hg, vweights
    while cur.n > _COARSEST:
        res = _coarsen_once(cur, curw)
        if res is None:
            break
        chg, cw, cid = res
        levels.append((cur, curw, cid))
        cur, curw = chg, cw
    # initial partition at coarsest: greedy weighted fill
    total = float(curw.sum())
    target_a = frac_a * total
    order = rng.permutation(cur.n)
    side = np.zeros(cur.n, dtype=bool)
    acc = 0.0
    for v in order:
        if acc + curw[v] <= target_a:
            acc += curw[v]
        else:
            side[v] = True
    side = _fm_refine(cur, side, curw, target_a)
    # uncoarsen
    while levels:
        fine, finew, cid = levels.pop()
        side = side[cid]
        side = _fm_refine(fine, side, finew, frac_a * float(finew.sum()))
    return side


def _sub_hypergraph(hg: Hypergraph, mask: np.ndarray):
    new_id = np.cumsum(mask) - 1
    edge_of_pin = np.repeat(np.arange(hg.m, dtype=np.int64), hg.edge_sizes)
    keep = mask[hg.e2v_indices]
    vp = new_id[hg.e2v_indices[keep]]
    ep = edge_of_pin[keep]
    # re-number edges compactly, drop edges with < 2 remaining pins
    ue, inv = np.unique(ep, return_inverse=True)
    cnt = np.bincount(inv)
    keep_e = cnt[inv] >= 2
    ue2, inv2 = np.unique(inv[keep_e], return_inverse=True)
    sub = Hypergraph.from_pins(int(mask.sum()), int(ue2.size),
                               vp[keep_e], inv2)
    return sub, np.flatnonzero(mask)


def multilevel_partition(hg: Hypergraph, k: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    assignment = np.zeros(hg.n, dtype=np.int32)
    vweights = np.ones(hg.n)

    def rec(sub: Hypergraph, ids: np.ndarray, w: np.ndarray, kk: int, base: int):
        if kk == 1 or sub.n == 0:
            assignment[ids] = base
            return
        k1 = kk // 2
        side = _bisect(sub, w, k1 / kk, rng)
        maskA = ~side
        subA, la = _sub_hypergraph(sub, maskA)
        subB, lb = _sub_hypergraph(sub, side)
        rec(subA, ids[la], w[maskA], k1, base)
        rec(subB, ids[lb], w[side], kk - k1, base + k1)

    rec(hg, np.arange(hg.n, dtype=np.int64), vweights, k, 0)
    return assignment
