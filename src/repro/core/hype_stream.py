"""Single-pass streaming / online HYPE partitioner (DESIGN.md §4h).

Every engine in the ladder needs the full hypergraph up front; this one
maintains an assignment while vertices *arrive*. Two modes share one
state object:

  * **Streaming pass** (``hype_stream_partition``): vertices arrive in a
    deterministic stream order and are buffered into micro-batches. Each
    micro-batch is one device call (``scoring.stream_step_device``): the
    fused ``hype_score_select`` Pallas kernel scores the batch against
    all k partition *fringes* at once, then a sequential on-device
    commit loop scores each vertex's k targets against the live
    **partition sketch** — per-partition hashed edge-presence counts,
    ``(k, 2**sketch_bits)`` int32 — with a FREIGHT-style balance
    penalty, and admits it under a hard capacity cap. The sketch and
    size vectors stay device-resident (donated) across batches; only
    the (mb, L) tiles go down and the (mb,) choices come back.

        score(v, p) = conn(v, p) + fringe_weight * |N(v) ∩ fringe_p|
                      - balance_alpha * size_p * (k / n)

    where ``conn(v, p)`` counts incident hyperedges whose sketch bucket
    is already present in partition ``p``. Ties break to the lowest
    partition id; at ``micro_batch=1`` the schedule is exactly the
    sequential streaming algorithm, replicated bit-for-bit by the numpy
    oracle in tests/test_hype_stream.py.

  * **Incremental mode** (``apply_updates``): vertex/edge insertions
    and deletions mutate the existing assignment. Deletions
    exact-decrement the sketch (the same invariant the superstep
    engines keep for their score cache: ``sketch[p, b]`` always equals
    the recount over current pins — digest-testable, zero residue);
    insertions re-admit new vertices through the same micro-batch
    scorer; and the *dirtied neighborhoods* — everything within
    ``update_radius`` hops of a touched vertex or edge — are locally
    re-expanded through one bounded ``refine_kway`` pass
    (``candidates=``-restricted, the PR 5 subsystem), never the whole
    graph.

Resilience follows the engine family's contract: ``snapshot_every``
micro-batches publish a ``PartitionCheckpoint`` (exact same-config
restore resumes the stream bit-identically), and a ``FaultPlan``
(knob or ``REPRO_FAULT_PLAN``) injects pre-dispatch faults that are
retried by replaying the deterministic batch. Device bytes participate
in the §4g planner via ``membudget.plan_stream_memory`` — a tight
budget halves the micro-batch, then drops the tile width.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Iterable, Optional, Sequence, Tuple

import numpy as np

from . import membudget, refine, resilience, scoring
from .hypergraph import Hypergraph
from .resilience import UnrecoverableFault

# Documented one-pass quality bound: km1(hype_stream) / km1(offline hype)
# on the quick generators stays under this factor. Streaming-partitioner
# papers report 1.5-4x for single-pass algorithms vs offline baselines;
# measured here the sketch+fringe scorer lands at 0.9-1.1x, so 2.0 keeps
# a comfortable margin. Enforced by tests/test_hype_stream.py and the
# compare_baseline bench gate (meta.streaming rows).
STREAM_KM1_BOUND = 2.0


@dataclasses.dataclass(frozen=True)
class StreamParams:
    """Knobs of the streaming engine (see module doc for semantics)."""
    micro_batch: int = 64       # vertices per device call
    sketch_bits: int = 16       # sketch table width: 2**sketch_bits buckets
    update_radius: int = 2      # dirty-neighborhood hops in apply_updates
    s: int = 16                 # fringe slots per partition
    balance_alpha: float = 1.0  # FREIGHT-style balance penalty weight
    fringe_weight: float = 0.5  # weight of the fringe-intersection term
    order: str = "random"       # arrival order: "random" (seeded) | "natural"
    seed: int = 0
    snapshot_every: int = 0     # micro-batches between snapshots (0 = off)
    snapshot_dir: Optional[str] = None
    keep_last: int = 3
    resume: Optional[str] = None
    fault_plan: Optional[object] = None
    max_retries: int = 2
    mem_budget: Optional[object] = None


@dataclasses.dataclass
class StreamStats:
    """Counters of one stream (and its later ``apply_updates`` calls)."""
    vertices: int = 0             # vertices admitted by the stream pass
    micro_batches: int = 0
    device_calls: int = 0
    kernel_rows: int = 0          # batch rows scored by the fused kernel
    host_to_device_bytes: int = 0
    stream_s: float = 0.0
    vertices_per_s: float = 0.0   # sustained stream throughput
    # memory plan (DESIGN.md §4g participation)
    planned_bytes: int = 0
    plan_micro_batch: int = 0
    plan_tile_l: int = 0
    # resilience
    faults_injected: int = 0
    retries: int = 0
    snapshots: int = 0
    snapshot_s: float = 0.0
    restore_s: float = 0.0
    resumed_at: int = -1          # micro-batch ordinal a resume continued at
    # incremental mode
    updates_applied: int = 0
    inserts: int = 0
    deletes: int = 0
    readmitted: int = 0           # vertices re-admitted by apply_updates
    refine_moves: int = 0         # bounded-radius re-expansion moves
    rebalance_moves: int = 0      # balance-guard forced moves
    update_s: float = 0.0
    updates_per_s: float = 0.0


@dataclasses.dataclass
class StreamState:
    """The online partitioner's full mutable state.

    ``assignment[v] == -1`` marks a vertex not currently admitted
    (never streamed yet, or deleted); ``full_assignment()`` fills those
    deterministically for metrics. The sketch invariant — maintained
    exactly by both modes — is ``sketch == recompute_sketch(...)``:
    every (pin, partition) incidence of the *current* graph is counted
    exactly once (``sketch_digest`` pins it in tests).
    """
    hg: Hypergraph
    k: int
    params: StreamParams
    assignment: np.ndarray        # (n,) int32, -1 = not admitted
    sizes: np.ndarray             # (k,) int32 admitted counts
    sketch: np.ndarray            # (k, 2**sketch_bits) int32
    fringe: np.ndarray            # (k, s) int32, -1 = empty slot
    fringe_pos: np.ndarray        # (k,) int64 ring write cursors
    cursor: int = 0               # vertices consumed from the stream order
    batch_idx: int = 0            # micro-batch ordinal (1-based after ++)
    stats: StreamStats = dataclasses.field(default_factory=StreamStats)

    def sketch_digest(self) -> str:
        """sha256 of (sketch, sizes) — the exact-decrement invariant."""
        h = hashlib.sha256()
        h.update(np.ascontiguousarray(self.sketch).tobytes())
        h.update(np.ascontiguousarray(self.sizes).tobytes())
        return h.hexdigest()[:16]

    def full_assignment(self) -> np.ndarray:
        """Complete assignment: unadmitted slots fill smallest-first.

        Deterministic (lowest partition id on ties, ascending vertex
        id), so metrics over a state with deletions are reproducible.
        """
        return _fill_unassigned(self.assignment, self.k)


def recompute_sketch(hg: Hypergraph, assignment: np.ndarray, k: int,
                     sketch_bits: int) -> Tuple[np.ndarray, np.ndarray]:
    """From-scratch ``(sketch, sizes)`` of (hg, assignment).

    The ground truth the exact-decrement bookkeeping must match: one
    count per current pin (v, e) with ``assignment[v] >= 0``.
    """
    sketch = np.zeros((k, 1 << sketch_bits), dtype=np.int32)
    sizes = np.bincount(assignment[assignment >= 0],
                        minlength=k).astype(np.int32)
    vids = hg.e2v_indices.astype(np.int64)
    eids = np.repeat(np.arange(hg.m, dtype=np.int64), hg.edge_sizes)
    parts = assignment[vids]
    live = parts >= 0
    buckets = scoring.stream_bucket(eids[live], sketch_bits)
    np.add.at(sketch, (parts[live].astype(np.int64), buckets), 1)
    return sketch, sizes


def _fill_unassigned(assignment: np.ndarray, k: int) -> np.ndarray:
    out = np.array(assignment, dtype=np.int32, copy=True)
    holes = np.flatnonzero(out < 0)
    if holes.size == 0:
        return out
    sizes = np.bincount(out[out >= 0], minlength=k).astype(np.int64)
    for v in holes:
        p = int(np.argmin(sizes))      # first-min = lowest id on ties
        out[v] = p
        sizes[p] += 1
    return out


# ----------------------------------------------------------- tile building

def _csr_tile(indptr, indices, ids: np.ndarray, cap: int,
              pad_rows: int) -> np.ndarray:
    """(pad_rows, L) -1-padded tile of CSR rows, truncated at ``cap``.

    Rows keep their CSR (sorted ascending) order; the width bucket is
    the smallest ``L_BUCKETS`` entry covering the truncated max row.
    The numpy oracle slices the same CSR rows at the same cap, so both
    sides see identical (possibly truncated) neighborhoods.
    """
    vals, owner = scoring.gather_csr_rows(indptr, indices, ids)
    counts = np.bincount(owner, minlength=ids.size) if vals.size else \
        np.zeros(ids.size, dtype=np.int64)
    width = int(min(counts.max() if counts.size else 0, cap))
    L = scoring._bucket_width(max(width, 1))
    tile = np.full((pad_rows, L), -1, np.int32)
    if vals.size:
        row_start = np.cumsum(counts) - counts
        offs = np.arange(vals.size, dtype=np.int64) - row_start[owner]
        keep = offs < cap
        tile[owner[keep], offs[keep]] = vals[keep]
    return tile


def _stream_adjacency(hg: Hypergraph):
    adj = hg.vertex_adjacency()
    if adj is not None:
        return adj
    # hub-expansion guard tripped: fall back to a degenerate adjacency
    # built per batch via neighbor_tile (rare; quality path unchanged)
    return None


def _batch_tiles(hg: Hypergraph, adj, batch: np.ndarray, tile_cap: int,
                 pad_rows: int) -> Tuple[np.ndarray, np.ndarray]:
    """Edge and neighbor tiles for a (pad-stripped) micro-batch."""
    edge_tile = _csr_tile(hg.v2e_indptr, hg.v2e_indices, batch,
                          tile_cap, pad_rows)
    if adj is not None:
        nbr_tile = _csr_tile(adj[0], adj[1], batch, tile_cap, pad_rows)
    else:
        dummy = np.full(hg.n, -1, np.int32)   # no assigned-filtering
        nbr_tile, _ = scoring.neighbor_tile(hg, batch, dummy,
                                            pad_b=pad_rows)
        if nbr_tile.shape[1] > tile_cap:
            nbr_tile = nbr_tile[:, :tile_cap]
    return edge_tile, nbr_tile


# --------------------------------------------------------------- the pass

def _validate_params(p: StreamParams) -> None:
    if p.micro_batch < 1:
        raise ValueError(f"micro_batch must be >= 1, got {p.micro_batch}")
    if not 4 <= p.sketch_bits <= 24:
        raise ValueError(
            f"sketch_bits must be in [4, 24], got {p.sketch_bits}")
    if p.s < 1:
        raise ValueError(f"s must be >= 1, got {p.s}")
    if p.order not in ("random", "natural"):
        raise ValueError(
            f"order must be 'random' or 'natural', got {p.order!r}")
    if p.update_radius < 0:
        raise ValueError(
            f"update_radius must be >= 0, got {p.update_radius}")
    if p.snapshot_every > 0 and not p.snapshot_dir:
        raise ValueError("snapshot_every > 0 requires snapshot_dir")


def _stream_order(n: int, p: StreamParams) -> np.ndarray:
    if p.order == "natural":
        return np.arange(n, dtype=np.int64)
    return np.random.default_rng(p.seed).permutation(n)


def _config_dict(state: StreamState, plan_mb: int, plan_tl: int) -> dict:
    p = state.params
    return {"k": state.k, "micro_batch": plan_mb, "tile_l": plan_tl,
            "sketch_bits": p.sketch_bits, "s": p.s,
            "balance_alpha": p.balance_alpha,
            "fringe_weight": p.fringe_weight, "order": p.order,
            "seed": p.seed}


def _push_fringe(state: StreamState, vs: np.ndarray,
                 parts: np.ndarray) -> None:
    """Ring-append admitted vertices to their partitions' fringes."""
    s = state.fringe.shape[1]
    for p in np.unique(parts[parts >= 0]):
        vp = vs[parts == p]
        pos = int(state.fringe_pos[p])
        if vp.size >= s:
            # only the last s sequential writes survive a full wrap
            start = (pos + vp.size - s) % s
            state.fringe[p, (start + np.arange(s)) % s] = vp[-s:]
        else:
            state.fringe[p, (pos + np.arange(vp.size)) % s] = vp
        state.fringe_pos[p] = pos + vp.size


def _snapshot(state: StreamState, plan_mb: int, plan_tl: int,
              sketch_dev, sizes_dev) -> None:
    t0 = time.perf_counter()
    state.sketch = np.array(sketch_dev, dtype=np.int32)
    state.sizes = np.array(sizes_dev, dtype=np.int32)
    ckpt = resilience.PartitionCheckpoint(
        engine="hype_stream", superstep=state.batch_idx,
        fingerprint=state.hg.fingerprint(),
        config=_config_dict(state, plan_mb, plan_tl),
        payload={"assignment": state.assignment.copy(),
                 "sizes": state.sizes.copy(),
                 "sketch": state.sketch.copy(),
                 "fringe": state.fringe.copy(),
                 "fringe_pos": state.fringe_pos.copy(),
                 "cursor": state.cursor,
                 "batch_idx": state.batch_idx})
    resilience.save_snapshot(state.params.snapshot_dir, ckpt,
                             state.params.keep_last)
    state.stats.snapshots += 1
    state.stats.snapshot_s += time.perf_counter() - t0


def _try_resume(state: StreamState, plan_mb: int, plan_tl: int) -> None:
    ckpt = resilience.load_latest(state.params.resume)
    if ckpt is None:
        return
    resilience.check_checkpoint(ckpt, state.hg, state.k)
    if ckpt.engine != "hype_stream" \
            or ckpt.config != _config_dict(state, plan_mb, plan_tl):
        return                      # cross-config snapshots cold-start
    t0 = time.perf_counter()
    pay = ckpt.payload
    state.assignment = np.asarray(pay["assignment"], np.int32).copy()
    state.sizes = np.asarray(pay["sizes"], np.int32).copy()
    state.sketch = np.asarray(pay["sketch"], np.int32).copy()
    state.fringe = np.asarray(pay["fringe"], np.int32).copy()
    state.fringe_pos = np.asarray(pay["fringe_pos"], np.int64).copy()
    state.cursor = int(pay["cursor"])
    state.batch_idx = int(pay["batch_idx"])
    state.stats.resumed_at = state.batch_idx
    state.stats.restore_s = time.perf_counter() - t0


def _fire_faults(plan, state: StreamState, ordinal: int) -> None:
    """Pre-dispatch fault site: injected faults replay the batch.

    Faults fire *before* the device call so the donated sketch/size
    buffers are never half-consumed; the batch is deterministic, so a
    retry replays it bit-identically. A fatal spec or an exhausted
    retry budget raises ``UnrecoverableFault``.
    """
    if plan is None:
        return
    retries = 0
    while True:
        spec = plan.fire(("dispatch", "nan"), ordinal)
        if spec is None:
            return
        state.stats.faults_injected += 1
        if spec.fatal:
            raise UnrecoverableFault(
                f"fatal injected {spec.kind} fault at stream "
                f"micro-batch {ordinal}")
        retries += 1
        state.stats.retries += 1
        if retries > state.params.max_retries:
            raise UnrecoverableFault(
                f"retry budget exhausted at stream micro-batch "
                f"{ordinal} ({retries} injected faults)")


def _run_stream(state: StreamState, order: np.ndarray, cap: int,
                plan_mb: int, plan_tl: int, plan) -> None:
    """Consume ``order[state.cursor:]`` in micro-batches of ``plan_mb``."""
    import jax.numpy as jnp
    from repro.kernels._compat import pallas_interpret

    hg, k, p, st = state.hg, state.k, state.params, state.stats
    n = hg.n
    adj = _stream_adjacency(hg)
    inv_target = np.float32(k / max(n, 1))
    sketch_dev = jnp.asarray(state.sketch)
    sizes_dev = jnp.asarray(state.sizes)
    t0 = time.perf_counter()
    snap_every = p.snapshot_every
    while state.cursor < order.size:
        batch = order[state.cursor:state.cursor + plan_mb]
        nb = batch.size
        edge_tile, nbr_tile = _batch_tiles(hg, adj, batch, plan_tl,
                                           plan_mb)
        valid_row = np.zeros(plan_mb, dtype=bool)
        valid_row[:nb] = True
        ordinal = state.batch_idx + 1
        _fire_faults(plan, state, ordinal)
        parts_dev, sketch_dev, sizes_dev = scoring.stream_step_device(
            jnp.asarray(edge_tile), jnp.asarray(nbr_tile),
            jnp.asarray(state.fringe), sketch_dev, sizes_dev,
            jnp.asarray(valid_row), alpha=p.balance_alpha,
            fringe_w=p.fringe_weight, inv_target=float(inv_target),
            cap=cap, sketch_bits=p.sketch_bits,
            interpret=pallas_interpret())
        parts = np.asarray(parts_dev)[:nb]
        state.assignment[batch] = parts
        _push_fringe(state, batch, parts)
        state.cursor += nb
        state.batch_idx = ordinal
        st.micro_batches += 1
        st.device_calls += 1
        st.kernel_rows += plan_mb
        st.host_to_device_bytes += (edge_tile.nbytes + nbr_tile.nbytes
                                    + state.fringe.nbytes + plan_mb)
        st.vertices += nb
        if snap_every and state.batch_idx % snap_every == 0:
            _snapshot(state, plan_mb, plan_tl, sketch_dev, sizes_dev)
    state.sketch = np.array(sketch_dev, dtype=np.int32)
    state.sizes = np.array(sizes_dev, dtype=np.int32)
    st.stream_s += time.perf_counter() - t0
    st.vertices_per_s = st.vertices / max(st.stream_s, 1e-9)


def hype_stream_partition(hg: Hypergraph, k: int,
                          params: Optional[StreamParams] = None, *,
                          return_stats: bool = False,
                          return_state: bool = False):
    """One streaming pass over ``hg``; see the module doc.

    Returns the complete int32 assignment; with ``return_stats`` a
    ``(assignment, StreamStats)`` pair, with ``return_state`` a
    ``(assignment, StreamState)`` pair (the state carries ``.stats``
    and feeds ``apply_updates``). Balance: ``max - min <= k`` via the
    hard ``ceil(n/k)`` capacity cap.
    """
    p = params or StreamParams()
    _validate_params(p)
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    state = _fresh_state(hg, k, p)
    if k == 1 or hg.n == 0:
        state.assignment[:] = 0 if k >= 1 else -1
        state.sizes = np.bincount(
            state.assignment[state.assignment >= 0],
            minlength=k).astype(np.int32)
        state.sketch, state.sizes = recompute_sketch(
            hg, state.assignment, k, p.sketch_bits)
        return _pack_result(state, return_stats, return_state)

    # memory plan (DESIGN.md §4g): streaming buffers go through the
    # byte planner; a tight budget halves the micro-batch, then the
    # tile width — pre-emptive, the stream never donates-then-dies
    budget = membudget.resolve_budget(p.mem_budget)
    spec = membudget.StreamSpec(
        n=hg.n, k=k, micro_batch=p.micro_batch,
        sketch_bits=p.sketch_bits, s=p.s,
        tile_l=scoring.L_BUCKETS[-1])
    plan_mb, plan_tl, planned, _fits = membudget.plan_stream_memory(
        spec, budget)
    state.stats.planned_bytes = planned
    state.stats.plan_micro_batch = plan_mb
    state.stats.plan_tile_l = plan_tl

    plan = resilience.resolve_fault_plan(p.fault_plan)
    if p.resume:
        _try_resume(state, plan_mb, plan_tl)
    order = _stream_order(hg.n, p)
    cap = -(-hg.n // k)
    _run_stream(state, order, cap, plan_mb, plan_tl, plan)
    return _pack_result(state, return_stats, return_state)


def _fresh_state(hg: Hypergraph, k: int, p: StreamParams) -> StreamState:
    return StreamState(
        hg=hg, k=k, params=p,
        assignment=np.full(hg.n, -1, np.int32),
        sizes=np.zeros(k, np.int32),
        sketch=np.zeros((k, 1 << p.sketch_bits), np.int32),
        fringe=np.full((k, p.s), -1, np.int32),
        fringe_pos=np.zeros(k, np.int64))


def _pack_result(state: StreamState, return_stats: bool,
                 return_state: bool):
    assignment = state.assignment.copy()
    if return_state:
        return assignment, state
    if return_stats:
        return assignment, state.stats
    return assignment


# --------------------------------------------------------- incremental mode

def _sketch_add(state: StreamState, part: int, edge_ids: np.ndarray,
                sign: int) -> None:
    """Exact sketch increment/decrement for pins of one vertex."""
    if edge_ids.size == 0:
        return
    buckets = scoring.stream_bucket(edge_ids, state.params.sketch_bits)
    np.add.at(state.sketch[part], buckets, sign)


def _expand_radius(hg: Hypergraph, seeds: np.ndarray,
                   radius: int) -> np.ndarray:
    """Vertices within ``radius`` hops of ``seeds`` (seeds included)."""
    seeds = np.unique(seeds.astype(np.int64))
    if radius <= 0 or seeds.size == 0:
        return seeds
    adj = hg.vertex_adjacency()
    if adj is None:
        return seeds
    frontier, dirty = seeds, seeds
    for _ in range(radius):
        nbrs, _ = scoring.gather_csr_rows(adj[0], adj[1], frontier)
        frontier = np.setdiff1d(np.unique(nbrs.astype(np.int64)), dirty)
        if frontier.size == 0:
            break
        dirty = np.union1d(dirty, frontier)
    return dirty


def _readmit(state: StreamState, new_vs: np.ndarray) -> None:
    """Stream-admit queued vertices against the current sketch/fringe."""
    if new_vs.size == 0:
        return
    import jax.numpy as jnp
    from repro.kernels._compat import pallas_interpret

    hg, k, p, st = state.hg, state.k, state.params, state.stats
    active = int((state.assignment >= 0).sum()) + int(new_vs.size)
    cap = max(-(-active // k), int(state.sizes.max()))
    inv_target = np.float32(k / max(hg.n, 1))
    adj = _stream_adjacency(hg)
    mb = st.plan_micro_batch or p.micro_batch
    tl = st.plan_tile_l or scoring.L_BUCKETS[-1]
    sketch_dev = jnp.asarray(state.sketch)
    sizes_dev = jnp.asarray(state.sizes)
    for b0 in range(0, new_vs.size, mb):
        batch = new_vs[b0:b0 + mb]
        edge_tile, nbr_tile = _batch_tiles(hg, adj, batch, tl, mb)
        valid_row = np.zeros(mb, dtype=bool)
        valid_row[:batch.size] = True
        parts_dev, sketch_dev, sizes_dev = scoring.stream_step_device(
            jnp.asarray(edge_tile), jnp.asarray(nbr_tile),
            jnp.asarray(state.fringe), sketch_dev, sizes_dev,
            jnp.asarray(valid_row), alpha=p.balance_alpha,
            fringe_w=p.fringe_weight, inv_target=float(inv_target),
            cap=cap, sketch_bits=p.sketch_bits,
            interpret=pallas_interpret())
        parts = np.asarray(parts_dev)[:batch.size]
        state.assignment[batch] = parts
        st.device_calls += 1
        st.readmitted += int(batch.size)
    state.sketch = np.array(sketch_dev, dtype=np.int32)
    state.sizes = np.array(sizes_dev, dtype=np.int32)


def _local_refine(state: StreamState, dirty: np.ndarray) -> None:
    """Bounded-radius re-expansion: one candidate-restricted refine pass."""
    if dirty.size == 0 or state.k <= 1:
        return
    hg, k = state.hg, state.k
    before = _fill_unassigned(state.assignment, k)
    refined, _rs = refine.refine_kway(
        hg, before, k, passes=1, candidates=dirty, use_device=False)
    moved = np.flatnonzero((refined != before)
                           & (state.assignment >= 0))
    for v in moved:
        src, dst = int(before[v]), int(refined[v])
        es = hg.vertex_edges(int(v)).astype(np.int64)
        _sketch_add(state, src, es, -1)
        _sketch_add(state, dst, es, +1)
        state.assignment[v] = dst
        state.sizes[src] -= 1
        state.sizes[dst] += 1
        state.stats.refine_moves += 1


def _rebalance_guard(state: StreamState) -> None:
    """Force the documented ``max - min <= k`` slack after deletions.

    Deterministic: while the slack is violated, move the best-gain
    (lowest id on ties) vertex from the largest partition to the
    smallest, keeping the sketch exact per move.
    """
    hg, k = state.hg, state.k
    adj = hg.vertex_adjacency()
    while True:
        sizes = state.sizes
        p_big = int(np.argmax(sizes))
        p_small = int(np.argmin(sizes))
        if int(sizes[p_big]) - int(sizes[p_small]) <= k:
            return
        cand = np.flatnonzero(state.assignment == p_big)
        if cand.size == 0:
            return
        if adj is not None:
            gains = refine._host_gains(
                adj, cand, _fill_unassigned(state.assignment, k),
                k)[:, p_small]
            v = int(cand[np.lexsort((cand, -gains))[0]])
        else:
            v = int(cand[0])
        es = hg.vertex_edges(v).astype(np.int64)
        _sketch_add(state, p_big, es, -1)
        _sketch_add(state, p_small, es, +1)
        state.assignment[v] = p_small
        state.sizes[p_big] -= 1
        state.sizes[p_small] += 1
        state.stats.rebalance_moves += 1


def apply_updates(state: StreamState,
                  ops: Sequence[Tuple]) -> StreamState:
    """Replay an op log against the live state; returns ``state``.

    Ops (applied in order):

      * ``("add_vertex", edge_ids)`` — append vertex ``n`` joining the
        listed existing hyperedges; it is re-admitted through the
        streaming scorer at the end of the call.
      * ``("remove_vertex", v)`` — drop all pins of ``v``; its slot
        stays (isolated), its sketch contributions are exact-decremented
        and it leaves every fringe.
      * ``("add_edge", vertex_ids)`` — append hyperedge ``m`` over the
        listed existing vertices; assigned pins increment the sketch.
      * ``("remove_edge", e)`` — drop all pins of hyperedge ``e``;
        assigned pins exact-decrement the sketch.

    After the log replays, new vertices are admitted micro-batch-wise,
    the dirtied neighborhoods (``update_radius`` hops around every
    touched vertex) get one candidate-restricted ``refine_kway`` pass,
    and a balance guard restores the documented ``max - min <= k``
    slack if deletions broke it. The sketch invariant
    (``sketch_digest() == digest(recompute_sketch(...))``) holds at
    return — the property the incremental-consistency suite pins.
    """
    t0 = time.perf_counter()
    st = state.stats
    dirty_parts: list = []
    new_vs: list = []
    for op in ops:
        kind = op[0]
        if kind == "add_vertex":
            edge_ids = np.asarray(list(op[1]), dtype=np.int64)
            vid = state.hg.n
            state.hg = state.hg.with_vertices([edge_ids.tolist()])
            state.assignment = np.append(
                state.assignment, np.int32(-1)).astype(np.int32)
            if edge_ids.size:
                pins, _ = scoring.gather_csr_rows(
                    state.hg.e2v_indptr, state.hg.e2v_indices, edge_ids)
                dirty_parts.append(pins.astype(np.int64))
            new_vs.append(vid)
            st.inserts += 1
        elif kind == "remove_vertex":
            v = int(op[1])
            part = int(state.assignment[v])
            es = state.hg.vertex_edges(v).astype(np.int64)
            dirty_parts.append(state.hg.neighbors(v).astype(np.int64))
            if part >= 0:
                _sketch_add(state, part, es, -1)
                state.sizes[part] -= 1
            state.assignment[v] = -1
            state.fringe[state.fringe == v] = -1
            state.hg = state.hg.without_vertices([v])
            new_vs = [u for u in new_vs if u != v]
            st.deletes += 1
        elif kind == "add_edge":
            pins = np.asarray(list(op[1]), dtype=np.int64)
            e = state.hg.m
            state.hg = state.hg.with_edges([pins.tolist()])
            b = int(scoring.stream_bucket(
                np.asarray([e]), state.params.sketch_bits)[0])
            # de-duplicated pins (from_pins semantics)
            for part in state.assignment[np.unique(pins)]:
                if part >= 0:
                    state.sketch[int(part), b] += 1
            dirty_parts.append(pins)
            st.inserts += 1
        elif kind == "remove_edge":
            e = int(op[1])
            pins = state.hg.edge_pins(e).astype(np.int64)
            b = int(scoring.stream_bucket(
                np.asarray([e]), state.params.sketch_bits)[0])
            for part in state.assignment[pins]:
                if part >= 0:
                    state.sketch[int(part), b] -= 1
            dirty_parts.append(pins)
            state.hg = state.hg.without_edges([e])
            st.deletes += 1
        else:
            raise ValueError(f"unknown stream op kind {kind!r}")
    st.updates_applied += len(ops)

    queued = np.asarray(sorted(set(new_vs)), dtype=np.int64)
    _readmit(state, queued)
    dirty = np.concatenate([a for a in dirty_parts if a.size]
                           + [queued]) if (dirty_parts or queued.size) \
        else np.empty(0, np.int64)
    dirty = dirty[dirty < state.hg.n]
    dirty = _expand_radius(state.hg, dirty, state.params.update_radius)
    _local_refine(state, dirty)
    _rebalance_guard(state)
    st.update_s += time.perf_counter() - t0
    st.updates_per_s = st.updates_applied / max(st.update_s, 1e-9)
    return state
