"""Batched-candidate HYPE: the throughput-oriented engine (DESIGN.md §4).

The paper's engine (``hype.py``) moves ONE vertex per growth step and
scores r=2 candidates at a time — latency-bound, CPU-idiomatic. This
engine turns the inner loop into tile work:

  per growth step
    1. (when the candidate pool runs low) draw a bulk batch of candidate
       vertices from the *smallest* active hyperedges — size-bucketed
       queues instead of a heap, one vectorized pin scan per draw,
    2. gather their unassigned-neighbor lists as dense (b, L) tiles
       (``scoring.neighbor_tile_adj``; assigned pins dropped, hubs
       capped),
    3. score every cache-miss candidate through the Pallas
       ``hype_scores`` kernel (fringe membership subtracted on the VPU),
    4. keep scored candidates in a pool sorted by score — the paper's
       s-sized fringe is its top-s — and admit the top-``t`` per step.

``t`` is the quality/speed knob: steps per partition drop from O(target)
to O(target / t); ``t=1`` recovers the sequential admission order (same
greedy rule, wider candidate pool). Scores are lazily cached per phase
exactly like the paper's optimization (c), so the kernel only sees
first-time candidates.

This is the first real consumer of ``kernels/hype_score`` — on CPU the
kernel runs in interpret mode (still one fused batched evaluation); on
TPU the same call compiles to the VPU tile loop the kernel was built for.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import numpy as np

from .hypergraph import Hypergraph
from . import scoring


@dataclasses.dataclass
class BatchedParams:
    b: int = 256           # rows per kernel tile (the paper's r=2)
    s: int = 16            # max fringe size (kernel compares vs s slots)
    t: int = 8             # admissions per step; 1 = sequential order
    pool_cap: int = 64     # scored candidates held between steps
    refill_lo: int = 64    # refill the pool when it drops below this
    cap_pins: int = 3072   # pins scanned per candidate before truncation
    kernel_min: int = 16   # min batch worth a device round-trip; smaller
    #                        dribbles score on host (same formula and hub
    #                        truncation convention as the kernel tiles)
    seed: int = 0


@dataclasses.dataclass
class BatchedStats:
    kernel_calls: int = 0
    kernel_rows: int = 0       # candidate rows scored by the Pallas kernel
    host_rows: int = 0         # rows scored by the numpy fallback
    cache_hits: int = 0
    edges_scanned: int = 0     # pins scanned during candidate selection
    random_restarts: int = 0
    steps: int = 0


class _BatchedState:
    """Mutable state for the k growth phases (host side, all numpy)."""

    def __init__(self, hg: Hypergraph, k: int, p: BatchedParams):
        self.hg = hg
        self.k = k
        self.p = p
        n, m = hg.n, hg.m
        self.assignment = np.full(n, -1, dtype=np.int32)
        self.in_fringe = np.zeros(n, dtype=bool)
        self.in_pool = np.zeros(n, dtype=bool)     # fringe ∪ held candidates
        self.cur_fringe = np.empty(0, dtype=np.int64)
        self.cache = np.full(n, -1.0)
        self.edge_sizes = np.asarray(hg.edge_sizes, dtype=np.int64)
        self.edge_epoch = np.full(m, -1, dtype=np.int32)   # activation epoch
        self.edge_dead = self.edge_sizes == 0              # no live pins left
        # size-bucketed active-edge queues (replaces the paper's min-heap):
        # buckets[size] is a FIFO of edge-id arrays; scanning pops from the
        # front and re-queues still-live edges at the front, so smallest
        # edges keep being drawn first, like the heap's requeue.
        self.buckets: dict = {}
        self.rng = np.random.default_rng(p.seed)
        self.rand_order = self.rng.permutation(n)
        self.rand_ptr = 0
        self.stats = BatchedStats()
        self._fringe_buf = np.full(p.s, -1, dtype=np.int32)
        # One-time unique-neighbor CSR (memoized on hg): turns every tile
        # build into a pure gather. None for pathological hub expansions —
        # scoring then falls back to per-batch dedup with cap_pins.
        self.adj = hg.vertex_adjacency()

    # ------------------------------------------------------------------ #
    def random_unassigned(self, count: int = 1) -> np.ndarray:
        """Next ``count`` unassigned non-pool vertices of the random stream.

        Vectorized skip-pointer scan over the shuffled order; the pointer
        only advances past consumed positions so no vertex is skipped.
        """
        n = self.hg.n
        out: list = []
        got = 0
        while self.rand_ptr < n and got < count:
            chunk = self.rand_order[self.rand_ptr:
                                    self.rand_ptr + max(1024, count)]
            ok = np.flatnonzero((self.assignment[chunk] < 0)
                                & ~self.in_pool[chunk])
            if ok.size >= count - got:
                ok = ok[:count - got]
                self.rand_ptr += int(ok[-1]) + 1
            else:
                self.rand_ptr += chunk.size
            take = chunk[ok].astype(np.int64)
            got += take.size
            if take.size:
                out.append(take)
        if got < count:     # stream exhausted; the stragglers sit earlier
            rem = np.flatnonzero((self.assignment < 0) & ~self.in_pool)
            if out:
                rem = np.setdiff1d(rem, np.concatenate(out),
                                   assume_unique=True)
            if rem.size:
                out.append(rem[:count - got].astype(np.int64))
        return (np.concatenate(out) if out
                else np.empty(0, dtype=np.int64))

    def set_fringe(self, new_fringe: np.ndarray) -> None:
        """Sync the s-sized fringe view (paper's F) used for scoring."""
        self.in_fringe[self.cur_fringe] = False
        self.in_fringe[new_fringe] = True
        self.cur_fringe = new_fringe
        self._fringe_buf[:] = -1
        self._fringe_buf[:new_fringe.size] = new_fringe

    # ------------------------------------------------------------------ #
    def activate(self, vs: np.ndarray, phase: int) -> None:
        """Mark the edges incident to newly admitted vertices active."""
        edges, _ = scoring.gather_csr_rows(
            self.hg.v2e_indptr, self.hg.v2e_indices, vs)
        if edges.size == 0:
            return
        edges = np.unique(edges.astype(np.int64))
        fresh = edges[(self.edge_epoch[edges] != phase)
                      & ~self.edge_dead[edges]]
        if fresh.size == 0:
            return
        self.edge_epoch[fresh] = phase
        sizes = self.edge_sizes[fresh]
        for sz in np.unique(sizes):
            self.buckets.setdefault(int(sz), collections.deque()).append(
                fresh[sizes == sz])

    # ------------------------------------------------------------------ #
    def draw_candidates(self, need: int) -> np.ndarray:
        """Up to ``need`` distinct universe vertices from smallest edges.

        One vectorized pass: pull edges smallest-size-first under a pin
        budget, scan all their pins at once, retire dead edges (no
        unassigned pin left — forever), requeue the still-live ones at the
        bucket fronts so they are rescanned first next time (the heap's
        requeue, without the heap).
        """
        if need <= 0:
            return np.empty(0, dtype=np.int64)
        budget = max(4 * need, 512)
        batches: list = []
        pulled = 0
        for sz in sorted(self.buckets.keys()):
            q = self.buckets[sz]
            while q and pulled < budget:
                arr = q.popleft()
                n_take = (budget - pulled + sz - 1) // max(sz, 1)
                if arr.size > n_take:
                    q.appendleft(arr[n_take:])
                    arr = arr[:n_take]
                batches.append(arr)
                pulled += arr.size * max(sz, 1)
            if not q:
                del self.buckets[sz]
            if pulled >= budget:
                break
        if not batches:
            return np.empty(0, dtype=np.int64)
        edges = np.concatenate(batches)
        pins, prow = scoring.gather_csr_rows(
            self.hg.e2v_indptr, self.hg.e2v_indices, edges)
        pins = pins.astype(np.int64)
        self.stats.edges_scanned += pins.size
        unassigned = self.assignment[pins] < 0
        live = np.bincount(prow[unassigned], minlength=edges.size) > 0
        if not live.all():
            self.edge_dead[edges[~live]] = True     # dead forever
        live_edges = edges[live]
        if live_edges.size:
            lsz = self.edge_sizes[live_edges]
            for s in np.unique(lsz):
                self.buckets.setdefault(
                    int(s), collections.deque()).appendleft(
                        live_edges[lsz == s])
        fresh = unassigned & ~self.in_pool[pins]
        cand = pins[fresh]
        if cand.size:
            _, first = np.unique(cand, return_index=True)
            cand = cand[np.sort(first)][:need]
        return cand

    # ------------------------------------------------------------------ #
    def score_misses(self, cand: np.ndarray) -> None:
        """Score cache-miss candidates in one batched pass, fill the cache.

        Large batches (every phase opening, where the bulk of the scoring
        lives) go through the Pallas ``hype_scores`` kernel as one (b, L)
        tile; dribbles below ``kernel_min`` rows are scored by the exact
        same formula on host, because a device round-trip per 2-3 rows is
        precisely the latency-bound pattern this engine exists to avoid.
        """
        if cand.size == 0:
            return
        miss = cand[self.cache[cand] < 0.0]
        self.stats.cache_hits += cand.size - miss.size
        if miss.size == 0:
            return
        if miss.size >= self.p.kernel_min:
            import jax.numpy as jnp
            from repro.kernels.hype_score.ops import hype_scores

            fringe_dev = jnp.asarray(self._fringe_buf)
            for lo in range(0, miss.size, self.p.b):
                chunk = miss[lo:lo + self.p.b]
                # two B buckets (64 / b) keep retraces rare while small
                # top-up batches avoid paying for a full-width tile
                pad_b = 64 if chunk.size <= 64 else self.p.b
                if self.adj is not None:
                    tile, truncated = scoring.neighbor_tile_adj(
                        self.adj, chunk, self.assignment, pad_b=pad_b)
                else:
                    tile, truncated = scoring.neighbor_tile(
                        self.hg, chunk, self.assignment,
                        cap_pins=self.p.cap_pins, pad_b=pad_b)
                out = np.asarray(hype_scores(jnp.asarray(tile), fringe_dev))
                sc = out[:chunk.size].astype(np.float64)
                sc[truncated] += scoring.TRUNC_PENALTY
                self.cache[chunk] = sc
                self.stats.kernel_calls += 1
                self.stats.kernel_rows += int(chunk.size)
        else:
            if self.adj is not None:
                sc = scoring.batched_dext_adj(
                    self.adj, miss, self.in_fringe, self.assignment)
            else:
                sc = scoring.batched_dext_numpy(
                    self.hg, miss, self.in_fringe, self.assignment,
                    cap_pins=self.p.cap_pins,
                    max_width=scoring.L_BUCKETS[-1])
            self.stats.host_rows += int(miss.size)
            self.cache[miss] = sc


def _grow_partition(st: _BatchedState, phase: int, target: int) -> None:
    """Grow core set ``phase`` to ``target`` vertices.

    The step loop keeps a *pool* of up to ``pool_cap`` scored candidates
    sorted by cached score. Refills happen in bulk (one kernel tile per
    ``b`` rows) whenever the pool runs low; between refills a step is just
    "admit the t best, queue their edges" — the latency-bound per-vertex
    machinery of the sequential engines is gone entirely. The paper's
    s-sized fringe survives as the top-s of the pool: it is what the
    scoring kernel subtracts, exactly like F in Eq. 1.
    """
    p = st.p
    st.cache[:] = -1.0
    st.buckets = {}
    pool = np.empty(0, dtype=np.int64)       # kept sorted by score asc
    pending: list = []                       # admitted, edges not yet queued

    seeds = st.random_unassigned(1)
    if seeds.size == 0:
        return
    st.assignment[seeds] = phase
    st.activate(seeds, phase)
    acc = 1

    while acc < target:
        st.stats.steps += 1
        # ------- refill: bulk-draw and kernel-score new candidates -------
        if pool.size < max(p.t, p.refill_lo):
            if pending:
                st.activate(np.concatenate(pending), phase)
                pending = []
            cand = st.draw_candidates(p.pool_cap - pool.size)
            if cand.size:
                st.score_misses(cand)
                st.in_pool[cand] = True
                pool = np.concatenate([pool, cand])
                pool = pool[np.argsort(st.cache[pool], kind="stable")]
                st.set_fringe(pool[:p.s])
        if pool.size == 0:                    # random restart (batched: on
            # shattered remainders each isolated vertex would otherwise
            # cost a full step, so seed up to t fresh growth points)
            vs = st.random_unassigned(p.t)
            if vs.size == 0:
                return
            st.stats.random_restarts += 1
            pool = vs
            st.in_pool[vs] = True
            st.cache[vs] = 0.0
            st.set_fringe(pool[:p.s])
        # ------- core update: admit the t best pool vertices -------
        nt = min(p.t, target - acc, pool.size)
        admit, pool = pool[:nt], pool[nt:]
        st.assignment[admit] = phase
        st.in_pool[admit] = False
        pending.append(admit)
        st.set_fringe(pool[:p.s])
        acc += int(admit.size)

    # release fringe + pool back to the universe (§III-B1 step 4)
    st.set_fringe(np.empty(0, dtype=np.int64))
    st.in_pool[pool] = False


def hype_batched_partition(hg: Hypergraph, k: int,
                           params: Optional[BatchedParams] = None,
                           return_stats: bool = False):
    """Partition ``hg`` into ``k`` parts with batched-candidate HYPE.

    Same contract as ``hype_partition``: complete int32 assignment with
    perfectly balanced partition sizes (max - min <= 1).
    """
    if params is None:
        params = BatchedParams()
    if k < 1:
        raise ValueError("k must be >= 1")
    if params.t < 1 or params.b < 1 or params.s < 1:
        raise ValueError("b, s, t must all be >= 1")
    if params.pool_cap < 1:
        raise ValueError("pool_cap must be >= 1")
    st = _BatchedState(hg, k, params)
    n = hg.n
    base, rem = divmod(n, k)
    for i in range(k):
        if i == k - 1:
            rem_v = np.flatnonzero(st.assignment < 0)
            st.assignment[rem_v] = i
            st.in_fringe[:] = False
            break
        _grow_partition(st, i, base + (1 if i < rem else 0))
    assert (st.assignment >= 0).all()
    if return_stats:
        return st.assignment, st.stats
    return st.assignment
