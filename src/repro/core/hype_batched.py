"""Batched-candidate HYPE: the throughput-oriented engine (DESIGN.md §4).

The paper's engine (``hype.py``) moves ONE vertex per growth step and
scores r=2 candidates at a time — latency-bound, CPU-idiomatic. This
engine turns the inner loop into tile work:

  per growth step
    1. (when the candidate pool runs low) draw a bulk batch of candidate
       vertices from the *smallest* active hyperedges — size-bucketed
       queues instead of a heap, one vectorized pin scan per draw,
    2. gather their unassigned-neighbor lists as dense (b, L) tiles
       (``scoring.neighbor_tile_adj``; assigned pins dropped, hubs
       capped),
    3. score every cache-miss candidate through the Pallas
       ``hype_scores`` kernel (fringe membership subtracted on the VPU),
    4. keep scored candidates in a pool sorted by score — the paper's
       s-sized fringe is its top-s — and admit the top-``t`` per step.

``t`` is the quality/speed knob: steps per partition drop from O(target)
to O(target / t); ``t=1`` recovers the sequential admission order (same
greedy rule, wider candidate pool). Scores are lazily cached per phase
exactly like the paper's optimization (c), so the kernel only sees
first-time candidates.

This is the first real consumer of ``kernels/hype_score`` — on CPU the
kernel runs in interpret mode (still one fused batched evaluation); on
TPU the same call compiles to the VPU tile loop the kernel was built for.

The module holds the top three rungs of the engine ladder (DESIGN.md §1):
``hype_batched_partition`` (host tiles), ``hype_superstep_partition``
(device-resident image, §4b) and ``hype_sharded_partition`` (phase
groups sharded over a device mesh, §4c). The two device engines share
the double-buffered superstep pipeline of §4d (``_run_pipeline``):
dispatch/harvest-split device calls with on-device admission, so host
orchestration overlaps device compute; ``pipeline_depth=1`` reproduces
the lock-step schedule bit for bit.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional

import numpy as np

from .hypergraph import Hypergraph
from . import device_loop
from . import membudget
from . import resilience
from . import scoring

# (1,) int32 replay markers for the device programs' sticky poison flag
# (scoring._poison_guard): 0 = normal superstep, 1 = host-driven replay
# of a quarantined superstep. Module constants so repeated dispatches
# hand jit the same host buffers.
_RESET0 = np.zeros(1, dtype=np.int32)
_RESET1 = np.ones(1, dtype=np.int32)


@dataclasses.dataclass
class BatchedParams:
    b: int = 256           # rows per kernel tile (the paper's r=2)
    s: int = 16            # max fringe size (kernel compares vs s slots)
    t: int = 8             # admissions per step; 1 = sequential order
    pool_cap: int = 64     # scored candidates held between steps
    refill_lo: int = 64    # refill the pool when it drops below this
    cap_pins: int = 3072   # pins scanned per candidate before truncation
    kernel_min: int = 16   # min batch worth a device round-trip; smaller
    #                        dribbles score on host (same formula and hub
    #                        truncation convention as the kernel tiles)
    refine_passes: int = 0  # post-pass boundary-refinement passes
    #                         (core/refine.py, DESIGN.md §4e); 0 = off,
    #                         output bit-identical to the bare engine
    seed: int = 0
    # resilience knobs (core/resilience.py, DESIGN.md §4f):
    snapshot_every: int = 0     # checkpoint cadence, counted in
    #                             supersteps (device engines) or
    #                             completed phases (batched); 0 = never.
    #                             The cadence is part of the schedule: a
    #                             resumed run is bit-identical to an
    #                             uninterrupted run with the SAME cadence
    #                             (snapshots drain the pipeline).
    snapshot_dir: Optional[str] = None   # where snapshots are published
    keep_last: int = 3          # snapshots the GC retains per directory
    resume: Optional[str] = None    # snapshot file or directory to
    #                                 resume from; a missing or empty
    #                                 directory starts fresh (what the
    #                                 degradation ladder wants)
    fault_plan: Optional[object] = None  # resilience.FaultPlan instance,
    #                                      spec string, or None = read
    #                                      the REPRO_FAULT_PLAN env var
    max_retries: int = 2        # transient-fault retry budget per call
    retry_backoff_s: float = 0.01   # linear backoff between retries


@dataclasses.dataclass
class BatchedStats:
    kernel_calls: int = 0
    kernel_rows: int = 0       # candidate rows scored by the Pallas kernel
    host_rows: int = 0         # rows scored by the numpy fallback
    cache_hits: int = 0
    edges_scanned: int = 0     # pins scanned during candidate selection
    random_restarts: int = 0
    steps: int = 0
    # superstep-engine counters (zero for the classic batched path):
    supersteps: int = 0             # fused device calls
    device_image_bytes: int = 0     # one-time CSR + assignment + cache
    #                                 upload at partition() start
    host_to_device_bytes: int = 0   # per-call id/bias buffers — the whole
    #                                 steady-state H2D traffic
    cache_invalidations: int = 0    # cached scores decremented by admission
    # sharded-engine counters (zero for the single-device engines):
    collectives: int = 0            # all_gather ops (one per superstep)
    collective_bytes: int = 0       # bytes materialized by the gathers:
    #                                 devices x global payload per superstep
    admission_conflicts: int = 0    # proposed admissions lost to the
    #                                 lowest-phase-wins conflict rule
    # pipeline counters (superstep/sharded engines):
    host_s: float = 0.0             # wall-clock spent in host packing +
    #                                 harvest mirroring (overlappable)
    device_s: float = 0.0           # wall-clock blocked waiting on device
    #                                 results at harvest time
    pipeline_stalls: int = 0        # rounds where the host could pack
    #                                 nothing and the device went idle
    stale_redraws: int = 0          # pool slots skipped on device because
    #                                 an interleaved superstep of the
    #                                 pipeline had already assigned them
    # device-loop counters (hype_device, DESIGN.md §4i):
    loop_chunks: int = 0            # host-visible while_loop segments
    loop_rounds: int = 0            # pack+dispatch rounds run on device
    loop_pack_only: int = 0         # rounds that had nothing to score
    loop_store_peak: int = 0        # peak live rows across phase stores
    loop_state_bytes: int = 0       # device-resident carry (loop state)
    refill_signals: int = 0         # kernel refill-trigger flags raised
    #                                 (phases whose candidate slots ran
    #                                 out during selection)
    # resilience counters (core/resilience.py, DESIGN.md §4f):
    faults_injected: int = 0        # FaultPlan specs that fired this run
    retries: int = 0                # transient-fault retries + poisoned-
    #                                 superstep replays (never counted as
    #                                 extra kernel_calls / supersteps)
    fallbacks: int = 0              # ladder rungs exhausted before this
    #                                 engine ran (partition_resilient)
    snapshots: int = 0              # checkpoints published
    snapshot_s: float = 0.0         # wall-clock publishing checkpoints
    restore_s: float = 0.0          # wall-clock restoring the resume ckpt
    resumed_at: int = -1            # superstep/phase the run resumed
    #                                 from; -1 = fresh start
    # memory-budget counters (core/membudget.py, DESIGN.md §4g):
    mem_retries: int = 0            # DeviceOOM-driven same-engine retries
    #                                 (real allocator failures + injected
    #                                 non-fatal oom faults)
    plan_rung: int = -1             # memory-plan rung the run executed at;
    #                                 -1 = engine never planned (host path)
    peak_bytes_planned: int = 0     # the plan's modeled peak device bytes
    peak_bytes_observed: int = 0    # backend peak_bytes_in_use when the
    #                                 allocator tracks it; the planned
    #                                 model value otherwise
    page_uploads: int = 0           # paged-adjacency chunk uploads
    page_hits: int = 0              # chunk requests served LRU-resident
    page_evictions: int = 0         # chunks evicted to stay under budget
    page_bytes: int = 0             # total bytes uploaded by the pager
    # refinement post-pass (None unless refine_passes > 0 ran):
    refine: Optional[object] = None     # core.refine.RefineStats


class _BatchedState:
    """Mutable state for the k growth phases (host side, all numpy)."""

    def __init__(self, hg: Hypergraph, k: int, p: BatchedParams):
        # opt into the persistent XLA compile cache (REPRO_COMPILE_CACHE)
        # before any engine traces a kernel; idempotent no-op when unset
        from repro.kernels._compat import enable_compile_cache
        enable_compile_cache()
        self.hg = hg
        self.k = k
        self.p = p
        n, m = hg.n, hg.m
        self.assignment = np.full(n, -1, dtype=np.int32)
        self.in_fringe = np.zeros(n, dtype=bool)
        self.in_pool = np.zeros(n, dtype=bool)     # fringe ∪ held candidates
        self.cur_fringe = np.empty(0, dtype=np.int64)
        self.cache = np.full(n, -1.0)
        self.edge_sizes = np.asarray(hg.edge_sizes, dtype=np.int64)
        self.edge_epoch = np.full(m, -1, dtype=np.int32)   # activation epoch
        self.edge_dead = self.edge_sizes == 0              # no live pins left
        # size-bucketed active-edge queues (replaces the paper's min-heap):
        # buckets[size] is a FIFO of edge-id arrays; scanning pops from the
        # front and re-queues still-live edges at the front, so smallest
        # edges keep being drawn first, like the heap's requeue.
        self.buckets: dict = {}
        self.rng = np.random.default_rng(p.seed)
        self.rand_order = self.rng.permutation(n)
        self.rand_ptr = 0
        self.stats = BatchedStats()
        self._fringe_buf = np.full(p.s, -1, dtype=np.int32)
        # One-time unique-neighbor CSR (memoized on hg): turns every tile
        # build into a pure gather. None for pathological hub expansions —
        # scoring then falls back to per-batch dedup with cap_pins.
        self.adj = hg.vertex_adjacency()
        # deterministic fault schedule: the param (shared instance across
        # a degradation ladder) or a FRESH parse of REPRO_FAULT_PLAN per
        # engine run, so every run of a chaos suite sees the full plan
        self.fault_plan = resilience.resolve_fault_plan(p.fault_plan)

    # ------------------------------------------------------------------ #
    def _guarded_kernel(self, fn, ordinal: int, kinds=("dispatch",),
                        donated=()):
        """Run a device call under fault injection + bounded retry.

        Injected faults fire *before* the call (the dispatch site), so a
        transient retry re-issues the identical pure computation — which
        is what keeps recovery bit-identical to a fault-free run. A
        fatal spec, an exhausted retry budget, or a real failure after
        any ``donated`` buffer was consumed (the call cannot be
        re-issued) raises ``UnrecoverableFault`` for the ladder.

        Memory faults are different: a real allocator failure
        (``membudget.is_oom_error``) or a non-fatal injected ``oom``
        raises ``DeviceOOM`` immediately — retrying the identical call
        cannot help an allocation that does not fit, and the memory-rung
        retry loop (``_run_pipeline_budgeted``, DESIGN.md §4g) rebuilds
        the whole engine state at a smaller plan anyway, donated or not.
        """
        plan = self.fault_plan
        attempts = 0
        while True:
            try:
                if plan is not None:
                    sp = plan.fire(kinds, ordinal)
                    if sp is not None:
                        self.stats.faults_injected += 1
                        raise resilience.FaultInjected(
                            sp.kind, ordinal, sp.fatal)
                return fn()
            except resilience.UnrecoverableFault:
                raise
            except membudget.DeviceOOM:
                raise
            except resilience.FaultInjected as exc:
                if exc.fatal:
                    raise resilience.UnrecoverableFault(str(exc)) from exc
                if exc.kind == "oom":
                    raise membudget.DeviceOOM(
                        str(exc),
                        rung=getattr(self, "mem_rung", None)) from exc
                err = exc
            except Exception as exc:
                if membudget.is_oom_error(exc):
                    raise membudget.DeviceOOM(
                        f"device allocation failed: {exc!r}",
                        rung=getattr(self, "mem_rung", None)) from exc
                if any(a.is_deleted() for a in donated):
                    raise resilience.UnrecoverableFault(
                        f"device call failed after buffer donation: "
                        f"{exc!r}") from exc
                err = exc
            attempts += 1
            if attempts > int(self.p.max_retries):
                raise resilience.UnrecoverableFault(
                    f"retry budget ({self.p.max_retries}) exhausted: "
                    f"{err!r}") from err
            self.stats.retries += 1
            time.sleep(float(self.p.retry_backoff_s) * attempts)

    # ------------------------------------------------------------------ #
    def random_unassigned(self, count: int = 1,
                          in_pool: Optional[np.ndarray] = None
                          ) -> np.ndarray:
        """Next ``count`` unassigned non-pool vertices of the random stream.

        Vectorized skip-pointer scan over the shuffled order; the pointer
        only advances past consumed positions so no vertex is skipped.
        ``in_pool`` selects which pool-membership mask to respect (the
        sharded engine keeps one per device group); default is the
        engine-wide mask.
        """
        if in_pool is None:
            in_pool = self.in_pool
        n = self.hg.n
        out: list = []
        got = 0
        while self.rand_ptr < n and got < count:
            chunk = self.rand_order[self.rand_ptr:
                                    self.rand_ptr + max(1024, count)]
            ok = np.flatnonzero((self.assignment[chunk] < 0)
                                & ~in_pool[chunk])
            if ok.size >= count - got:
                ok = ok[:count - got]
                self.rand_ptr += int(ok[-1]) + 1
            else:
                self.rand_ptr += chunk.size
            take = chunk[ok].astype(np.int64)
            got += take.size
            if take.size:
                out.append(take)
        if got < count:     # stream exhausted; the stragglers sit earlier
            rem = np.flatnonzero((self.assignment < 0) & ~in_pool)
            if out:
                rem = np.setdiff1d(rem, np.concatenate(out),
                                   assume_unique=True)
            if rem.size:
                out.append(rem[:count - got].astype(np.int64))
        return (np.concatenate(out) if out
                else np.empty(0, dtype=np.int64))

    def set_fringe(self, new_fringe: np.ndarray) -> None:
        """Sync the s-sized fringe view (paper's F) used for scoring."""
        self.in_fringe[self.cur_fringe] = False
        self.in_fringe[new_fringe] = True
        self.cur_fringe = new_fringe
        self._fringe_buf[:] = -1
        self._fringe_buf[:new_fringe.size] = new_fringe

    # ------------------------------------------------------------------ #
    def activate(self, vs: np.ndarray, phase: int) -> None:
        """Mark the edges incident to newly admitted vertices active."""
        edges, _ = scoring.gather_csr_rows(
            self.hg.v2e_indptr, self.hg.v2e_indices, vs)
        if edges.size == 0:
            return
        edges = np.unique(edges.astype(np.int64))
        fresh = edges[(self.edge_epoch[edges] != phase)
                      & ~self.edge_dead[edges]]
        if fresh.size == 0:
            return
        self.edge_epoch[fresh] = phase
        sizes = self.edge_sizes[fresh]
        for sz in np.unique(sizes):
            self.buckets.setdefault(int(sz), collections.deque()).append(
                fresh[sizes == sz])

    # ------------------------------------------------------------------ #
    def draw_candidates(self, need: int) -> np.ndarray:
        """Up to ``need`` distinct universe vertices from smallest edges.

        One vectorized pass: pull edges smallest-size-first under a pin
        budget, scan all their pins at once, retire dead edges (no
        unassigned pin left — forever), requeue the still-live ones at the
        bucket fronts so they are rescanned first next time (the heap's
        requeue, without the heap). Serves the classic batched engine;
        the superstep engines draw all phases at once from the flat
        bucket store instead (``pack_superstep``).
        """
        buckets = self.buckets
        in_pool = self.in_pool
        if need <= 0:
            return np.empty(0, dtype=np.int64)
        budget = max(4 * need, 512)
        batches: list = []
        keys: list = []     # (source bucket key, count) pairs, for requeues
        pulled = 0
        for sz in sorted(buckets.keys()):
            q = buckets[sz]
            while q and pulled < budget:
                arr = q.popleft()
                n_take = (budget - pulled + sz - 1) // max(sz, 1)
                if arr.size > n_take:
                    q.appendleft(arr[n_take:])
                    arr = arr[:n_take]
                batches.append(arr)
                keys.append((sz, arr.size))
                pulled += arr.size * max(sz, 1)
            if not q:
                del buckets[sz]
            if pulled >= budget:
                break
        if not batches:
            return np.empty(0, dtype=np.int64)
        edges = np.concatenate(batches)
        pins, prow = scoring.gather_csr_rows(
            self.hg.e2v_indptr, self.hg.e2v_indices, edges)
        pins = pins.astype(np.int64)
        self.stats.edges_scanned += pins.size
        unassigned = self.assignment[pins] < 0
        live = np.bincount(prow[unassigned], minlength=edges.size) > 0
        if not live.all():
            self.edge_dead[edges[~live]] = True     # dead forever
        live_edges = edges[live]
        if live_edges.size:
            # requeue under the key each edge was drawn from, so the
            # caller's key scheme (exact sizes for the classic engine,
            # power-of-two classes for the superstep engine) is preserved
            lkey = np.repeat([k for k, _ in keys],
                             [c for _, c in keys])[live]
            for s in np.unique(lkey):
                buckets.setdefault(
                    int(s), collections.deque()).appendleft(
                        live_edges[lkey == s])
        fresh = unassigned & ~in_pool[pins]
        cand = pins[fresh]
        if cand.size:
            _, first = np.unique(cand, return_index=True)
            cand = cand[np.sort(first)][:need]
        return cand

    # ------------------------------------------------------------------ #
    def score_misses(self, cand: np.ndarray) -> None:
        """Score cache-miss candidates in one batched pass, fill the cache.

        Large batches (every phase opening, where the bulk of the scoring
        lives) go through the Pallas ``hype_scores`` kernel as one (b, L)
        tile; dribbles below ``kernel_min`` rows are scored by the exact
        same formula on host, because a device round-trip per 2-3 rows is
        precisely the latency-bound pattern this engine exists to avoid.
        """
        if cand.size == 0:
            return
        miss = cand[self.cache[cand] < 0.0]
        self.stats.cache_hits += cand.size - miss.size
        if miss.size == 0:
            return
        if miss.size >= self.p.kernel_min:
            import jax.numpy as jnp
            from repro.kernels.hype_score.ops import hype_scores

            plan = self.fault_plan
            fringe_dev = jnp.asarray(self._fringe_buf)
            for lo in range(0, miss.size, self.p.b):
                chunk = miss[lo:lo + self.p.b]
                # two B buckets (64 / b) keep retraces rare while small
                # top-up batches avoid paying for a full-width tile
                pad_b = 64 if chunk.size <= 64 else self.p.b
                if self.adj is not None:
                    tile, truncated = scoring.neighbor_tile_adj(
                        self.adj, chunk, self.assignment, pad_b=pad_b)
                else:
                    tile, truncated = scoring.neighbor_tile(
                        self.hg, chunk, self.assignment,
                        cap_pins=self.p.cap_pins, pad_b=pad_b)
                ordinal = self.stats.kernel_calls + 1
                out = np.asarray(self._guarded_kernel(
                    lambda: hype_scores(jnp.asarray(tile), fringe_dev),
                    ordinal)).astype(np.float64)
                if plan is not None:
                    sp = plan.fire(("nan",), ordinal)
                    if sp is not None:    # poison the whole score tile
                        self.stats.faults_injected += 1
                        if sp.fatal:
                            raise resilience.UnrecoverableFault(
                                f"injected fatal nan tile at kernel "
                                f"call {ordinal}")
                        out = out.copy()
                        out[:chunk.size] = np.nan
                sc = out[:chunk.size]
                bad = ~np.isfinite(sc)
                if bad.any():   # quarantine: rescore poisoned rows on
                    #             host, bit-identical to a clean kernel
                    sc[bad] = self._rescore_rows(chunk[bad])
                    self.stats.host_rows += int(bad.sum())
                sc[truncated] += scoring.TRUNC_PENALTY
                self.cache[chunk] = sc
                self.stats.kernel_calls += 1
                self.stats.kernel_rows += int(chunk.size)
        else:
            if self.adj is not None:
                sc = scoring.batched_dext_adj(
                    self.adj, miss, self.in_fringe, self.assignment)
            else:
                sc = scoring.batched_dext_numpy(
                    self.hg, miss, self.in_fringe, self.assignment,
                    cap_pins=self.p.cap_pins,
                    max_width=scoring.L_BUCKETS[-1])
            self.stats.host_rows += int(miss.size)
            self.cache[miss] = sc

    def _rescore_rows(self, ids: np.ndarray) -> np.ndarray:
        """Host re-score of NaN-quarantined kernel rows (DESIGN.md §4f).

        Rebuilds the same clipped neighbor tile the kernel saw and
        emulates its count (valid entries minus fringe members), so the
        recovered scores are bit-identical to an unpoisoned kernel call:
        the kernel's integer counts are float32-exact and the truncation
        penalty is applied by the caller either way.
        """
        if self.adj is not None:
            tile, _ = scoring.neighbor_tile_adj(
                self.adj, ids, self.assignment)
        else:
            tile, _ = scoring.neighbor_tile(
                self.hg, ids, self.assignment, cap_pins=self.p.cap_pins)
        tile = tile[:ids.size]
        valid = tile >= 0
        ent = np.where(valid, tile, 0)
        return (valid & ~self.in_fringe[ent]).sum(axis=1).astype(
            np.float64)


def _grow_partition(st: _BatchedState, phase: int, target: int,
                    warm: bool = False) -> None:
    """Grow core set ``phase`` to ``target`` vertices.

    The step loop keeps a *pool* of up to ``pool_cap`` scored candidates
    sorted by cached score. Refills happen in bulk (one kernel tile per
    ``b`` rows) whenever the pool runs low; between refills a step is just
    "admit the t best, queue their edges" — the latency-bound per-vertex
    machinery of the sequential engines is gone entirely. The paper's
    s-sized fringe survives as the top-s of the pool: it is what the
    scoring kernel subtracts, exactly like F in Eq. 1.

    ``warm`` continues a phase that already has members (a cross-engine
    warm start from a snapshot, DESIGN.md §4f): existing members are
    activated instead of seeding, and growth resumes from their count.
    """
    p = st.p
    st.cache[:] = -1.0
    st.buckets = {}
    pool = np.empty(0, dtype=np.int64)       # kept sorted by score asc
    pending: list = []                       # admitted, edges not yet queued

    acc = 0
    if warm:
        members = np.flatnonzero(st.assignment == phase)
        acc = int(members.size)
        if acc >= target:
            return
        if acc:
            st.activate(members.astype(np.int64), phase)
    if acc == 0:
        seeds = st.random_unassigned(1)
        if seeds.size == 0:
            return
        st.assignment[seeds] = phase
        st.activate(seeds, phase)
        acc = 1

    while acc < target:
        st.stats.steps += 1
        # ------- refill: bulk-draw and kernel-score new candidates -------
        if pool.size < max(p.t, p.refill_lo):
            if pending:
                st.activate(np.concatenate(pending), phase)
                pending = []
            cand = st.draw_candidates(p.pool_cap - pool.size)
            if cand.size:
                st.score_misses(cand)
                st.in_pool[cand] = True
                pool = np.concatenate([pool, cand])
                pool = pool[np.argsort(st.cache[pool], kind="stable")]
                st.set_fringe(pool[:p.s])
        if pool.size == 0:                    # random restart (batched: on
            # shattered remainders each isolated vertex would otherwise
            # cost a full step, so seed up to t fresh growth points)
            vs = st.random_unassigned(p.t)
            if vs.size == 0:
                return
            st.stats.random_restarts += 1
            pool = vs
            st.in_pool[vs] = True
            st.cache[vs] = 0.0
            st.set_fringe(pool[:p.s])
        # ------- core update: admit the t best pool vertices -------
        nt = min(p.t, target - acc, pool.size)
        admit, pool = pool[:nt], pool[nt:]
        st.assignment[admit] = phase
        st.in_pool[admit] = False
        pending.append(admit)
        st.set_fringe(pool[:p.s])
        acc += int(admit.size)

    # release fringe + pool back to the universe (§III-B1 step 4)
    st.set_fringe(np.empty(0, dtype=np.int64))
    st.in_pool[pool] = False


# --------------------------------------------------------------------- #
# Superstep engine: device-resident, multi-phase, cross-phase cache.
# --------------------------------------------------------------------- #

@dataclasses.dataclass
class SuperstepParams(BatchedParams):
    """Knobs for the superstep engine (DESIGN.md §4).

    Inherits the batched knobs; ``t`` (admissions per phase per
    superstep), ``s``, ``pool_cap`` and ``seed`` keep their meaning.
    ``b``/``kernel_min``/``refill_lo`` are unused — refills are sized by
    ``rows`` and every score goes through the fused device call.
    """
    # fresh candidate rows per phase per superstep; None = max(8, t) so
    # refills keep up with the admission drain at any t
    rows: Optional[int] = None
    # in-flight supersteps of the double-buffered pipeline (DESIGN.md
    # §4d). 1 = lock-step (bit-identical to the pre-pipeline engine);
    # 2 = the default overlap: while the device runs superstep N the
    # host mirrors superstep N-1's admissions and packs superstep N+1.
    pipeline_depth: int = 2
    # device-memory budget (core/membudget.py, DESIGN.md §4g): bytes,
    # a "512MB"/"2GiB" string, or None = the REPRO_DEVICE_MEM_BUDGET
    # env var, falling back to the backend's reported allocator limit.
    # The engine plans its tile sizes against the budget before upload
    # and walks the memory-rung ladder on (real or injected) OOM.
    mem_budget: Optional[object] = None


# Flat bucket-store key layout: one sorted int64 per queued (phase,
# class, edge) activation — phase in the top bits, the power-of-two
# size-class exponent below it, and a sequence number in the low bits.
# Keeping the store sorted by this key makes "draw smallest classes
# first, FIFO within a class, requeues at the front" a pure prefix scan
# per phase: back-appends allocate increasing sequence numbers, front
# requeues allocate decreasing ones.
_PH_SHIFT = 50
_CLS_SHIFT = 44
_SEQ_START = np.int64(1) << 43


@dataclasses.dataclass
class _CallArgs:
    """The host-built buffers of one superstep's device call.

    Kept on the in-flight handle so a quarantined superstep can be
    replayed *exactly* (same pure program, same inputs, current image
    state). ``bias`` is always the CLEAN bias — an injected NaN tile
    poisons a copy at dispatch time only.
    """
    delta: np.ndarray
    vals: np.ndarray
    dirty: np.ndarray
    dcnt: np.ndarray
    fresh: np.ndarray
    bias: np.ndarray
    pool_arr: np.ndarray
    fringe: np.ndarray
    targets: np.ndarray
    select_k: int
    # spill rung only: the held pool's scores from the host cache
    # mirror, captured at dispatch AFTER the dirty decrements were
    # applied host-side — a replay reuses them verbatim, so the
    # decrements are never double-applied (DESIGN.md §4g)
    prev: Optional[np.ndarray] = None


@dataclasses.dataclass
class _Superstep:
    """One in-flight superstep: result futures + replay material.

    ``winners``/``n_stale``/``poison`` (and ``ncf`` for the sharded
    engine) are device futures the driver blocks on at harvest;
    ``donated`` pins the consumed image arrays until that block (a
    donated buffer's last reference must not drop while the execution
    consuming it is still in flight); ``args`` is the clean input set
    for poisoned-superstep replays.
    """
    winners: object
    n_stale: object
    poison: object
    fresh_ids: np.ndarray
    donated: tuple
    args: _CallArgs
    ncf: object = None
    # spill rung only: the fresh scores the host cache mirror adopts at
    # harvest (after the poison check — a quarantined superstep's
    # scores are garbage and are replaced by the replay's)
    scores: object = None


class _SuperstepState(_BatchedState):
    """Adds the device-resident graph image and per-phase growth state.

    The host keeps only ids and flags (assignment mirror, pool id lists,
    the flat active-edge bucket store, a has-been-scored bitmask); every
    *score* lives in the device cache and is maintained exactly by the
    decrement rule in ``scoring._pipeline_program`` — no per-phase wipe.
    Admissions are selected, capped and applied *on device*
    (``dispatch``); the host mirrors them at ``harvest`` time, possibly
    several supersteps later, which is what lets the pipeline driver
    overlap host orchestration with device compute.
    """

    def __init__(self, hg: Hypergraph, k: int, p: SuperstepParams,
                 mesh=None, mem_rung: int = 0):
        super().__init__(hg, k, p)
        self.dev_cache = None       # device score cache (None when spilled)
        self.host_cache = None      # host float32 mirror (spill rung only)
        self.paged_adj = None       # membudget.PagedAdjacency (paged rung)
        self.mem_plan = None
        self.g_chunk = 1
        self.mem_rung = int(mem_rung)
        if k >= 1 << (63 - _PH_SHIFT):      # bucket-store key width
            self.dev = None
            return
        if self.adj is None:        # hub-expansion guard tripped on host
            self.dev = None
            return
        deg = np.diff(self.adj[0])
        self.deg = deg
        # One gather-width per run: every distinct shape retraces the
        # whole jitted superstep program (~0.5-1s in interpret mode), and
        # padding a gather is far cheaper than a retrace. The tile width
        # is the bucket of the 99.5th-percentile degree — the handful of
        # rows wider than that are truncated and carry the hub penalty
        # (they'd compare as "huge neighborhood" anyway).
        self.tile_l = scoring._bucket_width(int(min(
            np.percentile(deg, 99.5) if deg.size else 1,
            scoring.L_BUCKETS[-1])))
        # memory plan (core/membudget.py, DESIGN.md §4g): size every
        # device-resident tensor BEFORE upload against the resolved
        # budget; ``mem_rung`` > 0 means an earlier attempt OOMed and
        # the retry loop wants the next-smaller configuration. An
        # unconstrained budget at rung 0 reproduces today's tile
        # choices bit for bit. MemoryLadderExhausted propagates to the
        # retry loop, which hands the engine-degradation ladder over.
        rows = p.rows if p.rows else max(8, p.t)
        self.mem_budget = membudget.resolve_budget(
            getattr(p, "mem_budget", None))
        spec = membudget.MemSpec(
            n=hg.n, adj_pins=int(self.adj[1].size), k=k, rows=int(rows),
            pool_cap=int(p.pool_cap), t=int(p.t),
            tile_l=int(self.tile_l),
            pipeline_depth=max(1, int(p.pipeline_depth)))
        plan = membudget.plan_memory(spec, self.mem_budget,
                                     self._mem_features,
                                     rung_start=self.mem_rung)
        self.mem_plan = plan
        self.mem_rung = plan.rung
        self.tile_l = plan.tile_l
        self.g_chunk = plan.g_chunk
        self.stats.plan_rung = plan.rung
        self.stats.peak_bytes_planned = int(plan.planned_bytes)
        fplan = self.fault_plan
        if fplan is not None:
            sp = fplan.fire(("oom",), 0)
            if sp is not None:
                # simulated allocation failure at the image-upload site
                self.stats.faults_injected += 1
                if sp.fatal:
                    raise resilience.UnrecoverableFault(
                        "injected fatal OOM during device image upload")
                raise membudget.DeviceOOM(
                    "injected OOM during device image upload",
                    rung=self.mem_rung)
        import jax
        import jax.numpy as jnp

        n, m = hg.n, hg.m
        try:
            if plan.paged:
                # no resident CSR: the pager uploads id-range chunks on
                # demand under its own LRU byte budget. ``dev`` keeps a
                # non-None sentinel so the driver takes the device path.
                self.paged_adj = membudget.PagedAdjacency(
                    self.adj, plan.page_bytes, self.stats)
                self.dev = (None, None)
            else:
                self.dev = hg.device_adjacency(mesh=mesh)
                if self.dev is None:
                    return
            self.dev_assign = jnp.full((n,), -1, jnp.int32)
            if plan.spill_cache:
                self.host_cache = np.full(n, -1.0, dtype=np.float32)
            else:
                self.dev_cache = jnp.full((n,), -1.0, jnp.float32)
            self.dev_acc = jnp.zeros((k,), jnp.int32)
            # sticky NaN-quarantine flag (scoring._poison_guard), donated
            # through every superstep like the rest of the mutable image
            self.dev_poison = jnp.zeros((1,), jnp.int32)
        except Exception as exc:
            if membudget.is_oom_error(exc):
                raise membudget.DeviceOOM(
                    f"device image upload failed: {exc!r}",
                    rung=self.mem_rung) from exc
            raise
        if mesh is not None:       # replicate the mutable image too
            from jax.sharding import NamedSharding, PartitionSpec
            rep = NamedSharding(mesh, PartitionSpec())
            self.dev_assign = jax.device_put(self.dev_assign, rep)
            self.dev_cache = jax.device_put(self.dev_cache, rep)
            self.dev_acc = jax.device_put(self.dev_acc, rep)
            self.dev_poison = jax.device_put(self.dev_poison, rep)
        self.cache_scored = np.zeros(n, dtype=bool)
        self.pools = [np.empty(0, dtype=np.int64) for _ in range(k)]
        # flat (phase, class, edge) bucket store — two parallel arrays
        # sorted by the composite key above, replacing the per-phase
        # dict-of-deques
        self.bq_key = np.empty(0, dtype=np.int64)
        self.bq_edge = np.empty(0, dtype=np.int64)
        self._bq_pending: list = []     # rows awaiting the lazy merge
        self._seq_back = np.int64(_SEQ_START)
        self._seq_front = np.int64(_SEQ_START) - 1
        self.edge_queued = np.zeros((k, m), dtype=bool)
        self.delta_ids: list = []
        self.delta_vals: list = []
        self.pending_dirty: list = []   # queued winner decrements
        self._excl_scratch = np.zeros(n, dtype=bool)
        # The dirty-pair pad is pre-sized from the expected per-superstep
        # dirty rate and only ratchets up (monotone -> at most a couple
        # of traces).
        mean_deg = self.adj[1].size / max(hg.n, 1)
        expect = min(hg.n, max(256, int(2 * k * p.t * mean_deg)))
        self._dirty_ratchet = 1 << int(np.ceil(np.log2(expect + 1)))
        csr_bytes = (0 if self.paged_adj is not None
                     else self.dev[0].nbytes + self.dev[1].nbytes)
        cache_bytes = (0 if self.dev_cache is None
                       else self.dev_cache.nbytes)
        self.stats.device_image_bytes = int(
            csr_bytes + cache_bytes + self.dev_assign.nbytes
            + self.dev_acc.nbytes)

    # ------------------------------------------------------------------ #
    # injected faults this engine's dispatch site can see (the sharded
    # engine adds "collective" — its dispatch owns the all_gather);
    # "oom@N" lets chaos suites simulate mid-run allocation failures
    _fault_kinds = ("dispatch", "oom")
    # memory-rung reductions this engine has program variants for
    # (membudget.rung_ladder); the sharded engine only supports the
    # width/depth knobs — its CSR is replicated per device
    _mem_features = membudget.SUPERSTEP_FEATURES

    @property
    def interpret(self) -> bool:
        """Pallas interpret mode, re-resolved per call.

        A property, not an ``__init__`` attribute, so flipping
        ``REPRO_PALLAS_INTERPRET`` steers even a live engine — the
        NaN-quarantine tests flip it without rebuilding state, and
        ``kernels/_compat.pallas_interpret`` already reads the env per
        call; this was the one residual cache of its value.
        """
        from repro.kernels._compat import pallas_interpret
        return pallas_interpret()

    def _to_device(self, arr: np.ndarray):
        """Upload a host array as this engine's replicated image layout."""
        import jax.numpy as jnp
        return jnp.asarray(arr)

    # ------------------------------------------------------------------ #
    def _pmask(self, g: int) -> np.ndarray:
        """Pool-membership mask governing phase ``g``'s draws.

        Engine-wide for the single-device engine; the sharded engine
        overrides this with the per-device-group mask.
        """
        return self.in_pool

    def _restart_mask(self) -> np.ndarray:
        """Mask a restart injection must avoid: every engine pool.

        Injections are applied to the device image with an unconditional
        scatter, so they must never name a vertex an in-flight superstep
        could still admit — i.e. anything in ANY pool. For the
        single-device engine that is exactly ``in_pool``; the sharded
        engine unions its per-group masks.
        """
        return self.in_pool

    def assign_now(self, vs: np.ndarray, phase: int) -> None:
        """Assign ``vs`` to ``phase``; queue the device delta + dirtying."""
        vs = np.asarray(vs, dtype=np.int64)
        self.assignment[vs] = phase
        self.in_pool[vs] = False
        self.delta_ids.append(vs)
        self.delta_vals.append(np.full(vs.size, phase, dtype=np.int32))

    def activate_phase(self, vs: np.ndarray, phase: int) -> None:
        """Queue the edges incident to newly admitted vertices of a phase."""
        self.activate_many(np.asarray(vs, dtype=np.int64),
                           np.full(len(vs), phase, dtype=np.int64))

    def activate_many(self, vs: np.ndarray, phases: np.ndarray) -> None:
        """Queue incident edges for a whole superstep's admissions at once.

        ``vs``/``phases`` are parallel arrays; one CSR gather + one
        lexsort appends every fresh (phase, edge) activation to the back
        of the flat sorted bucket store — no per-phase python pass.
        """
        edges, owner = scoring.gather_csr_rows(
            self.hg.v2e_indptr, self.hg.v2e_indices, vs)
        if edges.size == 0:
            return
        edges = edges.astype(np.int64)
        ph = phases[owner]
        key = np.unique(ph * np.int64(self.hg.m) + edges)
        ph, edges = key // self.hg.m, key % self.hg.m
        live = ~self.edge_queued[ph, edges] & ~self.edge_dead[edges]
        ph, edges = ph[live], edges[live]
        if edges.size == 0:
            return
        self.edge_queued[ph, edges] = True
        # power-of-two size classes instead of exact sizes: smallest-first
        # drawing is a heuristic, and ~12 classes keep the number of
        # (phase, class) segments small.
        sizes = self.edge_sizes[edges]
        cls = np.where(
            sizes <= 1, np.int64(0),
            np.ceil(np.log2(np.maximum(sizes, 2))).astype(np.int64))
        order = np.lexsort((cls, ph))
        ph, edges, cls = ph[order], edges[order], cls[order]
        seq = np.arange(self._seq_back, self._seq_back + edges.size,
                        dtype=np.int64)
        self._seq_back += edges.size
        self._store_insert(
            (ph << _PH_SHIFT) | (cls << _CLS_SHIFT) | seq, edges)

    # ------------------------------------------------------ bucket store
    def _store_insert(self, key: np.ndarray, edges: np.ndarray) -> None:
        """Queue rows for the store; merged lazily at the next draw.

        Batching the merges (one sorted-merge per pack instead of one
        per activation) keeps store maintenance O(store) *per superstep*
        rather than per call — visibility is identical because draws
        only happen at pack time, after ``_store_flush``.
        """
        if key.size:
            self._bq_pending.append((key, edges))

    def _store_flush(self) -> None:
        if not self._bq_pending:
            return
        key = np.concatenate([kk for kk, _ in self._bq_pending])
        edges = np.concatenate([ee for _, ee in self._bq_pending])
        self._bq_pending = []
        order = np.argsort(key, kind="stable")
        key, edges = key[order], edges[order]
        if self.bq_key.size == 0:
            self.bq_key, self.bq_edge = key, edges
            return
        pos = np.searchsorted(self.bq_key, key)
        self.bq_key = np.insert(self.bq_key, pos, key)
        self.bq_edge = np.insert(self.bq_edge, pos, edges)

    def _store_take(self, budget: np.ndarray):
        """Greedy smallest-class-first prefix take for every phase.

        ``budget`` is the per-phase pin budget; each queued edge
        contributes its power-of-two class value (the same accounting
        the dict-of-deques draw used). Only each phase's front slice
        (at most ``budget`` rows — every edge costs >= 1 unit) is ever
        decoded, so the take is O(sum budgets + k log store), not
        O(store). Returns the taken rows' ``(edges, ph, cls_log)``
        columns, phase-major (the store is key-sorted), and drops them
        from the store.
        """
        self._store_flush()
        key = self.bq_key
        if key.size == 0 or not budget.any():
            empty = np.empty(0, dtype=np.int64)
            return empty, empty, empty
        k = self.k
        bounds = np.searchsorted(
            key, np.arange(k + 1, dtype=np.int64) << _PH_SHIFT)
        start = bounds[:k]
        cap = np.minimum(bounds[1:] - start, budget)
        tot = int(cap.sum())
        if tot == 0:
            empty = np.empty(0, dtype=np.int64)
            return empty, empty, empty
        head = np.cumsum(cap) - cap
        local = np.arange(tot, dtype=np.int64) - np.repeat(head, cap)
        rows = np.repeat(start, cap) + local
        ph_r = np.repeat(np.arange(k, dtype=np.int64), cap)
        ckey = key[rows]
        cls_log = (ckey >> _CLS_SHIFT) & np.int64(63)
        csize = np.int64(1) << cls_log
        cum = np.cumsum(csize)
        excl = cum - csize
        base = np.zeros(k, dtype=np.int64)
        has = cap > 0
        base[has] = excl[head[has]]
        take = (excl - base[ph_r]) < budget[ph_r]
        tk = rows[take]
        edges_t, ph_t, cls_t = self.bq_edge[tk], ph_r[take], cls_log[take]
        if tk.size:     # drop taken rows NOW — restarts may insert
            keep = np.ones(key.size, dtype=bool)
            keep[tk] = False
            self.bq_key = key[keep]
            self.bq_edge = self.bq_edge[keep]
        return edges_t, ph_t, cls_t

    def _store_requeue(self, rq_ph: list, rq_cls: list,
                       rq_edge: list) -> None:
        """Requeue still-live taken rows at their queue fronts."""
        if not rq_ph:
            return
        ph = np.concatenate(rq_ph)
        cls = np.concatenate(rq_cls)
        edges = np.concatenate(rq_edge)
        seq = np.arange(self._seq_front - edges.size + 1,
                        self._seq_front + 1, dtype=np.int64)
        self._seq_front -= edges.size
        key = (ph << _PH_SHIFT) | (cls << _CLS_SHIFT) | seq
        order = np.argsort(key, kind="stable")
        self._store_insert(key[order], edges[order])

    def take_delta(self, cap: int):
        """Drain up to ``cap`` queued (id, phase) assignment pairs.

        FIFO across calls: an overflowing drain leaves the tail queued
        (int64 ids / int32 phases preserved) for the next superstep.
        """
        if not self.delta_ids:
            return (np.empty(0, dtype=np.int64),
                    np.empty(0, dtype=np.int32))
        ids = np.concatenate(self.delta_ids).astype(np.int64, copy=False)
        vals = np.concatenate(self.delta_vals).astype(np.int32,
                                                      copy=False)
        if ids.size <= cap:
            self.delta_ids, self.delta_vals = [], []
            return ids, vals
        self.delta_ids = [ids[cap:]]
        self.delta_vals = [vals[cap:]]
        return ids[:cap], vals[:cap]

    def _pack_delta_dirty(self, delta_cap, extra_dirty=()):
        """Drain queued assignments into the padded device buffers.

        Pre-aggregates the dirtied-neighbor multiset of the drained
        delta — one CSR gather + bincount, shipped as (unique id, count)
        pairs padded to a power-of-two bucket (bounded retraces,
        O(unique) device scatter). ``extra_dirty`` merges additional raw
        neighbor-id arrays into the multiset (the sharded engine's
        queued decrement tails). Returns ``(delta, vals, dirty, dcnt)``;
        shared by both device engines so their cache-exactness
        bookkeeping cannot drift apart.
        """
        d_ids, d_vals = self.take_delta(delta_cap)
        delta = np.full(delta_cap, -1, dtype=np.int32)
        vals = np.zeros(delta_cap, dtype=np.int32)
        delta[:d_ids.size] = d_ids
        vals[:d_ids.size] = d_vals
        nbrs, _ = scoring.gather_csr_rows(self.adj[0], self.adj[1], d_ids)
        parts = list(extra_dirty)
        if nbrs.size:
            parts.append(nbrs.astype(np.int64))
        if parts:
            counts = np.bincount(np.concatenate(parts))
            uniq = np.flatnonzero(counts)
            self.stats.cache_invalidations += int(uniq.size)
        else:
            uniq = np.empty(0, dtype=np.int64)
            counts = np.empty(0, dtype=np.int64)
        cap = max(self._dirty_ratchet,
                  1 << int(np.ceil(np.log2(max(uniq.size, 1)))))
        self._dirty_ratchet = cap
        dirty = np.full(cap, -1, dtype=np.int32)
        dcnt = np.zeros(cap, dtype=np.float32)
        dirty[:uniq.size] = uniq
        dcnt[:uniq.size] = counts[uniq]
        return delta, vals, dirty, dcnt

    # ---------------------------------------------------- pipeline hooks
    def pack_superstep(self, active, R: int, P: int, t: int,
                       targets: np.ndarray, acc: np.ndarray):
        """Host half of one superstep: draw, dedup, tile-pack, restart.

        One flat store scan + ONE pins gather covers every active
        phase's candidate draw (stage A, assignment-independent); a thin
        rotation-ordered pass then applies the order-sensitive pieces —
        edge liveness, candidate acceptance against the live pool masks,
        and random restarts (stage B). Mutates pools/masks/acc for the
        injections and returns ``(packed, injected)`` where ``packed``
        is ``(fresh, bias, pool_arr, fresh_ids)`` or None when no phase
        had anything to score.
        """
        kG = self.k
        rot = self.stats.supersteps % active.size
        order = np.concatenate([active[rot:], active[:rot]])
        # stage 0: drop ids that went stale (admitted meanwhile) from
        # the held pools, then size each phase's draw
        need = np.zeros(kG, dtype=np.int64)
        budget = np.zeros(kG, dtype=np.int64)
        for g in order:
            gi = int(g)
            ids = self.pools[gi]
            if ids.size:
                keep = self.assignment[ids] < 0
                if not keep.all():
                    self._pmask(gi)[ids[~keep]] = False
                    ids = ids[keep]
                    self.pools[gi] = ids
            need[gi] = min(R, P - ids.size)
            if need[gi] > 0:
                budget[gi] = max(4 * need[gi], 512)
        # stage A: one prefix take over the sorted store + one CSR
        # gather for every taken edge of every phase
        edges_t, ph_t, cls_t = self._store_take(budget)
        pins, prow = scoring.gather_csr_rows(
            self.hg.e2v_indptr, self.hg.e2v_indices, edges_t)
        pins = pins.astype(np.int64)
        self.stats.edges_scanned += int(pins.size)
        edge_lo = np.searchsorted(ph_t, np.arange(kG + 1, dtype=np.int64))
        pin_lo = np.searchsorted(prow, edge_lo)
        # per-phase first-occurrence dedup of the pin streams. The
        # acceptance filters below are per-pin properties, so deduping
        # before filtering equals the old filter-then-dedup, row for row.
        if pins.size:
            pph = ph_t[prow]
            _, first = np.unique(pph * np.int64(self.hg.n) + pins,
                                 return_index=True)
            first = np.sort(first)
            cand_all = pins[first]
            cand_lo = np.searchsorted(pph[first],
                                      np.arange(kG + 1, dtype=np.int64))
        else:
            cand_all = pins
            cand_lo = np.zeros(kG + 1, dtype=np.int64)
        # stage B: rotation-ordered liveness / acceptance / restarts
        fresh = np.full((kG, R), -1, dtype=np.int32)
        bias = np.full((kG, R), np.inf, dtype=np.float32)
        pool_arr = np.full((kG, P), -1, dtype=np.int32)
        fresh_parts: list = []
        rq_ph: list = []
        rq_cls: list = []
        rq_edge: list = []
        injected = 0
        packed_any = False
        rmask = None    # injection-safety mask, computed at most once
        #                 per pack (the sharded union is O(devices * n))
        for g in order:
            gi = int(g)
            e0, e1 = int(edge_lo[gi]), int(edge_lo[gi + 1])
            if e1 > e0:     # edge liveness at this phase's turn
                p0, p1 = int(pin_lo[gi]), int(pin_lo[gi + 1])
                unas = self.assignment[pins[p0:p1]] < 0
                live = np.bincount(prow[p0:p1][unas] - e0,
                                   minlength=e1 - e0) > 0
                eg = edges_t[e0:e1]
                if not live.all():
                    self.edge_dead[eg[~live]] = True    # dead forever
                if live.any():
                    rq_ph.append(ph_t[e0:e1][live])
                    rq_cls.append(cls_t[e0:e1][live])
                    rq_edge.append(eg[live])
            pmask = self._pmask(gi)
            cg = cand_all[int(cand_lo[gi]):int(cand_lo[gi + 1])]
            drawn = cg
            if cg.size:
                okc = (self.assignment[cg] < 0) & ~pmask[cg]
                drawn = cg[okc][:need[gi]]
            ids = self.pools[gi]
            miss = np.empty(0, dtype=np.int64)
            if drawn.size:
                pmask[drawn] = True
                if rmask is not None and rmask is not pmask:
                    rmask[drawn] = True     # keep the union mask live
                scored = self.cache_scored[drawn]
                hits, miss = drawn[scored], drawn[~scored]
                if hits.size:       # cross-phase reuse: already cached
                    ids = np.concatenate([ids, hits])
            if ids.size == 0 and miss.size == 0:
                # shattered remainder: seed fresh growth points directly
                if rmask is None:
                    rmask = self._restart_mask()
                vs = self.random_unassigned(
                    min(t, int(targets[gi] - acc[gi])), in_pool=rmask)
                if vs.size:
                    self.stats.random_restarts += 1
                    self.assign_now(vs, gi)
                    self.activate_phase(vs, gi)
                    acc[gi] += vs.size
                    injected += int(vs.size)
                continue
            fresh[gi, :miss.size] = miss
            bias[gi, :miss.size] = np.where(
                self.deg[miss] > self.tile_l, scoring.TRUNC_PENALTY, 0.0)
            pool_arr[gi, :ids.size] = ids
            # every pool_arr slot is a score served straight from the
            # device cache (held-over or cross-phase hit) instead of a
            # kernel rescore — the reuse the exact-decrement design buys
            self.stats.cache_hits += int(ids.size)
            self.pools[gi] = np.concatenate([ids, miss])
            fresh_parts.append(miss)
            self.stats.kernel_rows += int(miss.size)
            packed_any = True
        self._store_requeue(rq_ph, rq_cls, rq_edge)
        if not packed_any:
            return None, injected
        fresh_ids = (np.concatenate(fresh_parts) if fresh_parts
                     else np.empty(0, dtype=np.int64))
        return (fresh, bias, pool_arr, fresh_ids), injected

    def _image_buffers(self) -> tuple:
        """The live donated image arrays of this engine's current mode.

        The spill rung keeps no device cache and the paged rung no
        resident CSR, so the donated set is mode-dependent — every
        dispatch/replay handle pins exactly these.
        """
        bufs = [self.dev_assign, self.dev_acc, self.dev_poison]
        if self.dev_cache is not None:
            bufs.insert(1, self.dev_cache)
        return tuple(bufs)

    def _call_program(self, args: _CallArgs, reset: np.ndarray):
        """Issue the fused superstep program; rotate the donated image.

        Returns ``(winners, n_stale, ncf, scores)`` futures (``ncf`` is
        None for the single-device engine; ``scores`` is None except on
        the spill rung, where the host owns the score cache and the
        fresh scores ride back with the winners). The memory plan picks
        the program variant (DESIGN.md §4g) — all of them bit-exact to
        the default on this engine. The sharded engine overrides this —
        it is the ONLY device-call difference between the two engines.
        """
        if self.paged_adj is not None:
            tile_raw = self.paged_adj.gather(
                args.fresh.reshape(-1), self.tile_l)
            (self.dev_assign, self.dev_cache, self.dev_acc,
             self.dev_poison, winners, n_stale) = \
                scoring.paged_superstep_device(
                    self.dev_assign, self.dev_cache, self.dev_acc,
                    self.dev_poison, args.delta, args.vals, args.dirty,
                    args.dcnt, tile_raw, args.fresh, args.bias,
                    args.pool_arr, args.fringe, args.targets, reset,
                    select_k=args.select_k, interpret=self.interpret)
            return winners, n_stale, None, None
        if self.host_cache is not None:
            (self.dev_assign, self.dev_acc, self.dev_poison, winners,
             n_stale, scores) = scoring.spill_superstep_device(
                self.dev[0], self.dev[1], self.dev_assign, self.dev_acc,
                self.dev_poison, args.delta, args.vals, args.fresh,
                args.bias, args.pool_arr, args.prev, args.fringe,
                args.targets, reset, tile_l=self.tile_l,
                select_k=args.select_k, interpret=self.interpret)
            return winners, n_stale, None, scores
        if self.g_chunk > 1:
            (self.dev_assign, self.dev_cache, self.dev_acc,
             self.dev_poison, winners, n_stale) = \
                scoring.chunked_superstep_device(
                    self.dev[0], self.dev[1], self.dev_assign,
                    self.dev_cache, self.dev_acc, self.dev_poison,
                    args.delta, args.vals, args.dirty, args.dcnt,
                    args.fresh, args.bias, args.pool_arr, args.fringe,
                    args.targets, reset, tile_l=self.tile_l,
                    select_k=args.select_k, interpret=self.interpret,
                    g_chunk=self.g_chunk)
            return winners, n_stale, None, None
        (self.dev_assign, self.dev_cache, self.dev_acc, self.dev_poison,
         winners, n_stale) = scoring.pipeline_superstep_device(
            self.dev[0], self.dev[1], self.dev_assign, self.dev_cache,
            self.dev_acc, self.dev_poison, args.delta, args.vals,
            args.dirty, args.dcnt, args.fresh, args.bias, args.pool_arr,
            args.fringe, args.targets, reset, tile_l=self.tile_l,
            select_k=args.select_k, interpret=self.interpret)
        return winners, n_stale, None, None

    def _call_guarded(self, args: _CallArgs, reset: np.ndarray):
        """``_call_program`` under fault injection + bounded retry."""
        return self._guarded_kernel(
            lambda: self._call_program(args, reset),
            int(self.stats.supersteps), self._fault_kinds,
            donated=self._image_buffers())

    def _count_dispatch(self, fresh: np.ndarray, select_k: int) -> None:
        """Per-dispatch counter hook (the sharded engine adds
        collective accounting). Replays never come through here — the
        kernel_calls == supersteps invariant survives recovery."""

    def _count_harvest(self, handle: _Superstep) -> None:
        """Per-harvest counter hook (sharded: admission conflicts)."""

    def dispatch(self, fresh, bias, pool_arr, fringe, fresh_ids,
                 targets_i32, delta_cap: int, select_k: int):
        """Launch one superstep on the device (async); returns a handle.

        JAX's async dispatch returns immediately — the returned handle's
        arrays are futures the driver blocks on only at ``harvest``, so
        the host keeps packing while the device computes. The previous
        (donated) image arrays ride the handle: deleting a donated
        buffer synchronizes with the execution consuming it, so their
        last reference must not drop before the harvest-time block.

        Fault-injection sites (DESIGN.md §4f): a ``dispatch`` (or, for
        the sharded engine, ``collective``) spec raises here and is
        retried/escalated by ``_call_guarded``; a ``nan`` spec poisons a
        COPY of the bias buffer so the device program's quarantine
        guard trips — the handle keeps the clean args for the replay.
        """
        tails = self.pending_dirty
        self.pending_dirty = []
        delta, vals, dirty, dcnt = self._pack_delta_dirty(
            delta_cap, extra_dirty=tails)
        prev = None
        if self.host_cache is not None:
            # spill rung: the host owns the score cache. Apply the dirty
            # decrements to the float32 mirror NOW (the same IEEE adds
            # the device program would have scattered) and ship the held
            # pool's scores in; the device still masks stale slots
            # itself against the post-injection assignment.
            u = dirty >= 0
            ids = dirty[u].astype(np.int64)
            self.host_cache[ids] -= dcnt[u]
            prev = self.host_cache[np.where(pool_arr >= 0, pool_arr,
                                            0)].astype(np.float32)
        self.stats.host_to_device_bytes += (
            fresh.nbytes + bias.nbytes + pool_arr.nbytes + fringe.nbytes
            + delta.nbytes + vals.nbytes + dirty.nbytes + dcnt.nbytes
            + targets_i32.nbytes)
        self.stats.supersteps += 1
        self.stats.kernel_calls += 1
        self._count_dispatch(fresh, select_k)
        args = _CallArgs(delta, vals, dirty, dcnt, fresh, bias,
                         pool_arr, fringe, targets_i32, select_k,
                         prev=prev)
        send = args
        plan = self.fault_plan
        if plan is not None:
            sp = plan.fire(("nan",), int(self.stats.supersteps))
            if sp is not None:
                self.stats.faults_injected += 1
                if sp.fatal:
                    raise resilience.UnrecoverableFault(
                        f"injected fatal nan tile at superstep "
                        f"{self.stats.supersteps}")
                bias_bad = bias.copy()
                bias_bad[fresh >= 0] = np.nan
                send = dataclasses.replace(args, bias=bias_bad)
        donated = self._image_buffers()
        winners, n_stale, ncf, scores = self._call_guarded(send, _RESET0)
        return _Superstep(winners, n_stale, self.dev_poison, fresh_ids,
                          donated, args, ncf, scores)

    def replay(self, h: _Superstep) -> _Superstep:
        """Re-issue a quarantined superstep from its clean args.

        The poisoned superstep (and every later in-flight one — the
        poison flag is sticky) reverted all of its device mutations, so
        the current image equals the state just before it ran: calling
        the same pure program with the handle's clean args and
        ``reset=1`` recovers exactly what a fault-free run computed.
        Counts as a retry only — never as a new superstep/kernel call.
        A superstep still poisoned after a clean replay means the
        non-finite scores are real (not injected): unrecoverable here,
        the ladder's host engines score around poisoned rows instead.
        """
        self.stats.retries += 1
        donated = self._image_buffers()
        winners, n_stale, ncf, scores = self._call_program(h.args,
                                                           _RESET1)
        nh = _Superstep(winners, n_stale, self.dev_poison, h.fresh_ids,
                        donated, h.args, ncf, scores)
        if int(np.asarray(nh.poison)[0]) > 0:
            raise resilience.UnrecoverableFault(
                "superstep still poisoned after a clean replay: the "
                "non-finite scores did not come from an injected fault")
        return nh

    def harvest(self, handle, acc: np.ndarray, targets: np.ndarray,
                exclude=()) -> int:
        """Block on one in-flight superstep and mirror its admissions.

        The only blocking transfer of the steady state: everything else
        the driver does (packing superstep N+1) happens while the device
        still computes superstep N. Admission mirroring is fully
        vectorized — no per-slot python loop. ``exclude`` carries the
        fresh-id arrays of the supersteps still in flight: their scores
        were computed *after* this superstep's winners were applied, so
        the queued winner decrements must skip them (double-decrement
        otherwise).

        A quarantined handle (non-finite scores poisoned the superstep,
        which reverted itself on device) is replayed from its clean
        args before mirroring — direct dispatch/harvest callers survive
        an injected NaN tile without the pipeline driver's help; the
        driver additionally replays the whole in-flight window to keep
        device-effect order (see ``_harvest_next``).
        """
        import time as _time

        if int(np.asarray(handle.poison)[0]) > 0:
            handle = self.replay(handle)
        winners_dev, stale_dev = handle.winners, handle.n_stale
        fresh_ids = handle.fresh_ids
        t0 = _time.perf_counter()
        try:
            winners = np.asarray(winners_dev)
            n_stale = int(stale_dev)
            if self.host_cache is not None and handle.scores is not None:
                # spill rung: adopt the fresh scores into the host
                # mirror — the same pad-dropping scatter the device
                # cache write performs, after the poison check above
                flat = handle.args.fresh.reshape(-1)
                sc = np.asarray(handle.scores).reshape(-1)
                real = flat >= 0
                self.host_cache[flat[real].astype(np.int64)] = sc[real]
        except membudget.DeviceOOM:
            raise
        except Exception as exc:
            # a real allocator failure can surface at the blocking
            # transfer, not just at dispatch — same recovery path
            if membudget.is_oom_error(exc):
                raise membudget.DeviceOOM(
                    f"superstep harvest failed: {exc!r}",
                    rung=self.mem_rung) from exc
            raise
        self.stats.device_s += _time.perf_counter() - t0
        t0 = _time.perf_counter()
        self.stats.stale_redraws += n_stale
        if fresh_ids.size:
            self.cache_scored[fresh_ids] = True
        kG, t = winners.shape
        flat = winners.reshape(-1).astype(np.int64)
        mask = flat >= 0
        vs = flat[mask]
        progress = int(vs.size)
        if vs.size:
            ph = np.repeat(np.arange(kG, dtype=np.int64), t)[mask]
            self.assignment[vs] = ph.astype(np.int32)
            self._release_members(vs, ph)
            acc += np.bincount(ph, minlength=kG)
            self.activate_many(vs, ph)
            self._queue_decrements(vs, exclude)
            for g in np.unique(ph):
                if acc[g] >= targets[g]:    # phase done: release pool
                    gi = int(g)
                    self._pmask(gi)[self.pools[gi]] = False
                    self.pools[gi] = np.empty(0, dtype=np.int64)
        self._count_harvest(handle)
        self.stats.host_s += _time.perf_counter() - t0
        return progress

    # ----------------------------------------------- snapshot / restore
    def capture_payload(self, acc: np.ndarray, cur_depth: int) -> dict:
        """Complete engine state at a drained superstep boundary.

        Called with the pipeline empty (the driver drains in-flight
        supersteps first), so the only live state is host bookkeeping
        plus the settled device image. Everything the continuation
        reads is captured; static derivatives (adjacency, tile width,
        random order) are rebuilt from the config at restore.
        """
        self._store_flush()
        return {
            "assignment": self.assignment.copy(),
            "acc": acc.copy(),
            "cur_depth": int(cur_depth),
            "in_pool": self.in_pool.copy(),
            "cache_scored": self.cache_scored.copy(),
            "pools": [ids.copy() for ids in self.pools],
            "bq_key": self.bq_key.copy(),
            "bq_edge": self.bq_edge.copy(),
            "seq_back": int(self._seq_back),
            "seq_front": int(self._seq_front),
            "edge_queued": self.edge_queued.copy(),
            "edge_dead": self.edge_dead.copy(),
            "delta_ids": [a.copy() for a in self.delta_ids],
            "delta_vals": [a.copy() for a in self.delta_vals],
            "pending_dirty": [a.copy() for a in self.pending_dirty],
            "rand_ptr": int(self.rand_ptr),
            "rng_state": self.rng.bit_generator.state,
            "dirty_ratchet": int(self._dirty_ratchet),
            "stats": dataclasses.replace(self.stats),
            "dev_assign": np.asarray(self.dev_assign),
            # on the spill rung the authoritative cache IS the host
            # mirror; either way the payload carries plain numpy
            "dev_cache": (self.host_cache.copy()
                          if self.host_cache is not None
                          else np.asarray(self.dev_cache)),
            "dev_acc": np.asarray(self.dev_acc),
        }

    def restore_exact(self, pay: dict):
        """Resume bit-identically from a same-engine/config payload.

        Returns ``(acc, cur_depth)`` for the driver. The device image
        is re-uploaded from the snapshot's downloaded copies; the
        poison flag restarts clean (snapshots are only taken at drained,
        replayed-if-needed boundaries).
        """
        self.assignment = pay["assignment"].copy()
        self.in_pool = pay["in_pool"].copy()
        self.cache_scored = pay["cache_scored"].copy()
        self.pools = [ids.copy() for ids in pay["pools"]]
        self.bq_key = pay["bq_key"].copy()
        self.bq_edge = pay["bq_edge"].copy()
        self._bq_pending = []
        self._seq_back = np.int64(pay["seq_back"])
        self._seq_front = np.int64(pay["seq_front"])
        self.edge_queued = pay["edge_queued"].copy()
        self.edge_dead = pay["edge_dead"].copy()
        self.delta_ids = [a.copy() for a in pay["delta_ids"]]
        self.delta_vals = [a.copy() for a in pay["delta_vals"]]
        self.pending_dirty = [a.copy() for a in pay["pending_dirty"]]
        self.rand_ptr = int(pay["rand_ptr"])
        self.rng.bit_generator.state = pay["rng_state"]
        self._dirty_ratchet = int(pay["dirty_ratchet"])
        self.stats = dataclasses.replace(pay["stats"])
        self.dev_assign = self._to_device(pay["dev_assign"])
        if self.host_cache is not None:
            self.host_cache = pay["dev_cache"].astype(np.float32,
                                                      copy=True)
        else:
            self.dev_cache = self._to_device(pay["dev_cache"])
        self.dev_acc = self._to_device(pay["dev_acc"])
        self.dev_poison = self._to_device(np.zeros(1, dtype=np.int32))
        return pay["acc"].copy(), int(pay["cur_depth"])

    def restore_warm(self, warm: np.ndarray) -> np.ndarray:
        """Cross-engine warm start: adopt a (partial) assignment.

        Mirrors the assignment into the device image and activates the
        incident edges of every adopted member, so growth continues
        from the snapshot instead of from scratch. Exactness is not
        claimed (the donor engine's transient state is gone) — this is
        the degradation ladder's path. Returns the per-phase totals.
        """
        done = np.flatnonzero(warm >= 0)
        acc = np.zeros(self.k, dtype=np.int64)
        if done.size:
            ph = warm[done].astype(np.int64)
            self.assignment[done] = warm[done]
            acc[:int(ph.max()) + 1] = np.bincount(ph)
            self.dev_assign = self._to_device(
                self.assignment.astype(np.int32, copy=True))
            self.dev_acc = self._to_device(
                acc.astype(np.int32, copy=True))
            self.activate_many(done.astype(np.int64), ph)
        return acc

    def _release_members(self, vs: np.ndarray, ph: np.ndarray) -> None:
        """Clear pool membership for freshly mirrored winners."""
        self.in_pool[vs] = False

    def _filter_rescored(self, nbrs: np.ndarray, exclude) -> np.ndarray:
        """Drop ids fresh-rescored by a still-in-flight superstep.

        Their cache entries are written *after* the winners applied, so
        they already reflect the admissions — decrementing them again
        would double-count. O(|nbrs| + |exclude|) via a reusable
        boolean scratch.
        """
        parts = [e for e in exclude if e.size]
        if not parts or nbrs.size == 0:
            return nbrs
        ex = np.concatenate(parts)
        scratch = self._excl_scratch
        scratch[ex] = True
        out = nbrs[~scratch[nbrs]]
        scratch[ex] = False
        return out

    def _queue_decrements(self, vs: np.ndarray, exclude=()) -> None:
        """Queue the winners' neighbor decrements for the next dispatch.

        The full multiset — one CSR gather, pre-aggregated into
        (unique id, count) pairs by ``_pack_delta_dirty`` — exactly the
        lock-step engine's decrement schedule at depth 1; ids rescored
        by an in-flight superstep are excluded (see
        ``_filter_rescored``).
        """
        nbrs, _ = scoring.gather_csr_rows(self.adj[0], self.adj[1], vs)
        if nbrs.size == 0:
            return
        nbrs = self._filter_rescored(nbrs.astype(np.int64), exclude)
        if nbrs.size:
            self.pending_dirty.append(nbrs)


def _harvest_next(st: _SuperstepState, inflight: collections.deque,
                  acc: np.ndarray, targets: np.ndarray) -> int:
    """Harvest the oldest in-flight superstep, replaying a poisoned one.

    When the popped superstep was quarantined (non-finite scores — an
    injected NaN tile, normally), every in-flight superstep dispatched
    after it self-aborted on the sticky poison flag: replay the whole
    window in FIFO order from the handles' clean args so device-effect
    order — and therefore bit-identical recovery — is preserved.
    """
    h = inflight.popleft()
    if int(np.asarray(h.poison)[0]) > 0:
        h = st.replay(h)
        redo = list(inflight)
        inflight.clear()
        for old in redo:
            inflight.append(st.replay(old))
    return st.harvest(h, acc, targets, [e.fresh_ids for e in inflight])


def _teardown_pipeline(st: _SuperstepState,
                       inflight: collections.deque) -> None:
    """Settle the donated-buffer chains of an aborted run (§4f).

    Blocks on every in-flight superstep's outputs so each donated
    execution completes (deleting a donated buffer synchronizes with
    the execution consuming it), then drops the handles and the queued
    host transients. Nothing device-side survives except the state's
    own current image arrays — no zombie refs, and the process is free
    to start a fresh engine run.
    """
    for h in list(inflight):
        try:
            np.asarray(h.winners)
            np.asarray(h.poison)
        except Exception:       # the abort may have broken the call
            pass
    inflight.clear()
    st.delta_ids, st.delta_vals = [], []
    st.pending_dirty = []


def _run_pipeline(hg: Hypergraph, k: int, p: SuperstepParams,
                  num_devices: Optional[int] = None, mem_rung: int = 0,
                  mem_warm: Optional[np.ndarray] = None,
                  mem_retries: int = 0):
    """Grow all ``k`` partitions concurrently; returns (assignment, state).

    The shared double-buffered superstep driver of the device engines
    (DESIGN.md §4d). Each *superstep* is one fused device call that
    scores the stacked fresh-candidate tiles of every growing phase and
    admits each phase's top-``t`` on device (paper §VI k-way growth).
    Up to ``p.pipeline_depth`` supersteps stay in flight: while the
    device computes superstep N, the host mirrors superstep N-1's
    admissions and speculatively draws/packs superstep N+1; proposals
    that went stale in between are skipped on device by the
    deterministic redraw rule, so results are seeded-deterministic at
    any depth and ``pipeline_depth=1`` reproduces the lock-step engine
    bit for bit.

    Resilience (DESIGN.md §4f): every ``p.snapshot_every`` supersteps
    the driver drains the pipeline and publishes a checkpoint; with
    ``p.resume`` pointing at a same-engine/same-config snapshot the run
    restores it and continues bit-identically to an uninterrupted run
    with the same cadence (a cross-engine snapshot warm-starts from its
    assignment instead). Any exception tears the pipeline down safely.
    """
    import time as _time

    if num_devices is None:
        kG = k
        engine = "hype_superstep"
        st = _SuperstepState(hg, k, p, mem_rung=mem_rung)
    else:
        kL = -(-k // num_devices)
        kG = kL * num_devices
        engine = "hype_sharded"
        st = _ShardedState(hg, kG, p, num_devices, mem_rung=mem_rung)
    if st.dev is None:
        return None, None                       # caller falls back
    st.stats.mem_retries = int(mem_retries)
    n = hg.n
    base, rem = divmod(n, k)
    targets = np.zeros(kG, dtype=np.int64)
    targets[:k] = base + (np.arange(k) < rem)
    targets_i32 = targets.astype(np.int32)
    acc = np.zeros(kG, dtype=np.int64)
    R, P, t = p.rows, p.pool_cap, p.t
    delta_cap = max(2 * kG * t, kG)
    # the memory plan may clamp the pipeline to lock-step (rung >= the
    # depth reduction): the clamp is part of the schedule, and at an
    # unconstrained budget the plan echoes the param unchanged
    depth = max(1, min(int(p.pipeline_depth),
                       int(st.mem_plan.pipeline_depth)))
    fringe = np.full((kG, 1), -1, dtype=np.int32)   # fringe-free scoring
    snap_every = max(0, int(p.snapshot_every or 0))
    # everything that decides the superstep schedule: an exact restore
    # requires all of it to match (snapshot cadence included — draining
    # the pipeline at snapshots IS part of the schedule at depth > 1).
    # Of the memory plan (§4g) only the EFFECTIVE tile width and the
    # depth clamp enter: the chunk/spill/paged rungs are bit-exact per
    # superstep, so a snapshot restores exactly across them, while a
    # tile_l or depth change is a schedule change and must warm-start
    config = {"k": k, "devices": 0 if num_devices is None else
              num_devices, "t": t, "rows": R, "pool_cap": P, "s": p.s,
              "seed": p.seed, "pipeline_depth": depth,
              "snapshot_every": snap_every,
              "tile_l": int(st.tile_l)}

    cur_depth = depth
    seeded = False
    ckpt = resilience.load_latest(p.resume) if p.resume else None
    if ckpt is not None:
        t0 = _time.perf_counter()
        resilience.check_checkpoint(ckpt, hg, k)
        if ckpt.engine == engine and ckpt.config == config:
            acc, cur_depth = st.restore_exact(ckpt.payload)
            seeded = True       # the snapshot already carries the seeds
        else:
            acc = st.restore_warm(resilience.warm_assignment(ckpt))
        st.stats.resumed_at = int(ckpt.superstep)
        st.stats.restore_s += _time.perf_counter() - t0
    elif mem_warm is not None:
        # memory-rung retry (DESIGN.md §4g): adopt the failed attempt's
        # host assignment mirror so already-grown members survive the
        # re-tiling — the seeding below only fills still-empty phases
        acc = st.restore_warm(np.asarray(mem_warm, dtype=np.int32))

    if not seeded:
        # seed every empty phase with one random vertex (paper §III-B1
        # step 1); a warm start only seeds phases the snapshot left empty
        seeds = st.random_unassigned(
            int(((acc == 0) & (targets > 0)).sum()))
        gi = 0
        for g in range(kG):
            if targets[g] == 0 or acc[g] > 0 or gi >= seeds.size:
                continue
            v = seeds[gi:gi + 1]
            gi += 1
            st.assign_now(v, g)
            st.activate_phase(v, g)
            acc[g] += 1

    last_snap = int(st.stats.supersteps)
    inflight: collections.deque = collections.deque()
    try:
        while True:
            progress = 0
            if (snap_every
                    and st.stats.supersteps - last_snap >= snap_every):
                while inflight:     # drain: snapshots see settled state
                    progress += _harvest_next(st, inflight, acc, targets)
                t0 = _time.perf_counter()
                st.stats.snapshots += 1
                resilience.save_snapshot(
                    p.snapshot_dir,
                    resilience.PartitionCheckpoint(
                        engine, int(st.stats.supersteps),
                        hg.fingerprint(), dict(config),
                        st.capture_payload(acc, cur_depth)),
                    keep_last=int(p.keep_last))
                st.stats.snapshot_s += _time.perf_counter() - t0
                last_snap = int(st.stats.supersteps)
            active = np.flatnonzero(acc < targets)
            if active.size == 0:
                break
            while len(inflight) >= cur_depth:   # tail heuristic shrank
                progress += _harvest_next(st, inflight, acc, targets)
            t0 = _time.perf_counter()
            packed, injected = st.pack_superstep(active, R, P, t,
                                                 targets, acc)
            progress += injected
            if packed is not None:
                fresh, bias, pool_arr, fresh_ids = packed
                handle = st.dispatch(fresh, bias, pool_arr, fringe,
                                     fresh_ids, targets_i32, delta_cap,
                                     t)
            st.stats.host_s += _time.perf_counter() - t0
            if packed is not None:
                inflight.append(handle)
            elif inflight:
                st.stats.pipeline_stalls += 1   # device idles this round
            if inflight and (len(inflight) >= cur_depth
                             or packed is None):
                harvested = _harvest_next(st, inflight, acc, targets)
                progress += harvested
                # adaptive depth: while a superstep admits less than
                # half its capacity the draw view — not the device — is
                # the bottleneck, and speculative packs only waste
                # fixed-cost device calls; drop to lock-step until
                # admissions recover. Deterministic: based solely on
                # mirrored results.
                cur_depth = 1 if 2 * harvested < active.size * t else depth
            if progress == 0 and not inflight:
                break   # starved: remaining vertices sit in other pools
        while inflight:     # drain the pipeline before the safety net
            _harvest_next(st, inflight, acc, targets)
    except membudget.DeviceOOM as exc:
        # memory fault mid-run: settle the pipeline, then enrich the
        # exception with everything the re-tiling retry loop needs —
        # the rung this attempt ran at and the host assignment mirror
        # (the admissions harvested so far) for the warm start
        _teardown_pipeline(st, inflight)
        if exc.rung is None:
            exc.rung = int(st.mem_plan.rung)
        exc.partial = st.assignment.copy()
        raise
    except BaseException:
        # abort path (injected unrecoverable fault, KeyboardInterrupt,
        # real device failure): settle every donated chain before
        # propagating so no zombie buffer outlives the run
        _teardown_pipeline(st, inflight)
        raise

    # safety net: balance-fill any stragglers into underfull phases
    rem_v = np.flatnonzero(st.assignment < 0)
    if rem_v.size:
        deficit = np.maximum(targets - acc, 0)
        fill = np.repeat(np.arange(kG), deficit)[:rem_v.size]
        st.assignment[rem_v[:fill.size]] = fill.astype(np.int32)
    st.in_pool[:] = False
    if num_devices is not None:
        st.group_pool[:] = False
    # the device image syncs at superstep boundaries only; the final
    # injections' delta dies with the state (the host assignment is
    # authoritative). Tests needing device/host parity flush explicitly
    # through dispatch/harvest.
    st.delta_ids, st.delta_vals = [], []
    obs = membudget.observed_peak_bytes()
    st.stats.peak_bytes_observed = (int(obs) if obs else
                                    int(st.stats.peak_bytes_planned))
    return st.assignment, st


def _run_pipeline_budgeted(hg: Hypergraph, k: int, p: SuperstepParams,
                           num_devices: Optional[int] = None):
    """``_run_pipeline`` under the memory-rung retry loop (§4g).

    A ``DeviceOOM`` — a real allocator failure at the upload, dispatch
    or harvest site, or an injected non-fatal ``oom`` fault — retries
    the SAME engine at the next-smaller memory plan, warm-started from
    the failed attempt's host assignment mirror, before the
    engine-degradation ladder (``partition_resilient``) is ever
    consulted. Only an exhausted rung ladder escalates, as
    ``UnrecoverableFault``. The fault plan is resolved once up front so
    a one-shot injected ``oom`` spec stays consumed across retries
    (re-parsing ``REPRO_FAULT_PLAN`` per attempt would re-fire it
    forever).
    """
    fplan = resilience.resolve_fault_plan(p.fault_plan)
    if fplan is not None:
        p = dataclasses.replace(p, fault_plan=fplan)
    rung, warm, retries = 0, None, 0
    while True:
        try:
            return _run_pipeline(hg, k, p, num_devices, mem_rung=rung,
                                 mem_warm=warm, mem_retries=retries)
        except membudget.DeviceOOM as exc:
            retries += 1
            rung = (rung if exc.rung is None else int(exc.rung)) + 1
            if exc.partial is not None and (exc.partial >= 0).any():
                warm = exc.partial
        except membudget.MemoryLadderExhausted as exc:
            raise resilience.UnrecoverableFault(
                f"device memory rungs exhausted: {exc}") from exc


# --------------------------------------------------------------------- #
# Mesh-sharded superstep engine: phase groups sharded over a device mesh.
# --------------------------------------------------------------------- #

@dataclasses.dataclass
class ShardedParams(SuperstepParams):
    """Knobs for the mesh-sharded superstep engine (DESIGN.md §4c).

    Inherits every superstep knob. ``devices`` sets the 1-D mesh size the
    k phase groups are sharded over; ``None`` uses every local JAX device
    (capped at ``k``). On CPU, simulate a mesh with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
    """
    devices: Optional[int] = None


class _ShardedState(_SuperstepState):
    """Superstep state plus the mesh and per-device-group pool masks.

    The CSR image, assignment, score cache and admission totals are
    *replicated* on every mesh device; the phase groups are sharded.
    Pool membership is tracked per device group (``group_pool``) —
    groups draw candidates independently, so two groups may pool (and
    propose) the same vertex; the device program's lowest-phase-wins
    rule resolves it, and the host mirrors winners without re-queuing
    them as deltas. Shares the pipeline driver with the single-device
    engine: only ``dispatch`` (the shard_map program + collective
    counters) and the pool-mask hooks differ.
    """

    def __init__(self, hg: Hypergraph, k_padded: int, p: ShardedParams,
                 num_devices: int, mem_rung: int = 0):
        self.D = num_devices
        self.kL = k_padded // num_devices
        mesh = scoring._sharded_mesh(num_devices)
        super().__init__(hg, k_padded, p, mesh=mesh, mem_rung=mem_rung)
        if self.dev is None:
            return
        self.mesh = mesh
        self.group_pool = np.zeros((num_devices, hg.n), dtype=bool)
        # the image lives once per device
        self.stats.device_image_bytes *= num_devices

    def group_of(self, g: int) -> int:
        return g // self.kL

    def _pmask(self, g: int) -> np.ndarray:
        return self.group_pool[g // self.kL]

    def _restart_mask(self) -> np.ndarray:
        # groups pool independently, so an injection-safe vertex must
        # sit in NO group's pool (it could be an in-flight slot there)
        return self.group_pool.any(axis=0)

    def _release_members(self, vs: np.ndarray, ph: np.ndarray) -> None:
        self.group_pool[ph // self.kL, vs] = False

    def _queue_decrements(self, vs: np.ndarray, exclude=()) -> None:
        """Sharded: the device program already decremented each winner's
        first ``tile_l`` neighbors; only the clipped tails of the (rare)
        wider winners ride the next dispatch's dirty pairs — with the
        same in-flight rescore exclusion as the single-device engine."""
        self.stats.cache_invalidations += int(
            np.minimum(self.deg[vs], self.tile_l).sum())
        wide = vs[self.deg[vs] > self.tile_l]
        if wide.size == 0:
            return
        indptr, indices = self.adj
        nbrs, owner = scoring.gather_csr_rows(indptr, indices, wide)
        lens = (indptr[wide + 1] - indptr[wide]).astype(np.int64)
        start = np.cumsum(lens) - lens
        off = np.arange(nbrs.size, dtype=np.int64) - start[owner]
        tail = self._filter_rescored(
            nbrs[off >= self.tile_l].astype(np.int64), exclude)
        if tail.size:
            self.pending_dirty.append(tail)

    def _to_device(self, arr: np.ndarray):
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec
        return jax.device_put(jnp.asarray(arr),
                              NamedSharding(self.mesh, PartitionSpec()))

    # the sharded dispatch site owns the per-superstep all_gather, so a
    # failed collective is injected (and retried) there too
    _fault_kinds = ("dispatch", "collective", "oom")
    # no chunked/spill/paged program variants exist for the replicated
    # shard_map image — only width and depth shrink (DESIGN.md §4g)
    _mem_features = membudget.SHARDED_FEATURES

    def _call_program(self, args: _CallArgs, reset: np.ndarray):
        """One mesh-sharded superstep (async).

        Host->device traffic is the same id/bias buffers as the
        single-device engine; the host-side dirty pairs carry the
        injections' neighbor multisets *and* the decrement tails of
        earlier wider-than-tile winners (the device clips its own
        decrement gather at ``tile_l``), so the replicated cache stays
        exact.
        """
        (self.dev_assign, self.dev_cache, self.dev_acc, self.dev_poison,
         winners, ncf, n_stale) = scoring.sharded_superstep_device(
            self.dev[0], self.dev[1], self.dev_assign, self.dev_cache,
            self.dev_acc, self.dev_poison, args.delta, args.vals,
            args.dirty, args.dcnt, args.fresh, args.bias, args.pool_arr,
            args.fringe, args.targets, reset, num_devices=self.D,
            group_l=self.kL, tile_l=self.tile_l,
            select_k=args.select_k, interpret=self.interpret)
        return winners, n_stale, ncf, None

    def _count_dispatch(self, fresh: np.ndarray, select_k: int) -> None:
        kG, R = fresh.shape
        # one all_gather per superstep: every device materializes the
        # global (kG, R + t) int32 payload of fresh scores + admissions
        self.stats.collectives += 1
        self.stats.collective_bytes += self.D * kG * (R + select_k) * 4

    def _count_harvest(self, handle: _Superstep) -> None:
        # the conflict count rides the harvested superstep's results, so
        # reading it here never adds a block
        self.stats.admission_conflicts += int(handle.ncf)

    def capture_payload(self, acc: np.ndarray, cur_depth: int) -> dict:
        pay = super().capture_payload(acc, cur_depth)
        pay["group_pool"] = self.group_pool.copy()
        return pay

    def restore_exact(self, pay: dict):
        out = super().restore_exact(pay)
        self.group_pool = pay["group_pool"].copy()
        return out


def _maybe_refine(hg: Hypergraph, k: int, params: BatchedParams,
                  assignment: np.ndarray, stats: BatchedStats
                  ) -> np.ndarray:
    """Run the k-way refinement post-pass when ``refine_passes`` > 0.

    Shared by every engine of the family (DESIGN.md §4e): boundary
    vertices are screened on device by the ``kway_gains`` kernel and
    moved under exact-gain, balance-capped admission, so the engine's
    ``max - min <= 1`` contract survives. ``refine_passes = 0`` returns
    the assignment object untouched — the engines stay bit-identical to
    their pre-refinement outputs (golden-hash-enforced).
    """
    passes = getattr(params, "refine_passes", 0)
    if passes <= 0 or k <= 1:
        return assignment
    from .refine import refine_kway

    refined, rstats = refine_kway(hg, assignment, k, passes)
    stats.refine = rstats
    return refined


def hype_sharded_partition(hg: Hypergraph, k: int,
                           params: Optional[ShardedParams] = None,
                           return_stats: bool = False):
    """Partition ``hg`` with the mesh-sharded superstep engine.

    Same contract as ``hype_superstep_partition`` (complete int32
    assignment, ``max - min <= 1`` vertex balance, all k phases grown
    concurrently) but the phase groups are sharded over a 1-D JAX device
    mesh with ``shard_map``: the CSR graph image, assignment vector and
    score cache are replicated per device, each device runs the fused
    ``hype_score_select`` superstep for its own contiguous phase group,
    and a single ``all_gather`` per superstep exchanges fresh scores and
    proposed admissions so every replica stays globally consistent —
    including the exact-decrement score-cache invalidations. Cross-device
    admission conflicts (two groups proposing the same vertex in one
    superstep) are resolved deterministically: the lowest phase id wins
    and losers redraw from their pools next superstep.

    ``params.devices`` picks the mesh size (default: all local devices,
    capped at ``k``); on CPU simulate devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``. With one
    device the engine degenerates to (slightly reordered) single-device
    superstep growth. Supersteps run on the shared double-buffered
    pipeline (``params.pipeline_depth``, DESIGN.md §4d). Falls back to
    ``hype_superstep_partition``'s own fallback chain when the
    adjacency guard trips.
    """
    if params is None:
        params = ShardedParams()
    if params.rows is None:
        params = dataclasses.replace(params, rows=max(8, params.t))
    if k < 1:
        raise ValueError("k must be >= 1")
    if params.t < 1 or params.rows < 1 or params.pool_cap < 1:
        raise ValueError("rows, pool_cap, t must all be >= 1")
    if params.pipeline_depth < 1:
        raise ValueError("pipeline_depth must be >= 1")
    if params.snapshot_every > 0 and not params.snapshot_dir:
        raise ValueError("snapshot_every requires snapshot_dir")
    if params.devices is not None and params.devices < 1:
        raise ValueError("devices must be >= 1")
    if k == 1:
        out = np.zeros(hg.n, dtype=np.int32)
        return (out, BatchedStats()) if return_stats else out
    import jax
    avail = len(jax.devices())
    num = params.devices if params.devices is not None else avail
    num = max(1, min(num, avail, k))
    assignment, st = _run_pipeline_budgeted(hg, k, params, num)
    if assignment is None:
        return hype_superstep_partition(hg, k, params, return_stats)
    assert (assignment >= 0).all()
    assignment = _maybe_refine(hg, k, params, assignment, st.stats)
    if return_stats:
        return assignment, st.stats
    return assignment


def hype_superstep_partition(hg: Hypergraph, k: int,
                             params: Optional[SuperstepParams] = None,
                             return_stats: bool = False):
    """Partition ``hg`` with the device-resident superstep engine.

    Same contract as ``hype_batched_partition`` (complete int32
    assignment, max - min <= 1 vertex balance) but all ``k`` partitions
    grow *concurrently*: every superstep stacks the fresh candidates of
    all growing phases into one fused ``hype_score_select`` device call
    against a graph image (CSR + assignment + score cache) that was
    uploaded once. Scores survive across refills and phases — admissions
    *decrement* their neighbors' cached scores instead of wiping the
    cache. ``params.pipeline_depth`` supersteps run double-buffered
    (DESIGN.md §4d): while the device computes superstep N the host
    mirrors N-1's admissions and packs N+1; ``pipeline_depth=1`` is the
    lock-step schedule, bit for bit. Falls back to
    ``hype_batched_partition`` when the adjacency guard trips
    (pathological hub expansion).
    """
    if params is None:
        params = SuperstepParams()
    if params.rows is None:
        params = dataclasses.replace(params, rows=max(8, params.t))
    if k < 1:
        raise ValueError("k must be >= 1")
    if params.t < 1 or params.rows < 1 or params.pool_cap < 1:
        raise ValueError("rows, pool_cap, t must all be >= 1")
    if params.pipeline_depth < 1:
        raise ValueError("pipeline_depth must be >= 1")
    if params.snapshot_every > 0 and not params.snapshot_dir:
        raise ValueError("snapshot_every requires snapshot_dir")
    if k == 1:
        out = np.zeros(hg.n, dtype=np.int32)
        return (out, BatchedStats()) if return_stats else out
    assignment, st = _run_pipeline_budgeted(hg, k, params)
    if assignment is None:
        return hype_batched_partition(hg, k, params, return_stats)
    assert (assignment >= 0).all()
    assignment = _maybe_refine(hg, k, params, assignment, st.stats)
    if return_stats:
        return assignment, st.stats
    return assignment


# --------------------------------------------------------------------- #
# Fully device-resident loop engine (DESIGN.md §4i).
# --------------------------------------------------------------------- #

@dataclasses.dataclass
class DeviceParams(SuperstepParams):
    """Knobs for the fully device-resident loop engine (DESIGN.md §4i).

    ``pipeline_depth`` is ignored: the device loop runs the lock-step
    pd1 cadence by construction — that is exactly what makes it
    golden-hash bit-identical to ``hype_superstep`` at depth 1.
    """
    # supersteps per host-visible while_loop segment; the host syncs a
    # handful of scalars (flags / progress / acc) once per chunk and the
    # snapshot cadence shortens chunks to land on its boundaries
    chunk_supersteps: int = 64
    # device score-cache storage: "float32" is bit-identical to the host
    # engines; "float16" halves the cache bytes — scores are small exact
    # integers plus the 1e12 hub penalty, so fp16 rounding only perturbs
    # ties above 2048 external neighbors (bounded-error tested)
    cache_dtype: str = "float32"
    # capacity overrides for the fixed device rings (None = planned from
    # graph statistics; the driver doubles a flagged cap and re-runs —
    # schedules are capacity-independent, so the rerun is bit-identical)
    store_cap: Optional[int] = None
    act_cap: Optional[int] = None


def _device_probe_faults(st: _SuperstepState, lo: int, hi: int):
    """Fire injected dispatch/oom specs for superstep ordinals [lo, hi].

    The host engines fire these one superstep at a time inside
    ``_guarded_kernel``; the device loop runs a whole chunk per host
    call, so the driver probes the chunk's ordinal range up front —
    same plan, same ordinals, same escalation rules.
    """
    plan = st.fault_plan
    if plan is None:
        return
    for o in range(lo, hi + 1):
        sp = plan.fire(("dispatch", "oom"), o)
        if sp is None:
            continue
        st.stats.faults_injected += 1
        if sp.fatal:
            raise resilience.UnrecoverableFault(
                f"injected fatal {sp.kind} fault at superstep {o}")
        if sp.kind == "oom":
            raise membudget.DeviceOOM(
                f"injected OOM at superstep {o}", rung=st.mem_rung)
        # transient dispatch fault: the injection fires *before* the
        # call, so the retry re-issues the identical pure chunk —
        # mirror _guarded_kernel's accounting and continue
        st.stats.retries += 1
        time.sleep(float(st.p.retry_backoff_s))


def _device_probe_nan(st: _SuperstepState, lo: int, hi: int):
    """Find the first injected nan spec in [lo, hi]; returns ordinal|-1.

    The device program poisons the flagged superstep's bias tile on
    device (``poison_at``) and replays it in place with the clean bias
    — the same quarantine/replay recovery as the host pipeline.
    """
    plan = st.fault_plan
    if plan is None:
        return -1
    for o in range(lo, hi + 1):
        sp = plan.fire(("nan",), o)
        if sp is None:
            continue
        st.stats.faults_injected += 1
        if sp.fatal:
            raise resilience.UnrecoverableFault(
                f"injected fatal nan tile at superstep {o}")
        return o
    return -1


def _device_export(st: _SuperstepState, k: int, acc: np.ndarray,
                   caps: dict, cache_f16: bool):
    """Build the initial device carry from the seeded host state.

    Returns ``(carry_np, caps)`` — plain numpy; the attempt loop
    uploads. ``caps["sp"]`` may grow if the host store does not fit.
    """
    hg, n, m = st.hg, st.hg.n, st.hg.m
    P = int(st.p.pool_cap)
    st._store_flush()
    enc = device_loop.host_store_to_device(
        st.bq_key, st.bq_edge, k, caps["sp"])
    while enc is None:
        caps = dict(caps, sp=caps["sp"] * 2)
        enc = device_loop.host_store_to_device(
            st.bq_key, st.bq_edge, k, caps["sp"])
    skey, sedge, sback, sfront = enc
    pool = np.full((k, P), -1, dtype=np.int32)
    pool_n = np.zeros(k, dtype=np.int32)
    for g, ids in enumerate(st.pools):
        pool[g, :ids.size] = ids
        pool_n[g] = ids.size
    # queued decrements: the undrained delta's neighbor multiset (the
    # host drains it at the next dispatch) plus any queued winner tails
    pend = np.zeros(n, dtype=np.int32)
    d_ids, _ = st.take_delta(1 << 60)
    if d_ids.size:
        nbrs, _ = scoring.gather_csr_rows(st.adj[0], st.adj[1], d_ids)
        np.add.at(pend, nbrs, 1)
    for a in st.pending_dirty:
        np.add.at(pend, np.asarray(a, dtype=np.int64), 1)
    st.pending_dirty = []
    cache = np.asarray(st.dev_cache, dtype=np.float32).copy()
    if cache_f16:
        cache = np.clip(cache, -65504.0, 65504.0).astype(np.float16)
    carry = dict(
        assign=st.assignment.astype(np.int32, copy=True),
        cache=cache,
        acc=acc.astype(np.int32, copy=True),
        in_pool=st.in_pool.copy(),
        cache_scored=st.cache_scored.copy(),
        edge_queued=st.edge_queued.copy(),
        edge_dead=st.edge_dead.copy(),
        skey=skey, sedge=sedge, sback=sback, sfront=sfront,
        pool=pool, pool_n=pool_n, pend=pend,
        rand_ptr=np.int32(st.rand_ptr),
        supersteps=np.int32(st.stats.supersteps),
        progress=np.int32(1),
        flags=np.int32(0),
        ss_in_chunk=np.int32(0),
        stats=np.zeros(device_loop.NSTATS, dtype=np.int32),
    )
    return carry, caps


def _device_attempt(hg: Hypergraph, k: int, p: DeviceParams,
                    caps_over: dict):
    """One capacity attempt of the device loop.

    Returns ``("ok", assignment, st)``, ``("fallback", reason, None)``
    or ``("overflow", flags, caps)``. DeviceOOM propagates (enriched
    with rung + partial) for the caller's ladder.
    """
    import time as _time

    chunk_max = max(1, int(getattr(p, "chunk_supersteps", 64)))
    cache_dtype = str(getattr(p, "cache_dtype", "float32"))
    cache_f16 = cache_dtype == "float16"
    st = _SuperstepState(hg, k, dataclasses.replace(p, pipeline_depth=1),
                         mem_rung=0)
    if st.dev is None:
        return ("fallback", "no device adjacency", None)
    if st.mem_plan.rung != 0:
        # the budget wants a reduced configuration; the §4g rungs are
        # host-pipeline programs — hand the whole run to that engine
        return ("fallback", "memory plan below rung 0", None)
    n, m = hg.n, hg.m
    base, rem = divmod(n, k)
    targets = np.zeros(k, dtype=np.int64)
    targets[:] = base + (np.arange(k) < rem)
    acc = np.zeros(k, dtype=np.int64)
    R, P, t = int(p.rows), int(p.pool_cap), int(p.t)
    vdeg = np.diff(hg.v2e_indptr).astype(np.int64)
    mean_vdeg = float(vdeg.mean()) if n else 1.0
    mean_adeg = float(st.deg.mean()) if n else 1.0
    sizes = st.edge_sizes
    max_edge = int(sizes.max()) if m else 1
    caps = device_loop.plan_caps(
        n=n, m=m, kG=k, rows=R, t=t, mean_vdeg=mean_vdeg,
        mean_adeg=mean_adeg, max_edge=max_edge,
        store_cap=getattr(p, "store_cap", None),
        act_cap=getattr(p, "act_cap", None))
    caps.update(caps_over)
    if not device_loop.supported(n=n, m=m, kG=k, bud=caps["bud"]):
        return ("fallback", "int32 encoding gates", None)

    snap_every = max(0, int(p.snapshot_every or 0))
    config = {"k": k, "devices": 0, "t": t, "rows": R, "pool_cap": P,
              "s": p.s, "seed": p.seed, "pipeline_depth": 1,
              "snapshot_every": snap_every, "tile_l": int(st.tile_l),
              "chunk_supersteps": chunk_max, "cache_dtype": cache_dtype}
    engine = "hype_device"
    resumed_carry = None
    ckpt = resilience.load_latest(p.resume) if p.resume else None
    if ckpt is not None:
        t0 = _time.perf_counter()
        resilience.check_checkpoint(ckpt, hg, k)
        if ckpt.engine == engine and ckpt.config == config:
            pay = ckpt.payload
            resumed_carry = {kk: vv.copy()
                             for kk, vv in pay["carry"].items()}
            caps = dict(pay["caps"])
            caps.update(caps_over)
            st.stats = dataclasses.replace(pay["stats"])
            acc = np.asarray(resumed_carry["acc"], dtype=np.int64)
        else:
            acc = st.restore_warm(resilience.warm_assignment(ckpt))
        st.stats.resumed_at = int(ckpt.superstep)
        st.stats.restore_s += _time.perf_counter() - t0

    if resumed_carry is None:
        # seed every empty phase with one random vertex — exactly the
        # pipeline driver's loop, so the device schedule starts from
        # the same state and random stream position
        seeds = st.random_unassigned(
            int(((acc == 0) & (targets > 0)).sum()))
        gi = 0
        for g in range(k):
            if targets[g] == 0 or acc[g] > 0 or gi >= seeds.size:
                continue
            v = seeds[gi:gi + 1]
            gi += 1
            st.assign_now(v, g)
            st.activate_phase(v, g)
            acc[g] += 1
        carry_np, caps = _device_export(st, k, acc, caps, cache_f16)
    else:
        carry_np = resumed_carry
        carry_np["flags"] = np.int32(0)
        carry_np["progress"] = np.int32(1)

    cfg = device_loop.DeviceLoopConfig(
        n=n, m=m, kG=k, rows=R, pool_cap=P, t=t, tile_l=int(st.tile_l),
        bud=caps["bud"], pp=caps["pp"], sp=caps["sp"], act=caps["act"],
        rawt=caps["rawt"], rawd=caps["rawd"], cw=caps["cw"],
        cache_f16=cache_f16, interpret=bool(st.interpret))

    import jax
    import jax.numpy as jnp

    cls_edge = np.where(
        sizes <= 1, np.int64(0),
        np.ceil(np.log2(np.maximum(sizes, 2))).astype(np.int64))
    consts = dict(
        adj_indptr=jnp.asarray(st.adj[0].astype(np.int32)),
        adj_indices=jnp.asarray(st.adj[1].astype(np.int32)),
        v2e_indptr=jnp.asarray(hg.v2e_indptr.astype(np.int32)),
        v2e_indices=jnp.asarray(hg.v2e_indices.astype(np.int32)),
        e2v_indptr=jnp.asarray(hg.e2v_indptr.astype(np.int32)),
        e2v_indices=jnp.asarray(hg.e2v_indices.astype(np.int32)),
        cls_edge=jnp.asarray(cls_edge.astype(np.int32)),
        deg=jnp.asarray(st.deg.astype(np.int32)),
        vdeg=jnp.asarray(vdeg.astype(np.int32)),
        targets=jnp.asarray(targets.astype(np.int32)),
        rand_order=jnp.asarray(st.rand_order.astype(np.int32)),
        fringe=jnp.full((k, 1), -1, jnp.int32),
    )
    try:
        run = device_loop.device_loop_program(cfg)
        carry = {kk: jnp.asarray(vv) for kk, vv in carry_np.items()}
    except Exception as exc:
        if membudget.is_oom_error(exc):
            raise membudget.DeviceOOM(
                f"device loop image upload failed: {exc!r}",
                rung=st.mem_rung) from exc
        raise
    st.stats.loop_state_bytes = device_loop.carry_bytes(carry_np)
    st.stats.device_image_bytes = int(
        sum(int(v.nbytes) for v in consts.values())) + \
        st.stats.loop_state_bytes

    def _snapshot_payload(carry_dev):
        return {"carry": {kk: np.asarray(vv)
                          for kk, vv in carry_dev.items()},
                "caps": dict(caps),
                "stats": dataclasses.replace(st.stats)}

    last_snap = int(carry_np["supersteps"])
    last_known = st.assignment.copy()
    t_wall0 = _time.perf_counter()
    host_accum = 0.0
    try:
        while True:
            t_host = _time.perf_counter()
            ss_now = int(np.asarray(carry["supersteps"]))
            acc_h = np.asarray(carry["acc"]).astype(np.int64)
            if snap_every and ss_now - last_snap >= snap_every:
                t0 = _time.perf_counter()
                st.stats.snapshots += 1
                resilience.save_snapshot(
                    p.snapshot_dir,
                    resilience.PartitionCheckpoint(
                        engine, ss_now, hg.fingerprint(), dict(config),
                        _snapshot_payload(carry)),
                    keep_last=int(p.keep_last))
                st.stats.snapshot_s += _time.perf_counter() - t0
                last_snap = ss_now
                last_known = np.asarray(carry["assign"]).copy()
            if (acc_h >= targets).all():
                break
            if int(np.asarray(carry["progress"])) == 0:
                break   # starved: stragglers sit in other pools
            cap = chunk_max
            if snap_every:
                cap = min(cap, snap_every - (ss_now - last_snap))
            cap = max(1, cap)
            _device_probe_faults(st, ss_now + 1, ss_now + cap)
            poison_at = _device_probe_nan(st, ss_now + 1, ss_now + cap)
            if poison_at > 0:
                cap = poison_at - ss_now    # poisoned step ends chunk
            host_accum += _time.perf_counter() - t_host
            t_dev = _time.perf_counter()
            try:
                carry = run(consts, carry, jnp.int32(cap),
                            jnp.int32(poison_at))
                flags = int(np.asarray(carry["flags"]))   # blocks
            except Exception as exc:
                if membudget.is_oom_error(exc):
                    raise membudget.DeviceOOM(
                        f"device loop chunk failed: {exc!r}",
                        rung=st.mem_rung) from exc
                raise
            st.stats.device_s += _time.perf_counter() - t_dev
            st.stats.loop_chunks += 1
            if flags:
                if flags & device_loop.FLAG_POISON:
                    raise resilience.UnrecoverableFault(
                        "superstep still poisoned after a clean "
                        "replay: the kernel emits non-finite scores "
                        "for finite inputs")
                return ("overflow", flags, caps)
    except membudget.DeviceOOM as exc:
        if exc.rung is None:
            exc.rung = int(st.mem_plan.rung)
        exc.partial = last_known
        raise
    st.stats.host_s += host_accum

    # final download + host mirror
    st.assignment = np.asarray(carry["assign"]).astype(np.int32,
                                                       copy=True)
    acc = np.asarray(carry["acc"]).astype(np.int64)
    dstats = np.asarray(carry["stats"]).astype(np.int64)
    st.stats.supersteps = int(np.asarray(carry["supersteps"]))
    st.stats.kernel_calls += st.stats.supersteps
    st.stats.loop_rounds += int(dstats[device_loop.S_ROUNDS])
    st.stats.loop_pack_only += int(dstats[device_loop.S_PACK_ONLY])
    st.stats.loop_store_peak = max(
        st.stats.loop_store_peak,
        int(dstats[device_loop.S_STORE_PEAK]))
    st.stats.refill_signals += int(dstats[device_loop.S_REFILL])
    st.stats.kernel_rows += int(dstats[device_loop.S_KERNEL_ROWS])
    st.stats.edges_scanned += int(dstats[device_loop.S_EDGES_SCANNED])
    st.stats.cache_invalidations += int(dstats[device_loop.S_CACHE_INV])
    st.stats.cache_hits += int(dstats[device_loop.S_CACHE_HITS])
    st.stats.random_restarts += int(dstats[device_loop.S_RESTARTS])
    st.stats.stale_redraws += int(dstats[device_loop.S_STALE])
    st.stats.retries += int(dstats[device_loop.S_RETRIES])
    # safety net: balance-fill any stragglers into underfull phases
    rem_v = np.flatnonzero(st.assignment < 0)
    if rem_v.size:
        deficit = np.maximum(targets - acc, 0)
        fill = np.repeat(np.arange(k), deficit)[:rem_v.size]
        st.assignment[rem_v[:fill.size]] = fill.astype(np.int32)
    st.in_pool[:] = False
    obs = membudget.observed_peak_bytes()
    st.stats.peak_bytes_observed = (int(obs) if obs else
                                    int(st.stats.peak_bytes_planned))
    del t_wall0
    return ("ok", st.assignment, st)


def _run_device_loop(hg: Hypergraph, k: int, p: DeviceParams):
    """Run the §4i device loop with the capacity-doubling rerun ladder.

    Returns ``(assignment, st)`` or ``(None, None)`` for the caller's
    engine fallback. A rerun with doubled caps replays bit-identically
    (the superstep schedule is capacity-independent); FLAG_SEQ —
    per-phase sequence-space exhaustion — has no doubling answer and
    falls back.
    """
    caps_over: dict = {}
    for _ in range(5):
        kind, a, b = _device_attempt(hg, k, p, caps_over)
        if kind == "ok":
            return a, b
        if kind == "fallback":
            return None, None
        flags, caps = a, b
        if flags & device_loop.FLAG_SEQ:
            return None, None
        if flags & device_loop.FLAG_STORE:
            caps_over["sp"] = 2 * caps["sp"]
        if flags & device_loop.FLAG_ACT:
            caps_over["act"] = 2 * caps["act"]
        if flags & device_loop.FLAG_RAWT:
            caps_over["rawt"] = 2 * caps["rawt"]
        if flags & device_loop.FLAG_RAWD:
            caps_over["rawd"] = 2 * caps["rawd"]
    return None, None


def hype_device_partition(hg: Hypergraph, k: int,
                          params: Optional[DeviceParams] = None,
                          return_stats: bool = False):
    """Partition ``hg`` with the fully device-resident loop (§4i).

    The entire k-way growth loop — pool maintenance, store draws,
    scoring, admission, exact cache decrements, restarts — runs as one
    ``lax.while_loop`` program on device; the host uploads the graph
    image once and downloads a few scalars per chunk of supersteps.
    Bit-identical to ``hype_superstep_partition`` at
    ``pipeline_depth=1`` with matching knobs. Falls back to
    ``hype_superstep_partition`` when the int32 encoding gates or the
    memory plan reject the graph, and down the §4g rung ladder (via the
    host pipeline) on device OOM.
    """
    if params is None:
        params = DeviceParams()
    if params.rows is None:
        params = dataclasses.replace(params, rows=max(8, params.t))
    if k < 1:
        raise ValueError("k must be >= 1")
    if params.t < 1 or params.rows < 1 or params.pool_cap < 1:
        raise ValueError("rows, pool_cap, t must all be >= 1")
    if int(getattr(params, "chunk_supersteps", 64)) < 1:
        raise ValueError("chunk_supersteps must be >= 1")
    if getattr(params, "cache_dtype", "float32") not in (
            "float32", "float16"):
        raise ValueError("cache_dtype must be float32 or float16")
    if params.snapshot_every > 0 and not params.snapshot_dir:
        raise ValueError("snapshot_every requires snapshot_dir")
    if k == 1:
        out = np.zeros(hg.n, dtype=np.int32)
        return (out, BatchedStats()) if return_stats else out
    fplan = resilience.resolve_fault_plan(params.fault_plan)
    if fplan is not None:
        params = dataclasses.replace(params, fault_plan=fplan)
    try:
        assignment, st = _run_device_loop(hg, k, params)
    except membudget.DeviceOOM as exc:
        # §4g: the device loop has no reduced-memory program variants —
        # fall down the host pipeline's rung ladder, warm-started from
        # the chunk boundary the failed attempt last synced. The ladder
        # keeps this engine's lock-step cadence (pipeline_depth=1): an
        # upload-time OOM then reruns fresh and lands on the same
        # golden schedule the device loop would have produced
        params = dataclasses.replace(params, pipeline_depth=1)
        rung = 1 if exc.rung is None else int(exc.rung) + 1
        warm = (exc.partial if exc.partial is not None
                and (np.asarray(exc.partial) >= 0).any() else None)
        retries = 1
        while True:
            try:
                assignment, pst = _run_pipeline(
                    hg, k, params, mem_rung=rung, mem_warm=warm,
                    mem_retries=retries)
                break
            except membudget.DeviceOOM as exc2:
                retries += 1
                rung = (rung if exc2.rung is None
                        else int(exc2.rung)) + 1
                if (exc2.partial is not None
                        and (exc2.partial >= 0).any()):
                    warm = exc2.partial
            except membudget.MemoryLadderExhausted as exc2:
                raise resilience.UnrecoverableFault(
                    f"device memory rungs exhausted: {exc2}") from exc2
        if assignment is None:
            return hype_batched_partition(hg, k, params, return_stats)
        pst.stats.fallbacks += 1
        assert (assignment >= 0).all()
        assignment = _maybe_refine(hg, k, params, assignment, pst.stats)
        return (assignment, pst.stats) if return_stats else assignment
    if assignment is None:
        return hype_superstep_partition(hg, k, params, return_stats)
    assert (assignment >= 0).all()
    assignment = _maybe_refine(hg, k, params, assignment, st.stats)
    if return_stats:
        return assignment, st.stats
    return assignment


def hype_batched_partition(hg: Hypergraph, k: int,
                           params: Optional[BatchedParams] = None,
                           return_stats: bool = False):
    """Partition ``hg`` into ``k`` parts with batched-candidate HYPE.

    Same contract as ``hype_partition``: complete int32 assignment with
    perfectly balanced partition sizes (max - min <= 1).

    Resilience (DESIGN.md §4f): snapshots are phase-granular — between
    ``_grow_partition`` calls all transient state (score cache, pools,
    buckets) is empty, so a checkpoint is just the assignment plus edge
    flags and the random stream; resuming a same-config snapshot
    continues bit-identically, and a cross-engine snapshot (the
    degradation ladder) warm-starts every phase from its members.
    """
    if params is None:
        params = BatchedParams()
    if k < 1:
        raise ValueError("k must be >= 1")
    if params.t < 1 or params.b < 1 or params.s < 1:
        raise ValueError("b, s, t must all be >= 1")
    if params.pool_cap < 1:
        raise ValueError("pool_cap must be >= 1")
    if params.snapshot_every > 0 and not params.snapshot_dir:
        raise ValueError("snapshot_every requires snapshot_dir")
    st = _BatchedState(hg, k, params)
    n = hg.n
    base, rem = divmod(n, k)
    snap_every = max(0, int(params.snapshot_every or 0))
    config = {"k": k, "t": params.t, "b": params.b, "s": params.s,
              "pool_cap": params.pool_cap, "refill_lo": params.refill_lo,
              "cap_pins": params.cap_pins,
              "kernel_min": params.kernel_min, "seed": params.seed,
              "snapshot_every": snap_every}
    start = 0
    warm = False
    ckpt = (resilience.load_latest(params.resume) if params.resume
            else None)
    if ckpt is not None:
        t0 = time.perf_counter()
        resilience.check_checkpoint(ckpt, hg, k)
        if ckpt.engine == "hype_batched" and ckpt.config == config:
            pay = ckpt.payload
            st.assignment = pay["assignment"].copy()
            st.edge_dead = pay["edge_dead"].copy()
            st.edge_epoch = pay["edge_epoch"].copy()
            st.rand_ptr = int(pay["rand_ptr"])
            st.rng.bit_generator.state = pay["rng_state"]
            st.stats = dataclasses.replace(pay["stats"])
            start = int(pay["next_phase"])
        else:
            wa = resilience.warm_assignment(ckpt)
            got = wa >= 0
            st.assignment[got] = wa[got]
            warm = True
        st.stats.resumed_at = int(ckpt.superstep)
        st.stats.restore_s += time.perf_counter() - t0
    last_snap = start
    for i in range(start, k):
        if i == k - 1:
            rem_v = np.flatnonzero(st.assignment < 0)
            st.assignment[rem_v] = i
            st.in_fringe[:] = False
            break
        _grow_partition(st, i, base + (1 if i < rem else 0), warm=warm)
        if snap_every and i + 1 - last_snap >= snap_every:
            t0 = time.perf_counter()
            st.stats.snapshots += 1
            resilience.save_snapshot(
                params.snapshot_dir,
                resilience.PartitionCheckpoint(
                    "hype_batched", i + 1, hg.fingerprint(),
                    dict(config),
                    {"assignment": st.assignment.copy(),
                     "edge_dead": st.edge_dead.copy(),
                     "edge_epoch": st.edge_epoch.copy(),
                     "rand_ptr": int(st.rand_ptr),
                     "rng_state": st.rng.bit_generator.state,
                     "stats": dataclasses.replace(st.stats),
                     "next_phase": i + 1}),
                keep_last=int(params.keep_last))
            st.stats.snapshot_s += time.perf_counter() - t0
            last_snap = i + 1
    assert (st.assignment >= 0).all()
    assignment = _maybe_refine(hg, k, params, st.assignment, st.stats)
    if return_stats:
        return assignment, st.stats
    return assignment
