"""Batched-candidate HYPE: the throughput-oriented engine (DESIGN.md §4).

The paper's engine (``hype.py``) moves ONE vertex per growth step and
scores r=2 candidates at a time — latency-bound, CPU-idiomatic. This
engine turns the inner loop into tile work:

  per growth step
    1. (when the candidate pool runs low) draw a bulk batch of candidate
       vertices from the *smallest* active hyperedges — size-bucketed
       queues instead of a heap, one vectorized pin scan per draw,
    2. gather their unassigned-neighbor lists as dense (b, L) tiles
       (``scoring.neighbor_tile_adj``; assigned pins dropped, hubs
       capped),
    3. score every cache-miss candidate through the Pallas
       ``hype_scores`` kernel (fringe membership subtracted on the VPU),
    4. keep scored candidates in a pool sorted by score — the paper's
       s-sized fringe is its top-s — and admit the top-``t`` per step.

``t`` is the quality/speed knob: steps per partition drop from O(target)
to O(target / t); ``t=1`` recovers the sequential admission order (same
greedy rule, wider candidate pool). Scores are lazily cached per phase
exactly like the paper's optimization (c), so the kernel only sees
first-time candidates.

This is the first real consumer of ``kernels/hype_score`` — on CPU the
kernel runs in interpret mode (still one fused batched evaluation); on
TPU the same call compiles to the VPU tile loop the kernel was built for.

The module holds the top three rungs of the engine ladder (DESIGN.md §1):
``hype_batched_partition`` (host tiles), ``hype_superstep_partition``
(device-resident image, §4b) and ``hype_sharded_partition`` (phase
groups sharded over a device mesh, §4c).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import numpy as np

from .hypergraph import Hypergraph
from . import scoring


@dataclasses.dataclass
class BatchedParams:
    b: int = 256           # rows per kernel tile (the paper's r=2)
    s: int = 16            # max fringe size (kernel compares vs s slots)
    t: int = 8             # admissions per step; 1 = sequential order
    pool_cap: int = 64     # scored candidates held between steps
    refill_lo: int = 64    # refill the pool when it drops below this
    cap_pins: int = 3072   # pins scanned per candidate before truncation
    kernel_min: int = 16   # min batch worth a device round-trip; smaller
    #                        dribbles score on host (same formula and hub
    #                        truncation convention as the kernel tiles)
    seed: int = 0


@dataclasses.dataclass
class BatchedStats:
    kernel_calls: int = 0
    kernel_rows: int = 0       # candidate rows scored by the Pallas kernel
    host_rows: int = 0         # rows scored by the numpy fallback
    cache_hits: int = 0
    edges_scanned: int = 0     # pins scanned during candidate selection
    random_restarts: int = 0
    steps: int = 0
    # superstep-engine counters (zero for the classic batched path):
    supersteps: int = 0             # fused device calls
    device_image_bytes: int = 0     # one-time CSR + assignment + cache
    #                                 upload at partition() start
    host_to_device_bytes: int = 0   # per-call id/bias buffers — the whole
    #                                 steady-state H2D traffic
    cache_invalidations: int = 0    # cached scores decremented by admission
    # sharded-engine counters (zero for the single-device engines):
    collectives: int = 0            # all_gather ops (one per superstep)
    collective_bytes: int = 0       # bytes materialized by the gathers:
    #                                 devices x global payload per superstep
    admission_conflicts: int = 0    # proposed admissions lost to the
    #                                 lowest-phase-wins conflict rule


class _BatchedState:
    """Mutable state for the k growth phases (host side, all numpy)."""

    def __init__(self, hg: Hypergraph, k: int, p: BatchedParams):
        self.hg = hg
        self.k = k
        self.p = p
        n, m = hg.n, hg.m
        self.assignment = np.full(n, -1, dtype=np.int32)
        self.in_fringe = np.zeros(n, dtype=bool)
        self.in_pool = np.zeros(n, dtype=bool)     # fringe ∪ held candidates
        self.cur_fringe = np.empty(0, dtype=np.int64)
        self.cache = np.full(n, -1.0)
        self.edge_sizes = np.asarray(hg.edge_sizes, dtype=np.int64)
        self.edge_epoch = np.full(m, -1, dtype=np.int32)   # activation epoch
        self.edge_dead = self.edge_sizes == 0              # no live pins left
        # size-bucketed active-edge queues (replaces the paper's min-heap):
        # buckets[size] is a FIFO of edge-id arrays; scanning pops from the
        # front and re-queues still-live edges at the front, so smallest
        # edges keep being drawn first, like the heap's requeue.
        self.buckets: dict = {}
        self.rng = np.random.default_rng(p.seed)
        self.rand_order = self.rng.permutation(n)
        self.rand_ptr = 0
        self.stats = BatchedStats()
        self._fringe_buf = np.full(p.s, -1, dtype=np.int32)
        # One-time unique-neighbor CSR (memoized on hg): turns every tile
        # build into a pure gather. None for pathological hub expansions —
        # scoring then falls back to per-batch dedup with cap_pins.
        self.adj = hg.vertex_adjacency()

    # ------------------------------------------------------------------ #
    def random_unassigned(self, count: int = 1,
                          in_pool: Optional[np.ndarray] = None
                          ) -> np.ndarray:
        """Next ``count`` unassigned non-pool vertices of the random stream.

        Vectorized skip-pointer scan over the shuffled order; the pointer
        only advances past consumed positions so no vertex is skipped.
        ``in_pool`` selects which pool-membership mask to respect (the
        sharded engine keeps one per device group); default is the
        engine-wide mask.
        """
        if in_pool is None:
            in_pool = self.in_pool
        n = self.hg.n
        out: list = []
        got = 0
        while self.rand_ptr < n and got < count:
            chunk = self.rand_order[self.rand_ptr:
                                    self.rand_ptr + max(1024, count)]
            ok = np.flatnonzero((self.assignment[chunk] < 0)
                                & ~in_pool[chunk])
            if ok.size >= count - got:
                ok = ok[:count - got]
                self.rand_ptr += int(ok[-1]) + 1
            else:
                self.rand_ptr += chunk.size
            take = chunk[ok].astype(np.int64)
            got += take.size
            if take.size:
                out.append(take)
        if got < count:     # stream exhausted; the stragglers sit earlier
            rem = np.flatnonzero((self.assignment < 0) & ~in_pool)
            if out:
                rem = np.setdiff1d(rem, np.concatenate(out),
                                   assume_unique=True)
            if rem.size:
                out.append(rem[:count - got].astype(np.int64))
        return (np.concatenate(out) if out
                else np.empty(0, dtype=np.int64))

    def set_fringe(self, new_fringe: np.ndarray) -> None:
        """Sync the s-sized fringe view (paper's F) used for scoring."""
        self.in_fringe[self.cur_fringe] = False
        self.in_fringe[new_fringe] = True
        self.cur_fringe = new_fringe
        self._fringe_buf[:] = -1
        self._fringe_buf[:new_fringe.size] = new_fringe

    # ------------------------------------------------------------------ #
    def activate(self, vs: np.ndarray, phase: int) -> None:
        """Mark the edges incident to newly admitted vertices active."""
        edges, _ = scoring.gather_csr_rows(
            self.hg.v2e_indptr, self.hg.v2e_indices, vs)
        if edges.size == 0:
            return
        edges = np.unique(edges.astype(np.int64))
        fresh = edges[(self.edge_epoch[edges] != phase)
                      & ~self.edge_dead[edges]]
        if fresh.size == 0:
            return
        self.edge_epoch[fresh] = phase
        sizes = self.edge_sizes[fresh]
        for sz in np.unique(sizes):
            self.buckets.setdefault(int(sz), collections.deque()).append(
                fresh[sizes == sz])

    # ------------------------------------------------------------------ #
    def draw_candidates(self, need: int,
                        buckets: Optional[dict] = None,
                        in_pool: Optional[np.ndarray] = None) -> np.ndarray:
        """Up to ``need`` distinct universe vertices from smallest edges.

        One vectorized pass: pull edges smallest-size-first under a pin
        budget, scan all their pins at once, retire dead edges (no
        unassigned pin left — forever), requeue the still-live ones at the
        bucket fronts so they are rescanned first next time (the heap's
        requeue, without the heap). ``buckets`` selects which active-edge
        queues to draw from (the superstep engine keeps one dict per
        concurrently growing phase); default is the single shared dict.
        ``in_pool`` selects the pool-membership mask that filters
        already-held candidates (the sharded engine keeps one per device
        group, so groups draw independently — by design they may overlap,
        which is what the admission conflict rule resolves).
        """
        if buckets is None:
            buckets = self.buckets
        if in_pool is None:
            in_pool = self.in_pool
        if need <= 0:
            return np.empty(0, dtype=np.int64)
        budget = max(4 * need, 512)
        batches: list = []
        keys: list = []     # (source bucket key, count) pairs, for requeues
        pulled = 0
        for sz in sorted(buckets.keys()):
            q = buckets[sz]
            while q and pulled < budget:
                arr = q.popleft()
                n_take = (budget - pulled + sz - 1) // max(sz, 1)
                if arr.size > n_take:
                    q.appendleft(arr[n_take:])
                    arr = arr[:n_take]
                batches.append(arr)
                keys.append((sz, arr.size))
                pulled += arr.size * max(sz, 1)
            if not q:
                del buckets[sz]
            if pulled >= budget:
                break
        if not batches:
            return np.empty(0, dtype=np.int64)
        edges = np.concatenate(batches)
        pins, prow = scoring.gather_csr_rows(
            self.hg.e2v_indptr, self.hg.e2v_indices, edges)
        pins = pins.astype(np.int64)
        self.stats.edges_scanned += pins.size
        unassigned = self.assignment[pins] < 0
        live = np.bincount(prow[unassigned], minlength=edges.size) > 0
        if not live.all():
            self.edge_dead[edges[~live]] = True     # dead forever
        live_edges = edges[live]
        if live_edges.size:
            # requeue under the key each edge was drawn from, so the
            # caller's key scheme (exact sizes for the classic engine,
            # power-of-two classes for the superstep engine) is preserved
            lkey = np.repeat([k for k, _ in keys],
                             [c for _, c in keys])[live]
            for s in np.unique(lkey):
                buckets.setdefault(
                    int(s), collections.deque()).appendleft(
                        live_edges[lkey == s])
        fresh = unassigned & ~in_pool[pins]
        cand = pins[fresh]
        if cand.size:
            _, first = np.unique(cand, return_index=True)
            cand = cand[np.sort(first)][:need]
        return cand

    # ------------------------------------------------------------------ #
    def score_misses(self, cand: np.ndarray) -> None:
        """Score cache-miss candidates in one batched pass, fill the cache.

        Large batches (every phase opening, where the bulk of the scoring
        lives) go through the Pallas ``hype_scores`` kernel as one (b, L)
        tile; dribbles below ``kernel_min`` rows are scored by the exact
        same formula on host, because a device round-trip per 2-3 rows is
        precisely the latency-bound pattern this engine exists to avoid.
        """
        if cand.size == 0:
            return
        miss = cand[self.cache[cand] < 0.0]
        self.stats.cache_hits += cand.size - miss.size
        if miss.size == 0:
            return
        if miss.size >= self.p.kernel_min:
            import jax.numpy as jnp
            from repro.kernels.hype_score.ops import hype_scores

            fringe_dev = jnp.asarray(self._fringe_buf)
            for lo in range(0, miss.size, self.p.b):
                chunk = miss[lo:lo + self.p.b]
                # two B buckets (64 / b) keep retraces rare while small
                # top-up batches avoid paying for a full-width tile
                pad_b = 64 if chunk.size <= 64 else self.p.b
                if self.adj is not None:
                    tile, truncated = scoring.neighbor_tile_adj(
                        self.adj, chunk, self.assignment, pad_b=pad_b)
                else:
                    tile, truncated = scoring.neighbor_tile(
                        self.hg, chunk, self.assignment,
                        cap_pins=self.p.cap_pins, pad_b=pad_b)
                out = np.asarray(hype_scores(jnp.asarray(tile), fringe_dev))
                sc = out[:chunk.size].astype(np.float64)
                sc[truncated] += scoring.TRUNC_PENALTY
                self.cache[chunk] = sc
                self.stats.kernel_calls += 1
                self.stats.kernel_rows += int(chunk.size)
        else:
            if self.adj is not None:
                sc = scoring.batched_dext_adj(
                    self.adj, miss, self.in_fringe, self.assignment)
            else:
                sc = scoring.batched_dext_numpy(
                    self.hg, miss, self.in_fringe, self.assignment,
                    cap_pins=self.p.cap_pins,
                    max_width=scoring.L_BUCKETS[-1])
            self.stats.host_rows += int(miss.size)
            self.cache[miss] = sc


def _grow_partition(st: _BatchedState, phase: int, target: int) -> None:
    """Grow core set ``phase`` to ``target`` vertices.

    The step loop keeps a *pool* of up to ``pool_cap`` scored candidates
    sorted by cached score. Refills happen in bulk (one kernel tile per
    ``b`` rows) whenever the pool runs low; between refills a step is just
    "admit the t best, queue their edges" — the latency-bound per-vertex
    machinery of the sequential engines is gone entirely. The paper's
    s-sized fringe survives as the top-s of the pool: it is what the
    scoring kernel subtracts, exactly like F in Eq. 1.
    """
    p = st.p
    st.cache[:] = -1.0
    st.buckets = {}
    pool = np.empty(0, dtype=np.int64)       # kept sorted by score asc
    pending: list = []                       # admitted, edges not yet queued

    seeds = st.random_unassigned(1)
    if seeds.size == 0:
        return
    st.assignment[seeds] = phase
    st.activate(seeds, phase)
    acc = 1

    while acc < target:
        st.stats.steps += 1
        # ------- refill: bulk-draw and kernel-score new candidates -------
        if pool.size < max(p.t, p.refill_lo):
            if pending:
                st.activate(np.concatenate(pending), phase)
                pending = []
            cand = st.draw_candidates(p.pool_cap - pool.size)
            if cand.size:
                st.score_misses(cand)
                st.in_pool[cand] = True
                pool = np.concatenate([pool, cand])
                pool = pool[np.argsort(st.cache[pool], kind="stable")]
                st.set_fringe(pool[:p.s])
        if pool.size == 0:                    # random restart (batched: on
            # shattered remainders each isolated vertex would otherwise
            # cost a full step, so seed up to t fresh growth points)
            vs = st.random_unassigned(p.t)
            if vs.size == 0:
                return
            st.stats.random_restarts += 1
            pool = vs
            st.in_pool[vs] = True
            st.cache[vs] = 0.0
            st.set_fringe(pool[:p.s])
        # ------- core update: admit the t best pool vertices -------
        nt = min(p.t, target - acc, pool.size)
        admit, pool = pool[:nt], pool[nt:]
        st.assignment[admit] = phase
        st.in_pool[admit] = False
        pending.append(admit)
        st.set_fringe(pool[:p.s])
        acc += int(admit.size)

    # release fringe + pool back to the universe (§III-B1 step 4)
    st.set_fringe(np.empty(0, dtype=np.int64))
    st.in_pool[pool] = False


# --------------------------------------------------------------------- #
# Superstep engine: device-resident, multi-phase, cross-phase cache.
# --------------------------------------------------------------------- #

@dataclasses.dataclass
class SuperstepParams(BatchedParams):
    """Knobs for the superstep engine (DESIGN.md §4).

    Inherits the batched knobs; ``t`` (admissions per phase per
    superstep), ``s``, ``pool_cap`` and ``seed`` keep their meaning.
    ``b``/``kernel_min``/``refill_lo`` are unused — refills are sized by
    ``rows`` and every score goes through the fused device call.
    """
    # fresh candidate rows per phase per superstep; None = max(8, t) so
    # refills keep up with the admission drain at any t
    rows: Optional[int] = None


class _SuperstepState(_BatchedState):
    """Adds the device-resident graph image and per-phase growth state.

    The host keeps only ids and flags (assignment mirror, pool id lists,
    per-phase active-edge buckets, a has-been-scored bitmask); every
    *score* lives in the device cache and is maintained exactly by the
    decrement rule in ``scoring.superstep_device`` — no per-phase wipe.
    """

    def __init__(self, hg: Hypergraph, k: int, p: SuperstepParams,
                 mesh=None):
        super().__init__(hg, k, p)
        self.dev = hg.device_adjacency(mesh=mesh)
        if self.dev is None:       # hub-expansion guard tripped on host
            return
        import jax
        import jax.numpy as jnp

        n, m = hg.n, hg.m
        self.interpret = jax.default_backend() != "tpu"
        self.dev_assign = jnp.full((n,), -1, jnp.int32)
        self.dev_cache = jnp.full((n,), -1.0, jnp.float32)
        if mesh is not None:       # replicate the mutable image too
            from jax.sharding import NamedSharding, PartitionSpec
            rep = NamedSharding(mesh, PartitionSpec())
            self.dev_assign = jax.device_put(self.dev_assign, rep)
            self.dev_cache = jax.device_put(self.dev_cache, rep)
        self.cache_scored = np.zeros(n, dtype=bool)
        self.pools = [np.empty(0, dtype=np.int64) for _ in range(k)]
        self.phase_buckets: list = [dict() for _ in range(k)]
        self.edge_queued = np.zeros((k, m), dtype=bool)
        self.delta_ids: list = []
        self.delta_vals: list = []
        deg = np.diff(self.adj[0])
        self.deg = deg
        # One gather-width per run: every distinct shape retraces the
        # whole jitted superstep program (~0.5-1s in interpret mode), and
        # padding a gather is far cheaper than a retrace. The tile width
        # is the bucket of the 99.5th-percentile degree — the handful of
        # rows wider than that are truncated and carry the hub penalty
        # (they'd compare as "huge neighborhood" anyway). The dirty-pair
        # pad is pre-sized from the expected per-superstep dirty rate and
        # only ratchets up (monotone -> at most a couple of traces).
        self.tile_l = scoring._bucket_width(int(min(
            np.percentile(deg, 99.5) if deg.size else 1,
            scoring.L_BUCKETS[-1])))
        mean_deg = self.adj[1].size / max(hg.n, 1)
        expect = min(hg.n, max(256, int(2 * k * p.t * mean_deg)))
        self._dirty_ratchet = 1 << int(np.ceil(np.log2(expect + 1)))
        self.stats.device_image_bytes = int(
            self.dev[0].nbytes + self.dev[1].nbytes
            + self.dev_assign.nbytes + self.dev_cache.nbytes)

    # ------------------------------------------------------------------ #
    def assign_now(self, vs: np.ndarray, phase: int) -> None:
        """Assign ``vs`` to ``phase``; queue the device delta + dirtying."""
        vs = np.asarray(vs, dtype=np.int64)
        self.assignment[vs] = phase
        self.in_pool[vs] = False
        self.delta_ids.append(vs)
        self.delta_vals.append(np.full(vs.size, phase, dtype=np.int32))

    def activate_phase(self, vs: np.ndarray, phase: int) -> None:
        """Queue the edges incident to newly admitted vertices of a phase."""
        self.activate_many(np.asarray(vs, dtype=np.int64),
                           np.full(len(vs), phase, dtype=np.int64))

    def activate_many(self, vs: np.ndarray, phases: np.ndarray) -> None:
        """Queue incident edges for a whole superstep's admissions at once.

        ``vs``/``phases`` are parallel arrays; one CSR gather + one
        lexsort covers every (phase, edge) activation of the superstep
        instead of a per-phase python pass.
        """
        edges, owner = scoring.gather_csr_rows(
            self.hg.v2e_indptr, self.hg.v2e_indices, vs)
        if edges.size == 0:
            return
        edges = edges.astype(np.int64)
        ph = phases[owner]
        key = np.unique(ph * np.int64(self.hg.m) + edges)
        ph, edges = key // self.hg.m, key % self.hg.m
        live = ~self.edge_queued[ph, edges] & ~self.edge_dead[edges]
        ph, edges = ph[live], edges[live]
        if edges.size == 0:
            return
        self.edge_queued[ph, edges] = True
        # power-of-two size classes instead of exact sizes: smallest-first
        # drawing is a heuristic, and ~12 classes keep the number of
        # (phase, class) groups — hence python-level queue churn — small.
        sizes = self.edge_sizes[edges]
        cls = np.where(
            sizes <= 1, np.int64(1),
            np.int64(1) << np.ceil(
                np.log2(np.maximum(sizes, 2))).astype(np.int64))
        order = np.lexsort((cls, ph))
        ph, edges, cls = ph[order], edges[order], cls[order]
        cuts = np.flatnonzero((np.diff(ph) != 0)
                              | (np.diff(cls) != 0)) + 1
        starts = np.concatenate([[0], cuts])
        for start, grp in zip(starts, np.split(edges, cuts)):
            self.phase_buckets[int(ph[start])].setdefault(
                int(cls[start]), collections.deque()).append(grp)

    def take_delta(self, cap: int):
        """Drain up to ``cap`` queued (id, phase) assignment pairs."""
        if not self.delta_ids:
            return (np.empty(0, dtype=np.int64),
                    np.empty(0, dtype=np.int32))
        ids = np.concatenate(self.delta_ids)
        vals = np.concatenate(self.delta_vals)
        if ids.size <= cap:
            self.delta_ids, self.delta_vals = [], []
            return ids, vals
        self.delta_ids = [ids[cap:]]
        self.delta_vals = [vals[cap:]]
        return ids[:cap], vals[:cap]

    def _pack_delta_dirty(self, delta_cap, extra_dirty=()):
        """Drain queued assignments into the padded device buffers.

        Pre-aggregates the dirtied-neighbor multiset of the drained
        delta — one CSR gather + bincount, shipped as (unique id, count)
        pairs padded to a power-of-two bucket (bounded retraces,
        O(unique) device scatter). ``extra_dirty`` merges additional raw
        neighbor-id arrays into the multiset (the sharded engine's
        queued decrement tails). Returns ``(delta, vals, dirty, dcnt)``;
        shared by both device engines so their cache-exactness
        bookkeeping cannot drift apart.
        """
        d_ids, d_vals = self.take_delta(delta_cap)
        delta = np.full(delta_cap, -1, dtype=np.int32)
        vals = np.zeros(delta_cap, dtype=np.int32)
        delta[:d_ids.size] = d_ids
        vals[:d_ids.size] = d_vals
        nbrs, _ = scoring.gather_csr_rows(self.adj[0], self.adj[1], d_ids)
        parts = list(extra_dirty)
        if nbrs.size:
            parts.append(nbrs.astype(np.int64))
        if parts:
            counts = np.bincount(np.concatenate(parts))
            uniq = np.flatnonzero(counts)
            self.stats.cache_invalidations += int(uniq.size)
        else:
            uniq = np.empty(0, dtype=np.int64)
            counts = np.empty(0, dtype=np.int64)
        cap = max(self._dirty_ratchet,
                  1 << int(np.ceil(np.log2(max(uniq.size, 1)))))
        self._dirty_ratchet = cap
        dirty = np.full(cap, -1, dtype=np.int32)
        dcnt = np.zeros(cap, dtype=np.float32)
        dirty[:uniq.size] = uniq
        dcnt[:uniq.size] = counts[uniq]
        return delta, vals, dirty, dcnt

    def superstep_call(self, fresh, bias, pool_arr, fringe, delta_cap,
                       select_k):
        """One fused device call; updates the device image in place."""
        delta, vals, dirty, dcnt = self._pack_delta_dirty(delta_cap)
        tile_l = self.tile_l
        self.stats.host_to_device_bytes += (
            fresh.nbytes + bias.nbytes + pool_arr.nbytes + fringe.nbytes
            + delta.nbytes + vals.nbytes + dirty.nbytes + dcnt.nbytes)
        self.stats.supersteps += 1
        self.stats.kernel_calls += 1
        self.dev_assign, self.dev_cache, sel_idx, sel_val = \
            scoring.superstep_device(
                self.dev[0], self.dev[1], self.dev_assign, self.dev_cache,
                delta, vals, dirty, dcnt, fresh, bias, pool_arr, fringe,
                tile_l=tile_l, select_k=select_k,
                interpret=self.interpret)
        return np.asarray(sel_idx), np.asarray(sel_val)


def _run_superstep(hg: Hypergraph, k: int, p: SuperstepParams):
    """Grow all ``k`` partitions concurrently; returns (assignment, state).

    Each *superstep* is one fused device call that scores the stacked
    fresh-candidate tiles of every growing phase and selects each phase's
    ``t`` admissions (paper §VI k-way growth on the fast engine).
    """
    from repro.kernels.hype_score.kernel import SELECT_PAD

    st = _SuperstepState(hg, k, p)
    if st.dev is None:
        return None, None                       # caller falls back
    n = hg.n
    base, rem = divmod(n, k)
    targets = base + (np.arange(k) < rem).astype(np.int64)
    acc = np.zeros(k, dtype=np.int64)
    R, P, t = p.rows, p.pool_cap, p.t
    delta_cap = max(2 * k * t, k)
    fringe = np.full((k, 1), -1, dtype=np.int32)   # fringe-free scoring

    # seed every phase with one random vertex (paper §III-B1 step 1)
    seeds = st.random_unassigned(int((targets > 0).sum()))
    gi = 0
    for g in range(k):
        if targets[g] == 0 or gi >= seeds.size:
            continue
        v = seeds[gi:gi + 1]
        gi += 1
        st.assign_now(v, g)
        st.activate_phase(v, g)
        acc[g] += 1

    while True:
        active = np.flatnonzero(acc < targets)
        if active.size == 0:
            break
        progress = 0
        fresh = np.full((k, R), -1, dtype=np.int32)
        bias = np.full((k, R), np.inf, dtype=np.float32)
        pool_arr = np.full((k, P), -1, dtype=np.int32)
        fresh_snap: list = [None] * k
        pool_snap: list = [None] * k
        # rotate the draw order so no phase always gets first pick
        rot = st.stats.supersteps % active.size
        for g in np.concatenate([active[rot:], active[:rot]]):
            ids = st.pools[g]
            need = min(R, P - ids.size)
            drawn = st.draw_candidates(need, st.phase_buckets[g]) \
                if need > 0 else np.empty(0, dtype=np.int64)
            miss = np.empty(0, dtype=np.int64)
            if drawn.size:
                st.in_pool[drawn] = True
                scored = st.cache_scored[drawn]
                hits, miss = drawn[scored], drawn[~scored]
                if hits.size:       # cross-phase reuse: already cached
                    st.stats.cache_hits += int(hits.size)
                    ids = np.concatenate([ids, hits])
                    st.pools[g] = ids
            if ids.size == 0 and miss.size == 0:
                # shattered remainder: seed fresh growth points directly
                vs = st.random_unassigned(
                    min(t, int(targets[g] - acc[g])))
                if vs.size:
                    st.stats.random_restarts += 1
                    st.assign_now(vs, g)
                    st.activate_phase(vs, g)
                    acc[g] += vs.size
                    progress += int(vs.size)
                continue
            fresh[g, :miss.size] = miss
            bias[g, :miss.size] = np.where(
                st.deg[miss] > st.tile_l, scoring.TRUNC_PENALTY, 0.0)
            pool_arr[g, :ids.size] = ids
            fresh_snap[g] = miss
            pool_snap[g] = ids
            st.stats.kernel_rows += int(miss.size)

        if any(f is not None for f in fresh_snap):
            sel_idx, sel_val = st.superstep_call(
                fresh, bias, pool_arr, fringe, delta_cap, select_k=t)
            adm_vs: list = []
            adm_ph: list = []
            for g in active:
                if fresh_snap[g] is None:
                    continue
                fr, ids = fresh_snap[g], pool_snap[g]
                st.cache_scored[fr] = True
                admit = []
                remaining = int(targets[g] - acc[g])
                for j in range(t):
                    if len(admit) >= remaining:
                        break
                    if sel_val[g, j] >= SELECT_PAD:
                        break       # sel_val ascending: nothing left
                    ii = int(sel_idx[g, j])
                    admit.append(fr[ii] if ii < R else ids[ii - R])
                merged = np.concatenate([ids, fr])
                if admit:
                    admit = np.asarray(admit, dtype=np.int64)
                    st.assign_now(admit, g)
                    # pool/fresh ids are exclusive to this phase, so the
                    # admitted ones are exactly the newly assigned ones
                    merged = merged[st.assignment[merged] < 0]
                    adm_vs.append(admit)
                    adm_ph.append(np.full(admit.size, g, dtype=np.int64))
                    acc[g] += admit.size
                    progress += int(admit.size)
                st.pools[g] = merged
                if acc[g] >= targets[g]:        # phase done: release pool
                    st.in_pool[st.pools[g]] = False
                    st.pools[g] = np.empty(0, dtype=np.int64)
            if adm_vs:      # one vectorized edge-activation pass
                st.activate_many(np.concatenate(adm_vs),
                                 np.concatenate(adm_ph))
        if progress == 0:
            break       # starved: remaining vertices sit in other pools

    # safety net: balance-fill any stragglers into underfull phases
    rem_v = np.flatnonzero(st.assignment < 0)
    if rem_v.size:
        deficit = np.maximum(targets - acc, 0)
        fill = np.repeat(np.arange(k), deficit)[:rem_v.size]
        for g in np.unique(fill):
            st.assign_now(rem_v[fill == g], g)
    st.in_pool[:] = False
    # the device image syncs at superstep boundaries only; the final
    # admissions' delta dies with the state (the host assignment is
    # authoritative). Tests needing device/host parity flush explicitly
    # through superstep_call.
    st.delta_ids, st.delta_vals = [], []
    return st.assignment, st


# --------------------------------------------------------------------- #
# Mesh-sharded superstep engine: phase groups sharded over a device mesh.
# --------------------------------------------------------------------- #

@dataclasses.dataclass
class ShardedParams(SuperstepParams):
    """Knobs for the mesh-sharded superstep engine (DESIGN.md §4c).

    Inherits every superstep knob. ``devices`` sets the 1-D mesh size the
    k phase groups are sharded over; ``None`` uses every local JAX device
    (capped at ``k``). On CPU, simulate a mesh with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
    """
    devices: Optional[int] = None


class _ShardedState(_SuperstepState):
    """Superstep state plus the mesh and per-device-group pool masks.

    The CSR image, assignment and score cache are *replicated* on every
    mesh device; the phase groups are sharded. Pool membership is
    tracked per device group (``group_pool``) — groups draw candidates
    independently, so two groups may pool (and propose) the same vertex;
    the device program's lowest-phase-wins rule resolves it, and the
    host mirrors winners without re-queuing them as deltas.
    """

    def __init__(self, hg: Hypergraph, k_padded: int, p: ShardedParams,
                 num_devices: int):
        self.D = num_devices
        self.kL = k_padded // num_devices
        mesh = scoring._sharded_mesh(num_devices)
        super().__init__(hg, k_padded, p, mesh=mesh)
        if self.dev is None:
            return
        self.mesh = mesh
        self.group_pool = np.zeros((num_devices, hg.n), dtype=bool)
        self.pending_dirty: list = []   # decrement tails of wide winners
        # the image lives once per device
        self.stats.device_image_bytes *= num_devices

    def group_of(self, g: int) -> int:
        return g // self.kL

    def sharded_call(self, fresh, bias, pool_arr, fringe, admit_cap,
                     delta_cap):
        """One mesh-sharded superstep; returns the (kG, t) winner ids.

        Host->device traffic is the same id/bias buffers as the
        single-device engine plus the admission caps; the host-side
        dirty pairs carry the injections' neighbor multisets *and* the
        decrement tails of last superstep's wider-than-tile winners
        (the device clips its own decrement gather at ``tile_l``), so
        the replicated cache stays exact.
        """
        tails = self.pending_dirty
        self.pending_dirty = []
        delta, vals, dirty, dcnt = self._pack_delta_dirty(
            delta_cap, extra_dirty=tails)
        admit_cap = np.asarray(admit_cap, dtype=np.int32)
        self.stats.host_to_device_bytes += (
            fresh.nbytes + bias.nbytes + pool_arr.nbytes + fringe.nbytes
            + delta.nbytes + vals.nbytes + dirty.nbytes + dcnt.nbytes
            + admit_cap.nbytes)
        self.stats.supersteps += 1
        self.stats.kernel_calls += 1
        kG, R = fresh.shape
        t = self.p.t
        # one all_gather per superstep: every device materializes the
        # global (kG, R + t) int32 payload of fresh scores + admissions
        self.stats.collectives += 1
        self.stats.collective_bytes += self.D * kG * (R + t) * 4
        self.dev_assign, self.dev_cache, winners, ncf = \
            scoring.sharded_superstep_device(
                self.dev[0], self.dev[1], self.dev_assign, self.dev_cache,
                delta, vals, dirty, dcnt, fresh, bias, pool_arr, fringe,
                admit_cap, num_devices=self.D, group_l=self.kL,
                tile_l=self.tile_l, select_k=t, interpret=self.interpret)
        winners = np.asarray(winners).astype(np.int64)
        self.stats.admission_conflicts += int(ncf)
        # exact-decrement invariant: queue the clipped tails of winners
        # wider than the device gather for the next superstep
        w = winners[winners >= 0]
        wide = w[self.deg[w] > self.tile_l]
        indptr, indices = self.adj
        for v in wide:
            self.pending_dirty.append(
                indices[indptr[v] + self.tile_l:indptr[v + 1]].astype(
                    np.int64))
        # the decrements the device performed itself
        if w.size:
            self.stats.cache_invalidations += int(
                np.minimum(self.deg[w], self.tile_l).sum())
        return winners


def _run_sharded(hg: Hypergraph, k: int, p: ShardedParams,
                 num_devices: int):
    """Grow all ``k`` partitions concurrently across the device mesh.

    Mirrors ``_run_superstep``; the differences are exactly the sharded
    semantics: phases are padded to ``num_devices`` equal groups, pool
    membership is per group (overlaps across groups are allowed and
    resolved by the device's lowest-phase-wins rule), admission caps are
    enforced on device, and the host mirrors the returned winners
    instead of selecting admissions itself.
    """
    kL = -(-k // num_devices)
    kG = kL * num_devices
    st = _ShardedState(hg, kG, p, num_devices)
    if st.dev is None:
        return None, None                       # caller falls back
    n = hg.n
    base, rem = divmod(n, k)
    targets = np.zeros(kG, dtype=np.int64)
    targets[:k] = base + (np.arange(k) < rem)
    acc = np.zeros(kG, dtype=np.int64)
    R, P, t = p.rows, p.pool_cap, p.t
    delta_cap = max(2 * kG * t, kG)
    fringe = np.full((kG, 1), -1, dtype=np.int32)   # fringe-free scoring

    seeds = st.random_unassigned(int((targets > 0).sum()))
    gi = 0
    for g in range(kG):
        if targets[g] == 0 or gi >= seeds.size:
            continue
        v = seeds[gi:gi + 1]
        gi += 1
        st.assign_now(v, g)
        st.activate_phase(v, g)
        acc[g] += 1

    while True:
        active = np.flatnonzero(acc < targets)
        if active.size == 0:
            break
        progress = 0
        fresh = np.full((kG, R), -1, dtype=np.int32)
        bias = np.full((kG, R), np.inf, dtype=np.float32)
        pool_arr = np.full((kG, P), -1, dtype=np.int32)
        fresh_snap: list = [None] * kG
        pool_snap: list = [None] * kG
        rot = st.stats.supersteps % active.size
        for g in np.concatenate([active[rot:], active[:rot]]):
            gp = st.group_pool[st.group_of(g)]
            ids = st.pools[g]
            if ids.size:        # other groups' winners may sit in here
                keep = st.assignment[ids] < 0
                if not keep.all():
                    gp[ids[~keep]] = False
                    ids = ids[keep]
                    st.pools[g] = ids
            need = min(R, P - ids.size)
            drawn = st.draw_candidates(need, st.phase_buckets[g],
                                       in_pool=gp) \
                if need > 0 else np.empty(0, dtype=np.int64)
            miss = np.empty(0, dtype=np.int64)
            if drawn.size:
                gp[drawn] = True
                scored = st.cache_scored[drawn]
                hits, miss = drawn[scored], drawn[~scored]
                if hits.size:   # cross-phase/-device reuse: cached
                    st.stats.cache_hits += int(hits.size)
                    ids = np.concatenate([ids, hits])
                    st.pools[g] = ids
            if ids.size == 0 and miss.size == 0:
                vs = st.random_unassigned(
                    min(t, int(targets[g] - acc[g])), in_pool=gp)
                if vs.size:
                    st.stats.random_restarts += 1
                    st.assign_now(vs, g)
                    st.activate_phase(vs, g)
                    acc[g] += vs.size
                    progress += int(vs.size)
                continue
            fresh[g, :miss.size] = miss
            bias[g, :miss.size] = np.where(
                st.deg[miss] > st.tile_l, scoring.TRUNC_PENALTY, 0.0)
            pool_arr[g, :ids.size] = ids
            fresh_snap[g] = miss
            pool_snap[g] = ids
            st.stats.kernel_rows += int(miss.size)

        if any(f is not None for f in fresh_snap):
            admit_cap = np.maximum(targets - acc, 0).astype(np.int32)
            winners = st.sharded_call(fresh, bias, pool_arr, fringe,
                                      admit_cap, delta_cap)
            adm_vs: list = []
            adm_ph: list = []
            for g in active:
                if fresh_snap[g] is None:
                    continue
                fr, ids = fresh_snap[g], pool_snap[g]
                st.cache_scored[fr] = True
                grp = st.group_of(g)
                w = winners[g]
                w = w[w >= 0]
                if w.size:      # mirror the device's admissions
                    st.assignment[w] = g
                    st.group_pool[grp][w] = False
                    acc[g] += w.size
                    progress += int(w.size)
                    adm_vs.append(w)
                    adm_ph.append(np.full(w.size, g, dtype=np.int64))
                merged = np.concatenate([ids, fr])
                keep = st.assignment[merged] < 0
                st.group_pool[grp][merged[~keep]] = False
                st.pools[g] = merged[keep]
                if acc[g] >= targets[g]:        # phase done: release pool
                    st.group_pool[grp][st.pools[g]] = False
                    st.pools[g] = np.empty(0, dtype=np.int64)
            if adm_vs:
                st.activate_many(np.concatenate(adm_vs),
                                 np.concatenate(adm_ph))
        if progress == 0:
            break       # starved: remaining vertices sit in other pools

    rem_v = np.flatnonzero(st.assignment < 0)
    if rem_v.size:
        deficit = np.maximum(targets - acc, 0)
        fill = np.repeat(np.arange(kG), deficit)[:rem_v.size]
        for g in np.unique(fill):
            st.assignment[rem_v[fill == g]] = np.int32(g)
    st.group_pool[:] = False
    st.delta_ids, st.delta_vals = [], []
    return st.assignment, st


def hype_sharded_partition(hg: Hypergraph, k: int,
                           params: Optional[ShardedParams] = None,
                           return_stats: bool = False):
    """Partition ``hg`` with the mesh-sharded superstep engine.

    Same contract as ``hype_superstep_partition`` (complete int32
    assignment, ``max - min <= 1`` vertex balance, all k phases grown
    concurrently) but the phase groups are sharded over a 1-D JAX device
    mesh with ``shard_map``: the CSR graph image, assignment vector and
    score cache are replicated per device, each device runs the fused
    ``hype_score_select`` superstep for its own contiguous phase group,
    and a single ``all_gather`` per superstep exchanges fresh scores and
    proposed admissions so every replica stays globally consistent —
    including the exact-decrement score-cache invalidations. Cross-device
    admission conflicts (two groups proposing the same vertex in one
    superstep) are resolved deterministically: the lowest phase id wins
    and losers redraw from their pools next superstep.

    ``params.devices`` picks the mesh size (default: all local devices,
    capped at ``k``); on CPU simulate devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``. With one
    device the engine degenerates to (slightly reordered) single-device
    superstep growth. Falls back to ``hype_superstep_partition``'s own
    fallback chain when the adjacency guard trips.
    """
    if params is None:
        params = ShardedParams()
    if params.rows is None:
        params = dataclasses.replace(params, rows=max(8, params.t))
    if k < 1:
        raise ValueError("k must be >= 1")
    if params.t < 1 or params.rows < 1 or params.pool_cap < 1:
        raise ValueError("rows, pool_cap, t must all be >= 1")
    if params.devices is not None and params.devices < 1:
        raise ValueError("devices must be >= 1")
    if k == 1:
        out = np.zeros(hg.n, dtype=np.int32)
        return (out, BatchedStats()) if return_stats else out
    import jax
    avail = len(jax.devices())
    num = params.devices if params.devices is not None else avail
    num = max(1, min(num, avail, k))
    assignment, st = _run_sharded(hg, k, params, num)
    if assignment is None:
        return hype_superstep_partition(hg, k, params, return_stats)
    assert (assignment >= 0).all()
    if return_stats:
        return assignment, st.stats
    return assignment


def hype_superstep_partition(hg: Hypergraph, k: int,
                             params: Optional[SuperstepParams] = None,
                             return_stats: bool = False):
    """Partition ``hg`` with the device-resident superstep engine.

    Same contract as ``hype_batched_partition`` (complete int32
    assignment, max - min <= 1 vertex balance) but all ``k`` partitions
    grow *concurrently*: every superstep stacks the fresh candidates of
    all growing phases into one fused ``hype_score_select`` device call
    against a graph image (CSR + assignment + score cache) that was
    uploaded once. Scores survive across refills and phases — admissions
    *decrement* their neighbors' cached scores instead of wiping the
    cache. Falls back to ``hype_batched_partition`` when the adjacency
    guard trips (pathological hub expansion).
    """
    if params is None:
        params = SuperstepParams()
    if params.rows is None:
        params = dataclasses.replace(params, rows=max(8, params.t))
    if k < 1:
        raise ValueError("k must be >= 1")
    if params.t < 1 or params.rows < 1 or params.pool_cap < 1:
        raise ValueError("rows, pool_cap, t must all be >= 1")
    if k == 1:
        out = np.zeros(hg.n, dtype=np.int32)
        return (out, BatchedStats()) if return_stats else out
    assignment, st = _run_superstep(hg, k, params)
    if assignment is None:
        return hype_batched_partition(hg, k, params, return_stats)
    assert (assignment >= 0).all()
    if return_stats:
        return assignment, st.stats
    return assignment


def hype_batched_partition(hg: Hypergraph, k: int,
                           params: Optional[BatchedParams] = None,
                           return_stats: bool = False):
    """Partition ``hg`` into ``k`` parts with batched-candidate HYPE.

    Same contract as ``hype_partition``: complete int32 assignment with
    perfectly balanced partition sizes (max - min <= 1).
    """
    if params is None:
        params = BatchedParams()
    if k < 1:
        raise ValueError("k must be >= 1")
    if params.t < 1 or params.b < 1 or params.s < 1:
        raise ValueError("b, s, t must all be >= 1")
    if params.pool_cap < 1:
        raise ValueError("pool_cap must be >= 1")
    st = _BatchedState(hg, k, params)
    n = hg.n
    base, rem = divmod(n, k)
    for i in range(k):
        if i == k - 1:
            rem_v = np.flatnonzero(st.assignment < 0)
            st.assignment[rem_v] = i
            st.in_fringe[:] = False
            break
        _grow_partition(st, i, base + (1 if i < rem else 0))
    assert (st.assignment >= 0).all()
    if return_stats:
        return st.assignment, st.stats
    return st.assignment
