"""Deprecated shim — the fast engines moved to ``repro.engines``.

This module used to hold the whole batched/superstep/sharded/device
engine family. Every name it ever exported still resolves here (with a
``DeprecationWarning``) so pinned imports keep working, but new code
should import from the per-engine modules:

``repro.engines.{batched,superstep,sharded,device}`` (Params + entry
point per engine) and ``repro.engines.runtime`` (``BatchedStats``, the
shared pipeline driver).

The private-state aliases map to their public successors (e.g.
``_SuperstepState`` -> ``repro.engines.superstep.SuperstepState``).
"""
from __future__ import annotations

import importlib
import warnings

# old name -> (module under repro.engines, new name)
_MOVED = {
    "BatchedStats": ("runtime", "BatchedStats"),
    "_RESET0": ("runtime", "_RESET0"),
    "_RESET1": ("runtime", "_RESET1"),
    "_harvest_next": ("runtime", "_harvest_next"),
    "_teardown_pipeline": ("runtime", "_teardown_pipeline"),
    "_maybe_refine": ("runtime", "maybe_refine"),
    "_CallArgs": ("pipeline", "_CallArgs"),
    "_Superstep": ("pipeline", "_Superstep"),
    "_PH_SHIFT": ("pipeline", "_PH_SHIFT"),
    "_CLS_SHIFT": ("pipeline", "_CLS_SHIFT"),
    "_SEQ_START": ("pipeline", "_SEQ_START"),
    "BatchedParams": ("batched", "BatchedParams"),
    "_BatchedState": ("batched", "BatchedState"),
    "_grow_partition": ("batched", "_grow_partition"),
    "hype_batched_partition": ("batched", "hype_batched_partition"),
    "SuperstepParams": ("superstep", "SuperstepParams"),
    "_SuperstepState": ("superstep", "SuperstepState"),
    "hype_superstep_partition": ("superstep", "hype_superstep_partition"),
    "ShardedParams": ("sharded", "ShardedParams"),
    "_ShardedState": ("sharded", "ShardedState"),
    "hype_sharded_partition": ("sharded", "hype_sharded_partition"),
    "DeviceParams": ("device", "DeviceParams"),
    "_device_probe_faults": ("device", "_device_probe_faults"),
    "_device_probe_nan": ("device", "_device_probe_nan"),
    "_device_export": ("device", "_device_export"),
    "_device_attempt": ("device", "_device_attempt"),
    "_run_device_loop": ("device", "_run_device_loop"),
    "hype_device_partition": ("device", "hype_device_partition"),
}


def _compat_run_pipeline(hg, k, p, num_devices=None, mem_rung=0,
                         mem_warm=None, mem_retries=0):
    """Old driver entry: dispatches on ``num_devices`` like the monolith."""
    from repro.engines import runtime, sharded, superstep
    if num_devices is None:
        return superstep.run_pipeline(
            hg, k, p, mem_rung=mem_rung, mem_warm=mem_warm,
            mem_retries=mem_retries)
    kG = -(-k // num_devices) * num_devices
    return runtime.run_pipeline(
        hg, k, p,
        lambda p2, rung: sharded.ShardedState(
            hg, kG, p2, num_devices, mem_rung=rung),
        "hype_sharded", devices=num_devices, mem_rung=mem_rung,
        mem_warm=mem_warm, mem_retries=mem_retries)


def _compat_run_pipeline_budgeted(hg, k, p, num_devices=None):
    from repro.engines import runtime, sharded, superstep
    if num_devices is None:
        return superstep.run_pipeline_budgeted(hg, k, p)
    kG = -(-k // num_devices) * num_devices
    return runtime.run_pipeline_budgeted(
        hg, k, p,
        lambda p2, rung: sharded.ShardedState(
            hg, kG, p2, num_devices, mem_rung=rung),
        "hype_sharded", devices=num_devices)


_COMPAT = {"_run_pipeline": _compat_run_pipeline,
           "_run_pipeline_budgeted": _compat_run_pipeline_budgeted}


def __getattr__(name: str):
    target = _MOVED.get(name)
    if target is None and name not in _COMPAT:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    warnings.warn(
        f"repro.core.hype_batched.{name} is deprecated; the fast engines "
        f"live in repro.engines (see repro.engines.__doc__)",
        DeprecationWarning, stacklevel=2)
    if target is None:
        return _COMPAT[name]
    mod_name, new_name = target
    return getattr(importlib.import_module(f"repro.engines.{mod_name}"),
                   new_name)
