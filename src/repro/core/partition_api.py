"""Unified partitioning API — the framework's entry point.

``partition(hg, k, method=...)`` returns an int32 assignment; every
distributed component (GNN halo sharding, embedding-table placement) takes
an assignment produced here, so partitioners are interchangeable.

Engine selection in one line each (see DESIGN.md for the full ladder):
``hype`` is the paper-faithful reference, ``hype_batched`` the
throughput default, ``hype_superstep`` the device-resident large-k
engine, ``hype_device`` the fully device-resident while_loop engine,
``hype_sharded`` the multi-device mesh engine,
``hype_multilevel`` the quality-first multilevel composition, and the
remaining methods are the paper's baselines. The batched-family
engines take a ``refine_passes`` knob — the k-way refinement post-pass
of DESIGN.md §4e. ``describe_methods()`` returns the one-liners
programmatically.
"""
from __future__ import annotations

import dataclasses
import functools
import importlib
import time
from typing import Dict, Optional, Tuple

import numpy as np

from .hypergraph import Hypergraph
from .hype import HypeParams, hype_partition
from . import resilience
from .resilience import UnrecoverableFault
from .minmax import hashing_partition, minmax_partition, random_partition
from .shp import shp_partition
from .multilevel import hype_multilevel_partition, multilevel_partition
from . import metrics

# The fast-engine family lives in ``repro.engines`` (one module per
# engine); ``core`` never imports it at module level (layering,
# tools/check_layering.py) — dispatch resolves the modules lazily.
_FAST_ENGINES: Dict[str, Tuple[str, str, str]] = {
    "hype_batched": ("repro.engines.batched", "BatchedParams",
                     "hype_batched_partition"),
    "hype_superstep": ("repro.engines.superstep", "SuperstepParams",
                       "hype_superstep_partition"),
    "hype_device": ("repro.engines.device", "DeviceParams",
                    "hype_device_partition"),
    "hype_sharded": ("repro.engines.sharded", "ShardedParams",
                     "hype_sharded_partition"),
}


@functools.lru_cache(maxsize=None)
def _engine(method: str):
    """Resolve a fast engine's (ParamsClass, runner) pair lazily."""
    mod_name, cls_name, run_name = _FAST_ENGINES[method]
    mod = importlib.import_module(mod_name)
    return getattr(mod, cls_name), getattr(mod, run_name)


@functools.lru_cache(maxsize=None)
def _params_class(spec: Tuple[str, str]):
    """Load the params dataclass a METHOD_INFO ``params`` spec names."""
    mod_name, cls_name = spec
    return getattr(importlib.import_module(mod_name), cls_name)

# method -> one-line description, vertex-balance slack, notable knobs.
# The slack is the engine's documented guarantee on max(part size) -
# min(part size): the HYPE family and the random baseline are perfectly
# balanced (<= 1); the streaming/swap baselines run with their papers'
# slack-100 constraint; hashing and the recursive-bisection multilevel
# partitioner only promise proportional balance (a fraction of n/k),
# recorded here as callables of (n, k) so the registry test can enforce
# exactly what is documented. Engine-specific keyword knobs are
# SINGLE-SOURCED from each engine's params dataclass: a ``params`` entry
# names ``(module, class)`` and ``method_knobs()`` derives the knob
# tuple from its fields (minus ``seed`` and any ``knob_exclude`` names
# the method pins itself), so the registry cannot drift from the
# dataclass — the two-way drift test in tests/test_partition_registry.py
# enforces it. Methods without a params dataclass keep a hand-maintained
# ``knobs`` tuple checked against the callable's signature. ``presets``
# maps ``preset=fast|balanced|quality`` to the knob defaults
# ``partition()`` folds under explicit keywords.
_PRESETS_HOST = {"fast": {}, "balanced": {"refine_passes": 1},
                 "quality": {"refine_passes": 4}}
# the pipelined engines additionally pin the lock-step schedule at
# ``quality``: depth 1 is the canonical golden cadence, and with the
# refinement post-pass dominating runtime the overlap buys nothing
_PRESETS_PIPE = {"fast": {}, "balanced": {"refine_passes": 1},
                 "quality": {"refine_passes": 4, "pipeline_depth": 1}}
METHOD_INFO: Dict[str, dict] = {
    "hype": {
        "desc": "paper-faithful numpy HYPE: heap + per-vertex growth "
                "steps (fidelity reference, ablations)",
        "balance_slack": lambda n, k: 1,
        "params": ("repro.core.hype", "HypeParams"),
    },
    "hype_batched": {
        "desc": "batched-candidate HYPE on the Pallas hype_scores "
                "kernel (host tiles; bit-stable throughput default)",
        "balance_slack": lambda n, k: 1,
        "params": ("repro.engines.batched", "BatchedParams"),
        "presets": _PRESETS_HOST,
    },
    "hype_jax": {
        "desc": "sequential HYPE as one jitted lax.while_loop program "
                "on dense padded arrays (on-device validation, small n)",
        "balance_slack": lambda n, k: 1,
    },
    "hype_parallel": {
        "desc": "jitted parallel k-way growth (paper §VI future work; "
                "validation scale)",
        "balance_slack": lambda n, k: 1,
    },
    "hype_superstep": {
        "desc": "device-resident HYPE: fused score+select supersteps "
                "grow all k phases concurrently on a double-buffered "
                "pipeline (large-k choice; pipeline_depth=1 locks step)",
        "balance_slack": lambda n, k: 1,
        "params": ("repro.engines.superstep", "SuperstepParams"),
        "presets": _PRESETS_PIPE,
    },
    "hype_device": {
        "desc": "fully device-resident HYPE: the whole growth loop as "
                "one lax.while_loop megakernel with on-device pool "
                "maintenance; host syncs once per chunk (DESIGN.md §4i)",
        "balance_slack": lambda n, k: 1,
        "params": ("repro.engines.device", "DeviceParams"),
        "presets": _PRESETS_HOST,
    },
    "hype_sharded": {
        "desc": "mesh-sharded superstep HYPE: phase groups sharded over "
                "a JAX device mesh, one all_gather per pipelined "
                "superstep",
        "balance_slack": lambda n, k: 1,
        "params": ("repro.engines.sharded", "ShardedParams"),
        "presets": _PRESETS_PIPE,
    },
    "hype_stream": {
        "desc": "single-pass streaming HYPE: micro-batched arrivals "
                "scored against a partition sketch + fringe kernel "
                "with a FREIGHT-style balance penalty; apply_updates "
                "mutates assignments incrementally (DESIGN.md §4h)",
        # hard ceil(n/k) capacity cap, no final rebalance: the last
        # arrivals can leave up to a k-wide size gap
        "balance_slack": lambda n, k: k,
        "params": ("repro.core.hype_stream", "StreamParams"),
    },
    "hype_weighted": {
        "desc": "numpy HYPE with degree-weighted balancing (HypeParams"
                "(balance='weighted'))",
        "balance_slack": lambda n, k: n,    # balances weight, not counts
        "params": ("repro.core.hype", "HypeParams"),
        "knob_exclude": ("balance",),       # pinned to "weighted"
    },
    "minmax_nb": {
        "desc": "streaming MinMax, vertex-balanced variant (HYPE paper "
                "footnote 2: slack of up to 100 vertices)",
        "balance_slack": lambda n, k: 101,  # slack + the vertex placed
        "knobs": ("slack",),
    },
    "minmax_eb": {
        "desc": "streaming MinMax, hyperedge-balanced original "
                "(Alistarh et al., NIPS'15); vertex counts may skew",
        "balance_slack": lambda n, k: n,    # balances edges, not vertices
    },
    "shp": {
        "desc": "Social-Hash-style iterative balanced swaps from a "
                "random start (Kabiljo et al., VLDB'17)",
        "balance_slack": lambda n, k: 1,    # swaps preserve random init
        "knobs": ("iters", "swap_frac"),
    },
    "multilevel": {
        "desc": "coarsen + recursive bisection + FM refinement "
                "(group (I) baseline); ~5% bisection tolerance",
        "balance_slack": lambda n, k: max(1, int(0.35 * (n / k)) + k),
    },
    "hype_multilevel": {
        "desc": "direct k-way multilevel: coarsen + hype_superstep "
                "initial partition + kway_refine uncoarsening passes "
                "(DESIGN.md §4e)",
        "balance_slack": lambda n, k: 1,
        "knobs": ("refine_passes", "coarsest"),
    },
    "random": {
        "desc": "balanced random assignment (quality lower bound)",
        "balance_slack": lambda n, k: 1,
    },
    "hashing": {
        "desc": "deterministic multiplicative hashing (what production "
                "systems default to); only statistically balanced",
        "balance_slack": lambda n, k: n,
    },
}

METHODS = tuple(METHOD_INFO)


def describe_methods() -> Dict[str, str]:
    """One-line description per registered method, keyed like ``METHODS``.

    The strings are the engine table of DESIGN.md in programmatic form —
    surfaces (CLIs, dashboards, docs generators) render them instead of
    hard-coding an engine list that drifts from the registry.
    """
    return {name: info["desc"] for name, info in METHOD_INFO.items()}


def method_knobs(method: str) -> tuple:
    """Engine-specific keyword knobs ``partition()`` forwards.

    Methods with a ``params`` dataclass spec derive the tuple from the
    dataclass fields (minus ``seed``, which ``partition()`` owns, and
    any ``knob_exclude`` names the method pins itself), so the registry
    cannot drift from the engine. Methods without one return their
    hand-maintained ``knobs`` tuple; empty for methods whose only knob
    is ``seed``. Either way the registry drift test verifies every
    listed knob against the engine's signature, so this tuple is safe
    to render in docs and CLIs.
    """
    info = METHOD_INFO[method]
    spec = info.get("params")
    if spec is None:
        return tuple(info.get("knobs", ()))
    cls = _params_class(spec)
    hidden = {"seed"} | set(info.get("knob_exclude", ()))
    return tuple(f.name for f in dataclasses.fields(cls)
                 if f.name not in hidden)


def method_presets(method: str) -> Dict[str, dict]:
    """The ``preset`` vocabulary ``partition()`` accepts for ``method``.

    Maps preset name -> the knob defaults it folds in (explicit keywords
    still win). Empty for methods without presets; ``"fast"`` is always
    the empty dict, i.e. bit-identical to the engine's own defaults.
    """
    return {name: dict(knobs) for name, knobs
            in METHOD_INFO[method].get("presets", {}).items()}


def balance_slack(method: str, n: int, k: int) -> int:
    """Documented worst-case ``max - min`` partition-size gap.

    For the perfectly balancing engines this is 1; streaming baselines
    return their slack constant; hashing/multilevel return proportional
    bounds. Used by the registry drift test to enforce exactly what each
    engine documents.
    """
    return int(METHOD_INFO[method]["balance_slack"](n, k))


# Method-independent knobs ``partition()`` itself consumes (never
# forwarded to an engine), name -> default. Registered so the knob
# drift test can enforce the signature defaults the same way engine
# knobs are enforced against their params dataclasses.
#
# ``auto_validate_max_n``: above this vertex count ``validate="auto"``
# skips the O(pins) invariant sweep — it starts to rival the cheap
# engines' own runtime. Huge-graph runs opt back in with
# ``validate=True`` or a larger threshold.
PARTITION_KNOBS: Dict[str, object] = {
    "auto_validate_max_n": 1_000_000,
}


def _resolve_validate(hg: Hypergraph, validate,
                      auto_validate_max_n: int) -> bool:
    if validate == "auto":
        return hg.n < int(auto_validate_max_n)
    if not isinstance(validate, bool):
        raise ValueError(
            f"validate must be 'auto' or a bool, got {validate!r}")
    return validate


def _resolve_preset(method: str, preset: Optional[str],
                    kw: dict) -> dict:
    """Fold ``preset`` defaults under the explicit knobs in ``kw``."""
    if preset is None:
        return kw
    presets = METHOD_INFO.get(method, {}).get("presets")
    if not presets:
        raise ValueError(
            f"method {method!r} does not support presets")
    if preset not in presets:
        raise ValueError(
            f"unknown preset {preset!r} for method {method!r}; "
            f"choose from {tuple(presets)}")
    return {**presets[preset], **kw}


def partition(hg: Hypergraph, k: int, method: str = "hype", *,
              seed: int = 0, preset: Optional[str] = None,
              validate="auto",
              auto_validate_max_n: int = 1_000_000, **kw) -> np.ndarray:
    """Partition ``hg`` into ``k`` parts; the single entry point.

    Parameters
    ----------
    hg : Hypergraph
        The hypergraph to partition (see ``Hypergraph.from_pins`` /
        ``from_edge_lists`` for construction).
    k : int
        Number of partitions (>= 1).
    method : str
        One of ``METHODS``; see ``describe_methods()`` for one-line
        summaries. Engine choice rule of thumb: ``hype`` for fidelity,
        ``hype_batched`` (default engine of the HYPE family) for host
        throughput, ``hype_superstep`` for large k on one accelerator,
        ``hype_sharded`` for a multi-device mesh.
    seed : int
        Seeds every stochastic engine; equal seeds give identical
        assignments for the same method and knobs.
    preset : str, optional
        Named knob bundle for the fast engines (``method_presets``):
        ``"fast"`` keeps the engine's own defaults (bit-identical to
        passing no preset), ``"balanced"`` adds one refinement pass,
        ``"quality"`` runs four refinement passes (the pipelined
        engines also pin ``pipeline_depth=1``). Explicit knobs in
        ``**kw`` override the preset. Raises ``ValueError`` for an
        unknown preset or a method without presets.
    validate : "auto" | bool
        Run ``hg.validate()`` before dispatching so CSR corruption
        surfaces as a clear ``ValueError`` here rather than an opaque
        kernel failure after the device image upload. ``"auto"`` (the
        default) validates graphs below ``auto_validate_max_n``
        vertices and skips larger ones; pass an explicit bool to force
        either way.
    auto_validate_max_n : int
        The ``"auto"`` cutoff (default 1e6, see ``PARTITION_KNOBS``).
        Raise it to keep validating huge graphs, or lower it to skip
        validation sooner; ignored when ``validate`` is a bool.
    **kw
        Engine-specific knobs, forwarded to the engine's params
        (e.g. ``t=16`` for the batched engines, ``devices=4`` for
        ``hype_sharded``, ``iters=8`` for ``shp``).

    Returns
    -------
    np.ndarray
        Complete int32 assignment of shape ``(hg.n,)`` with values in
        ``[0, k)``. Balance is engine-specific (``balance_slack``): the
        HYPE family guarantees ``max - min <= 1`` vertex counts.
    """
    if _resolve_validate(hg, validate, auto_validate_max_n):
        hg.validate()
    kw = _resolve_preset(method, preset, kw)
    if method == "hype":
        return hype_partition(hg, k, HypeParams(seed=seed, **kw))
    if method in _FAST_ENGINES:
        params_cls, runner = _engine(method)
        return runner(hg, k, params_cls(seed=seed, **kw))
    if method == "hype_jax":
        from .hype_jax import hype_jax_partition
        return hype_jax_partition(hg, k, seed=seed, **kw)
    if method == "hype_parallel":
        from .hype_jax import hype_parallel_partition
        return hype_parallel_partition(hg, k, seed=seed, **kw)
    if method == "hype_stream":
        from .hype_stream import StreamParams, hype_stream_partition
        return hype_stream_partition(hg, k, StreamParams(seed=seed, **kw))
    if method == "hype_weighted":
        return hype_partition(hg, k, HypeParams(seed=seed, balance="weighted", **kw))
    if method == "minmax_nb":
        return minmax_partition(hg, k, mode="nb", seed=seed, **kw)
    if method == "minmax_eb":
        return minmax_partition(hg, k, mode="eb", seed=seed, **kw)
    if method == "shp":
        return shp_partition(hg, k, seed=seed, **kw)
    if method == "multilevel":
        return multilevel_partition(hg, k, seed=seed, **kw)
    if method == "hype_multilevel":
        return hype_multilevel_partition(hg, k, seed=seed, **kw)
    if method == "random":
        return random_partition(hg, k, seed=seed)
    if method == "hashing":
        return hashing_partition(hg, k)
    raise ValueError(f"unknown method {method!r}; choose from {METHODS}")


def partition_and_report(hg: Hypergraph, k: int, method: str = "hype", *,
                         seed: int = 0, preset: Optional[str] = None,
                         validate="auto",
                         **kw) -> Tuple[dict, np.ndarray]:
    """Partition and measure: returns ``(report, assignment)``.

    Parameters are exactly ``partition``'s. ``report`` is
    ``metrics.all_metrics`` (``k_minus_1``, ``hyperedge_cut``,
    ``imbalance``, ``replication_factor``, ...) plus ``method``/``k``/
    ``runtime_s``; ``assignment`` is the int32 array ``partition``
    produced (the pair, not just the dict — callers feed the assignment
    to placement code and the report to dashboards).
    """
    t0 = time.perf_counter()
    assignment = partition(hg, k, method, seed=seed, preset=preset,
                           validate=validate, **kw)
    dt = time.perf_counter() - t0
    rep = metrics.all_metrics(hg, assignment, k)
    rep.update(method=method, k=k, runtime_s=dt)
    return rep, assignment


# ----------------------------------------------------- degradation ladder

# Each engine's structured fallback when it raises UnrecoverableFault:
# shed one capability per rung (mesh -> single device -> host tiles ->
# pure numpy) rather than abandoning the run. The final ``hype`` rung
# has no device dependency at all, so the ladder always terminates.
_LADDER = {
    "hype_device": "hype_superstep",
    "hype_sharded": "hype_superstep",
    "hype_superstep": "hype_batched",
    "hype_batched": "hype",
}


def _run_rung(hg: Hypergraph, k: int, method: str, seed: int,
              resume, snapshot_dir, snapshot_every: int, keep_last: int,
              plan, kw: dict):
    """One ladder rung: run ``method`` and return ``(assignment, stats)``.

    ``kw`` is filtered down to the rung's registered knobs so that, say,
    ``devices=4`` survives the hop from ``hype_sharded`` to
    ``hype_superstep`` without a TypeError.
    """
    knobs = set(method_knobs(method))
    sub = {key: val for key, val in kw.items() if key in knobs}
    if method == "hype":
        warm = None
        if resume:
            ckpt = resilience.load_latest(resume)
            if ckpt is not None:
                resilience.check_checkpoint(ckpt, hg, k)
                warm = resilience.warm_assignment(ckpt)
        return hype_partition(hg, k, HypeParams(seed=seed, **sub),
                              return_stats=True, warm_start=warm)
    params_cls, runner = _engine(method)
    sub.update(snapshot_every=snapshot_every, snapshot_dir=snapshot_dir,
               keep_last=keep_last, resume=resume, fault_plan=plan)
    return runner(hg, k, params_cls(seed=seed, **sub), return_stats=True)


def partition_resilient(hg: Hypergraph, k: int,
                        method: str = "hype_sharded", *,
                        seed: int = 0,
                        snapshot_dir: Optional[str] = None,
                        snapshot_every: int = 0,
                        keep_last: int = 3,
                        resume: Optional[str] = None,
                        fault_plan=None,
                        validate="auto",
                        auto_validate_max_n: int = 1_000_000,
                        **kw) -> Tuple[np.ndarray, dict]:
    """Partition with retries, snapshots and the degradation ladder.

    Runs ``method``; if the engine raises
    :class:`~repro.core.resilience.UnrecoverableFault` (fatal injected
    fault, exhausted retry budget, failed device image upload, device
    failure after buffer donation), falls back one rung at a time —
    ``hype_sharded -> hype_superstep -> hype_batched -> hype`` — resuming
    each fallback from the last snapshot in ``snapshot_dir`` (cross-engine
    restores warm-start from the snapshotted assignment; the pure-numpy
    ``hype`` rung adopts it via ``warm_start=``). Transient faults are
    retried *inside* each engine (``max_retries``/``retry_backoff_s``
    knobs) and never reach the ladder.

    ``snapshot_every > 0`` requires ``snapshot_dir``. ``fault_plan``
    (a ``FaultPlan``, a spec string, or None for ``REPRO_FAULT_PLAN``)
    is resolved once and shared across rungs so a consumed fault does
    not re-fire after a fallback. Engine knobs in ``**kw`` are filtered
    per rung, so e.g. ``devices=4`` is dropped when the ladder leaves
    ``hype_sharded``.

    Returns ``(assignment, report)`` where ``report`` carries the
    quality metrics plus ``method`` (the rung that finished),
    ``requested_method``, ``degraded_from`` (one ``{"method", "error"}``
    record per abandoned rung), ``fallbacks`` and the finishing engine's
    ``stats`` dataclass.
    """
    if method not in ("hype", *_LADDER):
        raise ValueError(
            f"unknown resilient method {method!r}; choose from "
            f"{('hype', *_LADDER)}")
    if _resolve_validate(hg, validate, auto_validate_max_n):
        hg.validate()
    plan = resilience.resolve_fault_plan(fault_plan)
    t0 = time.perf_counter()
    attempted = []
    cur = method
    while True:
        try:
            assignment, stats = _run_rung(
                hg, k, cur, seed, resume, snapshot_dir, snapshot_every,
                keep_last, plan, kw)
            break
        except UnrecoverableFault as e:
            nxt = _LADDER.get(cur)
            if nxt is None:
                raise
            attempted.append({"method": cur, "error": str(e)})
            cur = nxt
            # Fallback rungs resume from whatever the failed rung last
            # published; with no snapshot_dir they cold-start instead.
            resume = snapshot_dir
    dt = time.perf_counter() - t0
    if hasattr(stats, "fallbacks"):
        stats.fallbacks = len(attempted)
    rep = metrics.all_metrics(hg, assignment, k)
    rep.update(method=cur, requested_method=method, k=k, runtime_s=dt,
               degraded_from=attempted, fallbacks=len(attempted),
               stats=stats)
    return assignment, rep
