"""Unified partitioning API — the framework's entry point.

``partition(hg, k, method=...)`` returns an int32 assignment; every
distributed component (GNN halo sharding, embedding-table placement) takes
an assignment produced here, so partitioners are interchangeable.

Engine selection in one line each (see DESIGN.md for the full ladder):
``hype`` is the paper-faithful reference, ``hype_batched`` the
throughput default, ``hype_superstep`` the device-resident large-k
engine, ``hype_sharded`` the multi-device mesh engine,
``hype_multilevel`` the quality-first multilevel composition, and the
remaining methods are the paper's baselines. The batched-family
engines take a ``refine_passes`` knob — the k-way refinement post-pass
of DESIGN.md §4e. ``describe_methods()`` returns the one-liners
programmatically.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import numpy as np

from .hypergraph import Hypergraph
from .hype import HypeParams, hype_partition
from .hype_batched import (BatchedParams, ShardedParams, SuperstepParams,
                           hype_batched_partition,
                           hype_sharded_partition,
                           hype_superstep_partition)
from .minmax import hashing_partition, minmax_partition, random_partition
from .shp import shp_partition
from .multilevel import hype_multilevel_partition, multilevel_partition
from . import metrics

# method -> one-line description, vertex-balance slack, notable knobs.
# The slack is the engine's documented guarantee on max(part size) -
# min(part size): the HYPE family and the random baseline are perfectly
# balanced (<= 1); the streaming/swap baselines run with their papers'
# slack-100 constraint; hashing and the recursive-bisection multilevel
# partitioner only promise proportional balance (a fraction of n/k),
# recorded here as callables of (n, k) so the registry test can enforce
# exactly what is documented. ``knobs`` lists the engine-specific
# keyword arguments ``partition()`` forwards — the registry drift test
# checks each against the engine's params signature, so a renamed or
# removed knob fails there, not in production.
METHOD_INFO: Dict[str, dict] = {
    "hype": {
        "desc": "paper-faithful numpy HYPE: heap + per-vertex growth "
                "steps (fidelity reference, ablations)",
        "balance_slack": lambda n, k: 1,
        "knobs": ("s", "r", "use_cache", "dext_mode"),
    },
    "hype_batched": {
        "desc": "batched-candidate HYPE on the Pallas hype_scores "
                "kernel (host tiles; bit-stable throughput default)",
        "balance_slack": lambda n, k: 1,
        "knobs": ("t", "b", "s", "pool_cap", "kernel_min",
                  "refine_passes"),
    },
    "hype_jax": {
        "desc": "sequential HYPE as one jitted lax.while_loop program "
                "on dense padded arrays (on-device validation, small n)",
        "balance_slack": lambda n, k: 1,
    },
    "hype_parallel": {
        "desc": "jitted parallel k-way growth (paper §VI future work; "
                "validation scale)",
        "balance_slack": lambda n, k: 1,
    },
    "hype_superstep": {
        "desc": "device-resident HYPE: fused score+select supersteps "
                "grow all k phases concurrently on a double-buffered "
                "pipeline (large-k choice; pipeline_depth=1 locks step)",
        "balance_slack": lambda n, k: 1,
        "knobs": ("t", "rows", "pool_cap", "pipeline_depth",
                  "refine_passes"),
    },
    "hype_sharded": {
        "desc": "mesh-sharded superstep HYPE: phase groups sharded over "
                "a JAX device mesh, one all_gather per pipelined "
                "superstep",
        "balance_slack": lambda n, k: 1,
        "knobs": ("t", "rows", "pool_cap", "pipeline_depth", "devices",
                  "refine_passes"),
    },
    "hype_weighted": {
        "desc": "numpy HYPE with degree-weighted balancing (HypeParams"
                "(balance='weighted'))",
        "balance_slack": lambda n, k: n,    # balances weight, not counts
    },
    "minmax_nb": {
        "desc": "streaming MinMax, vertex-balanced variant (HYPE paper "
                "footnote 2: slack of up to 100 vertices)",
        "balance_slack": lambda n, k: 101,  # slack + the vertex placed
        "knobs": ("slack",),
    },
    "minmax_eb": {
        "desc": "streaming MinMax, hyperedge-balanced original "
                "(Alistarh et al., NIPS'15); vertex counts may skew",
        "balance_slack": lambda n, k: n,    # balances edges, not vertices
    },
    "shp": {
        "desc": "Social-Hash-style iterative balanced swaps from a "
                "random start (Kabiljo et al., VLDB'17)",
        "balance_slack": lambda n, k: 1,    # swaps preserve random init
        "knobs": ("iters", "swap_frac"),
    },
    "multilevel": {
        "desc": "coarsen + recursive bisection + FM refinement "
                "(group (I) baseline); ~5% bisection tolerance",
        "balance_slack": lambda n, k: max(1, int(0.35 * (n / k)) + k),
    },
    "hype_multilevel": {
        "desc": "direct k-way multilevel: coarsen + hype_superstep "
                "initial partition + kway_refine uncoarsening passes "
                "(DESIGN.md §4e)",
        "balance_slack": lambda n, k: 1,
        "knobs": ("refine_passes", "coarsest"),
    },
    "random": {
        "desc": "balanced random assignment (quality lower bound)",
        "balance_slack": lambda n, k: 1,
    },
    "hashing": {
        "desc": "deterministic multiplicative hashing (what production "
                "systems default to); only statistically balanced",
        "balance_slack": lambda n, k: n,
    },
}

METHODS = tuple(METHOD_INFO)


def describe_methods() -> Dict[str, str]:
    """One-line description per registered method, keyed like ``METHODS``.

    The strings are the engine table of DESIGN.md in programmatic form —
    surfaces (CLIs, dashboards, docs generators) render them instead of
    hard-coding an engine list that drifts from the registry.
    """
    return {name: info["desc"] for name, info in METHOD_INFO.items()}


def method_knobs(method: str) -> tuple:
    """Engine-specific keyword knobs ``partition()`` forwards.

    Empty for methods whose only knob is ``seed``. The registry drift
    test verifies every listed knob against the engine's params
    signature, so this tuple is safe to render in docs and CLIs.
    """
    return tuple(METHOD_INFO[method].get("knobs", ()))


def balance_slack(method: str, n: int, k: int) -> int:
    """Documented worst-case ``max - min`` partition-size gap.

    For the perfectly balancing engines this is 1; streaming baselines
    return their slack constant; hashing/multilevel return proportional
    bounds. Used by the registry drift test to enforce exactly what each
    engine documents.
    """
    return int(METHOD_INFO[method]["balance_slack"](n, k))


def partition(hg: Hypergraph, k: int, method: str = "hype", *,
              seed: int = 0, **kw) -> np.ndarray:
    """Partition ``hg`` into ``k`` parts; the single entry point.

    Parameters
    ----------
    hg : Hypergraph
        The hypergraph to partition (see ``Hypergraph.from_pins`` /
        ``from_edge_lists`` for construction).
    k : int
        Number of partitions (>= 1).
    method : str
        One of ``METHODS``; see ``describe_methods()`` for one-line
        summaries. Engine choice rule of thumb: ``hype`` for fidelity,
        ``hype_batched`` (default engine of the HYPE family) for host
        throughput, ``hype_superstep`` for large k on one accelerator,
        ``hype_sharded`` for a multi-device mesh.
    seed : int
        Seeds every stochastic engine; equal seeds give identical
        assignments for the same method and knobs.
    **kw
        Engine-specific knobs, forwarded to the engine's params
        (e.g. ``t=16`` for the batched engines, ``devices=4`` for
        ``hype_sharded``, ``iters=8`` for ``shp``).

    Returns
    -------
    np.ndarray
        Complete int32 assignment of shape ``(hg.n,)`` with values in
        ``[0, k)``. Balance is engine-specific (``balance_slack``): the
        HYPE family guarantees ``max - min <= 1`` vertex counts.
    """
    if method == "hype":
        return hype_partition(hg, k, HypeParams(seed=seed, **kw))
    if method == "hype_batched":
        return hype_batched_partition(hg, k, BatchedParams(seed=seed, **kw))
    if method == "hype_jax":
        from .hype_jax import hype_jax_partition
        return hype_jax_partition(hg, k, seed=seed, **kw)
    if method == "hype_parallel":
        from .hype_jax import hype_parallel_partition
        return hype_parallel_partition(hg, k, seed=seed, **kw)
    if method == "hype_superstep":
        return hype_superstep_partition(
            hg, k, SuperstepParams(seed=seed, **kw))
    if method == "hype_sharded":
        return hype_sharded_partition(
            hg, k, ShardedParams(seed=seed, **kw))
    if method == "hype_weighted":
        return hype_partition(hg, k, HypeParams(seed=seed, balance="weighted", **kw))
    if method == "minmax_nb":
        return minmax_partition(hg, k, mode="nb", seed=seed, **kw)
    if method == "minmax_eb":
        return minmax_partition(hg, k, mode="eb", seed=seed, **kw)
    if method == "shp":
        return shp_partition(hg, k, seed=seed, **kw)
    if method == "multilevel":
        return multilevel_partition(hg, k, seed=seed, **kw)
    if method == "hype_multilevel":
        return hype_multilevel_partition(hg, k, seed=seed, **kw)
    if method == "random":
        return random_partition(hg, k, seed=seed)
    if method == "hashing":
        return hashing_partition(hg, k)
    raise ValueError(f"unknown method {method!r}; choose from {METHODS}")


def partition_and_report(hg: Hypergraph, k: int, method: str = "hype", *,
                         seed: int = 0,
                         **kw) -> Tuple[dict, np.ndarray]:
    """Partition and measure: returns ``(report, assignment)``.

    Parameters are exactly ``partition``'s. ``report`` is
    ``metrics.all_metrics`` (``k_minus_1``, ``hyperedge_cut``,
    ``imbalance``, ``replication_factor``, ...) plus ``method``/``k``/
    ``runtime_s``; ``assignment`` is the int32 array ``partition``
    produced (the pair, not just the dict — callers feed the assignment
    to placement code and the report to dashboards).
    """
    t0 = time.perf_counter()
    assignment = partition(hg, k, method, seed=seed, **kw)
    dt = time.perf_counter() - t0
    rep = metrics.all_metrics(hg, assignment, k)
    rep.update(method=method, k=k, runtime_s=dt)
    return rep, assignment
