"""Unified partitioning API — the framework's entry point.

``partition(hg, k, method=...)`` returns an int32 assignment; every
distributed component (GNN halo sharding, embedding-table placement) takes
an assignment produced here, so partitioners are interchangeable.
"""
from __future__ import annotations

import time
from typing import Optional, Tuple

import numpy as np

from .hypergraph import Hypergraph
from .hype import HypeParams, hype_partition
from .hype_batched import (BatchedParams, SuperstepParams,
                           hype_batched_partition,
                           hype_superstep_partition)
from .minmax import hashing_partition, minmax_partition, random_partition
from .shp import shp_partition
from .multilevel import multilevel_partition
from . import metrics

METHODS = ("hype", "hype_batched", "hype_superstep", "hype_weighted",
           "minmax_nb", "minmax_eb", "shp", "multilevel", "random",
           "hashing")


def partition(hg: Hypergraph, k: int, method: str = "hype", *,
              seed: int = 0, **kw) -> np.ndarray:
    if method == "hype":
        return hype_partition(hg, k, HypeParams(seed=seed, **kw))
    if method == "hype_batched":
        return hype_batched_partition(hg, k, BatchedParams(seed=seed, **kw))
    if method == "hype_superstep":
        return hype_superstep_partition(
            hg, k, SuperstepParams(seed=seed, **kw))
    if method == "hype_weighted":
        return hype_partition(hg, k, HypeParams(seed=seed, balance="weighted", **kw))
    if method == "minmax_nb":
        return minmax_partition(hg, k, mode="nb", seed=seed, **kw)
    if method == "minmax_eb":
        return minmax_partition(hg, k, mode="eb", seed=seed, **kw)
    if method == "shp":
        return shp_partition(hg, k, seed=seed, **kw)
    if method == "multilevel":
        return multilevel_partition(hg, k, seed=seed, **kw)
    if method == "random":
        return random_partition(hg, k, seed=seed)
    if method == "hashing":
        return hashing_partition(hg, k)
    raise ValueError(f"unknown method {method!r}; choose from {METHODS}")


def partition_and_report(hg: Hypergraph, k: int, method: str = "hype", *,
                         seed: int = 0,
                         **kw) -> Tuple[dict, np.ndarray]:
    """Partition and measure: returns ``(report, assignment)``.

    ``report`` is ``metrics.all_metrics`` plus ``method``/``k``/
    ``runtime_s``; ``assignment`` is the int32 array ``partition``
    produced (the pair, not just the dict — callers feed the assignment
    to placement code and the report to dashboards).
    """
    t0 = time.perf_counter()
    assignment = partition(hg, k, method, seed=seed, **kw)
    dt = time.perf_counter() - t0
    rep = metrics.all_metrics(hg, assignment, k)
    rep.update(method=method, k=k, runtime_s=dt)
    return rep, assignment
