"""JAX-native HYPE: TPU-adapted neighborhood expansion.

Two engines, both pure ``jax.lax`` control flow (jit-compatible, runs on
TPU/CPU, differentiably irrelevant but shardable):

1. ``hype_jax_partition`` — a faithful sequential HYPE on *dense padded*
   CSR arrays. One ``lax.while_loop`` iteration moves one vertex, exactly
   like Algorithm 1-3 with the s/r/caching optimizations. Used to
   cross-validate the numpy engine and to run the partitioner on-device.

2. ``hype_parallel_partition`` — the paper's §VI future-work direction
   ("grow the k core sets in parallel"), realized as a TPU-native batched
   expansion: all k cores take one growth step per iteration; candidate
   scoring is vectorized over (partition, candidate) with masked segment
   ops; collisions (two cores wanting the same vertex) are resolved by
   priority = (lower current core size, lower score). This turns HYPE's
   inner loop into dense matrix work that maps onto the MXU, which is the
   hardware-adaptation story for this paper (see DESIGN.md).

Hardware adaptation note: the paper's per-vertex heap + hash-set machinery
is CPU-idiomatic and does not map to TPU. The JAX engines replace
  * the active-edge min-heap        -> masked argmin over edge-size vector,
  * hash-set neighbor dedup         -> boolean membership vectors,
  * the lazy score cache            -> a score vector updated with
                                       ``.at[].set`` under a staleness mask.
Both engines operate on hypergraphs padded to (n, max_deg) / (m, max_size).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .hypergraph import Hypergraph
from .scoring import batched_dext_jax

_INF = jnp.float32(3.4e38)


def _pad_csr(indptr: np.ndarray, indices: np.ndarray, rows: int,
             width: int) -> np.ndarray:
    """Dense (rows, width) -1-padded view of a CSR structure.

    Pure numpy scatter — one assignment over all nonzeros, no per-row
    Python loop.
    """
    out = np.full((rows, width), -1, dtype=np.int32)
    if rows and indices.size:
        lens = np.diff(indptr).astype(np.int64)
        r = np.repeat(np.arange(rows, dtype=np.int64), lens)
        c = (np.arange(indices.size, dtype=np.int64)
             - np.repeat(indptr[:-1].astype(np.int64), lens))
        out[r, c] = indices
    return out


def _member_mask(n: int, ids: jax.Array) -> jax.Array:
    """(n,) bool mask with True at every non-negative id in ``ids``.

    -1 pads are routed to the out-of-bounds index n and dropped by the
    scatter, so a pad entry can never clobber a real vertex (the old
    ``.at[where(ids >= 0, ids, 0)].set(gathered & ...)`` idiom raced on
    vertex 0 when a pad and a real update landed on the same slot).
    """
    safe = jnp.where(ids >= 0, ids, n)
    return jnp.zeros(n, dtype=bool).at[safe].set(True, mode="drop")


class PaddedHypergraph(NamedTuple):
    """Dense padded views of a hypergraph (device-resident).

    ``n``/``m`` are recovered from static array shapes so the structure is
    a plain jit-able pytree of arrays.
    """
    v2e: jax.Array        # (n, max_deg) int32, -1 padded
    e2v: jax.Array        # (m, max_size) int32, -1 padded
    edge_sizes: jax.Array  # (m,) int32

    @property
    def n(self) -> int:
        return self.v2e.shape[0]

    @property
    def m(self) -> int:
        return self.e2v.shape[0]

    @classmethod
    def from_hypergraph(cls, hg: Hypergraph) -> "PaddedHypergraph":
        max_deg = max(1, int(hg.vertex_degrees.max()) if hg.n else 1)
        max_size = max(1, int(hg.edge_sizes.max()) if hg.m else 1)
        v2e = _pad_csr(hg.v2e_indptr, hg.v2e_indices, hg.n, max_deg)
        e2v = _pad_csr(hg.e2v_indptr, hg.e2v_indices, hg.m, max_size)
        return cls(v2e=jnp.asarray(v2e), e2v=jnp.asarray(e2v),
                   edge_sizes=jnp.asarray(hg.edge_sizes, dtype=jnp.int32))


def _d_ext_batch(ph: PaddedHypergraph, vs: jax.Array, in_fringe: jax.Array,
                 assignment: jax.Array) -> jax.Array:
    """|N(v) ∩ V'| for a batch of vertices (see hype.py docstring).

    Shared gather + sorted-segment counting from ``core.scoring`` — no
    O(n) dense membership mask per candidate, so the cost scales with the
    candidate neighborhoods, not with the graph.
    """
    ext = (~in_fringe) & (assignment < 0)
    return batched_dext_jax(ph.v2e, ph.e2v, vs, ext)


class _SeqState(NamedTuple):
    assignment: jax.Array    # (n,) int32, -1 unassigned
    in_fringe: jax.Array     # (n,) bool
    fringe: jax.Array        # (s,) int32, -1 empty slots
    cache: jax.Array         # (n,) float32, <0 = missing
    edge_active: jax.Array   # (m,) bool  (incident to current core)
    core_size: jax.Array     # () int32
    rand_key: jax.Array


def _seq_grow(ph: PaddedHypergraph, state: _SeqState, part: int,
              target: jax.Array, s: int, r: int) -> _SeqState:
    """Grow core set `part` to `target` vertices (one while_loop)."""
    n, m = ph.n, ph.m

    def pick_random_unassigned(key, assignment, in_fringe):
        key, sub = jax.random.split(key)
        avail = (assignment < 0) & (~in_fringe)
        p = avail.astype(jnp.float32)
        idx = jnp.argmax(p * jax.random.uniform(sub, (n,), minval=0.5, maxval=1.0))
        return key, jnp.where(jnp.any(avail), idx, -1).astype(jnp.int32)

    def add_to_core(st: _SeqState, v: jax.Array) -> _SeqState:
        assignment = st.assignment.at[v].set(part)
        in_fringe = st.in_fringe.at[v].set(False)
        es = ph.v2e[v]
        edge_active = st.edge_active.at[jnp.where(es >= 0, es, m)].set(
            True, mode="drop")
        return st._replace(assignment=assignment, in_fringe=in_fringe,
                           edge_active=edge_active,
                           core_size=st.core_size + 1)

    def upd8_fringe(st: _SeqState) -> _SeqState:
        # --- candidate selection: r vertices from smallest active edges ---
        # An edge is usable if active and has >=1 pin in the universe.
        pins_univ = (st.assignment[jnp.where(ph.e2v >= 0, ph.e2v, 0)] < 0) \
            & (~st.in_fringe[jnp.where(ph.e2v >= 0, ph.e2v, 0)]) & (ph.e2v >= 0)
        edge_live = st.edge_active & jnp.any(pins_univ, axis=1)
        sizes = jnp.where(edge_live, ph.edge_sizes, jnp.iinfo(jnp.int32).max)

        def take_candidate(carry, _):
            cand, cand_cnt, taken = carry
            # smallest live edge with a pin not yet taken this round
            pin_ok = pins_univ & (~taken[jnp.where(ph.e2v >= 0, ph.e2v, 0)])
            live = edge_live & jnp.any(pin_ok, axis=1)
            e = jnp.argmin(jnp.where(live, sizes, jnp.iinfo(jnp.int32).max))
            any_live = jnp.any(live)
            row_ok = pin_ok[e]
            j = jnp.argmax(row_ok)
            v = jnp.where(any_live & row_ok[j], ph.e2v[e, j], -1)
            cand = cand.at[cand_cnt].set(jnp.where(v >= 0, v, -1))
            cand_cnt = cand_cnt + (v >= 0).astype(jnp.int32)
            taken = taken.at[jnp.where(v >= 0, v, n)].set(True)
            return (cand, cand_cnt, taken), None

        taken0 = jnp.zeros(n + 1, dtype=bool)
        (cand, _, _), _ = jax.lax.scan(
            take_candidate, (jnp.full((r,), -1, jnp.int32), jnp.int32(0), taken0),
            None, length=r)

        # --- update cache for candidates (lazy, one batched scoring) ---
        scores_new = _d_ext_batch(ph, cand, st.in_fringe, st.assignment)
        miss = (cand >= 0) & (st.cache[jnp.where(cand >= 0, cand, 0)] < 0)
        cache = st.cache.at[jnp.where(miss, cand, n)].set(
            scores_new, mode="drop")

        # --- fringe = top-s smallest scores of fringe ∪ candidates ---
        pool = jnp.concatenate([st.fringe, cand])                   # (s+r,)
        valid = pool >= 0
        # dedup (candidates are never in fringe by construction)
        scores = jnp.where(valid, cache[jnp.where(valid, pool, 0)], _INF)
        order = jnp.argsort(scores)
        pool_sorted = pool[order]
        new_fringe = pool_sorted[:s]
        evicted = pool_sorted[s:]
        in_fringe = ((st.in_fringe & ~_member_mask(n, evicted))
                     | _member_mask(n, new_fringe))
        st = st._replace(cache=cache, fringe=new_fringe, in_fringe=in_fringe)

        # --- random restart if fringe empty ---
        def restart(st: _SeqState) -> _SeqState:
            key, v = pick_random_unassigned(st.rand_key, st.assignment,
                                            st.in_fringe)
            safe = jnp.where(v >= 0, v, n)
            fr = st.fringe.at[0].set(v)
            inf = st.in_fringe.at[safe].set(True, mode="drop")
            cache = st.cache.at[safe].set(jnp.float32(0), mode="drop")
            return st._replace(fringe=fr, in_fringe=inf, rand_key=key,
                               cache=cache)
        return jax.lax.cond(jnp.all(st.fringe < 0), restart, lambda x: x, st)

    def upd8_core(st: _SeqState) -> _SeqState:
        scores = jnp.where(st.fringe >= 0,
                           st.cache[jnp.where(st.fringe >= 0, st.fringe, 0)],
                           _INF)
        i = jnp.argmin(scores)
        v = st.fringe[i]
        st = st._replace(fringe=st.fringe.at[i].set(-1))
        return jax.lax.cond(v >= 0, lambda s_: add_to_core(s_, v),
                            lambda s_: s_, st)

    def body(st: _SeqState) -> _SeqState:
        return upd8_core(upd8_fringe(st))

    def cond(st: _SeqState):
        return st.core_size < target

    # seed vertex
    key, seed_v = pick_random_unassigned(state.rand_key, state.assignment,
                                         state.in_fringe)
    state = state._replace(rand_key=key, core_size=jnp.int32(0),
                           cache=jnp.full((n,), -1.0, jnp.float32),
                           edge_active=jnp.zeros((m,), bool),
                           fringe=jnp.full((s,), -1, jnp.int32))
    state = jax.lax.cond(seed_v >= 0,
                         lambda s_: add_to_core(s_, seed_v),
                         lambda s_: s_, state)
    return jax.lax.while_loop(cond, body, state)


def _release_fringe(state: _SeqState, n: int, s: int) -> _SeqState:
    """§III-B1 step 4: evicted fringe vertices rejoin the universe.

    After this, ``in_fringe`` must be all-False — every vertex is either
    released here or was cleared on admission (regression-tested).
    """
    in_fringe = state.in_fringe & ~_member_mask(n, state.fringe)
    return state._replace(in_fringe=in_fringe,
                          fringe=jnp.full((s,), -1, jnp.int32))


@functools.partial(jax.jit, static_argnames=("k", "s", "r"))
def _hype_jax_impl(ph: PaddedHypergraph, k: int, s: int, r: int,
                   seed: jax.Array) -> jax.Array:
    n = ph.n
    base, rem = divmod(n, k)
    state = _SeqState(
        assignment=jnp.full((n,), -1, jnp.int32),
        in_fringe=jnp.zeros((n,), bool),
        fringe=jnp.full((s,), -1, jnp.int32),
        cache=jnp.full((n,), -1.0, jnp.float32),
        edge_active=jnp.zeros((ph.m,), bool),
        core_size=jnp.int32(0),
        rand_key=jax.random.PRNGKey(seed),
    )
    for i in range(k - 1):
        target = jnp.int32(base + (1 if i < rem else 0))
        state = _seq_grow(ph, state, i, target, s, r)
        state = _release_fringe(state, n, s)
    # last partition absorbs the remainder
    assignment = jnp.where(state.assignment < 0, k - 1, state.assignment)
    return assignment


def hype_jax_partition(hg: Hypergraph, k: int, *, s: int = 10, r: int = 2,
                       seed: int = 0) -> np.ndarray:
    """Sequential HYPE as a single jitted JAX program."""
    ph = PaddedHypergraph.from_hypergraph(hg)
    return np.asarray(_hype_jax_impl(ph, k, s, r, seed))


# --------------------------------------------------------------------------- #
# Parallel k-way growth (paper §VI future work — beyond-paper contribution)
# --------------------------------------------------------------------------- #

@functools.partial(jax.jit, static_argnames=("k", "c"))
def _parallel_impl(ph: PaddedHypergraph, k: int, c: int, seed: jax.Array):
    """All k cores grow simultaneously; one step assigns <= k vertices.

    Per step, every partition scores ``c`` candidate vertices drawn from its
    smallest active hyperedges (vectorized over partitions), picks its best,
    and collisions are resolved in favor of the smaller core. Vertices whose
    partitions lost a collision retry next step.
    """
    n, m = ph.n, ph.m
    base, rem = divmod(n, k)
    targets = jnp.asarray([base + (1 if i < rem else 0) for i in range(k)],
                          dtype=jnp.int32)

    key = jax.random.PRNGKey(seed)
    key, sub = jax.random.split(key)
    seeds = jax.random.choice(sub, n, shape=(k,), replace=False)
    assignment = jnp.full((n,), -1, jnp.int32).at[seeds].set(
        jnp.arange(k, dtype=jnp.int32))
    core_sizes = jnp.ones((k,), jnp.int32)
    # edge_owner_active[p, e]: edge e incident to core p
    edge_active = jnp.zeros((k, m), bool)
    es0 = ph.v2e[seeds]                                  # (k, max_deg)
    edge_active = edge_active.at[
        jnp.arange(k)[:, None], jnp.where(es0 >= 0, es0, m)].set(
            True, mode="drop")

    e2v_safe = jnp.where(ph.e2v >= 0, ph.e2v, 0)
    e2v_valid = ph.e2v >= 0

    def step(carry):
        assignment, core_sizes, edge_active, key, stall = carry
        unassigned = assignment < 0

        # (k, m): live edges per partition
        pin_univ = unassigned[e2v_safe] & e2v_valid       # (m, max_size)
        edge_has_univ = jnp.any(pin_univ, axis=1)         # (m,)
        live = edge_active & edge_has_univ[None, :]       # (k, m)
        sizes = jnp.where(live, ph.edge_sizes[None, :],
                          jnp.iinfo(jnp.int32).max)       # (k, m)

        # c candidates per partition from the c smallest live edges
        neg_sz, eidx = jax.lax.top_k(-sizes, c)           # (k, c)
        has_edge = neg_sz > -jnp.iinfo(jnp.int32).max
        # first universe pin of each selected edge
        rows = pin_univ[eidx]                              # (k, c, max_size)
        j = jnp.argmax(rows, axis=-1)                      # (k, c)
        cand = jnp.where(has_edge & jnp.take_along_axis(rows, j[..., None],
                                                        axis=-1)[..., 0],
                         ph.e2v[eidx, j], -1)              # (k, c)

        # score candidates: d_ext = |N(v) ∩ V'| (no fringe in parallel
        # mode); one shared batched gather+segment pass over all (k, c)
        flat = cand.reshape(-1)
        sc_flat = batched_dext_jax(ph.v2e, ph.e2v, flat, unassigned)
        scores = jnp.where(cand >= 0, sc_flat.reshape(cand.shape), _INF)

        # each partition picks its best candidate
        bi = jnp.argmin(scores, axis=1)                    # (k,)
        pick = cand[jnp.arange(k), bi]                     # (k,)
        pick_score = scores[jnp.arange(k), bi]
        full = core_sizes >= targets
        want = (pick >= 0) & (~full)
        # collision resolution: smaller core wins, then lower score
        prio = core_sizes.astype(jnp.float32) * 1e6 + pick_score
        prio = jnp.where(want, prio, _INF)
        best_for_v = jnp.full((n + 1,), _INF).at[
            jnp.where(want, pick, n)].min(prio)
        win = want & (prio <= best_for_v[jnp.where(want, pick, n)])
        # break exact ties by partition id: lowest id wins
        first_p = jnp.full((n + 1,), k, jnp.int32).at[
            jnp.where(win, pick, n)].min(
                jnp.where(win, jnp.arange(k, dtype=jnp.int32), k))
        win = win & (first_p[jnp.where(win, pick, n)] == jnp.arange(k))

        assignment = assignment.at[jnp.where(win, pick, n)].set(
            jnp.arange(k, dtype=jnp.int32), mode="drop")
        core_sizes = core_sizes + win.astype(jnp.int32)
        # activate edges of newly added vertices
        es = ph.v2e[jnp.where(win, pick, 0)]               # (k, max_deg)
        upd = (es >= 0) & win[:, None]
        edge_active = edge_active.at[
            jnp.arange(k)[:, None], jnp.where(upd, es, m)].set(
                True, mode="drop")

        # stall detection: if nobody won but vertices remain, pick random
        # vertices for the emptiest non-full partitions.
        any_win = jnp.any(win)
        key, sub = jax.random.split(key)

        def rescue(args):
            assignment, core_sizes, edge_active = args
            p = jnp.argmin(jnp.where(full, jnp.iinfo(jnp.int32).max,
                                     core_sizes))
            avail = assignment < 0
            v = jnp.argmax(avail.astype(jnp.float32)
                           * jax.random.uniform(sub, (n,), minval=0.5,
                                                maxval=1.0))
            ok = jnp.any(avail)
            assignment = assignment.at[v].set(
                jnp.where(ok, p.astype(jnp.int32), assignment[v]))
            core_sizes = core_sizes.at[p].add(ok.astype(jnp.int32))
            es = ph.v2e[v]
            upd = (es >= 0) & ok
            edge_active = edge_active.at[p, jnp.where(upd, es, m)].set(
                True, mode="drop")
            return assignment, core_sizes, edge_active

        assignment, core_sizes, edge_active = jax.lax.cond(
            any_win, lambda a: a, rescue,
            (assignment, core_sizes, edge_active))
        return assignment, core_sizes, edge_active, key, jnp.int32(0)

    def cond(carry):
        assignment, core_sizes, *_ = carry
        return jnp.any(assignment < 0) & jnp.any(core_sizes < targets)

    carry = (assignment, core_sizes, edge_active, key, jnp.int32(0))
    assignment, core_sizes, *_ = jax.lax.while_loop(cond, step, carry)
    # distribute leftovers by per-partition deficit (keeps balance exact)
    deficit = jnp.maximum(targets - core_sizes, 0)
    bounds = jnp.cumsum(deficit)
    rank = jnp.cumsum((assignment < 0).astype(jnp.int32)) - 1
    part_for_rank = jnp.searchsorted(bounds, rank, side="right")
    part_for_rank = jnp.minimum(part_for_rank, k - 1).astype(jnp.int32)
    assignment = jnp.where(assignment < 0, part_for_rank, assignment)
    return assignment


def hype_parallel_partition(hg: Hypergraph, k: int, *, candidates: int = 4,
                            seed: int = 0) -> np.ndarray:
    """Parallel k-way neighborhood expansion (beyond-paper, TPU-native)."""
    ph = PaddedHypergraph.from_hypergraph(hg)
    return np.asarray(_parallel_impl(ph, k, candidates, seed))
