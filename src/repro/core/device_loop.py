"""Fully device-resident HYPE superstep loop (DESIGN.md §4i).

One ``lax.while_loop`` program runs the entire k-way growth round —
[stage-0 pool maintenance → store take → pins gather/dedup → per-slot
liveness/draw/restart → requeue → gather → score+select kernel → admit
→ exact cache decrement → activation] — with every piece of the host
scheduler's mutable state (assignment, score cache, candidate pools,
the sorted bucket store, pending decrements, the random-restart
stream pointer) carried as device arrays. The host uploads the graph
image once and, per *chunk* of supersteps, downloads only a handful of
scalars (flags / progress / acc); full state comes back only at
snapshot boundaries and at the end.

Parity contract: with matching knobs this loop is **bit-identical** to
``hype_superstep`` at ``pipeline_depth=1`` (golden-hashed in
tests/test_hype_device.py). The invariants that make that possible:

* The host's sorted int64 bucket store ``(ph<<50 | cls<<44 | seq)`` is
  re-encoded per phase as fixed-width ``(kG, SP)`` int32 rows with key
  ``(cls << 25) | seq``; back-inserted seqs ascend from ``SEQ0`` and
  requeue seqs descend from ``SEQ0 - 1``, so within-phase (cls, seq)
  order equals the host's within-phase (cls, global-seq) order — and
  only within-phase order is observable (takes are per-phase prefixes).
* All three store-insertion blocks (requeue, restart activations,
  winner activations) are built already sorted, so merging is two
  ``searchsorted`` scatters per phase — no sorts on the store itself.
* Random restarts replay ``random_unassigned`` exactly, including its
  dynamic chunk width ``max(1024, count)`` and skip-pointer advance.
* Restart activations are deferred to the end of the round but filter
  edge deaths with a per-round ``dead_slot`` minimum so they observe
  exactly the deaths that had happened by their pack slot.

Capacity model: every variable-size host structure gets a fixed
power-of-two capacity planned by :func:`plan_caps`. Overflow never
produces a wrong partition — it raises a sticky flag and the driver
re-runs (bit-identically, schedules are capacity-independent) with the
flagged capacity doubled, except seq-space exhaustion (FLAG_SEQ) which
falls back to the host engine.
"""
from __future__ import annotations

import functools as _functools
from typing import NamedTuple

import numpy as np

# int32 key pad: larger than any live key ((cls<=31)<<25 | seq < 2^30).
PAD32 = np.int32(2**31 - 1)
# Per-phase seq origin: back inserts ascend from SEQ0, requeue descends
# from SEQ0-1; FLAG_SEQ fires before either side leaves [0, 2^25).
SEQ0 = 1 << 24
CLS_SHIFT = 25          # device key = (cls << CLS_SHIFT) | seq
CLS_CLAMP = 18          # store-take size clamp, see _round stage A
DEAD_NEVER = 1 << 30    # dead_slot value for "not killed this round"

# Host store key layout (mirrors engines.pipeline._PH_SHIFT/_CLS_SHIFT;
# duplicated here so the module imports without the engine).
_HOST_PH_SHIFT = 50
_HOST_CLS_SHIFT = 44

# Sticky overflow / fault flags (bitmask in carry["flags"]).
FLAG_POISON = 1         # kernel NaN survived a clean-bias replay
FLAG_STORE = 2          # per-phase store rows exceeded SP
FLAG_ACT = 4            # one activation batch exceeded ACT per phase
FLAG_RAWT = 8           # flat activation walk exceeded RAWT slots
FLAG_RAWD = 16          # flat decrement walk exceeded RAWD slots
FLAG_SEQ = 32           # per-phase seq space exhausted (unrecoverable)

# Loop counter slots in the carry["stats"] vector.
S_ROUNDS = 0
S_KERNEL_ROWS = 1
S_EDGES_SCANNED = 2
S_CACHE_INV = 3
S_CACHE_HITS = 4
S_RESTARTS = 5
S_STALE = 6
S_RETRIES = 7
S_REFILL = 8
S_PACK_ONLY = 9
S_STORE_PEAK = 10
NSTATS = 11


class DeviceLoopConfig(NamedTuple):
    """Static (trace-time) shape of one device-loop program."""

    n: int              # vertices
    m: int              # hyperedges
    kG: int             # phases (k)
    rows: int           # fresh tile rows per phase (R)
    pool_cap: int       # held-pool slots per phase (P)
    t: int              # select_k / max admissions per phase per step
    tile_l: int         # adjacency tile width
    bud: int            # store-take row budget ceiling per phase
    pp: int             # pins-gather width per phase (bud + max edge)
    sp: int             # store rows per phase
    act: int            # activation insert width per phase
    rawt: int           # flat activation CSR-walk slots
    rawd: int           # flat decrement CSR-walk slots
    cw: int             # random-draw scan window (max(1024, t))
    cache_f16: bool     # store the score cache as float16 between steps
    interpret: bool     # Pallas interpret mode


def _pow2ceil(x: int) -> int:
    return 1 if x <= 1 else 1 << (int(x) - 1).bit_length()


def plan_caps(*, n, m, kG, rows, t, mean_vdeg, mean_adeg, max_edge,
              resume_store_max=0, store_cap=None, act_cap=None,
              rawt_cap=None, rawd_cap=None):
    """Pick the static capacities for :class:`DeviceLoopConfig`.

    Heuristics sized from measured occupancies (reddit-quick per-phase
    store peak ~29k at m=105847); every cap is a power of two so the
    doubling-on-overflow rerun ladder converges in a few steps.
    ``resume_store_max`` lets a snapshot resume start above the
    fresh-run heuristic. Returns a dict of cap fields.
    """
    bud = max(4 * int(rows), 512)
    pp = bud + _pow2ceil(max_edge)
    sp = store_cap or min(
        _pow2ceil(m),
        _pow2ceil(max(4096, int(resume_store_max), m // 4 + 4 * bud)))
    act = act_cap or min(
        _pow2ceil(m), _pow2ceil(max(1024, int(2 * t * mean_vdeg))))
    rawt = rawt_cap or _pow2ceil(max(16384, int(2 * kG * t * mean_vdeg)))
    rawd = rawd_cap or _pow2ceil(max(16384, int(2 * kG * t * mean_adeg)))
    return dict(bud=bud, pp=pp, sp=sp, act=act, rawt=rawt, rawd=rawd,
                cw=max(1024, int(t)))


def supported(*, n, m, kG, bud) -> bool:
    """Static gates for the int32 device encoding (else host engine).

    ``bud * 2^CLS_CLAMP < 2^31`` keeps the stage-A size cumsum exact in
    int32 even when every taken row clamps (a clamped row is always
    bigger than any budget, so clamping never changes the take set).
    """
    return (kG * m < 2**31 and m < 2**26
            and bud * (1 << CLS_CLAMP) < 2**31 and n < 2**31)


def host_store_to_device(bq_key, bq_edge, kG, sp):
    """Re-encode the host's sorted int64 store as per-phase int32 rows.

    Host keys are globally sorted by ``(ph, cls, seq)``; per phase the
    rows are emitted in that order with fresh device seqs ascending
    from ``SEQ0``, which preserves the within-phase relative order —
    the only order the take/requeue machinery observes. Returns
    ``(skey, sedge, sback, sfront)`` or None if a phase overflows
    ``sp`` (caller re-plans with a bigger store).
    """
    skey = np.full((kG, sp), PAD32, dtype=np.int32)
    sedge = np.full((kG, sp), -1, dtype=np.int32)
    sback = np.full(kG, SEQ0, dtype=np.int32)
    sfront = np.full(kG, SEQ0 - 1, dtype=np.int32)
    key = np.asarray(bq_key, dtype=np.int64)
    bounds = np.searchsorted(
        key, np.arange(kG + 1, dtype=np.int64) << _HOST_PH_SHIFT)
    for g in range(kG):
        lo, hi = int(bounds[g]), int(bounds[g + 1])
        c = hi - lo
        if c > sp:
            return None
        cls = ((key[lo:hi] >> _HOST_CLS_SHIFT) & np.int64(63)).astype(
            np.int32)
        skey[g, :c] = (cls << CLS_SHIFT) | (SEQ0 + np.arange(
            c, dtype=np.int32))
        sedge[g, :c] = bq_edge[lo:hi]
        sback[g] = SEQ0 + c
    return skey, sedge, sback, sfront


def carry_bytes(carry) -> int:
    """Total bytes of the device-resident loop state (for BENCH meta)."""
    tot = 0
    for v in carry.values():
        tot += int(np.asarray(v).nbytes) if np.isscalar(v) or getattr(
            v, "nbytes", None) is None else int(v.nbytes)
    return tot


@_functools.lru_cache(maxsize=None)
def _device_loop_program(cfg: DeviceLoopConfig):
    import jax
    import jax.numpy as jnp

    from repro.kernels.hype_score.kernel import SELECT_PAD
    from repro.kernels.hype_score.ops import hype_score_select
    from . import scoring as _scoring

    n, m, kG = cfg.n, cfg.m, cfg.kG
    R, P, t, L = cfg.rows, cfg.pool_cap, cfg.t, cfg.tile_l
    BUD, PP, SP = cfg.bud, cfg.pp, cfg.sp
    ACT, RAWT, RAWD, CW = cfg.act, cfg.rawt, cfg.rawd, cfg.cw
    i32, f32 = jnp.int32, jnp.float32
    PADK = jnp.int32(int(PAD32))

    def _exclusive(x):
        c = jnp.cumsum(x)
        return c - x

    def _merge1(ak, av, bk, bv):
        """Merge two sorted (PADK-padded) key rows; keep the first SP.

        Keys are globally unique and pad destinations are provably
        collision-free (an a-pad lands at index + live_b < SA + live_b,
        a b-pad at index + SA >= SA + live_b), so two plain scatters
        replace a sort.
        """
        SA, SB = ak.shape[0], bk.shape[0]
        pa = jnp.arange(SA, dtype=i32) + jnp.searchsorted(
            bk, ak, side="left").astype(i32)
        pb = jnp.arange(SB, dtype=i32) + jnp.searchsorted(
            ak, bk, side="right").astype(i32)
        ok = jnp.full(SA + SB, PADK, i32).at[pa].set(ak).at[pb].set(bk)
        ov = jnp.full(SA + SB, -1, i32).at[pa].set(av).at[pb].set(bv)
        return ok[:SP], ov[:SP]

    _merge = jax.vmap(_merge1)

    def run_factory(consts):
        adj_indptr = consts["adj_indptr"]
        adj_indices = consts["adj_indices"]
        v2e_indptr = consts["v2e_indptr"]
        v2e_indices = consts["v2e_indices"]
        e2v_indptr = consts["e2v_indptr"]
        e2v_indices = consts["e2v_indices"]
        cls_edge = consts["cls_edge"]
        deg = consts["deg"]
        vdeg = consts["vdeg"]
        targets = consts["targets"]
        rand_order = consts["rand_order"]
        fringe = consts["fringe"]

        def _activate(vs_grid, deadfn, eq, sback, skey, sedge, flags):
            """Queue the edges incident to ``vs_grid`` admissions.

            Mirrors ``activate_many``: one flat RAWT-slot CSR walk over
            every (phase, vertex) row, dedup of (phase, edge) keys, a
            (ph, cls, e)-ordered compaction (== the host lexsort), and
            a pre-sorted per-phase insertion block merged into the
            store. Returns updated (eq, sback, skey, sedge, flags).
            """
            W = vs_grid.shape[1]
            vflat = vs_grid.reshape(-1)
            phflat = jnp.arange(kG * W, dtype=i32) // W
            vok = vflat >= 0
            vsafe = jnp.where(vok, vflat, 0)
            vd = jnp.where(vok, vdeg[vsafe], 0)
            offs = _exclusive(vd)
            total = vd.sum()
            pos = jnp.arange(RAWT, dtype=i32)
            owner = jnp.searchsorted(offs, pos, side="right").astype(
                i32) - 1
            pvalid = pos < total
            own = jnp.where(pvalid, owner, 0)
            eidx = v2e_indptr[vsafe[own]] + pos - offs[own]
            e = v2e_indices[jnp.where(pvalid, eidx, 0)]
            oph = phflat[own]
            key = oph * m + e
            qrow = eq.reshape(-1)[jnp.where(pvalid, key, 0)]
            live = pvalid & ~qrow & ~deadfn(e, oph)
            sk = jnp.sort(jnp.where(live, key, PADK))
            prevk = jnp.concatenate([jnp.full(1, -1, i32), sk[:-1]])
            first = (sk != PADK) & (sk != prevk)
            rank = jnp.cumsum(first.astype(i32)) - 1
            ckey = jnp.full(RAWT, PADK, i32).at[
                jnp.where(first, rank, RAWT)].set(sk, mode="drop")
            uvalid = ckey != PADK
            uph = jnp.where(uvalid, ckey // m, kG)
            ue = jnp.where(uvalid, ckey % m, 0)
            ucls = cls_edge[ue]
            # reorder (ph, e) -> (ph, cls, e); stable sort keeps the
            # within-(ph, cls) e-ascending order the host lexsort gives
            okey = jnp.where(uvalid, uph * 64 + ucls, 64 * kG + 63)
            perm = jnp.argsort(okey)
            uph, ue, ucls, uvalid = (uph[perm], ue[perm], ucls[perm],
                                     uvalid[perm])
            grank = jnp.arange(RAWT, dtype=i32)
            local = grank - jnp.searchsorted(
                uph, uph, side="left").astype(i32)
            cnt = jnp.zeros(kG, i32).at[
                jnp.where(uvalid, uph, kG)].add(1, mode="drop")
            seq = sback[jnp.where(uvalid, uph, 0)] + local
            nkey = jnp.where(uvalid, (ucls << CLS_SHIFT) | seq, PADK)
            sback = sback + cnt
            flags = flags | jnp.where(
                (sback >= (1 << CLS_SHIFT)).any(), FLAG_SEQ, 0)
            flags = flags | jnp.where(total > RAWT, FLAG_RAWT, 0)
            flags = flags | jnp.where((cnt > ACT).any(), FLAG_ACT, 0)
            rows_ = jnp.where(uvalid, uph, kG)
            cols_ = jnp.minimum(local, ACT)
            ins_k = jnp.full((kG, ACT), PADK, i32).at[
                rows_, cols_].set(nkey, mode="drop")
            ins_e = jnp.full((kG, ACT), -1, i32).at[rows_, cols_].set(
                jnp.where(uvalid, ue, -1), mode="drop")
            eq = eq.reshape(-1).at[
                jnp.where(uvalid, uph * m + ue, kG * m)].set(
                    True, mode="drop").reshape(kG, m)
            seg = (skey != PADK).sum(axis=1)
            flags = flags | jnp.where(
                (seg + cnt > SP).any(), FLAG_STORE, 0)
            skey, sedge = _merge(skey, sedge, ins_k, ins_e)
            return eq, sback, skey, sedge, flags

        def _decrements(vflat, pend, flags):
            """Accumulate the admissions' neighbor multiset into pend.

            The flat RAWD-slot walk over full adjacency rows replicates
            the host's ``bincount(concat(adjacency rows))`` exactly
            (duplicates included).
            """
            vok = vflat >= 0
            vsafe = jnp.where(vok, vflat, 0)
            vd = jnp.where(vok, deg[vsafe], 0)
            offs = _exclusive(vd)
            total = vd.sum()
            pos = jnp.arange(RAWD, dtype=i32)
            owner = jnp.searchsorted(offs, pos, side="right").astype(
                i32) - 1
            pvalid = pos < total
            own = jnp.where(pvalid, owner, 0)
            idx = adj_indptr[vsafe[own]] + pos - offs[own]
            nbr = adj_indices[jnp.where(pvalid, idx, 0)]
            pend = pend.at[jnp.where(pvalid, nbr, n)].add(
                1, mode="drop")
            flags = flags | jnp.where(total > RAWD, FLAG_RAWD, 0)
            return pend, flags

        def _rand_draw(assign, in_pool, ptr, cnt):
            """Exact ``random_unassigned(cnt)`` over the device stream.

            The scan window is the *dynamic* ``max(1024, cnt)`` (masked
            inside the static CW width) because the host chunk width
            feeds its pointer-advance rule. Returns (vs (t,), got,
            ptr); vs is -1-padded.
            """
            cw = jnp.maximum(jnp.int32(1024), cnt)
            vs0 = jnp.full(t, -1, i32)

            def cond(s):
                ptr_, got_, _ = s
                return (ptr_ < n) & (got_ < cnt)

            def body(s):
                ptr_, got_, vs_ = s
                csz = jnp.minimum(cw, n - ptr_)
                pos = jnp.arange(CW, dtype=i32)
                inb = pos < csz
                v = rand_order[jnp.where(inb, ptr_ + pos, 0)]
                okv = inb & (assign[v] < 0) & ~in_pool[v]
                navail = okv.sum()
                need_now = cnt - got_
                rank = jnp.cumsum(okv.astype(i32)) - 1
                take = okv & (rank < need_now)
                vs_ = vs_.at[jnp.where(take, got_ + rank, t)].set(
                    v, mode="drop")
                last = jnp.max(jnp.where(take, pos, -1))
                adv = jnp.where(navail >= need_now, last + 1, csz)
                return (ptr_ + adv, got_ + jnp.minimum(
                    navail, need_now), vs_)

            ptr, got, vs = jax.lax.while_loop(
                cond, body, (ptr, jnp.int32(0), vs0))

            def fallback(args):
                # stream exhausted: stragglers sit before the pointer —
                # host takes the remaining unassigned by ascending id
                got_, vs_ = args
                taken = jnp.zeros(n, bool).at[
                    jnp.where(vs_ >= 0, vs_, n)].set(True, mode="drop")
                remm = (assign < 0) & ~in_pool & ~taken
                rrank = jnp.cumsum(remm.astype(i32)) - 1
                tk = remm & (rrank < cnt - got_)
                vs_ = vs_.at[jnp.where(tk, got_ + rrank, t)].set(
                    jnp.arange(n, dtype=i32), mode="drop")
                return (got_ + jnp.minimum(remm.sum(), cnt - got_),
                        vs_)

            got, vs = jax.lax.cond(
                got < cnt, fallback, lambda a: a, (got, vs))
            return vs, got, ptr

        _TRUNC = jnp.float32(_scoring.TRUNC_PENALTY)
        _PADSEL = jnp.float32(SELECT_PAD)
        iota_k = jnp.arange(kG, dtype=i32)
        iota_r = jnp.arange(R, dtype=i32)
        iota_pool = jnp.arange(P, dtype=i32)
        iota_bud = jnp.arange(BUD, dtype=i32)

        def _round(c, poison_at):
            """One full host round: pack + dispatch + harvest."""
            assign, cache, acc = c["assign"], c["cache"], c["acc"]
            in_pool = c["in_pool"]
            cache_scored = c["cache_scored"]
            eq, edge_dead = c["edge_queued"], c["edge_dead"]
            skey, sedge = c["skey"], c["sedge"]
            sback, sfront = c["sback"], c["sfront"]
            pool, pool_n = c["pool"], c["pool_n"]
            pend, rand_ptr = c["pend"], c["rand_ptr"]
            ss, flags, stats = c["supersteps"], c["flags"], c["stats"]
            pre_dead = edge_dead    # death view at the top of the round

            # -- slot order: host rolls the ascending active ids by the
            #    superstep counter
            active_mask = acc < targets
            n_active = jnp.maximum(active_mask.sum().astype(i32), 1)
            ord0 = jnp.argsort(jnp.where(active_mask, 0, 1))
            rot = ss % n_active
            order_arr = jnp.where(
                iota_k < n_active, ord0[(rot + iota_k) % n_active], -1)

            # -- stage 0: drop stale held ids, size each phase's draw
            psafe = jnp.where(pool >= 0, pool, 0)
            keep = (pool >= 0) & (assign[psafe] < 0)
            in_pool = in_pool.at[jnp.where(
                (pool >= 0) & ~keep, pool, n).reshape(-1)].set(
                    False, mode="drop")
            perm0 = jnp.argsort(jnp.where(keep, 0, 1), axis=1)
            pool_n = keep.sum(axis=1).astype(i32)
            pool = jnp.where(
                iota_pool[None, :] < pool_n[:, None],
                jnp.take_along_axis(pool, perm0, axis=1), -1)
            need = jnp.where(
                active_mask, jnp.minimum(R, P - pool_n), 0)
            budget = jnp.where(
                need > 0, jnp.maximum(4 * need, 512), 0)

            # -- stage A: greedy smallest-class prefix take per phase.
            #    csize clamps at 2^CLS_CLAMP (> any budget — the gate
            #    guarantees BUD < 2^CLS_CLAMP) which keeps int32 exact:
            #    a clamped row can only ever be the LAST taken row.
            sl_key, sl_edge = skey[:, :BUD], sedge[:, :BUD]
            live_row = sl_key != PADK
            cls_row = jnp.where(live_row, sl_key >> CLS_SHIFT, 0)
            csize = jnp.where(live_row, jnp.left_shift(
                1, jnp.minimum(cls_row, CLS_CLAMP)), 0)
            excl = jnp.cumsum(csize, axis=1) - csize
            take = live_row & (excl < budget[:, None])
            T = take.sum(axis=1).astype(i32)
            ek = jnp.where(take, sl_edge, -1)
            tcls = jnp.where(take, cls_row, 0)
            iota_sp = jnp.arange(SP, dtype=i32)[None, :]
            src = iota_sp + T[:, None]
            srcc = jnp.minimum(src, SP - 1)
            skey = jnp.where(
                src < SP, jnp.take_along_axis(skey, srcc, 1), PADK)
            sedge = jnp.where(
                src < SP, jnp.take_along_axis(sedge, srcc, 1), -1)

            # -- pins gather: one flat PP-slot walk per phase (the PP
            #    bound sum(taken sizes) <= BUD + max_edge is proven in
            #    DESIGN.md §4i — no overflow flag needed) + stream-order
            #    first-occurrence dedup
            ek_safe = jnp.where(take, ek, 0)
            esz = jnp.where(take, e2v_indptr[ek_safe + 1]
                            - e2v_indptr[ek_safe], 0)
            offs_ex = jnp.concatenate(
                [jnp.zeros((kG, 1), i32), jnp.cumsum(esz, axis=1)], 1)
            total_g = offs_ex[:, -1]
            pos_pp = jnp.arange(PP, dtype=i32)
            jcol = jax.vmap(lambda o: jnp.searchsorted(
                o, pos_pp, side="right"))(offs_ex).astype(i32) - 1
            pv = pos_pp[None, :] < total_g[:, None]
            jsafe = jnp.where(pv, jcol, 0)
            eoj = jnp.take_along_axis(ek_safe, jsafe, 1)
            obase = jnp.take_along_axis(offs_ex, jsafe, 1)
            pidx = e2v_indptr[eoj] + pos_pp[None, :] - obase
            pins = e2v_indices[jnp.where(pv, pidx, 0)]
            stats = stats.at[S_EDGES_SCANNED].add(pv.sum())
            permd = jnp.argsort(jnp.where(pv, pins, n), axis=1)
            spin = jnp.take_along_axis(pins, permd, 1)
            svalid = jnp.take_along_axis(pv, permd, 1)
            dprev = jnp.concatenate(
                [jnp.full((kG, 1), -1, i32), spin[:, :-1]], 1)
            firsts = svalid & (spin != dprev)
            dedup = jnp.put_along_axis(
                jnp.zeros((kG, PP), bool), permd, firsts, axis=1,
                inplace=False)

            # -- stage B: rotation-ordered liveness / draws / restarts
            sB = dict(
                assign=assign, in_pool=in_pool, acc=acc,
                edge_dead=edge_dead,
                dead_slot=jnp.full(m, DEAD_NEVER, i32),
                slot_r=jnp.full(kG, -1, i32),
                pool=pool, pool_n=pool_n, rand_ptr=rand_ptr,
                fresh=jnp.full((kG, R), -1, i32),
                bias=jnp.full((kG, R), jnp.inf, f32),
                pool_arr=jnp.full((kG, P), -1, i32),
                live_rq=jnp.zeros((kG, BUD), bool),
                restart_vs=jnp.full((kG, t), -1, i32),
                injected=jnp.int32(0),
                packed_any=jnp.zeros((), bool),
                stats=stats)

            def slot_body(i, s):
                g = order_arr[i]

                def work(s):
                    gs = jnp.maximum(g, 0)
                    pins_g, pv_g = pins[gs], pv[gs]
                    # liveness of the taken edges at this phase's turn
                    unas = pv_g & (s["assign"][pins_g] < 0)
                    live_e = jnp.zeros(BUD, bool).at[jnp.where(
                        unas, jcol[gs], BUD)].set(True, mode="drop")
                    taken_g = iota_bud < T[gs]
                    live_e = live_e & taken_g
                    newly_dead = taken_g & ~live_e
                    ekg = ek[gs]
                    ed = s["edge_dead"].at[jnp.where(
                        newly_dead, ekg, m)].set(True, mode="drop")
                    dsl = s["dead_slot"].at[jnp.where(
                        newly_dead, ekg, m)].min(
                            jnp.full(BUD, i, i32), mode="drop")
                    lrq = s["live_rq"].at[gs].set(live_e)
                    # candidate draw in pin-stream first-occurrence
                    # order (== the host's np.unique first-index order)
                    okc = (dedup[gs] & pv_g & (s["assign"][pins_g] < 0)
                           & ~s["in_pool"][pins_g])
                    crank = jnp.cumsum(okc.astype(i32)) - 1
                    drawn = okc & (crank < need[gs])
                    nd = drawn.sum().astype(i32)
                    ip = s["in_pool"].at[jnp.where(
                        drawn, pins_g, n)].set(True, mode="drop")
                    sc = cache_scored[pins_g]
                    hits_m = drawn & sc
                    miss_m = drawn & ~sc
                    nh = hits_m.sum().astype(i32)
                    nm = miss_m.sum().astype(i32)
                    held = s["pool_n"][gs]
                    s = dict(s, edge_dead=ed, dead_slot=dsl,
                             live_rq=lrq, in_pool=ip)
                    is_restart = (held == 0) & (nd == 0)

                    def restart(s):
                        cnt = jnp.minimum(
                            jnp.int32(t), targets[gs] - s["acc"][gs])
                        vs, nv, ptr = _rand_draw(
                            s["assign"], s["in_pool"], s["rand_ptr"],
                            cnt)
                        st = s["stats"].at[S_RESTARTS].add(
                            (nv > 0).astype(i32))
                        asg = s["assign"].at[jnp.where(
                            vs >= 0, vs, n)].set(gs, mode="drop")
                        return dict(
                            s, assign=asg, stats=st, rand_ptr=ptr,
                            acc=s["acc"].at[gs].add(nv),
                            restart_vs=s["restart_vs"].at[gs].set(vs),
                            slot_r=s["slot_r"].at[gs].set(
                                jnp.where(nv > 0, i, -1)),
                            injected=s["injected"] + nv)

                    def pack(s):
                        permM = jnp.argsort(jnp.where(miss_m, 0, 1))
                        mc = pins_g[permM][:R]
                        fr = jnp.where(iota_r < nm, mc, -1)
                        frs = jnp.where(fr >= 0, fr, 0)
                        br = jnp.where(
                            iota_r < nm,
                            jnp.where(deg[frs] > L, _TRUNC,
                                      jnp.float32(0.0)),
                            jnp.float32(jnp.inf))
                        permH = jnp.argsort(jnp.where(hits_m, 0, 1))
                        hc = pins_g[permH]
                        prow = s["pool"][gs]
                        idxh = jnp.clip(iota_pool - held, 0, PP - 1)
                        pa_row = jnp.where(
                            iota_pool < held, prow,
                            jnp.where(iota_pool < held + nh,
                                      hc[idxh], -1))
                        idxm = jnp.clip(
                            iota_pool - held - nh, 0, R - 1)
                        np_row = jnp.where(
                            iota_pool < held + nh, pa_row,
                            jnp.where(iota_pool < held + nh + nm,
                                      mc[idxm], -1))
                        st = s["stats"].at[S_KERNEL_ROWS].add(nm)
                        st = st.at[S_CACHE_HITS].add(held + nh)
                        return dict(
                            s,
                            fresh=s["fresh"].at[gs].set(fr),
                            bias=s["bias"].at[gs].set(br),
                            pool_arr=s["pool_arr"].at[gs].set(pa_row),
                            pool=s["pool"].at[gs].set(np_row),
                            pool_n=s["pool_n"].at[gs].add(nd),
                            stats=st,
                            packed_any=jnp.ones((), bool))

                    return jax.lax.cond(is_restart, restart, pack, s)

                return jax.lax.cond(g >= 0, work, lambda s: s, s)

            sB = jax.lax.fori_loop(0, kG, slot_body, sB)
            assign, in_pool, acc = sB["assign"], sB["in_pool"], sB["acc"]
            edge_dead, pool, pool_n = (sB["edge_dead"], sB["pool"],
                                       sB["pool_n"])
            rand_ptr, stats = sB["rand_ptr"], sB["stats"]
            fresh, bias, pool_arr = sB["fresh"], sB["bias"], sB["pool_arr"]
            injected, packed_any = sB["injected"], sB["packed_any"]

            # -- requeue still-live taken rows at the queue fronts
            #    (front seqs descend, so requeues sort before fresher
            #    rows of the same class — the host's global-front rule)
            rq_c = sB["live_rq"].sum(axis=1).astype(i32)
            permq = jnp.argsort(jnp.where(sB["live_rq"], 0, 1), axis=1)
            rq_e = jnp.take_along_axis(ek, permq, 1)
            rq_cl = jnp.take_along_axis(tcls, permq, 1)
            colb = iota_bud[None, :]
            rq_val = colb < rq_c[:, None]
            rq_seq = (sfront - rq_c)[:, None] + 1 + colb
            rq_key = jnp.where(
                rq_val, (rq_cl << CLS_SHIFT) | rq_seq, PADK)
            sfront = sfront - rq_c
            flags = flags | jnp.where((sfront < 0).any(), FLAG_SEQ, 0)
            seg = (skey != PADK).sum(axis=1)
            flags = flags | jnp.where(
                (seg + rq_c > SP).any(), FLAG_STORE, 0)
            skey, sedge = _merge(skey, sedge, rq_key,
                                 jnp.where(rq_val, rq_e, -1))

            # -- deferred restart activations: filter deaths with the
            #    per-round dead_slot so each sees exactly the deaths
            #    that had happened by its pack slot; their neighbor
            #    decrements join pend now (host drains the restart
            #    delta at THIS round's dispatch)
            dead_slot, slot_r = sB["dead_slot"], sB["slot_r"]
            eq, sback, skey, sedge, flags = _activate(
                sB["restart_vs"],
                lambda e, ph: pre_dead[e] | (dead_slot[e]
                                             <= slot_r[ph]),
                eq, sback, skey, sedge, flags)
            pend, flags = _decrements(
                sB["restart_vs"].reshape(-1), pend, flags)

            # -- dispatch + harvest (skipped on a pack-only round:
            #    host neither bumps supersteps nor drains decrements)
            D = dict(assign=assign, cache=cache, acc=acc,
                     in_pool=in_pool, cache_scored=cache_scored,
                     eq=eq, edge_dead=edge_dead, skey=skey,
                     sedge=sedge, sback=sback, pend=pend,
                     pool=pool, pool_n=pool_n, supersteps=ss,
                     flags=flags, stats=stats,
                     ss_in_chunk=c["ss_in_chunk"], nwin=jnp.int32(0))

            def dispatch(D):
                ss = D["supersteps"] + 1
                stats = D["stats"].at[S_CACHE_INV].add(
                    (D["pend"] > 0).sum())
                c32 = (D["cache"].astype(f32) if cfg.cache_f16
                       else D["cache"])
                # exact decrement drain: one full-array subtract is
                # bit-equal to the host's scatter-add of -counts
                # (x - 0.0 == x; the cache never holds -0.0)
                c32 = c32 - D["pend"].astype(f32)
                pend = jnp.zeros_like(D["pend"])
                assign = D["assign"]
                flat = fresh.reshape(-1)
                tile = _scoring._gather_fresh_tiles(
                    adj_indptr, adj_indices, assign, flat, L)
                prev, n_stale = _scoring._stale_masked_prev(
                    pool_arr, assign, c32)
                bad_bias = jnp.where(
                    fresh >= 0, jnp.float32(jnp.nan), bias)
                bias_used = jnp.where(ss == poison_at, bad_bias, bias)

                def kernel(b):
                    return hype_score_select(
                        tile.reshape(kG, R, L), fringe, b, prev,
                        select_k=t, interpret=cfg.interpret,
                        with_remaining=True)

                out = kernel(bias_used)

                def _bad(o):
                    return ((flat >= 0)
                            & ~jnp.isfinite(o[0].reshape(-1))).any()

                pois = _bad(out)
                # poisoned scores admit nothing: replay in-place with
                # the clean bias (the host's _RESET1 replay)
                out = jax.lax.cond(
                    pois, lambda _: kernel(bias), lambda o: o, out)
                scores, sel_idx, sel_val, rem = out
                stats = stats.at[S_RETRIES].add(pois.astype(i32))
                flags = D["flags"] | jnp.where(
                    _bad(out), FLAG_POISON, 0)
                phase_has = ((fresh >= 0).any(axis=1)
                             | (pool_arr >= 0).any(axis=1))
                stats = stats.at[S_REFILL].add(
                    (phase_has & (rem < t)).sum())
                c32 = c32.at[jnp.where(flat >= 0, flat, n)].set(
                    scores.reshape(-1), mode="drop")
                slots = jnp.concatenate([fresh, pool_arr], axis=1)
                cand = jnp.take_along_axis(slots, sel_idx, axis=1)
                okw = (sel_val < _PADSEL) & (cand >= 0)
                okw &= assign[jnp.where(cand >= 0, cand, 0)] < 0
                cap = jnp.maximum(targets - D["acc"], 0)
                rankw = jnp.cumsum(okw.astype(i32), axis=1)
                adm = okw & (rankw <= cap[:, None])
                winners = jnp.where(adm, cand, -1)
                phase_row = jax.lax.broadcasted_iota(
                    i32, adm.shape, 0)
                assign = assign.at[jnp.where(adm, cand, n)].set(
                    phase_row, mode="drop")
                acc = D["acc"] + adm.sum(axis=1, dtype=i32)
                # harvest: mirror of the host's post-kernel pass
                stats = stats.at[S_STALE].add(n_stale)
                cache_scored = D["cache_scored"].at[jnp.where(
                    flat >= 0, flat, n)].set(True, mode="drop")
                in_pool = D["in_pool"].at[jnp.where(
                    winners >= 0, winners, n).reshape(-1)].set(
                        False, mode="drop")
                nwin = (winners >= 0).sum().astype(i32)
                edge_dead = D["edge_dead"]
                eq, sback, skey, sedge, flags = _activate(
                    winners, lambda e, ph: edge_dead[e], D["eq"],
                    D["sback"], D["skey"], D["sedge"], flags)
                pend, flags = _decrements(
                    winners.reshape(-1), pend, flags)
                # release completed phases' pools
                done = adm.any(axis=1) & (acc >= targets)
                pool = D["pool"]
                in_pool = in_pool.at[jnp.where(
                    done[:, None] & (pool >= 0), pool,
                    n).reshape(-1)].set(False, mode="drop")
                pool = jnp.where(done[:, None], -1, pool)
                pool_n = jnp.where(done, 0, D["pool_n"])
                cache = (jnp.clip(c32, -65504.0, 65504.0).astype(
                    jnp.float16) if cfg.cache_f16 else c32)
                return dict(
                    D, assign=assign, cache=cache, acc=acc,
                    in_pool=in_pool, cache_scored=cache_scored,
                    eq=eq, edge_dead=edge_dead, skey=skey,
                    sedge=sedge, sback=sback, pend=pend, pool=pool,
                    pool_n=pool_n, supersteps=ss, flags=flags,
                    stats=stats,
                    ss_in_chunk=D["ss_in_chunk"] + 1, nwin=nwin)

            def pack_only(D):
                return dict(
                    D, stats=D["stats"].at[S_PACK_ONLY].add(1))

            D = jax.lax.cond(packed_any, dispatch, pack_only, D)
            stats = D["stats"].at[S_ROUNDS].add(1)
            stats = stats.at[S_STORE_PEAK].max(
                (D["skey"] != PADK).sum())
            return dict(
                assign=D["assign"], cache=D["cache"], acc=D["acc"],
                in_pool=D["in_pool"],
                cache_scored=D["cache_scored"],
                edge_queued=D["eq"], edge_dead=D["edge_dead"],
                skey=D["skey"], sedge=D["sedge"], sback=D["sback"],
                sfront=sfront, pool=D["pool"], pool_n=D["pool_n"],
                pend=D["pend"], rand_ptr=rand_ptr,
                supersteps=D["supersteps"],
                progress=injected + D["nwin"], flags=D["flags"],
                ss_in_chunk=D["ss_in_chunk"], stats=stats)

        return _round

    @_functools.partial(jax.jit, donate_argnums=(1,))
    def run(consts, carry, chunk_cap, poison_at):
        """Run up to ``chunk_cap`` supersteps fully on device.

        ``carry`` is donated; ``chunk_cap``/``poison_at`` are traced
        scalars so chunk resizing never retraces. Pack-only rounds do
        not count against the chunk (host snapshot cadence counts
        supersteps). Exits early on completion, zero progress, or any
        sticky flag.
        """
        _round = run_factory(consts)

        def cond(c):
            return ((c["acc"] < consts["targets"]).any()
                    & (c["progress"] > 0) & (c["flags"] == 0)
                    & (c["ss_in_chunk"] < chunk_cap))

        carry = dict(carry, ss_in_chunk=jnp.int32(0))
        return jax.lax.while_loop(
            cond, lambda c: _round(c, poison_at), carry)

    return run


def device_loop_program(cfg: DeviceLoopConfig):
    """The jitted chunked device-loop runner for a static config.

    Returns ``run(consts, carry, chunk_cap, poison_at) -> carry`` with
    ``carry`` donated. See the module docstring for the state layout;
    ``repro.engines.device._run_device_loop`` is the host driver.
    """
    return _device_loop_program(cfg)
