"""Device-resident k-way refinement (DESIGN.md §4e).

A post-pass that composes with every engine: given a complete k-way
assignment, run boundary-vertex passes that move vertices between
partitions to shrink the (k-1) objective while preserving the engine's
balance guarantee. Each pass is a screen -> verify -> admit pipeline:

  1. **boundary detection** (host, one vectorized pin scan): vertices on
     cut hyperedges — the only vertices whose move can change (k-1);
  2. **screening** (device): the boundary ids go down in fixed-size
     tiles, the Pallas ``kway_gains`` kernel ranks every candidate's
     k move targets by *connectivity gain* over its (B, L)
     neighbor-partition tile, gathered from the resident
     ``Hypergraph.device_adjacency()`` image against a device-resident
     assignment that the host's admitted-move deltas keep in sync (the
     superstep engines' delta-scatter machinery, ``scoring.
     _refine_program``); only (B, k) gain rows come back;
  3. **exact verification** (host, vectorized): the top screened
     candidates get their *exact* per-edge (k-1) deltas — the
     neighborhood image cannot see pin multiplicities, so the screen
     only ranks; admission trusts nothing but the exact gain;
  4. **deterministic balance-capped admission**: positive-exact-gain
     moves are admitted greedily (gain-descending, vertex id as the tie
     break) under two caps — *edge-disjointness* (no two admitted moves
     may share a hyperedge, which makes the admitted gains exactly
     additive, so every pass provably lowers k-1 by ``stats.gain``) and
     the *balance window* ``[lo, hi]`` (per-partition size caps; the
     default window is the engines' ``max - min <= 1`` floor/ceil).
     Moves blocked only by balance wait in per-direction pending lists
     and are admitted as balance-neutral swap pairs when an opposite
     move shows up.

``refine_passes = 0`` is a strict no-op (the engines' outputs stay bit
identical); each pass early-stops the whole refinement when it admits
nothing. The same gain/admission machinery drives the rebuilt
multilevel partitioner's uncoarsening (``multilevel.py``), with vertex
weights and a widened window instead of the unit caps.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .hypergraph import Hypergraph
from . import scoring


@dataclasses.dataclass
class RefineStats:
    """Counters for one ``refine_kway`` call (BENCH ``meta.refine``)."""
    passes_run: int = 0         # passes that admitted at least one move
    boundary_rows: int = 0      # candidate rows screened (device or host)
    kernel_calls: int = 0       # device screening calls
    host_rows: int = 0          # rows screened by the host fallback path
    proposals: int = 0          # positive-exact-gain admission proposals
    moves: int = 0              # admitted moves (swap members included)
    swaps: int = 0              # balance-neutral swap pairs admitted
    gain: int = 0               # exact total k-1 reduction (additive)
    rejected_conflict: int = 0  # proposals dropped by edge-disjointness
    rejected_balance: int = 0   # proposals left pending without a partner


def _cut_boundary(hg: Hypergraph, assignment: np.ndarray) -> np.ndarray:
    """Unique vertices incident to cut hyperedges (one vectorized scan)."""
    part_of_pin = assignment[hg.e2v_indices]
    sizes = hg.edge_sizes
    nz = sizes > 0
    if not nz.any():
        return np.empty(0, dtype=np.int64)
    starts = hg.e2v_indptr[:-1][nz]
    pmin = np.minimum.reduceat(part_of_pin, starts)
    pmax = np.maximum.reduceat(part_of_pin, starts)
    cut_edges = np.flatnonzero(nz)[pmin != pmax]
    if cut_edges.size == 0:
        return np.empty(0, dtype=np.int64)
    pins, _ = scoring.gather_csr_rows(hg.e2v_indptr, hg.e2v_indices,
                                      cut_edges)
    return np.unique(pins.astype(np.int64))


def _host_gains(adj, cand: np.ndarray, assignment: np.ndarray,
                k: int) -> np.ndarray:
    """Host twin of the ``kway_gains`` screening (full-width, no tile cut)."""
    nbrs, owner = scoring.gather_csr_rows(adj[0], adj[1], cand)
    cnt = np.zeros((cand.size, k), dtype=np.int64)
    if nbrs.size:
        parts = assignment[nbrs.astype(np.int64)].astype(np.int64)
        cnt = np.bincount(owner * k + parts,
                          minlength=cand.size * k).reshape(cand.size, k)
    own = assignment[cand]
    return (cnt - cnt[np.arange(cand.size), own][:, None]).astype(
        np.float32)


def exact_gain_matrix(hg: Hypergraph, cand: np.ndarray,
                      assignment: np.ndarray, k: int) -> np.ndarray:
    """Exact per-vertex (k-1) move gains, all k targets at once.

    For ``v`` in partition ``p``, moving to ``q`` changes (k-1) by
    ``-(free(v) - pen(v, q))`` where ``free(v)`` counts incident edges
    whose only ``p``-pin is ``v`` (the move frees them from ``p``) and
    ``pen(v, q)`` counts incident edges with no ``q``-pin yet (the move
    newly stretches them into ``q``). Returned as gain = free - pen,
    positive = (k-1) drops; column ``own`` is fixed to 0. One CSR
    gather + bincounts over the candidates' incident edges — no
    (m, k) matrix is ever materialized.
    """
    M = cand.size
    gains = np.zeros((M, k), dtype=np.int64)
    es, owner = scoring.gather_csr_rows(hg.v2e_indptr, hg.v2e_indices,
                                        cand)
    if es.size == 0:
        return gains
    es = es.astype(np.int64)
    ue, inv = np.unique(es, return_inverse=True)
    pins, prow = scoring.gather_csr_rows(hg.e2v_indptr, hg.e2v_indices,
                                         ue)
    cnt = np.bincount(
        prow * k + assignment[pins.astype(np.int64)].astype(np.int64),
        minlength=ue.size * k).reshape(ue.size, k)
    own = assignment[cand].astype(np.int64)
    sole = cnt[inv, own[owner]] == 1
    free = np.bincount(owner[sole], minlength=M)
    # pen via the PRESENT (edge, partition) pairs — sparse (a cut edge
    # spans few of the k partitions), so expanding each (v, e) incidence
    # by its edge's present-partition list stays O(pins * mean span)
    pres_pairs = cnt > 0
    span = pres_pairs.sum(axis=1)
    ei, qi = np.nonzero(pres_pairs)              # sorted by edge row
    eptr = np.zeros(ue.size + 1, dtype=np.int64)
    eptr[1:] = np.cumsum(span)
    qs, pidx = scoring.gather_csr_rows(eptr, qi, inv)
    pres = np.bincount(owner[pidx] * k + qs,
                       minlength=M * k).reshape(M, k)
    deg = (hg.v2e_indptr[cand + 1] - hg.v2e_indptr[cand]).astype(np.int64)
    gains = free[:, None] - (deg[:, None] - pres)
    gains[np.arange(M), own] = 0
    return gains


def admit_moves(vs: np.ndarray, src: np.ndarray, dst: np.ndarray,
                gain: np.ndarray, hg: Hypergraph, sizes: np.ndarray,
                lo: np.ndarray, hi: np.ndarray, stats: RefineStats,
                weights: Optional[np.ndarray] = None
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Greedy edge-disjoint balance-capped admission (deterministic).

    Proposals must arrive sorted (gain descending, vertex id ascending).
    Walks them once: a proposal is admitted when none of its incident
    hyperedges is frozen by an earlier admission (edge-disjointness ->
    the admitted exact gains are additive) and the move keeps every
    partition size inside ``[lo, hi]``. Balance-blocked unit-weight
    proposals wait in per-direction pending lists and are admitted as
    swap *pairs* when an opposite-direction proposal arrives (both
    sides' edges still unfrozen and mutually disjoint). ``sizes`` is
    updated in place; returns the admitted ``(vertices, targets)``.
    """
    indptr, indices = hg.v2e_indptr, hg.v2e_indices
    frozen = np.zeros(hg.m, dtype=bool)
    pending: dict = {}
    adm_v: list = []
    adm_dst: list = []
    for i in range(vs.size):
        v, p, q = int(vs[i]), int(src[i]), int(dst[i])
        es = indices[indptr[v]:indptr[v + 1]]
        if frozen[es].any():
            stats.rejected_conflict += 1
            continue
        wv = 1 if weights is None else weights[v]
        if sizes[p] - wv >= lo[p] and sizes[q] + wv <= hi[q]:
            sizes[p] -= wv
            sizes[q] += wv
            frozen[es] = True
            adm_v.append(v)
            adm_dst.append(q)
            stats.moves += 1
            stats.gain += int(gain[i])
            continue
        if weights is None:
            matched = False
            partners = pending.get((q, p))
            if partners:
                for pos, j in enumerate(partners):
                    u = int(vs[j])
                    eu = indices[indptr[u]:indptr[u + 1]]
                    if frozen[eu].any():
                        continue        # partner went stale; skip it
                    frozen[es] = True   # mutual disjointness check
                    if frozen[eu].any():
                        frozen[es] = False
                        continue
                    frozen[eu] = True
                    adm_v.extend((v, u))
                    adm_dst.extend((q, p))
                    partners.pop(pos)
                    stats.moves += 2
                    stats.swaps += 1
                    stats.gain += int(gain[i]) + int(gain[j])
                    stats.rejected_balance -= 1   # the revived partner
                    matched = True
                    break
            if matched:
                continue
            pending.setdefault((p, q), []).append(i)
        stats.rejected_balance += 1
    return (np.asarray(adm_v, dtype=np.int64),
            np.asarray(adm_dst, dtype=np.int32))


def refine_kway(hg: Hypergraph, assignment: np.ndarray, k: int,
                passes: int, *, weights: Optional[np.ndarray] = None,
                lo: Optional[np.ndarray] = None,
                hi: Optional[np.ndarray] = None,
                cand_cap: int = 8192, tile_rows: int = 4096,
                use_device: Optional[bool] = None,
                candidates: Optional[np.ndarray] = None
                ) -> Tuple[np.ndarray, RefineStats]:
    """Run up to ``passes`` boundary-refinement passes; see module doc.

    Returns ``(refined assignment copy, RefineStats)``. With the
    default unit weights the balance window is the engines'
    ``[floor(n/k), ceil(n/k)]`` contract, widened to the incoming sizes
    when those already sit outside it (never worsening balance, never
    blocking on an inherited violation). ``weights``/``lo``/``hi``
    switch to weighted windows (the multilevel uncoarsening path; the
    swap matcher is unit-weight-only and disabled there).
    ``use_device=None`` screens on device whenever the adjacency image
    exists, the host twin otherwise; ``passes <= 0`` or ``k <= 1``
    return the input unchanged (same array, zero stats).

    ``candidates`` restricts every pass to the given vertex ids: the
    cut boundary is intersected with them before screening, so only
    those vertices can move (the streaming engine's bounded-radius
    re-expansion — dirtied neighborhoods only, never the whole graph).
    """
    stats = RefineStats()
    if passes <= 0 or k <= 1 or hg.n == 0:
        return assignment, stats
    if (assignment < 0).any():
        raise ValueError("refinement requires a complete assignment")
    assignment = np.array(assignment, dtype=np.int32, copy=True)
    n = hg.n
    if weights is None:
        sizes = np.bincount(assignment, minlength=k).astype(np.int64)
        if lo is None:
            lo = np.full(k, n // k, dtype=np.int64)
        if hi is None:
            hi = np.full(k, -(-n // k), dtype=np.int64)
    else:
        if lo is None or hi is None:
            raise ValueError("weighted refinement needs explicit lo/hi")
        sizes = np.zeros(k, dtype=np.float64)
        np.add.at(sizes, assignment, weights)
    lo = np.minimum(np.asarray(lo), sizes)   # inherited violations never
    hi = np.maximum(np.asarray(hi), sizes)   # block (nor worsen) a pass

    adj = hg.vertex_adjacency()
    if adj is None:
        return assignment, stats    # hub-expansion guard: skip refining
    use_dev = use_device if use_device is not None else True
    dev_assign = None
    if use_dev:
        dev = hg.device_adjacency()
        if dev is None:
            use_dev = False
    if use_dev:
        import jax.numpy as jnp
        from repro.kernels._compat import pallas_interpret

        interpret = pallas_interpret()
        dev_assign = jnp.asarray(assignment)
        deg = np.diff(adj[0])
        tile_l = scoring._bucket_width(int(min(
            np.percentile(deg, 99.5) if deg.size else 1,
            scoring.L_BUCKETS[-1])))
        # a pass admits at most cand_cap moves (moves <= proposals), so
        # the delta buffer must hold that many, not just one tile
        delta_cap = max(tile_rows, cand_cap)
        pend_ids = np.empty(0, dtype=np.int64)
        pend_vals = np.empty(0, dtype=np.int32)

    if candidates is not None:
        candidates = np.unique(np.asarray(candidates, dtype=np.int64))
    for _ in range(passes):
        boundary = _cut_boundary(hg, assignment)
        if candidates is not None:
            boundary = np.intersect1d(boundary, candidates,
                                      assume_unique=True)
        if boundary.size == 0:
            break
        stats.boundary_rows += int(boundary.size)
        # ---- screen: rank the boundary by best-target move gain ----
        # (the ranking only needs each row's best gain — the admitted
        # target is recomputed from the EXACT gains below)
        best_g = np.empty(boundary.size, dtype=np.float32)
        if use_dev:
            for b0 in range(0, boundary.size, tile_rows):
                chunk = boundary[b0:b0 + tile_rows]
                cand_buf = np.full(tile_rows, -1, dtype=np.int32)
                cand_buf[:chunk.size] = chunk
                delta = np.full(delta_cap, -1, dtype=np.int32)
                vals = np.zeros(delta_cap, dtype=np.int32)
                delta[:pend_ids.size] = pend_ids
                vals[:pend_ids.size] = pend_vals
                pend_ids = np.empty(0, dtype=np.int64)
                pend_vals = np.empty(0, dtype=np.int32)
                dev_assign, gains = scoring.refine_gains_device(
                    dev[0], dev[1], dev_assign, jnp.asarray(delta),
                    jnp.asarray(vals), jnp.asarray(cand_buf),
                    tile_l=tile_l, k=k, interpret=interpret)
                stats.kernel_calls += 1
                g = np.array(gains)[:chunk.size]    # writable host copy
                own = assignment[chunk]
                g[np.arange(chunk.size), own] = -np.inf
                best_g[b0:b0 + chunk.size] = g.max(axis=1)
        else:
            g = _host_gains(adj, boundary, assignment, k)
            stats.host_rows += int(boundary.size)
            own = assignment[boundary]
            g[np.arange(boundary.size), own] = -np.inf
            best_g = g.max(axis=1)
        # ---- verify: exact (k-1) gains for the top screened rows ----
        order = np.lexsort((boundary, -best_g))
        cand = boundary[order][:cand_cap]
        exact = exact_gain_matrix(hg, cand, assignment, k)
        own = assignment[cand].astype(np.int64)
        exact[np.arange(cand.size), own] = np.iinfo(np.int64).min
        bq = exact.argmax(axis=1)
        bgain = exact[np.arange(cand.size), bq]
        pos = bgain > 0
        stats.proposals += int(pos.sum())
        if not pos.any():
            break
        pv, pq, pg = cand[pos], bq[pos], bgain[pos]
        psrc = own[pos]
        order2 = np.lexsort((pv, -pg))
        adm_v, adm_dst = admit_moves(
            pv[order2], psrc[order2], pq[order2], pg[order2], hg,
            sizes, lo, hi, stats, weights=weights)
        if adm_v.size == 0:
            break
        assignment[adm_v] = adm_dst
        stats.passes_run += 1
        if use_dev:     # sync the device assignment at the next screen
            pend_ids = adm_v
            pend_vals = adm_dst
    return assignment, stats


def rebalance_kway(hg: Hypergraph, assignment: np.ndarray,
                   k: int) -> np.ndarray:
    """Force exact ``max - min <= 1`` balance with least-damage moves.

    Used by the multilevel partitioner's finest level, where projected
    coarse assignments balance coarse-vertex *weights* only. Target
    sizes are the balanced ``base (+1)`` vector permuted so the largest
    incoming partitions keep the ``+1`` slots (fewest forced moves);
    donors' vertices flow to deficit partitions in connectivity-gain
    order. Deterministic; returns a copy.
    """
    assignment = np.array(assignment, dtype=np.int32, copy=True)
    n = hg.n
    sizes = np.bincount(assignment, minlength=k).astype(np.int64)
    base, rem = divmod(n, k)
    order = np.argsort(-sizes, kind="stable")
    target = np.full(k, base, dtype=np.int64)
    target[order[:rem]] += 1
    excess = sizes - target
    if not excess.any():
        return assignment
    adj = hg.vertex_adjacency()
    donors = np.flatnonzero(excess > 0)
    cand = np.flatnonzero(np.isin(assignment, donors))
    if adj is not None:
        # chunked: the (cand, k) gain matrix of a large donor set would
        # otherwise dominate memory for the handful of needed moves
        g = np.empty((cand.size, k), dtype=np.float32)
        for c0 in range(0, cand.size, 65536):
            g[c0:c0 + 65536] = _host_gains(adj, cand[c0:c0 + 65536],
                                           assignment, k)
    else:
        g = np.zeros((cand.size, k), dtype=np.float32)
    own = assignment[cand]
    g[np.arange(cand.size), own] = -np.inf
    bg = g.max(axis=1)
    for i in np.lexsort((cand, -bg)):
        v = int(cand[i])
        p = int(assignment[v])
        if excess[p] <= 0:
            continue
        recv = excess < 0
        row = np.where(recv, g[i], -np.inf)
        q = int(row.argmax())
        if not recv[q]:
            continue
        assignment[v] = q
        excess[p] -= 1
        excess[q] += 1
        if not (excess > 0).any():
            break
    return assignment
