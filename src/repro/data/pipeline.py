"""Host data pipeline: synthetic token/recsys streams with deterministic
shard-aware iteration, prefetch, and straggler-tolerant batching.

At scale, each host process feeds only its addressable devices; the stream
is seeded by (epoch, step, shard) so any host can reproduce any batch —
this is what makes checkpoint/restart and elastic re-sharding exact: no
data-loader state needs to be saved besides the integer step.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


class TokenStream:
    """Deterministic synthetic LM token stream (shard-aware).

    Produces (tokens, labels) of shape (batch, seq). Tokens follow a
    mixture of Zipf unigrams and local n-gram structure so models can
    actually reduce loss.
    """

    def __init__(self, vocab: int, batch: int, seq: int, *,
                 shard: int = 0, n_shards: int = 1, seed: int = 0):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.shard, self.n_shards, self.seed = shard, n_shards, seed

    def batch_at(self, step: int):
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.shard)
        z = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        toks = (z - 1) % self.vocab
        # inject learnable bigram structure
        mask = rng.random((self.batch, self.seq)) < 0.5
        nxt = (toks[:, :-1] * 31 + 7) % self.vocab
        toks[:, 1:] = np.where(mask, nxt, toks[:, 1:])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class RecsysStream:
    """Synthetic two-tower interaction stream with Zipf item popularity."""

    def __init__(self, user_vocab: int, item_vocab: int, batch: int, *,
                 n_fields: int = 4, bag: int = 8, shard: int = 0, seed: int = 0):
        self.uv, self.iv, self.batch = user_vocab, item_vocab, batch
        self.n_fields, self.bag = n_fields, bag
        self.shard, self.seed = shard, seed

    def batch_at(self, step: int):
        rng = np.random.default_rng(
            (self.seed * 999_983 + step) * 65_537 + self.shard)

        def bags(vocab):
            ids = ((rng.zipf(1.2, size=(self.batch, self.n_fields, self.bag))
                    - 1) % vocab).astype(np.int32)
            drop = rng.random(ids.shape) < 0.3
            return np.where(drop, -1, ids)

        item_ids = bags(self.iv)
        # logQ = log sampling probability of the positive item (approx zipf)
        first = np.maximum(item_ids[:, 0, 0], 1).astype(np.float64)
        logq = (-1.2 * np.log(first)).astype(np.float32)
        return {"user_ids": bags(self.uv), "item_ids": item_ids,
                "item_logq": logq}


class Prefetcher:
    """Background-thread prefetch with a bounded queue and timeout skip.

    ``timeout_s`` models straggler mitigation at the data tier: when a
    batch is late the previous batch is re-served (training prefers a
    duplicate gradient over a stalled step); skipped steps are counted.
    """

    def __init__(self, it: Iterator, depth: int = 4,
                 timeout_s: float | None = None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._timeout = timeout_s
        self._last = None
        self.skipped = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        for item in self._it:
            if self._stop.is_set():
                return
            self._q.put(item)

    def __iter__(self):
        return self

    def __next__(self):
        try:
            item = self._q.get(timeout=self._timeout) \
                if self._timeout else self._q.get()
            self._last = item
            return item
        except queue.Empty:
            if self._last is None:
                raise
            self.skipped += 1
            return self._last

    def close(self):
        self._stop.set()
