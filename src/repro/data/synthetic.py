"""Synthetic hypergraph / graph generators.

The container has no network access, so the paper's datasets (Github,
StackOverflow, Reddit — Table II) are modelled by generators that match
their two key structural properties (paper §II):

  * power-law vertex degrees AND hyperedge sizes,
  * strong local community structure with a long tail of hub hyperedges.

``community_hypergraph`` plants communities explicitly so that partition
quality differences between structure-aware (HYPE) and structure-oblivious
(MinMax/random) partitioners are measurable, mirroring the real-data
behaviour reported in the paper.
"""
from __future__ import annotations

import numpy as np

from repro.core.hypergraph import Hypergraph


def _powerlaw_sizes(rng, count, alpha, lo, hi):
    """Discrete power-law samples in [lo, hi] via inverse CDF."""
    u = rng.random(count)
    a1 = 1.0 - alpha
    x = ((hi ** a1 - lo ** a1) * u + lo ** a1) ** (1.0 / a1)
    return np.clip(x.astype(np.int64), lo, hi)


def powerlaw_hypergraph(n: int, m: int, *, alpha_edge: float = 2.2,
                        alpha_vertex: float = 2.5, max_edge: int | None = None,
                        max_degree: int | None = None, seed: int = 0,
                        locality: float = 0.9) -> Hypergraph:
    """Power-law hyperedge sizes AND vertex degrees, with spatial locality.

    Configuration-model style: every vertex gets a power-law number of
    "stub slots" laid out contiguously on a ring, so that (a) pin sampling
    is degree-weighted (power-law vertex degrees emerge) and (b) sampling a
    window of slots around a hyperedge's center produces local community
    structure. A ``1 - locality`` fraction of pins is drawn globally (the
    long-range tail). Hub hyperedges (large windows) span many communities,
    matching the structure of the paper's Github/StackOverflow/Reddit data.
    """
    rng = np.random.default_rng(seed)
    max_edge = max_edge or max(4, n // 20)
    max_degree = max_degree or max(4, m // 20)
    sizes = _powerlaw_sizes(rng, m, alpha_edge, 2, max_edge)
    degs = _powerlaw_sizes(rng, n, alpha_vertex, 1, max_degree)
    # ring of stub slots; vertex v owns a contiguous run of degs[v] slots
    slots = np.repeat(np.arange(n, dtype=np.int64), degs)
    n_slots = slots.size
    total = int(sizes.sum())
    edge_of_pin = np.repeat(np.arange(m, dtype=np.int64), sizes)

    # Hierarchical locality: pins are placed at heavy-tailed (Pareto)
    # displacements from the hyperedge's center, creating community
    # structure at every scale — tight micro-communities, overlapping
    # meso-communities, and a global tail — as observed in real
    # affiliation networks (paper §II).
    centers = rng.integers(0, n_slots, size=m)
    center_of_pin = centers[edge_of_pin]
    local = rng.random(total) < locality
    u = rng.random(total)
    beta = 0.9
    disp = (2.0 * u ** (-1.0 / beta)).astype(np.int64)
    disp = np.minimum(disp, n_slots // 2)
    sign = rng.integers(0, 2, size=total) * 2 - 1
    local_slot = (center_of_pin + sign * disp) % n_slots
    global_slot = rng.integers(0, n_slots, size=total)
    pins = slots[np.where(local, local_slot, global_slot)]
    return Hypergraph.from_pins(n, m, pins, edge_of_pin)


def community_hypergraph(n: int, m: int, n_communities: int, *,
                         p_intra: float = 0.95, alpha_edge: float = 2.3,
                         max_edge: int | None = None, seed: int = 0) -> Hypergraph:
    """Planted-community hypergraph.

    Each hyperedge belongs to a community; ``p_intra`` of its pins come from
    that community, the rest are global. The planted assignment gives a
    quality reference point for partitioners.
    """
    rng = np.random.default_rng(seed)
    max_edge = max_edge or max(4, n // n_communities)
    sizes = _powerlaw_sizes(rng, m, alpha_edge, 2, max_edge)
    total = int(sizes.sum())
    comm_of_edge = rng.integers(0, n_communities, size=m)
    edge_of_pin = np.repeat(np.arange(m, dtype=np.int64), sizes)
    comm_of_pin = comm_of_edge[edge_of_pin]
    csize = n // n_communities
    intra = rng.random(total) < p_intra
    local_pins = comm_of_pin * csize + rng.integers(0, csize, size=total)
    global_pins = rng.integers(0, n, size=total)
    pins = np.where(intra, local_pins, global_pins)
    pins = np.clip(pins, 0, n - 1)
    return Hypergraph.from_pins(n, m, pins, edge_of_pin)


# --- scale models of the paper's datasets (Table II), default scaled to CPU ---

def github_like(scale: float = 1.0, seed: int = 0) -> Hypergraph:
    """Github: 177,386 vertices / 56,519 hyperedges / 440,237 pins."""
    n = int(177_386 * scale)
    m = int(56_519 * scale)
    return powerlaw_hypergraph(n, m, alpha_edge=2.0, max_edge=max(8, n // 40),
                               seed=seed)


def stackoverflow_like(scale: float = 1.0, seed: int = 0) -> Hypergraph:
    """StackOverflow: 641,876 vertices / 545,196 hyperedges / 1.3M pins."""
    n = int(641_876 * scale)
    m = int(545_196 * scale)
    return powerlaw_hypergraph(n, m, alpha_edge=2.6, max_edge=max(8, n // 100),
                               seed=seed)


def reddit_like(scale: float = 0.02, seed: int = 0) -> Hypergraph:
    """Reddit: 430,156 vertices / 21.2M hyperedges / 179.7M pins.

    Default scale 0.02 keeps host benchmarks tractable (~8.6k vertices,
    ~424k hyperedges, ~3.6M pins) while preserving the extreme
    hyperedges-per-vertex ratio that makes Reddit hard.
    """
    n = int(430_156 * scale)
    m = int(21_169_586 * scale)
    return powerlaw_hypergraph(n, m, alpha_edge=2.4, max_edge=max(8, n // 4),
                               seed=seed)
