"""Graph generators + batch builders for the GNN architectures.

Generates statically-shaped ``GraphBatch`` dicts (see models/gnn.py) for
the four assigned GNN shapes:

  full_graph_sm  n=2,708  e=10,556    d_feat=1,433   (cora-scale)
  minibatch_lg   n=232,965 e=114.6M   batch=1,024 fanout 15-10 (reddit-scale)
  ogb_products   n=2,449,029 e=61.9M  d_feat=100     (full-batch-large)
  molecule       n=30 e=64 batch=128  (batched small graphs)

Full-batch-large graphs are only materialized as ShapeDtypeStructs by the
dry-run; generators here produce *scaled* host-side graphs for smoke tests
and end-to-end examples.
"""
from __future__ import annotations

import numpy as np


def random_graph(n: int, avg_degree: float, seed: int = 0,
                 power_law: bool = True):
    """Directed edge list with power-law-ish out-degrees (src, dst)."""
    rng = np.random.default_rng(seed)
    n_edges = int(n * avg_degree)
    if power_law:
        # preferential-attachment-flavoured endpoints
        u = rng.random(n_edges * 2)
        idx = ((u ** 2.5) * n).astype(np.int64) % n
        src, dst = idx[:n_edges], idx[n_edges:]
    else:
        src = rng.integers(0, n, n_edges)
        dst = rng.integers(0, n, n_edges)
    keep = src != dst
    return src[keep].astype(np.int32), dst[keep].astype(np.int32)


def build_graph_batch(n: int, src: np.ndarray, dst: np.ndarray, d_feat: int,
                      n_classes: int, seed: int = 0, d_edge: int = 4,
                      n_graphs: int = 1, pad_nodes: int | None = None,
                      pad_edges: int | None = None):
    """Statically-shaped GraphBatch with masks; labels correlated with
    features so training can actually learn."""
    rng = np.random.default_rng(seed)
    N = pad_nodes or n
    E = pad_edges or src.size
    assert N >= n and E >= src.size
    nodes = np.zeros((N, d_feat), np.float32)
    labels = np.zeros((N,), np.int32)
    proto = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    lab = rng.integers(0, n_classes, n)
    nodes[:n] = proto[lab] * 0.5 + rng.normal(size=(n, d_feat)) * 0.5
    labels[:n] = lab
    es = np.zeros((E,), np.int32)
    ed = np.zeros((E,), np.int32)
    es[:src.size] = src
    ed[:dst.size] = dst
    emask = np.zeros((E,), bool)
    emask[:src.size] = True
    nmask = np.zeros((N,), bool)
    nmask[:n] = True
    gid = np.zeros((N,), np.int32)
    if n_graphs > 1:
        per = n // n_graphs
        gid[:n] = np.minimum(np.arange(n) // per, n_graphs - 1)
    return {
        "nodes": nodes,
        "pos": rng.normal(size=(N, 3)).astype(np.float32) * 3.0,
        "edge_src": es,
        "edge_dst": ed,
        "edge_x": rng.normal(size=(E, d_edge)).astype(np.float32),
        "node_mask": nmask,
        "edge_mask": emask,
        "graph_id": gid,
        "labels": labels,
        "targets": nodes[:, :d_feat].astype(np.float32),
        "graph_targets": rng.normal(size=(max(n_graphs, 1),)).astype(np.float32),
    }


def molecule_batch(n_mols: int = 128, n_atoms: int = 30, n_bonds: int = 64,
                   d_feat: int = 16, seed: int = 0):
    """Batch of small molecules flattened into one padded graph."""
    rng = np.random.default_rng(seed)
    N = n_mols * n_atoms
    E = n_mols * n_bonds
    src = np.zeros((E,), np.int32)
    dst = np.zeros((E,), np.int32)
    for g in range(n_mols):
        s = rng.integers(0, n_atoms, n_bonds) + g * n_atoms
        d = rng.integers(0, n_atoms, n_bonds) + g * n_atoms
        src[g * n_bonds:(g + 1) * n_bonds] = s
        dst[g * n_bonds:(g + 1) * n_bonds] = d
    batch = build_graph_batch(N, src, dst, d_feat, 2, seed=seed,
                              n_graphs=n_mols)
    batch["graph_id"] = (np.arange(N) // n_atoms).astype(np.int32)
    # positions clustered per molecule so schnet cutoffs are meaningful
    centers = rng.normal(size=(n_mols, 3)) * 50
    batch["pos"] = (np.repeat(centers, n_atoms, axis=0)
                    + rng.normal(size=(N, 3)) * 2).astype(np.float32)
    return batch


# --------------------------------------------------------------- sampler

class NeighborSampler:
    """CSR uniform neighbor sampler (GraphSAGE fanout sampling).

    Real sampler over the in-edges CSR: for each seed node, samples up to
    ``fanout[h]`` neighbors per hop (with replacement when the degree is
    large, deterministic subsampling otherwise), producing a statically
    padded subgraph in GraphBatch edge-list form that every GNN arch
    consumes unchanged.
    """

    def __init__(self, n: int, src: np.ndarray, dst: np.ndarray):
        self.n = n
        order = np.argsort(dst, kind="stable")
        self.in_src = src[order]
        self.indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(self.indptr, dst.astype(np.int64) + 1, 1)
        np.cumsum(self.indptr, out=self.indptr)

    def sample(self, seeds: np.ndarray, fanouts, rng) -> dict:
        """Returns dict with local subgraph: seeds first in `node_ids`."""
        layers = [np.asarray(seeds, np.int64)]
        edges_src, edges_dst = [], []
        frontier = layers[0]
        for f in fanouts:
            lo = self.indptr[frontier]
            hi = self.indptr[frontier + 1]
            deg = (hi - lo).astype(np.int64)
            # sample f neighbors per frontier node (with replacement)
            offs = (rng.random((frontier.size, f))
                    * np.maximum(deg, 1)[:, None]).astype(np.int64)
            # zero-degree nodes gather a dummy (masked below); clamp index
            idx = np.minimum(lo[:, None] + offs,
                             max(self.in_src.size - 1, 0))
            nbr = self.in_src[idx] if self.in_src.size else \
                np.zeros_like(idx)
            valid = np.broadcast_to(deg[:, None] > 0, nbr.shape)
            src_flat = nbr[valid]
            dst_flat = np.repeat(frontier, f).reshape(frontier.size, f)[valid]
            edges_src.append(src_flat.astype(np.int64))
            edges_dst.append(dst_flat.astype(np.int64))
            frontier = np.unique(src_flat)
            layers.append(frontier)
        node_ids, inv = np.unique(np.concatenate(layers), return_inverse=True)
        # relabel seeds first
        seed_pos = np.searchsorted(node_ids, np.asarray(seeds, np.int64))
        perm = np.full(node_ids.size, -1, np.int64)
        perm[seed_pos] = np.arange(len(seeds))
        rest = np.flatnonzero(perm < 0)
        perm[rest] = len(seeds) + np.arange(rest.size)
        relabel = perm
        src = relabel[np.searchsorted(node_ids, np.concatenate(edges_src))]
        dst = relabel[np.searchsorted(node_ids, np.concatenate(edges_dst))]
        new_ids = np.empty_like(node_ids)
        new_ids[perm] = node_ids
        return {
            "node_ids": new_ids,            # global id per local slot
            "n_seeds": len(seeds),
            "edge_src": src.astype(np.int32),
            "edge_dst": dst.astype(np.int32),
        }

    def sample_padded(self, seeds, fanouts, rng, max_nodes: int,
                      max_edges: int, features: np.ndarray,
                      labels: np.ndarray, d_edge: int = 4) -> dict:
        sub = self.sample(seeds, fanouts, rng)
        n, e = sub["node_ids"].size, sub["edge_src"].size
        n_keep = min(n, max_nodes)
        # drop edges touching clipped nodes
        emask_src = (sub["edge_src"] < n_keep) & (sub["edge_dst"] < n_keep)
        src = sub["edge_src"][emask_src][:max_edges]
        dst = sub["edge_dst"][emask_src][:max_edges]
        ids = sub["node_ids"][:n_keep]
        batch = {
            "nodes": np.zeros((max_nodes, features.shape[1]), np.float32),
            "pos": np.zeros((max_nodes, 3), np.float32),
            "edge_src": np.zeros((max_edges,), np.int32),
            "edge_dst": np.zeros((max_edges,), np.int32),
            "edge_x": np.zeros((max_edges, d_edge), np.float32),
            "node_mask": np.zeros((max_nodes,), bool),
            "edge_mask": np.zeros((max_edges,), bool),
            "graph_id": np.zeros((max_nodes,), np.int32),
            "labels": np.zeros((max_nodes,), np.int32),
        }
        batch["nodes"][:n_keep] = features[ids]
        batch["labels"][:n_keep] = labels[ids]
        # loss only on seed nodes
        batch["node_mask"][:sub["n_seeds"]] = True
        batch["edge_src"][:src.size] = src
        batch["edge_dst"][:dst.size] = dst
        batch["edge_mask"][:src.size] = True
        batch["targets"] = batch["nodes"].copy()
        batch["graph_targets"] = np.zeros((1,), np.float32)
        return batch
