from .synthetic import (powerlaw_hypergraph, github_like, stackoverflow_like,
                        reddit_like, community_hypergraph)
from .graphs import (random_graph, build_graph_batch, molecule_batch,
                     NeighborSampler)
from .pipeline import TokenStream, RecsysStream, Prefetcher
