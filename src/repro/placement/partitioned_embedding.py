"""Partitioned embedding tables: co-access-aware row sharding.

An embedding lookup batch is a hypergraph — rows are vertices, each
query's row set is a hyperedge — so HYPE's (k-1) objective directly
minimises the number of shards a query touches. ``partition_rows_hype``
runs the offline partitioner over a query log; ``RowPlacement`` is the
serving-side routing table (row -> shard) the benchmark interrogates
for shards-touched / remote-fraction under affinity routing.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from repro.core.hype import HypeParams, hype_partition
from repro.core.hypergraph import Hypergraph


@dataclasses.dataclass(frozen=True)
class RowPlacement:
    """Routing table of a k-way sharded embedding table."""
    k: int
    owner: np.ndarray          # (vocab,) int32 shard of each row
    shard_rows: np.ndarray     # (k,) int64 rows per shard

    @classmethod
    def from_assignment(cls, assignment: np.ndarray,
                        k: int) -> "RowPlacement":
        owner = np.asarray(assignment, dtype=np.int32)
        if owner.size and (owner.min() < 0 or owner.max() >= k):
            raise ValueError("assignment ids must lie in [0, k)")
        return cls(k=k, owner=owner,
                   shard_rows=np.bincount(owner, minlength=k)
                   .astype(np.int64))


def queries_to_hypergraph(vocab: int,
                          queries: Sequence[Iterable[int]]) -> Hypergraph:
    """Rows = vertices, one hyperedge per query's co-accessed row set."""
    return Hypergraph.from_edge_lists(
        vocab, [np.unique(np.asarray(q, dtype=np.int64))
                for q in queries])


def partition_rows_hype(vocab: int, queries: Sequence[Iterable[int]],
                        k: int, seed: int = 0) -> np.ndarray:
    """k-way row assignment minimising shards-per-query via HYPE."""
    hg = queries_to_hypergraph(vocab, queries)
    return hype_partition(hg, k, HypeParams(seed=seed))
