"""Distributed-placement consumers of the partitioners.

Two thin model layers that turn an assignment into the quantities a
distributed runtime actually pays for: halo-exchange rows for
partitioned GNN aggregation (``partitioned_gnn``) and shard-local
routing for partitioned embedding tables (``partitioned_embedding``).
Consumed by ``benchmarks/bench_beyond_paper.py`` and
``launch/perf_experiments.py``.
"""
