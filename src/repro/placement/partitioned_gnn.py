"""Partitioned GNN aggregation: what an assignment costs at runtime.

A directed graph (src -> dst) aggregated per destination maps onto a
hypergraph with one hyperedge per destination vertex containing the
destination and all of its sources (the paper's GNN-placement framing:
(k-1) of that hypergraph counts the replica rows the aggregation must
materialise). ``build_partitioned_graph`` then measures, for a given
k-way assignment, the halo each device must receive: every remote
source row feeding a local destination is one exchanged feature row,
and the all-to-all payload is bounded by the *largest* per-device halo
(``s_max`` — collectives run at the speed of the fattest shard).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.hypergraph import Hypergraph


def graph_to_hypergraph(n: int, src: np.ndarray,
                        dst: np.ndarray) -> Hypergraph:
    """One hyperedge per destination: {v} ∪ {u : (u -> v) in E}.

    Duplicate (src, dst) pairs collapse to one pin; vertices with no
    in-edges become singleton hyperedges (zero (k-1) weight, so they
    never distort quality numbers).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if src.shape != dst.shape:
        raise ValueError("src and dst must have the same shape")
    order = np.argsort(dst, kind="stable")
    s, d = src[order], dst[order]
    starts = np.searchsorted(d, np.arange(n), side="left")
    ends = np.searchsorted(d, np.arange(n), side="right")
    edges = [np.unique(np.concatenate(([v], s[starts[v]:ends[v]])))
             for v in range(n)]
    return Hypergraph.from_edge_lists(n, edges)


@dataclasses.dataclass(frozen=True)
class PartitionedGraph:
    """A k-way placement of a directed graph plus its exchange costs."""
    k: int
    owner: np.ndarray          # (n,) int32 device of each vertex
    halo_rows: np.ndarray      # (k,) int64 remote rows device p receives
    s_max: int                 # max(halo_rows) — the collective's bound
    stats: dict                # exchanged_rows, remote_edge_frac


def build_partitioned_graph(n: int, src: np.ndarray, dst: np.ndarray,
                            assignment: np.ndarray,
                            k: int) -> PartitionedGraph:
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    owner = np.asarray(assignment, dtype=np.int32)
    if owner.shape != (n,):
        raise ValueError(f"assignment must have shape ({n},)")
    remote = owner[src] != owner[dst]
    # a source row is exchanged once per destination device, however
    # many local destinations consume it: unique (recv device, src row)
    pairs = owner[dst[remote]].astype(np.int64) * np.int64(n) \
        + src[remote]
    uniq = np.unique(pairs)
    halo = np.bincount((uniq // n).astype(np.int64), minlength=k)
    n_edges = max(int(src.size), 1)
    stats = {
        "exchanged_rows": int(uniq.size),
        "remote_edge_frac": float(np.count_nonzero(remote)) / n_edges,
    }
    return PartitionedGraph(k=k, owner=owner,
                            halo_rows=halo.astype(np.int64),
                            s_max=int(halo.max()) if k > 0 else 0,
                            stats=stats)
