"""Shared superstep pipeline state for the device-image engines.

``PipelineState`` is the host half of the double-buffered superstep
pipeline (DESIGN.md §4d) shared by the ``superstep``, ``sharded`` and
``device`` engines: the device-resident graph image and its memory plan
(§4g), the flat (phase, class, edge) bucket store, per-phase candidate
pools, superstep packing, async dispatch/harvest with poisoned-superstep
replay (§4f), and exact score-cache decrement bookkeeping.

The one thing it does NOT own is the device call itself:
``_call_program`` is abstract, and each engine module co-locates its
program with a subclass (``engines.superstep.SuperstepState``,
``engines.sharded.ShardedState``). ``engines.device`` builds the carry
for its while_loop megakernel from a plain ``PipelineState`` — it never
dispatches through it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.hypergraph import Hypergraph
from ..core import membudget
from ..core import resilience
from ..core import scoring
from .runtime import EngineRuntime, SnapshotMixin, _RESET0, _RESET1

# Flat bucket-store key layout: one sorted int64 per queued (phase,
# class, edge) activation — phase in the top bits, the power-of-two
# size-class exponent below it, and a sequence number in the low bits.
# Keeping the store sorted by this key makes "draw smallest classes
# first, FIFO within a class, requeues at the front" a pure prefix scan
# per phase: back-appends allocate increasing sequence numbers, front
# requeues allocate decreasing ones.
_PH_SHIFT = 50
_CLS_SHIFT = 44
_SEQ_START = np.int64(1) << 43


@dataclasses.dataclass
class _CallArgs:
    """The host-built buffers of one superstep's device call.

    Kept on the in-flight handle so a quarantined superstep can be
    replayed *exactly* (same pure program, same inputs, current image
    state). ``bias`` is always the CLEAN bias — an injected NaN tile
    poisons a copy at dispatch time only.
    """
    delta: np.ndarray
    vals: np.ndarray
    dirty: np.ndarray
    dcnt: np.ndarray
    fresh: np.ndarray
    bias: np.ndarray
    pool_arr: np.ndarray
    fringe: np.ndarray
    targets: np.ndarray
    select_k: int
    # spill rung only: the held pool's scores from the host cache
    # mirror, captured at dispatch AFTER the dirty decrements were
    # applied host-side — a replay reuses them verbatim, so the
    # decrements are never double-applied (DESIGN.md §4g)
    prev: Optional[np.ndarray] = None


@dataclasses.dataclass
class _Superstep:
    """One in-flight superstep: result futures + replay material.

    ``winners``/``n_stale``/``poison`` (and ``ncf`` for the sharded
    engine) are device futures the driver blocks on at harvest;
    ``donated`` pins the consumed image arrays until that block (a
    donated buffer's last reference must not drop while the execution
    consuming it is still in flight); ``args`` is the clean input set
    for poisoned-superstep replays.
    """
    winners: object
    n_stale: object
    poison: object
    fresh_ids: np.ndarray
    donated: tuple
    args: _CallArgs
    ncf: object = None
    # spill rung only: the fresh scores the host cache mirror adopts at
    # harvest (after the poison check — a quarantined superstep's
    # scores are garbage and are replaced by the replay's)
    scores: object = None


class PipelineState(SnapshotMixin, EngineRuntime):
    """The device-resident graph image and per-phase growth state.

    The host keeps only ids and flags (assignment mirror, pool id lists,
    the flat active-edge bucket store, a has-been-scored bitmask); every
    *score* lives in the device cache and is maintained exactly by the
    decrement rule in the engine's superstep program — no per-phase
    wipe. Admissions are selected, capped and applied *on device*
    (``dispatch``); the host mirrors them at ``harvest`` time, possibly
    several supersteps later, which is what lets the pipeline driver
    overlap host orchestration with device compute.
    """

    def __init__(self, hg: Hypergraph, k: int, p,
                 mesh=None, mem_rung: int = 0):
        super().__init__(hg, k, p)
        self.dev_cache = None       # device score cache (None when spilled)
        self.host_cache = None      # host float32 mirror (spill rung only)
        self.paged_adj = None       # membudget.PagedAdjacency (paged rung)
        self.mem_plan = None
        self.g_chunk = 1
        self.mem_rung = int(mem_rung)
        if k >= 1 << (63 - _PH_SHIFT):      # bucket-store key width
            self.dev = None
            return
        if self.adj is None:        # hub-expansion guard tripped on host
            self.dev = None
            return
        deg = np.diff(self.adj[0])
        self.deg = deg
        # One gather-width per run: every distinct shape retraces the
        # whole jitted superstep program (~0.5-1s in interpret mode), and
        # padding a gather is far cheaper than a retrace. The tile width
        # is the bucket of the 99.5th-percentile degree — the handful of
        # rows wider than that are truncated and carry the hub penalty
        # (they'd compare as "huge neighborhood" anyway).
        self.tile_l = scoring._bucket_width(int(min(
            np.percentile(deg, 99.5) if deg.size else 1,
            scoring.L_BUCKETS[-1])))
        # memory plan (core/membudget.py, DESIGN.md §4g): size every
        # device-resident tensor BEFORE upload against the resolved
        # budget; ``mem_rung`` > 0 means an earlier attempt OOMed and
        # the retry loop wants the next-smaller configuration. An
        # unconstrained budget at rung 0 reproduces today's tile
        # choices bit for bit. MemoryLadderExhausted propagates to the
        # retry loop, which hands the engine-degradation ladder over.
        rows = p.rows if p.rows else max(8, p.t)
        self.mem_budget = membudget.resolve_budget(
            getattr(p, "mem_budget", None))
        spec = membudget.MemSpec(
            n=hg.n, adj_pins=int(self.adj[1].size), k=k, rows=int(rows),
            pool_cap=int(p.pool_cap), t=int(p.t),
            tile_l=int(self.tile_l),
            pipeline_depth=max(1, int(p.pipeline_depth)))
        plan = membudget.plan_memory(spec, self.mem_budget,
                                     self._mem_features,
                                     rung_start=self.mem_rung)
        self.mem_plan = plan
        self.mem_rung = plan.rung
        self.tile_l = plan.tile_l
        self.g_chunk = plan.g_chunk
        self.stats.plan_rung = plan.rung
        self.stats.peak_bytes_planned = int(plan.planned_bytes)
        fplan = self.fault_plan
        if fplan is not None:
            sp = fplan.fire(("oom",), 0)
            if sp is not None:
                # simulated allocation failure at the image-upload site
                self.stats.faults_injected += 1
                if sp.fatal:
                    raise resilience.UnrecoverableFault(
                        "injected fatal OOM during device image upload")
                raise membudget.DeviceOOM(
                    "injected OOM during device image upload",
                    rung=self.mem_rung)
        import jax
        import jax.numpy as jnp

        n, m = hg.n, hg.m
        try:
            if plan.paged:
                # no resident CSR: the pager uploads id-range chunks on
                # demand under its own LRU byte budget. ``dev`` keeps a
                # non-None sentinel so the driver takes the device path.
                self.paged_adj = membudget.PagedAdjacency(
                    self.adj, plan.page_bytes, self.stats)
                self.dev = (None, None)
            else:
                self.dev = hg.device_adjacency(mesh=mesh)
                if self.dev is None:
                    return
            self.dev_assign = jnp.full((n,), -1, jnp.int32)
            if plan.spill_cache:
                self.host_cache = np.full(n, -1.0, dtype=np.float32)
            else:
                self.dev_cache = jnp.full((n,), -1.0, jnp.float32)
            self.dev_acc = jnp.zeros((k,), jnp.int32)
            # sticky NaN-quarantine flag (scoring._poison_guard), donated
            # through every superstep like the rest of the mutable image
            self.dev_poison = jnp.zeros((1,), jnp.int32)
        except Exception as exc:
            if membudget.is_oom_error(exc):
                raise membudget.DeviceOOM(
                    f"device image upload failed: {exc!r}",
                    rung=self.mem_rung) from exc
            raise
        if mesh is not None:       # replicate the mutable image too
            from jax.sharding import NamedSharding, PartitionSpec
            rep = NamedSharding(mesh, PartitionSpec())
            self.dev_assign = jax.device_put(self.dev_assign, rep)
            self.dev_cache = jax.device_put(self.dev_cache, rep)
            self.dev_acc = jax.device_put(self.dev_acc, rep)
            self.dev_poison = jax.device_put(self.dev_poison, rep)
        self.cache_scored = np.zeros(n, dtype=bool)
        self.pools = [np.empty(0, dtype=np.int64) for _ in range(k)]
        # flat (phase, class, edge) bucket store — two parallel arrays
        # sorted by the composite key above, replacing the per-phase
        # dict-of-deques
        self.bq_key = np.empty(0, dtype=np.int64)
        self.bq_edge = np.empty(0, dtype=np.int64)
        self._bq_pending: list = []     # rows awaiting the lazy merge
        self._seq_back = np.int64(_SEQ_START)
        self._seq_front = np.int64(_SEQ_START) - 1
        self.edge_queued = np.zeros((k, m), dtype=bool)
        self.delta_ids: list = []
        self.delta_vals: list = []
        self.pending_dirty: list = []   # queued winner decrements
        self._excl_scratch = np.zeros(n, dtype=bool)
        # The dirty-pair pad is pre-sized from the expected per-superstep
        # dirty rate and only ratchets up (monotone -> at most a couple
        # of traces).
        mean_deg = self.adj[1].size / max(hg.n, 1)
        expect = min(hg.n, max(256, int(2 * k * p.t * mean_deg)))
        self._dirty_ratchet = 1 << int(np.ceil(np.log2(expect + 1)))
        csr_bytes = (0 if self.paged_adj is not None
                     else self.dev[0].nbytes + self.dev[1].nbytes)
        cache_bytes = (0 if self.dev_cache is None
                       else self.dev_cache.nbytes)
        self.stats.device_image_bytes = int(
            csr_bytes + cache_bytes + self.dev_assign.nbytes
            + self.dev_acc.nbytes)

    # ------------------------------------------------------------------ #
    # injected faults this engine's dispatch site can see (the sharded
    # engine adds "collective" — its dispatch owns the all_gather);
    # "oom@N" lets chaos suites simulate mid-run allocation failures
    _fault_kinds = ("dispatch", "oom")
    # memory-rung reductions this engine has program variants for
    # (membudget.rung_ladder); the sharded engine only supports the
    # width/depth knobs — its CSR is replicated per device
    _mem_features = membudget.SUPERSTEP_FEATURES

    @property
    def interpret(self) -> bool:
        """Pallas interpret mode, re-resolved per call.

        A property, not an ``__init__`` attribute, so flipping
        ``REPRO_PALLAS_INTERPRET`` steers even a live engine — the
        NaN-quarantine tests flip it without rebuilding state, and
        ``kernels/_compat.pallas_interpret`` already reads the env per
        call; this was the one residual cache of its value.
        """
        from repro.kernels._compat import pallas_interpret
        return pallas_interpret()

    def _to_device(self, arr: np.ndarray):
        """Upload a host array as this engine's replicated image layout."""
        import jax.numpy as jnp
        return jnp.asarray(arr)

    def release_pools(self) -> None:
        """End-of-run hook: clear every pool-membership mask."""
        self.in_pool[:] = False

    # ------------------------------------------------------------------ #
    def _pmask(self, g: int) -> np.ndarray:
        """Pool-membership mask governing phase ``g``'s draws.

        Engine-wide for the single-device engine; the sharded engine
        overrides this with the per-device-group mask.
        """
        return self.in_pool

    def _restart_mask(self) -> np.ndarray:
        """Mask a restart injection must avoid: every engine pool.

        Injections are applied to the device image with an unconditional
        scatter, so they must never name a vertex an in-flight superstep
        could still admit — i.e. anything in ANY pool. For the
        single-device engine that is exactly ``in_pool``; the sharded
        engine unions its per-group masks.
        """
        return self.in_pool

    def assign_now(self, vs: np.ndarray, phase: int) -> None:
        """Assign ``vs`` to ``phase``; queue the device delta + dirtying."""
        vs = np.asarray(vs, dtype=np.int64)
        self.assignment[vs] = phase
        self.in_pool[vs] = False
        self.delta_ids.append(vs)
        self.delta_vals.append(np.full(vs.size, phase, dtype=np.int32))

    def activate_phase(self, vs: np.ndarray, phase: int) -> None:
        """Queue the edges incident to newly admitted vertices of a phase."""
        self.activate_many(np.asarray(vs, dtype=np.int64),
                           np.full(len(vs), phase, dtype=np.int64))

    def activate_many(self, vs: np.ndarray, phases: np.ndarray) -> None:
        """Queue incident edges for a whole superstep's admissions at once.

        ``vs``/``phases`` are parallel arrays; one CSR gather + one
        lexsort appends every fresh (phase, edge) activation to the back
        of the flat sorted bucket store — no per-phase python pass.
        """
        edges, owner = scoring.gather_csr_rows(
            self.hg.v2e_indptr, self.hg.v2e_indices, vs)
        if edges.size == 0:
            return
        edges = edges.astype(np.int64)
        ph = phases[owner]
        key = np.unique(ph * np.int64(self.hg.m) + edges)
        ph, edges = key // self.hg.m, key % self.hg.m
        live = ~self.edge_queued[ph, edges] & ~self.edge_dead[edges]
        ph, edges = ph[live], edges[live]
        if edges.size == 0:
            return
        self.edge_queued[ph, edges] = True
        # power-of-two size classes instead of exact sizes: smallest-first
        # drawing is a heuristic, and ~12 classes keep the number of
        # (phase, class) segments small.
        sizes = self.edge_sizes[edges]
        cls = np.where(
            sizes <= 1, np.int64(0),
            np.ceil(np.log2(np.maximum(sizes, 2))).astype(np.int64))
        order = np.lexsort((cls, ph))
        ph, edges, cls = ph[order], edges[order], cls[order]
        seq = np.arange(self._seq_back, self._seq_back + edges.size,
                        dtype=np.int64)
        self._seq_back += edges.size
        self._store_insert(
            (ph << _PH_SHIFT) | (cls << _CLS_SHIFT) | seq, edges)

    # ------------------------------------------------------ bucket store
    def _store_insert(self, key: np.ndarray, edges: np.ndarray) -> None:
        """Queue rows for the store; merged lazily at the next draw.

        Batching the merges (one sorted-merge per pack instead of one
        per activation) keeps store maintenance O(store) *per superstep*
        rather than per call — visibility is identical because draws
        only happen at pack time, after ``_store_flush``.
        """
        if key.size:
            self._bq_pending.append((key, edges))

    def _store_flush(self) -> None:
        if not self._bq_pending:
            return
        key = np.concatenate([kk for kk, _ in self._bq_pending])
        edges = np.concatenate([ee for _, ee in self._bq_pending])
        self._bq_pending = []
        order = np.argsort(key, kind="stable")
        key, edges = key[order], edges[order]
        if self.bq_key.size == 0:
            self.bq_key, self.bq_edge = key, edges
            return
        pos = np.searchsorted(self.bq_key, key)
        self.bq_key = np.insert(self.bq_key, pos, key)
        self.bq_edge = np.insert(self.bq_edge, pos, edges)

    def _store_take(self, budget: np.ndarray):
        """Greedy smallest-class-first prefix take for every phase.

        ``budget`` is the per-phase pin budget; each queued edge
        contributes its power-of-two class value (the same accounting
        the dict-of-deques draw used). Only each phase's front slice
        (at most ``budget`` rows — every edge costs >= 1 unit) is ever
        decoded, so the take is O(sum budgets + k log store), not
        O(store). Returns the taken rows' ``(edges, ph, cls_log)``
        columns, phase-major (the store is key-sorted), and drops them
        from the store.
        """
        self._store_flush()
        key = self.bq_key
        if key.size == 0 or not budget.any():
            empty = np.empty(0, dtype=np.int64)
            return empty, empty, empty
        k = self.k
        bounds = np.searchsorted(
            key, np.arange(k + 1, dtype=np.int64) << _PH_SHIFT)
        start = bounds[:k]
        cap = np.minimum(bounds[1:] - start, budget)
        tot = int(cap.sum())
        if tot == 0:
            empty = np.empty(0, dtype=np.int64)
            return empty, empty, empty
        head = np.cumsum(cap) - cap
        local = np.arange(tot, dtype=np.int64) - np.repeat(head, cap)
        rows = np.repeat(start, cap) + local
        ph_r = np.repeat(np.arange(k, dtype=np.int64), cap)
        ckey = key[rows]
        cls_log = (ckey >> _CLS_SHIFT) & np.int64(63)
        csize = np.int64(1) << cls_log
        cum = np.cumsum(csize)
        excl = cum - csize
        base = np.zeros(k, dtype=np.int64)
        has = cap > 0
        base[has] = excl[head[has]]
        take = (excl - base[ph_r]) < budget[ph_r]
        tk = rows[take]
        edges_t, ph_t, cls_t = self.bq_edge[tk], ph_r[take], cls_log[take]
        if tk.size:     # drop taken rows NOW — restarts may insert
            keep = np.ones(key.size, dtype=bool)
            keep[tk] = False
            self.bq_key = key[keep]
            self.bq_edge = self.bq_edge[keep]
        return edges_t, ph_t, cls_t

    def _store_requeue(self, rq_ph: list, rq_cls: list,
                       rq_edge: list) -> None:
        """Requeue still-live taken rows at their queue fronts."""
        if not rq_ph:
            return
        ph = np.concatenate(rq_ph)
        cls = np.concatenate(rq_cls)
        edges = np.concatenate(rq_edge)
        seq = np.arange(self._seq_front - edges.size + 1,
                        self._seq_front + 1, dtype=np.int64)
        self._seq_front -= edges.size
        key = (ph << _PH_SHIFT) | (cls << _CLS_SHIFT) | seq
        order = np.argsort(key, kind="stable")
        self._store_insert(key[order], edges[order])

    def take_delta(self, cap: int):
        """Drain up to ``cap`` queued (id, phase) assignment pairs.

        FIFO across calls: an overflowing drain leaves the tail queued
        (int64 ids / int32 phases preserved) for the next superstep.
        """
        if not self.delta_ids:
            return (np.empty(0, dtype=np.int64),
                    np.empty(0, dtype=np.int32))
        ids = np.concatenate(self.delta_ids).astype(np.int64, copy=False)
        vals = np.concatenate(self.delta_vals).astype(np.int32,
                                                      copy=False)
        if ids.size <= cap:
            self.delta_ids, self.delta_vals = [], []
            return ids, vals
        self.delta_ids = [ids[cap:]]
        self.delta_vals = [vals[cap:]]
        return ids[:cap], vals[:cap]

    def _pack_delta_dirty(self, delta_cap, extra_dirty=()):
        """Drain queued assignments into the padded device buffers.

        Pre-aggregates the dirtied-neighbor multiset of the drained
        delta — one CSR gather + bincount, shipped as (unique id, count)
        pairs padded to a power-of-two bucket (bounded retraces,
        O(unique) device scatter). ``extra_dirty`` merges additional raw
        neighbor-id arrays into the multiset (the sharded engine's
        queued decrement tails). Returns ``(delta, vals, dirty, dcnt)``;
        shared by both device engines so their cache-exactness
        bookkeeping cannot drift apart.
        """
        d_ids, d_vals = self.take_delta(delta_cap)
        delta = np.full(delta_cap, -1, dtype=np.int32)
        vals = np.zeros(delta_cap, dtype=np.int32)
        delta[:d_ids.size] = d_ids
        vals[:d_ids.size] = d_vals
        nbrs, _ = scoring.gather_csr_rows(self.adj[0], self.adj[1], d_ids)
        parts = list(extra_dirty)
        if nbrs.size:
            parts.append(nbrs.astype(np.int64))
        if parts:
            counts = np.bincount(np.concatenate(parts))
            uniq = np.flatnonzero(counts)
            self.stats.cache_invalidations += int(uniq.size)
        else:
            uniq = np.empty(0, dtype=np.int64)
            counts = np.empty(0, dtype=np.int64)
        cap = max(self._dirty_ratchet,
                  1 << int(np.ceil(np.log2(max(uniq.size, 1)))))
        self._dirty_ratchet = cap
        dirty = np.full(cap, -1, dtype=np.int32)
        dcnt = np.zeros(cap, dtype=np.float32)
        dirty[:uniq.size] = uniq
        dcnt[:uniq.size] = counts[uniq]
        return delta, vals, dirty, dcnt

    # ---------------------------------------------------- pipeline hooks
    def pack_superstep(self, active, R: int, P: int, t: int,
                       targets: np.ndarray, acc: np.ndarray):
        """Host half of one superstep: draw, dedup, tile-pack, restart.

        One flat store scan + ONE pins gather covers every active
        phase's candidate draw (stage A, assignment-independent); a thin
        rotation-ordered pass then applies the order-sensitive pieces —
        edge liveness, candidate acceptance against the live pool masks,
        and random restarts (stage B). Mutates pools/masks/acc for the
        injections and returns ``(packed, injected)`` where ``packed``
        is ``(fresh, bias, pool_arr, fresh_ids)`` or None when no phase
        had anything to score.
        """
        kG = self.k
        rot = self.stats.supersteps % active.size
        order = np.concatenate([active[rot:], active[:rot]])
        # stage 0: drop ids that went stale (admitted meanwhile) from
        # the held pools, then size each phase's draw
        need = np.zeros(kG, dtype=np.int64)
        budget = np.zeros(kG, dtype=np.int64)
        for g in order:
            gi = int(g)
            ids = self.pools[gi]
            if ids.size:
                keep = self.assignment[ids] < 0
                if not keep.all():
                    self._pmask(gi)[ids[~keep]] = False
                    ids = ids[keep]
                    self.pools[gi] = ids
            need[gi] = min(R, P - ids.size)
            if need[gi] > 0:
                budget[gi] = max(4 * need[gi], 512)
        # stage A: one prefix take over the sorted store + one CSR
        # gather for every taken edge of every phase
        edges_t, ph_t, cls_t = self._store_take(budget)
        pins, prow = scoring.gather_csr_rows(
            self.hg.e2v_indptr, self.hg.e2v_indices, edges_t)
        pins = pins.astype(np.int64)
        self.stats.edges_scanned += int(pins.size)
        edge_lo = np.searchsorted(ph_t, np.arange(kG + 1, dtype=np.int64))
        pin_lo = np.searchsorted(prow, edge_lo)
        # per-phase first-occurrence dedup of the pin streams. The
        # acceptance filters below are per-pin properties, so deduping
        # before filtering equals the old filter-then-dedup, row for row.
        if pins.size:
            pph = ph_t[prow]
            _, first = np.unique(pph * np.int64(self.hg.n) + pins,
                                 return_index=True)
            first = np.sort(first)
            cand_all = pins[first]
            cand_lo = np.searchsorted(pph[first],
                                      np.arange(kG + 1, dtype=np.int64))
        else:
            cand_all = pins
            cand_lo = np.zeros(kG + 1, dtype=np.int64)
        # stage B: rotation-ordered liveness / acceptance / restarts
        fresh = np.full((kG, R), -1, dtype=np.int32)
        bias = np.full((kG, R), np.inf, dtype=np.float32)
        pool_arr = np.full((kG, P), -1, dtype=np.int32)
        fresh_parts: list = []
        rq_ph: list = []
        rq_cls: list = []
        rq_edge: list = []
        injected = 0
        packed_any = False
        rmask = None    # injection-safety mask, computed at most once
        #                 per pack (the sharded union is O(devices * n))
        for g in order:
            gi = int(g)
            e0, e1 = int(edge_lo[gi]), int(edge_lo[gi + 1])
            if e1 > e0:     # edge liveness at this phase's turn
                p0, p1 = int(pin_lo[gi]), int(pin_lo[gi + 1])
                unas = self.assignment[pins[p0:p1]] < 0
                live = np.bincount(prow[p0:p1][unas] - e0,
                                   minlength=e1 - e0) > 0
                eg = edges_t[e0:e1]
                if not live.all():
                    self.edge_dead[eg[~live]] = True    # dead forever
                if live.any():
                    rq_ph.append(ph_t[e0:e1][live])
                    rq_cls.append(cls_t[e0:e1][live])
                    rq_edge.append(eg[live])
            pmask = self._pmask(gi)
            cg = cand_all[int(cand_lo[gi]):int(cand_lo[gi + 1])]
            drawn = cg
            if cg.size:
                okc = (self.assignment[cg] < 0) & ~pmask[cg]
                drawn = cg[okc][:need[gi]]
            ids = self.pools[gi]
            miss = np.empty(0, dtype=np.int64)
            if drawn.size:
                pmask[drawn] = True
                if rmask is not None and rmask is not pmask:
                    rmask[drawn] = True     # keep the union mask live
                scored = self.cache_scored[drawn]
                hits, miss = drawn[scored], drawn[~scored]
                if hits.size:       # cross-phase reuse: already cached
                    ids = np.concatenate([ids, hits])
            if ids.size == 0 and miss.size == 0:
                # shattered remainder: seed fresh growth points directly
                if rmask is None:
                    rmask = self._restart_mask()
                vs = self.random_unassigned(
                    min(t, int(targets[gi] - acc[gi])), in_pool=rmask)
                if vs.size:
                    self.stats.random_restarts += 1
                    self.assign_now(vs, gi)
                    self.activate_phase(vs, gi)
                    acc[gi] += vs.size
                    injected += int(vs.size)
                continue
            fresh[gi, :miss.size] = miss
            bias[gi, :miss.size] = np.where(
                self.deg[miss] > self.tile_l, scoring.TRUNC_PENALTY, 0.0)
            pool_arr[gi, :ids.size] = ids
            # every pool_arr slot is a score served straight from the
            # device cache (held-over or cross-phase hit) instead of a
            # kernel rescore — the reuse the exact-decrement design buys
            self.stats.cache_hits += int(ids.size)
            self.pools[gi] = np.concatenate([ids, miss])
            fresh_parts.append(miss)
            self.stats.kernel_rows += int(miss.size)
            packed_any = True
        self._store_requeue(rq_ph, rq_cls, rq_edge)
        if not packed_any:
            return None, injected
        fresh_ids = (np.concatenate(fresh_parts) if fresh_parts
                     else np.empty(0, dtype=np.int64))
        return (fresh, bias, pool_arr, fresh_ids), injected

    def _image_buffers(self) -> tuple:
        """The live donated image arrays of this engine's current mode.

        The spill rung keeps no device cache and the paged rung no
        resident CSR, so the donated set is mode-dependent — every
        dispatch/replay handle pins exactly these.
        """
        bufs = [self.dev_assign, self.dev_acc, self.dev_poison]
        if self.dev_cache is not None:
            bufs.insert(1, self.dev_cache)
        return tuple(bufs)

    def _call_program(self, args: _CallArgs, reset: np.ndarray):
        """Issue the engine's fused superstep program; rotate the image.

        Returns ``(winners, n_stale, ncf, scores)`` futures (``ncf`` is
        None for the single-device engine; ``scores`` is None except on
        the spill rung, where the host owns the score cache and the
        fresh scores ride back with the winners). Abstract here: each
        engine module co-locates its device program with its state
        subclass — the ONLY device-call difference between the
        superstep and sharded engines.
        """
        raise NotImplementedError(
            "PipelineState subclasses co-locate their device program")

    def _call_guarded(self, args: _CallArgs, reset: np.ndarray):
        """``_call_program`` under fault injection + bounded retry."""
        return self._guarded_kernel(
            lambda: self._call_program(args, reset),
            int(self.stats.supersteps), self._fault_kinds,
            donated=self._image_buffers())

    def _count_dispatch(self, fresh: np.ndarray, select_k: int) -> None:
        """Per-dispatch counter hook (the sharded engine adds
        collective accounting). Replays never come through here — the
        kernel_calls == supersteps invariant survives recovery."""

    def _count_harvest(self, handle: _Superstep) -> None:
        """Per-harvest counter hook (sharded: admission conflicts)."""

    def dispatch(self, fresh, bias, pool_arr, fringe, fresh_ids,
                 targets_i32, delta_cap: int, select_k: int):
        """Launch one superstep on the device (async); returns a handle.

        JAX's async dispatch returns immediately — the returned handle's
        arrays are futures the driver blocks on only at ``harvest``, so
        the host keeps packing while the device computes. The previous
        (donated) image arrays ride the handle: deleting a donated
        buffer synchronizes with the execution consuming it, so their
        last reference must not drop before the harvest-time block.

        Fault-injection sites (DESIGN.md §4f): a ``dispatch`` (or, for
        the sharded engine, ``collective``) spec raises here and is
        retried/escalated by ``_call_guarded``; a ``nan`` spec poisons a
        COPY of the bias buffer so the device program's quarantine
        guard trips — the handle keeps the clean args for the replay.
        """
        tails = self.pending_dirty
        self.pending_dirty = []
        delta, vals, dirty, dcnt = self._pack_delta_dirty(
            delta_cap, extra_dirty=tails)
        prev = None
        if self.host_cache is not None:
            # spill rung: the host owns the score cache. Apply the dirty
            # decrements to the float32 mirror NOW (the same IEEE adds
            # the device program would have scattered) and ship the held
            # pool's scores in; the device still masks stale slots
            # itself against the post-injection assignment.
            u = dirty >= 0
            ids = dirty[u].astype(np.int64)
            self.host_cache[ids] -= dcnt[u]
            prev = self.host_cache[np.where(pool_arr >= 0, pool_arr,
                                            0)].astype(np.float32)
        self.stats.host_to_device_bytes += (
            fresh.nbytes + bias.nbytes + pool_arr.nbytes + fringe.nbytes
            + delta.nbytes + vals.nbytes + dirty.nbytes + dcnt.nbytes
            + targets_i32.nbytes)
        self.stats.supersteps += 1
        self.stats.kernel_calls += 1
        self._count_dispatch(fresh, select_k)
        args = _CallArgs(delta, vals, dirty, dcnt, fresh, bias,
                         pool_arr, fringe, targets_i32, select_k,
                         prev=prev)
        send = args
        plan = self.fault_plan
        if plan is not None:
            sp = plan.fire(("nan",), int(self.stats.supersteps))
            if sp is not None:
                self.stats.faults_injected += 1
                if sp.fatal:
                    raise resilience.UnrecoverableFault(
                        f"injected fatal nan tile at superstep "
                        f"{self.stats.supersteps}")
                bias_bad = bias.copy()
                bias_bad[fresh >= 0] = np.nan
                send = dataclasses.replace(args, bias=bias_bad)
        donated = self._image_buffers()
        winners, n_stale, ncf, scores = self._call_guarded(send, _RESET0)
        return _Superstep(winners, n_stale, self.dev_poison, fresh_ids,
                          donated, args, ncf, scores)

    def replay(self, h: _Superstep) -> _Superstep:
        """Re-issue a quarantined superstep from its clean args.

        The poisoned superstep (and every later in-flight one — the
        poison flag is sticky) reverted all of its device mutations, so
        the current image equals the state just before it ran: calling
        the same pure program with the handle's clean args and
        ``reset=1`` recovers exactly what a fault-free run computed.
        Counts as a retry only — never as a new superstep/kernel call.
        A superstep still poisoned after a clean replay means the
        non-finite scores are real (not injected): unrecoverable here,
        the ladder's host engines score around poisoned rows instead.
        """
        self.stats.retries += 1
        donated = self._image_buffers()
        winners, n_stale, ncf, scores = self._call_program(h.args,
                                                           _RESET1)
        nh = _Superstep(winners, n_stale, self.dev_poison, h.fresh_ids,
                        donated, h.args, ncf, scores)
        if int(np.asarray(nh.poison)[0]) > 0:
            raise resilience.UnrecoverableFault(
                "superstep still poisoned after a clean replay: the "
                "non-finite scores did not come from an injected fault")
        return nh

    def harvest(self, handle, acc: np.ndarray, targets: np.ndarray,
                exclude=()) -> int:
        """Block on one in-flight superstep and mirror its admissions.

        The only blocking transfer of the steady state: everything else
        the driver does (packing superstep N+1) happens while the device
        still computes superstep N. Admission mirroring is fully
        vectorized — no per-slot python loop. ``exclude`` carries the
        fresh-id arrays of the supersteps still in flight: their scores
        were computed *after* this superstep's winners were applied, so
        the queued winner decrements must skip them (double-decrement
        otherwise).

        A quarantined handle (non-finite scores poisoned the superstep,
        which reverted itself on device) is replayed from its clean
        args before mirroring — direct dispatch/harvest callers survive
        an injected NaN tile without the pipeline driver's help; the
        driver additionally replays the whole in-flight window to keep
        device-effect order (see ``runtime._harvest_next``).
        """
        import time as _time

        if int(np.asarray(handle.poison)[0]) > 0:
            handle = self.replay(handle)
        winners_dev, stale_dev = handle.winners, handle.n_stale
        fresh_ids = handle.fresh_ids
        t0 = _time.perf_counter()
        try:
            winners = np.asarray(winners_dev)
            n_stale = int(stale_dev)
            if self.host_cache is not None and handle.scores is not None:
                # spill rung: adopt the fresh scores into the host
                # mirror — the same pad-dropping scatter the device
                # cache write performs, after the poison check above
                flat = handle.args.fresh.reshape(-1)
                sc = np.asarray(handle.scores).reshape(-1)
                real = flat >= 0
                self.host_cache[flat[real].astype(np.int64)] = sc[real]
        except membudget.DeviceOOM:
            raise
        except Exception as exc:
            # a real allocator failure can surface at the blocking
            # transfer, not just at dispatch — same recovery path
            if membudget.is_oom_error(exc):
                raise membudget.DeviceOOM(
                    f"superstep harvest failed: {exc!r}",
                    rung=self.mem_rung) from exc
            raise
        self.stats.device_s += _time.perf_counter() - t0
        t0 = _time.perf_counter()
        self.stats.stale_redraws += n_stale
        if fresh_ids.size:
            self.cache_scored[fresh_ids] = True
        kG, t = winners.shape
        flat = winners.reshape(-1).astype(np.int64)
        mask = flat >= 0
        vs = flat[mask]
        progress = int(vs.size)
        if vs.size:
            ph = np.repeat(np.arange(kG, dtype=np.int64), t)[mask]
            self.assignment[vs] = ph.astype(np.int32)
            self._release_members(vs, ph)
            acc += np.bincount(ph, minlength=kG)
            self.activate_many(vs, ph)
            self._queue_decrements(vs, exclude)
            for g in np.unique(ph):
                if acc[g] >= targets[g]:    # phase done: release pool
                    gi = int(g)
                    self._pmask(gi)[self.pools[gi]] = False
                    self.pools[gi] = np.empty(0, dtype=np.int64)
        self._count_harvest(handle)
        self.stats.host_s += _time.perf_counter() - t0
        return progress

    def _release_members(self, vs: np.ndarray, ph: np.ndarray) -> None:
        """Clear pool membership for freshly mirrored winners."""
        self.in_pool[vs] = False

    def _filter_rescored(self, nbrs: np.ndarray, exclude) -> np.ndarray:
        """Drop ids fresh-rescored by a still-in-flight superstep.

        Their cache entries are written *after* the winners applied, so
        they already reflect the admissions — decrementing them again
        would double-count. O(|nbrs| + |exclude|) via a reusable
        boolean scratch.
        """
        parts = [e for e in exclude if e.size]
        if not parts or nbrs.size == 0:
            return nbrs
        ex = np.concatenate(parts)
        scratch = self._excl_scratch
        scratch[ex] = True
        out = nbrs[~scratch[nbrs]]
        scratch[ex] = False
        return out

    def _queue_decrements(self, vs: np.ndarray, exclude=()) -> None:
        """Queue the winners' neighbor decrements for the next dispatch.

        The full multiset — one CSR gather, pre-aggregated into
        (unique id, count) pairs by ``_pack_delta_dirty`` — exactly the
        lock-step engine's decrement schedule at depth 1; ids rescored
        by an in-flight superstep are excluded (see
        ``_filter_rescored``).
        """
        nbrs, _ = scoring.gather_csr_rows(self.adj[0], self.adj[1], vs)
        if nbrs.size == 0:
            return
        nbrs = self._filter_rescored(nbrs.astype(np.int64), exclude)
        if nbrs.size:
            self.pending_dirty.append(nbrs)
