"""Shared engine runtime for the HYPE engine family (DESIGN.md §1).

Every fast engine (``batched``, ``superstep``, ``sharded``, ``device``)
used to hand-copy the same cross-cutting concerns; this module owns them
once:

  * ``EngineRuntime`` — the host-side state core every engine extends:
    assignment/pool bookkeeping, the deterministic random stream,
    fault-injection + bounded-retry device calls (``_guarded_kernel``,
    core/resilience.py §4f), and the compile-cache opt-in.
  * ``BatchedStats`` — the family-wide counter dataclass, plus ``merge``
    for combining the stats of split or restarted runs.
  * ``SnapshotMixin`` — snapshot capture / exact restore / cross-engine
    warm start for the device-image engines (§4f cadence semantics).
  * ``run_pipeline`` / ``run_pipeline_budgeted`` — the double-buffered
    superstep pipeline driver (§4d) and its memory-rung retry loop
    (§4g), parameterized by a state factory so the superstep and
    sharded engines share one driver without importing each other.
  * ``maybe_refine`` — the post-run k-way refinement stage (§4e).

Engine modules may import this module and ``engines.pipeline``; they
never import each other's internals (enforced by
``tools/check_layering.py``).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional

import numpy as np

from ..core.hypergraph import Hypergraph
from ..core import membudget
from ..core import resilience

# (1,) int32 replay markers for the device programs' sticky poison flag
# (scoring._poison_guard): 0 = normal superstep, 1 = host-driven replay
# of a quarantined superstep. Module constants so repeated dispatches
# hand jit the same host buffers.
_RESET0 = np.zeros(1, dtype=np.int32)
_RESET1 = np.ones(1, dtype=np.int32)


@dataclasses.dataclass
class BatchedStats:
    kernel_calls: int = 0
    kernel_rows: int = 0       # candidate rows scored by the Pallas kernel
    host_rows: int = 0         # rows scored by the numpy fallback
    cache_hits: int = 0
    edges_scanned: int = 0     # pins scanned during candidate selection
    random_restarts: int = 0
    steps: int = 0
    # superstep-engine counters (zero for the classic batched path):
    supersteps: int = 0             # fused device calls
    device_image_bytes: int = 0     # one-time CSR + assignment + cache
    #                                 upload at partition() start
    host_to_device_bytes: int = 0   # per-call id/bias buffers — the whole
    #                                 steady-state H2D traffic
    cache_invalidations: int = 0    # cached scores decremented by admission
    # sharded-engine counters (zero for the single-device engines):
    collectives: int = 0            # all_gather ops (one per superstep)
    collective_bytes: int = 0       # bytes materialized by the gathers:
    #                                 devices x global payload per superstep
    admission_conflicts: int = 0    # proposed admissions lost to the
    #                                 lowest-phase-wins conflict rule
    # pipeline counters (superstep/sharded engines):
    host_s: float = 0.0             # wall-clock spent in host packing +
    #                                 harvest mirroring (overlappable)
    device_s: float = 0.0           # wall-clock blocked waiting on device
    #                                 results at harvest time
    pipeline_stalls: int = 0        # rounds where the host could pack
    #                                 nothing and the device went idle
    stale_redraws: int = 0          # pool slots skipped on device because
    #                                 an interleaved superstep of the
    #                                 pipeline had already assigned them
    # device-loop counters (hype_device, DESIGN.md §4i):
    loop_chunks: int = 0            # host-visible while_loop segments
    loop_rounds: int = 0            # pack+dispatch rounds run on device
    loop_pack_only: int = 0         # rounds that had nothing to score
    loop_store_peak: int = 0        # peak live rows across phase stores
    loop_state_bytes: int = 0       # device-resident carry (loop state)
    refill_signals: int = 0         # kernel refill-trigger flags raised
    #                                 (phases whose candidate slots ran
    #                                 out during selection)
    # resilience counters (core/resilience.py, DESIGN.md §4f):
    faults_injected: int = 0        # FaultPlan specs that fired this run
    retries: int = 0                # transient-fault retries + poisoned-
    #                                 superstep replays (never counted as
    #                                 extra kernel_calls / supersteps)
    fallbacks: int = 0              # ladder rungs exhausted before this
    #                                 engine ran (partition_resilient)
    snapshots: int = 0              # checkpoints published
    snapshot_s: float = 0.0         # wall-clock publishing checkpoints
    restore_s: float = 0.0          # wall-clock restoring the resume ckpt
    resumed_at: int = -1            # superstep/phase the run resumed
    #                                 from; -1 = fresh start
    # memory-budget counters (core/membudget.py, DESIGN.md §4g):
    mem_retries: int = 0            # DeviceOOM-driven same-engine retries
    #                                 (real allocator failures + injected
    #                                 non-fatal oom faults)
    plan_rung: int = -1             # memory-plan rung the run executed at;
    #                                 -1 = engine never planned (host path)
    peak_bytes_planned: int = 0     # the plan's modeled peak device bytes
    peak_bytes_observed: int = 0    # backend peak_bytes_in_use when the
    #                                 allocator tracks it; the planned
    #                                 model value otherwise
    page_uploads: int = 0           # paged-adjacency chunk uploads
    page_hits: int = 0              # chunk requests served LRU-resident
    page_evictions: int = 0         # chunks evicted to stay under budget
    page_bytes: int = 0             # total bytes uploaded by the pager
    # refinement post-pass (None unless refine_passes > 0 ran):
    refine: Optional[object] = None     # core.refine.RefineStats

    # counters that are high-water marks / identities rather than sums —
    # ``merge`` keeps the max (or the non-default value) instead of adding
    _MERGE_MAX = ("loop_store_peak", "loop_state_bytes",
                  "peak_bytes_planned", "peak_bytes_observed",
                  "device_image_bytes", "plan_rung", "resumed_at")

    def merge(self, other: "BatchedStats") -> "BatchedStats":
        """Combine two runs' counters into a new ``BatchedStats``.

        Additive counters sum; peak/identity fields keep the max; the
        ``refine`` record of the later run wins (the earlier one refined
        an assignment that no longer exists). Used when a partition is
        assembled from multiple engine runs (restarts, split ladders).
        """
        out = BatchedStats()
        for f in dataclasses.fields(BatchedStats):
            a, b = getattr(self, f.name), getattr(other, f.name)
            if f.name == "refine":
                out.refine = b if b is not None else a
            elif f.name in self._MERGE_MAX:
                setattr(out, f.name, max(a, b))
            else:
                setattr(out, f.name, a + b)
        return out


class EngineRuntime:
    """Mutable host-side state core shared by every fast engine.

    Owns the bookkeeping every engine needs regardless of where its
    scores live: the assignment mirror, pool membership, the seeded
    random stream, per-run stats, the memoized vertex adjacency, and
    the resolved fault plan. Device-call protection
    (``_guarded_kernel``) lives here so retry/escalation semantics
    cannot drift between engines.
    """

    def __init__(self, hg: Hypergraph, k: int, p):
        # opt into the persistent XLA compile cache (REPRO_COMPILE_CACHE)
        # before any engine traces a kernel; idempotent no-op when unset
        from repro.kernels._compat import enable_compile_cache
        enable_compile_cache()
        self.hg = hg
        self.k = k
        self.p = p
        n = hg.n
        self.assignment = np.full(n, -1, dtype=np.int32)
        self.in_pool = np.zeros(n, dtype=bool)     # fringe ∪ held candidates
        self.edge_sizes = np.asarray(hg.edge_sizes, dtype=np.int64)
        self.edge_dead = self.edge_sizes == 0              # no live pins left
        self.rng = np.random.default_rng(p.seed)
        self.rand_order = self.rng.permutation(n)
        self.rand_ptr = 0
        self.stats = BatchedStats()
        # One-time unique-neighbor CSR (memoized on hg): turns every tile
        # build into a pure gather. None for pathological hub expansions —
        # scoring then falls back to per-batch dedup with cap_pins.
        self.adj = hg.vertex_adjacency()
        # deterministic fault schedule: the param (shared instance across
        # a degradation ladder) or a FRESH parse of REPRO_FAULT_PLAN per
        # engine run, so every run of a chaos suite sees the full plan
        self.fault_plan = resilience.resolve_fault_plan(p.fault_plan)

    # ------------------------------------------------------------------ #
    def _guarded_kernel(self, fn, ordinal: int, kinds=("dispatch",),
                        donated=()):
        """Run a device call under fault injection + bounded retry.

        Injected faults fire *before* the call (the dispatch site), so a
        transient retry re-issues the identical pure computation — which
        is what keeps recovery bit-identical to a fault-free run. A
        fatal spec, an exhausted retry budget, or a real failure after
        any ``donated`` buffer was consumed (the call cannot be
        re-issued) raises ``UnrecoverableFault`` for the ladder.

        Memory faults are different: a real allocator failure
        (``membudget.is_oom_error``) or a non-fatal injected ``oom``
        raises ``DeviceOOM`` immediately — retrying the identical call
        cannot help an allocation that does not fit, and the memory-rung
        retry loop (``run_pipeline_budgeted``, DESIGN.md §4g) rebuilds
        the whole engine state at a smaller plan anyway, donated or not.
        """
        plan = self.fault_plan
        attempts = 0
        while True:
            try:
                if plan is not None:
                    sp = plan.fire(kinds, ordinal)
                    if sp is not None:
                        self.stats.faults_injected += 1
                        raise resilience.FaultInjected(
                            sp.kind, ordinal, sp.fatal)
                return fn()
            except resilience.UnrecoverableFault:
                raise
            except membudget.DeviceOOM:
                raise
            except resilience.FaultInjected as exc:
                if exc.fatal:
                    raise resilience.UnrecoverableFault(str(exc)) from exc
                if exc.kind == "oom":
                    raise membudget.DeviceOOM(
                        str(exc),
                        rung=getattr(self, "mem_rung", None)) from exc
                err = exc
            except Exception as exc:
                if membudget.is_oom_error(exc):
                    raise membudget.DeviceOOM(
                        f"device allocation failed: {exc!r}",
                        rung=getattr(self, "mem_rung", None)) from exc
                if any(a.is_deleted() for a in donated):
                    raise resilience.UnrecoverableFault(
                        f"device call failed after buffer donation: "
                        f"{exc!r}") from exc
                err = exc
            attempts += 1
            if attempts > int(self.p.max_retries):
                raise resilience.UnrecoverableFault(
                    f"retry budget ({self.p.max_retries}) exhausted: "
                    f"{err!r}") from err
            self.stats.retries += 1
            time.sleep(float(self.p.retry_backoff_s) * attempts)

    # ------------------------------------------------------------------ #
    def random_unassigned(self, count: int = 1,
                          in_pool: Optional[np.ndarray] = None
                          ) -> np.ndarray:
        """Next ``count`` unassigned non-pool vertices of the random stream.

        Vectorized skip-pointer scan over the shuffled order; the pointer
        only advances past consumed positions so no vertex is skipped.
        ``in_pool`` selects which pool-membership mask to respect (the
        sharded engine keeps one per device group); default is the
        engine-wide mask.
        """
        if in_pool is None:
            in_pool = self.in_pool
        n = self.hg.n
        out: list = []
        got = 0
        while self.rand_ptr < n and got < count:
            chunk = self.rand_order[self.rand_ptr:
                                    self.rand_ptr + max(1024, count)]
            ok = np.flatnonzero((self.assignment[chunk] < 0)
                                & ~in_pool[chunk])
            if ok.size >= count - got:
                ok = ok[:count - got]
                self.rand_ptr += int(ok[-1]) + 1
            else:
                self.rand_ptr += chunk.size
            take = chunk[ok].astype(np.int64)
            got += take.size
            if take.size:
                out.append(take)
        if got < count:     # stream exhausted; the stragglers sit earlier
            rem = np.flatnonzero((self.assignment < 0) & ~in_pool)
            if out:
                rem = np.setdiff1d(rem, np.concatenate(out),
                                   assume_unique=True)
            if rem.size:
                out.append(rem[:count - got].astype(np.int64))
        return (np.concatenate(out) if out
                else np.empty(0, dtype=np.int64))


class SnapshotMixin:
    """Snapshot/resume for the device-image pipeline states (§4f).

    Mixed into ``engines.pipeline.PipelineState``: captures the complete
    engine state at a drained superstep boundary, restores it
    bit-identically for a same-engine/same-config resume, and
    warm-starts growth from a cross-engine snapshot's assignment.
    """

    def capture_payload(self, acc: np.ndarray, cur_depth: int) -> dict:
        """Complete engine state at a drained superstep boundary.

        Called with the pipeline empty (the driver drains in-flight
        supersteps first), so the only live state is host bookkeeping
        plus the settled device image. Everything the continuation
        reads is captured; static derivatives (adjacency, tile width,
        random order) are rebuilt from the config at restore.
        """
        self._store_flush()
        return {
            "assignment": self.assignment.copy(),
            "acc": acc.copy(),
            "cur_depth": int(cur_depth),
            "in_pool": self.in_pool.copy(),
            "cache_scored": self.cache_scored.copy(),
            "pools": [ids.copy() for ids in self.pools],
            "bq_key": self.bq_key.copy(),
            "bq_edge": self.bq_edge.copy(),
            "seq_back": int(self._seq_back),
            "seq_front": int(self._seq_front),
            "edge_queued": self.edge_queued.copy(),
            "edge_dead": self.edge_dead.copy(),
            "delta_ids": [a.copy() for a in self.delta_ids],
            "delta_vals": [a.copy() for a in self.delta_vals],
            "pending_dirty": [a.copy() for a in self.pending_dirty],
            "rand_ptr": int(self.rand_ptr),
            "rng_state": self.rng.bit_generator.state,
            "dirty_ratchet": int(self._dirty_ratchet),
            "stats": dataclasses.replace(self.stats),
            "dev_assign": np.asarray(self.dev_assign),
            # on the spill rung the authoritative cache IS the host
            # mirror; either way the payload carries plain numpy
            "dev_cache": (self.host_cache.copy()
                          if self.host_cache is not None
                          else np.asarray(self.dev_cache)),
            "dev_acc": np.asarray(self.dev_acc),
        }

    def restore_exact(self, pay: dict):
        """Resume bit-identically from a same-engine/config payload.

        Returns ``(acc, cur_depth)`` for the driver. The device image
        is re-uploaded from the snapshot's downloaded copies; the
        poison flag restarts clean (snapshots are only taken at drained,
        replayed-if-needed boundaries).
        """
        self.assignment = pay["assignment"].copy()
        self.in_pool = pay["in_pool"].copy()
        self.cache_scored = pay["cache_scored"].copy()
        self.pools = [ids.copy() for ids in pay["pools"]]
        self.bq_key = pay["bq_key"].copy()
        self.bq_edge = pay["bq_edge"].copy()
        self._bq_pending = []
        self._seq_back = np.int64(pay["seq_back"])
        self._seq_front = np.int64(pay["seq_front"])
        self.edge_queued = pay["edge_queued"].copy()
        self.edge_dead = pay["edge_dead"].copy()
        self.delta_ids = [a.copy() for a in pay["delta_ids"]]
        self.delta_vals = [a.copy() for a in pay["delta_vals"]]
        self.pending_dirty = [a.copy() for a in pay["pending_dirty"]]
        self.rand_ptr = int(pay["rand_ptr"])
        self.rng.bit_generator.state = pay["rng_state"]
        self._dirty_ratchet = int(pay["dirty_ratchet"])
        self.stats = dataclasses.replace(pay["stats"])
        self.dev_assign = self._to_device(pay["dev_assign"])
        if self.host_cache is not None:
            self.host_cache = pay["dev_cache"].astype(np.float32,
                                                      copy=True)
        else:
            self.dev_cache = self._to_device(pay["dev_cache"])
        self.dev_acc = self._to_device(pay["dev_acc"])
        self.dev_poison = self._to_device(np.zeros(1, dtype=np.int32))
        return pay["acc"].copy(), int(pay["cur_depth"])

    def restore_warm(self, warm: np.ndarray) -> np.ndarray:
        """Cross-engine warm start: adopt a (partial) assignment.

        Mirrors the assignment into the device image and activates the
        incident edges of every adopted member, so growth continues
        from the snapshot instead of from scratch. Exactness is not
        claimed (the donor engine's transient state is gone) — this is
        the degradation ladder's path. Returns the per-phase totals.
        """
        done = np.flatnonzero(warm >= 0)
        acc = np.zeros(self.k, dtype=np.int64)
        if done.size:
            ph = warm[done].astype(np.int64)
            self.assignment[done] = warm[done]
            acc[:int(ph.max()) + 1] = np.bincount(ph)
            self.dev_assign = self._to_device(
                self.assignment.astype(np.int32, copy=True))
            self.dev_acc = self._to_device(
                acc.astype(np.int32, copy=True))
            self.activate_many(done.astype(np.int64), ph)
        return acc


def maybe_refine(hg: Hypergraph, k: int, params,
                 assignment: np.ndarray, stats: BatchedStats
                 ) -> np.ndarray:
    """Run the k-way refinement post-pass when ``refine_passes`` > 0.

    Shared by every engine of the family (DESIGN.md §4e): boundary
    vertices are screened on device by the ``kway_gains`` kernel and
    moved under exact-gain, balance-capped admission, so the engine's
    ``max - min <= 1`` contract survives. ``refine_passes = 0`` returns
    the assignment object untouched — the engines stay bit-identical to
    their pre-refinement outputs (golden-hash-enforced).
    """
    passes = getattr(params, "refine_passes", 0)
    if passes <= 0 or k <= 1:
        return assignment
    from ..core.refine import refine_kway

    refined, rstats = refine_kway(hg, assignment, k, passes)
    stats.refine = rstats
    return refined


def _harvest_next(st, inflight: collections.deque,
                  acc: np.ndarray, targets: np.ndarray) -> int:
    """Harvest the oldest in-flight superstep, replaying a poisoned one.

    When the popped superstep was quarantined (non-finite scores — an
    injected NaN tile, normally), every in-flight superstep dispatched
    after it self-aborted on the sticky poison flag: replay the whole
    window in FIFO order from the handles' clean args so device-effect
    order — and therefore bit-identical recovery — is preserved.
    """
    h = inflight.popleft()
    if int(np.asarray(h.poison)[0]) > 0:
        h = st.replay(h)
        redo = list(inflight)
        inflight.clear()
        for old in redo:
            inflight.append(st.replay(old))
    return st.harvest(h, acc, targets, [e.fresh_ids for e in inflight])


def _teardown_pipeline(st, inflight: collections.deque) -> None:
    """Settle the donated-buffer chains of an aborted run (§4f).

    Blocks on every in-flight superstep's outputs so each donated
    execution completes (deleting a donated buffer synchronizes with
    the execution consuming it), then drops the handles and the queued
    host transients. Nothing device-side survives except the state's
    own current image arrays — no zombie refs, and the process is free
    to start a fresh engine run.
    """
    for h in list(inflight):
        try:
            np.asarray(h.winners)
            np.asarray(h.poison)
        except Exception:       # the abort may have broken the call
            pass
    inflight.clear()
    st.delta_ids, st.delta_vals = [], []
    st.pending_dirty = []


def run_pipeline(hg: Hypergraph, k: int, p, make_state, engine: str,
                 devices: int = 0, mem_rung: int = 0,
                 mem_warm: Optional[np.ndarray] = None,
                 mem_retries: int = 0):
    """Grow all ``k`` partitions concurrently; returns (assignment, state).

    The shared double-buffered superstep driver of the device engines
    (DESIGN.md §4d). Each *superstep* is one fused device call that
    scores the stacked fresh-candidate tiles of every growing phase and
    admits each phase's top-``t`` on device (paper §VI k-way growth).
    Up to ``p.pipeline_depth`` supersteps stay in flight: while the
    device computes superstep N, the host mirrors superstep N-1's
    admissions and speculatively draws/packs superstep N+1; proposals
    that went stale in between are skipped on device by the
    deterministic redraw rule, so results are seeded-deterministic at
    any depth and ``pipeline_depth=1`` reproduces the lock-step engine
    bit for bit.

    ``make_state(p, mem_rung)`` builds the engine's pipeline state (a
    ``engines.pipeline.PipelineState`` subclass); its ``st.k`` may pad
    ``k`` up (the sharded engine's device-aligned phase groups) and its
    ``release_pools`` hook clears the engine's pool masks at the end.
    ``engine``/``devices`` identify the schedule in snapshot configs.

    Resilience (DESIGN.md §4f): every ``p.snapshot_every`` supersteps
    the driver drains the pipeline and publishes a checkpoint; with
    ``p.resume`` pointing at a same-engine/same-config snapshot the run
    restores it and continues bit-identically to an uninterrupted run
    with the same cadence (a cross-engine snapshot warm-starts from its
    assignment instead). Any exception tears the pipeline down safely.
    """
    import time as _time

    st = make_state(p, mem_rung)
    if st.dev is None:
        return None, None                       # caller falls back
    kG = st.k
    st.stats.mem_retries = int(mem_retries)
    n = hg.n
    base, rem = divmod(n, k)
    targets = np.zeros(kG, dtype=np.int64)
    targets[:k] = base + (np.arange(k) < rem)
    targets_i32 = targets.astype(np.int32)
    acc = np.zeros(kG, dtype=np.int64)
    R, P, t = p.rows, p.pool_cap, p.t
    delta_cap = max(2 * kG * t, kG)
    # the memory plan may clamp the pipeline to lock-step (rung >= the
    # depth reduction): the clamp is part of the schedule, and at an
    # unconstrained budget the plan echoes the param unchanged
    depth = max(1, min(int(p.pipeline_depth),
                       int(st.mem_plan.pipeline_depth)))
    fringe = np.full((kG, 1), -1, dtype=np.int32)   # fringe-free scoring
    snap_every = max(0, int(p.snapshot_every or 0))
    # everything that decides the superstep schedule: an exact restore
    # requires all of it to match (snapshot cadence included — draining
    # the pipeline at snapshots IS part of the schedule at depth > 1).
    # Of the memory plan (§4g) only the EFFECTIVE tile width and the
    # depth clamp enter: the chunk/spill/paged rungs are bit-exact per
    # superstep, so a snapshot restores exactly across them, while a
    # tile_l or depth change is a schedule change and must warm-start
    config = {"k": k, "devices": devices, "t": t, "rows": R,
              "pool_cap": P, "s": p.s, "seed": p.seed,
              "pipeline_depth": depth, "snapshot_every": snap_every,
              "tile_l": int(st.tile_l)}

    cur_depth = depth
    seeded = False
    ckpt = resilience.load_latest(p.resume) if p.resume else None
    if ckpt is not None:
        t0 = _time.perf_counter()
        resilience.check_checkpoint(ckpt, hg, k)
        if ckpt.engine == engine and ckpt.config == config:
            acc, cur_depth = st.restore_exact(ckpt.payload)
            seeded = True       # the snapshot already carries the seeds
        else:
            acc = st.restore_warm(resilience.warm_assignment(ckpt))
        st.stats.resumed_at = int(ckpt.superstep)
        st.stats.restore_s += _time.perf_counter() - t0
    elif mem_warm is not None:
        # memory-rung retry (DESIGN.md §4g): adopt the failed attempt's
        # host assignment mirror so already-grown members survive the
        # re-tiling — the seeding below only fills still-empty phases
        acc = st.restore_warm(np.asarray(mem_warm, dtype=np.int32))

    if not seeded:
        # seed every empty phase with one random vertex (paper §III-B1
        # step 1); a warm start only seeds phases the snapshot left empty
        seeds = st.random_unassigned(
            int(((acc == 0) & (targets > 0)).sum()))
        gi = 0
        for g in range(kG):
            if targets[g] == 0 or acc[g] > 0 or gi >= seeds.size:
                continue
            v = seeds[gi:gi + 1]
            gi += 1
            st.assign_now(v, g)
            st.activate_phase(v, g)
            acc[g] += 1

    last_snap = int(st.stats.supersteps)
    inflight: collections.deque = collections.deque()
    try:
        while True:
            progress = 0
            if (snap_every
                    and st.stats.supersteps - last_snap >= snap_every):
                while inflight:     # drain: snapshots see settled state
                    progress += _harvest_next(st, inflight, acc, targets)
                t0 = _time.perf_counter()
                st.stats.snapshots += 1
                resilience.save_snapshot(
                    p.snapshot_dir,
                    resilience.PartitionCheckpoint(
                        engine, int(st.stats.supersteps),
                        hg.fingerprint(), dict(config),
                        st.capture_payload(acc, cur_depth)),
                    keep_last=int(p.keep_last))
                st.stats.snapshot_s += _time.perf_counter() - t0
                last_snap = int(st.stats.supersteps)
            active = np.flatnonzero(acc < targets)
            if active.size == 0:
                break
            while len(inflight) >= cur_depth:   # tail heuristic shrank
                progress += _harvest_next(st, inflight, acc, targets)
            t0 = _time.perf_counter()
            packed, injected = st.pack_superstep(active, R, P, t,
                                                 targets, acc)
            progress += injected
            if packed is not None:
                fresh, bias, pool_arr, fresh_ids = packed
                handle = st.dispatch(fresh, bias, pool_arr, fringe,
                                     fresh_ids, targets_i32, delta_cap,
                                     t)
            st.stats.host_s += _time.perf_counter() - t0
            if packed is not None:
                inflight.append(handle)
            elif inflight:
                st.stats.pipeline_stalls += 1   # device idles this round
            if inflight and (len(inflight) >= cur_depth
                             or packed is None):
                harvested = _harvest_next(st, inflight, acc, targets)
                progress += harvested
                # adaptive depth: while a superstep admits less than
                # half its capacity the draw view — not the device — is
                # the bottleneck, and speculative packs only waste
                # fixed-cost device calls; drop to lock-step until
                # admissions recover. Deterministic: based solely on
                # mirrored results.
                cur_depth = 1 if 2 * harvested < active.size * t else depth
            if progress == 0 and not inflight:
                break   # starved: remaining vertices sit in other pools
        while inflight:     # drain the pipeline before the safety net
            _harvest_next(st, inflight, acc, targets)
    except membudget.DeviceOOM as exc:
        # memory fault mid-run: settle the pipeline, then enrich the
        # exception with everything the re-tiling retry loop needs —
        # the rung this attempt ran at and the host assignment mirror
        # (the admissions harvested so far) for the warm start
        _teardown_pipeline(st, inflight)
        if exc.rung is None:
            exc.rung = int(st.mem_plan.rung)
        exc.partial = st.assignment.copy()
        raise
    except BaseException:
        # abort path (injected unrecoverable fault, KeyboardInterrupt,
        # real device failure): settle every donated chain before
        # propagating so no zombie buffer outlives the run
        _teardown_pipeline(st, inflight)
        raise

    # safety net: balance-fill any stragglers into underfull phases
    rem_v = np.flatnonzero(st.assignment < 0)
    if rem_v.size:
        deficit = np.maximum(targets - acc, 0)
        fill = np.repeat(np.arange(kG), deficit)[:rem_v.size]
        st.assignment[rem_v[:fill.size]] = fill.astype(np.int32)
    st.release_pools()
    # the device image syncs at superstep boundaries only; the final
    # injections' delta dies with the state (the host assignment is
    # authoritative). Tests needing device/host parity flush explicitly
    # through dispatch/harvest.
    st.delta_ids, st.delta_vals = [], []
    obs = membudget.observed_peak_bytes()
    st.stats.peak_bytes_observed = (int(obs) if obs else
                                    int(st.stats.peak_bytes_planned))
    return st.assignment, st


def run_pipeline_budgeted(hg: Hypergraph, k: int, p, make_state,
                          engine: str, devices: int = 0):
    """``run_pipeline`` under the memory-rung retry loop (§4g).

    A ``DeviceOOM`` — a real allocator failure at the upload, dispatch
    or harvest site, or an injected non-fatal ``oom`` fault — retries
    the SAME engine at the next-smaller memory plan, warm-started from
    the failed attempt's host assignment mirror, before the
    engine-degradation ladder (``partition_resilient``) is ever
    consulted. Only an exhausted rung ladder escalates, as
    ``UnrecoverableFault``. The fault plan is resolved once up front so
    a one-shot injected ``oom`` spec stays consumed across retries
    (re-parsing ``REPRO_FAULT_PLAN`` per attempt would re-fire it
    forever).
    """
    fplan = resilience.resolve_fault_plan(p.fault_plan)
    if fplan is not None:
        p = dataclasses.replace(p, fault_plan=fplan)
    rung, warm, retries = 0, None, 0
    while True:
        try:
            return run_pipeline(hg, k, p, make_state, engine, devices,
                                mem_rung=rung, mem_warm=warm,
                                mem_retries=retries)
        except membudget.DeviceOOM as exc:
            retries += 1
            rung = (rung if exc.rung is None else int(exc.rung)) + 1
            if exc.partial is not None and (exc.partial >= 0).any():
                warm = exc.partial
        except membudget.MemoryLadderExhausted as exc:
            raise resilience.UnrecoverableFault(
                f"device memory rungs exhausted: {exc}") from exc
