"""Fully device-resident loop engine (DESIGN.md §4i).

The entire k-way growth loop — pool maintenance, store draws, scoring,
admission, exact cache decrements, restarts — runs as one
``lax.while_loop`` program on device (``core/device_loop.py``); the
host uploads the graph image once and downloads a few scalars per chunk
of supersteps. The schedule is the lock-step pd1 cadence by
construction, which is what makes the engine golden-hash bit-identical
to ``hype_superstep`` at ``pipeline_depth=1``.

The driver builds its initial carry from a plain
``engines.pipeline.PipelineState`` (the seeded host bookkeeping + the
uploaded image) — it never dispatches through the pipeline, so the
abstract ``_call_program`` is never reached. Fallbacks: the superstep
host pipeline down the §4g rung ladder on device OOM, the engine ladder
(``superstep`` → ``batched``) when the int32 encoding gates or the
memory plan reject the graph.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from ..core.hypergraph import Hypergraph
from ..core import device_loop
from ..core import membudget
from ..core import resilience
from ..core.scoring import gather_csr_rows
from .pipeline import PipelineState
from .runtime import BatchedStats, maybe_refine
from .superstep import SuperstepParams


@dataclasses.dataclass
class DeviceParams(SuperstepParams):
    """Knobs for the fully device-resident loop engine (DESIGN.md §4i).

    ``pipeline_depth`` is ignored: the device loop runs the lock-step
    pd1 cadence by construction — that is exactly what makes it
    golden-hash bit-identical to ``hype_superstep`` at depth 1.
    """
    # supersteps per host-visible while_loop segment; the host syncs a
    # handful of scalars (flags / progress / acc) once per chunk and the
    # snapshot cadence shortens chunks to land on its boundaries
    chunk_supersteps: int = 64
    # device score-cache storage: "float32" is bit-identical to the host
    # engines; "float16" halves the cache bytes — scores are small exact
    # integers plus the 1e12 hub penalty, so fp16 rounding only perturbs
    # ties above 2048 external neighbors (bounded-error tested)
    cache_dtype: str = "float32"
    # capacity overrides for the fixed device rings (None = planned from
    # graph statistics; the driver doubles a flagged cap and re-runs —
    # schedules are capacity-independent, so the rerun is bit-identical)
    store_cap: Optional[int] = None
    act_cap: Optional[int] = None


def _device_probe_faults(st: PipelineState, lo: int, hi: int):
    """Fire injected dispatch/oom specs for superstep ordinals [lo, hi].

    The host engines fire these one superstep at a time inside
    ``_guarded_kernel``; the device loop runs a whole chunk per host
    call, so the driver probes the chunk's ordinal range up front —
    same plan, same ordinals, same escalation rules.
    """
    plan = st.fault_plan
    if plan is None:
        return
    for o in range(lo, hi + 1):
        sp = plan.fire(("dispatch", "oom"), o)
        if sp is None:
            continue
        st.stats.faults_injected += 1
        if sp.fatal:
            raise resilience.UnrecoverableFault(
                f"injected fatal {sp.kind} fault at superstep {o}")
        if sp.kind == "oom":
            raise membudget.DeviceOOM(
                f"injected OOM at superstep {o}", rung=st.mem_rung)
        # transient dispatch fault: the injection fires *before* the
        # call, so the retry re-issues the identical pure chunk —
        # mirror _guarded_kernel's accounting and continue
        st.stats.retries += 1
        time.sleep(float(st.p.retry_backoff_s))


def _device_probe_nan(st: PipelineState, lo: int, hi: int):
    """Find the first injected nan spec in [lo, hi]; returns ordinal|-1.

    The device program poisons the flagged superstep's bias tile on
    device (``poison_at``) and replays it in place with the clean bias
    — the same quarantine/replay recovery as the host pipeline.
    """
    plan = st.fault_plan
    if plan is None:
        return -1
    for o in range(lo, hi + 1):
        sp = plan.fire(("nan",), o)
        if sp is None:
            continue
        st.stats.faults_injected += 1
        if sp.fatal:
            raise resilience.UnrecoverableFault(
                f"injected fatal nan tile at superstep {o}")
        return o
    return -1


def _device_export(st: PipelineState, k: int, acc: np.ndarray,
                   caps: dict, cache_f16: bool):
    """Build the initial device carry from the seeded host state.

    Returns ``(carry_np, caps)`` — plain numpy; the attempt loop
    uploads. ``caps["sp"]`` may grow if the host store does not fit.
    """
    hg, n, m = st.hg, st.hg.n, st.hg.m
    P = int(st.p.pool_cap)
    st._store_flush()
    enc = device_loop.host_store_to_device(
        st.bq_key, st.bq_edge, k, caps["sp"])
    while enc is None:
        caps = dict(caps, sp=caps["sp"] * 2)
        enc = device_loop.host_store_to_device(
            st.bq_key, st.bq_edge, k, caps["sp"])
    skey, sedge, sback, sfront = enc
    pool = np.full((k, P), -1, dtype=np.int32)
    pool_n = np.zeros(k, dtype=np.int32)
    for g, ids in enumerate(st.pools):
        pool[g, :ids.size] = ids
        pool_n[g] = ids.size
    # queued decrements: the undrained delta's neighbor multiset (the
    # host drains it at the next dispatch) plus any queued winner tails
    pend = np.zeros(n, dtype=np.int32)
    d_ids, _ = st.take_delta(1 << 60)
    if d_ids.size:
        nbrs, _ = gather_csr_rows(st.adj[0], st.adj[1], d_ids)
        np.add.at(pend, nbrs, 1)
    for a in st.pending_dirty:
        np.add.at(pend, np.asarray(a, dtype=np.int64), 1)
    st.pending_dirty = []
    cache = np.asarray(st.dev_cache, dtype=np.float32).copy()
    if cache_f16:
        cache = np.clip(cache, -65504.0, 65504.0).astype(np.float16)
    carry = dict(
        assign=st.assignment.astype(np.int32, copy=True),
        cache=cache,
        acc=acc.astype(np.int32, copy=True),
        in_pool=st.in_pool.copy(),
        cache_scored=st.cache_scored.copy(),
        edge_queued=st.edge_queued.copy(),
        edge_dead=st.edge_dead.copy(),
        skey=skey, sedge=sedge, sback=sback, sfront=sfront,
        pool=pool, pool_n=pool_n, pend=pend,
        rand_ptr=np.int32(st.rand_ptr),
        supersteps=np.int32(st.stats.supersteps),
        progress=np.int32(1),
        flags=np.int32(0),
        ss_in_chunk=np.int32(0),
        stats=np.zeros(device_loop.NSTATS, dtype=np.int32),
    )
    return carry, caps


def _device_attempt(hg: Hypergraph, k: int, p: DeviceParams,
                    caps_over: dict):
    """One capacity attempt of the device loop.

    Returns ``("ok", assignment, st)``, ``("fallback", reason, None)``
    or ``("overflow", flags, caps)``. DeviceOOM propagates (enriched
    with rung + partial) for the caller's ladder.
    """
    import time as _time

    chunk_max = max(1, int(getattr(p, "chunk_supersteps", 64)))
    cache_dtype = str(getattr(p, "cache_dtype", "float32"))
    cache_f16 = cache_dtype == "float16"
    st = PipelineState(hg, k, dataclasses.replace(p, pipeline_depth=1),
                       mem_rung=0)
    if st.dev is None:
        return ("fallback", "no device adjacency", None)
    if st.mem_plan.rung != 0:
        # the budget wants a reduced configuration; the §4g rungs are
        # host-pipeline programs — hand the whole run to that engine
        return ("fallback", "memory plan below rung 0", None)
    n, m = hg.n, hg.m
    base, rem = divmod(n, k)
    targets = np.zeros(k, dtype=np.int64)
    targets[:] = base + (np.arange(k) < rem)
    acc = np.zeros(k, dtype=np.int64)
    R, P, t = int(p.rows), int(p.pool_cap), int(p.t)
    vdeg = np.diff(hg.v2e_indptr).astype(np.int64)
    mean_vdeg = float(vdeg.mean()) if n else 1.0
    mean_adeg = float(st.deg.mean()) if n else 1.0
    sizes = st.edge_sizes
    max_edge = int(sizes.max()) if m else 1
    caps = device_loop.plan_caps(
        n=n, m=m, kG=k, rows=R, t=t, mean_vdeg=mean_vdeg,
        mean_adeg=mean_adeg, max_edge=max_edge,
        store_cap=getattr(p, "store_cap", None),
        act_cap=getattr(p, "act_cap", None))
    caps.update(caps_over)
    if not device_loop.supported(n=n, m=m, kG=k, bud=caps["bud"]):
        return ("fallback", "int32 encoding gates", None)

    snap_every = max(0, int(p.snapshot_every or 0))
    config = {"k": k, "devices": 0, "t": t, "rows": R, "pool_cap": P,
              "s": p.s, "seed": p.seed, "pipeline_depth": 1,
              "snapshot_every": snap_every, "tile_l": int(st.tile_l),
              "chunk_supersteps": chunk_max, "cache_dtype": cache_dtype}
    engine = "hype_device"
    resumed_carry = None
    ckpt = resilience.load_latest(p.resume) if p.resume else None
    if ckpt is not None:
        t0 = _time.perf_counter()
        resilience.check_checkpoint(ckpt, hg, k)
        if ckpt.engine == engine and ckpt.config == config:
            pay = ckpt.payload
            resumed_carry = {kk: vv.copy()
                             for kk, vv in pay["carry"].items()}
            caps = dict(pay["caps"])
            caps.update(caps_over)
            st.stats = dataclasses.replace(pay["stats"])
            acc = np.asarray(resumed_carry["acc"], dtype=np.int64)
        else:
            acc = st.restore_warm(resilience.warm_assignment(ckpt))
        st.stats.resumed_at = int(ckpt.superstep)
        st.stats.restore_s += _time.perf_counter() - t0

    if resumed_carry is None:
        # seed every empty phase with one random vertex — exactly the
        # pipeline driver's loop, so the device schedule starts from
        # the same state and random stream position
        seeds = st.random_unassigned(
            int(((acc == 0) & (targets > 0)).sum()))
        gi = 0
        for g in range(k):
            if targets[g] == 0 or acc[g] > 0 or gi >= seeds.size:
                continue
            v = seeds[gi:gi + 1]
            gi += 1
            st.assign_now(v, g)
            st.activate_phase(v, g)
            acc[g] += 1
        carry_np, caps = _device_export(st, k, acc, caps, cache_f16)
    else:
        carry_np = resumed_carry
        carry_np["flags"] = np.int32(0)
        carry_np["progress"] = np.int32(1)

    cfg = device_loop.DeviceLoopConfig(
        n=n, m=m, kG=k, rows=R, pool_cap=P, t=t, tile_l=int(st.tile_l),
        bud=caps["bud"], pp=caps["pp"], sp=caps["sp"], act=caps["act"],
        rawt=caps["rawt"], rawd=caps["rawd"], cw=caps["cw"],
        cache_f16=cache_f16, interpret=bool(st.interpret))

    import jax
    import jax.numpy as jnp

    cls_edge = np.where(
        sizes <= 1, np.int64(0),
        np.ceil(np.log2(np.maximum(sizes, 2))).astype(np.int64))
    consts = dict(
        adj_indptr=jnp.asarray(st.adj[0].astype(np.int32)),
        adj_indices=jnp.asarray(st.adj[1].astype(np.int32)),
        v2e_indptr=jnp.asarray(hg.v2e_indptr.astype(np.int32)),
        v2e_indices=jnp.asarray(hg.v2e_indices.astype(np.int32)),
        e2v_indptr=jnp.asarray(hg.e2v_indptr.astype(np.int32)),
        e2v_indices=jnp.asarray(hg.e2v_indices.astype(np.int32)),
        cls_edge=jnp.asarray(cls_edge.astype(np.int32)),
        deg=jnp.asarray(st.deg.astype(np.int32)),
        vdeg=jnp.asarray(vdeg.astype(np.int32)),
        targets=jnp.asarray(targets.astype(np.int32)),
        rand_order=jnp.asarray(st.rand_order.astype(np.int32)),
        fringe=jnp.full((k, 1), -1, jnp.int32),
    )
    try:
        run = device_loop.device_loop_program(cfg)
        carry = {kk: jnp.asarray(vv) for kk, vv in carry_np.items()}
    except Exception as exc:
        if membudget.is_oom_error(exc):
            raise membudget.DeviceOOM(
                f"device loop image upload failed: {exc!r}",
                rung=st.mem_rung) from exc
        raise
    st.stats.loop_state_bytes = device_loop.carry_bytes(carry_np)
    st.stats.device_image_bytes = int(
        sum(int(v.nbytes) for v in consts.values())) + \
        st.stats.loop_state_bytes

    def _snapshot_payload(carry_dev):
        return {"carry": {kk: np.asarray(vv)
                          for kk, vv in carry_dev.items()},
                "caps": dict(caps),
                "stats": dataclasses.replace(st.stats)}

    last_snap = int(carry_np["supersteps"])
    last_known = st.assignment.copy()
    t_wall0 = _time.perf_counter()
    host_accum = 0.0
    try:
        while True:
            t_host = _time.perf_counter()
            ss_now = int(np.asarray(carry["supersteps"]))
            acc_h = np.asarray(carry["acc"]).astype(np.int64)
            if snap_every and ss_now - last_snap >= snap_every:
                t0 = _time.perf_counter()
                st.stats.snapshots += 1
                resilience.save_snapshot(
                    p.snapshot_dir,
                    resilience.PartitionCheckpoint(
                        engine, ss_now, hg.fingerprint(), dict(config),
                        _snapshot_payload(carry)),
                    keep_last=int(p.keep_last))
                st.stats.snapshot_s += _time.perf_counter() - t0
                last_snap = ss_now
                last_known = np.asarray(carry["assign"]).copy()
            if (acc_h >= targets).all():
                break
            if int(np.asarray(carry["progress"])) == 0:
                break   # starved: stragglers sit in other pools
            cap = chunk_max
            if snap_every:
                cap = min(cap, snap_every - (ss_now - last_snap))
            cap = max(1, cap)
            _device_probe_faults(st, ss_now + 1, ss_now + cap)
            poison_at = _device_probe_nan(st, ss_now + 1, ss_now + cap)
            if poison_at > 0:
                cap = poison_at - ss_now    # poisoned step ends chunk
            host_accum += _time.perf_counter() - t_host
            t_dev = _time.perf_counter()
            try:
                carry = run(consts, carry, jnp.int32(cap),
                            jnp.int32(poison_at))
                flags = int(np.asarray(carry["flags"]))   # blocks
            except Exception as exc:
                if membudget.is_oom_error(exc):
                    raise membudget.DeviceOOM(
                        f"device loop chunk failed: {exc!r}",
                        rung=st.mem_rung) from exc
                raise
            st.stats.device_s += _time.perf_counter() - t_dev
            st.stats.loop_chunks += 1
            if flags:
                if flags & device_loop.FLAG_POISON:
                    raise resilience.UnrecoverableFault(
                        "superstep still poisoned after a clean "
                        "replay: the kernel emits non-finite scores "
                        "for finite inputs")
                return ("overflow", flags, caps)
    except membudget.DeviceOOM as exc:
        if exc.rung is None:
            exc.rung = int(st.mem_plan.rung)
        exc.partial = last_known
        raise
    st.stats.host_s += host_accum

    # final download + host mirror
    st.assignment = np.asarray(carry["assign"]).astype(np.int32,
                                                       copy=True)
    acc = np.asarray(carry["acc"]).astype(np.int64)
    dstats = np.asarray(carry["stats"]).astype(np.int64)
    st.stats.supersteps = int(np.asarray(carry["supersteps"]))
    st.stats.kernel_calls += st.stats.supersteps
    st.stats.loop_rounds += int(dstats[device_loop.S_ROUNDS])
    st.stats.loop_pack_only += int(dstats[device_loop.S_PACK_ONLY])
    st.stats.loop_store_peak = max(
        st.stats.loop_store_peak,
        int(dstats[device_loop.S_STORE_PEAK]))
    st.stats.refill_signals += int(dstats[device_loop.S_REFILL])
    st.stats.kernel_rows += int(dstats[device_loop.S_KERNEL_ROWS])
    st.stats.edges_scanned += int(dstats[device_loop.S_EDGES_SCANNED])
    st.stats.cache_invalidations += int(dstats[device_loop.S_CACHE_INV])
    st.stats.cache_hits += int(dstats[device_loop.S_CACHE_HITS])
    st.stats.random_restarts += int(dstats[device_loop.S_RESTARTS])
    st.stats.stale_redraws += int(dstats[device_loop.S_STALE])
    st.stats.retries += int(dstats[device_loop.S_RETRIES])
    # safety net: balance-fill any stragglers into underfull phases
    rem_v = np.flatnonzero(st.assignment < 0)
    if rem_v.size:
        deficit = np.maximum(targets - acc, 0)
        fill = np.repeat(np.arange(k), deficit)[:rem_v.size]
        st.assignment[rem_v[:fill.size]] = fill.astype(np.int32)
    st.in_pool[:] = False
    obs = membudget.observed_peak_bytes()
    st.stats.peak_bytes_observed = (int(obs) if obs else
                                    int(st.stats.peak_bytes_planned))
    del t_wall0
    return ("ok", st.assignment, st)


def _run_device_loop(hg: Hypergraph, k: int, p: DeviceParams):
    """Run the §4i device loop with the capacity-doubling rerun ladder.

    Returns ``(assignment, st)`` or ``(None, None)`` for the caller's
    engine fallback. A rerun with doubled caps replays bit-identically
    (the superstep schedule is capacity-independent); FLAG_SEQ —
    per-phase sequence-space exhaustion — has no doubling answer and
    falls back.
    """
    caps_over: dict = {}
    for _ in range(5):
        kind, a, b = _device_attempt(hg, k, p, caps_over)
        if kind == "ok":
            return a, b
        if kind == "fallback":
            return None, None
        flags, caps = a, b
        if flags & device_loop.FLAG_SEQ:
            return None, None
        if flags & device_loop.FLAG_STORE:
            caps_over["sp"] = 2 * caps["sp"]
        if flags & device_loop.FLAG_ACT:
            caps_over["act"] = 2 * caps["act"]
        if flags & device_loop.FLAG_RAWT:
            caps_over["rawt"] = 2 * caps["rawt"]
        if flags & device_loop.FLAG_RAWD:
            caps_over["rawd"] = 2 * caps["rawd"]
    return None, None


def hype_device_partition(hg: Hypergraph, k: int,
                          params: Optional[DeviceParams] = None,
                          return_stats: bool = False):
    """Partition ``hg`` with the fully device-resident loop (§4i).

    The entire k-way growth loop — pool maintenance, store draws,
    scoring, admission, exact cache decrements, restarts — runs as one
    ``lax.while_loop`` program on device; the host uploads the graph
    image once and downloads a few scalars per chunk of supersteps.
    Bit-identical to ``hype_superstep_partition`` at
    ``pipeline_depth=1`` with matching knobs. Falls back to
    ``hype_superstep_partition`` when the int32 encoding gates or the
    memory plan reject the graph, and down the §4g rung ladder (via the
    host pipeline) on device OOM.
    """
    if params is None:
        params = DeviceParams()
    if params.rows is None:
        params = dataclasses.replace(params, rows=max(8, params.t))
    if k < 1:
        raise ValueError("k must be >= 1")
    if params.t < 1 or params.rows < 1 or params.pool_cap < 1:
        raise ValueError("rows, pool_cap, t must all be >= 1")
    if int(getattr(params, "chunk_supersteps", 64)) < 1:
        raise ValueError("chunk_supersteps must be >= 1")
    if getattr(params, "cache_dtype", "float32") not in (
            "float32", "float16"):
        raise ValueError("cache_dtype must be float32 or float16")
    if params.snapshot_every > 0 and not params.snapshot_dir:
        raise ValueError("snapshot_every requires snapshot_dir")
    if k == 1:
        out = np.zeros(hg.n, dtype=np.int32)
        return (out, BatchedStats()) if return_stats else out
    fplan = resilience.resolve_fault_plan(params.fault_plan)
    if fplan is not None:
        params = dataclasses.replace(params, fault_plan=fplan)
    try:
        assignment, st = _run_device_loop(hg, k, params)
    except membudget.DeviceOOM as exc:
        # §4g: the device loop has no reduced-memory program variants —
        # fall down the host pipeline's rung ladder, warm-started from
        # the chunk boundary the failed attempt last synced. The ladder
        # keeps this engine's lock-step cadence (pipeline_depth=1): an
        # upload-time OOM then reruns fresh and lands on the same
        # golden schedule the device loop would have produced
        from .superstep import run_pipeline as superstep_pipeline
        params = dataclasses.replace(params, pipeline_depth=1)
        rung = 1 if exc.rung is None else int(exc.rung) + 1
        warm = (exc.partial if exc.partial is not None
                and (np.asarray(exc.partial) >= 0).any() else None)
        retries = 1
        while True:
            try:
                assignment, pst = superstep_pipeline(
                    hg, k, params, mem_rung=rung, mem_warm=warm,
                    mem_retries=retries)
                break
            except membudget.DeviceOOM as exc2:
                retries += 1
                rung = (rung if exc2.rung is None
                        else int(exc2.rung)) + 1
                if (exc2.partial is not None
                        and (exc2.partial >= 0).any()):
                    warm = exc2.partial
            except membudget.MemoryLadderExhausted as exc2:
                raise resilience.UnrecoverableFault(
                    f"device memory rungs exhausted: {exc2}") from exc2
        if assignment is None:
            from .batched import hype_batched_partition
            return hype_batched_partition(hg, k, params, return_stats)
        pst.stats.fallbacks += 1
        assert (assignment >= 0).all()
        assignment = maybe_refine(hg, k, params, assignment, pst.stats)
        return (assignment, pst.stats) if return_stats else assignment
    if assignment is None:
        from .superstep import hype_superstep_partition
        return hype_superstep_partition(hg, k, params, return_stats)
    assert (assignment >= 0).all()
    assignment = maybe_refine(hg, k, params, assignment, st.stats)
    if return_stats:
        return assignment, st.stats
    return assignment


__all__ = ["DeviceParams", "hype_device_partition"]
