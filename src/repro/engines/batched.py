"""Batched-candidate HYPE: the throughput-oriented host engine (§4).

The paper's engine (``core/hype.py``) moves ONE vertex per growth step
and scores r=2 candidates at a time — latency-bound, CPU-idiomatic.
This engine turns the inner loop into tile work:

  per growth step
    1. (when the candidate pool runs low) draw a bulk batch of candidate
       vertices from the *smallest* active hyperedges — size-bucketed
       queues instead of a heap, one vectorized pin scan per draw,
    2. gather their unassigned-neighbor lists as dense (b, L) tiles
       (``scoring.neighbor_tile_adj``; assigned pins dropped, hubs
       capped),
    3. score every cache-miss candidate through the Pallas
       ``hype_scores`` kernel (fringe membership subtracted on the VPU),
    4. keep scored candidates in a pool sorted by score — the paper's
       s-sized fringe is its top-s — and admit the top-``t`` per step.

``t`` is the quality/speed knob: steps per partition drop from
O(target) to O(target / t); ``t=1`` recovers the sequential admission
order (same greedy rule, wider candidate pool). Scores are lazily
cached per phase exactly like the paper's optimization (c), so the
kernel only sees first-time candidates.

This is the first real consumer of ``kernels/hype_score`` — on CPU the
kernel runs in interpret mode (still one fused batched evaluation); on
TPU the same call compiles to the VPU tile loop the kernel was built
for. The device-resident engines live in their sibling modules
(``engines.superstep`` / ``engines.sharded`` / ``engines.device``) on
the shared ``engines.runtime`` driver.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional

import numpy as np

from ..core.hypergraph import Hypergraph
from ..core import resilience
from ..core import scoring
from .runtime import BatchedStats, EngineRuntime, maybe_refine


@dataclasses.dataclass
class BatchedParams:
    b: int = 256           # rows per kernel tile (the paper's r=2)
    s: int = 16            # max fringe size (kernel compares vs s slots)
    t: int = 8             # admissions per step; 1 = sequential order
    pool_cap: int = 64     # scored candidates held between steps
    refill_lo: int = 64    # refill the pool when it drops below this
    cap_pins: int = 3072   # pins scanned per candidate before truncation
    kernel_min: int = 16   # min batch worth a device round-trip; smaller
    #                        dribbles score on host (same formula and hub
    #                        truncation convention as the kernel tiles)
    refine_passes: int = 0  # post-pass boundary-refinement passes
    #                         (core/refine.py, DESIGN.md §4e); 0 = off,
    #                         output bit-identical to the bare engine
    seed: int = 0
    # resilience knobs (core/resilience.py, DESIGN.md §4f):
    snapshot_every: int = 0     # checkpoint cadence, counted in
    #                             supersteps (device engines) or
    #                             completed phases (batched); 0 = never.
    #                             The cadence is part of the schedule: a
    #                             resumed run is bit-identical to an
    #                             uninterrupted run with the SAME cadence
    #                             (snapshots drain the pipeline).
    snapshot_dir: Optional[str] = None   # where snapshots are published
    keep_last: int = 3          # snapshots the GC retains per directory
    resume: Optional[str] = None    # snapshot file or directory to
    #                                 resume from; a missing or empty
    #                                 directory starts fresh (what the
    #                                 degradation ladder wants)
    fault_plan: Optional[object] = None  # resilience.FaultPlan instance,
    #                                      spec string, or None = read
    #                                      the REPRO_FAULT_PLAN env var
    max_retries: int = 2        # transient-fault retry budget per call
    retry_backoff_s: float = 0.01   # linear backoff between retries


class BatchedState(EngineRuntime):
    """Mutable state for the k growth phases (host side, all numpy)."""

    def __init__(self, hg: Hypergraph, k: int, p: BatchedParams):
        super().__init__(hg, k, p)
        n, m = hg.n, hg.m
        self.in_fringe = np.zeros(n, dtype=bool)
        self.cur_fringe = np.empty(0, dtype=np.int64)
        self.cache = np.full(n, -1.0)
        self.edge_epoch = np.full(m, -1, dtype=np.int32)   # activation epoch
        # size-bucketed active-edge queues (replaces the paper's min-heap):
        # buckets[size] is a FIFO of edge-id arrays; scanning pops from the
        # front and re-queues still-live edges at the front, so smallest
        # edges keep being drawn first, like the heap's requeue.
        self.buckets: dict = {}
        self._fringe_buf = np.full(p.s, -1, dtype=np.int32)

    def set_fringe(self, new_fringe: np.ndarray) -> None:
        """Sync the s-sized fringe view (paper's F) used for scoring."""
        self.in_fringe[self.cur_fringe] = False
        self.in_fringe[new_fringe] = True
        self.cur_fringe = new_fringe
        self._fringe_buf[:] = -1
        self._fringe_buf[:new_fringe.size] = new_fringe

    # ------------------------------------------------------------------ #
    def activate(self, vs: np.ndarray, phase: int) -> None:
        """Mark the edges incident to newly admitted vertices active."""
        edges, _ = scoring.gather_csr_rows(
            self.hg.v2e_indptr, self.hg.v2e_indices, vs)
        if edges.size == 0:
            return
        edges = np.unique(edges.astype(np.int64))
        fresh = edges[(self.edge_epoch[edges] != phase)
                      & ~self.edge_dead[edges]]
        if fresh.size == 0:
            return
        self.edge_epoch[fresh] = phase
        sizes = self.edge_sizes[fresh]
        for sz in np.unique(sizes):
            self.buckets.setdefault(int(sz), collections.deque()).append(
                fresh[sizes == sz])

    # ------------------------------------------------------------------ #
    def draw_candidates(self, need: int) -> np.ndarray:
        """Up to ``need`` distinct universe vertices from smallest edges.

        One vectorized pass: pull edges smallest-size-first under a pin
        budget, scan all their pins at once, retire dead edges (no
        unassigned pin left — forever), requeue the still-live ones at the
        bucket fronts so they are rescanned first next time (the heap's
        requeue, without the heap). Serves the classic batched engine;
        the superstep engines draw all phases at once from the flat
        bucket store instead (``PipelineState.pack_superstep``).
        """
        buckets = self.buckets
        in_pool = self.in_pool
        if need <= 0:
            return np.empty(0, dtype=np.int64)
        budget = max(4 * need, 512)
        batches: list = []
        keys: list = []     # (source bucket key, count) pairs, for requeues
        pulled = 0
        for sz in sorted(buckets.keys()):
            q = buckets[sz]
            while q and pulled < budget:
                arr = q.popleft()
                n_take = (budget - pulled + sz - 1) // max(sz, 1)
                if arr.size > n_take:
                    q.appendleft(arr[n_take:])
                    arr = arr[:n_take]
                batches.append(arr)
                keys.append((sz, arr.size))
                pulled += arr.size * max(sz, 1)
            if not q:
                del buckets[sz]
            if pulled >= budget:
                break
        if not batches:
            return np.empty(0, dtype=np.int64)
        edges = np.concatenate(batches)
        pins, prow = scoring.gather_csr_rows(
            self.hg.e2v_indptr, self.hg.e2v_indices, edges)
        pins = pins.astype(np.int64)
        self.stats.edges_scanned += pins.size
        unassigned = self.assignment[pins] < 0
        live = np.bincount(prow[unassigned], minlength=edges.size) > 0
        if not live.all():
            self.edge_dead[edges[~live]] = True     # dead forever
        live_edges = edges[live]
        if live_edges.size:
            # requeue under the key each edge was drawn from, so the
            # caller's key scheme (exact sizes for the classic engine,
            # power-of-two classes for the superstep engine) is preserved
            lkey = np.repeat([k for k, _ in keys],
                             [c for _, c in keys])[live]
            for s in np.unique(lkey):
                buckets.setdefault(
                    int(s), collections.deque()).appendleft(
                        live_edges[lkey == s])
        fresh = unassigned & ~in_pool[pins]
        cand = pins[fresh]
        if cand.size:
            _, first = np.unique(cand, return_index=True)
            cand = cand[np.sort(first)][:need]
        return cand

    # ------------------------------------------------------------------ #
    def score_misses(self, cand: np.ndarray) -> None:
        """Score cache-miss candidates in one batched pass, fill the cache.

        Large batches (every phase opening, where the bulk of the scoring
        lives) go through the Pallas ``hype_scores`` kernel as one (b, L)
        tile; dribbles below ``kernel_min`` rows are scored by the exact
        same formula on host, because a device round-trip per 2-3 rows is
        precisely the latency-bound pattern this engine exists to avoid.
        """
        if cand.size == 0:
            return
        miss = cand[self.cache[cand] < 0.0]
        self.stats.cache_hits += cand.size - miss.size
        if miss.size == 0:
            return
        if miss.size >= self.p.kernel_min:
            import jax.numpy as jnp
            from repro.kernels.hype_score.ops import hype_scores

            plan = self.fault_plan
            fringe_dev = jnp.asarray(self._fringe_buf)
            for lo in range(0, miss.size, self.p.b):
                chunk = miss[lo:lo + self.p.b]
                # two B buckets (64 / b) keep retraces rare while small
                # top-up batches avoid paying for a full-width tile
                pad_b = 64 if chunk.size <= 64 else self.p.b
                if self.adj is not None:
                    tile, truncated = scoring.neighbor_tile_adj(
                        self.adj, chunk, self.assignment, pad_b=pad_b)
                else:
                    tile, truncated = scoring.neighbor_tile(
                        self.hg, chunk, self.assignment,
                        cap_pins=self.p.cap_pins, pad_b=pad_b)
                ordinal = self.stats.kernel_calls + 1
                out = np.asarray(self._guarded_kernel(
                    lambda: hype_scores(jnp.asarray(tile), fringe_dev),
                    ordinal)).astype(np.float64)
                if plan is not None:
                    sp = plan.fire(("nan",), ordinal)
                    if sp is not None:    # poison the whole score tile
                        self.stats.faults_injected += 1
                        if sp.fatal:
                            raise resilience.UnrecoverableFault(
                                f"injected fatal nan tile at kernel "
                                f"call {ordinal}")
                        out = out.copy()
                        out[:chunk.size] = np.nan
                sc = out[:chunk.size]
                bad = ~np.isfinite(sc)
                if bad.any():   # quarantine: rescore poisoned rows on
                    #             host, bit-identical to a clean kernel
                    sc[bad] = self._rescore_rows(chunk[bad])
                    self.stats.host_rows += int(bad.sum())
                sc[truncated] += scoring.TRUNC_PENALTY
                self.cache[chunk] = sc
                self.stats.kernel_calls += 1
                self.stats.kernel_rows += int(chunk.size)
        else:
            if self.adj is not None:
                sc = scoring.batched_dext_adj(
                    self.adj, miss, self.in_fringe, self.assignment)
            else:
                sc = scoring.batched_dext_numpy(
                    self.hg, miss, self.in_fringe, self.assignment,
                    cap_pins=self.p.cap_pins,
                    max_width=scoring.L_BUCKETS[-1])
            self.stats.host_rows += int(miss.size)
            self.cache[miss] = sc

    def _rescore_rows(self, ids: np.ndarray) -> np.ndarray:
        """Host re-score of NaN-quarantined kernel rows (DESIGN.md §4f).

        Rebuilds the same clipped neighbor tile the kernel saw and
        emulates its count (valid entries minus fringe members), so the
        recovered scores are bit-identical to an unpoisoned kernel call:
        the kernel's integer counts are float32-exact and the truncation
        penalty is applied by the caller either way.
        """
        if self.adj is not None:
            tile, _ = scoring.neighbor_tile_adj(
                self.adj, ids, self.assignment)
        else:
            tile, _ = scoring.neighbor_tile(
                self.hg, ids, self.assignment, cap_pins=self.p.cap_pins)
        tile = tile[:ids.size]
        valid = tile >= 0
        ent = np.where(valid, tile, 0)
        return (valid & ~self.in_fringe[ent]).sum(axis=1).astype(
            np.float64)


def _grow_partition(st: BatchedState, phase: int, target: int,
                    warm: bool = False) -> None:
    """Grow core set ``phase`` to ``target`` vertices.

    The step loop keeps a *pool* of up to ``pool_cap`` scored candidates
    sorted by cached score. Refills happen in bulk (one kernel tile per
    ``b`` rows) whenever the pool runs low; between refills a step is just
    "admit the t best, queue their edges" — the latency-bound per-vertex
    machinery of the sequential engines is gone entirely. The paper's
    s-sized fringe survives as the top-s of the pool: it is what the
    scoring kernel subtracts, exactly like F in Eq. 1.

    ``warm`` continues a phase that already has members (a cross-engine
    warm start from a snapshot, DESIGN.md §4f): existing members are
    activated instead of seeding, and growth resumes from their count.
    """
    p = st.p
    st.cache[:] = -1.0
    st.buckets = {}
    pool = np.empty(0, dtype=np.int64)       # kept sorted by score asc
    pending: list = []                       # admitted, edges not yet queued

    acc = 0
    if warm:
        members = np.flatnonzero(st.assignment == phase)
        acc = int(members.size)
        if acc >= target:
            return
        if acc:
            st.activate(members.astype(np.int64), phase)
    if acc == 0:
        seeds = st.random_unassigned(1)
        if seeds.size == 0:
            return
        st.assignment[seeds] = phase
        st.activate(seeds, phase)
        acc = 1

    while acc < target:
        st.stats.steps += 1
        # ------- refill: bulk-draw and kernel-score new candidates -------
        if pool.size < max(p.t, p.refill_lo):
            if pending:
                st.activate(np.concatenate(pending), phase)
                pending = []
            cand = st.draw_candidates(p.pool_cap - pool.size)
            if cand.size:
                st.score_misses(cand)
                st.in_pool[cand] = True
                pool = np.concatenate([pool, cand])
                pool = pool[np.argsort(st.cache[pool], kind="stable")]
                st.set_fringe(pool[:p.s])
        if pool.size == 0:                    # random restart (batched: on
            # shattered remainders each isolated vertex would otherwise
            # cost a full step, so seed up to t fresh growth points)
            vs = st.random_unassigned(p.t)
            if vs.size == 0:
                return
            st.stats.random_restarts += 1
            pool = vs
            st.in_pool[vs] = True
            st.cache[vs] = 0.0
            st.set_fringe(pool[:p.s])
        # ------- core update: admit the t best pool vertices -------
        nt = min(p.t, target - acc, pool.size)
        admit, pool = pool[:nt], pool[nt:]
        st.assignment[admit] = phase
        st.in_pool[admit] = False
        pending.append(admit)
        st.set_fringe(pool[:p.s])
        acc += int(admit.size)

    # release fringe + pool back to the universe (§III-B1 step 4)
    st.set_fringe(np.empty(0, dtype=np.int64))
    st.in_pool[pool] = False


def hype_batched_partition(hg: Hypergraph, k: int,
                           params: Optional[BatchedParams] = None,
                           return_stats: bool = False):
    """Partition ``hg`` into ``k`` parts with batched-candidate HYPE.

    Same contract as ``hype_partition``: complete int32 assignment with
    perfectly balanced partition sizes (max - min <= 1).

    Resilience (DESIGN.md §4f): snapshots are phase-granular — between
    ``_grow_partition`` calls all transient state (score cache, pools,
    buckets) is empty, so a checkpoint is just the assignment plus edge
    flags and the random stream; resuming a same-config snapshot
    continues bit-identically, and a cross-engine snapshot (the
    degradation ladder) warm-starts every phase from its members.
    """
    if params is None:
        params = BatchedParams()
    if k < 1:
        raise ValueError("k must be >= 1")
    if params.t < 1 or params.b < 1 or params.s < 1:
        raise ValueError("b, s, t must all be >= 1")
    if params.pool_cap < 1:
        raise ValueError("pool_cap must be >= 1")
    if params.snapshot_every > 0 and not params.snapshot_dir:
        raise ValueError("snapshot_every requires snapshot_dir")
    st = BatchedState(hg, k, params)
    n = hg.n
    base, rem = divmod(n, k)
    snap_every = max(0, int(params.snapshot_every or 0))
    config = {"k": k, "t": params.t, "b": params.b, "s": params.s,
              "pool_cap": params.pool_cap, "refill_lo": params.refill_lo,
              "cap_pins": params.cap_pins,
              "kernel_min": params.kernel_min, "seed": params.seed,
              "snapshot_every": snap_every}
    start = 0
    warm = False
    ckpt = (resilience.load_latest(params.resume) if params.resume
            else None)
    if ckpt is not None:
        t0 = time.perf_counter()
        resilience.check_checkpoint(ckpt, hg, k)
        if ckpt.engine == "hype_batched" and ckpt.config == config:
            pay = ckpt.payload
            st.assignment = pay["assignment"].copy()
            st.edge_dead = pay["edge_dead"].copy()
            st.edge_epoch = pay["edge_epoch"].copy()
            st.rand_ptr = int(pay["rand_ptr"])
            st.rng.bit_generator.state = pay["rng_state"]
            st.stats = dataclasses.replace(pay["stats"])
            start = int(pay["next_phase"])
        else:
            wa = resilience.warm_assignment(ckpt)
            got = wa >= 0
            st.assignment[got] = wa[got]
            warm = True
        st.stats.resumed_at = int(ckpt.superstep)
        st.stats.restore_s += time.perf_counter() - t0
    last_snap = start
    for i in range(start, k):
        if i == k - 1:
            rem_v = np.flatnonzero(st.assignment < 0)
            st.assignment[rem_v] = i
            st.in_fringe[:] = False
            break
        _grow_partition(st, i, base + (1 if i < rem else 0), warm=warm)
        if snap_every and i + 1 - last_snap >= snap_every:
            t0 = time.perf_counter()
            st.stats.snapshots += 1
            resilience.save_snapshot(
                params.snapshot_dir,
                resilience.PartitionCheckpoint(
                    "hype_batched", i + 1, hg.fingerprint(),
                    dict(config),
                    {"assignment": st.assignment.copy(),
                     "edge_dead": st.edge_dead.copy(),
                     "edge_epoch": st.edge_epoch.copy(),
                     "rand_ptr": int(st.rand_ptr),
                     "rng_state": st.rng.bit_generator.state,
                     "stats": dataclasses.replace(st.stats),
                     "next_phase": i + 1}),
                keep_last=int(params.keep_last))
            st.stats.snapshot_s += time.perf_counter() - t0
            last_snap = i + 1
    assert (st.assignment >= 0).all()
    assignment = maybe_refine(hg, k, params, st.assignment, st.stats)
    if return_stats:
        return assignment, st.stats
    return assignment


__all__ = ["BatchedParams", "BatchedState", "BatchedStats",
           "hype_batched_partition"]
