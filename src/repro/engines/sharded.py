"""Mesh-sharded superstep engine (DESIGN.md §4c).

Phase groups sharded over a 1-D JAX device mesh with ``shard_map``: the
CSR graph image, assignment vector and score cache are *replicated* per
device, each device runs the fused ``hype_score_select`` superstep for
its own contiguous phase group, and ONE ``all_gather`` per superstep
exchanges fresh scores and proposed admissions so every replica stays
globally consistent — including the exact-decrement score-cache
invalidations. Cross-device admission conflicts are resolved
deterministically (lowest phase id wins).

Shares the pipeline driver (``engines.runtime.run_pipeline``) and host
state (``engines.pipeline.PipelineState``) with the single-device
engine: only the device program, the per-device-group pool masks and
the collective counters differ.
"""
from __future__ import annotations

import dataclasses
import functools as _functools
from typing import Optional

import numpy as np

from ..core.hypergraph import Hypergraph
from ..core import membudget
from ..core.scoring import (_apply_host_injections, _gather_fresh_tiles,
                            _poison_guard, _stale_masked_prev,
                            gather_csr_rows)
from .pipeline import PipelineState, _CallArgs, _Superstep
from .runtime import (BatchedStats, maybe_refine, run_pipeline_budgeted
                      as _run_pipeline_budgeted)
from .superstep import SuperstepParams, hype_superstep_partition


@dataclasses.dataclass
class ShardedParams(SuperstepParams):
    """Knobs for the mesh-sharded superstep engine (DESIGN.md §4c).

    Inherits every superstep knob. ``devices`` sets the 1-D mesh size the
    k phase groups are sharded over; ``None`` uses every local JAX device
    (capped at ``k``). On CPU, simulate a mesh with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
    """
    devices: Optional[int] = None


# ---------------------------------------------------------- sharded superstep
# Mesh-sharded superstep program: the per-superstep device work of the
# sharded engine, run under shard_map over a 1-D device mesh. The CSR
# image, assignment and score cache are *replicated* on every device;
# the k phase groups are sharded — each device gathers, scores and
# selects only its own contiguous group of phases, then ONE all_gather
# per superstep exchanges (fresh scores | admissions) so every replica
# applies the same cache writes, conflict resolution and exact-decrement
# invalidations. Replicas therefore stay bit-identical without ever
# shipping the (n,)-sized state between devices.


@_functools.lru_cache(maxsize=None)
def _sharded_mesh(num_devices: int):
    """1-D device mesh over the first ``num_devices`` local devices."""
    import jax
    import numpy as _np
    from jax.sharding import Mesh

    return Mesh(_np.asarray(jax.devices()[:num_devices]), ("shard",))


@_functools.lru_cache(maxsize=None)
def _sharded_program(num_devices: int, group_l: int, tile_l: int,
                     select_k: int, interpret: bool):
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.kernels.hype_score.kernel import SELECT_PAD
    from repro.kernels.hype_score.ops import hype_score_select_shard

    kL = group_l

    def step(indptr, indices, assign, cache, acc, poison, delta_ids,
             delta_vals, dirty_ids, dirty_counts, fresh, bias, pool,
             fringe, targets, reset):
        n = assign.shape[0]
        G, R = fresh.shape
        t = select_k
        assign0, cache0, acc0 = assign, cache, acc
        # 1. host injections + dirty decrements — replicated inputs,
        #    applied identically on every replica (shared helper keeps
        #    this program bit-aligned with the single-device one)
        assign, cache, acc = _apply_host_injections(
            assign, cache, acc, delta_ids, delta_vals, dirty_ids,
            dirty_counts)
        # 2. this device's phase-group shard; the admission cap is each
        #    phase's remaining target per the *device* totals (the host
        #    view may lag the pipeline, the replicas never do)
        off = jax.lax.axis_index("shard") * kL
        fresh_l = jax.lax.dynamic_slice_in_dim(fresh, off, kL, 0)
        pool_l = jax.lax.dynamic_slice_in_dim(pool, off, kL, 0)
        cap = jnp.maximum(targets - acc, 0)
        cap_l = jax.lax.dynamic_slice_in_dim(cap, off, kL, 0)
        # 3. gather ONLY the shard's fresh-candidate tiles from the
        #    replicated CSR
        flat = fresh_l.reshape(-1)
        tile = _gather_fresh_tiles(indptr, indices, assign, flat, tile_l)
        # 4. held pool scores from the replicated cache, stale slots
        #    masked — computed on the *global* pool so the count is
        #    replicated
        prev, n_stale = _stale_masked_prev(pool, assign, cache)
        # 5. fused score + top-select on the local phase group
        scores_l, sel_idx, sel_val = hype_score_select_shard(
            tile.reshape(kL, R, tile_l), fringe, bias, prev,
            select_k=t, shard_offset=off, interpret=interpret)
        # 6. map selected slots to vertex ids and apply the per-phase
        #    admission cap (remaining target): slots are score-ascending,
        #    so the cap keeps the best ``cap`` admissible ones.
        slots = jnp.concatenate([fresh_l, pool_l], axis=1)
        cand = jnp.take_along_axis(slots, sel_idx, axis=1)
        ok = (sel_val < jnp.float32(SELECT_PAD)) & (cand >= 0)
        ok &= assign[jnp.where(cand >= 0, cand, 0)] < 0
        rank = jnp.cumsum(ok.astype(jnp.int32), axis=1)
        adm = ok & (rank <= cap_l[:, None])
        adm_ids = jnp.where(adm, cand, -1)              # (kL, t)
        # 7. the superstep's single collective: all devices exchange
        #    [fresh scores | proposed admissions] in one all_gather
        payload = jnp.concatenate(
            [jax.lax.bitcast_convert_type(scores_l, jnp.int32), adm_ids],
            axis=1)                                     # (kL, R + t)
        gathered = jax.lax.all_gather(payload, "shard", axis=0,
                                      tiled=True)       # (G, R + t)
        g_scores = jax.lax.bitcast_convert_type(gathered[:, :R],
                                                jnp.float32)
        g_adm = gathered[:, R:]                         # (G, t)
        # 8. fresh scores enter every replica's cache (fresh ids are a
        #    replicated input, so the write is identical everywhere)
        flat_g = fresh.reshape(-1)
        cache = cache.at[jnp.where(flat_g >= 0, flat_g, n)].set(
            g_scores.reshape(-1), mode="drop")
        # 9. deterministic conflict resolution: when several phases
        #    propose the same vertex in one superstep, the LOWEST phase
        #    id wins; losers keep the vertex out and redraw from their
        #    pools next superstep. Sort (id, phase) pairs and keep each
        #    id's first occurrence.
        ids_f = g_adm.reshape(-1)                       # (G * t,)
        phase_f = (jax.lax.iota(jnp.int32, G * t) // t)
        ids_key = jnp.where(ids_f >= 0, ids_f, n)
        order = jnp.lexsort((phase_f, ids_key))
        sorted_ids = ids_f[order]
        first = jnp.concatenate(
            [jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]])
        win_sorted = first & (sorted_ids >= 0)
        winner = jnp.zeros((G * t,), bool).at[order].set(win_sorted)
        n_conflicts = ((ids_f >= 0) & ~winner).sum().astype(jnp.int32)
        # 10. apply the winners to every replica's assignment + totals
        assign = assign.at[jnp.where(winner, ids_f, n)].set(
            phase_f, mode="drop")
        acc = acc.at[phase_f].add(winner.astype(acc.dtype))
        # 11. exact-decrement invalidation for the winners: every
        #     neighbor of a newly assigned vertex has one fewer
        #     unassigned neighbor. Gather width is the run's tile_l;
        #     the (rare) winners with more neighbors than that get their
        #     tail decrements queued by the host into the next
        #     superstep's dirty buffer, keeping the cache exact.
        wsafe = jnp.where(winner, ids_f, 0)
        wstart = indptr[wsafe]
        wdeg = jnp.minimum(indptr[wsafe + 1] - wstart, tile_l)
        wcol = jax.lax.broadcasted_iota(jnp.int32, (G * t, tile_l), 1)
        wvalid = (wcol < wdeg[:, None]) & winner[:, None]
        wnbr = indices[jnp.where(wvalid, wstart[:, None] + wcol, 0)]
        cache = cache.at[jnp.where(wvalid, wnbr, n)].add(
            -1.0, mode="drop")
        winners = jnp.where(winner, ids_f, -1).reshape(G, t)
        # 12. NaN/inf quarantine on the *gathered* scores — replicated
        #     input to the guard, so every replica takes the same revert
        #     branch and the replicas stay bit-identical. No-op when
        #     clean (fault-free runs unchanged).
        poisoned = _poison_guard(flat_g, g_scores.reshape(-1), poison,
                                 reset)
        assign = jnp.where(poisoned, assign0, assign)
        cache = jnp.where(poisoned, cache0, cache)
        acc = jnp.where(poisoned, acc0, acc)
        winners = jnp.where(poisoned, -1, winners)
        n_conflicts = jnp.where(poisoned, 0, n_conflicts)
        n_stale = jnp.where(poisoned, 0, n_stale)
        poison = poisoned.astype(jnp.int32)[None]
        return assign, cache, acc, poison, winners, n_conflicts, n_stale

    mesh = _sharded_mesh(num_devices)
    rep = P()     # every array is replicated; devices differ via axis_index
    # poison undonated for the same reason as _pipeline_program: older
    # in-flight handles must still be able to read their poison output.
    return jax.jit(shard_map(
        step, mesh=mesh,
        in_specs=(rep,) * 16, out_specs=(rep,) * 7,
        check_rep=False), donate_argnums=(2, 3, 4))


def sharded_superstep_device(indptr, indices, assign, cache, acc,
                             poison, delta_ids, delta_vals, dirty_ids,
                             dirty_counts, fresh, bias, pool, fringe,
                             targets, reset, *, num_devices: int,
                             group_l: int, tile_l: int, select_k: int,
                             interpret: bool):
    """Run one mesh-sharded superstep; see ``_sharded_program``.

    ``fresh``/``bias``/``pool``/``fringe``/``targets`` stack all
    ``G = num_devices * group_l`` phases; each device processes the
    contiguous group ``[axis_index * group_l, ...)`` and ONE all_gather
    per call exchanges (fresh scores | proposed admissions), after which
    every replica applies identical cache writes, lowest-phase-wins
    conflict resolution and exact decrements. ``assign``/``cache``/
    ``acc``/``poison`` are DONATED — keep the returned arrays, never
    reuse the inputs. ``poison``/``reset`` are the (1,) int32 NaN
    quarantine flag and replay marker (see ``_poison_guard``); a
    poisoned superstep reverts every mutation on every replica and must
    be replayed by the host. Admission caps are each phase's remaining
    target computed against the device-resident ``acc`` totals, so they
    stay exact at any pipeline depth. Returns ``(assign', cache',
    acc', poison', winners (G, select_k) int32 ids (-1 = none),
    n_conflicts, n_stale)``.
    """
    return _sharded_program(num_devices, group_l, tile_l, select_k,
                            interpret)(
        indptr, indices, assign, cache, acc, poison, delta_ids,
        delta_vals, dirty_ids, dirty_counts, fresh, bias, pool, fringe,
        targets, reset)


# --------------------------------------------------------------------- #
class ShardedState(PipelineState):
    """Superstep state plus the mesh and per-device-group pool masks.

    The CSR image, assignment, score cache and admission totals are
    *replicated* on every mesh device; the phase groups are sharded.
    Pool membership is tracked per device group (``group_pool``) —
    groups draw candidates independently, so two groups may pool (and
    propose) the same vertex; the device program's lowest-phase-wins
    rule resolves it, and the host mirrors winners without re-queuing
    them as deltas. Shares the pipeline driver with the single-device
    engine: only ``dispatch`` (the shard_map program + collective
    counters) and the pool-mask hooks differ.
    """

    def __init__(self, hg: Hypergraph, k_padded: int, p: ShardedParams,
                 num_devices: int, mem_rung: int = 0):
        self.D = num_devices
        self.kL = k_padded // num_devices
        mesh = _sharded_mesh(num_devices)
        super().__init__(hg, k_padded, p, mesh=mesh, mem_rung=mem_rung)
        if self.dev is None:
            return
        self.mesh = mesh
        self.group_pool = np.zeros((num_devices, hg.n), dtype=bool)
        # the image lives once per device
        self.stats.device_image_bytes *= num_devices

    def group_of(self, g: int) -> int:
        return g // self.kL

    def _pmask(self, g: int) -> np.ndarray:
        return self.group_pool[g // self.kL]

    def _restart_mask(self) -> np.ndarray:
        # groups pool independently, so an injection-safe vertex must
        # sit in NO group's pool (it could be an in-flight slot there)
        return self.group_pool.any(axis=0)

    def release_pools(self) -> None:
        super().release_pools()
        self.group_pool[:] = False

    def _release_members(self, vs: np.ndarray, ph: np.ndarray) -> None:
        self.group_pool[ph // self.kL, vs] = False

    def _queue_decrements(self, vs: np.ndarray, exclude=()) -> None:
        """Sharded: the device program already decremented each winner's
        first ``tile_l`` neighbors; only the clipped tails of the (rare)
        wider winners ride the next dispatch's dirty pairs — with the
        same in-flight rescore exclusion as the single-device engine."""
        self.stats.cache_invalidations += int(
            np.minimum(self.deg[vs], self.tile_l).sum())
        wide = vs[self.deg[vs] > self.tile_l]
        if wide.size == 0:
            return
        indptr, indices = self.adj
        nbrs, owner = gather_csr_rows(indptr, indices, wide)
        lens = (indptr[wide + 1] - indptr[wide]).astype(np.int64)
        start = np.cumsum(lens) - lens
        off = np.arange(nbrs.size, dtype=np.int64) - start[owner]
        tail = self._filter_rescored(
            nbrs[off >= self.tile_l].astype(np.int64), exclude)
        if tail.size:
            self.pending_dirty.append(tail)

    def _to_device(self, arr: np.ndarray):
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec
        return jax.device_put(jnp.asarray(arr),
                              NamedSharding(self.mesh, PartitionSpec()))

    # the sharded dispatch site owns the per-superstep all_gather, so a
    # failed collective is injected (and retried) there too
    _fault_kinds = ("dispatch", "collective", "oom")
    # no chunked/spill/paged program variants exist for the replicated
    # shard_map image — only width and depth shrink (DESIGN.md §4g)
    _mem_features = membudget.SHARDED_FEATURES

    def _call_program(self, args: _CallArgs, reset: np.ndarray):
        """One mesh-sharded superstep (async).

        Host->device traffic is the same id/bias buffers as the
        single-device engine; the host-side dirty pairs carry the
        injections' neighbor multisets *and* the decrement tails of
        earlier wider-than-tile winners (the device clips its own
        decrement gather at ``tile_l``), so the replicated cache stays
        exact.
        """
        (self.dev_assign, self.dev_cache, self.dev_acc, self.dev_poison,
         winners, ncf, n_stale) = sharded_superstep_device(
            self.dev[0], self.dev[1], self.dev_assign, self.dev_cache,
            self.dev_acc, self.dev_poison, args.delta, args.vals,
            args.dirty, args.dcnt, args.fresh, args.bias, args.pool_arr,
            args.fringe, args.targets, reset, num_devices=self.D,
            group_l=self.kL, tile_l=self.tile_l,
            select_k=args.select_k, interpret=self.interpret)
        return winners, n_stale, ncf, None

    def _count_dispatch(self, fresh: np.ndarray, select_k: int) -> None:
        kG, R = fresh.shape
        # one all_gather per superstep: every device materializes the
        # global (kG, R + t) int32 payload of fresh scores + admissions
        self.stats.collectives += 1
        self.stats.collective_bytes += self.D * kG * (R + select_k) * 4

    def _count_harvest(self, handle: _Superstep) -> None:
        # the conflict count rides the harvested superstep's results, so
        # reading it here never adds a block
        self.stats.admission_conflicts += int(handle.ncf)

    def capture_payload(self, acc: np.ndarray, cur_depth: int) -> dict:
        pay = super().capture_payload(acc, cur_depth)
        pay["group_pool"] = self.group_pool.copy()
        return pay

    def restore_exact(self, pay: dict):
        out = super().restore_exact(pay)
        self.group_pool = pay["group_pool"].copy()
        return out


def hype_sharded_partition(hg: Hypergraph, k: int,
                           params: Optional[ShardedParams] = None,
                           return_stats: bool = False):
    """Partition ``hg`` with the mesh-sharded superstep engine.

    Same contract as ``hype_superstep_partition`` (complete int32
    assignment, ``max - min <= 1`` vertex balance, all k phases grown
    concurrently) but the phase groups are sharded over a 1-D JAX device
    mesh with ``shard_map``: the CSR graph image, assignment vector and
    score cache are replicated per device, each device runs the fused
    ``hype_score_select`` superstep for its own contiguous phase group,
    and a single ``all_gather`` per superstep exchanges fresh scores and
    proposed admissions so every replica stays globally consistent —
    including the exact-decrement score-cache invalidations. Cross-device
    admission conflicts (two groups proposing the same vertex in one
    superstep) are resolved deterministically: the lowest phase id wins
    and losers redraw from their pools next superstep.

    ``params.devices`` picks the mesh size (default: all local devices,
    capped at ``k``); on CPU simulate devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``. With one
    device the engine degenerates to (slightly reordered) single-device
    superstep growth. Supersteps run on the shared double-buffered
    pipeline (``params.pipeline_depth``, DESIGN.md §4d). Falls back to
    ``hype_superstep_partition``'s own fallback chain when the
    adjacency guard trips.
    """
    if params is None:
        params = ShardedParams()
    if params.rows is None:
        params = dataclasses.replace(params, rows=max(8, params.t))
    if k < 1:
        raise ValueError("k must be >= 1")
    if params.t < 1 or params.rows < 1 or params.pool_cap < 1:
        raise ValueError("rows, pool_cap, t must all be >= 1")
    if params.pipeline_depth < 1:
        raise ValueError("pipeline_depth must be >= 1")
    if params.snapshot_every > 0 and not params.snapshot_dir:
        raise ValueError("snapshot_every requires snapshot_dir")
    if params.devices is not None and params.devices < 1:
        raise ValueError("devices must be >= 1")
    if k == 1:
        out = np.zeros(hg.n, dtype=np.int32)
        return (out, BatchedStats()) if return_stats else out
    import jax
    avail = len(jax.devices())
    num = params.devices if params.devices is not None else avail
    num = max(1, min(num, avail, k))
    kG = (-(-k // num)) * num       # phase groups padded to the mesh
    assignment, st = _run_pipeline_budgeted(
        hg, k, params,
        lambda p2, rung: ShardedState(hg, kG, p2, num, mem_rung=rung),
        "hype_sharded", devices=num)
    if assignment is None:
        return hype_superstep_partition(hg, k, params, return_stats)
    assert (assignment >= 0).all()
    assignment = maybe_refine(hg, k, params, assignment, st.stats)
    if return_stats:
        return assignment, st.stats
    return assignment


__all__ = ["ShardedParams", "ShardedState", "hype_sharded_partition",
           "sharded_superstep_device"]
